//! Benchmark reporting: aligned ASCII tables for the console and CSV files
//! under `bench_out/` for plotting.

use std::fmt::Write as _;

/// A simple column-aligned table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Table {
        Table {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells);
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let line = |cells: &[String], widths: &[usize]| {
            let mut s = String::new();
            for i in 0..ncols {
                let _ = write!(s, "{:<w$}  ", cells[i], w = widths[i]);
            }
            s.trim_end().to_string()
        };
        let _ = writeln!(out, "{}", line(&self.headers, &widths));
        let total: usize = widths.iter().sum::<usize>() + 2 * ncols;
        let _ = writeln!(out, "{}", "-".repeat(total));
        for row in &self.rows {
            let _ = writeln!(out, "{}", line(row, &widths));
        }
        out
    }

    /// CSV encoding (headers + rows).
    pub fn to_csv(&self) -> String {
        let esc = |s: &String| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.clone()
            }
        };
        let mut out = String::new();
        let _ = writeln!(
            out,
            "{}",
            self.headers.iter().map(esc).collect::<Vec<_>>().join(",")
        );
        for row in &self.rows {
            let _ = writeln!(out, "{}", row.iter().map(esc).collect::<Vec<_>>().join(","));
        }
        out
    }
}

/// Write a table as CSV under `bench_out/` (created if needed).
pub fn write_csv(table: &Table, filename: &str) -> std::io::Result<std::path::PathBuf> {
    let dir = std::path::PathBuf::from("bench_out");
    std::fs::create_dir_all(&dir)?;
    let path = dir.join(filename);
    std::fs::write(&path, table.to_csv())?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_aligns_columns() {
        let mut t = Table::new("demo", &["kernel", "fpc"]);
        t.row(vec!["base".into(), "0.5".into()]);
        t.row(vec!["interleaved_blocked".into(), "2.75".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("interleaved_blocked"));
        // Header and rows share alignment column.
        let lines: Vec<&str> = s.lines().collect();
        let h = lines[1].find("fpc").unwrap();
        assert_eq!(lines[3].find("0.5"), Some(h + 0).map(|_| lines[3].find("0.5").unwrap()));
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["has,comma".into(), "has\"quote".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"has,comma\""));
        assert!(csv.contains("\"has\"\"quote\""));
    }

    #[test]
    #[should_panic(expected = "column count mismatch")]
    fn row_width_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
