//! Per-figure experiment drivers: each function regenerates one of the
//! paper's figures as an ASCII table + CSV (`bench_out/`). The benches in
//! `rust/benches/` are thin wrappers over these; the CLI exposes them via
//! `stgemm bench --figure <id>`.

use crate::autotune::grid::{unroll_grid_search, UNROLL_K_FACTORS, UNROLL_M_FACTORS};
use crate::bench::harness::{measure_kernel, BenchScale};
use crate::bench::report::Table;
use crate::kernels::KernelParams;
use crate::perf::opint::{format_bytes_model, operational_intensity, OpIntInputs};
use crate::perf::roofline::{host_peak_scalar_flops_per_cycle, M1_SCALAR_PEAK};

const SEED: u64 = 20250710;

fn fmt3(v: f64) -> String {
    format!("{v:.3}")
}

/// Figures 2–4: unroll-factor grid heatmaps. Paper: s=25%, M=32, N=1024,
/// K ∈ {1024 … 16384}; cells are speedups vs BaseTCSC.
pub fn fig2_unroll_grid(scale: BenchScale) -> Vec<Table> {
    let ks = scale.cap_ks(&[1024, 2048, 4096, 8192, 16384], 4096);
    let n = match scale {
        BenchScale::Full => 1024,
        BenchScale::Ci => 256,
    };
    let timer = scale.timer();
    let mut tables = Vec::new();
    for k in ks {
        let points = unroll_grid_search(32, k, n, 0.25, SEED, &timer);
        let mut t = Table::new(
            format!("Fig 2-4 grid: K={k} (speedup vs base, s=25%, M=32, N={n})"),
            &std::iter::once("KU\\MU".to_string())
                .chain(UNROLL_M_FACTORS.iter().map(|m| format!("MU={m}")))
                .collect::<Vec<_>>()
                .iter()
                .map(|s| s.as_str())
                .collect::<Vec<_>>(),
        );
        for &ku in &UNROLL_K_FACTORS {
            let mut row = vec![format!("KU={ku}")];
            for &mu in &UNROLL_M_FACTORS {
                let p = points
                    .iter()
                    .find(|p| p.ku == ku && p.mu == mu)
                    .expect("grid point");
                row.push(fmt3(p.speedup_vs_base));
            }
            t.row(row);
        }
        tables.push(t);
    }
    tables
}

/// Fig 6: performance (flops/cycle) over K for the scalar kernel family at
/// 50% sparsity. Paper: M=64, N=4096.
pub fn fig6_variants(scale: BenchScale) -> Table {
    let ks = scale.cap_ks(&[1024, 2048, 4096, 8192, 16384], 4096);
    let n = match scale {
        BenchScale::Full => 4096,
        BenchScale::Ci => 512,
    };
    let kernels = [
        "base_tcsc",
        "unrolled_tcsc_12",
        "unrolled_tcsc_k4_m4",
        "unrolled_blocked_tcsc_k4_m4",
        "interleaved_tcsc",
        "interleaved_blocked_tcsc",
    ];
    let timer = scale.timer();
    let mut headers = vec!["kernel".to_string()];
    headers.extend(ks.iter().map(|k| format!("K={k}")));
    let mut t = Table::new(
        format!("Fig 6: flops/cycle over K (s=50%, M=64, N={n})"),
        &headers.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    for kernel in kernels {
        let mut row = vec![kernel.to_string()];
        for &k in &ks {
            let m = measure_kernel(kernel, 64, k, n, 0.5, SEED, KernelParams::default(), &timer);
            row.push(fmt3(m.flops_per_cycle()));
        }
        t.row(row);
    }
    t
}

/// Fig 8: N-invariance. Paper: K=8192, M=8 — performance constant across N.
pub fn fig8_n_sweep(scale: BenchScale) -> Table {
    let k = match scale {
        BenchScale::Full => 8192,
        BenchScale::Ci => 2048,
    };
    let ns: &[usize] = &[256, 512, 1024, 2048, 4096];
    let ns = match scale {
        BenchScale::Full => ns.to_vec(),
        BenchScale::Ci => vec![256, 512, 1024],
    };
    let timer = scale.timer();
    let mut headers = vec!["kernel".to_string()];
    headers.extend(ns.iter().map(|n| format!("N={n}")));
    let mut t = Table::new(
        format!("Fig 8: flops/cycle over N (K={k}, M=8, s=25%)"),
        &headers.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    for kernel in ["base_tcsc", "interleaved_blocked_tcsc"] {
        let mut row = vec![kernel.to_string()];
        for &n in &ns {
            let m = measure_kernel(kernel, 8, k, n, 0.25, SEED, KernelParams::default(), &timer);
            row.push(fmt3(m.flops_per_cycle()));
        }
        t.row(row);
    }
    t
}

/// Fig 9: the best scalar kernel across sparsity × K, plus the baseline.
/// Paper: M=64, N=4096, B = min(K, 4096).
pub fn fig9_sparsity(scale: BenchScale) -> Table {
    let ks = scale.cap_ks(&[1024, 2048, 4096, 8192, 16384], 4096);
    let n = match scale {
        BenchScale::Full => 4096,
        BenchScale::Ci => 512,
    };
    let timer = scale.timer();
    let mut headers = vec!["kernel".to_string(), "sparsity".to_string()];
    headers.extend(ks.iter().map(|k| format!("K={k}")));
    let mut t = Table::new(
        format!("Fig 9: flops/cycle over K × sparsity (M=64, N={n}, B=min(K,4096))"),
        &headers.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    for kernel in ["interleaved_blocked_tcsc", "base_tcsc"] {
        for &s in &crate::PAPER_SPARSITIES {
            let mut row = vec![kernel.to_string(), format!("{:.4}", s)];
            for &k in &ks {
                let m = measure_kernel(kernel, 64, k, n, s, SEED, KernelParams::default(), &timer);
                row.push(fmt3(m.flops_per_cycle()));
            }
            t.row(row);
        }
    }
    t
}

/// Fig 10: operational-intensity heatmap (analytic — same estimate as the
/// paper: exact sparse-format size + X + Y + b bytes).
pub fn fig10_opint() -> Table {
    let ks = [1024usize, 2048, 4096, 8192, 16384];
    let m = 64usize;
    let n = 4096usize;
    let mut headers = vec!["sparsity".to_string()];
    headers.extend(ks.iter().map(|k| format!("K={k}")));
    let mut t = Table::new(
        format!("Fig 10: operational intensity (flops/byte), BaseTCSC model, M={m}, N={n}"),
        &headers.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    for &s in &crate::PAPER_SPARSITIES {
        let mut row = vec![format!("{s:.4}")];
        for &k in &ks {
            let oi = operational_intensity(&OpIntInputs {
                m,
                k,
                n,
                sparsity: s,
                format_bytes: format_bytes_model(k, n, s),
            });
            row.push(fmt3(oi));
        }
        t.row(row);
    }
    t
}

/// Fig 11: vectorized kernels over K at 25% sparsity, PReLU fused, plus the
/// best scalar. Paper: M=N=1024; cells also report speedup vs base.
pub fn fig11_simd(scale: BenchScale) -> Table {
    let ks = scale.cap_ks(&[512, 1024, 2048, 4096, 8192], 2048);
    let (m, n) = match scale {
        BenchScale::Full => (1024, 1024),
        BenchScale::Ci => (128, 256),
    };
    let timer = scale.timer();
    let params = KernelParams {
        prelu_alpha: Some(crate::kernels::PRELU_DEFAULT_ALPHA),
        ..Default::default()
    };
    let kernels = [
        "base_tcsc",
        "simd_vertical",
        "simd_horizontal",
        "simd_blocked_interleaved",
        "interleaved_blocked_tcsc", // best scalar (PReLU separate pass)
    ];
    let mut headers = vec!["kernel".to_string()];
    for k in &ks {
        headers.push(format!("K={k} fpc"));
        headers.push(format!("K={k} ×base"));
    }
    let mut t = Table::new(
        format!("Fig 11: vectorized kernels (s=25%, M={m}, N={n}, PReLU fused)"),
        &headers.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    // Baselines per K first.
    let mut base_fpc = Vec::new();
    for &k in &ks {
        let b = measure_kernel("base_tcsc", m, k, n, 0.25, SEED, params, &timer);
        base_fpc.push(b.flops_per_cycle());
    }
    for kernel in kernels {
        let mut row = vec![kernel.to_string()];
        for (i, &k) in ks.iter().enumerate() {
            let meas = measure_kernel(kernel, m, k, n, 0.25, SEED, params, &timer);
            let fpc = meas.flops_per_cycle();
            row.push(fmt3(fpc));
            row.push(fmt3(fpc / base_fpc[i]));
        }
        t.row(row);
    }
    t
}

/// E7 headline numbers: speedup and percent-of-peak at K=16384, s=50%
/// (paper: 5.98×, 50.2% of M1 scalar peak; baseline best 15.3%).
pub fn headline(scale: BenchScale) -> Table {
    let (k, n, m) = match scale {
        BenchScale::Full => (16384, 4096, 64),
        BenchScale::Ci => (4096, 512, 64),
    };
    let timer = scale.timer();
    let base = measure_kernel("base_tcsc", m, k, n, 0.5, SEED, KernelParams::default(), &timer);
    let best = measure_kernel(
        "interleaved_blocked_tcsc",
        m,
        k,
        n,
        0.5,
        SEED,
        KernelParams::default(),
        &timer,
    );
    let host_peak = host_peak_scalar_flops_per_cycle();
    let mut t = Table::new(
        format!("Headline: K={k}, N={n}, M={m}, s=50% (paper: 5.98x, 50.2% of peak)"),
        &["metric", "value"],
    );
    let bf = base.flops_per_cycle();
    let of = best.flops_per_cycle();
    t.row(vec!["base flops/cycle".into(), fmt3(bf)]);
    t.row(vec!["best flops/cycle".into(), fmt3(of)]);
    t.row(vec!["speedup".into(), fmt3(of / bf)]);
    t.row(vec![
        "host measured scalar peak (flops/cycle)".into(),
        fmt3(host_peak),
    ]);
    t.row(vec![
        "best as % of host peak".into(),
        format!("{:.1}%", 100.0 * of / host_peak),
    ]);
    t.row(vec![
        "best as % of M1-model peak (4 f/c)".into(),
        format!("{:.1}%", 100.0 * of / M1_SCALAR_PEAK),
    ]);
    t
}

/// E9 ablation: value compression vs unroll-5 baseline across sparsity
/// (paper: wins at 50%, ties at 25%, loses below).
pub fn ablation_compressed(scale: BenchScale) -> Table {
    let (m, k, n) = match scale {
        BenchScale::Full => (32, 4096, 1024),
        BenchScale::Ci => (8, 1024, 256),
    };
    let timer = scale.timer();
    let mut t = Table::new(
        format!("Ablation: value compression vs unrolled-5 (M={m}, K={k}, N={n})"),
        &[
            "sparsity",
            "unrolled5 fpc",
            "compressed(mul) fpc",
            "compressed(branch) fpc",
            "best ratio",
        ],
    );
    for &s in &crate::PAPER_SPARSITIES {
        let u5 = measure_kernel("unrolled_tcsc_5", m, k, n, s, SEED, KernelParams::default(), &timer);
        let cm = measure_kernel("compressed_ternary", m, k, n, s, SEED, KernelParams::default(), &timer);
        let cb = measure_kernel(
            "compressed_ternary_branch",
            m,
            k,
            n,
            s,
            SEED,
            KernelParams::default(),
            &timer,
        );
        let a = u5.flops_per_cycle();
        let (b, c) = (cm.flops_per_cycle(), cb.flops_per_cycle());
        t.row(vec![
            format!("{s:.4}"),
            fmt3(a),
            fmt3(b),
            fmt3(c),
            fmt3(b.max(c) / a),
        ]);
    }
    t
}

/// E10 ablation: inverted index vs base (paper: inverted is slower).
pub fn ablation_inverted(scale: BenchScale) -> Table {
    let (m, k, n) = match scale {
        BenchScale::Full => (32, 4096, 1024),
        BenchScale::Ci => (8, 1024, 256),
    };
    let timer = scale.timer();
    let mut t = Table::new(
        format!("Ablation: inverted index vs base (M={m}, K={k}, N={n})"),
        &["sparsity", "base fpc", "inverted fpc", "ratio"],
    );
    for &s in &crate::PAPER_SPARSITIES {
        let base = measure_kernel("base_tcsc", m, k, n, s, SEED, KernelParams::default(), &timer);
        let inv = measure_kernel("inverted_index", m, k, n, s, SEED, KernelParams::default(), &timer);
        let (a, b) = (base.flops_per_cycle(), inv.flops_per_cycle());
        t.row(vec![format!("{s:.4}"), fmt3(a), fmt3(b), fmt3(b / a)]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    // Figure drivers are exercised at CI scale with tiny shapes through the
    // benches; here we only check the cheap/analytic ones stay consistent.

    #[test]
    fn fig10_has_full_grid() {
        let t = fig10_opint();
        assert_eq!(t.rows.len(), crate::PAPER_SPARSITIES.len());
        assert_eq!(t.headers.len(), 6);
        // Denser rows have higher intensity in every K column.
        let first: f64 = t.rows[0][1].parse().unwrap(); // s=0.5
        let last: f64 = t.rows[3][1].parse().unwrap(); // s=0.0625
        assert!(first > last);
    }

    #[test]
    fn table_csv_roundtrip_shape() {
        let t = fig10_opint();
        let csv = t.to_csv();
        assert_eq!(csv.lines().count(), 1 + t.rows.len());
    }
}
