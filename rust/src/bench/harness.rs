//! Measurement protocol: warmup + median-of-reps cycle timing of planned
//! kernels, scaled by `STGEMM_BENCH_SCALE` (`full` = paper shapes, `ci` =
//! same shapes with fewer reps so `cargo bench` stays minutes-fast).
//!
//! Measurements run through [`crate::plan::GemmPlan`] — the same execution
//! path the serving engine uses — with the kernel pinned by name. When
//! `prelu_alpha` is set, fusing kernels fuse it and scalar kernels get the
//! separate epilogue pass, so the measured time matches what the cost
//! model's `with_prelu` counts (the old harness silently skipped PReLU for
//! non-fusing kernels).

use crate::kernels::{KernelId, KernelParams};
use crate::perf::flops::CostModel;
use crate::perf::timer::{CycleTimer, Measurement};
use crate::plan::{Epilogue, PlanHints, Planner};
use crate::tensor::Matrix;
use crate::ternary::TernaryMatrix;

/// Benchmark scale selected via `STGEMM_BENCH_SCALE`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BenchScale {
    /// Paper shapes, full reps.
    Full,
    /// Paper shapes, minimal reps (CI smoke).
    Ci,
}

impl BenchScale {
    pub fn from_env() -> BenchScale {
        match std::env::var("STGEMM_BENCH_SCALE").as_deref() {
            Ok("full") => BenchScale::Full,
            _ => BenchScale::Ci,
        }
    }

    pub fn timer(self) -> CycleTimer {
        match self {
            BenchScale::Full => CycleTimer::new(2, 5),
            BenchScale::Ci => CycleTimer::new(1, 2),
        }
    }

    /// Shrink a dimension list in CI mode (keeps curve shape, caps cost).
    pub fn cap_ks(self, ks: &[usize], cap: usize) -> Vec<usize> {
        match self {
            BenchScale::Full => ks.to_vec(),
            BenchScale::Ci => ks.iter().copied().filter(|&k| k <= cap).collect(),
        }
    }
}

/// One kernel measurement: name, shape, and performance.
#[derive(Debug, Clone)]
pub struct KernelMeasurement {
    pub kernel: String,
    pub m: usize,
    pub k: usize,
    pub n: usize,
    pub sparsity: f32,
    pub measurement: Measurement,
    pub flops: f64,
    /// Coefficient of variation of the cycle counts across the timer's
    /// reps (0 for a single rep) — run-to-run noise, consumed by the
    /// autotune sweep's self-calibrating divergence floor.
    pub cycles_cv: f64,
}

impl KernelMeasurement {
    pub fn flops_per_cycle(&self) -> f64 {
        self.measurement.flops_per_cycle(self.flops)
    }

    pub fn gflops(&self) -> f64 {
        self.measurement.gflops_per_second(self.flops)
    }
}

/// Measure one registry kernel on a synthetic workload.
///
/// Plan construction (format building, scratch pre-sizing) happens
/// *outside* the timed region (the paper benchmarks the GEMM, not format
/// conversion), and steady-state runs reuse the plan's scratch exactly as
/// serving does.
///
/// # Panics
/// On a name that is not a registry kernel. The harness is
/// programmer-facing (figure drivers and sweeps iterate
/// [`crate::kernels::kernel_names`]); user-supplied names must be
/// resolved with `name.parse::<KernelId>()` *before* reaching here so the
/// failure surfaces as [`crate::Error::UnknownKernel`], not a panic.
#[allow(clippy::too_many_arguments)] // a measurement is its full shape tuple
pub fn measure_kernel(
    name: &str,
    m: usize,
    k: usize,
    n: usize,
    sparsity: f32,
    seed: u64,
    params: KernelParams,
    timer: &CycleTimer,
) -> KernelMeasurement {
    let w = TernaryMatrix::random(k, n, sparsity, seed);
    let x = Matrix::random(m, k, seed + 1);
    let bias: Vec<f32> = (0..n).map(|i| (i % 13) as f32 * 0.05).collect();
    let planner = Planner::new();
    let kernel: KernelId = name.parse().expect("registry kernel");
    let hints = PlanHints {
        kernel: Some(kernel),
        expected_batch: m,
        ..Default::default()
    };
    let plan = planner
        .plan(
            &w,
            params,
            Epilogue::new(bias, 1.0, params.prelu_alpha),
            &hints,
        )
        .expect("registry kernel");
    let mut y = Matrix::zeros(m, n);
    let (measurement, cycles_cv) =
        timer.run_stats(|| plan.run(&x, &mut y).expect("bench kernels do not panic"));
    std::hint::black_box(y.as_slice());
    let mut cost = CostModel::new(m, k, n, sparsity);
    if params.prelu_alpha.is_some() {
        cost = cost.with_prelu();
    }
    KernelMeasurement {
        kernel: name.to_string(),
        m,
        k,
        n,
        sparsity,
        measurement,
        flops: cost.flops(),
        cycles_cv,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_produces_positive_performance() {
        let timer = CycleTimer::new(0, 1);
        let m = measure_kernel(
            "base_tcsc",
            4,
            128,
            32,
            0.25,
            7,
            KernelParams::default(),
            &timer,
        );
        assert!(m.flops_per_cycle() > 0.0);
        assert!(m.gflops() > 0.0);
        assert_eq!(m.flops, 4.0 * 32.0 * (1.0 + 0.25 * 128.0));
    }

    #[test]
    fn scale_from_env_defaults_ci() {
        // Note: don't set the env var here (tests run in parallel).
        let s = BenchScale::from_env();
        assert!(matches!(s, BenchScale::Ci | BenchScale::Full));
    }

    #[test]
    fn cap_ks_filters_in_ci() {
        let ks = [1024usize, 4096, 16384];
        assert_eq!(BenchScale::Ci.cap_ks(&ks, 4096), vec![1024, 4096]);
        assert_eq!(BenchScale::Full.cap_ks(&ks, 4096), ks.to_vec());
    }
}
