//! Benchmark harness (no criterion offline): measurement protocol, table /
//! CSV reporting, and the per-figure experiment drivers that regenerate the
//! paper's plots.

pub mod harness;
pub mod report;
pub mod figures;

pub use harness::{measure_kernel, BenchScale, KernelMeasurement};
pub use report::{write_csv, Table};
