//! Ternary matrices: dense `{-1, 0, +1}` representation, exact-sparsity
//! synthetic generation, absmean quantization of float weights, and
//! distribution statistics.

pub mod matrix;
pub mod quantize;
pub mod stats;

pub use matrix::TernaryMatrix;
pub use quantize::{quantize_absmean, QuantizedLinear};
pub use stats::TernaryStats;
