//! Absmean ternary quantization (BitNet-b1.58 style).
//!
//! The paper motivates sparse ternary GEMM with LLM weight quantization to
//! `{-1, 0, +1}`. This module provides the quantizer that produces those
//! weights from float matrices: scale by the mean absolute value, then
//! round-and-clip to the ternary set. The per-tensor scale is folded into
//! the layer so inference needs one multiply per output element (fused with
//! the bias add).

use crate::tensor::Matrix;
use crate::ternary::TernaryMatrix;

/// Result of quantizing a float weight matrix: ternary weights plus the
/// scale `gamma` such that `W_float ≈ gamma · W_ternary`.
#[derive(Debug, Clone)]
pub struct QuantizedLinear {
    pub weights: TernaryMatrix,
    pub scale: f32,
}

/// Absmean quantization: `gamma = mean(|W|)`,
/// `W_t = clip(round(W / gamma), -1, 1)`.
pub fn quantize_absmean(w: &Matrix) -> QuantizedLinear {
    let data = w.as_slice();
    let gamma = if data.is_empty() {
        1.0
    } else {
        let s: f64 = data.iter().map(|v| v.abs() as f64).sum();
        ((s / data.len() as f64) as f32).max(f32::MIN_POSITIVE)
    };
    let mut t = TernaryMatrix::zeros(w.rows(), w.cols());
    for i in 0..w.rows() {
        for j in 0..w.cols() {
            let q = (w[(i, j)] / gamma).round().clamp(-1.0, 1.0);
            t.set(i, j, q as i8);
        }
    }
    QuantizedLinear {
        weights: t,
        scale: gamma,
    }
}

impl QuantizedLinear {
    /// Dequantize back to floats (for error measurement).
    pub fn dequantize(&self) -> Matrix {
        Matrix::from_fn(self.weights.k(), self.weights.n(), |i, j| {
            self.weights.get(i, j) as f32 * self.scale
        })
    }

    /// Mean squared quantization error against the original weights.
    pub fn mse(&self, original: &Matrix) -> f64 {
        let dq = self.dequantize();
        let n = (original.rows() * original.cols()).max(1);
        original
            .as_slice()
            .iter()
            .zip(dq.as_slice())
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            / n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantizes_to_ternary() {
        let w = Matrix::random(32, 32, 17);
        let q = quantize_absmean(&w);
        assert!(q
            .weights
            .entries()
            .iter()
            .all(|&v| (-1..=1).contains(&v)));
        assert!(q.scale > 0.0);
    }

    #[test]
    fn exact_ternary_is_fixed_point() {
        // A matrix that is already gamma·ternary quantizes losslessly.
        let t = TernaryMatrix::random(16, 16, 0.5, 3);
        let gamma = 0.37f32;
        let w = Matrix::from_fn(16, 16, |i, j| t.get(i, j) as f32 * gamma);
        let q = quantize_absmean(&w);
        // absmean of gamma·ternary with 50% nonzeros is gamma/2; W/scale
        // = ±2 clips to ±1 — signs survive, magnitudes are ternary.
        for i in 0..16 {
            for j in 0..16 {
                assert_eq!(q.weights.get(i, j).signum(), t.get(i, j).signum());
            }
        }
    }

    #[test]
    fn sparsity_from_small_weights() {
        // Entries well below gamma round to zero → sparsity appears.
        let mut w = Matrix::zeros(8, 8);
        for i in 0..8 {
            w[(i, i)] = 4.0; // large diagonal
        }
        w[(0, 1)] = 0.01; // tiny off-diagonal
        let q = quantize_absmean(&w);
        assert_eq!(q.weights.get(0, 1), 0);
        assert_eq!(q.weights.get(3, 3), 1);
    }

    #[test]
    fn mse_reasonable() {
        let w = Matrix::random(64, 64, 23);
        let q = quantize_absmean(&w);
        // Uniform[-1,1): absmean 0.5; ternary approx error is bounded.
        assert!(q.mse(&w) < 0.25, "mse {}", q.mse(&w));
    }

    #[test]
    fn negative_weights_quantize_negative() {
        let w = Matrix::from_slice(1, 4, &[-2.0, -0.9, 0.9, 2.0]);
        let q = quantize_absmean(&w);
        assert_eq!(q.weights.get(0, 0), -1);
        assert_eq!(q.weights.get(0, 3), 1);
    }
}
