//! Distribution statistics for ternary matrices — used by the autotuner
//! (symmetric-format padding overhead depends on per-column sign balance)
//! and by benchmark reports.

use crate::ternary::TernaryMatrix;

/// Summary statistics of a ternary matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct TernaryStats {
    pub k: usize,
    pub n: usize,
    pub nnz: usize,
    pub positives: usize,
    pub negatives: usize,
    /// Min/mean/max nonzeros per column.
    pub col_nnz_min: usize,
    pub col_nnz_mean: f64,
    pub col_nnz_max: usize,
    /// Mean |#pos - #neg| per column (symmetric-format padding driver).
    pub mean_sign_imbalance: f64,
}

impl TernaryStats {
    pub fn compute(w: &TernaryMatrix) -> TernaryStats {
        let (k, n) = (w.k(), w.n());
        let mut positives = 0usize;
        let mut negatives = 0usize;
        let mut col_min = usize::MAX;
        let mut col_max = 0usize;
        let mut col_sum = 0usize;
        let mut imbalance_sum = 0usize;
        for j in 0..n {
            let mut p = 0usize;
            let mut q = 0usize;
            for i in 0..k {
                match w.get(i, j) {
                    1 => p += 1,
                    -1 => q += 1,
                    _ => {}
                }
            }
            positives += p;
            negatives += q;
            let c = p + q;
            col_min = col_min.min(c);
            col_max = col_max.max(c);
            col_sum += c;
            imbalance_sum += p.abs_diff(q);
        }
        if n == 0 {
            col_min = 0;
        }
        TernaryStats {
            k,
            n,
            nnz: positives + negatives,
            positives,
            negatives,
            col_nnz_min: col_min,
            col_nnz_mean: if n == 0 { 0.0 } else { col_sum as f64 / n as f64 },
            col_nnz_max: col_max,
            mean_sign_imbalance: if n == 0 {
                0.0
            } else {
                imbalance_sum as f64 / n as f64
            },
        }
    }

    /// Nonzero fraction.
    pub fn density(&self) -> f64 {
        if self.k * self.n == 0 {
            0.0
        } else {
            self.nnz as f64 / (self.k * self.n) as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_match_matrix() {
        let w = TernaryMatrix::random(64, 32, 0.25, 7);
        let s = TernaryStats::compute(&w);
        assert_eq!(s.nnz, w.nnz());
        assert_eq!(s.positives + s.negatives, s.nnz);
        assert!((s.density() - 0.25).abs() < 1e-9);
    }

    #[test]
    fn column_extremes() {
        let mut w = TernaryMatrix::zeros(4, 3);
        // col 0: 2 pos; col 1: empty; col 2: 1 pos 1 neg
        w.set(0, 0, 1);
        w.set(1, 0, 1);
        w.set(0, 2, 1);
        w.set(3, 2, -1);
        let s = TernaryStats::compute(&w);
        assert_eq!(s.col_nnz_min, 0);
        assert_eq!(s.col_nnz_max, 2);
        assert!((s.col_nnz_mean - 4.0 / 3.0).abs() < 1e-12);
        // imbalances: |2-0|=2, 0, |1-1|=0 → mean 2/3
        assert!((s.mean_sign_imbalance - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn empty_matrix() {
        let s = TernaryStats::compute(&TernaryMatrix::zeros(0, 0));
        assert_eq!(s.nnz, 0);
        assert_eq!(s.density(), 0.0);
    }

    #[test]
    fn balanced_generator_low_imbalance() {
        let w = TernaryMatrix::random(1024, 64, 0.5, 13);
        let s = TernaryStats::compute(&w);
        // Random balanced assignment: per-column imbalance ~ sqrt(nnz/col) ≈ 23
        // for 512/col; must be well below the nonzero count.
        assert!(s.mean_sign_imbalance < s.col_nnz_mean / 4.0);
    }
}
