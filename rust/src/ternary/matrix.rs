//! Dense ternary matrix (`i8` entries in {-1, 0, +1}) — the ground truth
//! from which every sparse format is constructed and validated.

use crate::util::rng::Rng;

/// Dense K×N ternary matrix, column-accessible. Stored row-major like the
/// mathematical `W` in `Y = X·W + b` (K rows, N columns).
#[derive(Debug, Clone, PartialEq)]
pub struct TernaryMatrix {
    k: usize,
    n: usize,
    data: Vec<i8>,
}

impl TernaryMatrix {
    /// All-zero K×N ternary matrix.
    pub fn zeros(k: usize, n: usize) -> TernaryMatrix {
        TernaryMatrix {
            k,
            n,
            data: vec![0; k * n],
        }
    }

    /// Build from raw entries (row-major, length K·N, values in {-1,0,1}).
    pub fn from_entries(k: usize, n: usize, entries: &[i8]) -> TernaryMatrix {
        assert_eq!(entries.len(), k * n, "shape/data mismatch");
        assert!(
            entries.iter().all(|&v| (-1..=1).contains(&v)),
            "entries must be ternary"
        );
        TernaryMatrix {
            k,
            n,
            data: entries.to_vec(),
        }
    }

    /// Random ternary matrix with *exactly* `round(sparsity·K·N)` nonzeros
    /// (paper workload: uniform placement, signs split as evenly as
    /// possible). `sparsity` is the paper's usage: fraction of nonzeros.
    pub fn random(k: usize, n: usize, sparsity: f32, seed: u64) -> TernaryMatrix {
        assert!((0.0..=1.0).contains(&sparsity), "sparsity in [0,1]");
        let total = k * n;
        let nnz = (sparsity as f64 * total as f64).round() as usize;
        let mut rng = Rng::new(seed);
        let positions = rng.sample_indices(total, nnz);
        let mut data = vec![0i8; total];
        // Balanced signs: first half +1, second half -1, assignment order
        // randomized by the already-random position sampling, then shuffled
        // again so ties don't correlate with position order.
        let mut signs: Vec<i8> = (0..nnz).map(|i| if i < nnz / 2 { -1 } else { 1 }).collect();
        rng.shuffle(&mut signs);
        for (pos, sign) in positions.into_iter().zip(signs) {
            data[pos] = sign;
        }
        TernaryMatrix { k, n, data }
    }

    #[inline]
    pub fn k(&self) -> usize {
        self.k
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    /// Entry at (row `i` ∈ [0,K), column `j` ∈ [0,N)).
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> i8 {
        debug_assert!(i < self.k && j < self.n);
        self.data[i * self.n + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: i8) {
        debug_assert!((-1..=1).contains(&v));
        self.data[i * self.n + j] = v;
    }

    /// Number of nonzero entries.
    pub fn nnz(&self) -> usize {
        self.data.iter().filter(|&&v| v != 0).count()
    }

    /// Actual nonzero fraction.
    pub fn density(&self) -> f64 {
        if self.data.is_empty() {
            0.0
        } else {
            self.nnz() as f64 / self.data.len() as f64
        }
    }

    /// Row indices of +1 entries in column `j`, ascending.
    pub fn col_positives(&self, j: usize) -> Vec<u32> {
        (0..self.k)
            .filter(|&i| self.get(i, j) == 1)
            .map(|i| i as u32)
            .collect()
    }

    /// Row indices of -1 entries in column `j`, ascending.
    pub fn col_negatives(&self, j: usize) -> Vec<u32> {
        (0..self.k)
            .filter(|&i| self.get(i, j) == -1)
            .map(|i| i as u32)
            .collect()
    }

    /// Raw row-major entries.
    pub fn entries(&self) -> &[i8] {
        &self.data
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_nnz_count() {
        for &s in &[0.5f32, 0.25, 0.125, 0.0625] {
            let w = TernaryMatrix::random(128, 64, s, 11);
            let expect = (s as f64 * (128 * 64) as f64).round() as usize;
            assert_eq!(w.nnz(), expect, "sparsity {s}");
        }
    }

    #[test]
    fn signs_balanced() {
        let w = TernaryMatrix::random(100, 100, 0.5, 5);
        let pos = w.entries().iter().filter(|&&v| v == 1).count();
        let neg = w.entries().iter().filter(|&&v| v == -1).count();
        assert!(pos.abs_diff(neg) <= 1, "pos {pos} neg {neg}");
    }

    #[test]
    fn deterministic_by_seed() {
        let a = TernaryMatrix::random(32, 32, 0.25, 3);
        let b = TernaryMatrix::random(32, 32, 0.25, 3);
        assert_eq!(a, b);
        let c = TernaryMatrix::random(32, 32, 0.25, 4);
        assert_ne!(a, c);
    }

    #[test]
    fn col_accessors_sorted_and_correct() {
        let w = TernaryMatrix::from_entries(
            4,
            2,
            // column 0: +1 at rows 0,3; -1 at row 2. column 1: -1 at rows 0,1
            &[1, -1, 0, -1, -1, 0, 1, 0],
        );
        assert_eq!(w.col_positives(0), vec![0, 3]);
        assert_eq!(w.col_negatives(0), vec![2]);
        assert_eq!(w.col_positives(1), Vec::<u32>::new());
        assert_eq!(w.col_negatives(1), vec![0, 1]);
    }

    #[test]
    fn zero_and_full_sparsity() {
        let z = TernaryMatrix::random(16, 16, 0.0, 1);
        assert_eq!(z.nnz(), 0);
        let f = TernaryMatrix::random(16, 16, 1.0, 1);
        assert_eq!(f.nnz(), 256);
    }

    #[test]
    #[should_panic(expected = "entries must be ternary")]
    fn from_entries_rejects_nonternary() {
        TernaryMatrix::from_entries(1, 2, &[0, 2]);
    }

    #[test]
    fn density_matches() {
        let w = TernaryMatrix::random(64, 64, 0.125, 9);
        assert!((w.density() - 0.125).abs() < 1e-9);
    }
}
