//! `stgemm` — the Sparse Ternary GEMM serving stack CLI.
//!
//! Subcommands:
//! - `serve`     start the HTTP inference server
//! - `bench`     regenerate a paper figure (`--figure fig2|fig6|fig8|fig9|
//!               fig10|fig11|headline|ablation_compressed|ablation_inverted|all`)
//! - `autotune`  run the unroll grid search for a shape
//! - `quantize`  generate + absmean-quantize a float model, save as .stw
//! - `selftest`  cross-check native kernels against the PJRT artifact
//! - `loadgen`   drive a running server with concurrent clients
//! - `generate`  short end-to-end decode run: bursty sessions through the
//!               continuous-batching scheduler (CI's decode smoke)
//!
//! This file is the **error boundary**: every library failure arrives as a
//! typed [`stgemm::Error`], is printed once, and maps to a process exit
//! code via [`stgemm::Error::exit_code`] (2 = usage/configuration, 1 =
//! runtime failure) — no library error panics the CLI.

use std::sync::Arc;
use std::time::Duration;

use stgemm::autotune::{
    sweep_model_opts, unroll_grid_search, CacheModel, SweepOptions, TuningTable,
};
use stgemm::bench::figures;
use stgemm::bench::harness::BenchScale;
use stgemm::bench::report::{write_csv, Table};
use stgemm::coordinator::server::{Server, ServerConfig};
use stgemm::coordinator::{
    Backend, BatchPolicy, DecodeConfig, DecodeLoadGen, Engine, LoadControlConfig,
    LoadGenerator, LoadOptions, ModelRegistry, Router,
};
use stgemm::model::{ModelConfig, TernaryMlp};
use stgemm::perf::timer::CycleTimer;
use stgemm::plan::{PlanHints, Planner};
use stgemm::runtime::artifacts::default_artifacts_dir;
use stgemm::runtime::{Manifest, XlaExecutor};
use stgemm::tensor::Matrix;
use stgemm::util::cli::Args;
use stgemm::util::PlacementPolicy;
use stgemm::{Error, Result};

fn main() {
    let args = Args::parse();
    let result = match args.subcommand.as_deref() {
        Some("serve") => cmd_serve(&args),
        Some("bench") => cmd_bench(&args),
        Some("autotune") => cmd_autotune(&args),
        Some("quantize") => cmd_quantize(&args),
        Some("selftest") => cmd_selftest(&args),
        Some("loadgen") => cmd_loadgen(&args),
        Some("generate") => cmd_generate(&args),
        _ => {
            print_usage();
            Ok(if args.has("help") || args.subcommand.is_none() {
                0
            } else {
                2
            })
        }
    };
    let code = match result {
        Ok(code) => code,
        Err(e) => {
            eprintln!("error: {e}");
            e.exit_code()
        }
    };
    std::process::exit(code);
}

fn print_usage() {
    eprintln!(
        "stgemm — Sparse Ternary GEMM serving stack

USAGE: stgemm <subcommand> [options]

  serve      --model <cfg.json> --addr 127.0.0.1:9000 --backend native|xla
             [--models <dir|cfg.json,cfg.json,…>] [--queue-budget N]
             [--tuning <table.json>] [--threads N] [--artifacts <dir>]
             [--max-batch 8] [--max-wait-us 2000] [--no-pipeline]
             [--no-autoscale] [--max-batch-cap 64] [--max-threads N]
             [--target-queue-us 2000] [--retune-secs N]
             [--decode-sessions 4] [--decode-max-tokens 32]
             [--placement perf|compact|spread|none] [--no-pin]
             (load-aware by default: max_batch and threads track observed
              queue depth / arrival rate; --models serves a fleet through
              the model registry — a directory is scanned for *.json
              configs — with the shared thread budget re-split by demand;
              --queue-budget rejects submits 429-style past N queued
              requests per model; models can also be loaded/unloaded at
              runtime via POST /load_model and /unload; --retune-secs
              re-sweeps the tuning table in the background every N
              seconds; multi-layer forwards are wavefront-pipelined unless
              --no-pipeline restores the per-layer barrier path; worker
              placement pins pool threads to performance cores by default
              — --placement picks the policy, --no-pin leaves scheduling
              to the OS; without --max-threads the budget is the
              performance-core count)
  bench      --figure fig2|fig6|fig8|fig9|fig10|fig11|headline|
                      ablation_compressed|ablation_inverted|all [--csv]
  autotune   [--m 32] [--k 4096] [--n 1024] [--sparsity 0.25]
             [--save <table.json>]  (measure registry kernels, persist the
                                     winner for the planner to consult)
  autotune sweep
             [--model <cfg.json>] [--buckets 1,8] [--reps 2]
             [--per-m] [--geometry] [--divergence 0.08]
             [--save <table.json>]  (fill the table for every layer ×
                                     M-bucket of a model config in one run;
                                     --per-m records k{{K}}_s{{S}}_m{{M}} entries
                                     for buckets whose winner diverges from
                                     the mean winner beyond the threshold;
                                     --geometry also measures each tile
                                     kernel across the cache-derived
                                     panel-width × K-block candidates and
                                     records a winner geometry only when it
                                     beats the default beyond the threshold;
                                     the threshold self-calibrates: it is
                                     clamped to the variance floor measured
                                     across --reps repetitions)
  quantize   --dims 256,1024,256 --seed 42 --out model.stw
  selftest   [--artifacts <dir>] [--model ffn_tiny]
  loadgen    --addr <host:port> --model <name> --d-in <n>
             [--clients 8] [--requests 100] [--timeout-s 30]
             [--generate] [--sessions 8] [--burst 4] [--burst-gap-ms 2]
             [--mean-tokens 16]
             (--generate switches to the decode workload: bursty
              autoregressive sessions streaming POST /generate, reported
              as tokens/sec + inter-token latency)
  generate   [--model <cfg.json>] [--sessions 4] [--burst 2]
             [--burst-gap-ms 1] [--mean-tokens 8] [--decode-sessions 4]
             [--threads N] [--seed 3] [--no-pin]
             (in-process decode smoke: loads the config — default demo —
              and runs bursty sessions through the continuous-batching
              scheduler; exits non-zero on any session error)"
    );
}

/// Resolve a `--models` spec — a directory of `*.json` configs or a
/// comma-separated path list — to config file paths.
fn model_config_paths(spec: &str) -> Result<Vec<String>> {
    let p = std::path::Path::new(spec);
    if p.is_dir() {
        let mut paths: Vec<String> = std::fs::read_dir(p)
            .map_err(|e| Error::io(format!("read dir {spec}"), e))?
            .filter_map(|entry| entry.ok())
            .map(|entry| entry.path())
            .filter(|path| path.extension().is_some_and(|x| x == "json"))
            .map(|path| path.to_string_lossy().into_owned())
            .collect();
        paths.sort();
        Ok(paths)
    } else {
        Ok(spec
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect())
    }
}

fn cmd_serve(args: &Args) -> Result<i32> {
    // Model set: `--models <dir|comma-list>` serves a fleet through the
    // registry; `--model` keeps the single-model path (the only one XLA
    // artifacts can attach to). Either way, more models can be loaded and
    // unloaded at runtime via POST /load_model and /unload.
    let mut configs: Vec<ModelConfig> = Vec::new();
    if let Some(spec) = args.get("models") {
        for path in model_config_paths(spec)? {
            configs.push(ModelConfig::from_file(&path)?);
        }
        if configs.is_empty() {
            return Err(Error::Config(format!("--models '{spec}' names no configs")));
        }
    } else {
        configs.push(match args.get("model") {
            Some(path) => ModelConfig::from_file(path)?,
            None => {
                eprintln!("[serve] no --model given; serving the default demo config");
                ModelConfig::default()
            }
        });
    }
    for cfg in &mut configs {
        cfg.threads = args.usize("threads", cfg.threads).max(1);
        // Wavefront pipelining is the default for multi-layer models;
        // --no-pipeline restores the per-layer barrier path (escape hatch
        // for debugging and A/B measurement — outputs are bitwise
        // identical).
        if args.has("no-pipeline") {
            cfg.pipeline = false;
        }
    }
    {
        let mut names: Vec<&str> = configs.iter().map(|c| c.name.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        if names.len() != configs.len() {
            return Err(Error::Config("duplicate model names in --models".into()));
        }
    }
    let backend: Backend = args.get_or("backend", "native").parse()?;
    if backend == Backend::Xla && configs.len() > 1 {
        return Err(Error::Config(
            "--backend xla serves a single model; use --model, not --models".into(),
        ));
    }
    // Kernel selection: measured tuning table when given, paper heuristics
    // (refined by the plan cache's online top-2 race on first traffic)
    // otherwise; the config's `kernel` key stays an explicit override.
    // This planner is the whole fleet's shared substrate: every model's
    // plan cache layers on it, so tuning learned by one model serves all.
    let have_table = args.get("tuning").is_some();
    let planner = Arc::new(match args.get("tuning") {
        Some(path) => {
            let p = Planner::from_table_file(path)?;
            println!(
                "[serve] tuning table: {path} ({} classes)",
                p.tuned_classes()
            );
            p
        }
        None => Planner::new(),
    });
    let default_threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4);
    // Worker placement: pool workers pin to performance cores by default;
    // `--placement compact|spread` opts into per-core policies and
    // `--no-pin` (or `--placement none`) leaves scheduling to the OS.
    // Placement moves work, never changes it — outputs stay bitwise
    // identical either way.
    let placement = if args.has("no-pin") {
        PlacementPolicy::None
    } else {
        match args.get("placement") {
            Some(s) => s.parse::<PlacementPolicy>().map_err(Error::Config)?,
            None => PlacementPolicy::default(),
        }
    };
    // Without an explicit --max-threads the thread budget is a *core*
    // budget: the topology's performance-core count under any placing
    // policy, host parallelism under `none`.
    let registry = Arc::new(match args.get("max-threads") {
        Some(_) => {
            planner.set_placement(placement);
            ModelRegistry::with_thread_budget(
                Arc::clone(&planner),
                args.usize("max-threads", default_threads),
            )
        }
        None => ModelRegistry::with_placement(Arc::clone(&planner), placement),
    });
    let thread_budget = registry.thread_budget();
    println!(
        "[serve] placement: {placement} over {} (core budget {thread_budget})",
        planner.topology().describe()
    );
    let policy = BatchPolicy {
        max_batch: args.usize("max-batch", 8),
        max_wait: Duration::from_micros(args.u64("max-wait-us", 2000)),
    };
    let control = if args.has("no-autoscale") {
        None
    } else {
        let control = LoadControlConfig {
            target_queue_us: args.u64("target-queue-us", 2000),
            min_batch: 1,
            max_batch: args.usize("max-batch-cap", 64).max(policy.max_batch),
            max_threads: thread_budget,
            adjust_every_batches: 16,
            ..LoadControlConfig::default()
        };
        println!(
            "[serve] autoscale: batch ≤ {}, threads ≤ {}, queue budget {} µs",
            control.max_batch, control.max_threads, control.target_queue_us
        );
        Some(control)
    };
    for cfg in &configs {
        let mut engine = Engine::from_config(cfg, &planner)?;
        if backend == Backend::Xla || args.get("artifacts").is_some() {
            let dir = args
                .get("artifacts")
                .map(std::path::PathBuf::from)
                .unwrap_or_else(default_artifacts_dir);
            match attach_xla(&dir, &cfg.name) {
                Ok(xla) => engine = engine.with_xla(xla),
                Err(e) => {
                    if backend == Backend::Xla {
                        return Err(e);
                    }
                    eprintln!(
                        "warning: XLA artifacts unavailable, serving native only: {e}"
                    );
                }
            }
        }
        let engine = engine.with_backend(backend);
        // `warm: true` compiles plans for the configured buckets at every
        // reachable thread step before the model's serving threads start —
        // but only for layers whose kernel choice is settled (an explicit
        // override or a tuning-table entry). Untuned buckets stay cold so
        // their first real traffic races the top-2 candidates.
        registry.load_engine(
            engine,
            LoadOptions {
                policy,
                control: control.clone(),
                queue_budget: args.usize("queue-budget", cfg.queue_budget),
                warm: true,
                buckets: cfg.batch_buckets.clone(),
                decode: DecodeConfig {
                    max_sessions: args.usize(
                        "decode-sessions",
                        DecodeConfig::default().max_sessions,
                    ),
                    default_max_tokens: args.usize(
                        "decode-max-tokens",
                        DecodeConfig::default().default_max_tokens,
                    ),
                    // The decode tick thread runs M=1 steps inline:
                    // compact-pin it to the first performance core unless
                    // serving is unpinned altogether.
                    placement: match placement {
                        PlacementPolicy::None => PlacementPolicy::None,
                        _ => PlacementPolicy::Compact,
                    },
                },
            },
        )?;
        if have_table {
            println!(
                "[serve] model '{}': plan cache warmed for buckets {:?} \
                 (tuned/pinned layers only)",
                cfg.name, cfg.batch_buckets
            );
        }
    }
    if configs.len() > 1 {
        // Re-split the fleet thread budget by observed demand twice a
        // second so one hot model cannot starve its neighbours.
        registry.start_balancer(Duration::from_millis(500));
        println!(
            "[serve] fleet balancer: {} models sharing a {thread_budget}-thread budget",
            configs.len()
        );
    }
    let router = Router::with_registry(Arc::clone(&registry));
    // Background re-tune: periodically re-sweep every layer × bucket on a
    // snapshot of the live table, install the result, and rebuild each
    // loaded model's plan cache so the next batches pick up the fresh
    // winners. Caches are resolved through the registry at tick time, so
    // models loaded or unloaded over HTTP are picked up / dropped
    // automatically.
    let retune_secs = args.u64("retune-secs", 0);
    if retune_secs > 0 {
        let planner_bg = Arc::clone(&planner);
        let registry_bg = Arc::clone(&registry);
        let configs_bg = configs.clone();
        std::thread::Builder::new()
            .name("stgemm-retune".into())
            .spawn(move || loop {
                std::thread::sleep(Duration::from_secs(retune_secs));
                let mut table = planner_bg.table_snapshot();
                let timer = CycleTimer::new(1, 2);
                let mut refreshed = 0usize;
                for cfg in &configs_bg {
                    // Serving races kernels per M bucket, so the
                    // background re-tune records per-bucket winners too —
                    // a mean-collapsed entry would undo what the online
                    // races learned.
                    let report = sweep_model_opts(
                        cfg,
                        &cfg.batch_buckets,
                        stgemm::kernels::available_kernel_ids(),
                        &timer,
                        &mut table,
                        &SweepOptions {
                            per_m: true,
                            ..Default::default()
                        },
                    );
                    refreshed += report.winners.len();
                }
                planner_bg.install_table(table);
                // Swap fresh plans in off the hot path; traffic always
                // finds a plan, and only changed winners pay a format
                // build.
                for (name, handle) in registry_bg.handles() {
                    if let Some(cache) = handle.engine().plan_cache() {
                        if let Err(e) = cache.rebuild() {
                            eprintln!("[serve] re-tune rebuild failed for '{name}': {e}");
                        }
                    }
                }
                println!(
                    "[serve] background re-tune: {refreshed} class(es) refreshed"
                );
            })
            .expect("spawn retune thread");
        println!("[serve] background re-tune every {retune_secs}s");
    }
    let router = Arc::new(router);
    let server = Server::start(
        Arc::clone(&router),
        ServerConfig {
            addr: args.get_or("addr", "127.0.0.1:9000").to_string(),
            workers: args.usize("workers", 8),
            ..Default::default()
        },
    )
    .map_err(|e| Error::io("start server", e))?;
    for cfg in &configs {
        println!(
            "[serve] model '{}' ({} → {}) backend={backend:?} pipeline={}",
            cfg.name,
            cfg.d_in(),
            cfg.d_out(),
            if cfg.pipeline { "wavefront" } else { "barrier" }
        );
    }
    println!(
        "[serve] fleet of {} on http://{} (/infer /generate /load_model /unload /status /metrics)",
        configs.len(),
        server.local_addr
    );
    // Serve until killed.
    loop {
        std::thread::sleep(Duration::from_secs(3600));
    }
}

fn attach_xla(dir: &std::path::Path, base: &str) -> Result<XlaExecutor> {
    let manifest = Manifest::load(dir)?;
    XlaExecutor::spawn(&manifest, base).map_err(|e| Error::Runtime(format!("{e:#}")))
}

fn emit(tables: Vec<Table>, csv: bool) {
    for t in tables {
        println!("{}", t.render());
        if csv {
            let slug: String = t
                .title
                .chars()
                .take(40)
                .map(|c| if c.is_alphanumeric() { c } else { '_' })
                .collect();
            match write_csv(&t, &format!("{slug}.csv")) {
                Ok(p) => println!("  [csv] {}", p.display()),
                Err(e) => eprintln!("  [csv] write failed: {e}"),
            }
        }
    }
}

fn cmd_bench(args: &Args) -> Result<i32> {
    let scale = BenchScale::from_env();
    let csv = args.has("csv");
    let figure = args.get_or("figure", "all");
    let run = |name: &str| -> Vec<Table> {
        match name {
            "fig2" => figures::fig2_unroll_grid(scale),
            "fig6" => vec![figures::fig6_variants(scale)],
            "fig8" => vec![figures::fig8_n_sweep(scale)],
            "fig9" => vec![figures::fig9_sparsity(scale)],
            "fig10" => vec![figures::fig10_opint()],
            "fig11" => vec![figures::fig11_simd(scale)],
            "headline" => vec![figures::headline(scale)],
            "ablation_compressed" => vec![figures::ablation_compressed(scale)],
            "ablation_inverted" => vec![figures::ablation_inverted(scale)],
            other => {
                eprintln!("unknown figure '{other}'");
                Vec::new()
            }
        }
    };
    if figure == "all" {
        for f in [
            "fig2",
            "fig6",
            "fig8",
            "fig9",
            "fig10",
            "fig11",
            "headline",
            "ablation_compressed",
            "ablation_inverted",
        ] {
            emit(run(f), csv);
        }
    } else {
        let tables = run(figure);
        if tables.is_empty() {
            return Ok(2);
        }
        emit(tables, csv);
    }
    Ok(0)
}

fn cmd_autotune(args: &Args) -> Result<i32> {
    if args.positional.first().map(String::as_str) == Some("sweep") {
        return cmd_autotune_sweep(args);
    }
    let m = args.usize("m", 32);
    let k = args.usize("k", 4096);
    let n = args.usize("n", 1024);
    let s = args.f32("sparsity", 0.25);
    let timer = CycleTimer::new(1, 3);
    println!("[autotune] grid search M={m} K={k} N={n} s={s}");
    let points = unroll_grid_search(m, k, n, s, 7, &timer);
    let best = stgemm::autotune::grid::best_point(&points);
    let cache = CacheModel::detect();
    println!(
        "best: KU={} MU={} at {:.3} flops/cycle ({:.2}x vs base)",
        best.ku, best.mu, best.flops_per_cycle, best.speedup_vs_base
    );
    println!(
        "cache model: L1d={} KiB, LLC={} MiB → predicted MU={}, block={}",
        cache.l1d_bytes / 1024,
        cache.llc_bytes / (1024 * 1024),
        cache.predicted_mu(k),
        cache.recommended_block(4)
    );
    // Registry-level tuning: measure every kernel for this shape class and
    // persist the winner where `serve --tuning` / the Planner can find it.
    if let Some(path) = args.get("save") {
        // A missing file starts a fresh table; an existing-but-unreadable
        // one is an error (silently clobbering measured entries is worse).
        let mut table = if std::path::Path::new(path).exists() {
            TuningTable::load(path)?
        } else {
            TuningTable::new()
        };
        let entry = table.tune(k, s, stgemm::kernels::available_kernel_ids(), &timer);
        table.save(path)?;
        println!(
            "[autotune] class (K={k}, s={s}): winner {} at {:.3} flops/cycle → {path} ({} classes)",
            entry.kernel,
            entry.flops_per_cycle,
            table.len()
        );
    }
    Ok(0)
}

/// `stgemm autotune sweep`: one run that measures every registry kernel
/// for every distinct layer class of a model config, at every batch
/// bucket, and persists the winners where `serve --tuning` finds them.
fn cmd_autotune_sweep(args: &Args) -> Result<i32> {
    let cfg = match args.get("model") {
        Some(path) => ModelConfig::from_file(path)?,
        None => {
            eprintln!("[autotune] no --model given; sweeping the default demo config");
            ModelConfig::default()
        }
    };
    let buckets = args.usize_list("buckets", &cfg.batch_buckets);
    let reps = args.usize("reps", 2).max(1);
    let opts = SweepOptions {
        per_m: args.has("per-m"),
        divergence_threshold: args.f32("divergence", 0.08) as f64,
        geometry: args.has("geometry"),
    };
    let timer = CycleTimer::new(1, reps);
    // Extend an existing table when --save points at one; a fresh file
    // starts empty. An existing-but-unreadable table is an error (silently
    // clobbering measured entries is worse).
    let mut table = match args.get("save") {
        Some(path) if std::path::Path::new(path).exists() => TuningTable::load(path)?,
        _ => TuningTable::new(),
    };
    println!(
        "[autotune] sweep: model '{}' ({} layer(s)), buckets {:?}, {} kernel(s){}",
        cfg.name,
        cfg.dims.len() - 1,
        buckets,
        stgemm::kernels::available_kernel_ids().len(),
        if opts.per_m {
            format!(
                ", per-M splits beyond {:.0}% divergence",
                opts.divergence_threshold * 100.0
            )
        } else {
            String::new()
        }
    );
    if opts.geometry {
        let candidates = stgemm::perf::geometry_candidates(&stgemm::perf::CpuCaps::host());
        println!(
            "[autotune] geometry sweep: {} candidate(s) per tile kernel: {}",
            candidates.len(),
            candidates
                .iter()
                .map(|g| g.name())
                .collect::<Vec<_>>()
                .join(", ")
        );
    }
    let report = sweep_model_opts(
        &cfg,
        &buckets,
        stgemm::kernels::available_kernel_ids(),
        &timer,
        &mut table,
        &opts,
    );
    if report.effective_divergence > opts.divergence_threshold {
        println!(
            "[autotune] divergence clamped: requested {:.1}%, measured variance \
             floor {:.1}% across {reps} rep(s) — splits below the floor are noise",
            opts.divergence_threshold * 100.0,
            report.variance_floor * 100.0
        );
    }
    for (class, entry) in &report.winners {
        // A recorded geometry means the sweep measured a divergent win over
        // the default tile walk; absence always means the default geometry.
        let geom = match &entry.geometry {
            Some(g) => format!(", geometry {}", g.name()),
            None => String::new(),
        };
        match class.m_bucket {
            Some(m) => println!(
                "  class {class}: winner {} at {:.3} flops/cycle{geom} (M-aware, bucket {m})",
                entry.kernel, entry.flops_per_cycle,
            ),
            None => println!(
                "  class {class}: winner {} at {:.3} flops/cycle{geom} (mean over {} bucket(s))",
                entry.kernel,
                entry.flops_per_cycle,
                buckets.len().max(1)
            ),
        }
    }
    if let Some(path) = args.get("save") {
        table.save(path)?;
        println!(
            "[autotune] sweep: {} class(es) → {path} ({} total)",
            report.winners.len(),
            table.len()
        );
    }
    Ok(0)
}

fn cmd_quantize(args: &Args) -> Result<i32> {
    use stgemm::model::serialize::{save, LayerData};
    use stgemm::ternary::quantize_absmean;
    let dims = args.usize_list("dims", &[256, 1024, 256]);
    let seed = args.u64("seed", 42);
    let out = args.get_or("out", "model.stw");
    let alpha = args.f32("prelu-alpha", 0.25);
    let mut layers = Vec::new();
    for i in 0..dims.len() - 1 {
        let (k, n) = (dims[i], dims[i + 1]);
        // Synthesize float weights, then absmean-quantize them — the
        // pipeline a real checkpoint would go through.
        let wf = Matrix::random(k, n, seed + i as u64);
        let q = quantize_absmean(&wf);
        println!(
            "layer {i}: {k}×{n} quantized, scale={:.4}, nnz={} ({:.1}%), mse={:.5}",
            q.scale,
            q.weights.nnz(),
            100.0 * q.weights.density(),
            q.mse(&wf)
        );
        layers.push(LayerData {
            weights: q.weights,
            bias: vec![0.0; n],
            scale: q.scale,
            prelu_alpha: (i + 1 < dims.len() - 1).then_some(alpha),
        });
    }
    save(out, &layers)?;
    println!("[quantize] wrote {out}");
    Ok(0)
}

fn cmd_selftest(args: &Args) -> Result<i32> {
    let dir = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(default_artifacts_dir);
    let base = args.get_or("model", "ffn_tiny");
    println!("[selftest] artifacts: {} model: {base}", dir.display());
    let manifest = match Manifest::load(&dir) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("error: {e} (run `make artifacts` first)");
            return Ok(1);
        }
    };
    let variants = manifest.variants_of(base);
    if variants.is_empty() {
        return Err(Error::Config(format!(
            "no variants named {base}_b* in manifest"
        )));
    }
    // Build the native model from the artifact's own weight dumps; each
    // layer's kernel is planner-selected for its (K, sparsity) class. All
    // loading errors are typed and bubble to the CLI boundary — no panics
    // on a missing or truncated dump.
    let planner = Planner::new();
    let v0 = variants[0];
    let mut layers = Vec::new();
    for (i, l) in v0.layers.iter().enumerate() {
        let w = v0.load_weights(&manifest.dir, i)?;
        let b = v0.load_bias(&manifest.dir, i)?;
        let layer = stgemm::model::TernaryLinear::planned(
            &planner,
            &w,
            b,
            1.0,
            l.prelu_alpha,
            &PlanHints::default(),
        )?;
        println!("  layer {i}: kernel {}", layer.kernel_name());
        layers.push(layer);
    }
    let mlp = TernaryMlp::from_layers(base.to_string(), layers)?;
    let xla = XlaExecutor::spawn(&manifest, base)
        .map_err(|e| Error::Runtime(format!("{e:#}")))?;
    let engine = Engine::new(base, mlp).with_xla(xla);

    let mut failures = 0;
    for v in &variants {
        let probe = v.load_probe_x(&manifest.dir)?;
        let want = v.load_probe_y(&manifest.dir)?;
        let x = Matrix::from_slice(v.batch, v.d_in, &probe);
        let (native, xla_out, diff) = engine.cross_check(&x)?;
        let want_m = Matrix::from_slice(v.batch, v.d_out, &want);
        let native_ok = native.allclose(&want_m, 1e-3);
        let xla_ok = xla_out.allclose(&want_m, 1e-3);
        println!(
            "  {}: native-vs-probe {} | xla-vs-probe {} | native-vs-xla maxΔ {:.2e}",
            v.name,
            if native_ok { "OK" } else { "FAIL" },
            if xla_ok { "OK" } else { "FAIL" },
            diff
        );
        if !native_ok || !xla_ok || diff > 1e-3 {
            failures += 1;
        }
    }
    if failures == 0 {
        println!("[selftest] all {} variants PASS", variants.len());
        Ok(0)
    } else {
        eprintln!("[selftest] {failures} variant(s) FAILED");
        Ok(1)
    }
}

fn cmd_loadgen(args: &Args) -> Result<i32> {
    let addr_str = args.get_or("addr", "127.0.0.1:9000");
    let addr: std::net::SocketAddr = addr_str
        .parse()
        .map_err(|e| Error::Config(format!("bad --addr '{addr_str}': {e}")))?;
    let timeout = Duration::from_secs(args.u64("timeout-s", 30));
    if args.has("generate") {
        // Decode workload: bursty autoregressive sessions streaming the
        // chunked POST /generate endpoint.
        let gen = DecodeLoadGen {
            sessions: args.usize("sessions", 8),
            burst: args.usize("burst", 4),
            burst_gap: Duration::from_millis(args.u64("burst-gap-ms", 2)),
            d: args.usize("d-in", 256),
            model: args.get_or("model", "ffn_demo").to_string(),
            seed: args.u64("seed", 1),
            mean_tokens: args.usize("mean-tokens", 16),
            request_timeout: timeout,
        };
        println!(
            "[loadgen] decode: {} sessions in bursts of {} → {addr}",
            gen.sessions, gen.burst
        );
        let report = gen.run_generate_http(addr);
        println!("{}", report.summary());
        return Ok(i32::from(report.errors > 0));
    }
    let gen = LoadGenerator {
        clients: args.usize("clients", 8),
        requests_per_client: args.usize("requests", 100),
        d_in: args.usize("d-in", 256),
        model: args.get_or("model", "ffn_demo").to_string(),
        seed: args.u64("seed", 1),
        request_timeout: timeout,
    };
    println!(
        "[loadgen] {} clients × {} requests → {addr}",
        gen.clients, gen.requests_per_client
    );
    let report = gen.run_http(addr);
    println!("{}", report.summary());
    Ok(i32::from(report.errors > 0))
}

/// `stgemm generate`: a short end-to-end decode run, in-process (no port
/// to bind — CI-safe). Loads the config (default: the demo model), warms
/// a decode scheduler through the registry's lazy path, and pushes
/// bursty sessions through the continuous-batching step loop.
fn cmd_generate(args: &Args) -> Result<i32> {
    let mut cfg = match args.get("model") {
        Some(path) => ModelConfig::from_file(path)?,
        None => {
            eprintln!("[generate] no --model given; using the default demo config");
            ModelConfig::default()
        }
    };
    cfg.threads = args.usize("threads", cfg.threads).max(1);
    if cfg.d_in() != cfg.d_out() {
        return Err(Error::Config(format!(
            "decode requires a square model (d_in == d_out); '{}' is {} → {}",
            cfg.name,
            cfg.d_in(),
            cfg.d_out()
        )));
    }
    let placement = if args.has("no-pin") {
        PlacementPolicy::None
    } else {
        PlacementPolicy::Compact
    };
    let registry = ModelRegistry::new(Arc::new(Planner::new()));
    let handle = registry.load(
        &cfg,
        LoadOptions {
            decode: DecodeConfig {
                max_sessions: args.usize(
                    "decode-sessions",
                    DecodeConfig::default().max_sessions,
                ),
                default_max_tokens: args.usize(
                    "decode-max-tokens",
                    DecodeConfig::default().default_max_tokens,
                ),
                placement,
            },
            ..LoadOptions::default()
        },
    )?;
    let sched = handle.decode_scheduler()?;
    let gen = DecodeLoadGen {
        sessions: args.usize("sessions", 4),
        burst: args.usize("burst", 2),
        burst_gap: Duration::from_millis(args.u64("burst-gap-ms", 1)),
        d: cfg.d_in(),
        model: cfg.name.clone(),
        seed: args.u64("seed", 3),
        mean_tokens: args.usize("mean-tokens", 8),
        request_timeout: Duration::from_secs(args.u64("timeout-s", 30)),
    };
    println!(
        "[generate] model '{}' (d={}): {} sessions in bursts of {}, \
         capacity {} (M-bucket {})",
        cfg.name,
        cfg.d_in(),
        gen.sessions,
        gen.burst,
        sched.capacity(),
        sched.capacity().next_power_of_two(),
    );
    let report = gen.run_scheduler(&sched);
    println!("{}", report.summary());
    let stats = sched.arena_stats();
    println!(
        "[generate] decode arena: {} allocations, {} reuses (steady state \
         allocates nothing)",
        stats.allocations, stats.reuses
    );
    registry.shutdown();
    Ok(i32::from(report.errors > 0))
}
