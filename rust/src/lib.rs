//! # stgemm — Sparse Ternary GEMM for Quantized ML
//!
//! A three-layer (Rust + JAX + Pallas) reproduction of *"Accelerating Sparse
//! Ternary GEMM for Quantized ML on Apple Silicon"* (ETH Zurich, 2025).
//!
//! The paper optimizes `Y = X·W + b` where `W ∈ {-1,0,+1}^{K×N}` is a
//! ternary weight matrix stored in sign-split sparse formats (TCSC and its
//! blocked / interleaved / symmetric descendants) and `X ∈ R^{M×K}` is a
//! dense activation matrix. Multiplication by ±1 degenerates to addition and
//! subtraction, so the whole kernel is an exercise in memory locality and
//! instruction-level parallelism.
//!
//! ## Crate layout
//!
//! - [`tensor`] — dense, cache-aligned row-major `Matrix<f32>`, plus
//!   zero-copy row views and the padded activation matrix the SIMD kernels
//!   read through.
//! - [`ternary`] — dense ternary matrices, exact-sparsity generators and the
//!   absmean quantizer that turns float weights ternary.
//! - [`formats`] — every sparse layout from the paper: [`formats::Tcsc`],
//!   [`formats::BlockedTcsc`], [`formats::InterleavedTcsc`],
//!   [`formats::InterleavedBlockedTcsc`], [`formats::SymmetricTcsc`] (SIMD),
//!   [`formats::CompressedTernary`] (base-3 packing),
//!   [`formats::InvertedIndex`], and [`formats::TilePanelTcsc`] — ternary
//!   columns grouped into panels with sign-split (k, c)-lexicographic
//!   streams, feeding the outer-product tile kernels. The tile-panel
//!   layout is parametric over a [`formats::TileGeometry`] (panel width
//!   4/[`formats::MAX_PANEL_WIDTH`] × optional K-block slicing the
//!   streams at ascending-k boundaries); every geometry replays the
//!   baseline's per-cell accumulation order exactly, so geometry is
//!   layout, never arithmetic.
//! - [`kernels`] — the GEMM kernel family over those formats, scalar and
//!   SIMD, plus the **typed registry**: every kernel has a
//!   [`kernels::KernelId`] and one row in the static
//!   [`kernels::KernelDescriptor`] table ([`kernels::descriptors`])
//!   declaring its family, fused-PReLU support, interleave-group/blocking
//!   behavior, padded/tile-scratch use, **required CPU capabilities**
//!   and batch affinity. Enumeration ([`kernels::kernel_names`] /
//!   [`kernels::kernel_ids`]), host-filtered availability
//!   ([`kernels::available_kernel_ids`] / [`kernels::available_ids`]),
//!   dispatch ([`kernels::KernelId::prepare`]), config validation and the
//!   planner's heuristic candidates are all derived queries over that
//!   table — adding a kernel is one enum variant plus one row. Strings
//!   appear only at the parse/display boundary
//!   ([`kernels::KernelId::parse`] / [`kernels::KernelId::name`]).
//!   The **outer-product family** ([`kernels::KernelFamily::OuterProduct`])
//!   accumulates whole register tiles per panel — the matrix-unit
//!   orientation — in a portable scalar emulation plus a NEON-gated
//!   lane-parallel variant, both **bitwise identical** to the sequential
//!   baseline (streams replay the baseline's per-cell accumulation order
//!   exactly) at **every** [`formats::TileGeometry`]: the family declares
//!   the blocking-geometry axis on its descriptors, and
//!   [`kernels::KernelParams::geometry`] selects the panel-width
//!   register-tile variant and the K-blocked walk.
//!   Capability gating is *selection-time only*: [`perf::CpuCaps`] decides
//!   what may be picked; `prepare` stays host-agnostic so any host can
//!   construct (and test) any kernel.
//! - [`plan`] — **the layer everything executes through**:
//!   [`plan::Planner`] turns weights + hints into a [`plan::GemmPlan`]
//!   (kernel selected via the autotune table or paper heuristics, epilogue
//!   fused where possible, scratch preallocated, rows partitioned across a
//!   thread pool with bitwise-sequential results). On the serving path,
//!   plans live in the M-bucketed [`plan::PlanCache`]: one plan per
//!   (layer, batch-size bucket, thread count), built on first traffic and
//!   reused forever, with an **online top-2 race** that times the two
//!   paper-candidate kernels on the first real batch of an untuned
//!   (K, sparsity, M-bucket) class and locks the winner into the shared
//!   table under the M-aware class. Multi-layer forwards additionally run
//!   through the **wavefront pipeline** ([`plan::pipeline`]): all layers
//!   compile into one [`plan::MlpPlan`] band-dependency graph per bucket,
//!   `(layer, band)` tasks are pulled by persistent pool workers with no
//!   barrier between layers, and intermediate activations live in
//!   [`plan::ActivationArena`] ping-pong buffers (zero allocation in
//!   steady state) — see *Execution model* below.
//! - [`autotune`] — the unroll-factor / block-size grid search behind the
//!   paper's Figures 2–4, the persisted `TuningTable` the planner
//!   consults, and [`autotune::sweep_model_opts`] (`stgemm autotune
//!   sweep`), which fills the table for every layer × M-bucket of a model
//!   config in one run. Table keys are `k{K}_s{S}` (M-agnostic) or
//!   `k{K}_s{S}_m{M}` (M-aware, recorded by `sweep --per-m` and the
//!   online races when per-bucket winners diverge); lookups try the
//!   M-aware entry for the batch's bucket first and fall back to the
//!   M-agnostic entry, so PR-2-era JSON tables keep working unchanged.
//!   **JSON stays name-keyed on disk**; kernel names resolve to typed
//!   [`kernels::KernelId`]s at load — an unknown name is excluded from
//!   lookups with a warning (but survives a load-modify-save cycle), and
//!   un-bucketed (hand-edited/stale) keys are re-bucketed with a warning
//!   instead of becoming silently unmatchable dead weight. The per-M divergence threshold self-calibrates: it is
//!   clamped to the variance floor ([`autotune::variance_floor`])
//!   measured across the sweep's own repetitions. Entries may record a
//!   winning [`formats::TileGeometry`] (`"geometry": "p8kb4096"`) —
//!   written by `sweep --geometry` and the online race only when a
//!   measured winner diverges from the default, so absence always means
//!   the default geometry and pre-geometry JSON loads unchanged.
//! - [`perf`] — cycle timers, the paper's flop cost model
//!   `C = M·N·(1+sK)`, operational intensity and roofline estimates, and
//!   **runtime CPU-capability detection** ([`perf::CpuCaps`]): arch,
//!   NEON, an Apple-matrix-unit hint and cache sizes where probeable
//!   (sysfs on Linux, `sysctlbyname` on macOS), detected once per
//!   process and consumed by every selection-time kernel query (planner
//!   heuristics, tuning-table lookups, sweep candidates, the online
//!   race). [`perf::CpuTopology`] probes the **core topology** the same
//!   way (sysfs `cpu_capacity` + shared-L2 groups on Linux,
//!   `hw.perflevel*` sysctls on macOS, a flat fallback elsewhere),
//!   classifying cores into performance/efficiency clusters — the
//!   substrate worker placement maps onto. [`perf::BlockingPolicy`]
//!   turns the probed L1d into concrete
//!   blocking decisions — the scalar families' K-block and the tile
//!   family's preferred [`formats::TileGeometry`] (half-of-L1d sizing,
//!   pow2-floored and clamped; the paper's M1 L1d lands exactly on its
//!   hand-picked 4096 block) — with documented paper fallbacks when
//!   unprobeable, and [`perf::geometry_candidates`] spans the grid the
//!   race and `--geometry` sweep measure.
//! - [`model`] — ternary MLP / FFN built from planned linear layers; the
//!   config system and weight serialization. Kernel names are optional
//!   overrides, not requirements.
//! - [`runtime`] — PJRT client wrapper that loads the JAX/Pallas AOT
//!   artifacts (HLO text) produced by `python/compile/aot.py`.
//! - [`coordinator`] — the L3 serving stack: a dynamic multi-model fleet
//!   registry ([`coordinator::ModelRegistry`]) mapping model names to
//!   [`coordinator::ModelHandle`]s with an explicit lifecycle
//!   (`Cold → Warming → Hot → Draining`), fronted by a thin
//!   [`coordinator::Router`] and the HTTP server. Every model shares one
//!   [`plan::Planner`] (hence one [`autotune::TuningTable`] and one
//!   [`util::threadpool::ThreadPool`]) while owning a private
//!   [`plan::PlanCache`], so tuning learned by one model serves all and
//!   per-model outputs stay bitwise identical to a single-model engine.
//!   Per-model [`coordinator::AdmissionController`]s reject submits
//!   429-style once a queue budget is hit, and a fleet balancer re-splits
//!   the shared thread budget by observed demand
//!   (arrival-rate EWMA × compute EWMA) so a hot model cannot starve its
//!   neighbours. Models load, warm, drain and unload at runtime over HTTP
//!   (`POST /load_model`, `POST /unload`, `GET /status`) with no dropped
//!   in-flight requests: unload stops the autoscale tick, closes the
//!   batcher (flushing queued work), joins the batch loop, then releases
//!   the model's plans and activation arena. The stack stays
//!   **load-aware**: the batcher reports queue depth and an arrival-rate
//!   EWMA into [`coordinator::Metrics`], and an autoscaled model re-sizes
//!   the live `max_batch` and the plan cache's thread ceiling from those
//!   signals ([`coordinator::LoadController`]; thread advice snaps to
//!   powers of two ≤ the ceiling) — both per executed batch and on a
//!   timer tick with hysteresis, so an idle model's targets decay after a
//!   burst. The stack also serves the **autoregressive decode** workload:
//!   a per-model [`coordinator::DecodeScheduler`] continuously batches
//!   concurrent [`model::DecodeSession`]s into one shared M-bucket step
//!   through a single decode plan whose kernels are pinned to their M=1
//!   choices, so a batched step is bitwise-identical to running each
//!   session's step as an independent forward. Sessions hold leased
//!   arena buffer pairs across steps (zero steady-state allocation) and
//!   stream tokens over a chunked `POST /generate` endpoint; a client
//!   hang-up cancels its session, and schedulers drain with their model.
//!   Serving is **topology-aware**: the shared pool's workers pin to
//!   performance cores per a [`util::PlacementPolicy`] (`--placement`,
//!   `--no-pin`), the fleet thread budget becomes a core budget, the
//!   decode tick thread compact-pins so a lone M=1 session steps on a
//!   performance core, and `/status` + `/metrics` carry per-worker
//!   placement rows and a stall-fraction effectiveness gauge. Placement
//!   moves work — it never changes results (property-tested bitwise
//!   across policies × thread counts in `tests/placement.rs`).
//! - [`bench`] — the measurement harness (timing the planned path) and
//!   per-figure experiment drivers.
//! - [`util`] — substrates built in-repo because the environment is offline:
//!   PRNG, JSON, CLI parsing, thread pool (with scoped fork-join, the
//!   scoped worker loops the wavefront scheduler pulls tasks on,
//!   condvar-parked idle waits and per-worker **placement**), the
//!   affinity layer ([`util::PlacementPolicy`] → OS pinning via
//!   `sched_setaffinity` / QoS + affinity tags, a counted no-op
//!   elsewhere), the aligned/hugepage allocation layer
//!   ([`util::AlignedBuffer`], [`util::advise_hugepages_f32`]), and a
//!   mini property-testing framework.
//! - [`error`] — the library-wide typed [`enum@Error`] (re-exported at the
//!   crate root with the [`Result`] alias): every fallible API returns it,
//!   variants classify failures (`UnknownKernel`, `BadKernelParams`,
//!   `UnsupportedKernel`, `Shape`, `Config`, `Tuning`, `Format`,
//!   `Runtime`, `Serve`, `Io`), and the CLI maps them to exit codes via
//!   [`Error::exit_code`].
//!
//! ## Execution model: barrier vs wavefront
//!
//! A multi-layer forward pass can run two ways, with a hard guarantee
//! that both produce **bitwise-identical outputs**:
//!
//! - **Barrier** (pre-PR-5 semantics; `pipeline: false` in the model
//!   config, `serve --no-pipeline`): each layer's batch is row-partitioned
//!   across the pool, then a full join runs before the next layer starts.
//!   This is also the path the online kernel race executes on, so racing
//!   is never skipped.
//! - **Wavefront** (the default): row band `[a, b)` of layer `i+1`
//!   depends only on row band `[a, b)` of layer `i`'s output, so band
//!   tasks flow through the whole stack with no global barrier —
//!   persistent workers pull the deepest runnable band first. Identity
//!   holds because bands reuse the same [`plan::RowPartition`]
//!   tile-aligned ranges and prepared kernels as the barrier path, and
//!   the epilogue is elementwise.
//!
//! Intermediate activations ping-pong through two pre-sized
//! [`plan::ActivationArena`] buffers per (model, M-bucket): after
//! plan-cache warmup, steady-state serving performs **zero activation
//! allocation** (asserted by arena reuse counters in `tests/prop_cache.rs`).
//! Scheduler observability (pipeline depth, stall time) feeds the serving
//! metrics, and `cargo bench --bench e2e_serving` emits a
//! barrier-vs-wavefront comparison with per-layer stall into
//! `e2e_serving.json`.
//!
//! ## Quickstart
//!
//! Plan once, run forever: the planner picks the kernel for the weight's
//! (K, sparsity) class, and the plan owns epilogue, scratch and threading.
//!
//! ```
//! use stgemm::kernels::KernelParams;
//! use stgemm::plan::{Epilogue, PlanHints, Planner};
//! use stgemm::tensor::Matrix;
//! use stgemm::ternary::TernaryMatrix;
//!
//! let (m, k, n) = (4, 64, 32);
//! let w = TernaryMatrix::random(k, n, 0.25, 42);       // 25% nonzero
//! let x = Matrix::random(m, k, 1);
//! let bias = vec![0.5f32; n];
//!
//! let planner = Planner::new();                        // heuristics only
//! let plan = planner
//!     .plan(
//!         &w,
//!         KernelParams::default(),
//!         Epilogue::with_bias(bias.clone()),
//!         &PlanHints::default(),                       // no kernel name!
//!     )
//!     .unwrap();
//! let mut y = Matrix::zeros(m, n);
//! plan.run(&x, &mut y).unwrap();
//!
//! let oracle = stgemm::kernels::dense_oracle(&x, &w, &bias);
//! assert!(y.allclose(&oracle, 1e-4));
//! ```
//!
//! Benches and ablations pin kernels explicitly via
//! [`plan::PlanHints::with_kernel`] with a typed [`kernels::KernelId`]
//! (name-keyed callers resolve through `"name".parse::<KernelId>()`; a
//! config's `kernel` key does this at parse time — the documented escape
//! hatch); serving loads a measured table with `Planner::from_table_file`
//! (`stgemm serve --tuning table.json`), fills it for a whole model with
//! `stgemm autotune sweep --save`, and re-tunes in the background with
//! `serve --retune-secs N`.

pub mod error;
pub mod util;
pub mod tensor;
pub mod ternary;
pub mod formats;
pub mod kernels;
pub mod plan;
pub mod autotune;
pub mod perf;
pub mod model;
pub mod runtime;
pub mod coordinator;
pub mod bench;

pub use error::{Error, Result};

/// Sparsity levels evaluated by the paper (fraction of nonzero entries).
pub const PAPER_SPARSITIES: [f32; 4] = [0.5, 0.25, 0.125, 0.0625];

/// The paper's optimal block size (elements of K per block), Apple M1 L1-tuned.
pub const PAPER_BLOCK_SIZE: usize = 4096;

/// The paper's optimal interleave group size (indices per sign per group)
/// for the plain interleaved format.
pub const PAPER_GROUP_SIZE: usize = 4;

/// The paper's interleave group for the **blocked** interleaved formats
/// (best scalar config: unroll factor F = 4 → F/2 = 2 indices per sign).
pub const PAPER_BLOCKED_GROUP: usize = 2;
