//! # stgemm — Sparse Ternary GEMM for Quantized ML
//!
//! A three-layer (Rust + JAX + Pallas) reproduction of *"Accelerating Sparse
//! Ternary GEMM for Quantized ML on Apple Silicon"* (ETH Zurich, 2025).
//!
//! The paper optimizes `Y = X·W + b` where `W ∈ {-1,0,+1}^{K×N}` is a
//! ternary weight matrix stored in sign-split sparse formats (TCSC and its
//! blocked / interleaved / symmetric descendants) and `X ∈ R^{M×K}` is a
//! dense activation matrix. Multiplication by ±1 degenerates to addition and
//! subtraction, so the whole kernel is an exercise in memory locality and
//! instruction-level parallelism.
//!
//! ## Crate layout
//!
//! - [`tensor`] — dense, cache-aligned row-major `Matrix<f32>`.
//! - [`ternary`] — dense ternary matrices, exact-sparsity generators and the
//!   absmean quantizer that turns float weights ternary.
//! - [`formats`] — every sparse layout from the paper: [`formats::Tcsc`],
//!   [`formats::BlockedTcsc`], [`formats::InterleavedTcsc`],
//!   [`formats::InterleavedBlockedTcsc`], [`formats::SymmetricTcsc`] (SIMD),
//!   [`formats::CompressedTernary`] (base-3 packing) and
//!   [`formats::InvertedIndex`].
//! - [`kernels`] — the GEMM kernel family over those formats, scalar and
//!   SIMD, plus the dense oracle and PReLU fusion.
//! - [`autotune`] — the unroll-factor / block-size grid search behind the
//!   paper's Figures 2–4.
//! - [`perf`] — cycle timers, the paper's flop cost model
//!   `C = M·N·(1+sK)`, operational intensity and roofline estimates.
//! - [`model`] — ternary MLP / FFN built from quantized linear layers; the
//!   config system and weight serialization.
//! - [`runtime`] — PJRT client wrapper that loads the JAX/Pallas AOT
//!   artifacts (HLO text) produced by `python/compile/aot.py`.
//! - [`coordinator`] — the L3 serving stack: dynamic batcher, backend
//!   router, inference engine, HTTP server, metrics and load generator.
//! - [`bench`] — the measurement harness and per-figure experiment drivers.
//! - [`util`] — substrates built in-repo because the environment is offline:
//!   PRNG, JSON, CLI parsing, thread pool, and a mini property-testing
//!   framework.
//!
//! ## Quickstart
//!
//! ```
//! use stgemm::tensor::Matrix;
//! use stgemm::ternary::TernaryMatrix;
//! use stgemm::formats::Tcsc;
//! use stgemm::kernels::{self, Kernel};
//!
//! let (m, k, n) = (4, 64, 32);
//! let w = TernaryMatrix::random(k, n, 0.25, 42);       // 25% nonzero
//! let x = Matrix::random(m, k, 1);
//! let bias = vec![0.5f32; n];
//! let fmt = Tcsc::from_ternary(&w);
//! let mut y = Matrix::zeros(m, n);
//! kernels::BaseTcscKernel.run(&x, &fmt, &bias, &mut y);
//! let oracle = kernels::dense_oracle(&x, &w, &bias);
//! assert!(y.allclose(&oracle, 1e-4));
//! ```

pub mod util;
pub mod tensor;
pub mod ternary;
pub mod formats;
pub mod kernels;
pub mod autotune;
pub mod perf;
pub mod model;
pub mod runtime;
pub mod coordinator;
pub mod bench;

/// Sparsity levels evaluated by the paper (fraction of nonzero entries).
pub const PAPER_SPARSITIES: [f32; 4] = [0.5, 0.25, 0.125, 0.0625];

/// The paper's optimal block size (elements of K per block), Apple M1 L1-tuned.
pub const PAPER_BLOCK_SIZE: usize = 4096;

/// The paper's optimal interleave group size (indices per sign per group).
pub const PAPER_GROUP_SIZE: usize = 4;
