//! InterleavedTCSC (paper §3 "Interleaving", Fig 7).
//!
//! Positive and negative row indices of each column are merged into one
//! stream of alternating sign groups of size `G` (paper-optimal G = 4):
//! `[G positives][G negatives][G positives]…`. Indices that cannot be
//! matched into full ± group pairs are stored separately as a positive
//! remainder then a negative remainder. One stream means one inner loop —
//! no pos→neg pass restart trashing the X working set.

use crate::formats::SparseFormat;
use crate::ternary::TernaryMatrix;

/// Interleaved sign-grouped CSC.
#[derive(Debug, Clone, PartialEq)]
pub struct InterleavedTcsc {
    k: usize,
    n: usize,
    /// Indices per sign per group (G).
    pub group: usize,
    /// All row indices, column-wise: per column `[interleaved | rest-pos |
    /// rest-neg]`.
    pub all_indices: Vec<u32>,
    /// Segment pointers, 3 per column + 1: for column `j`,
    /// interleaved = `[ptr[3j], ptr[3j+1])`, rest-pos = `[ptr[3j+1],
    /// ptr[3j+2])`, rest-neg = `[ptr[3j+2], ptr[3j+3])`.
    pub col_segment_ptr: Vec<u32>,
}

impl InterleavedTcsc {
    /// Build with sign-group size `group` (paper uses 4).
    pub fn from_ternary(w: &TernaryMatrix, group: usize) -> InterleavedTcsc {
        assert!(group >= 1, "group size must be >= 1");
        let (k, n) = (w.k(), w.n());
        let mut all_indices = Vec::new();
        let mut col_segment_ptr = Vec::with_capacity(3 * n + 1);
        col_segment_ptr.push(0);
        for j in 0..n {
            let pos = w.col_positives(j);
            let neg = w.col_negatives(j);
            let full_groups = (pos.len() / group).min(neg.len() / group);
            // Interleaved region: alternating [G pos][G neg] runs.
            for g in 0..full_groups {
                all_indices.extend_from_slice(&pos[g * group..(g + 1) * group]);
                all_indices.extend_from_slice(&neg[g * group..(g + 1) * group]);
            }
            col_segment_ptr.push(all_indices.len() as u32);
            // Remaining positives.
            all_indices.extend_from_slice(&pos[full_groups * group..]);
            col_segment_ptr.push(all_indices.len() as u32);
            // Remaining negatives.
            all_indices.extend_from_slice(&neg[full_groups * group..]);
            col_segment_ptr.push(all_indices.len() as u32);
        }
        let f = InterleavedTcsc {
            k,
            n,
            group,
            all_indices,
            col_segment_ptr,
        };
        debug_assert_eq!(f.validate(), Ok(()));
        f
    }

    /// Interleaved segment of column `j` (length multiple of `2·group`).
    #[inline]
    pub fn col_interleaved(&self, j: usize) -> &[u32] {
        &self.all_indices
            [self.col_segment_ptr[3 * j] as usize..self.col_segment_ptr[3 * j + 1] as usize]
    }

    /// Remaining positive indices of column `j`.
    #[inline]
    pub fn col_rest_pos(&self, j: usize) -> &[u32] {
        &self.all_indices
            [self.col_segment_ptr[3 * j + 1] as usize..self.col_segment_ptr[3 * j + 2] as usize]
    }

    /// Remaining negative indices of column `j`.
    #[inline]
    pub fn col_rest_neg(&self, j: usize) -> &[u32] {
        &self.all_indices
            [self.col_segment_ptr[3 * j + 2] as usize..self.col_segment_ptr[3 * j + 3] as usize]
    }
}

impl SparseFormat for InterleavedTcsc {
    const NAME: &'static str = "InterleavedTCSC";

    fn k(&self) -> usize {
        self.k
    }

    fn n(&self) -> usize {
        self.n
    }

    fn nnz(&self) -> usize {
        self.all_indices.len()
    }

    fn bytes(&self) -> usize {
        std::mem::size_of::<u32>() * (self.all_indices.len() + self.col_segment_ptr.len())
    }

    fn to_dense(&self) -> TernaryMatrix {
        let mut w = TernaryMatrix::zeros(self.k, self.n);
        let g = self.group;
        for j in 0..self.n {
            let inter = self.col_interleaved(j);
            for (chunk_idx, chunk) in inter.chunks(g).enumerate() {
                let sign = if chunk_idx % 2 == 0 { 1 } else { -1 };
                for &i in chunk {
                    w.set(i as usize, j, sign);
                }
            }
            for &i in self.col_rest_pos(j) {
                w.set(i as usize, j, 1);
            }
            for &i in self.col_rest_neg(j) {
                w.set(i as usize, j, -1);
            }
        }
        w
    }

    fn validate(&self) -> crate::Result<()> {
        if self.col_segment_ptr.len() != 3 * self.n + 1 {
            return Err(crate::Error::Format("segment pointer length mismatch".into()));
        }
        if self.col_segment_ptr[0] != 0
            || *self.col_segment_ptr.last().unwrap() as usize != self.all_indices.len()
        {
            return Err(crate::Error::Format("segment pointer endpoints wrong".into()));
        }
        for w in self.col_segment_ptr.windows(2) {
            if w[0] > w[1] {
                return Err(crate::Error::Format("segment pointers not monotone".into()));
            }
        }
        for j in 0..self.n {
            let inter = self.col_interleaved(j);
            if inter.len() % (2 * self.group) != 0 {
                return Err(crate::Error::Format(format!(
                    "column {j}: interleaved length {} not a multiple of 2G",
                    inter.len()
                )));
            }
            for &i in self
                .col_interleaved(j)
                .iter()
                .chain(self.col_rest_pos(j))
                .chain(self.col_rest_neg(j))
            {
                if i as usize >= self.k {
                    return Err(crate::Error::Format(format!("column {j}: index {i} out of range")));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_group_sizes() {
        let w = TernaryMatrix::random(96, 32, 0.5, 41);
        for g in [1, 2, 4, 8] {
            let f = InterleavedTcsc::from_ternary(&w, g);
            assert_eq!(f.to_dense(), w, "group {g}");
            f.validate().unwrap();
            assert_eq!(f.nnz(), w.nnz());
        }
    }

    #[test]
    fn interleaved_region_alternates_signs() {
        let w = TernaryMatrix::random(128, 4, 0.5, 5);
        let f = InterleavedTcsc::from_ternary(&w, 2);
        for j in 0..4 {
            let inter = f.col_interleaved(j);
            for (ci, chunk) in inter.chunks(2).enumerate() {
                let want = if ci % 2 == 0 { 1 } else { -1 };
                for &i in chunk {
                    assert_eq!(w.get(i as usize, j), want);
                }
            }
        }
    }

    #[test]
    fn remainders_hold_unmatched() {
        // Column with 3 pos, 1 neg, group 2 → 0 full group pairs:
        // everything in remainders.
        let mut w = TernaryMatrix::zeros(8, 1);
        w.set(0, 0, 1);
        w.set(2, 0, 1);
        w.set(4, 0, 1);
        w.set(6, 0, -1);
        let f = InterleavedTcsc::from_ternary(&w, 2);
        assert!(f.col_interleaved(0).is_empty());
        assert_eq!(f.col_rest_pos(0), &[0, 2, 4]);
        assert_eq!(f.col_rest_neg(0), &[6]);
        assert_eq!(f.to_dense(), w);
    }

    #[test]
    fn fig7_style_grouping() {
        // Group 2: col with pos {0,1,4} and neg {2,3,5} → interleave
        // [0,1][2,3]; remainders pos [4], neg [5].
        let mut w = TernaryMatrix::zeros(8, 1);
        for i in [0, 1, 4] {
            w.set(i, 0, 1);
        }
        for i in [2, 3, 5] {
            w.set(i, 0, -1);
        }
        let f = InterleavedTcsc::from_ternary(&w, 2);
        assert_eq!(f.col_interleaved(0), &[0, 1, 2, 3]);
        assert_eq!(f.col_rest_pos(0), &[4]);
        assert_eq!(f.col_rest_neg(0), &[5]);
    }

    #[test]
    fn sparse_column_edge_cases() {
        let w = TernaryMatrix::zeros(16, 3); // all-zero columns
        let f = InterleavedTcsc::from_ternary(&w, 4);
        assert_eq!(f.nnz(), 0);
        assert_eq!(f.to_dense(), w);
    }
}
