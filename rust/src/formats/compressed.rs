//! Value compression (paper §3 "Value Compression") — five ternary entries
//! packed into one byte as a 5-digit base-3 number (3^5 = 243 ≤ 2^8,
//! 5.08 % wasted code space). Decoding goes through a 243-entry lookup
//! table that fits in L1 and costs zero flops.
//!
//! The paper prototyped this and dropped it (wins at s = 50 %, loses below
//! 25 % because packed zeros waste work); we keep it for the ablation bench
//! that reproduces exactly that crossover.

use crate::formats::SparseFormat;
use crate::ternary::TernaryMatrix;

/// Number of ternary digits per byte code.
pub const DIGITS: usize = 5;
/// Number of valid codes (3^5).
pub const CODES: usize = 243;

/// The 243-entry decode LUT: code → five `{-1,0,+1}` digits
/// (least-significant digit first = lowest row index first).
pub fn decode_lut() -> &'static [[i8; DIGITS]; CODES] {
    static LUT: std::sync::OnceLock<[[i8; DIGITS]; CODES]> = std::sync::OnceLock::new();
    LUT.get_or_init(|| {
        let mut lut = [[0i8; DIGITS]; CODES];
        for (code, entry) in lut.iter_mut().enumerate() {
            let mut rest = code;
            for d in entry.iter_mut() {
                *d = (rest % 3) as i8 - 1; // digit 0 → -1, 1 → 0, 2 → +1
                rest /= 3;
            }
        }
        lut
    })
}

/// Encode five ternary values (low row first) into a byte code.
pub fn encode5(vals: &[i8; DIGITS]) -> u8 {
    let mut code = 0usize;
    for &v in vals.iter().rev() {
        debug_assert!((-1..=1).contains(&v));
        code = code * 3 + (v + 1) as usize;
    }
    code as u8
}

/// Column-major packed ternary matrix: each column stores `ceil(K/5)`
/// byte codes covering rows `[5t, 5t+5)` (tail padded with zeros).
#[derive(Debug, Clone, PartialEq)]
pub struct CompressedTernary {
    k: usize,
    n: usize,
    /// Codes per column.
    pub codes_per_col: usize,
    /// Column-major code array, length `n · codes_per_col`.
    pub codes: Vec<u8>,
    nnz: usize,
}

impl CompressedTernary {
    pub fn from_ternary(w: &TernaryMatrix) -> CompressedTernary {
        let (k, n) = (w.k(), w.n());
        let codes_per_col = k.div_ceil(DIGITS);
        let mut codes = Vec::with_capacity(n * codes_per_col);
        for j in 0..n {
            for t in 0..codes_per_col {
                let mut vals = [0i8; DIGITS];
                for (d, val) in vals.iter_mut().enumerate() {
                    let i = t * DIGITS + d;
                    if i < k {
                        *val = w.get(i, j);
                    }
                }
                codes.push(encode5(&vals));
            }
        }
        let f = CompressedTernary {
            k,
            n,
            codes_per_col,
            codes,
            nnz: w.nnz(),
        };
        debug_assert_eq!(f.validate(), Ok(()));
        f
    }

    /// Codes of column `j`.
    #[inline]
    pub fn col_codes(&self, j: usize) -> &[u8] {
        &self.codes[j * self.codes_per_col..(j + 1) * self.codes_per_col]
    }
}

impl SparseFormat for CompressedTernary {
    const NAME: &'static str = "CompressedTernary";

    fn k(&self) -> usize {
        self.k
    }

    fn n(&self) -> usize {
        self.n
    }

    fn nnz(&self) -> usize {
        self.nnz
    }

    fn bytes(&self) -> usize {
        self.codes.len()
    }

    fn to_dense(&self) -> TernaryMatrix {
        let lut = decode_lut();
        let mut w = TernaryMatrix::zeros(self.k, self.n);
        for j in 0..self.n {
            for (t, &code) in self.col_codes(j).iter().enumerate() {
                let digits = &lut[code as usize];
                for (d, &v) in digits.iter().enumerate() {
                    let i = t * DIGITS + d;
                    if i < self.k && v != 0 {
                        w.set(i, j, v);
                    }
                }
            }
        }
        w
    }

    fn validate(&self) -> crate::Result<()> {
        if self.codes.len() != self.n * self.codes_per_col {
            return Err(crate::Error::Format("code array length mismatch".into()));
        }
        let lut = decode_lut();
        // Tail codes must not place values beyond K.
        if self.k % DIGITS != 0 && self.codes_per_col > 0 {
            let valid = self.k % DIGITS;
            for j in 0..self.n {
                let tail = self.col_codes(j)[self.codes_per_col - 1];
                let digits = &lut[tail as usize];
                if digits[valid..].iter().any(|&v| v != 0) {
                    return Err(crate::Error::Format(format!(
                        "column {j}: tail code writes beyond K"
                    )));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lut_inverts_encode() {
        let lut = decode_lut();
        for code in 0..CODES {
            assert_eq!(encode5(&lut[code]) as usize, code);
        }
    }

    #[test]
    fn encode_examples() {
        assert_eq!(encode5(&[0, 0, 0, 0, 0]), 121); // all-zero = middle code
        assert_eq!(encode5(&[-1, -1, -1, -1, -1]), 0);
        assert_eq!(encode5(&[1, 1, 1, 1, 1]), 242);
        assert_eq!(encode5(&[1, 0, 0, 0, 0]), 122); // +1 in lowest digit
    }

    #[test]
    fn roundtrip_random() {
        for &s in &crate::PAPER_SPARSITIES {
            let w = TernaryMatrix::random(53, 17, s, 61); // K not divisible by 5
            let f = CompressedTernary::from_ternary(&w);
            assert_eq!(f.to_dense(), w, "s {s}");
            f.validate().unwrap();
        }
    }

    #[test]
    fn bytes_are_one_per_five_rows() {
        let w = TernaryMatrix::random(100, 10, 0.5, 3);
        let f = CompressedTernary::from_ternary(&w);
        assert_eq!(f.bytes(), 10 * 20);
        // vs TCSC at 4 bytes/index: compression is large.
        use crate::formats::Tcsc;
        assert!(f.bytes() < Tcsc::from_ternary(&w).bytes());
    }

    #[test]
    fn k_multiple_of_five() {
        let w = TernaryMatrix::random(25, 4, 0.25, 9);
        let f = CompressedTernary::from_ternary(&w);
        assert_eq!(f.codes_per_col, 5);
        assert_eq!(f.to_dense(), w);
    }
}
