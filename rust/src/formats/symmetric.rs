//! Symmetric padded format for SIMD kernels (paper §3 "SIMD
//! Vectorization").
//!
//! The vector kernels process four output columns per iteration, so the
//! format mandates *symmetry* across each group of four W columns:
//!
//! - every column in a 4-column group stores the same number of index
//!   **quads** `[pos, pos, neg, neg]`;
//! - the quad count per group is padded up to a multiple of 2 (the vertical
//!   kernel consumes two sign groups — four values — per column per
//!   iteration);
//! - deficit lanes point at a **dummy index** `K`, which reads 0.0 from a
//!   [`crate::tensor::PaddedMatrix`] row (stride K+1 with a zero pad slot),
//!   contributing nothing to the sums.
//!
//! Memory layout of `indices`: group-major, then step-major, then
//! column-major — at group `g`, step `t`, the 16 contiguous u32s are
//! `[col0: p,p,n,n][col1: p,p,n,n][col2 …][col3 …]`, which both the
//! vertical and horizontal kernels stream sequentially.

use crate::formats::SparseFormat;
use crate::ternary::TernaryMatrix;

/// Symmetric padded sign-quad format for 4-wide SIMD.
#[derive(Debug, Clone, PartialEq)]
pub struct SymmetricTcsc {
    k: usize,
    /// True (unpadded) number of columns.
    n: usize,
    /// Quad-steps per 4-column group; length `ngroups`. Always even.
    pub steps_per_group: Vec<u32>,
    /// Start offset (in u32s) of each group's index block; length
    /// `ngroups + 1`. Group `g` occupies `indices[group_ptr[g] ..
    /// group_ptr[g+1]]` = `steps_per_group[g] · 16` u32s.
    pub group_ptr: Vec<u32>,
    /// Index stream (see module docs for layout). Dummy entries equal `K`.
    pub indices: Vec<u32>,
    /// Count of real (non-dummy) stored indices == nnz of W.
    real_indices: usize,
}

impl SymmetricTcsc {
    /// The dummy row index (reads 0.0 via `PaddedMatrix`).
    #[inline]
    pub fn dummy_index(&self) -> u32 {
        self.k as u32
    }

    /// Number of 4-column groups (`ceil(N/4)`).
    pub fn ngroups(&self) -> usize {
        self.n.div_ceil(4)
    }

    /// Index block of group `g`.
    #[inline]
    pub fn group_indices(&self, g: usize) -> &[u32] {
        &self.indices[self.group_ptr[g] as usize..self.group_ptr[g + 1] as usize]
    }

    /// Build from a dense ternary matrix.
    pub fn from_ternary(w: &TernaryMatrix) -> SymmetricTcsc {
        let (k, n) = (w.k(), w.n());
        let dummy = k as u32;
        let ngroups = n.div_ceil(4);
        let mut steps_per_group = Vec::with_capacity(ngroups);
        let mut group_ptr = Vec::with_capacity(ngroups + 1);
        let mut indices = Vec::new();
        let mut real_indices = 0usize;
        group_ptr.push(0);
        for g in 0..ngroups {
            // Collect per-column pos/neg lists (empty for padded columns).
            let mut pos: [Vec<u32>; 4] = Default::default();
            let mut neg: [Vec<u32>; 4] = Default::default();
            for c in 0..4 {
                let j = 4 * g + c;
                if j < n {
                    pos[c] = w.col_positives(j);
                    neg[c] = w.col_negatives(j);
                    real_indices += pos[c].len() + neg[c].len();
                }
            }
            // Steps needed per column: each step consumes 2 pos + 2 neg.
            let need = (0..4)
                .map(|c| pos[c].len().div_ceil(2).max(neg[c].len().div_ceil(2)))
                .max()
                .unwrap();
            // Pad to an even step count (vertical kernel unrolls by 2).
            let steps = if need % 2 == 0 { need } else { need + 1 };
            steps_per_group.push(steps as u32);
            for t in 0..steps {
                for c in 0..4 {
                    for s in 0..2 {
                        indices.push(*pos[c].get(2 * t + s).unwrap_or(&dummy));
                    }
                    for s in 0..2 {
                        indices.push(*neg[c].get(2 * t + s).unwrap_or(&dummy));
                    }
                }
            }
            group_ptr.push(indices.len() as u32);
        }
        let f = SymmetricTcsc {
            k,
            n,
            steps_per_group,
            group_ptr,
            indices,
            real_indices,
        };
        debug_assert_eq!(f.validate(), Ok(()));
        f
    }
}

impl SparseFormat for SymmetricTcsc {
    const NAME: &'static str = "SymmetricTCSC";

    fn k(&self) -> usize {
        self.k
    }

    fn n(&self) -> usize {
        self.n
    }

    fn nnz(&self) -> usize {
        self.real_indices
    }

    fn bytes(&self) -> usize {
        std::mem::size_of::<u32>()
            * (self.indices.len() + self.group_ptr.len() + self.steps_per_group.len())
    }

    fn to_dense(&self) -> TernaryMatrix {
        let mut w = TernaryMatrix::zeros(self.k, self.n);
        let dummy = self.dummy_index();
        for g in 0..self.ngroups() {
            let block = self.group_indices(g);
            for (t, quad16) in block.chunks(16).enumerate() {
                let _ = t;
                for c in 0..4 {
                    let j = 4 * g + c;
                    if j >= self.n {
                        continue;
                    }
                    let quad = &quad16[4 * c..4 * c + 4];
                    for &i in &quad[..2] {
                        if i != dummy {
                            w.set(i as usize, j, 1);
                        }
                    }
                    for &i in &quad[2..] {
                        if i != dummy {
                            w.set(i as usize, j, -1);
                        }
                    }
                }
            }
        }
        w
    }

    fn validate(&self) -> crate::Result<()> {
        if self.group_ptr.len() != self.ngroups() + 1 {
            return Err(crate::Error::Format("group_ptr length mismatch".into()));
        }
        if self.steps_per_group.len() != self.ngroups() {
            return Err(crate::Error::Format("steps_per_group length mismatch".into()));
        }
        for g in 0..self.ngroups() {
            let steps = self.steps_per_group[g];
            if steps % 2 != 0 {
                return Err(crate::Error::Format(format!("group {g}: odd step count {steps}")));
            }
            let span = self.group_ptr[g + 1] - self.group_ptr[g];
            if span != steps * 16 {
                return Err(crate::Error::Format(format!("group {g}: span {span} != steps·16")));
            }
            for &i in self.group_indices(g) {
                if i > self.k as u32 {
                    return Err(crate::Error::Format(format!("group {g}: index {i} beyond dummy")));
                }
            }
            // Padded (beyond-N) columns must be all-dummy.
            for (ci, chunk) in self.group_indices(g).chunks(4).enumerate() {
                let c = ci % 4;
                let j = 4 * g + c;
                if j >= self.n && chunk.iter().any(|&i| i != self.dummy_index()) {
                    return Err(crate::Error::Format(format!(
                        "group {g}: padded column {j} has real indices"
                    )));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_various_shapes() {
        for &(k, n) in &[(32usize, 8usize), (64, 12), (17, 5), (128, 4), (8, 1)] {
            for &s in &[0.5f32, 0.25, 0.0625] {
                let w = TernaryMatrix::random(k, n, s, (k * n) as u64);
                let f = SymmetricTcsc::from_ternary(&w);
                assert_eq!(f.to_dense(), w, "k{k} n{n} s{s}");
                f.validate().unwrap();
                assert_eq!(f.nnz(), w.nnz());
            }
        }
    }

    #[test]
    fn symmetry_within_groups() {
        let w = TernaryMatrix::random(64, 16, 0.5, 3);
        let f = SymmetricTcsc::from_ternary(&w);
        // All columns in a group consume exactly steps·(2 pos + 2 neg)
        // slots; block size is steps·16.
        for g in 0..f.ngroups() {
            assert_eq!(
                f.group_indices(g).len(),
                f.steps_per_group[g] as usize * 16
            );
            assert_eq!(f.steps_per_group[g] % 2, 0);
        }
    }

    #[test]
    fn deficit_lanes_are_dummy() {
        // One column with only positives: neg slots must be dummy.
        let mut w = TernaryMatrix::zeros(16, 1);
        w.set(0, 0, 1);
        w.set(5, 0, 1);
        let f = SymmetricTcsc::from_ternary(&w);
        let dummy = f.dummy_index();
        let block = f.group_indices(0);
        // col 0, step 0: [0, 5, dummy, dummy]
        assert_eq!(&block[0..4], &[0, 5, dummy, dummy]);
        // padded cols 1..3 all dummy
        assert!(block[4..16].iter().all(|&i| i == dummy));
        assert_eq!(f.to_dense(), w);
    }

    #[test]
    fn dummy_reads_zero_through_padded_matrix() {
        use crate::tensor::{Matrix, PaddedMatrix};
        let w = TernaryMatrix::random(8, 4, 0.5, 1);
        let f = SymmetricTcsc::from_ternary(&w);
        let x = Matrix::random(2, 8, 2);
        let p = PaddedMatrix::from_matrix(&x);
        assert_eq!(p.row(0)[f.dummy_index() as usize], 0.0);
    }

    #[test]
    fn empty_matrix() {
        let w = TernaryMatrix::zeros(8, 8);
        let f = SymmetricTcsc::from_ternary(&w);
        assert_eq!(f.nnz(), 0);
        assert_eq!(f.to_dense(), w);
        // Zero steps everywhere — nothing stored.
        assert!(f.indices.is_empty());
    }
}
