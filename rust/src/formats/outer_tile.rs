//! Tile-panel TCSC — the storage layout behind the outer-product kernel
//! family.
//!
//! Columns are grouped into panels of [`OUTER_TILE`] consecutive output
//! columns. Within a panel the sign-split nonzeros are stored as two
//! streams — `(k, c)` pairs in `(k, c)`-lexicographic order, where `c` is
//! the column offset *inside* the panel (fits in a `u8`). An outer-product
//! kernel walks one panel's streams once per M-row tile: every entry turns
//! into an add (or sub) of a gathered X value into a register-resident
//! T×T accumulator tile, so the accumulators never round-trip through
//! memory inside a panel.
//!
//! The `(k, c)` order is load-bearing for bitwise reproducibility: for any
//! fixed output cell `(r, col)` the entries of that cell's column appear in
//! ascending-k order within the stream, which is exactly the order the
//! sequential baseline ([`crate::kernels::BaseTcscKernel`]) accumulates
//! them in. With one accumulator per cell, positives applied before
//! negatives, the outer-product kernels reproduce the baseline's f32
//! rounding bit for bit.

use crate::formats::SparseFormat;
use crate::ternary::TernaryMatrix;

/// Accumulator tile width: panels cover `OUTER_TILE` output columns, and
/// the kernels pair that with `OUTER_TILE` X rows for a T×T register tile.
pub const OUTER_TILE: usize = 4;

/// Sign-split tile-panel format: per-panel `(k, c)`-ordered entry streams.
#[derive(Debug, Clone, PartialEq)]
pub struct TilePanelTcsc {
    k: usize,
    n: usize,
    /// Panel (column-tile) width; currently always [`OUTER_TILE`].
    pub tile: usize,
    /// Start of each panel's +1 entries in `pos_k`/`pos_c`; length
    /// `panels + 1`.
    pub panel_start_pos: Vec<u32>,
    /// Start of each panel's -1 entries in `neg_k`/`neg_c`; length
    /// `panels + 1`.
    pub panel_start_neg: Vec<u32>,
    /// Row (k) index of every +1 entry, panel-major, `(k, c)`-ascending
    /// within a panel.
    pub pos_k: Vec<u32>,
    /// In-panel column offset of every +1 entry; parallel to `pos_k`.
    pub pos_c: Vec<u8>,
    /// Row (k) index of every -1 entry, panel-major, `(k, c)`-ascending
    /// within a panel.
    pub neg_k: Vec<u32>,
    /// In-panel column offset of every -1 entry; parallel to `neg_k`.
    pub neg_c: Vec<u8>,
}

impl TilePanelTcsc {
    /// Build from a dense ternary matrix, panels of [`OUTER_TILE`] columns.
    pub fn from_ternary(w: &TernaryMatrix) -> TilePanelTcsc {
        let (k, n) = (w.k(), w.n());
        let tile = OUTER_TILE;
        let panels = n.div_ceil(tile);
        let mut panel_start_pos = Vec::with_capacity(panels + 1);
        let mut panel_start_neg = Vec::with_capacity(panels + 1);
        let mut pos_k = Vec::new();
        let mut pos_c = Vec::new();
        let mut neg_k = Vec::new();
        let mut neg_c = Vec::new();
        panel_start_pos.push(0);
        panel_start_neg.push(0);
        for p in 0..panels {
            let col0 = p * tile;
            let width = tile.min(n - col0);
            // k outer, c inner → (k, c)-lexicographic per panel per sign.
            for row in 0..k {
                for c in 0..width {
                    match w.get(row, col0 + c) {
                        1 => {
                            pos_k.push(row as u32);
                            pos_c.push(c as u8);
                        }
                        -1 => {
                            neg_k.push(row as u32);
                            neg_c.push(c as u8);
                        }
                        _ => {}
                    }
                }
            }
            panel_start_pos.push(pos_k.len() as u32);
            panel_start_neg.push(neg_k.len() as u32);
        }
        let f = TilePanelTcsc {
            k,
            n,
            tile,
            panel_start_pos,
            panel_start_neg,
            pos_k,
            pos_c,
            neg_k,
            neg_c,
        };
        debug_assert_eq!(f.validate(), Ok(()));
        f
    }

    /// Number of column panels.
    pub fn panels(&self) -> usize {
        self.n.div_ceil(self.tile)
    }

    /// Width of panel `p` (the last panel may be narrower than `tile`).
    pub fn panel_width(&self, p: usize) -> usize {
        self.tile.min(self.n - p * self.tile)
    }

    /// Panel `p`'s +1 entries as parallel `(k, c)` slices.
    #[inline]
    pub fn panel_pos(&self, p: usize) -> (&[u32], &[u8]) {
        let lo = self.panel_start_pos[p] as usize;
        let hi = self.panel_start_pos[p + 1] as usize;
        (&self.pos_k[lo..hi], &self.pos_c[lo..hi])
    }

    /// Panel `p`'s -1 entries as parallel `(k, c)` slices.
    #[inline]
    pub fn panel_neg(&self, p: usize) -> (&[u32], &[u8]) {
        let lo = self.panel_start_neg[p] as usize;
        let hi = self.panel_start_neg[p + 1] as usize;
        (&self.neg_k[lo..hi], &self.neg_c[lo..hi])
    }

    fn validate_stream(
        &self,
        label: &str,
        panel_start: &[u32],
        ks: &[u32],
        cs: &[u8],
    ) -> crate::Result<()> {
        let panels = self.panels();
        let err = |msg: String| Err(crate::Error::Format(format!("TilePanelTCSC {label}: {msg}")));
        if panel_start.len() != panels + 1 {
            return err(format!("panel_start length {} != panels+1", panel_start.len()));
        }
        if panel_start[0] != 0 {
            return err("panel_start[0] != 0".to_string());
        }
        if *panel_start.last().unwrap() as usize != ks.len() {
            return err("panel_start end != entry count".to_string());
        }
        if ks.len() != cs.len() {
            return err("k/c stream length mismatch".to_string());
        }
        for p in 0..panels {
            if panel_start[p] > panel_start[p + 1] {
                return err(format!("panel_start not monotone at panel {p}"));
            }
            let lo = panel_start[p] as usize;
            let hi = panel_start[p + 1] as usize;
            let width = self.panel_width(p);
            let mut prev: Option<(u32, u8)> = None;
            for (&row, &c) in ks[lo..hi].iter().zip(&cs[lo..hi]) {
                if row as usize >= self.k {
                    return err(format!("panel {p} k index {row} out of range"));
                }
                if c as usize >= width {
                    return err(format!("panel {p} column offset {c} >= width {width}"));
                }
                if let Some(prev) = prev {
                    if prev >= (row, c) {
                        return err(format!("panel {p} entries not strictly (k,c)-ascending"));
                    }
                }
                prev = Some((row, c));
            }
        }
        Ok(())
    }
}

impl SparseFormat for TilePanelTcsc {
    const NAME: &'static str = "TilePanelTCSC";

    fn k(&self) -> usize {
        self.k
    }

    fn n(&self) -> usize {
        self.n
    }

    fn nnz(&self) -> usize {
        self.pos_k.len() + self.neg_k.len()
    }

    fn bytes(&self) -> usize {
        std::mem::size_of::<u32>()
            * (self.panel_start_pos.len()
                + self.panel_start_neg.len()
                + self.pos_k.len()
                + self.neg_k.len())
            + std::mem::size_of::<u8>() * (self.pos_c.len() + self.neg_c.len())
    }

    fn to_dense(&self) -> TernaryMatrix {
        let mut w = TernaryMatrix::zeros(self.k, self.n);
        for p in 0..self.panels() {
            let col0 = p * self.tile;
            let (ks, cs) = self.panel_pos(p);
            for (&row, &c) in ks.iter().zip(cs) {
                w.set(row as usize, col0 + c as usize, 1);
            }
            let (ks, cs) = self.panel_neg(p);
            for (&row, &c) in ks.iter().zip(cs) {
                w.set(row as usize, col0 + c as usize, -1);
            }
        }
        w
    }

    fn validate(&self) -> crate::Result<()> {
        if self.tile == 0 {
            return Err(crate::Error::Format(
                "TilePanelTCSC: tile width must be positive".to_string(),
            ));
        }
        self.validate_stream("pos", &self.panel_start_pos, &self.pos_k, &self.pos_c)?;
        self.validate_stream("neg", &self.panel_start_neg, &self.neg_k, &self.neg_c)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_random() {
        for &s in &crate::PAPER_SPARSITIES {
            // 48 columns = 12 full panels; 50 leaves a 2-wide last panel.
            for n in [48, 50] {
                let w = TernaryMatrix::random(64, n, s, 23);
                let f = TilePanelTcsc::from_ternary(&w);
                assert_eq!(f.to_dense(), w, "sparsity {s} n {n}");
                assert_eq!(f.nnz(), w.nnz());
                f.validate().unwrap();
            }
        }
    }

    #[test]
    fn panel_entries_are_k_ascending_per_column() {
        // The bitwise-identity contract: restricted to one in-panel column,
        // the stream order is ascending k — the baseline's accumulation
        // order.
        let w = TernaryMatrix::random(97, 13, 0.5, 7);
        let f = TilePanelTcsc::from_ternary(&w);
        for p in 0..f.panels() {
            for (ks, cs) in [f.panel_pos(p), f.panel_neg(p)] {
                for c in 0..f.panel_width(p) {
                    let col_ks: Vec<u32> = ks
                        .iter()
                        .zip(cs)
                        .filter(|&(_, &cc)| cc as usize == c)
                        .map(|(&row, _)| row)
                        .collect();
                    assert!(
                        col_ks.windows(2).all(|w| w[0] < w[1]),
                        "panel {p} col {c} not k-ascending"
                    );
                }
            }
        }
    }

    #[test]
    fn narrow_last_panel_and_empty_matrix() {
        let w = TernaryMatrix::zeros(8, 5);
        let f = TilePanelTcsc::from_ternary(&w);
        assert_eq!(f.panels(), 2);
        assert_eq!(f.panel_width(0), 4);
        assert_eq!(f.panel_width(1), 1);
        assert_eq!(f.nnz(), 0);
        assert_eq!(f.to_dense(), w);
        f.validate().unwrap();
    }

    #[test]
    fn bytes_counts_all_arrays() {
        let w = TernaryMatrix::random(16, 8, 0.5, 3);
        let f = TilePanelTcsc::from_ternary(&w);
        let expect = 4 * (2 * (f.panels() + 1) + f.nnz()) + f.nnz();
        assert_eq!(f.bytes(), expect);
    }

    #[test]
    fn validate_catches_corruption() {
        let w = TernaryMatrix::random(16, 8, 0.5, 4);
        let mut f = TilePanelTcsc::from_ternary(&w);
        assert!(!f.pos_c.is_empty(), "seed must produce +1 entries");
        f.pos_c[0] = OUTER_TILE as u8; // offset beyond panel width
        assert!(f.validate().is_err());
        let mut f = TilePanelTcsc::from_ternary(&w);
        f.pos_k[0] = 99; // k out of range
        assert!(f.validate().is_err());
    }
}
