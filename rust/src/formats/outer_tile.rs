//! Tile-panel TCSC — the storage layout behind the outer-product kernel
//! family.
//!
//! Columns are grouped into panels of [`TileGeometry::panel_width`]
//! consecutive output columns (4 or 8; [`OUTER_TILE`] is the default).
//! Within a panel the sign-split nonzeros are stored as two streams —
//! `(k, c)` pairs in `(k, c)`-lexicographic order, where `c` is the column
//! offset *inside* the panel (fits in a `u8`). An outer-product kernel
//! walks one panel's streams once per M-row tile: every entry turns into
//! an add (or sub) of a gathered X value into a register-resident
//! accumulator tile, so the accumulators never round-trip through memory
//! inside a panel.
//!
//! When [`TileGeometry::k_block`] is nonzero the header additionally
//! records per-(panel, K-block) stream offsets, so a kernel can consume a
//! panel's streams in L1d-resident K-slices ([`TilePanelTcsc::panel_pos_block`]).
//! The K-blocks partition each panel stream at ascending-k boundaries, so
//! walking a panel's blocks in order replays the unblocked stream exactly.
//!
//! The `(k, c)` order is load-bearing for bitwise reproducibility: for any
//! fixed output cell `(r, col)` the entries of that cell's column appear in
//! ascending-k order within the stream, which is exactly the order the
//! sequential baseline ([`crate::kernels::BaseTcscKernel`]) accumulates
//! them in. With one accumulator per cell, positives applied before
//! negatives (all of a panel's positive K-blocks before any negative one),
//! the outer-product kernels reproduce the baseline's f32 rounding bit for
//! bit at **every** geometry.

use crate::formats::SparseFormat;
use crate::ternary::TernaryMatrix;

/// Default accumulator tile width: panels cover `OUTER_TILE` output
/// columns, and the kernels pair that with `OUTER_TILE` X rows for a T×T
/// register tile.
pub const OUTER_TILE: usize = 4;

/// Widest panel the format (and the kernels' register tiles) support.
pub const MAX_PANEL_WIDTH: usize = 8;

/// Blocking geometry of a tile-panel format: how wide the column panels
/// are and how the K dimension is sliced. Carried in the format header,
/// threaded through [`crate::kernels::KernelParams`], recorded by tuning
/// entries, and derived from cache sizes by `perf::blocking`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct TileGeometry {
    /// Panel (column-tile) width: 4 or [`MAX_PANEL_WIDTH`].
    pub panel_width: usize,
    /// K-slice length for the blocked walk; `0` = unblocked (one slice
    /// spanning all of K).
    pub k_block: usize,
}

impl TileGeometry {
    /// The pre-geometry-era layout: 4-wide panels, unblocked K. Old tuning
    /// entries (and `KernelParams` with no geometry) resolve to this.
    pub const DEFAULT: TileGeometry = TileGeometry {
        panel_width: OUTER_TILE,
        k_block: 0,
    };

    pub fn new(panel_width: usize, k_block: usize) -> TileGeometry {
        TileGeometry {
            panel_width,
            k_block,
        }
    }

    /// Reject geometries the kernels have no register-tile variant for.
    pub fn validate(&self) -> crate::Result<()> {
        if self.panel_width != OUTER_TILE && self.panel_width != MAX_PANEL_WIDTH {
            return Err(crate::Error::BadKernelParams(format!(
                "tile geometry panel width must be {OUTER_TILE} or {MAX_PANEL_WIDTH}, got {}",
                self.panel_width
            )));
        }
        Ok(())
    }

    /// Number of K-slices a K-row matrix splits into (1 when unblocked or
    /// when K is empty).
    pub fn k_blocks(&self, k: usize) -> usize {
        if self.k_block == 0 {
            1
        } else {
            k.div_ceil(self.k_block).max(1)
        }
    }

    /// Half-open k range `[lo, hi)` of block `b` (the last block may be
    /// short).
    pub fn block_bounds(&self, k: usize, b: usize) -> (usize, usize) {
        if self.k_block == 0 {
            (0, k)
        } else {
            let lo = b * self.k_block;
            (lo.min(k), ((b + 1) * self.k_block).min(k))
        }
    }

    /// Compact spelling used in tuning-table JSON and bench rows:
    /// `p{width}` when unblocked, `p{width}kb{block}` when K-blocked.
    pub fn name(&self) -> String {
        if self.k_block == 0 {
            format!("p{}", self.panel_width)
        } else {
            format!("p{}kb{}", self.panel_width, self.k_block)
        }
    }

    /// Parse the [`TileGeometry::name`] spelling. Strict: `None` for
    /// anything that is not a valid, kernel-supported geometry (JSON
    /// loaders degrade unknown spellings to the default instead of
    /// guessing).
    pub fn parse(s: &str) -> Option<TileGeometry> {
        let rest = s.strip_prefix('p')?;
        let (width_str, block_str) = match rest.split_once("kb") {
            Some((w, b)) => (w, Some(b)),
            None => (rest, None),
        };
        let panel_width: usize = width_str.parse().ok()?;
        let k_block: usize = match block_str {
            Some(b) => {
                let b: usize = b.parse().ok()?;
                if b == 0 {
                    return None; // "kb0" is not a spelling we emit
                }
                b
            }
            None => 0,
        };
        let g = TileGeometry {
            panel_width,
            k_block,
        };
        g.validate().ok()?;
        Some(g)
    }
}

impl Default for TileGeometry {
    fn default() -> Self {
        TileGeometry::DEFAULT
    }
}

impl std::fmt::Display for TileGeometry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.name())
    }
}

/// Sign-split tile-panel format: per-panel, per-K-block `(k, c)`-ordered
/// entry streams, geometry carried in the header.
#[derive(Debug, Clone, PartialEq)]
pub struct TilePanelTcsc {
    k: usize,
    n: usize,
    geom: TileGeometry,
    /// Stream start of each (panel, K-block) slice of the +1 entries;
    /// length `panels · k_blocks + 1`, indexed `p · k_blocks + b`.
    pub block_start_pos: Vec<u32>,
    /// Stream start of each (panel, K-block) slice of the -1 entries.
    pub block_start_neg: Vec<u32>,
    /// Row (k) index of every +1 entry, panel-major then block-major,
    /// `(k, c)`-ascending within a (panel, block).
    pub pos_k: Vec<u32>,
    /// In-panel column offset of every +1 entry; parallel to `pos_k`.
    pub pos_c: Vec<u8>,
    /// Row (k) index of every -1 entry, panel-major then block-major,
    /// `(k, c)`-ascending within a (panel, block).
    pub neg_k: Vec<u32>,
    /// In-panel column offset of every -1 entry; parallel to `neg_k`.
    pub neg_c: Vec<u8>,
}

impl TilePanelTcsc {
    /// Build with the default geometry (4-wide panels, unblocked K).
    pub fn from_ternary(w: &TernaryMatrix) -> TilePanelTcsc {
        TilePanelTcsc::from_ternary_with(w, TileGeometry::DEFAULT)
    }

    /// Build with an explicit geometry. `geom` must pass
    /// [`TileGeometry::validate`] — callers reaching this through the
    /// registry have already validated it via `KernelParams::validate`.
    pub fn from_ternary_with(w: &TernaryMatrix, geom: TileGeometry) -> TilePanelTcsc {
        geom.validate().expect("kernel-supported tile geometry");
        let (k, n) = (w.k(), w.n());
        let tile = geom.panel_width;
        let panels = n.div_ceil(tile);
        let kblocks = geom.k_blocks(k);
        let mut block_start_pos = Vec::with_capacity(panels * kblocks + 1);
        let mut block_start_neg = Vec::with_capacity(panels * kblocks + 1);
        let mut pos_k = Vec::new();
        let mut pos_c = Vec::new();
        let mut neg_k = Vec::new();
        let mut neg_c = Vec::new();
        block_start_pos.push(0);
        block_start_neg.push(0);
        for p in 0..panels {
            let col0 = p * tile;
            let width = tile.min(n - col0);
            for b in 0..kblocks {
                let (klo, khi) = geom.block_bounds(k, b);
                // k outer, c inner → (k, c)-lexicographic per (panel,
                // block) per sign; blocks ascend in k, so the panel's
                // concatenated stream is identical to the unblocked one.
                for row in klo..khi {
                    for c in 0..width {
                        match w.get(row, col0 + c) {
                            1 => {
                                pos_k.push(row as u32);
                                pos_c.push(c as u8);
                            }
                            -1 => {
                                neg_k.push(row as u32);
                                neg_c.push(c as u8);
                            }
                            _ => {}
                        }
                    }
                }
                block_start_pos.push(pos_k.len() as u32);
                block_start_neg.push(neg_k.len() as u32);
            }
        }
        let f = TilePanelTcsc {
            k,
            n,
            geom,
            block_start_pos,
            block_start_neg,
            pos_k,
            pos_c,
            neg_k,
            neg_c,
        };
        debug_assert_eq!(f.validate(), Ok(()));
        f
    }

    /// The blocking geometry carried in the header.
    pub fn geometry(&self) -> TileGeometry {
        self.geom
    }

    /// Panel (column-tile) width.
    pub fn tile(&self) -> usize {
        self.geom.panel_width
    }

    /// Number of column panels.
    pub fn panels(&self) -> usize {
        self.n.div_ceil(self.geom.panel_width)
    }

    /// Number of K-slices per panel (1 when unblocked).
    pub fn k_blocks(&self) -> usize {
        self.geom.k_blocks(self.k)
    }

    /// Width of panel `p` (the last panel may be narrower than the tile).
    pub fn panel_width(&self, p: usize) -> usize {
        self.geom.panel_width.min(self.n - p * self.geom.panel_width)
    }

    /// Panel `p`'s +1 entries as parallel `(k, c)` slices (all K-blocks).
    #[inline]
    pub fn panel_pos(&self, p: usize) -> (&[u32], &[u8]) {
        let kb = self.k_blocks();
        let lo = self.block_start_pos[p * kb] as usize;
        let hi = self.block_start_pos[(p + 1) * kb] as usize;
        (&self.pos_k[lo..hi], &self.pos_c[lo..hi])
    }

    /// Panel `p`'s -1 entries as parallel `(k, c)` slices (all K-blocks).
    #[inline]
    pub fn panel_neg(&self, p: usize) -> (&[u32], &[u8]) {
        let kb = self.k_blocks();
        let lo = self.block_start_neg[p * kb] as usize;
        let hi = self.block_start_neg[(p + 1) * kb] as usize;
        (&self.neg_k[lo..hi], &self.neg_c[lo..hi])
    }

    /// K-block `b` of panel `p`'s +1 entries.
    #[inline]
    pub fn panel_pos_block(&self, p: usize, b: usize) -> (&[u32], &[u8]) {
        let kb = self.k_blocks();
        let lo = self.block_start_pos[p * kb + b] as usize;
        let hi = self.block_start_pos[p * kb + b + 1] as usize;
        (&self.pos_k[lo..hi], &self.pos_c[lo..hi])
    }

    /// K-block `b` of panel `p`'s -1 entries.
    #[inline]
    pub fn panel_neg_block(&self, p: usize, b: usize) -> (&[u32], &[u8]) {
        let kb = self.k_blocks();
        let lo = self.block_start_neg[p * kb + b] as usize;
        let hi = self.block_start_neg[p * kb + b + 1] as usize;
        (&self.neg_k[lo..hi], &self.neg_c[lo..hi])
    }

    fn validate_stream(
        &self,
        label: &str,
        block_start: &[u32],
        ks: &[u32],
        cs: &[u8],
    ) -> crate::Result<()> {
        let panels = self.panels();
        let kblocks = self.k_blocks();
        let err = |msg: String| Err(crate::Error::Format(format!("TilePanelTCSC {label}: {msg}")));
        if block_start.len() != panels * kblocks + 1 {
            return err(format!(
                "block_start length {} != panels·k_blocks+1",
                block_start.len()
            ));
        }
        if block_start[0] != 0 {
            return err("block_start[0] != 0".to_string());
        }
        if *block_start.last().unwrap() as usize != ks.len() {
            return err("block_start end != entry count".to_string());
        }
        if ks.len() != cs.len() {
            return err("k/c stream length mismatch".to_string());
        }
        for p in 0..panels {
            let width = self.panel_width(p);
            for b in 0..kblocks {
                let slot = p * kblocks + b;
                if block_start[slot] > block_start[slot + 1] {
                    return err(format!("block_start not monotone at panel {p} block {b}"));
                }
                let lo = block_start[slot] as usize;
                let hi = block_start[slot + 1] as usize;
                let (klo, khi) = self.geom.block_bounds(self.k, b);
                let mut prev: Option<(u32, u8)> = None;
                for (&row, &c) in ks[lo..hi].iter().zip(&cs[lo..hi]) {
                    if row as usize >= self.k {
                        return err(format!("panel {p} k index {row} out of range"));
                    }
                    if (row as usize) < klo || row as usize >= khi {
                        return err(format!(
                            "panel {p} block {b} k index {row} outside slice [{klo}, {khi})"
                        ));
                    }
                    if c as usize >= width {
                        return err(format!("panel {p} column offset {c} >= width {width}"));
                    }
                    if let Some(prev) = prev {
                        if prev >= (row, c) {
                            return err(format!(
                                "panel {p} block {b} entries not strictly (k,c)-ascending"
                            ));
                        }
                    }
                    prev = Some((row, c));
                }
            }
        }
        Ok(())
    }
}

impl SparseFormat for TilePanelTcsc {
    const NAME: &'static str = "TilePanelTCSC";

    fn k(&self) -> usize {
        self.k
    }

    fn n(&self) -> usize {
        self.n
    }

    fn nnz(&self) -> usize {
        self.pos_k.len() + self.neg_k.len()
    }

    fn bytes(&self) -> usize {
        std::mem::size_of::<u32>()
            * (self.block_start_pos.len()
                + self.block_start_neg.len()
                + self.pos_k.len()
                + self.neg_k.len())
            + std::mem::size_of::<u8>() * (self.pos_c.len() + self.neg_c.len())
    }

    fn to_dense(&self) -> TernaryMatrix {
        let mut w = TernaryMatrix::zeros(self.k, self.n);
        for p in 0..self.panels() {
            let col0 = p * self.geom.panel_width;
            let (ks, cs) = self.panel_pos(p);
            for (&row, &c) in ks.iter().zip(cs) {
                w.set(row as usize, col0 + c as usize, 1);
            }
            let (ks, cs) = self.panel_neg(p);
            for (&row, &c) in ks.iter().zip(cs) {
                w.set(row as usize, col0 + c as usize, -1);
            }
        }
        w
    }

    fn validate(&self) -> crate::Result<()> {
        self.geom.validate().map_err(|e| {
            crate::Error::Format(format!("TilePanelTCSC: bad geometry: {e}"))
        })?;
        self.validate_stream("pos", &self.block_start_pos, &self.pos_k, &self.pos_c)?;
        self.validate_stream("neg", &self.block_start_neg, &self.neg_k, &self.neg_c)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The geometry grid the format tests sweep: both widths, unblocked
    /// plus K-blocks that don't divide K, a degenerate block of 1, and a
    /// block larger than K.
    fn test_geometries() -> Vec<TileGeometry> {
        let mut gs = Vec::new();
        for w in [4usize, 8] {
            for kb in [0usize, 1, 7, 16, 1024] {
                gs.push(TileGeometry::new(w, kb));
            }
        }
        gs
    }

    #[test]
    fn roundtrip_random_across_geometries() {
        for &s in &crate::PAPER_SPARSITIES {
            // 48 columns = full panels at both widths; 50 leaves a narrow
            // last panel at both widths.
            for n in [48, 50] {
                let w = TernaryMatrix::random(64, n, s, 23);
                for g in test_geometries() {
                    let f = TilePanelTcsc::from_ternary_with(&w, g);
                    assert_eq!(f.to_dense(), w, "sparsity {s} n {n} geom {g}");
                    assert_eq!(f.nnz(), w.nnz());
                    f.validate().unwrap();
                }
            }
        }
    }

    #[test]
    fn default_geometry_matches_legacy_layout() {
        let w = TernaryMatrix::random(64, 48, 0.25, 29);
        let f = TilePanelTcsc::from_ternary(&w);
        assert_eq!(f.geometry(), TileGeometry::DEFAULT);
        assert_eq!(f.tile(), OUTER_TILE);
        assert_eq!(f.k_blocks(), 1);
        assert_eq!(f.block_start_pos.len(), f.panels() + 1);
    }

    #[test]
    fn blocked_streams_concatenate_to_the_unblocked_stream() {
        // The bitwise-identity bridge: per panel, walking K-blocks in
        // order must replay the unblocked stream exactly.
        let w = TernaryMatrix::random(97, 26, 0.5, 31);
        for width in [4usize, 8] {
            let flat =
                TilePanelTcsc::from_ternary_with(&w, TileGeometry::new(width, 0));
            let blocked =
                TilePanelTcsc::from_ternary_with(&w, TileGeometry::new(width, 16));
            assert_eq!(blocked.k_blocks(), 97usize.div_ceil(16));
            for p in 0..flat.panels() {
                let (fk, fc) = flat.panel_pos(p);
                let mut bk: Vec<u32> = Vec::new();
                let mut bc: Vec<u8> = Vec::new();
                for b in 0..blocked.k_blocks() {
                    let (ks, cs) = blocked.panel_pos_block(p, b);
                    bk.extend_from_slice(ks);
                    bc.extend_from_slice(cs);
                }
                assert_eq!((fk, fc), (bk.as_slice(), bc.as_slice()), "panel {p}");
                let (fk, fc) = flat.panel_neg(p);
                let mut bk: Vec<u32> = Vec::new();
                let mut bc: Vec<u8> = Vec::new();
                for b in 0..blocked.k_blocks() {
                    let (ks, cs) = blocked.panel_neg_block(p, b);
                    bk.extend_from_slice(ks);
                    bc.extend_from_slice(cs);
                }
                assert_eq!((fk, fc), (bk.as_slice(), bc.as_slice()), "panel {p} neg");
            }
        }
    }

    #[test]
    fn panel_entries_are_k_ascending_per_column() {
        // The bitwise-identity contract: restricted to one in-panel column,
        // the stream order is ascending k — the baseline's accumulation
        // order — at every geometry.
        let w = TernaryMatrix::random(97, 13, 0.5, 7);
        for g in test_geometries() {
            let f = TilePanelTcsc::from_ternary_with(&w, g);
            for p in 0..f.panels() {
                for (ks, cs) in [f.panel_pos(p), f.panel_neg(p)] {
                    for c in 0..f.panel_width(p) {
                        let col_ks: Vec<u32> = ks
                            .iter()
                            .zip(cs)
                            .filter(|&(_, &cc)| cc as usize == c)
                            .map(|(&row, _)| row)
                            .collect();
                        assert!(
                            col_ks.windows(2).all(|w| w[0] < w[1]),
                            "geom {g} panel {p} col {c} not k-ascending"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn narrow_last_panel_and_empty_matrix() {
        let w = TernaryMatrix::zeros(8, 5);
        let f = TilePanelTcsc::from_ternary(&w);
        assert_eq!(f.panels(), 2);
        assert_eq!(f.panel_width(0), 4);
        assert_eq!(f.panel_width(1), 1);
        assert_eq!(f.nnz(), 0);
        assert_eq!(f.to_dense(), w);
        f.validate().unwrap();
        let f8 = TilePanelTcsc::from_ternary_with(&w, TileGeometry::new(8, 0));
        assert_eq!(f8.panels(), 1);
        assert_eq!(f8.panel_width(0), 5);
        f8.validate().unwrap();
    }

    #[test]
    fn bytes_counts_all_arrays() {
        let w = TernaryMatrix::random(16, 8, 0.5, 3);
        for g in [TileGeometry::DEFAULT, TileGeometry::new(8, 4)] {
            let f = TilePanelTcsc::from_ternary_with(&w, g);
            let slots = f.panels() * f.k_blocks() + 1;
            let expect = 4 * (2 * slots + f.nnz()) + f.nnz();
            assert_eq!(f.bytes(), expect, "geom {g}");
        }
    }

    #[test]
    fn geometry_name_parse_roundtrip() {
        for g in [
            TileGeometry::DEFAULT,
            TileGeometry::new(8, 0),
            TileGeometry::new(4, 1024),
            TileGeometry::new(8, 4096),
        ] {
            assert_eq!(TileGeometry::parse(&g.name()), Some(g), "{g}");
        }
        assert_eq!(TileGeometry::DEFAULT.name(), "p4");
        assert_eq!(TileGeometry::new(8, 1024).name(), "p8kb1024");
        // Invalid spellings and unsupported widths do not parse.
        for bad in ["", "p", "p3", "p16", "p4kb", "p4kb0", "4kb8", "p4kbx"] {
            assert_eq!(TileGeometry::parse(bad), None, "{bad:?}");
        }
        assert!(TileGeometry::new(5, 0).validate().is_err());
        assert!(TileGeometry::new(8, 123).validate().is_ok());
    }

    #[test]
    fn block_bounds_cover_k_exactly() {
        let g = TileGeometry::new(4, 16);
        let k = 37;
        assert_eq!(g.k_blocks(k), 3);
        let mut covered = 0;
        for b in 0..g.k_blocks(k) {
            let (lo, hi) = g.block_bounds(k, b);
            assert_eq!(lo, covered);
            assert!(hi <= k);
            covered = hi;
        }
        assert_eq!(covered, k);
        // Unblocked: one slice spanning K; empty K still has one block.
        assert_eq!(TileGeometry::DEFAULT.k_blocks(37), 1);
        assert_eq!(TileGeometry::DEFAULT.block_bounds(37, 0), (0, 37));
        assert_eq!(TileGeometry::new(4, 16).k_blocks(0), 1);
    }

    #[test]
    fn validate_catches_corruption() {
        let w = TernaryMatrix::random(16, 8, 0.5, 4);
        let mut f = TilePanelTcsc::from_ternary(&w);
        assert!(!f.pos_c.is_empty(), "seed must produce +1 entries");
        f.pos_c[0] = OUTER_TILE as u8; // offset beyond panel width
        assert!(f.validate().is_err());
        let mut f = TilePanelTcsc::from_ternary(&w);
        f.pos_k[0] = 99; // k out of range
        assert!(f.validate().is_err());
        // A k index outside its K-block's slice is caught even when it is
        // in range for the matrix.
        let mut f = TilePanelTcsc::from_ternary_with(&w, TileGeometry::new(4, 8));
        let (lo, hi) = (f.block_start_pos[0] as usize, f.block_start_pos[1] as usize);
        if hi > lo {
            f.pos_k[lo] = 15; // block 0 spans k in [0, 8)
            assert!(f.validate().is_err());
        }
    }
}
