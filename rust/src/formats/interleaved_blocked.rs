//! InterleavedBlockedTCSC (paper §3 "Interleaving + Blocking") — the
//! paper's best scalar format: the K rows are blocked (B = 4096) for X
//! locality *and* each blocked column stores one interleaved index stream
//! with three segments (interleaved ± groups, remaining positives,
//! remaining negatives).

use crate::formats::{num_blocks, SparseFormat};
use crate::ternary::TernaryMatrix;

/// Blocked + interleaved sign-grouped CSC. Segment pointers are laid out
/// block-major: for block `b`, column `j`, the three segments start at
/// `col_segment_ptr[3·(b·N + j) + {0,1,2}]`.
#[derive(Debug, Clone, PartialEq)]
pub struct InterleavedBlockedTcsc {
    k: usize,
    n: usize,
    pub block_size: usize,
    /// Indices per sign per interleave group (G).
    pub group: usize,
    /// Single index stream: per (block, column) `[interleaved | rest-pos |
    /// rest-neg]`, block-major.
    pub all_indices: Vec<u32>,
    /// Segment pointers, 3 per (block, column) + 1.
    pub col_segment_ptr: Vec<u32>,
}

impl InterleavedBlockedTcsc {
    /// Build with block size `B` (paper: `min(K, 4096)`) and group `G`
    /// (paper: 4 — with unroll factor F, F/2 per sign).
    pub fn from_ternary(w: &TernaryMatrix, block_size: usize, group: usize) -> Self {
        assert!(group >= 1 && block_size >= 1);
        let (k, n) = (w.k(), w.n());
        let nblocks = num_blocks(k.max(1), block_size);
        let mut all_indices = Vec::new();
        let mut col_segment_ptr = Vec::with_capacity(3 * nblocks * n + 1);
        col_segment_ptr.push(0);
        // Scratch per-column-per-block sign lists.
        let mut pos: Vec<u32> = Vec::new();
        let mut neg: Vec<u32> = Vec::new();
        for b in 0..nblocks {
            let lo = b * block_size;
            let hi = ((b + 1) * block_size).min(k);
            for j in 0..n {
                pos.clear();
                neg.clear();
                for i in lo..hi {
                    match w.get(i, j) {
                        1 => pos.push(i as u32),
                        -1 => neg.push(i as u32),
                        _ => {}
                    }
                }
                let full = (pos.len() / group).min(neg.len() / group);
                for g in 0..full {
                    all_indices.extend_from_slice(&pos[g * group..(g + 1) * group]);
                    all_indices.extend_from_slice(&neg[g * group..(g + 1) * group]);
                }
                col_segment_ptr.push(all_indices.len() as u32);
                all_indices.extend_from_slice(&pos[full * group..]);
                col_segment_ptr.push(all_indices.len() as u32);
                all_indices.extend_from_slice(&neg[full * group..]);
                col_segment_ptr.push(all_indices.len() as u32);
            }
        }
        let f = InterleavedBlockedTcsc {
            k,
            n,
            block_size,
            group,
            all_indices,
            col_segment_ptr,
        };
        debug_assert_eq!(f.validate(), Ok(()));
        f
    }

    pub fn nblocks(&self) -> usize {
        num_blocks(self.k.max(1), self.block_size)
    }

    #[inline]
    fn base(&self, b: usize, j: usize) -> usize {
        3 * (b * self.n + j)
    }

    /// Interleaved segment for (block, column).
    #[inline]
    pub fn seg_interleaved(&self, b: usize, j: usize) -> &[u32] {
        let p = self.base(b, j);
        &self.all_indices[self.col_segment_ptr[p] as usize..self.col_segment_ptr[p + 1] as usize]
    }

    /// Remaining-positive segment for (block, column).
    #[inline]
    pub fn seg_rest_pos(&self, b: usize, j: usize) -> &[u32] {
        let p = self.base(b, j);
        &self.all_indices
            [self.col_segment_ptr[p + 1] as usize..self.col_segment_ptr[p + 2] as usize]
    }

    /// Remaining-negative segment for (block, column).
    #[inline]
    pub fn seg_rest_neg(&self, b: usize, j: usize) -> &[u32] {
        let p = self.base(b, j);
        &self.all_indices
            [self.col_segment_ptr[p + 2] as usize..self.col_segment_ptr[p + 3] as usize]
    }
}

impl SparseFormat for InterleavedBlockedTcsc {
    const NAME: &'static str = "InterleavedBlockedTCSC";

    fn k(&self) -> usize {
        self.k
    }

    fn n(&self) -> usize {
        self.n
    }

    fn nnz(&self) -> usize {
        self.all_indices.len()
    }

    fn bytes(&self) -> usize {
        std::mem::size_of::<u32>() * (self.all_indices.len() + self.col_segment_ptr.len())
    }

    fn to_dense(&self) -> TernaryMatrix {
        let mut w = TernaryMatrix::zeros(self.k, self.n);
        for b in 0..self.nblocks() {
            for j in 0..self.n {
                for (ci, chunk) in self.seg_interleaved(b, j).chunks(self.group).enumerate() {
                    let sign = if ci % 2 == 0 { 1 } else { -1 };
                    for &i in chunk {
                        w.set(i as usize, j, sign);
                    }
                }
                for &i in self.seg_rest_pos(b, j) {
                    w.set(i as usize, j, 1);
                }
                for &i in self.seg_rest_neg(b, j) {
                    w.set(i as usize, j, -1);
                }
            }
        }
        w
    }

    fn validate(&self) -> crate::Result<()> {
        let nblocks = self.nblocks();
        if self.col_segment_ptr.len() != 3 * nblocks * self.n + 1 {
            return Err(crate::Error::Format("segment pointer length mismatch".into()));
        }
        for w in self.col_segment_ptr.windows(2) {
            if w[0] > w[1] {
                return Err(crate::Error::Format("segment pointers not monotone".into()));
            }
        }
        if *self.col_segment_ptr.last().unwrap() as usize != self.all_indices.len() {
            return Err(crate::Error::Format("segment pointer end mismatch".into()));
        }
        for b in 0..nblocks {
            let lo = (b * self.block_size) as u32;
            let hi = (((b + 1) * self.block_size).min(self.k)) as u32;
            for j in 0..self.n {
                if self.seg_interleaved(b, j).len() % (2 * self.group) != 0 {
                    return Err(crate::Error::Format(format!(
                        "block {b} col {j}: bad interleaved length"
                    )));
                }
                for &i in self
                    .seg_interleaved(b, j)
                    .iter()
                    .chain(self.seg_rest_pos(b, j))
                    .chain(self.seg_rest_neg(b, j))
                {
                    if i < lo || i >= hi {
                        return Err(crate::Error::Format(format!(
                            "block {b} col {j}: index {i} outside [{lo},{hi})"
                        )));
                    }
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_grid() {
        let w = TernaryMatrix::random(100, 16, 0.25, 55);
        for bs in [7, 25, 100, 4096] {
            for g in [1, 2, 4] {
                let f = InterleavedBlockedTcsc::from_ternary(&w, bs, g);
                assert_eq!(f.to_dense(), w, "bs {bs} g {g}");
                f.validate().unwrap();
            }
        }
    }

    #[test]
    fn single_block_matches_interleaved() {
        use crate::formats::InterleavedTcsc;
        let w = TernaryMatrix::random(64, 8, 0.5, 77);
        let a = InterleavedBlockedTcsc::from_ternary(&w, 64, 4);
        let b = InterleavedTcsc::from_ternary(&w, 4);
        assert_eq!(a.all_indices, b.all_indices);
    }

    #[test]
    fn nnz_preserved_across_blocking() {
        let w = TernaryMatrix::random(129, 9, 0.5, 8);
        let f = InterleavedBlockedTcsc::from_ternary(&w, 32, 2);
        assert_eq!(f.nnz(), w.nnz());
    }

    #[test]
    fn segments_within_block_range() {
        let w = TernaryMatrix::random(64, 4, 0.5, 2);
        let f = InterleavedBlockedTcsc::from_ternary(&w, 16, 2);
        for b in 0..f.nblocks() {
            for j in 0..4 {
                for &i in f.seg_interleaved(b, j) {
                    assert_eq!((i as usize) / 16, b);
                }
            }
        }
    }

    #[test]
    fn all_zero_matrix() {
        let w = TernaryMatrix::zeros(32, 4);
        let f = InterleavedBlockedTcsc::from_ternary(&w, 8, 4);
        assert_eq!(f.nnz(), 0);
        assert_eq!(f.to_dense(), w);
    }
}
