//! Sparse ternary storage formats — every layout the paper introduces,
//! including the two it evaluates and drops (value compression, inverted
//! index), because the ablation benches reproduce those negative results.
//!
//! All formats are built from a dense [`TernaryMatrix`] ground truth,
//! validate their internal invariants on construction (debug assertions +
//! explicit `validate()`), and can reconstruct the dense matrix via
//! [`SparseFormat::to_dense`] — the round-trip property every format test
//! exercises.

pub mod tcsc;
pub mod blocked;
pub mod interleaved;
pub mod interleaved_blocked;
pub mod symmetric;
pub mod compressed;
pub mod inverted;
pub mod outer_tile;

pub use outer_tile::{TileGeometry, TilePanelTcsc, MAX_PANEL_WIDTH, OUTER_TILE};
pub use tcsc::Tcsc;
pub use blocked::BlockedTcsc;
pub use interleaved::InterleavedTcsc;
pub use interleaved_blocked::InterleavedBlockedTcsc;
pub use symmetric::SymmetricTcsc;
pub use compressed::CompressedTernary;
pub use inverted::InvertedIndex;

use crate::ternary::TernaryMatrix;

/// Common interface over all sparse ternary formats.
pub trait SparseFormat: Sized {
    /// Human-readable format name (used in benchmark tables).
    const NAME: &'static str;

    /// Logical shape: W is K×N.
    fn k(&self) -> usize;
    fn n(&self) -> usize;

    /// Number of stored nonzeros (excluding any padding the format adds).
    fn nnz(&self) -> usize;

    /// Exact in-memory byte size of the format's arrays — the quantity the
    /// paper's Fig 10 operational-intensity estimate uses.
    fn bytes(&self) -> usize;

    /// Reconstruct the dense ternary matrix (tests: roundtrip identity).
    fn to_dense(&self) -> TernaryMatrix;

    /// Check internal invariants; returns an error description on violation.
    fn validate(&self) -> crate::Result<()>;
}

/// Shared helper: standard block count for blocked formats.
pub(crate) fn num_blocks(k: usize, block_size: usize) -> usize {
    assert!(block_size > 0, "block size must be positive");
    k.div_ceil(block_size)
}
