//! BlockedTCSC (paper §3 "Blocking", Fig 5).
//!
//! The K rows are split into blocks of size `B`; the format stores, for
//! every block in turn, the TCSC arrays of every column restricted to that
//! block's row range. Iterating block-major constrains all `X[row_index]`
//! accesses within a processing phase to a window of `B` elements,
//! shrinking the working set of X from K to B (paper-optimal B = 4096).

use crate::formats::{num_blocks, SparseFormat};
use crate::ternary::TernaryMatrix;

/// Blocked sign-split CSC. Row indices are stored *absolute* (within
/// `[b·B, (b+1)·B)` for block `b`) so kernels index X directly.
#[derive(Debug, Clone, PartialEq)]
pub struct BlockedTcsc {
    k: usize,
    n: usize,
    /// Rows per block.
    pub block_size: usize,
    /// Per (block, column) start pointers for +1s; length nblocks·N + 1,
    /// block-major (`ptr[b·N + j]`).
    pub col_start_pos: Vec<u32>,
    /// Per (block, column) start pointers for -1s; same layout.
    pub col_start_neg: Vec<u32>,
    /// +1 row indices, block-major then column-wise, ascending per segment.
    pub row_index_pos: Vec<u32>,
    /// -1 row indices, same layout.
    pub row_index_neg: Vec<u32>,
}

impl BlockedTcsc {
    /// Build with the given block size (the paper uses `min(K, 4096)`).
    pub fn from_ternary(w: &TernaryMatrix, block_size: usize) -> BlockedTcsc {
        let (k, n) = (w.k(), w.n());
        let nblocks = num_blocks(k.max(1), block_size);
        let mut col_start_pos = Vec::with_capacity(nblocks * n + 1);
        let mut col_start_neg = Vec::with_capacity(nblocks * n + 1);
        let mut row_index_pos = Vec::new();
        let mut row_index_neg = Vec::new();
        col_start_pos.push(0);
        col_start_neg.push(0);
        for b in 0..nblocks {
            let lo = b * block_size;
            let hi = ((b + 1) * block_size).min(k);
            for j in 0..n {
                for i in lo..hi {
                    match w.get(i, j) {
                        1 => row_index_pos.push(i as u32),
                        -1 => row_index_neg.push(i as u32),
                        _ => {}
                    }
                }
                col_start_pos.push(row_index_pos.len() as u32);
                col_start_neg.push(row_index_neg.len() as u32);
            }
        }
        let f = BlockedTcsc {
            k,
            n,
            block_size,
            col_start_pos,
            col_start_neg,
            row_index_pos,
            row_index_neg,
        };
        debug_assert_eq!(f.validate(), Ok(()));
        f
    }

    /// Number of row blocks.
    pub fn nblocks(&self) -> usize {
        num_blocks(self.k.max(1), self.block_size)
    }

    /// Positive row indices for (block `b`, column `j`).
    #[inline]
    pub fn block_col_pos(&self, b: usize, j: usize) -> &[u32] {
        let p = b * self.n + j;
        &self.row_index_pos[self.col_start_pos[p] as usize..self.col_start_pos[p + 1] as usize]
    }

    /// Negative row indices for (block `b`, column `j`).
    #[inline]
    pub fn block_col_neg(&self, b: usize, j: usize) -> &[u32] {
        let p = b * self.n + j;
        &self.row_index_neg[self.col_start_neg[p] as usize..self.col_start_neg[p + 1] as usize]
    }
}

impl SparseFormat for BlockedTcsc {
    const NAME: &'static str = "BlockedTCSC";

    fn k(&self) -> usize {
        self.k
    }

    fn n(&self) -> usize {
        self.n
    }

    fn nnz(&self) -> usize {
        self.row_index_pos.len() + self.row_index_neg.len()
    }

    fn bytes(&self) -> usize {
        std::mem::size_of::<u32>()
            * (self.col_start_pos.len()
                + self.col_start_neg.len()
                + self.row_index_pos.len()
                + self.row_index_neg.len())
    }

    fn to_dense(&self) -> TernaryMatrix {
        let mut w = TernaryMatrix::zeros(self.k, self.n);
        for b in 0..self.nblocks() {
            for j in 0..self.n {
                for &i in self.block_col_pos(b, j) {
                    w.set(i as usize, j, 1);
                }
                for &i in self.block_col_neg(b, j) {
                    w.set(i as usize, j, -1);
                }
            }
        }
        w
    }

    fn validate(&self) -> crate::Result<()> {
        let nblocks = self.nblocks();
        let expect_ptrs = nblocks * self.n + 1;
        if self.col_start_pos.len() != expect_ptrs || self.col_start_neg.len() != expect_ptrs {
            return Err(crate::Error::Format("pointer array length mismatch".into()));
        }
        for b in 0..nblocks {
            let lo = (b * self.block_size) as u32;
            let hi = (((b + 1) * self.block_size).min(self.k)) as u32;
            for j in 0..self.n {
                for (label, seg) in [
                    ("pos", self.block_col_pos(b, j)),
                    ("neg", self.block_col_neg(b, j)),
                ] {
                    for w in seg.windows(2) {
                        if w[0] >= w[1] {
                            return Err(crate::Error::Format(format!(
                                "{label}: block {b} col {j} not strictly ascending"
                            )));
                        }
                    }
                    for &i in seg {
                        if i < lo || i >= hi {
                            return Err(crate::Error::Format(format!(
                                "{label}: block {b} col {j} index {i} outside [{lo},{hi})"
                            )));
                        }
                    }
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_various_block_sizes() {
        let w = TernaryMatrix::random(100, 24, 0.25, 31);
        for bs in [1, 2, 16, 50, 100, 128, 4096] {
            let f = BlockedTcsc::from_ternary(&w, bs);
            assert_eq!(f.to_dense(), w, "block size {bs}");
            f.validate().unwrap();
        }
    }

    #[test]
    fn indices_constrained_to_block_window() {
        let w = TernaryMatrix::random(64, 8, 0.5, 7);
        let f = BlockedTcsc::from_ternary(&w, 16);
        assert_eq!(f.nblocks(), 4);
        for b in 0..4 {
            for j in 0..8 {
                for &i in f.block_col_pos(b, j) {
                    assert!((i as usize) / 16 == b);
                }
                for &i in f.block_col_neg(b, j) {
                    assert!((i as usize) / 16 == b);
                }
            }
        }
    }

    #[test]
    fn single_block_equals_tcsc_content() {
        use crate::formats::Tcsc;
        let w = TernaryMatrix::random(32, 16, 0.5, 9);
        let t = Tcsc::from_ternary(&w);
        let b = BlockedTcsc::from_ternary(&w, 32); // one block
        assert_eq!(b.row_index_pos, t.row_index_pos);
        assert_eq!(b.row_index_neg, t.row_index_neg);
    }

    #[test]
    fn nnz_preserved() {
        let w = TernaryMatrix::random(77, 13, 0.125, 3);
        let f = BlockedTcsc::from_ternary(&w, 10);
        assert_eq!(f.nnz(), w.nnz());
    }

    #[test]
    fn block_size_larger_than_k() {
        let w = TernaryMatrix::random(8, 8, 0.5, 4);
        let f = BlockedTcsc::from_ternary(&w, 4096);
        assert_eq!(f.nblocks(), 1);
        assert_eq!(f.to_dense(), w);
    }

    #[test]
    fn fig5_style_example() {
        // B=2 over a 4-row matrix: block 0 holds rows 0-1, block 1 rows 2-3.
        let mut w = TernaryMatrix::zeros(4, 2);
        w.set(0, 0, 1);
        w.set(3, 0, -1);
        w.set(1, 1, 1);
        w.set(2, 1, 1);
        let f = BlockedTcsc::from_ternary(&w, 2);
        assert_eq!(f.block_col_pos(0, 0), &[0]);
        assert_eq!(f.block_col_pos(0, 1), &[1]);
        assert_eq!(f.block_col_pos(1, 1), &[2]);
        assert_eq!(f.block_col_neg(1, 0), &[3]);
    }
}
