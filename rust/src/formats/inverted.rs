//! Inverted-index format (paper §3 "Inverted Index") — positive and
//! negative indices merged into one row-sorted stream per column, the sign
//! encoded in the index itself: `+1` at row `i` is stored as `i`, `-1` as
//! `!i` (bitwise NOT). Halves the column pointers and unifies the inner
//! loops, but decoding branches in the innermost loop — the paper measured
//! it *slower* than the baseline and abandoned it; the ablation bench
//! reproduces that.

use crate::formats::SparseFormat;
use crate::ternary::TernaryMatrix;

/// Merged single-stream CSC with sign-in-index encoding.
#[derive(Debug, Clone, PartialEq)]
pub struct InvertedIndex {
    k: usize,
    n: usize,
    /// Column start pointers; length N+1.
    pub col_start: Vec<u32>,
    /// Encoded indices, column-wise, ascending by *row*: `i` for +1,
    /// `!i` for -1.
    pub indices: Vec<u32>,
}

/// Decode an entry into (row, sign).
#[inline(always)]
pub fn decode(entry: u32) -> (usize, i8) {
    if entry & 0x8000_0000 != 0 {
        ((!entry) as usize, -1)
    } else {
        (entry as usize, 1)
    }
}

/// Encode (row, sign) into an entry.
#[inline(always)]
pub fn encode(row: usize, sign: i8) -> u32 {
    debug_assert!(row < (1 << 31));
    if sign >= 0 {
        row as u32
    } else {
        !(row as u32)
    }
}

impl InvertedIndex {
    pub fn from_ternary(w: &TernaryMatrix) -> InvertedIndex {
        let (k, n) = (w.k(), w.n());
        let mut col_start = Vec::with_capacity(n + 1);
        let mut indices = Vec::new();
        col_start.push(0);
        for j in 0..n {
            // Row-sorted merge: walk rows once, keeping X access order
            // monotone within the column (the format's locality win).
            for i in 0..k {
                match w.get(i, j) {
                    1 => indices.push(encode(i, 1)),
                    -1 => indices.push(encode(i, -1)),
                    _ => {}
                }
            }
            col_start.push(indices.len() as u32);
        }
        let f = InvertedIndex {
            k,
            n,
            col_start,
            indices,
        };
        debug_assert_eq!(f.validate(), Ok(()));
        f
    }

    /// Encoded entries of column `j`.
    #[inline]
    pub fn col(&self, j: usize) -> &[u32] {
        &self.indices[self.col_start[j] as usize..self.col_start[j + 1] as usize]
    }
}

impl SparseFormat for InvertedIndex {
    const NAME: &'static str = "InvertedIndex";

    fn k(&self) -> usize {
        self.k
    }

    fn n(&self) -> usize {
        self.n
    }

    fn nnz(&self) -> usize {
        self.indices.len()
    }

    fn bytes(&self) -> usize {
        std::mem::size_of::<u32>() * (self.col_start.len() + self.indices.len())
    }

    fn to_dense(&self) -> TernaryMatrix {
        let mut w = TernaryMatrix::zeros(self.k, self.n);
        for j in 0..self.n {
            for &e in self.col(j) {
                let (i, s) = decode(e);
                w.set(i, j, s);
            }
        }
        w
    }

    fn validate(&self) -> crate::Result<()> {
        if self.col_start.len() != self.n + 1 || self.col_start[0] != 0 {
            return Err(crate::Error::Format("bad column pointers".into()));
        }
        if *self.col_start.last().unwrap() as usize != self.indices.len() {
            return Err(crate::Error::Format("pointer end mismatch".into()));
        }
        for j in 0..self.n {
            let mut prev_row: Option<usize> = None;
            for &e in self.col(j) {
                let (i, _) = decode(e);
                if i >= self.k {
                    return Err(crate::Error::Format(format!("column {j}: row {i} out of range")));
                }
                if let Some(p) = prev_row {
                    if i <= p {
                        return Err(crate::Error::Format(format!(
                            "column {j}: rows not strictly ascending"
                        )));
                    }
                }
                prev_row = Some(i);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_decode_inverse() {
        for row in [0usize, 1, 1000, (1 << 30)] {
            for sign in [1i8, -1] {
                let (r, s) = decode(encode(row, sign));
                assert_eq!((r, s), (row, sign));
            }
        }
    }

    #[test]
    fn negative_encoding_sets_high_bit() {
        assert_eq!(encode(0, -1), 0xFFFF_FFFF);
        assert_eq!(encode(5, -1), !5u32);
        assert_eq!(encode(5, 1), 5);
    }

    #[test]
    fn roundtrip_random() {
        for &s in &crate::PAPER_SPARSITIES {
            let w = TernaryMatrix::random(64, 32, s, 71);
            let f = InvertedIndex::from_ternary(&w);
            assert_eq!(f.to_dense(), w);
            f.validate().unwrap();
            assert_eq!(f.nnz(), w.nnz());
        }
    }

    #[test]
    fn halves_pointer_arrays_vs_tcsc() {
        use crate::formats::Tcsc;
        let w = TernaryMatrix::random(64, 32, 0.25, 5);
        let inv = InvertedIndex::from_ternary(&w);
        let tcsc = Tcsc::from_ternary(&w);
        // Same index count, half the pointers.
        assert_eq!(inv.indices.len(), tcsc.row_index_pos.len() + tcsc.row_index_neg.len());
        assert_eq!(inv.col_start.len() * 2, tcsc.col_start_pos.len() + tcsc.col_start_neg.len());
        assert!(inv.bytes() < tcsc.bytes());
    }

    #[test]
    fn rows_sorted_within_column() {
        let w = TernaryMatrix::random(128, 8, 0.5, 99);
        let f = InvertedIndex::from_ternary(&w);
        for j in 0..8 {
            let rows: Vec<usize> = f.col(j).iter().map(|&e| decode(e).0).collect();
            let mut sorted = rows.clone();
            sorted.sort_unstable();
            assert_eq!(rows, sorted);
        }
    }
}
