//! Ternary Compressed Sparse Column (TCSC) — the paper's baseline format.
//!
//! Four integer arrays (paper §2, Fig 1): column start pointers and
//! column-wise row indices, kept separately for +1 and -1 entries. The sign
//! is implicit in which array an index lives in, so no value array exists.

use crate::formats::SparseFormat;
use crate::ternary::TernaryMatrix;

/// Baseline TCSC: sign-split CSC with implicit values.
#[derive(Debug, Clone, PartialEq)]
pub struct Tcsc {
    k: usize,
    n: usize,
    /// Column start pointers for +1 entries; length N+1.
    pub col_start_pos: Vec<u32>,
    /// Column start pointers for -1 entries; length N+1.
    pub col_start_neg: Vec<u32>,
    /// Row indices of all +1 entries, column-wise, ascending within column.
    pub row_index_pos: Vec<u32>,
    /// Row indices of all -1 entries, column-wise, ascending within column.
    pub row_index_neg: Vec<u32>,
}

impl Tcsc {
    /// Build from a dense ternary matrix.
    pub fn from_ternary(w: &TernaryMatrix) -> Tcsc {
        let (k, n) = (w.k(), w.n());
        let mut col_start_pos = Vec::with_capacity(n + 1);
        let mut col_start_neg = Vec::with_capacity(n + 1);
        let mut row_index_pos = Vec::new();
        let mut row_index_neg = Vec::new();
        col_start_pos.push(0);
        col_start_neg.push(0);
        for j in 0..n {
            row_index_pos.extend(w.col_positives(j));
            row_index_neg.extend(w.col_negatives(j));
            col_start_pos.push(row_index_pos.len() as u32);
            col_start_neg.push(row_index_neg.len() as u32);
        }
        let f = Tcsc {
            k,
            n,
            col_start_pos,
            col_start_neg,
            row_index_pos,
            row_index_neg,
        };
        debug_assert_eq!(f.validate(), Ok(()));
        f
    }

    /// Positive row indices of column `j`.
    #[inline]
    pub fn col_pos(&self, j: usize) -> &[u32] {
        &self.row_index_pos
            [self.col_start_pos[j] as usize..self.col_start_pos[j + 1] as usize]
    }

    /// Negative row indices of column `j`.
    #[inline]
    pub fn col_neg(&self, j: usize) -> &[u32] {
        &self.row_index_neg
            [self.col_start_neg[j] as usize..self.col_start_neg[j + 1] as usize]
    }
}

impl SparseFormat for Tcsc {
    const NAME: &'static str = "TCSC";

    fn k(&self) -> usize {
        self.k
    }

    fn n(&self) -> usize {
        self.n
    }

    fn nnz(&self) -> usize {
        self.row_index_pos.len() + self.row_index_neg.len()
    }

    fn bytes(&self) -> usize {
        std::mem::size_of::<u32>()
            * (self.col_start_pos.len()
                + self.col_start_neg.len()
                + self.row_index_pos.len()
                + self.row_index_neg.len())
    }

    fn to_dense(&self) -> TernaryMatrix {
        let mut w = TernaryMatrix::zeros(self.k, self.n);
        for j in 0..self.n {
            for &i in self.col_pos(j) {
                w.set(i as usize, j, 1);
            }
            for &i in self.col_neg(j) {
                w.set(i as usize, j, -1);
            }
        }
        w
    }

    fn validate(&self) -> crate::Result<()> {
        validate_csc(
            "pos",
            self.k,
            self.n,
            &self.col_start_pos,
            &self.row_index_pos,
        )?;
        validate_csc(
            "neg",
            self.k,
            self.n,
            &self.col_start_neg,
            &self.row_index_neg,
        )?;
        Ok(())
    }
}

/// Shared CSC-side validation: pointer monotonicity, bounds, per-column
/// sorted and distinct row indices.
pub(crate) fn validate_csc(
    label: &str,
    k: usize,
    n: usize,
    col_start: &[u32],
    row_index: &[u32],
) -> crate::Result<()> {
    if col_start.len() != n + 1 {
        return Err(crate::Error::Format(format!(
            "{label}: col_start length {} != N+1",
            col_start.len()
        )));
    }
    if col_start[0] != 0 {
        return Err(crate::Error::Format(format!("{label}: col_start[0] != 0")));
    }
    if *col_start.last().unwrap() as usize != row_index.len() {
        return Err(crate::Error::Format(format!("{label}: col_start end != index count")));
    }
    for j in 0..n {
        if col_start[j] > col_start[j + 1] {
            return Err(crate::Error::Format(format!(
                "{label}: col_start not monotone at column {j}"
            )));
        }
        let seg = &row_index[col_start[j] as usize..col_start[j + 1] as usize];
        for w in seg.windows(2) {
            if w[0] >= w[1] {
                return Err(crate::Error::Format(format!(
                    "{label}: column {j} indices not strictly ascending"
                )));
            }
        }
        if let Some(&last) = seg.last() {
            if last as usize >= k {
                return Err(crate::Error::Format(format!(
                    "{label}: column {j} index {last} out of range"
                )));
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The worked example from the paper's Fig 1: a 4×4 ternary matrix.
    fn paper_fig1_matrix() -> TernaryMatrix {
        // Reconstructed from the Fig 1 arrays:
        //   pos ptrs [0,0,1,2,4], pos rows [1,0,1,3]
        //   neg ptrs [0,1,3,4,4], neg rows [3,0,3,2]
        // → col0: -1@3; col1: +1@1, -1@0, -1@3; col2: +1@0, -1@2; col3: +1@1, +1@3
        let mut w = TernaryMatrix::zeros(4, 4);
        w.set(3, 0, -1);
        w.set(1, 1, 1);
        w.set(0, 1, -1);
        w.set(3, 1, -1);
        w.set(0, 2, 1);
        w.set(2, 2, -1);
        w.set(1, 3, 1);
        w.set(3, 3, 1);
        w
    }

    #[test]
    fn matches_paper_fig1() {
        let f = Tcsc::from_ternary(&paper_fig1_matrix());
        assert_eq!(f.col_start_pos, vec![0, 0, 1, 2, 4]);
        assert_eq!(f.row_index_pos, vec![1, 0, 1, 3]);
        assert_eq!(f.col_start_neg, vec![0, 1, 3, 4, 4]);
        assert_eq!(f.row_index_neg, vec![3, 0, 3, 2]);
    }

    #[test]
    fn roundtrip_random() {
        for &s in &crate::PAPER_SPARSITIES {
            let w = TernaryMatrix::random(64, 48, s, 21);
            let f = Tcsc::from_ternary(&w);
            assert_eq!(f.to_dense(), w, "sparsity {s}");
            assert_eq!(f.nnz(), w.nnz());
            f.validate().unwrap();
        }
    }

    #[test]
    fn bytes_counts_all_arrays() {
        let w = TernaryMatrix::random(16, 8, 0.5, 1);
        let f = Tcsc::from_ternary(&w);
        let expect = 4 * (2 * 9 + f.nnz());
        assert_eq!(f.bytes(), expect);
    }

    #[test]
    fn empty_matrix_roundtrip() {
        let w = TernaryMatrix::zeros(8, 8);
        let f = Tcsc::from_ternary(&w);
        assert_eq!(f.nnz(), 0);
        assert_eq!(f.to_dense(), w);
    }

    #[test]
    fn validate_catches_corruption() {
        let w = TernaryMatrix::random(16, 8, 0.5, 2);
        let mut f = Tcsc::from_ternary(&w);
        f.row_index_pos[0] = 99; // out of range
        assert!(f.validate().is_err());
    }
}
