//! The library-wide typed error: every fallible `stgemm` API returns
//! [`enum@Error`] (via the [`Result`] alias) instead of a bare `String`.
//!
//! Variants classify *what kind* of failure occurred so callers can react
//! programmatically — the CLI boundary maps usage-class errors to exit
//! code 2 and runtime-class errors to exit code 1 ([`Error::exit_code`]),
//! the serving path distinguishes client mistakes ([`Error::Shape`],
//! [`Error::Serve`]) from backend faults ([`Error::Runtime`]), and tests
//! can assert on the variant rather than substring-matching a message.
//!
//! Every variant carries a human-readable description; [`Error`]
//! implements [`std::fmt::Display`] and [`std::error::Error`], so it
//! interoperates with `?`-based code and `Box<dyn std::error::Error>`
//! consumers. All payloads are `String`s, keeping the type `Clone` (the
//! engine fans one batch error out to every request in the batch).

/// Library-wide result alias: `stgemm::Result<T>` = `Result<T, stgemm::Error>`.
pub type Result<T> = std::result::Result<T, Error>;

/// Unified error type for the whole library.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Error {
    /// A kernel name that does not resolve to a registry
    /// [`crate::kernels::KernelId`] (config `kernel` key, `PlanHints`
    /// override, bench `--kernel` flags).
    UnknownKernel(String),
    /// Kernel build parameters rejected by
    /// [`crate::kernels::KernelParams::validate`] (e.g. interleave group 0).
    BadKernelParams(String),
    /// A kernel whose descriptor `requires` a CPU capability the planner's
    /// [`crate::perf::CpuCaps`] does not satisfy (explicit plan hints or
    /// plan-cache registrations naming a gated kernel on the wrong host).
    UnsupportedKernel(String),
    /// Operand shape mismatch: bias length vs N, layer dim chaining,
    /// request input width vs `d_in`.
    Shape(String),
    /// Malformed or invalid configuration (model config JSON, CLI values,
    /// request traces).
    Config(String),
    /// Tuning-table problems: unparseable keys or undecodable JSON.
    Tuning(String),
    /// Serialized-data problems: corrupt `.stw` weights, invalid sparse
    /// format invariants, artifact manifest decoding.
    Format(String),
    /// XLA/PJRT runtime failures (artifact compilation, execution,
    /// service-thread death).
    Runtime(String),
    /// Serving-path failures: unknown model, shut-down batcher, response
    /// timeout.
    Serve(String),
    /// Underlying I/O failure, with the path/context baked into the
    /// message.
    Io(String),
}

impl Error {
    /// I/O error with context (`Error::io("read table.json", e)`).
    pub fn io(context: impl std::fmt::Display, err: std::io::Error) -> Error {
        Error::Io(format!("{context}: {err}"))
    }

    /// Process exit code for the CLI boundary: 2 for usage/configuration
    /// mistakes the caller can fix by re-invoking (bad kernel name, bad
    /// params, bad config, malformed tuning table), 1 for runtime
    /// failures.
    pub fn exit_code(&self) -> i32 {
        match self {
            Error::UnknownKernel(_)
            | Error::BadKernelParams(_)
            | Error::UnsupportedKernel(_)
            | Error::Config(_)
            | Error::Tuning(_) => 2,
            Error::Shape(_)
            | Error::Format(_)
            | Error::Runtime(_)
            | Error::Serve(_)
            | Error::Io(_) => 1,
        }
    }
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::UnknownKernel(name) => write!(f, "unknown kernel '{name}'"),
            Error::BadKernelParams(msg) => write!(f, "bad kernel params: {msg}"),
            Error::UnsupportedKernel(msg) => write!(f, "unsupported kernel: {msg}"),
            Error::Shape(msg) => write!(f, "shape mismatch: {msg}"),
            Error::Config(msg) => write!(f, "config: {msg}"),
            Error::Tuning(msg) => write!(f, "tuning table: {msg}"),
            Error::Format(msg) => write!(f, "format: {msg}"),
            Error::Runtime(msg) => write!(f, "runtime: {msg}"),
            Error::Serve(msg) => write!(f, "serve: {msg}"),
            Error::Io(msg) => write!(f, "io: {msg}"),
        }
    }
}

impl std::error::Error for Error {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_prefixes_classify() {
        assert_eq!(
            Error::UnknownKernel("nope".into()).to_string(),
            "unknown kernel 'nope'"
        );
        assert!(Error::Shape("bias 3 != N 4".into())
            .to_string()
            .starts_with("shape mismatch"));
        assert!(Error::UnsupportedKernel("needs neon".into())
            .to_string()
            .starts_with("unsupported kernel"));
        assert!(Error::Io("read x: gone".into()).to_string().starts_with("io:"));
    }

    #[test]
    fn exit_codes_split_usage_from_runtime() {
        assert_eq!(Error::UnknownKernel("x".into()).exit_code(), 2);
        assert_eq!(Error::Config("bad".into()).exit_code(), 2);
        assert_eq!(Error::BadKernelParams("g=0".into()).exit_code(), 2);
        assert_eq!(
            Error::UnsupportedKernel("needs neon".into()).exit_code(),
            2
        );
        assert_eq!(Error::Tuning("bad key".into()).exit_code(), 2);
        assert_eq!(Error::Runtime("pjrt".into()).exit_code(), 1);
        assert_eq!(Error::Io("read".into()).exit_code(), 1);
        assert_eq!(Error::Serve("closed".into()).exit_code(), 1);
    }

    #[test]
    fn error_is_std_error_and_clone() {
        let e: Box<dyn std::error::Error> = Box::new(Error::Tuning("bad key".into()));
        assert!(e.to_string().contains("bad key"));
        let a = Error::Format("corrupt".into());
        assert_eq!(a.clone(), a);
    }
}
