//! Artifact manifest parsing and raw weight loading.
//!
//! `python/compile/aot.py` emits `manifest.json` describing every lowered
//! model variant: its HLO file, per-layer raw weight/bias dumps, and a
//! probe input/output pair for smoke checks. The weight dumps let the Rust
//! side construct the *identical* model for its native kernels, enabling
//! cross-backend equivalence tests.

use crate::ternary::TernaryMatrix;
use crate::util::json::Json;
use crate::{Error, Result};
use std::path::{Path, PathBuf};

/// One layer of an artifact model.
#[derive(Debug, Clone)]
pub struct ArtifactLayer {
    pub k: usize,
    pub n: usize,
    pub sparsity: f32,
    pub prelu_alpha: Option<f32>,
    pub weights_file: String,
    pub bias_file: String,
    pub nnz: usize,
}

/// One lowered model variant.
#[derive(Debug, Clone)]
pub struct ArtifactModel {
    pub name: String,
    pub batch: usize,
    pub d_in: usize,
    pub d_out: usize,
    pub hlo_file: String,
    pub layers: Vec<ArtifactLayer>,
    pub probe_x_file: String,
    pub probe_y_file: String,
}

/// The parsed artifact manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: Vec<ArtifactModel>,
}

fn req_usize(v: &Json, key: &str) -> Result<usize> {
    v.get(key)
        .and_then(|x| x.as_usize())
        .ok_or_else(|| Error::Format(format!("manifest: missing/invalid '{key}'")))
}

fn req_str(v: &Json, key: &str) -> Result<String> {
    v.get(key)
        .and_then(|x| x.as_str())
        .map(|s| s.to_string())
        .ok_or_else(|| Error::Format(format!("manifest: missing/invalid '{key}'")))
}

impl Manifest {
    /// Load `manifest.json` from an artifacts directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| Error::io(format!("read {}", path.display()), e))?;
        let v = Json::parse(&text).map_err(|e| Error::Format(e.to_string()))?;
        let models_json = v
            .get("models")
            .and_then(|m| m.as_arr())
            .ok_or_else(|| Error::Format("manifest: missing 'models' array".into()))?;
        let mut models = Vec::new();
        for mj in models_json {
            let layers_json = mj
                .get("layers")
                .and_then(|l| l.as_arr())
                .ok_or_else(|| Error::Format("manifest: model missing 'layers'".into()))?;
            let mut layers = Vec::new();
            for lj in layers_json {
                layers.push(ArtifactLayer {
                    k: req_usize(lj, "k")?,
                    n: req_usize(lj, "n")?,
                    sparsity: lj
                        .get("sparsity")
                        .and_then(|s| s.as_f64())
                        .unwrap_or(0.0) as f32,
                    prelu_alpha: lj.get("prelu_alpha").and_then(|a| a.as_f64()).map(|a| a as f32),
                    weights_file: req_str(lj, "weights_file")?,
                    bias_file: req_str(lj, "bias_file")?,
                    nnz: req_usize(lj, "nnz")?,
                });
            }
            models.push(ArtifactModel {
                name: req_str(mj, "name")?,
                batch: req_usize(mj, "batch")?,
                d_in: req_usize(mj, "d_in")?,
                d_out: req_usize(mj, "d_out")?,
                hlo_file: req_str(mj, "hlo_file")?,
                layers,
                probe_x_file: req_str(mj, "probe_x_file")?,
                probe_y_file: req_str(mj, "probe_y_file")?,
            });
        }
        Ok(Manifest { dir, models })
    }

    /// Find a model by name.
    pub fn model(&self, name: &str) -> Option<&ArtifactModel> {
        self.models.iter().find(|m| m.name == name)
    }

    /// Model variants grouped by base name (stripping the `_b<batch>`
    /// suffix), e.g. `ffn_e2e` → [batch 1, batch 8].
    pub fn variants_of(&self, base: &str) -> Vec<&ArtifactModel> {
        let prefix = format!("{base}_b");
        let mut v: Vec<&ArtifactModel> = self
            .models
            .iter()
            .filter(|m| m.name.starts_with(&prefix))
            .collect();
        v.sort_by_key(|m| m.batch);
        v
    }

    /// Absolute path of an artifact file.
    pub fn path(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }
}

impl ArtifactModel {
    /// Load a layer's ternary weights from its raw i8 dump.
    pub fn load_weights(&self, dir: &Path, layer: usize) -> Result<TernaryMatrix> {
        let l = &self.layers[layer];
        let path = dir.join(&l.weights_file);
        let bytes = std::fs::read(&path)
            .map_err(|e| Error::io(format!("read {}", path.display()), e))?;
        if bytes.len() != l.k * l.n {
            return Err(Error::Format(format!(
                "{}: expected {} bytes, got {}",
                l.weights_file,
                l.k * l.n,
                bytes.len()
            )));
        }
        let entries: Vec<i8> = bytes.iter().map(|&b| b as i8).collect();
        if entries.iter().any(|&v| !(-1..=1).contains(&v)) {
            return Err(Error::Format(format!("{}: non-ternary entry", l.weights_file)));
        }
        Ok(TernaryMatrix::from_entries(l.k, l.n, &entries))
    }

    /// Load a layer's bias from its raw little-endian f32 dump.
    pub fn load_bias(&self, dir: &Path, layer: usize) -> Result<Vec<f32>> {
        let l = &self.layers[layer];
        read_f32_file(&dir.join(&l.bias_file), l.n)
    }

    /// Load the probe input (batch × d_in).
    pub fn load_probe_x(&self, dir: &Path) -> Result<Vec<f32>> {
        read_f32_file(&dir.join(&self.probe_x_file), self.batch * self.d_in)
    }

    /// Load the probe output (batch × d_out).
    pub fn load_probe_y(&self, dir: &Path) -> Result<Vec<f32>> {
        read_f32_file(&dir.join(&self.probe_y_file), self.batch * self.d_out)
    }
}

/// Read a raw little-endian f32 file with an expected element count.
pub fn read_f32_file(path: &Path, expect: usize) -> Result<Vec<f32>> {
    let bytes =
        std::fs::read(path).map_err(|e| Error::io(format!("read {}", path.display()), e))?;
    if bytes.len() != expect * 4 {
        return Err(Error::Format(format!(
            "{}: expected {} f32s, got {} bytes",
            path.display(),
            expect,
            bytes.len()
        )));
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

/// Locate the artifacts directory: `$STGEMM_ARTIFACTS` or `./artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var("STGEMM_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build a synthetic manifest on disk for parser tests (real-artifact
    /// integration lives in rust/tests/runtime_hlo.rs).
    fn synth_manifest(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        let w: Vec<u8> = vec![1, 0, 255, 0, 1, 255]; // 3×2 ternary (255 = -1)
        std::fs::write(dir.join("m.w0.i8"), &w).unwrap();
        let bias: Vec<u8> = [0.5f32, -0.5]
            .iter()
            .flat_map(|f| f.to_le_bytes())
            .collect();
        std::fs::write(dir.join("m.b0.f32"), &bias).unwrap();
        let probe: Vec<u8> = [1.0f32, 2.0, 3.0].iter().flat_map(|f| f.to_le_bytes()).collect();
        std::fs::write(dir.join("m.px.f32"), &probe).unwrap();
        let py: Vec<u8> = [0.0f32, 0.0].iter().flat_map(|f| f.to_le_bytes()).collect();
        std::fs::write(dir.join("m.py.f32"), &py).unwrap();
        std::fs::write(dir.join("m.hlo.txt"), "HloModule fake").unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"version":1,"models":[{"name":"m_b1","batch":1,"d_in":3,"d_out":2,
                "hlo_file":"m.hlo.txt",
                "layers":[{"k":3,"n":2,"sparsity":0.5,"seed":1,"prelu_alpha":null,
                           "weights_file":"m.w0.i8","bias_file":"m.b0.f32","nnz":4}],
                "probe_x_file":"m.px.f32","probe_y_file":"m.py.f32"}]}"#,
        )
        .unwrap();
    }

    #[test]
    fn parse_and_load() {
        let dir = std::env::temp_dir().join("stgemm_manifest_test");
        synth_manifest(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.models.len(), 1);
        let model = m.model("m_b1").unwrap();
        assert_eq!(model.d_in, 3);
        assert_eq!(model.layers[0].nnz, 4);
        assert_eq!(model.layers[0].prelu_alpha, None);
        let w = model.load_weights(&m.dir, 0).unwrap();
        assert_eq!(w.k(), 3);
        assert_eq!(w.get(0, 0), 1);
        assert_eq!(w.get(0, 1), 0);
        assert_eq!(w.get(1, 0), -1);
        let b = model.load_bias(&m.dir, 0).unwrap();
        assert_eq!(b, vec![0.5, -0.5]);
        assert_eq!(model.load_probe_x(&m.dir).unwrap(), vec![1.0, 2.0, 3.0]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn variants_sorted_by_batch() {
        let dir = std::env::temp_dir().join("stgemm_manifest_test2");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"version":1,"models":[
              {"name":"x_b8","batch":8,"d_in":1,"d_out":1,"hlo_file":"h","layers":[],
               "probe_x_file":"p","probe_y_file":"q"},
              {"name":"x_b1","batch":1,"d_in":1,"d_out":1,"hlo_file":"h","layers":[],
               "probe_x_file":"p","probe_y_file":"q"}]}"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        let v = m.variants_of("x");
        assert_eq!(v.iter().map(|m| m.batch).collect::<Vec<_>>(), vec![1, 8]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_manifest_is_error() {
        assert!(Manifest::load("/nonexistent/dir").is_err());
    }
}
