//! Thread-safe XLA execution service.
//!
//! The `xla` crate's PJRT handles are `!Send` (they hold `Rc`s over the C
//! API), so they cannot be shared across the coordinator's threads
//! directly. The production pattern: one dedicated **service thread** owns
//! the PJRT client and every compiled executable; the rest of the system
//! talks to it through a channel. [`XlaExecutor`] is that channel handle —
//! `Send + Sync`, cheap to share, and it serializes executions (PJRT CPU
//! executions are single-stream anyway; the dynamic batcher provides the
//! parallelism that matters by growing M).

use crate::runtime::artifacts::Manifest;
use crate::runtime::pjrt::{CompiledModel, PjrtRuntime};
use crate::tensor::Matrix;
use crate::Error;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::sync::mpsc;
use std::sync::Mutex;

enum Request {
    Run {
        x: Matrix,
        reply: mpsc::Sender<std::result::Result<Matrix, Error>>,
    },
    Shutdown,
}

/// Channel handle to the XLA service thread (one model family,
/// batch-bucketed executables).
pub struct XlaExecutor {
    pub base_name: String,
    pub d_in: usize,
    pub d_out: usize,
    buckets: Vec<usize>,
    tx: Mutex<mpsc::Sender<Request>>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl XlaExecutor {
    /// Spawn the service thread: it creates the PJRT CPU client, compiles
    /// every `<base>_b<batch>` variant in the manifest, then serves run
    /// requests until dropped.
    pub fn spawn(manifest: &Manifest, base: &str) -> Result<XlaExecutor> {
        let variants = manifest.variants_of(base);
        anyhow::ensure!(!variants.is_empty(), "no artifact variants named {base}_b*");
        let (d_in, d_out) = (variants[0].d_in, variants[0].d_out);
        for v in &variants {
            anyhow::ensure!(
                v.d_in == d_in && v.d_out == d_out,
                "variant {} shape mismatch",
                v.name
            );
        }
        let plan: Vec<(usize, std::path::PathBuf)> = variants
            .iter()
            .map(|v| (v.batch, manifest.path(&v.hlo_file)))
            .collect();
        let buckets: Vec<usize> = plan.iter().map(|(b, _)| *b).collect();
        let (tx, rx) = mpsc::channel::<Request>();
        let (init_tx, init_rx) = mpsc::channel::<std::result::Result<(), Error>>();
        let base_name = base.to_string();
        let thread_base = base_name.clone();
        let thread = std::thread::Builder::new()
            .name(format!("stgemm-xla-{base}"))
            .spawn(move || {
                // Everything !Send lives only on this thread.
                let setup = || -> Result<BTreeMap<usize, CompiledModel>> {
                    let rt = PjrtRuntime::cpu()?;
                    let mut models = BTreeMap::new();
                    for (batch, path) in &plan {
                        let compiled = rt
                            .compile_hlo_file(path, *batch, d_in, d_out)
                            .with_context(|| format!("compile bucket b{batch}"))?;
                        models.insert(*batch, compiled);
                    }
                    Ok(models)
                };
                let models = match setup() {
                    Ok(m) => {
                        let _ = init_tx.send(Ok(()));
                        m
                    }
                    Err(e) => {
                        let _ = init_tx.send(Err(Error::Runtime(format!("{e:#}"))));
                        return;
                    }
                };
                while let Ok(req) = rx.recv() {
                    match req {
                        Request::Run { x, reply } => {
                            let result = run_bucketed(&models, &x, d_in, d_out);
                            let _ =
                                reply.send(result.map_err(|e| Error::Runtime(format!("{e:#}"))));
                        }
                        Request::Shutdown => break,
                    }
                }
                drop(thread_base);
            })
            .context("spawn xla service thread")?;
        init_rx
            .recv()
            .context("xla service thread died during init")?
            .map_err(|e| anyhow::anyhow!(e))?;
        Ok(XlaExecutor {
            base_name,
            d_in,
            d_out,
            buckets,
            tx: Mutex::new(tx),
            thread: Some(thread),
        })
    }

    /// Available batch buckets, ascending.
    pub fn buckets(&self) -> &[usize] {
        &self.buckets
    }

    /// Smallest bucket ≥ `m` (or the largest available).
    pub fn bucket_for(&self, m: usize) -> usize {
        self.buckets
            .iter()
            .copied()
            .find(|&b| b >= m)
            .unwrap_or_else(|| *self.buckets.last().unwrap())
    }

    /// Run a batch: pads to the chosen bucket on the service thread's
    /// input, slices real rows back out.
    pub fn run(&self, x: &Matrix) -> Result<Matrix> {
        anyhow::ensure!(x.rows() > 0, "empty batch");
        anyhow::ensure!(x.cols() == self.d_in, "input width mismatch");
        anyhow::ensure!(
            x.rows() <= *self.buckets.last().unwrap(),
            "batch {} exceeds largest compiled bucket {}",
            x.rows(),
            self.buckets.last().unwrap()
        );
        let (reply_tx, reply_rx) = mpsc::channel();
        {
            let tx = self.tx.lock().expect("xla sender mutex");
            tx.send(Request::Run {
                x: x.clone(),
                reply: reply_tx,
            })
            .map_err(|_| anyhow::anyhow!("xla service thread has exited"))?;
        }
        reply_rx
            .recv()
            .context("xla service reply channel closed")?
            .map_err(|e| anyhow::anyhow!(e))
    }
}

impl Drop for XlaExecutor {
    fn drop(&mut self) {
        if let Ok(tx) = self.tx.lock() {
            let _ = tx.send(Request::Shutdown);
        }
        if let Some(h) = self.thread.take() {
            let _ = h.join();
        }
    }
}

/// Pad → execute → slice on the service thread.
fn run_bucketed(
    models: &BTreeMap<usize, CompiledModel>,
    x: &Matrix,
    d_in: usize,
    d_out: usize,
) -> Result<Matrix> {
    let m = x.rows();
    let bucket = models
        .keys()
        .copied()
        .find(|&b| b >= m)
        .unwrap_or_else(|| *models.keys().last().unwrap());
    anyhow::ensure!(m <= bucket, "batch {m} exceeds bucket {bucket}");
    let padded = if m == bucket {
        x.clone()
    } else {
        let mut p = Matrix::zeros(bucket, d_in);
        for r in 0..m {
            p.row_mut(r).copy_from_slice(x.row(r));
        }
        p
    };
    let y_full = models.get(&bucket).unwrap().run(&padded)?;
    if m == bucket {
        return Ok(y_full);
    }
    let mut y = Matrix::zeros(m, d_out);
    for r in 0..m {
        y.row_mut(r).copy_from_slice(y_full.row(r));
    }
    Ok(y)
}

// Integration tests with real artifacts: rust/tests/runtime_hlo.rs.
#[cfg(test)]
mod tests {
    #[test]
    fn bucket_selection_logic() {
        let buckets = [1usize, 8];
        let pick = |m: usize| {
            buckets
                .iter()
                .copied()
                .find(|&b| b >= m)
                .unwrap_or(*buckets.last().unwrap())
        };
        assert_eq!(pick(1), 1);
        assert_eq!(pick(2), 8);
        assert_eq!(pick(8), 8);
        assert_eq!(pick(9), 8); // clamped; run() rejects with an error
    }
}
