//! Runtime: loading and executing the JAX/Pallas AOT artifacts through the
//! PJRT C API (`xla` crate). Build-time Python produced `artifacts/*.hlo.txt`
//! plus raw weight dumps and a manifest; this module turns them into
//! executables and native models the coordinator can serve.

pub mod artifacts;
pub mod pjrt;
pub mod executor;

pub use artifacts::{ArtifactLayer, ArtifactModel, Manifest};
pub use executor::XlaExecutor;
pub use pjrt::PjrtRuntime;
