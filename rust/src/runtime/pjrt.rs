//! PJRT client wrapper: HLO text → compiled executable → execution with
//! `Matrix` inputs/outputs. Adapted from the /opt/xla-example/load_hlo
//! reference; HLO *text* is the interchange format (jax ≥ 0.5 emits 64-bit
//! instruction ids that xla_extension 0.5.1's proto path rejects).

use crate::tensor::Matrix;
use anyhow::{Context, Result};
use std::path::Path;

/// A PJRT CPU client plus compiled executables.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
}

/// One compiled model executable with fixed input shape.
pub struct CompiledModel {
    exe: xla::PjRtLoadedExecutable,
    /// Expected input shape (batch, d_in).
    pub batch: usize,
    pub d_in: usize,
    /// Output shape (batch, d_out).
    pub d_out: usize,
}

impl PjrtRuntime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<PjrtRuntime> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(PjrtRuntime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO text file and compile it for the given logical shapes.
    pub fn compile_hlo_file(
        &self,
        path: impl AsRef<Path>,
        batch: usize,
        d_in: usize,
        d_out: usize,
    ) -> Result<CompiledModel> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile {}", path.display()))?;
        Ok(CompiledModel {
            exe,
            batch,
            d_in,
            d_out,
        })
    }
}

impl CompiledModel {
    /// Execute on a (batch × d_in) input matrix; returns (batch × d_out).
    ///
    /// The AOT driver lowers with `return_tuple=True`, so the result is a
    /// one-element tuple we unwrap.
    pub fn run(&self, x: &Matrix) -> Result<Matrix> {
        anyhow::ensure!(
            x.rows() == self.batch && x.cols() == self.d_in,
            "input shape ({}, {}) != compiled shape ({}, {})",
            x.rows(),
            x.cols(),
            self.batch,
            self.d_in
        );
        let lit = xla::Literal::vec1(x.as_slice())
            .reshape(&[self.batch as i64, self.d_in as i64])
            .context("reshape input literal")?;
        let result = self.exe.execute::<xla::Literal>(&[lit])?[0][0]
            .to_literal_sync()
            .context("fetch result literal")?;
        let out = result.to_tuple1().context("unwrap 1-tuple result")?;
        let values = out.to_vec::<f32>().context("read f32 output")?;
        anyhow::ensure!(
            values.len() == self.batch * self.d_out,
            "output length {} != {}·{}",
            values.len(),
            self.batch,
            self.d_out
        );
        Ok(Matrix::from_slice(self.batch, self.d_out, &values))
    }
}

// NOTE: correctness tests for this module live in rust/tests/runtime_hlo.rs
// because they need real artifacts (built by `make artifacts`). Unit tests
// here only cover shape guards with an intentionally bad call.
#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_creation_smoke() {
        // PJRT CPU client must always be constructible.
        let rt = PjrtRuntime::cpu().expect("cpu client");
        assert!(!rt.platform().is_empty());
    }

    #[test]
    fn missing_hlo_file_is_error() {
        let rt = PjrtRuntime::cpu().unwrap();
        assert!(rt.compile_hlo_file("/nonexistent.hlo.txt", 1, 4, 4).is_err());
    }
}
