//! Multi-core execution of any prepared kernel by row partitioning.
//!
//! The paper evaluates single-core performance (its contribution is the
//! per-core kernel); a serving system also needs to scale across cores.
//! Because `Y = X·W + b` is embarrassingly parallel over rows of X, we
//! split the batch into contiguous row chunks and run the *same* prepared
//! kernel on each chunk in parallel — no synchronization inside the GEMM,
//! and per-chunk results are written into disjoint slices of Y.

use crate::kernels::PreparedGemm;
use crate::tensor::Matrix;
use std::sync::Arc;

/// A prepared kernel wrapped for multi-core row-partitioned execution.
pub struct ParallelGemm {
    inner: Arc<dyn PreparedGemm>,
    /// Worker threads used per run (1 = sequential passthrough).
    pub threads: usize,
    /// Minimum rows per chunk; batches smaller than `2·min_rows` run
    /// sequentially (thread spawn isn't worth it).
    pub min_rows: usize,
}

impl ParallelGemm {
    pub fn new(inner: Arc<dyn PreparedGemm>, threads: usize) -> ParallelGemm {
        ParallelGemm {
            inner,
            threads: threads.max(1),
            min_rows: 2,
        }
    }

    /// Compute `Y = X·W + b` using up to `self.threads` cores.
    pub fn run(&self, x: &Matrix, bias: &[f32], y: &mut Matrix) {
        let m = x.rows();
        assert_eq!(y.rows(), m);
        assert_eq!(x.cols(), self.inner.k());
        assert_eq!(y.cols(), self.inner.n());
        let chunks = self
            .threads
            .min(m / self.min_rows.max(1))
            .max(1);
        if chunks <= 1 {
            self.inner.run(x, bias, y);
            return;
        }
        let n = self.inner.n();
        let rows_per = m.div_ceil(chunks);
        // Split X rows and collect per-chunk outputs, then stitch. The
        // copy is one sequential pass over Y — negligible next to the GEMM.
        let chunk_inputs: Vec<Matrix> = (0..chunks)
            .filter_map(|c| {
                let lo = c * rows_per;
                if lo >= m {
                    return None; // ceil-division can over-provision chunks
                }
                let hi = ((c + 1) * rows_per).min(m);
                let mut xc = Matrix::zeros(hi - lo, x.cols());
                for (i, r) in (lo..hi).enumerate() {
                    xc.row_mut(i).copy_from_slice(x.row(r));
                }
                Some(xc)
            })
            .collect();
        let results: Vec<Matrix> = std::thread::scope(|scope| {
            let handles: Vec<_> = chunk_inputs
                .iter()
                .map(|xc| {
                    let inner = Arc::clone(&self.inner);
                    scope.spawn(move || {
                        let mut yc = Matrix::zeros(xc.rows(), n);
                        inner.run(xc, bias, &mut yc);
                        yc
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("chunk")).collect()
        });
        let mut r = 0;
        for yc in results {
            for i in 0..yc.rows() {
                y.row_mut(r).copy_from_slice(yc.row(i));
                r += 1;
            }
        }
        debug_assert_eq!(r, m);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{dense_oracle, prepare_kernel, KernelParams};
    use crate::ternary::TernaryMatrix;

    fn setup(m: usize) -> (TernaryMatrix, Matrix, Vec<f32>) {
        let w = TernaryMatrix::random(96, 32, 0.25, 3);
        let x = Matrix::random(m, 96, 4);
        let bias: Vec<f32> = (0..32).map(|i| 0.1 * i as f32).collect();
        (w, x, bias)
    }

    #[test]
    fn matches_sequential_for_all_thread_counts() {
        let (w, x, bias) = setup(13);
        let oracle = dense_oracle(&x, &w, &bias);
        let inner: Arc<dyn crate::kernels::PreparedGemm> =
            prepare_kernel("interleaved_blocked_tcsc", &w, KernelParams::default())
                .unwrap()
                .into();
        for threads in [1, 2, 4, 8] {
            let par = ParallelGemm::new(Arc::clone(&inner), threads);
            let mut y = Matrix::zeros(13, 32);
            par.run(&x, &bias, &mut y);
            assert!(y.allclose(&oracle, 1e-3), "threads={threads}");
        }
    }

    #[test]
    fn tiny_batches_run_sequentially() {
        let (w, x, bias) = setup(1);
        let oracle = dense_oracle(&x, &w, &bias);
        let inner: Arc<dyn crate::kernels::PreparedGemm> =
            prepare_kernel("base_tcsc", &w, KernelParams::default())
                .unwrap()
                .into();
        let par = ParallelGemm::new(inner, 8);
        let mut y = Matrix::zeros(1, 32);
        par.run(&x, &bias, &mut y);
        assert!(y.allclose(&oracle, 1e-3));
    }

    #[test]
    fn uneven_row_split() {
        let (w, x, bias) = setup(7); // 7 rows over 3 threads → 3+3+1
        let oracle = dense_oracle(&x, &w, &bias);
        let inner: Arc<dyn crate::kernels::PreparedGemm> =
            prepare_kernel("unrolled_tcsc_12", &w, KernelParams::default())
                .unwrap()
                .into();
        let par = ParallelGemm::new(inner, 3);
        let mut y = Matrix::zeros(7, 32);
        par.run(&x, &bias, &mut y);
        assert!(y.allclose(&oracle, 1e-3));
    }
}
