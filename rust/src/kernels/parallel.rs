//! Multi-core execution of any prepared kernel by row partitioning.
//!
//! This is now a thin veneer over the planning layer's partitioner
//! ([`crate::plan::execute_partitioned`]) kept for API compatibility and
//! as a regression surface: the old implementation copied every X chunk
//! into a fresh matrix, ran into per-chunk Y matrices, and stitched the
//! results back with one more pass over Y. The partitioner instead reads X
//! through zero-copy row views, writes each worker's output directly into
//! its disjoint `&mut Y` row block, reuses per-worker scratch across runs,
//! and executes on a pooled fork-join — with chunk boundaries aligned so
//! results are **bitwise identical** to the sequential path.
//!
//! New code should plan with [`crate::plan::Planner`] instead, which
//! bundles the same partitioner with kernel selection and the epilogue.

use crate::kernels::{GemmScratch, PreparedGemm};
use crate::plan::partition::{execute_partitioned, RowPartition};
use crate::tensor::Matrix;
use crate::util::threadpool::ThreadPool;
use crate::Result;
use std::sync::{Arc, Mutex};

/// A prepared kernel wrapped for multi-core row-partitioned execution.
pub struct ParallelGemm {
    inner: Arc<dyn PreparedGemm>,
    /// Worker threads used per run (1 = sequential passthrough). May be
    /// changed between runs; the pool and scratch adapt on the next call.
    pub threads: usize,
    /// Minimum rows per chunk; batches smaller than `2·min_rows` run
    /// sequentially (fan-out isn't worth it).
    pub min_rows: usize,
    /// Created lazily on the first parallel run (a `threads == 1` wrapper
    /// never spawns workers).
    pool: Mutex<Option<ThreadPool>>,
    scratch: Mutex<Vec<GemmScratch>>,
}

impl ParallelGemm {
    pub fn new(inner: Arc<dyn PreparedGemm>, threads: usize) -> ParallelGemm {
        ParallelGemm {
            inner,
            threads: threads.max(1),
            min_rows: 2,
            pool: Mutex::new(None),
            scratch: Mutex::new(Vec::new()),
        }
    }

    /// Compute `Y = X·W + b` using up to `self.threads` cores.
    ///
    /// # Errors
    /// [`crate::Error::Runtime`] when a worker job panicked (`y` is then
    /// incomplete and must be discarded).
    pub fn run(&self, x: &Matrix, bias: &[f32], y: &mut Matrix) -> Result<()> {
        let threads = self.threads.max(1);
        let part = RowPartition::new(threads, self.min_rows);
        let mut scratches = self.scratch.lock().unwrap_or_else(|e| e.into_inner());
        if scratches.len() < threads {
            scratches.resize_with(threads, GemmScratch::new);
        }
        let mut pool_slot = self.pool.lock().unwrap_or_else(|e| e.into_inner());
        if threads > 1 && pool_slot.is_none() {
            // Sized once to the first parallel request; a later larger
            // `threads` still works (extra chunks queue on the workers).
            *pool_slot = Some(ThreadPool::new(threads));
        }
        let pool = if threads > 1 { pool_slot.as_ref() } else { None };
        execute_partitioned(
            self.inner.as_ref(),
            part,
            pool,
            x,
            bias,
            y,
            &mut scratches,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{dense_oracle, prepare_kernel, KernelParams};
    use crate::ternary::TernaryMatrix;

    fn setup(m: usize) -> (TernaryMatrix, Matrix, Vec<f32>) {
        let w = TernaryMatrix::random(96, 32, 0.25, 3);
        let x = Matrix::random(m, 96, 4);
        let bias: Vec<f32> = (0..32).map(|i| 0.1 * i as f32).collect();
        (w, x, bias)
    }

    #[test]
    fn matches_sequential_for_all_thread_counts() {
        let (w, x, bias) = setup(13);
        let oracle = dense_oracle(&x, &w, &bias);
        let inner: Arc<dyn crate::kernels::PreparedGemm> =
            prepare_kernel("interleaved_blocked_tcsc", &w, KernelParams::default())
                .unwrap()
                .into();
        for threads in [1, 2, 4, 8] {
            let par = ParallelGemm::new(Arc::clone(&inner), threads);
            let mut y = Matrix::zeros(13, 32);
            par.run(&x, &bias, &mut y).unwrap();
            assert!(y.allclose(&oracle, 1e-3), "threads={threads}");
        }
    }

    #[test]
    fn parallel_is_bitwise_identical_to_sequential() {
        // Regression for the old copy-and-stitch implementation: the
        // in-place partitioner must produce exactly the sequential bits for
        // every kernel family (scalar, M-tiled, SIMD, dense).
        let (w, x, bias) = setup(13);
        for name in [
            "base_tcsc",
            "unrolled_tcsc_k4_m4",
            "interleaved_blocked_tcsc",
            "simd_vertical",
            "simd_blocked_interleaved",
            "dense_gemm",
        ] {
            let inner: Arc<dyn crate::kernels::PreparedGemm> =
                prepare_kernel(name, &w, KernelParams::default())
                    .unwrap()
                    .into();
            let mut y_seq = Matrix::zeros(13, 32);
            inner.run(&x, &bias, &mut y_seq);
            let par = ParallelGemm::new(Arc::clone(&inner), 4);
            let mut y_par = Matrix::zeros(13, 32);
            par.run(&x, &bias, &mut y_par).unwrap();
            assert_eq!(y_seq, y_par, "kernel {name}");
        }
    }

    #[test]
    fn repeated_runs_do_not_grow_scratch() {
        let (w, x, bias) = setup(12);
        let inner: Arc<dyn crate::kernels::PreparedGemm> =
            prepare_kernel("simd_horizontal", &w, KernelParams::default())
                .unwrap()
                .into();
        let par = ParallelGemm::new(inner, 3);
        let mut y = Matrix::zeros(12, 32);
        par.run(&x, &bias, &mut y).unwrap();
        let caps: Vec<usize> = par
            .scratch
            .lock()
            .unwrap()
            .iter()
            .map(|s| s.padded_capacity())
            .collect();
        for _ in 0..5 {
            par.run(&x, &bias, &mut y).unwrap();
        }
        let caps_after: Vec<usize> = par
            .scratch
            .lock()
            .unwrap()
            .iter()
            .map(|s| s.padded_capacity())
            .collect();
        assert_eq!(caps, caps_after);
    }

    #[test]
    fn threads_can_grow_after_construction() {
        let (w, x, bias) = setup(16);
        let inner: Arc<dyn crate::kernels::PreparedGemm> =
            prepare_kernel("base_tcsc", &w, KernelParams::default())
                .unwrap()
                .into();
        let mut y_seq = Matrix::zeros(16, 32);
        inner.run(&x, &bias, &mut y_seq);
        let mut par = ParallelGemm::new(Arc::clone(&inner), 1);
        let mut y = Matrix::zeros(16, 32);
        par.run(&x, &bias, &mut y).unwrap(); // sequential, spawns no workers
        assert_eq!(y_seq, y);
        par.threads = 8; // grow after construction — pool/scratch adapt
        par.run(&x, &bias, &mut y).unwrap();
        assert_eq!(y_seq, y);
    }

    #[test]
    fn tiny_batches_run_sequentially() {
        let (w, x, bias) = setup(1);
        let oracle = dense_oracle(&x, &w, &bias);
        let inner: Arc<dyn crate::kernels::PreparedGemm> =
            prepare_kernel("base_tcsc", &w, KernelParams::default())
                .unwrap()
                .into();
        let par = ParallelGemm::new(inner, 8);
        let mut y = Matrix::zeros(1, 32);
        par.run(&x, &bias, &mut y).unwrap();
        assert!(y.allclose(&oracle, 1e-3));
    }

    #[test]
    fn uneven_row_split() {
        let (w, x, bias) = setup(7); // 7 rows: tile-aligned split 4+3
        let oracle = dense_oracle(&x, &w, &bias);
        let inner: Arc<dyn crate::kernels::PreparedGemm> =
            prepare_kernel("unrolled_tcsc_12", &w, KernelParams::default())
                .unwrap()
                .into();
        let par = ParallelGemm::new(inner, 3);
        let mut y = Matrix::zeros(7, 32);
        par.run(&x, &bias, &mut y).unwrap();
        assert!(y.allclose(&oracle, 1e-3));
    }
}
