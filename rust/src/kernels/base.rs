//! BaseTCSC — the paper's baseline kernel (§2).
//!
//! For every output element `Y[m][n]`: one pass over the column's positive
//! row indices (adds), one pass over the negatives (subtracts), plus the
//! bias. Two separate inner loops per column is precisely the locality
//! problem the later kernels fix.

use crate::formats::Tcsc;
use crate::kernels::Kernel;
use crate::tensor::Matrix;

/// The unoptimized TCSC baseline.
pub struct BaseTcscKernel;

impl Kernel for BaseTcscKernel {
    type Format = Tcsc;

    fn name(&self) -> &'static str {
        "base_tcsc"
    }

    fn run(&self, x: &Matrix, w: &Tcsc, bias: &[f32], y: &mut Matrix) {
        use crate::formats::SparseFormat;
        crate::kernels::debug_check_shapes(x, w.k(), w.n(), bias, y);
        let m = x.rows();
        let n = w.n();
        for r in 0..m {
            let xr = x.row(r);
            let yr = y.row_mut(r);
            for c in 0..n {
                // NOTE: deliberately checked indexing — this kernel is the
                // paper's unoptimized baseline and stays exactly naive.
                let mut acc = 0.0f32;
                for &i in w.col_pos(c) {
                    acc += xr[i as usize];
                }
                for &i in w.col_neg(c) {
                    acc -= xr[i as usize];
                }
                yr[c] = acc + bias[c];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::dense_oracle;
    use crate::ternary::TernaryMatrix;

    #[test]
    fn matches_oracle_across_sparsities() {
        for &s in &crate::PAPER_SPARSITIES {
            let w = TernaryMatrix::random(96, 40, s, 7);
            let f = Tcsc::from_ternary(&w);
            let x = Matrix::random(6, 96, 8);
            let bias: Vec<f32> = (0..40).map(|i| (i as f32).sin()).collect();
            let oracle = dense_oracle(&x, &w, &bias);
            let mut y = Matrix::zeros(6, 40);
            BaseTcscKernel.run(&x, &f, &bias, &mut y);
            assert!(y.allclose(&oracle, 1e-4), "sparsity {s}");
        }
    }

    #[test]
    fn single_element() {
        let w = TernaryMatrix::from_entries(1, 1, &[-1]);
        let f = Tcsc::from_ternary(&w);
        let x = Matrix::from_slice(1, 1, &[3.0]);
        let mut y = Matrix::zeros(1, 1);
        BaseTcscKernel.run(&x, &f, &[1.0], &mut y);
        assert_eq!(y[(0, 0)], -2.0);
    }

    #[test]
    fn empty_rows_ok() {
        let w = TernaryMatrix::random(16, 8, 0.5, 1);
        let f = Tcsc::from_ternary(&w);
        let x = Matrix::zeros(0, 16);
        let mut y = Matrix::zeros(0, 8);
        BaseTcscKernel.run(&x, &f, &[0.0; 8], &mut y); // must not panic
    }
}
