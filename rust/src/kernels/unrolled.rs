//! UnrolledTCSC (paper §3 "Loop unrolling") — the innermost (nonzero) loop
//! unrolled by a compile-time factor with that many independent
//! accumulators, breaking the write-after-write dependency chain of the
//! baseline's single `y_val`. The paper's grid search found factor 12
//! optimal on M1; the [`crate::autotune`] grid search reproduces that
//! experiment on the host.

use crate::formats::Tcsc;
use crate::kernels::Kernel;
use crate::tensor::Matrix;

/// Inner-loop-unrolled TCSC kernel with `U` accumulators.
pub struct UnrolledTcscKernel<const U: usize>;

/// Unchecked gather: formats validate `idx < xr.len()` at construction
/// (`SparseFormat::validate`, also debug-asserted in every constructor),
/// so the innermost loops skip the bounds check — worth 10–25% on the
/// gather-bound kernels (see EXPERIMENTS.md §Perf).
#[inline(always)]
pub(crate) fn gat(xr: &[f32], i: u32) -> f32 {
    debug_assert!((i as usize) < xr.len(), "gather index out of range");
    // SAFETY: index validated against K at format construction; callers
    // assert `xr.len() == K` on entry.
    unsafe { *xr.get_unchecked(i as usize) }
}

/// Sum `x` gathered at `idx` using `U` parallel accumulator chains.
#[inline(always)]
pub(crate) fn unrolled_gather_sum<const U: usize>(xr: &[f32], idx: &[u32]) -> f32 {
    let mut acc = [0.0f32; U];
    let chunks = idx.len() / U;
    let mut p = 0;
    for _ in 0..chunks {
        // U independent adds per iteration — no WAW dependency.
        for u in 0..U {
            acc[u] += gat(xr, idx[p + u]);
        }
        p += U;
    }
    // Cleanup tail.
    let mut tail = 0.0f32;
    for &i in &idx[p..] {
        tail += gat(xr, i);
    }
    acc.iter().sum::<f32>() + tail
}

impl<const U: usize> UnrolledTcscKernel<U> {
    pub const fn new() -> Self {
        UnrolledTcscKernel
    }
}

impl<const U: usize> Default for UnrolledTcscKernel<U> {
    fn default() -> Self {
        Self::new()
    }
}

impl<const U: usize> Kernel for UnrolledTcscKernel<U> {
    type Format = Tcsc;

    fn name(&self) -> &'static str {
        // Const generics can't format at compile time on stable; registry
        // provides the parameterized display name.
        "unrolled_tcsc"
    }

    fn run(&self, x: &Matrix, w: &Tcsc, bias: &[f32], y: &mut Matrix) {
        use crate::formats::SparseFormat;
        crate::kernels::debug_check_shapes(x, w.k(), w.n(), bias, y);
        let m = x.rows();
        let n = w.n();
        for r in 0..m {
            let xr = x.row(r);
            let yr = y.row_mut(r);
            for c in 0..n {
                let pos = unrolled_gather_sum::<U>(xr, w.col_pos(c));
                let neg = unrolled_gather_sum::<U>(xr, w.col_neg(c));
                yr[c] = pos - neg + bias[c];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::dense_oracle;
    use crate::ternary::TernaryMatrix;

    fn check<const U: usize>() {
        let w = TernaryMatrix::random(130, 24, 0.5, 19); // odd size → tails
        let f = Tcsc::from_ternary(&w);
        let x = Matrix::random(3, 130, 20);
        let bias: Vec<f32> = (0..24).map(|i| i as f32 * 0.01).collect();
        let oracle = dense_oracle(&x, &w, &bias);
        let mut y = Matrix::zeros(3, 24);
        UnrolledTcscKernel::<U>.run(&x, &f, &bias, &mut y);
        assert!(y.allclose(&oracle, 1e-4), "U={U}");
    }

    #[test]
    fn all_paper_factors_match_oracle() {
        check::<1>();
        check::<2>();
        check::<4>();
        check::<8>();
        check::<12>();
        check::<16>();
    }

    #[test]
    fn gather_sum_handles_short_inputs() {
        let xr = [1.0f32, 2.0, 3.0, 4.0];
        // Fewer indices than U: everything lands in the tail.
        assert_eq!(unrolled_gather_sum::<8>(&xr, &[0, 2]), 4.0);
        assert_eq!(unrolled_gather_sum::<4>(&xr, &[]), 0.0);
        assert_eq!(unrolled_gather_sum::<2>(&xr, &[0, 1, 2, 3, 0]), 11.0);
    }

    #[test]
    fn low_sparsity_tails() {
        let w = TernaryMatrix::random(64, 16, 0.0625, 5);
        let f = Tcsc::from_ternary(&w);
        let x = Matrix::random(2, 64, 6);
        let bias = vec![0.0f32; 16];
        let oracle = dense_oracle(&x, &w, &bias);
        let mut y = Matrix::zeros(2, 16);
        UnrolledTcscKernel::<12>.run(&x, &f, &bias, &mut y);
        assert!(y.allclose(&oracle, 1e-4));
    }
}
