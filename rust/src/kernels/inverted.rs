//! Inverted-index kernel (paper §3 "Inverted Index") — a single row-sorted
//! pass per column, decoding `(row, sign)` from each entry with a branch in
//! the innermost loop. The paper measured the decode branching costs more
//! than the unified pass saves; kept for the ablation bench that reproduces
//! that negative result.

use crate::formats::inverted::{decode, InvertedIndex};
use crate::kernels::Kernel;
use crate::tensor::Matrix;

/// Sign-in-index single-pass kernel.
pub struct InvertedKernel;

impl Kernel for InvertedKernel {
    type Format = InvertedIndex;

    fn name(&self) -> &'static str {
        "inverted_index"
    }

    fn run(&self, x: &Matrix, w: &InvertedIndex, bias: &[f32], y: &mut Matrix) {
        use crate::formats::SparseFormat;
        crate::kernels::debug_check_shapes(x, w.k(), w.n(), bias, y);
        let m = x.rows();
        let n = w.n();
        for r in 0..m {
            let xr = x.row(r);
            let yr = y.row_mut(r);
            for c in 0..n {
                let mut acc = 0.0f32;
                for &e in w.col(c) {
                    // The branch the paper blames: decode index and sign.
                    let (i, s) = decode(e);
                    if s > 0 {
                        acc += xr[i];
                    } else {
                        acc -= xr[i];
                    }
                }
                yr[c] = acc + bias[c];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::dense_oracle;
    use crate::ternary::TernaryMatrix;

    #[test]
    fn matches_oracle() {
        for &s in &crate::PAPER_SPARSITIES {
            let w = TernaryMatrix::random(110, 18, s, 91);
            let f = InvertedIndex::from_ternary(&w);
            let x = Matrix::random(5, 110, 92);
            let bias: Vec<f32> = (0..18).map(|i| -(i as f32) * 0.02).collect();
            let oracle = dense_oracle(&x, &w, &bias);
            let mut y = Matrix::zeros(5, 18);
            InvertedKernel.run(&x, &f, &bias, &mut y);
            assert!(y.allclose(&oracle, 1e-4), "s={s}");
        }
    }

    #[test]
    fn all_negative_column() {
        let mut w = TernaryMatrix::zeros(4, 1);
        for i in 0..4 {
            w.set(i, 0, -1);
        }
        let f = InvertedIndex::from_ternary(&w);
        let x = Matrix::from_slice(1, 4, &[1.0, 2.0, 3.0, 4.0]);
        let mut y = Matrix::zeros(1, 1);
        InvertedKernel.run(&x, &f, &[0.0], &mut y);
        assert_eq!(y[(0, 0)], -10.0);
    }
}
