//! UnrolledBlockedTCSC_K{KU}_M{MU} (paper §3 "Blocking") — the K4/M4
//! unrolled kernel running over the block-major [`BlockedTcsc`] format:
//! Y is initialized with the bias, then each K-block accumulates into it,
//! keeping every gathered X element inside a `B`-element window
//! (paper-optimal B = 4096, i.e. 4 rows of 4096 f32 in M1's L1).

use crate::formats::BlockedTcsc;
use crate::kernels::unrolled_m::gather_rows;
use crate::kernels::Kernel;
use crate::tensor::Matrix;

/// Blocked + unrolled kernel. Paper configuration: `KU=4, MU=4`, B=4096.
pub struct UnrolledBlockedKernel<const KU: usize, const MU: usize>;

impl<const KU: usize, const MU: usize> Kernel for UnrolledBlockedKernel<KU, MU> {
    type Format = BlockedTcsc;

    fn name(&self) -> &'static str {
        "unrolled_blocked_tcsc"
    }

    fn run(&self, x: &Matrix, w: &BlockedTcsc, bias: &[f32], y: &mut Matrix) {
        use crate::formats::SparseFormat;
        crate::kernels::debug_check_shapes(x, w.k(), w.n(), bias, y);
        let m = x.rows();
        let n = w.n();
        // Bias initialization pass (the +1 flop per element in the paper's
        // cost model).
        for r in 0..m {
            y.row_mut(r).copy_from_slice(bias);
        }
        let nblocks = w.nblocks();
        for b in 0..nblocks {
            let mut r = 0;
            while r + MU <= m {
                let xrows: [&[f32]; MU] = std::array::from_fn(|i| x.row(r + i));
                for c in 0..n {
                    let mut acc = [0.0f32; MU];
                    gather_rows::<KU, MU>(&xrows, w.block_col_pos(b, c), &mut acc, false);
                    gather_rows::<KU, MU>(&xrows, w.block_col_neg(b, c), &mut acc, true);
                    for (i, a) in acc.iter().enumerate() {
                        y[(r + i, c)] += a;
                    }
                }
                r += MU;
            }
            while r < m {
                let xrows: [&[f32]; 1] = [x.row(r)];
                for c in 0..n {
                    let mut acc = [0.0f32; 1];
                    gather_rows::<KU, 1>(&xrows, w.block_col_pos(b, c), &mut acc, false);
                    gather_rows::<KU, 1>(&xrows, w.block_col_neg(b, c), &mut acc, true);
                    y[(r, c)] += acc[0];
                }
                r += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::dense_oracle;
    use crate::ternary::TernaryMatrix;

    fn check<const KU: usize, const MU: usize>(m: usize, k: usize, bs: usize) {
        let w = TernaryMatrix::random(k, 20, 0.25, 47);
        let f = BlockedTcsc::from_ternary(&w, bs);
        let x = Matrix::random(m, k, 48);
        let bias: Vec<f32> = (0..20).map(|i| (i as f32) * 0.3).collect();
        let oracle = dense_oracle(&x, &w, &bias);
        let mut y = Matrix::zeros(m, 20);
        UnrolledBlockedKernel::<KU, MU>.run(&x, &f, &bias, &mut y);
        assert!(
            y.allclose(&oracle, 1e-4),
            "KU={KU} MU={MU} m={m} k={k} bs={bs}"
        );
    }

    #[test]
    fn paper_configuration() {
        check::<4, 4>(8, 128, 32);
    }

    #[test]
    fn non_dividing_block_sizes() {
        check::<4, 4>(4, 100, 17);
        check::<2, 2>(5, 67, 10);
    }

    #[test]
    fn single_block_degenerates_to_unblocked() {
        check::<4, 4>(4, 64, 4096);
    }

    #[test]
    fn tiny_blocks() {
        check::<4, 4>(3, 33, 1);
    }

    #[test]
    fn row_remainder() {
        check::<4, 4>(6, 80, 16); // 6 = 4 + 2 remainder rows
    }
}
