//! Vectorization of the best scalar kernel (paper §3, last vectorization
//! approach): blocked (B = 4096) + interleaved (paper group 2, any group
//! supported) format, vectorized over **M** — one `F32x4` accumulator per
//! W column whose four lanes map to four rows of X. Each innermost
//! iteration consumes one interleaved step (G positive + G negative
//! indices) and performs column-gathers of X (stride-K "vertical" gathers,
//! four scalar loads each — NEON has no gather, and neither do we).
//! Remainder segments and ragged rows fall back to the scalar cleanup,
//! whose ILP is why the paper found this variant performs *similarly but
//! not better* than the best scalar kernel.

use crate::formats::{InterleavedBlockedTcsc, SparseFormat};
use crate::kernels::prelu::prelu_scalar;
use crate::kernels::simd::f32x4::F32x4;
use crate::kernels::unrolled_m::gather_rows;
use crate::tensor::Matrix;

/// SIMD-over-M vectorization of [`crate::kernels::InterleavedBlockedKernel`].
pub struct SimdBlockedMnKernel {
    /// Fused PReLU slope; `None` disables activation.
    pub prelu_alpha: Option<f32>,
}

impl SimdBlockedMnKernel {
    pub fn new(prelu_alpha: Option<f32>) -> Self {
        SimdBlockedMnKernel { prelu_alpha }
    }

    /// Gather X[r..r+4][i] (a column of the 4-row tile). Unchecked: the
    /// format validates `i < K` at construction and `run` asserts row
    /// lengths; see `F32x4::gather_unchecked` for the shared contract.
    #[inline(always)]
    fn col_gather(xrows: &[&[f32]; 4], i: u32) -> F32x4 {
        let i = i as usize;
        debug_assert!(xrows.iter().all(|r| i < r.len()));
        // SAFETY: see above.
        unsafe {
            F32x4([
                *xrows[0].get_unchecked(i),
                *xrows[1].get_unchecked(i),
                *xrows[2].get_unchecked(i),
                *xrows[3].get_unchecked(i),
            ])
        }
    }

    pub fn run(
        &self,
        x: &Matrix,
        w: &InterleavedBlockedTcsc,
        bias: &[f32],
        y: &mut Matrix,
    ) {
        assert_eq!(x.cols(), w.k());
        assert_eq!(bias.len(), w.n());
        assert_eq!(y.rows(), x.rows());
        assert_eq!(y.cols(), w.n());
        let m = x.rows();
        let n = w.n();
        let g = w.group;
        for r in 0..m {
            y.row_mut(r).copy_from_slice(bias);
        }
        for b in 0..w.nblocks() {
            let mut r = 0;
            // 4-row SIMD tiles.
            while r + 4 <= m {
                let xrows: [&[f32]; 4] = std::array::from_fn(|i| x.row(r + i));
                for c in 0..n {
                    let inter = w.seg_interleaved(b, c);
                    let mut acc = F32x4::ZERO;
                    if g == 2 {
                        // Paper config fast path (group 2): 2 adds + 2 subs
                        // per step. Two accumulators would add ILP; measured
                        // neutral here because the 16 scalar gather loads
                        // dominate the port pressure (the paper's
                        // observation exactly).
                        for step in inter.chunks_exact(4) {
                            let p0 = Self::col_gather(&xrows, step[0]);
                            let p1 = Self::col_gather(&xrows, step[1]);
                            let n0 = Self::col_gather(&xrows, step[2]);
                            let n1 = Self::col_gather(&xrows, step[3]);
                            acc = acc.add(p0).add(p1).sub(n0).sub(n1);
                        }
                    } else {
                        // Generic group: g adds then g subtracts per step.
                        for step in inter.chunks_exact(2 * g) {
                            for &i in &step[..g] {
                                acc = acc.add(Self::col_gather(&xrows, i));
                            }
                            for &i in &step[g..] {
                                acc = acc.sub(Self::col_gather(&xrows, i));
                            }
                        }
                    }
                    // Scalar cleanup for the unmatched remainders.
                    let mut rest = [0.0f32; 4];
                    gather_rows::<4, 4>(&xrows, w.seg_rest_pos(b, c), &mut rest, false);
                    gather_rows::<4, 4>(&xrows, w.seg_rest_neg(b, c), &mut rest, true);
                    for i in 0..4 {
                        y[(r + i, c)] += acc.0[i] + rest[i];
                    }
                }
                r += 4;
            }
            // Ragged rows: scalar path, same accumulation order as a tile
            // lane so chunked execution stays bit-identical.
            while r < m {
                let xrows: [&[f32]; 1] = [x.row(r)];
                for c in 0..n {
                    let mut acc = [0.0f32; 1];
                    let inter = w.seg_interleaved(b, c);
                    if g == 2 {
                        for step in inter.chunks_exact(4) {
                            acc[0] = acc[0] + xrows[0][step[0] as usize]
                                + xrows[0][step[1] as usize]
                                - xrows[0][step[2] as usize]
                                - xrows[0][step[3] as usize];
                        }
                    } else {
                        for step in inter.chunks_exact(2 * g) {
                            for &i in &step[..g] {
                                acc[0] += xrows[0][i as usize];
                            }
                            for &i in &step[g..] {
                                acc[0] -= xrows[0][i as usize];
                            }
                        }
                    }
                    gather_rows::<4, 1>(&xrows, w.seg_rest_pos(b, c), &mut acc, false);
                    gather_rows::<4, 1>(&xrows, w.seg_rest_neg(b, c), &mut acc, true);
                    y[(r, c)] += acc[0];
                }
                r += 1;
            }
        }
        if let Some(alpha) = self.prelu_alpha {
            for v in y.as_mut_slice() {
                *v = prelu_scalar(*v, alpha);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{dense_oracle, prelu_inplace};
    use crate::ternary::TernaryMatrix;

    fn check(m: usize, k: usize, bs: usize, s: f32, prelu: Option<f32>) {
        let w = TernaryMatrix::random(k, 16, s, 121);
        let f = InterleavedBlockedTcsc::from_ternary(&w, bs, 2);
        let x = Matrix::random(m, k, 122);
        let bias: Vec<f32> = (0..16).map(|i| 0.02 * i as f32 - 0.1).collect();
        let mut oracle = dense_oracle(&x, &w, &bias);
        if let Some(a) = prelu {
            prelu_inplace(&mut oracle, a);
        }
        let mut y = Matrix::zeros(m, 16);
        SimdBlockedMnKernel::new(prelu).run(&x, &f, &bias, &mut y);
        assert!(y.allclose(&oracle, 1e-4), "m={m} k={k} bs={bs} s={s}");
    }

    #[test]
    fn paper_config() {
        check(8, 256, 64, 0.5, None);
    }

    #[test]
    fn across_sparsities_with_prelu() {
        for &s in &crate::PAPER_SPARSITIES {
            check(4, 128, 32, s, Some(0.25));
        }
    }

    #[test]
    fn ragged_rows() {
        check(7, 96, 24, 0.5, None);
        check(3, 64, 16, 0.25, Some(0.1));
        check(1, 32, 8, 0.5, None);
    }

    #[test]
    fn nondefault_groups_match_oracle() {
        // The kernel is no longer pinned to the paper's group-2 layout:
        // any interleave group runs through the generic walk.
        for g in [1usize, 3, 4] {
            let w = TernaryMatrix::random(96, 12, 0.25, 7 + g as u64);
            let f = InterleavedBlockedTcsc::from_ternary(&w, 32, g);
            let x = Matrix::random(6, 96, 8);
            let bias: Vec<f32> = (0..12).map(|i| 0.03 * i as f32).collect();
            let oracle = dense_oracle(&x, &w, &bias);
            let mut y = Matrix::zeros(6, 12);
            SimdBlockedMnKernel::new(None).run(&x, &f, &bias, &mut y);
            assert!(y.allclose(&oracle, 1e-4), "group {g}");
        }
    }
}
