//! SIMD kernels (paper §3 "SIMD Vectorization").
//!
//! The paper targets NEON's 4-lane f32 registers; NEON has **no gather**
//! instruction (SVE does, Apple Silicon doesn't implement it), which is the
//! paper's central vectorization finding. We mirror the constraint exactly
//! with [`f32x4`]: a portable 4-lane vector whose "gather" is four scalar
//! loads — the same μop cost NEON pays — so the scalar-beats-vector result
//! transfers.

pub mod f32x4;
pub mod vertical;
pub mod horizontal;
pub mod blocked_mn;

pub use blocked_mn::SimdBlockedMnKernel;
pub use f32x4::F32x4;
pub use horizontal::HorizontalSimdKernel;
pub use vertical::VerticalSimdKernel;
