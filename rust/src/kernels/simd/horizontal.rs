//! Horizontal SIMD kernel (paper §3) — one vector register per output
//! column holding partial sums `[P0, P1, N0, N1]`: lanes 0–1 accumulate
//! the column's positive gathers, lanes 2–3 the negatives (the symmetric
//! format stores quads `[p,p,n,n]`, so each step is one 4-index gather and
//! one vector add per column). The final value is a horizontal reduction
//! `(P0+P1) − (N0+N1) + bias`, PReLU fused.

use crate::formats::{SparseFormat, SymmetricTcsc};
use crate::kernels::prelu::prelu_scalar;
use crate::kernels::simd::f32x4::F32x4;
use crate::tensor::{Matrix, PaddedMatrix};

/// Horizontal (register = one column's `[P,P,N,N]`) SIMD kernel.
pub struct HorizontalSimdKernel {
    /// Fused PReLU slope; `None` disables activation.
    pub prelu_alpha: Option<f32>,
}

impl HorizontalSimdKernel {
    pub fn new(prelu_alpha: Option<f32>) -> Self {
        HorizontalSimdKernel { prelu_alpha }
    }

    /// Run over a padded activation matrix (the dummy index reads 0.0).
    pub fn run_padded(
        &self,
        x: &PaddedMatrix,
        w: &SymmetricTcsc,
        bias: &[f32],
        y: &mut Matrix,
    ) {
        assert_eq!(x.k(), w.k(), "X cols must equal K");
        assert_eq!(bias.len(), w.n());
        assert_eq!(y.rows(), x.rows());
        assert_eq!(y.cols(), w.n());
        let m = x.rows();
        let n = w.n();
        for r in 0..m {
            let xr = x.row(r);
            for g in 0..w.ngroups() {
                let block = w.group_indices(g);
                // One [P,P,N,N] accumulator per column of the group.
                let mut acc = [F32x4::ZERO; 4];
                for step in block.chunks_exact(16) {
                    for (c, a) in acc.iter_mut().enumerate() {
                        let quad = &step[4 * c..4 * c + 4];
                        let v = F32x4::gather_unchecked(
                            xr,
                            [quad[0], quad[1], quad[2], quad[3]],
                        );
                        *a = a.add(v);
                    }
                }
                let cols = (n - 4 * g).min(4);
                let yr = y.row_mut(r);
                for c in 0..cols {
                    let mut v = acc[c].hsum_pos_neg() + bias[4 * g + c];
                    if let Some(alpha) = self.prelu_alpha {
                        v = prelu_scalar(v, alpha);
                    }
                    yr[4 * g + c] = v;
                }
            }
        }
    }

    /// Convenience wrapper that pads X internally (copies X once).
    pub fn run(&self, x: &Matrix, w: &SymmetricTcsc, bias: &[f32], y: &mut Matrix) {
        let padded = PaddedMatrix::from_matrix(x);
        self.run_padded(&padded, w, bias, y);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{dense_oracle, prelu_inplace};
    use crate::ternary::TernaryMatrix;

    fn check(k: usize, n: usize, s: f32, prelu: Option<f32>) {
        let w = TernaryMatrix::random(k, n, s, 111);
        let f = SymmetricTcsc::from_ternary(&w);
        let x = Matrix::random(4, k, 112);
        let bias: Vec<f32> = (0..n).map(|i| (i as f32) * 0.07 - 0.5).collect();
        let mut oracle = dense_oracle(&x, &w, &bias);
        if let Some(a) = prelu {
            prelu_inplace(&mut oracle, a);
        }
        let mut y = Matrix::zeros(4, n);
        HorizontalSimdKernel::new(prelu).run(&x, &f, &bias, &mut y);
        assert!(y.allclose(&oracle, 1e-4), "k={k} n={n} s={s}");
    }

    #[test]
    fn matches_oracle_across_sparsities() {
        for &s in &crate::PAPER_SPARSITIES {
            check(96, 12, s, None);
        }
    }

    #[test]
    fn with_fused_prelu() {
        check(96, 12, 0.25, Some(0.25));
    }

    #[test]
    fn ragged_n() {
        check(48, 9, 0.5, None);
        check(48, 2, 0.5, Some(0.33));
    }

    #[test]
    fn agrees_with_vertical() {
        use crate::kernels::simd::vertical::VerticalSimdKernel;
        let w = TernaryMatrix::random(80, 20, 0.5, 9);
        let f = SymmetricTcsc::from_ternary(&w);
        let x = Matrix::random(3, 80, 10);
        let bias = vec![0.25f32; 20];
        let mut yh = Matrix::zeros(3, 20);
        let mut yv = Matrix::zeros(3, 20);
        HorizontalSimdKernel::new(Some(0.25)).run(&x, &f, &bias, &mut yh);
        VerticalSimdKernel::new(Some(0.25)).run(&x, &f, &bias, &mut yv);
        assert!(yh.allclose(&yv, 1e-5));
    }
}
