//! Portable 4-lane f32 vector — the NEON `float32x4_t` stand-in.
//!
//! Implemented as `[f32; 4]` with `#[inline(always)]` lane-parallel ops;
//! LLVM reliably lowers these to a single SSE/NEON register op at
//! `opt-level=3`. Deliberately **no gather constructor from memory +
//! indices as a single op** — `gather` below is four scalar loads, exactly
//! the cost model of NEON (and the reason the paper's vectorized kernels
//! don't beat the best scalar one).

/// 4-lane f32 vector.
#[derive(Debug, Clone, Copy, PartialEq)]
#[repr(C, align(16))]
pub struct F32x4(pub [f32; 4]);

impl F32x4 {
    pub const ZERO: F32x4 = F32x4([0.0; 4]);

    #[inline(always)]
    pub fn splat(v: f32) -> F32x4 {
        F32x4([v; 4])
    }

    /// Aligned-friendly sequential load.
    #[inline(always)]
    pub fn load(src: &[f32]) -> F32x4 {
        F32x4([src[0], src[1], src[2], src[3]])
    }

    /// Sequential store.
    #[inline(always)]
    pub fn store(self, dst: &mut [f32]) {
        dst[..4].copy_from_slice(&self.0);
    }

    /// "Gather": four scalar loads (NEON has no gather; this is the honest
    /// cost).
    #[inline(always)]
    pub fn gather(src: &[f32], idx: [usize; 4]) -> F32x4 {
        F32x4([src[idx[0]], src[idx[1]], src[idx[2]], src[idx[3]]])
    }

    /// Unchecked gather for the kernel hot loops. SAFETY contract: every
    /// index has been validated `< src.len()` by the format constructor
    /// (`SymmetricTcsc`/`InterleavedBlockedTcsc::validate`, plus the
    /// padded-matrix dummy slot) and the kernel asserts row lengths on
    /// entry. Debug builds still bounds-check via `debug_assert`.
    #[inline(always)]
    pub fn gather_unchecked(src: &[f32], idx: [u32; 4]) -> F32x4 {
        debug_assert!(idx.iter().all(|&i| (i as usize) < src.len()));
        // SAFETY: see above.
        unsafe {
            F32x4([
                *src.get_unchecked(idx[0] as usize),
                *src.get_unchecked(idx[1] as usize),
                *src.get_unchecked(idx[2] as usize),
                *src.get_unchecked(idx[3] as usize),
            ])
        }
    }

    /// Lane-wise add.
    #[inline(always)]
    pub fn add(self, o: F32x4) -> F32x4 {
        F32x4([
            self.0[0] + o.0[0],
            self.0[1] + o.0[1],
            self.0[2] + o.0[2],
            self.0[3] + o.0[3],
        ])
    }

    /// Lane-wise subtract.
    #[inline(always)]
    pub fn sub(self, o: F32x4) -> F32x4 {
        F32x4([
            self.0[0] - o.0[0],
            self.0[1] - o.0[1],
            self.0[2] - o.0[2],
            self.0[3] - o.0[3],
        ])
    }

    /// Lane-wise multiply (PReLU fusion needs it).
    #[inline(always)]
    pub fn mul(self, o: F32x4) -> F32x4 {
        F32x4([
            self.0[0] * o.0[0],
            self.0[1] * o.0[1],
            self.0[2] * o.0[2],
            self.0[3] * o.0[3],
        ])
    }

    /// Horizontal sum of all four lanes (NEON `vaddvq_f32`).
    #[inline(always)]
    pub fn hsum(self) -> f32 {
        (self.0[0] + self.0[1]) + (self.0[2] + self.0[3])
    }

    /// Sum of the low two lanes minus sum of the high two lanes — the
    /// horizontal kernel's `[P0,P1,N0,N1]` reduction.
    #[inline(always)]
    pub fn hsum_pos_neg(self) -> f32 {
        (self.0[0] + self.0[1]) - (self.0[2] + self.0[3])
    }

    /// Lane-wise PReLU (`v > 0 ? v : α·v`) — vectorized select.
    #[inline(always)]
    pub fn prelu(self, alpha: f32) -> F32x4 {
        F32x4([
            if self.0[0] > 0.0 { self.0[0] } else { alpha * self.0[0] },
            if self.0[1] > 0.0 { self.0[1] } else { alpha * self.0[1] },
            if self.0[2] > 0.0 { self.0[2] } else { alpha * self.0[2] },
            if self.0[3] > 0.0 { self.0[3] } else { alpha * self.0[3] },
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arithmetic() {
        let a = F32x4([1.0, 2.0, 3.0, 4.0]);
        let b = F32x4::splat(10.0);
        assert_eq!(a.add(b).0, [11.0, 12.0, 13.0, 14.0]);
        assert_eq!(b.sub(a).0, [9.0, 8.0, 7.0, 6.0]);
        assert_eq!(a.mul(a).0, [1.0, 4.0, 9.0, 16.0]);
    }

    #[test]
    fn gather_and_reductions() {
        let src = [0.0f32, 10.0, 20.0, 30.0, 40.0];
        let v = F32x4::gather(&src, [4, 0, 2, 1]);
        assert_eq!(v.0, [40.0, 0.0, 20.0, 10.0]);
        assert_eq!(v.hsum(), 70.0);
        assert_eq!(v.hsum_pos_neg(), 40.0 + 0.0 - 20.0 - 10.0);
    }

    #[test]
    fn load_store_roundtrip() {
        let src = [5.0f32, 6.0, 7.0, 8.0];
        let mut dst = [0.0f32; 4];
        F32x4::load(&src).store(&mut dst);
        assert_eq!(dst, src);
    }

    #[test]
    fn prelu_lanes() {
        let v = F32x4([-4.0, -1.0, 0.5, 2.0]).prelu(0.25);
        assert_eq!(v.0, [-1.0, -0.25, 0.5, 2.0]);
    }

    #[test]
    fn alignment() {
        assert_eq!(std::mem::align_of::<F32x4>(), 16);
        assert_eq!(std::mem::size_of::<F32x4>(), 16);
    }
}
