//! Vertical SIMD kernel (paper §3) — each vector lane holds one of four
//! output columns of `Y[m][4g..4g+4]`. Per innermost iteration it consumes
//! one symmetric-format step per column (2 positive + 2 negative gathered
//! X values), accumulating into one positive and one negative sum register;
//! the final value is `pos − neg + bias`, with PReLU fused (the paper's
//! Fig 11 vectorized functions all include it).

use crate::formats::{SparseFormat, SymmetricTcsc};
use crate::kernels::simd::f32x4::F32x4;
use crate::tensor::{Matrix, PaddedMatrix};

/// Vertical (lane = output column) SIMD kernel over the symmetric format.
pub struct VerticalSimdKernel {
    /// Fused PReLU slope; `None` disables activation.
    pub prelu_alpha: Option<f32>,
}

impl VerticalSimdKernel {
    pub fn new(prelu_alpha: Option<f32>) -> Self {
        VerticalSimdKernel { prelu_alpha }
    }

    /// Run over a padded activation matrix (the dummy index reads 0.0).
    pub fn run_padded(
        &self,
        x: &PaddedMatrix,
        w: &SymmetricTcsc,
        bias: &[f32],
        y: &mut Matrix,
    ) {
        assert_eq!(x.k(), w.k(), "X cols must equal K");
        assert_eq!(bias.len(), w.n());
        assert_eq!(y.rows(), x.rows());
        assert_eq!(y.cols(), w.n());
        let m = x.rows();
        let n = w.n();
        let ngroups = w.ngroups();
        for r in 0..m {
            let xr = x.row(r); // length K+1, slot K == 0.0
            for g in 0..ngroups {
                let block = w.group_indices(g);
                let mut posv = F32x4::ZERO;
                let mut negv = F32x4::ZERO;
                // 16 indices per step: [c0:p,p,n,n][c1:p,p,n,n][c2…][c3…].
                for step in block.chunks_exact(16) {
                    let p0 =
                        F32x4::gather_unchecked(xr, [step[0], step[4], step[8], step[12]]);
                    let p1 =
                        F32x4::gather_unchecked(xr, [step[1], step[5], step[9], step[13]]);
                    let n0 =
                        F32x4::gather_unchecked(xr, [step[2], step[6], step[10], step[14]]);
                    let n1 =
                        F32x4::gather_unchecked(xr, [step[3], step[7], step[11], step[15]]);
                    posv = posv.add(p0).add(p1);
                    negv = negv.add(n0).add(n1);
                }
                // pos − neg + bias, fused PReLU, masked tail store.
                let cols = (n - 4 * g).min(4);
                let mut bias_v = [0.0f32; 4];
                bias_v[..cols].copy_from_slice(&bias[4 * g..4 * g + cols]);
                let mut out = posv.sub(negv).add(F32x4(bias_v));
                if let Some(alpha) = self.prelu_alpha {
                    out = out.prelu(alpha);
                }
                let yr = y.row_mut(r);
                yr[4 * g..4 * g + cols].copy_from_slice(&out.0[..cols]);
            }
        }
    }

    /// Convenience wrapper that pads X internally (copies X once).
    pub fn run(&self, x: &Matrix, w: &SymmetricTcsc, bias: &[f32], y: &mut Matrix) {
        let padded = PaddedMatrix::from_matrix(x);
        self.run_padded(&padded, w, bias, y);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{dense_oracle, prelu_inplace};
    use crate::ternary::TernaryMatrix;

    fn check(k: usize, n: usize, s: f32, prelu: Option<f32>) {
        let w = TernaryMatrix::random(k, n, s, 101);
        let f = SymmetricTcsc::from_ternary(&w);
        let x = Matrix::random(3, k, 102);
        let bias: Vec<f32> = (0..n).map(|i| (i as f32 - n as f32 / 2.0) * 0.1).collect();
        let mut oracle = dense_oracle(&x, &w, &bias);
        if let Some(a) = prelu {
            prelu_inplace(&mut oracle, a);
        }
        let mut y = Matrix::zeros(3, n);
        VerticalSimdKernel::new(prelu).run(&x, &f, &bias, &mut y);
        assert!(y.allclose(&oracle, 1e-4), "k={k} n={n} s={s}");
    }

    #[test]
    fn matches_oracle_across_sparsities() {
        for &s in &crate::PAPER_SPARSITIES {
            check(64, 16, s, None);
        }
    }

    #[test]
    fn with_fused_prelu() {
        check(64, 16, 0.5, Some(0.25));
    }

    #[test]
    fn n_not_multiple_of_four() {
        check(32, 7, 0.5, None);
        check(32, 1, 0.5, Some(0.1));
        check(32, 5, 0.25, None);
    }

    #[test]
    fn unbalanced_signs_use_dummy() {
        // All-positive matrix: every negative slot is the dummy.
        let mut w = TernaryMatrix::zeros(16, 4);
        for i in 0..16 {
            for j in 0..4 {
                if (i + j) % 3 == 0 {
                    w.set(i, j, 1);
                }
            }
        }
        let f = SymmetricTcsc::from_ternary(&w);
        let x = Matrix::random(2, 16, 5);
        let bias = vec![0.0f32; 4];
        let oracle = dense_oracle(&x, &w, &bias);
        let mut y = Matrix::zeros(2, 4);
        VerticalSimdKernel::new(None).run(&x, &f, &bias, &mut y);
        assert!(y.allclose(&oracle, 1e-4));
    }
}
