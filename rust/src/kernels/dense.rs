//! Dense reference implementations.
//!
//! [`dense_oracle`] is the correctness ground truth every sparse kernel is
//! tested against; [`DenseGemm`] is a plain f32 GEMM used in benchmark
//! reports to show what *ignoring* ternary structure costs.

use crate::tensor::Matrix;
use crate::ternary::TernaryMatrix;

/// Ground-truth `Y = X·W + b` straight off the dense ternary matrix.
/// f64 accumulation so kernel tests compare against a better-rounded
/// reference.
pub fn dense_oracle(x: &Matrix, w: &TernaryMatrix, bias: &[f32]) -> Matrix {
    assert_eq!(x.cols(), w.k());
    assert_eq!(bias.len(), w.n());
    let (m, k, n) = (x.rows(), w.k(), w.n());
    let mut y = Matrix::zeros(m, n);
    for r in 0..m {
        let xr = x.row(r);
        for c in 0..n {
            let mut acc = 0.0f64;
            for i in 0..k {
                match w.get(i, c) {
                    1 => acc += xr[i] as f64,
                    -1 => acc -= xr[i] as f64,
                    _ => {}
                }
            }
            y[(r, c)] = (acc + bias[c] as f64) as f32;
        }
    }
    y
}

/// Dense f32 GEMM (i-k-j loop order, row-major friendly): `Y = X·W + b`
/// where `W` is materialized densely from the ternary matrix. Benchmarked
/// as the "no sparsity exploited" baseline.
pub struct DenseGemm {
    /// Densified weights, row-major K×N.
    w: Vec<f32>,
    k: usize,
    n: usize,
}

impl DenseGemm {
    pub fn new(w: &TernaryMatrix) -> DenseGemm {
        let (k, n) = (w.k(), w.n());
        let mut dense = vec![0.0f32; k * n];
        for i in 0..k {
            for j in 0..n {
                dense[i * n + j] = w.get(i, j) as f32;
            }
        }
        DenseGemm { w: dense, k, n }
    }

    pub fn run(&self, x: &Matrix, bias: &[f32], y: &mut Matrix) {
        crate::kernels::debug_check_shapes(x, self.k, self.n, bias, y);
        let (m, k, n) = (x.rows(), self.k, self.n);
        for r in 0..m {
            let yr = y.row_mut(r);
            yr.copy_from_slice(bias);
        }
        for r in 0..m {
            let xr = x.row(r);
            let yr = y.row_mut(r);
            for i in 0..k {
                let xv = xr[i];
                let wrow = &self.w[i * n..(i + 1) * n];
                for j in 0..n {
                    yr[j] += xv * wrow[j];
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oracle_hand_example() {
        // X = [[1, 2]], W = [[+1, -1], [0, +1]], b = [10, 20]
        // Y = [1·1 + 2·0 + 10, 1·(-1) + 2·1 + 20] = [11, 21]
        let x = Matrix::from_slice(1, 2, &[1.0, 2.0]);
        let w = TernaryMatrix::from_entries(2, 2, &[1, -1, 0, 1]);
        let y = dense_oracle(&x, &w, &[10.0, 20.0]);
        assert_eq!(y.as_slice(), &[11.0, 21.0]);
    }

    #[test]
    fn dense_gemm_matches_oracle() {
        let w = TernaryMatrix::random(48, 24, 0.5, 1);
        let x = Matrix::random(5, 48, 2);
        let bias: Vec<f32> = (0..24).map(|i| i as f32 * 0.1).collect();
        let oracle = dense_oracle(&x, &w, &bias);
        let g = DenseGemm::new(&w);
        let mut y = Matrix::zeros(5, 24);
        g.run(&x, &bias, &mut y);
        assert!(y.allclose(&oracle, 1e-4));
    }

    #[test]
    fn zero_weights_give_bias() {
        let w = TernaryMatrix::zeros(8, 4);
        let x = Matrix::random(3, 8, 3);
        let bias = vec![1.5f32; 4];
        let y = dense_oracle(&x, &w, &bias);
        assert!(y.as_slice().iter().all(|&v| v == 1.5));
    }
}
