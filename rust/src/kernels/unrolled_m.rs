//! UnrolledTCSC_K{KU}_M{MU} (paper §3) — inner (nonzero/K-direction) unroll
//! by `KU` *and* outer (row/M-direction) unroll by `MU`: each pass over a
//! column's indices feeds `MU` rows of X at once, amortizing the index
//! stream across rows at the cost of a working set of `MU` rows of X and Y
//! (the cache-capacity tradeoff of the paper's Figs 2–4).

use crate::formats::Tcsc;
use crate::kernels::Kernel;
use crate::tensor::Matrix;

/// Row-and-nonzero unrolled TCSC kernel. Paper optimum: `KU=4, MU=4`.
pub struct UnrolledMKernel<const KU: usize, const MU: usize>;

/// Accumulate `sign · X[rows][idx]` into `acc[MU]` for a block of MU rows
/// starting at row pointer `xrows` (each a row slice of X).
#[inline(always)]
pub(crate) fn gather_rows<const KU: usize, const MU: usize>(
    xrows: &[&[f32]; MU],
    idx: &[u32],
    acc: &mut [f32; MU],
    negate: bool,
) {
    use super::unrolled::gat;
    let chunks = idx.len() / KU;
    let mut p = 0;
    if negate {
        for _ in 0..chunks {
            for u in 0..KU {
                let i = idx[p + u];
                for (m, row) in xrows.iter().enumerate() {
                    acc[m] -= gat(row, i);
                }
            }
            p += KU;
        }
        for &i in &idx[p..] {
            for (m, row) in xrows.iter().enumerate() {
                acc[m] -= gat(row, i);
            }
        }
    } else {
        for _ in 0..chunks {
            for u in 0..KU {
                let i = idx[p + u];
                for (m, row) in xrows.iter().enumerate() {
                    acc[m] += gat(row, i);
                }
            }
            p += KU;
        }
        for &i in &idx[p..] {
            for (m, row) in xrows.iter().enumerate() {
                acc[m] += gat(row, i);
            }
        }
    }
}

impl<const KU: usize, const MU: usize> Kernel for UnrolledMKernel<KU, MU> {
    type Format = Tcsc;

    fn name(&self) -> &'static str {
        "unrolled_km_tcsc"
    }

    fn run(&self, x: &Matrix, w: &Tcsc, bias: &[f32], y: &mut Matrix) {
        use crate::formats::SparseFormat;
        crate::kernels::debug_check_shapes(x, w.k(), w.n(), bias, y);
        let m = x.rows();
        let n = w.n();
        let mut r = 0;
        // Full MU-row tiles.
        while r + MU <= m {
            let xrows: [&[f32]; MU] = std::array::from_fn(|i| x.row(r + i));
            for c in 0..n {
                let mut acc = [0.0f32; MU];
                gather_rows::<KU, MU>(&xrows, w.col_pos(c), &mut acc, false);
                gather_rows::<KU, MU>(&xrows, w.col_neg(c), &mut acc, true);
                for (i, a) in acc.iter().enumerate() {
                    y[(r + i, c)] = a + bias[c];
                }
            }
            r += MU;
        }
        // Row remainder with the single-row path.
        while r < m {
            let xr = x.row(r);
            for c in 0..n {
                let pos = super::unrolled::unrolled_gather_sum::<KU>(xr, w.col_pos(c));
                let neg = super::unrolled::unrolled_gather_sum::<KU>(xr, w.col_neg(c));
                y[(r, c)] = pos - neg + bias[c];
            }
            r += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::dense_oracle;
    use crate::ternary::TernaryMatrix;

    fn check<const KU: usize, const MU: usize>(m: usize) {
        let w = TernaryMatrix::random(90, 20, 0.25, 33);
        let f = Tcsc::from_ternary(&w);
        let x = Matrix::random(m, 90, 34);
        let bias: Vec<f32> = (0..20).map(|i| -(i as f32) * 0.2).collect();
        let oracle = dense_oracle(&x, &w, &bias);
        let mut y = Matrix::zeros(m, 20);
        UnrolledMKernel::<KU, MU>.run(&x, &f, &bias, &mut y);
        assert!(y.allclose(&oracle, 1e-4), "KU={KU} MU={MU} m={m}");
    }

    #[test]
    fn paper_optimum_k4_m4() {
        check::<4, 4>(8);
    }

    #[test]
    fn row_remainders() {
        // m not divisible by MU exercises the scalar remainder path.
        check::<4, 4>(7);
        check::<2, 3>(4);
        check::<8, 2>(5);
    }

    #[test]
    fn grid_of_factors() {
        check::<1, 1>(3);
        check::<2, 2>(6);
        check::<12, 4>(9);
        check::<16, 8>(16);
    }

    #[test]
    fn m_smaller_than_mu() {
        check::<4, 8>(3); // all rows go through the remainder path
    }
}
