//! PReLU activation: `y = y if y > 0 else α·y`.
//!
//! The paper excludes PReLU from the scalar variants (to keep optimization
//! targets clean) and fuses it into all vectorized implementations (Fig 11
//! plots include it). Both forms live here: a standalone pass for scalar
//! pipelines and a fused epilogue helper the SIMD kernels call.

use crate::tensor::Matrix;

/// Default PReLU slope used across examples and benches.
pub const PRELU_DEFAULT_ALPHA: f32 = 0.25;

/// In-place PReLU over a full matrix.
pub fn prelu_inplace(y: &mut Matrix, alpha: f32) {
    for v in y.as_mut_slice() {
        if *v < 0.0 {
            *v *= alpha;
        }
    }
}

/// Scalar PReLU for a single value (fused epilogues).
#[inline(always)]
pub fn prelu_scalar(v: f32, alpha: f32) -> f32 {
    if v > 0.0 {
        v
    } else {
        alpha * v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_positive_scales_negative() {
        let mut y = Matrix::from_slice(1, 4, &[-2.0, -0.5, 0.0, 3.0]);
        prelu_inplace(&mut y, 0.25);
        assert_eq!(y.as_slice(), &[-0.5, -0.125, 0.0, 3.0]);
    }

    #[test]
    fn scalar_matches_inplace() {
        let vals = [-1.5f32, -0.1, 0.0, 0.1, 2.0];
        let mut m = Matrix::from_slice(1, 5, &vals);
        prelu_inplace(&mut m, 0.3);
        for (i, &v) in vals.iter().enumerate() {
            assert_eq!(m.as_slice()[i], prelu_scalar(v, 0.3));
        }
    }

    #[test]
    fn alpha_one_is_identity() {
        let vals = [-3.0f32, 4.0];
        let mut m = Matrix::from_slice(1, 2, &vals);
        prelu_inplace(&mut m, 1.0);
        assert_eq!(m.as_slice(), &vals);
    }
}
