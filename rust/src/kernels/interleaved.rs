//! InterleavedTCSC kernel (paper §3 "Interleaving") — one inner loop per
//! column walking the interleaved ± stream (adds and subtracts mingled in
//! sign groups of G), followed by the positive and negative remainder
//! cleanups. With `MU` rows unrolled like the best scalar variants.

use crate::formats::InterleavedTcsc;
use crate::kernels::unrolled_m::gather_rows;
use crate::kernels::Kernel;
use crate::tensor::Matrix;

/// Interleaved-stream kernel, `MU`-row unrolled. The interleaved segment is
/// consumed in `[G pos][G neg]` chunks in a single loop.
pub struct InterleavedKernel<const MU: usize>;

/// Walk an interleaved segment: alternating groups of `g` adds then `g`
/// subtracts for MU rows simultaneously.
#[inline(always)]
fn walk_interleaved<const MU: usize>(
    xrows: &[&[f32]; MU],
    inter: &[u32],
    g: usize,
    acc: &mut [f32; MU],
) {
    use crate::kernels::unrolled::gat;
    let step = 2 * g;
    debug_assert_eq!(inter.len() % step, 0);
    let mut p = 0;
    while p < inter.len() {
        for &i in &inter[p..p + g] {
            for (m, row) in xrows.iter().enumerate() {
                acc[m] += gat(row, i);
            }
        }
        for &i in &inter[p + g..p + step] {
            for (m, row) in xrows.iter().enumerate() {
                acc[m] -= gat(row, i);
            }
        }
        p += step;
    }
}

impl<const MU: usize> Kernel for InterleavedKernel<MU> {
    type Format = InterleavedTcsc;

    fn name(&self) -> &'static str {
        "interleaved_tcsc"
    }

    fn run(&self, x: &Matrix, w: &InterleavedTcsc, bias: &[f32], y: &mut Matrix) {
        use crate::formats::SparseFormat;
        crate::kernels::debug_check_shapes(x, w.k(), w.n(), bias, y);
        let m = x.rows();
        let n = w.n();
        let g = w.group;
        let mut r = 0;
        while r + MU <= m {
            let xrows: [&[f32]; MU] = std::array::from_fn(|i| x.row(r + i));
            for c in 0..n {
                let mut acc = [0.0f32; MU];
                walk_interleaved::<MU>(&xrows, w.col_interleaved(c), g, &mut acc);
                gather_rows::<4, MU>(&xrows, w.col_rest_pos(c), &mut acc, false);
                gather_rows::<4, MU>(&xrows, w.col_rest_neg(c), &mut acc, true);
                for (i, a) in acc.iter().enumerate() {
                    y[(r + i, c)] = a + bias[c];
                }
            }
            r += MU;
        }
        while r < m {
            let xrows: [&[f32]; 1] = [x.row(r)];
            for c in 0..n {
                let mut acc = [0.0f32; 1];
                walk_interleaved::<1>(&xrows, w.col_interleaved(c), g, &mut acc);
                gather_rows::<4, 1>(&xrows, w.col_rest_pos(c), &mut acc, false);
                gather_rows::<4, 1>(&xrows, w.col_rest_neg(c), &mut acc, true);
                y[(r, c)] = acc[0] + bias[c];
            }
            r += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::dense_oracle;
    use crate::ternary::TernaryMatrix;

    fn check<const MU: usize>(m: usize, g: usize, s: f32) {
        let w = TernaryMatrix::random(120, 24, s, 53);
        let f = InterleavedTcsc::from_ternary(&w, g);
        let x = Matrix::random(m, 120, 54);
        let bias: Vec<f32> = (0..24).map(|i| (i as f32).cos()).collect();
        let oracle = dense_oracle(&x, &w, &bias);
        let mut y = Matrix::zeros(m, 24);
        InterleavedKernel::<MU>.run(&x, &f, &bias, &mut y);
        assert!(y.allclose(&oracle, 1e-4), "MU={MU} m={m} g={g} s={s}");
    }

    #[test]
    fn paper_group_4() {
        check::<4>(8, 4, 0.5);
    }

    #[test]
    fn group_sizes_and_rows() {
        check::<1>(3, 1, 0.5);
        check::<2>(5, 2, 0.25);
        check::<4>(7, 8, 0.125);
    }

    #[test]
    fn low_sparsity_mostly_remainders() {
        check::<4>(4, 4, 0.0625);
    }
}
