//! Typed kernel registry: one static [`KernelDescriptor`] table is the
//! single source of truth for the whole kernel family.
//!
//! Every kernel the paper evaluates (TCSC baseline → unrolled →
//! blocked/interleaved → SIMD, plus the two ablation formats and the dense
//! reference) has exactly one [`KernelId`] and one row in [`descriptors`].
//! Everything else is a *derived query* over that table:
//!
//! - [`kernel_names`] / [`kernel_ids`] — enumeration, in canonical
//!   benchmark order;
//! - [`KernelId::parse`] / [`KernelId::name`] — the name ↔ id boundary
//!   (JSON tuning tables, model configs and bench flags stay name-keyed);
//! - [`KernelId::prepare`] — format construction + kernel binding, via the
//!   descriptor's constructor;
//! - capability filters ([`gemv_specialist`], [`best_scalar`],
//!   [`fused_simd`], [`matrix_tile`]) — the planner's heuristic candidate
//!   sets, selected by declared capability instead of hard-coded name
//!   literals;
//! - CPU-capability gating ([`available_ids`], [`available_kernel_ids`]) —
//!   each row declares the [`CpuFeature`]s its *selection* requires, and
//!   the planner, autotune sweep and online race enumerate only kernels
//!   the given [`CpuCaps`] satisfies. `prepare` stays host-agnostic: every
//!   kernel is portable by construction, so tests can always build one.
//!
//! Adding a kernel is one enum variant + one table row; the planner,
//! autotune sweep, config validation and benches pick it up without edits.

use crate::formats::{
    BlockedTcsc, CompressedTernary, InterleavedBlockedTcsc, InterleavedTcsc, InvertedIndex,
    SparseFormat, SymmetricTcsc, Tcsc, TileGeometry, TilePanelTcsc,
};
use crate::kernels::simd::{HorizontalSimdKernel, SimdBlockedMnKernel, VerticalSimdKernel};
use crate::kernels::{
    BaseTcscKernel, CompressedKernel, DenseGemm, InterleavedBlockedKernel, InterleavedKernel,
    InvertedKernel, Kernel, OuterTileKernel, OuterTileSimdKernel, UnrolledBlockedKernel,
    UnrolledMKernel, UnrolledTcscKernel,
};
use crate::perf::cpu::{CpuCaps, CpuFeature};
use crate::tensor::{Matrix, PaddedMatrix};
use crate::ternary::TernaryMatrix;
use crate::{Error, Result};
use std::sync::OnceLock;

/// Parameters a kernel build may consume (paper defaults).
#[derive(Debug, Clone, Copy)]
pub struct KernelParams {
    /// Block size for blocked formats; the paper's rule is `min(K, 4096)`.
    pub block_size: usize,
    /// Interleave group size (indices per sign). `None` picks the paper
    /// default per kernel family: 4 for `interleaved_tcsc`, 2 for the
    /// blocked interleaved kernels. `Some(g)` is honored by every
    /// interleaving kernel.
    pub group: Option<usize>,
    /// PReLU slope for kernels that fuse activation; `None` = no activation.
    pub prelu_alpha: Option<f32>,
    /// Tile geometry for kernels whose descriptor declares the geometry
    /// axis (the outer-product family). `None` picks
    /// [`TileGeometry::DEFAULT`]; the planner replaces `None` with the
    /// cache-driven [`crate::perf::BlockingPolicy`] pick, and tuning-table
    /// entries may carry a raced-in winner. Ignored by kernels without the
    /// axis.
    pub geometry: Option<TileGeometry>,
}

impl Default for KernelParams {
    fn default() -> Self {
        KernelParams {
            block_size: crate::PAPER_BLOCK_SIZE,
            group: None,
            prelu_alpha: None,
            geometry: None,
        }
    }
}

impl KernelParams {
    /// Paper rule: block size `min(K, 4096)`.
    pub fn effective_block(&self, k: usize) -> usize {
        self.block_size.min(k.max(1))
    }

    /// Group for the plain interleaved format (paper default 4).
    pub fn interleave_group(&self) -> usize {
        self.group.unwrap_or(crate::PAPER_GROUP_SIZE)
    }

    /// Group for the blocked interleaved formats (paper default 2).
    pub fn blocked_group(&self) -> usize {
        self.group.unwrap_or(crate::PAPER_BLOCKED_GROUP)
    }

    /// Tile geometry for the outer-product family (default: the
    /// pre-geometry-era 4-wide unblocked layout).
    pub fn tile_geometry(&self) -> TileGeometry {
        self.geometry.unwrap_or(TileGeometry::DEFAULT)
    }

    /// Reject parameter values no kernel constructor can honor. Called by
    /// [`KernelId::prepare`]; validating up front keeps the descriptor
    /// constructors infallible.
    pub fn validate(&self) -> Result<()> {
        if self.group == Some(0) {
            return Err(Error::BadKernelParams(
                "interleave group must be >= 1".into(),
            ));
        }
        if let Some(g) = self.geometry {
            g.validate()?;
        }
        Ok(())
    }
}

/// Reusable per-caller buffers a prepared kernel may keep across runs:
/// the SIMD family's padded X copy and the outer-product family's
/// transposed X tile — both previously rebuilt on **every** call, now
/// reused whenever the allocation is large enough (steady-state serving
/// performs no allocation).
#[derive(Debug, Default)]
pub struct GemmScratch {
    padded_x: Option<PaddedMatrix>,
    tile_x: Vec<f32>,
}

impl GemmScratch {
    pub fn new() -> GemmScratch {
        GemmScratch::default()
    }

    /// Padded copy of `x`, reusing the buffer when capacity allows.
    pub fn padded_x(&mut self, x: &Matrix) -> &PaddedMatrix {
        if self.padded_x.is_none() {
            self.padded_x = Some(PaddedMatrix::from_matrix(x));
        } else {
            self.padded_x.as_mut().expect("checked above").copy_from(x);
        }
        self.padded_x.as_ref().expect("just filled")
    }

    /// Pre-size the padded buffer for a `rows`×`k` problem (avoids the
    /// first-call allocation on the serving path).
    pub fn reserve_padded(&mut self, rows: usize, k: usize) {
        let needed = rows * (k + 1);
        let have = self.padded_x.as_ref().map_or(0, |p| p.capacity());
        if needed > have {
            self.padded_x = Some(PaddedMatrix::with_capacity(rows, k));
        }
    }

    /// Current padded-buffer capacity in f32 elements (0 = not allocated).
    /// Allocation-stability tests snapshot this across runs.
    pub fn padded_capacity(&self) -> usize {
        self.padded_x.as_ref().map_or(0, |p| p.capacity())
    }

    /// Transposed-tile staging buffer for the outer-product SIMD kernel.
    /// Layout and sizing belong to the kernel; the scratch just owns the
    /// allocation so it survives across calls.
    pub fn tile_x(&mut self) -> &mut Vec<f32> {
        &mut self.tile_x
    }

    /// Pre-size the tile buffer for a K-column problem
    /// (`K ·` [`crate::formats::OUTER_TILE`] f32 elements).
    pub fn reserve_tile(&mut self, k: usize) {
        let needed = k * crate::formats::OUTER_TILE;
        if self.tile_x.capacity() < needed {
            self.tile_x.reserve_exact(needed - self.tile_x.len());
        }
    }

    /// Current tile-buffer capacity in f32 elements (0 = not allocated).
    pub fn tile_capacity(&self) -> usize {
        self.tile_x.capacity()
    }
}

/// A kernel bound to its prepared format: the serving-time object.
pub trait PreparedGemm: Send + Sync {
    /// Registry name.
    fn name(&self) -> &str;

    /// Compute `Y = X·W + b` (+ fused activation where supported).
    fn run(&self, x: &Matrix, bias: &[f32], y: &mut Matrix);

    /// Like [`PreparedGemm::run`], but allowed to keep per-call buffers in
    /// `scratch` for reuse across calls. Kernels that need no scratch fall
    /// through to `run`. The planned execution path
    /// ([`crate::plan::GemmPlan`]) always calls this form.
    fn run_with_scratch(
        &self,
        x: &Matrix,
        bias: &[f32],
        y: &mut Matrix,
        _scratch: &mut GemmScratch,
    ) {
        self.run(x, bias, y);
    }

    /// Logical K.
    fn k(&self) -> usize;

    /// Logical N.
    fn n(&self) -> usize;

    /// Stored nonzeros.
    fn nnz(&self) -> usize;

    /// Exact format byte size (operational-intensity accounting).
    fn format_bytes(&self) -> usize;

    /// Whether PReLU is fused into `run`.
    fn fused_prelu(&self) -> bool {
        false
    }

    /// Whether `run_with_scratch` uses the padded-X scratch buffer (the
    /// planner pre-sizes scratch only for kernels that benefit).
    fn uses_padded_scratch(&self) -> bool {
        false
    }

    /// Whether `run_with_scratch` stages X through the reusable transposed
    /// tile buffer ([`GemmScratch::tile_x`]).
    fn uses_tile_scratch(&self) -> bool {
        false
    }

    /// Interleave group of the prepared format, for kernels built from an
    /// interleaved layout (`None` otherwise). Lets callers verify that
    /// [`KernelParams::group`] was honored.
    fn interleave_group(&self) -> Option<usize> {
        None
    }
}

/// Typed identity of a registry kernel. The dispatch currency of the
/// whole stack: tuning entries, plan-cache keys, planner candidates and
/// config overrides all carry a `KernelId`; strings appear only at the
/// parse/display boundary ([`KernelId::parse`] / [`KernelId::name`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum KernelId {
    BaseTcsc,
    UnrolledTcsc5,
    UnrolledTcsc12,
    UnrolledTcscK4M4,
    UnrolledBlockedTcscK4M4,
    InterleavedTcsc,
    InterleavedBlockedTcsc,
    CompressedTernary,
    CompressedTernaryBranch,
    InvertedIndex,
    SimdVertical,
    SimdHorizontal,
    SimdBlockedInterleaved,
    OuterProductTile,
    OuterProductTileSimd,
    DenseGemm,
}

impl KernelId {
    /// The descriptor row for this kernel.
    pub fn descriptor(self) -> &'static KernelDescriptor {
        descriptors()
            .iter()
            .find(|d| d.id == self)
            .expect("descriptor table covers every KernelId")
    }

    /// Registry name (the JSON / CLI / benchmark-table spelling).
    pub fn name(self) -> &'static str {
        self.descriptor().name
    }

    /// Resolve a registry name to its id (`None` for unknown names).
    pub fn parse(name: &str) -> Option<KernelId> {
        descriptors().iter().find(|d| d.name == name).map(|d| d.id)
    }

    /// Build the prepared GEMM for this kernel over dense ternary weights.
    ///
    /// # Errors
    /// [`Error::BadKernelParams`] when `params` fails validation; the
    /// descriptor constructors themselves are infallible.
    pub fn prepare(
        self,
        w: &TernaryMatrix,
        params: KernelParams,
    ) -> Result<Box<dyn PreparedGemm>> {
        params.validate()?;
        Ok((self.descriptor().constructor)(w, params))
    }
}

impl std::fmt::Display for KernelId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for KernelId {
    type Err = Error;

    fn from_str(s: &str) -> Result<KernelId> {
        KernelId::parse(s).ok_or_else(|| Error::UnknownKernel(s.to_string()))
    }
}

/// Paper lineage of a kernel (how the figures group the family).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelFamily {
    /// Scalar TCSC column walkers (base + unrolled variants, Figs 2/6).
    Tcsc,
    /// Cache-blocked K (Fig 5's tiling, scalar).
    Blocked,
    /// Interleaved index/sign streams (the paper's best scalar line).
    Interleaved,
    /// Symmetric-format SIMD kernels (Fig 11).
    Simd,
    /// Base-3 value packing (evaluated-and-dropped ablation).
    Compressed,
    /// Inverted row index (evaluated-and-dropped ablation).
    Inverted,
    /// Outer-product tile kernels over the tile-panel format — the
    /// matrix-unit orientation ("Above the Inner Loop").
    OuterProduct,
    /// Dense f32 reference GEMM.
    Dense,
}

/// Which batch regime a kernel is *specialized* for. Selection metadata,
/// not a correctness constraint — every kernel handles any M.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BatchAffinity {
    /// Single-row / latency specialist: wins at the GEMV end of Fig 2 and
    /// at the sparsest class, where there is nothing to amortize.
    Gemv,
    /// Needs rows to amortize per-batch overhead (the SIMD family's
    /// padded-X copy).
    Gemm,
    /// Performance-neutral in M (paper Fig 8).
    Any,
}

/// One row of the registry: a kernel's identity, capabilities and
/// constructor. The planner, autotune sweep, config validation and the
/// benches all derive their behavior from these fields.
pub struct KernelDescriptor {
    pub id: KernelId,
    /// Registry name (stable: JSON tuning tables are keyed by it).
    pub name: &'static str,
    pub family: KernelFamily,
    /// Can fold PReLU into the GEMM inner loop ([`KernelParams::prelu_alpha`]).
    pub supports_fused_prelu: bool,
    /// Honors [`KernelParams::group`].
    pub uses_group: bool,
    /// Paper-default interleave group when `uses_group` (else `None`).
    pub default_group: Option<usize>,
    /// Builds a K-blocked format (block size `min(K, 4096)`).
    pub uses_block: bool,
    /// `run_with_scratch` reads X through the reusable padded buffer.
    pub uses_padded_scratch: bool,
    /// `run_with_scratch` stages X through the reusable transposed tile
    /// buffer.
    pub uses_tile_scratch: bool,
    /// Honors [`KernelParams::geometry`] (panel width / K-slice of the
    /// tile-panel format) — the geometry axis the blocking policy, the
    /// plan-cache race and the `--geometry` sweep vary.
    pub geometry: bool,
    /// Vector (SIMD) kernel, vs scalar.
    pub simd: bool,
    /// CPU features this kernel's *selection* requires (empty = selectable
    /// anywhere). Gates candidate enumeration only — `prepare` is
    /// host-agnostic, so tests can construct gated kernels on any host.
    pub requires: &'static [CpuFeature],
    pub batch_affinity: BatchAffinity,
    /// Build the prepared GEMM. Infallible: [`KernelParams::validate`]
    /// runs before any constructor.
    constructor: fn(&TernaryMatrix, KernelParams) -> Box<dyn PreparedGemm>,
}

impl std::fmt::Debug for KernelDescriptor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("KernelDescriptor")
            .field("id", &self.id)
            .field("name", &self.name)
            .field("family", &self.family)
            .field("supports_fused_prelu", &self.supports_fused_prelu)
            .field("uses_group", &self.uses_group)
            .field("default_group", &self.default_group)
            .field("uses_block", &self.uses_block)
            .field("uses_padded_scratch", &self.uses_padded_scratch)
            .field("uses_tile_scratch", &self.uses_tile_scratch)
            .field("geometry", &self.geometry)
            .field("simd", &self.simd)
            .field("requires", &self.requires)
            .field("batch_affinity", &self.batch_affinity)
            .finish_non_exhaustive()
    }
}

// Trailing `with_group` marker opts in an `interleave_group` accessor for
// formats with a public `group` field.
macro_rules! typed_prepared {
    ($struct_name:ident, $fmt:ty, $kernel:expr, $name:expr $(, $with_group:ident)?) => {
        struct $struct_name {
            fmt: $fmt,
        }
        impl PreparedGemm for $struct_name {
            fn name(&self) -> &str {
                $name
            }
            fn run(&self, x: &Matrix, bias: &[f32], y: &mut Matrix) {
                $kernel.run(x, &self.fmt, bias, y);
            }
            fn k(&self) -> usize {
                self.fmt.k()
            }
            fn n(&self) -> usize {
                self.fmt.n()
            }
            fn nnz(&self) -> usize {
                self.fmt.nnz()
            }
            fn format_bytes(&self) -> usize {
                self.fmt.bytes()
            }
            $(
                fn interleave_group(&self) -> Option<usize> {
                    let _ = stringify!($with_group);
                    Some(self.fmt.group)
                }
            )?
        }
    };
}

typed_prepared!(PBase, Tcsc, BaseTcscKernel, "base_tcsc");
typed_prepared!(PUnrolled5, Tcsc, UnrolledTcscKernel::<5>, "unrolled_tcsc_5");
typed_prepared!(PUnrolled12, Tcsc, UnrolledTcscKernel::<12>, "unrolled_tcsc_12");
typed_prepared!(PUnrolledK4M4, Tcsc, UnrolledMKernel::<4, 4>, "unrolled_tcsc_k4_m4");
typed_prepared!(
    PBlocked,
    BlockedTcsc,
    UnrolledBlockedKernel::<4, 4>,
    "unrolled_blocked_tcsc_k4_m4"
);
typed_prepared!(
    PInterleaved,
    InterleavedTcsc,
    InterleavedKernel::<4>,
    "interleaved_tcsc",
    with_group
);
typed_prepared!(
    PInterleavedBlocked,
    InterleavedBlockedTcsc,
    InterleavedBlockedKernel::<4>,
    "interleaved_blocked_tcsc",
    with_group
);
typed_prepared!(PCompressed, CompressedTernary, CompressedKernel, "compressed_ternary");
typed_prepared!(
    PCompressedBranch,
    CompressedTernary,
    crate::kernels::compressed::CompressedKernelBranch,
    "compressed_ternary_branch"
);
typed_prepared!(PInverted, InvertedIndex, InvertedKernel, "inverted_index");
typed_prepared!(POuterTile, TilePanelTcsc, OuterTileKernel, "outer_product_tile");

struct POuterSimd {
    fmt: TilePanelTcsc,
    kernel: OuterTileSimdKernel,
}

impl PreparedGemm for POuterSimd {
    fn name(&self) -> &str {
        "outer_product_tile_simd"
    }
    fn run(&self, x: &Matrix, bias: &[f32], y: &mut Matrix) {
        // One-shot path: stages the transposed X tile in a fresh buffer.
        // The planned path below reuses the caller's scratch instead.
        self.kernel.run(x, &self.fmt, bias, y);
    }
    fn run_with_scratch(
        &self,
        x: &Matrix,
        bias: &[f32],
        y: &mut Matrix,
        scratch: &mut GemmScratch,
    ) {
        self.kernel.run_with_buf(x, &self.fmt, bias, y, scratch.tile_x());
    }
    fn k(&self) -> usize {
        self.fmt.k()
    }
    fn n(&self) -> usize {
        self.fmt.n()
    }
    fn nnz(&self) -> usize {
        self.fmt.nnz()
    }
    fn format_bytes(&self) -> usize {
        self.fmt.bytes()
    }
    fn uses_tile_scratch(&self) -> bool {
        true
    }
}

struct PDense {
    gemm: DenseGemm,
    k: usize,
    n: usize,
    nnz: usize,
}

impl PreparedGemm for PDense {
    fn name(&self) -> &str {
        "dense_gemm"
    }
    fn run(&self, x: &Matrix, bias: &[f32], y: &mut Matrix) {
        self.gemm.run(x, bias, y);
    }
    fn k(&self) -> usize {
        self.k
    }
    fn n(&self) -> usize {
        self.n
    }
    fn nnz(&self) -> usize {
        self.nnz
    }
    fn format_bytes(&self) -> usize {
        self.k * self.n * std::mem::size_of::<f32>()
    }
}

struct PSimd<K> {
    fmt: SymmetricTcsc,
    kernel: K,
    name: &'static str,
    prelu: bool,
}

impl PreparedGemm for PSimd<VerticalSimdKernel> {
    fn name(&self) -> &str {
        self.name
    }
    fn run(&self, x: &Matrix, bias: &[f32], y: &mut Matrix) {
        // One-shot path: pads X fresh. The planned path below reuses the
        // caller's scratch instead.
        let padded = PaddedMatrix::from_matrix(x);
        self.kernel.run_padded(&padded, &self.fmt, bias, y);
    }
    fn run_with_scratch(
        &self,
        x: &Matrix,
        bias: &[f32],
        y: &mut Matrix,
        scratch: &mut GemmScratch,
    ) {
        self.kernel.run_padded(scratch.padded_x(x), &self.fmt, bias, y);
    }
    fn k(&self) -> usize {
        self.fmt.k()
    }
    fn n(&self) -> usize {
        self.fmt.n()
    }
    fn nnz(&self) -> usize {
        self.fmt.nnz()
    }
    fn format_bytes(&self) -> usize {
        self.fmt.bytes()
    }
    fn fused_prelu(&self) -> bool {
        self.prelu
    }
    fn uses_padded_scratch(&self) -> bool {
        true
    }
}

impl PreparedGemm for PSimd<HorizontalSimdKernel> {
    fn name(&self) -> &str {
        self.name
    }
    fn run(&self, x: &Matrix, bias: &[f32], y: &mut Matrix) {
        let padded = PaddedMatrix::from_matrix(x);
        self.kernel.run_padded(&padded, &self.fmt, bias, y);
    }
    fn run_with_scratch(
        &self,
        x: &Matrix,
        bias: &[f32],
        y: &mut Matrix,
        scratch: &mut GemmScratch,
    ) {
        self.kernel.run_padded(scratch.padded_x(x), &self.fmt, bias, y);
    }
    fn k(&self) -> usize {
        self.fmt.k()
    }
    fn n(&self) -> usize {
        self.fmt.n()
    }
    fn nnz(&self) -> usize {
        self.fmt.nnz()
    }
    fn format_bytes(&self) -> usize {
        self.fmt.bytes()
    }
    fn fused_prelu(&self) -> bool {
        self.prelu
    }
    fn uses_padded_scratch(&self) -> bool {
        true
    }
}

struct PSimdBlocked {
    fmt: InterleavedBlockedTcsc,
    kernel: SimdBlockedMnKernel,
    prelu: bool,
}

impl PreparedGemm for PSimdBlocked {
    fn name(&self) -> &str {
        "simd_blocked_interleaved"
    }
    fn run(&self, x: &Matrix, bias: &[f32], y: &mut Matrix) {
        self.kernel.run(x, &self.fmt, bias, y);
    }
    fn k(&self) -> usize {
        self.fmt.k()
    }
    fn n(&self) -> usize {
        self.fmt.n()
    }
    fn nnz(&self) -> usize {
        self.fmt.nnz()
    }
    fn format_bytes(&self) -> usize {
        self.fmt.bytes()
    }
    fn fused_prelu(&self) -> bool {
        self.prelu
    }
    fn interleave_group(&self) -> Option<usize> {
        Some(self.fmt.group)
    }
}

// ---- descriptor constructors (one per table row, all infallible) ----------

fn build_base(w: &TernaryMatrix, _p: KernelParams) -> Box<dyn PreparedGemm> {
    Box::new(PBase {
        fmt: Tcsc::from_ternary(w),
    })
}

fn build_unrolled5(w: &TernaryMatrix, _p: KernelParams) -> Box<dyn PreparedGemm> {
    Box::new(PUnrolled5 {
        fmt: Tcsc::from_ternary(w),
    })
}

fn build_unrolled12(w: &TernaryMatrix, _p: KernelParams) -> Box<dyn PreparedGemm> {
    Box::new(PUnrolled12 {
        fmt: Tcsc::from_ternary(w),
    })
}

fn build_unrolled_k4_m4(w: &TernaryMatrix, _p: KernelParams) -> Box<dyn PreparedGemm> {
    Box::new(PUnrolledK4M4 {
        fmt: Tcsc::from_ternary(w),
    })
}

fn build_unrolled_blocked(w: &TernaryMatrix, p: KernelParams) -> Box<dyn PreparedGemm> {
    Box::new(PBlocked {
        fmt: BlockedTcsc::from_ternary(w, p.effective_block(w.k())),
    })
}

fn build_interleaved(w: &TernaryMatrix, p: KernelParams) -> Box<dyn PreparedGemm> {
    Box::new(PInterleaved {
        fmt: InterleavedTcsc::from_ternary(w, p.interleave_group()),
    })
}

fn build_interleaved_blocked(w: &TernaryMatrix, p: KernelParams) -> Box<dyn PreparedGemm> {
    Box::new(PInterleavedBlocked {
        fmt: InterleavedBlockedTcsc::from_ternary(w, p.effective_block(w.k()), p.blocked_group()),
    })
}

fn build_compressed(w: &TernaryMatrix, _p: KernelParams) -> Box<dyn PreparedGemm> {
    Box::new(PCompressed {
        fmt: CompressedTernary::from_ternary(w),
    })
}

fn build_compressed_branch(w: &TernaryMatrix, _p: KernelParams) -> Box<dyn PreparedGemm> {
    Box::new(PCompressedBranch {
        fmt: CompressedTernary::from_ternary(w),
    })
}

fn build_inverted(w: &TernaryMatrix, _p: KernelParams) -> Box<dyn PreparedGemm> {
    Box::new(PInverted {
        fmt: InvertedIndex::from_ternary(w),
    })
}

fn build_simd_vertical(w: &TernaryMatrix, p: KernelParams) -> Box<dyn PreparedGemm> {
    Box::new(PSimd {
        fmt: SymmetricTcsc::from_ternary(w),
        kernel: VerticalSimdKernel::new(p.prelu_alpha),
        name: "simd_vertical",
        prelu: p.prelu_alpha.is_some(),
    })
}

fn build_simd_horizontal(w: &TernaryMatrix, p: KernelParams) -> Box<dyn PreparedGemm> {
    Box::new(PSimd {
        fmt: SymmetricTcsc::from_ternary(w),
        kernel: HorizontalSimdKernel::new(p.prelu_alpha),
        name: "simd_horizontal",
        prelu: p.prelu_alpha.is_some(),
    })
}

fn build_simd_blocked(w: &TernaryMatrix, p: KernelParams) -> Box<dyn PreparedGemm> {
    Box::new(PSimdBlocked {
        fmt: InterleavedBlockedTcsc::from_ternary(w, p.effective_block(w.k()), p.blocked_group()),
        kernel: SimdBlockedMnKernel::new(p.prelu_alpha),
        prelu: p.prelu_alpha.is_some(),
    })
}

fn build_outer_tile(w: &TernaryMatrix, p: KernelParams) -> Box<dyn PreparedGemm> {
    Box::new(POuterTile {
        fmt: TilePanelTcsc::from_ternary_with(w, p.tile_geometry()),
    })
}

fn build_outer_tile_simd(w: &TernaryMatrix, p: KernelParams) -> Box<dyn PreparedGemm> {
    Box::new(POuterSimd {
        fmt: TilePanelTcsc::from_ternary_with(w, p.tile_geometry()),
        kernel: OuterTileSimdKernel,
    })
}

fn build_dense(w: &TernaryMatrix, _p: KernelParams) -> Box<dyn PreparedGemm> {
    Box::new(PDense {
        gemm: DenseGemm::new(w),
        k: w.k(),
        n: w.n(),
        nnz: w.nnz(),
    })
}

/// The registry table, in canonical benchmark order. **Adding a kernel is
/// one `KernelId` variant plus one row here** — enumeration, dispatch,
/// validation and the planner's candidate filters all derive from it.
static DESCRIPTORS: [KernelDescriptor; 16] = [
    KernelDescriptor {
        id: KernelId::BaseTcsc,
        name: "base_tcsc",
        family: KernelFamily::Tcsc,
        supports_fused_prelu: false,
        uses_group: false,
        default_group: None,
        uses_block: false,
        uses_padded_scratch: false,
        uses_tile_scratch: false,
        geometry: false,
        simd: false,
        requires: &[],
        batch_affinity: BatchAffinity::Any,
        constructor: build_base,
    },
    KernelDescriptor {
        id: KernelId::UnrolledTcsc5,
        name: "unrolled_tcsc_5",
        family: KernelFamily::Tcsc,
        supports_fused_prelu: false,
        uses_group: false,
        default_group: None,
        uses_block: false,
        uses_padded_scratch: false,
        uses_tile_scratch: false,
        geometry: false,
        simd: false,
        requires: &[],
        batch_affinity: BatchAffinity::Any,
        constructor: build_unrolled5,
    },
    KernelDescriptor {
        id: KernelId::UnrolledTcsc12,
        name: "unrolled_tcsc_12",
        family: KernelFamily::Tcsc,
        supports_fused_prelu: false,
        uses_group: false,
        default_group: None,
        uses_block: false,
        uses_padded_scratch: false,
        uses_tile_scratch: false,
        geometry: false,
        simd: false,
        requires: &[],
        batch_affinity: BatchAffinity::Any,
        constructor: build_unrolled12,
    },
    KernelDescriptor {
        id: KernelId::UnrolledTcscK4M4,
        name: "unrolled_tcsc_k4_m4",
        family: KernelFamily::Tcsc,
        supports_fused_prelu: false,
        uses_group: false,
        default_group: None,
        uses_block: false,
        uses_padded_scratch: false,
        uses_tile_scratch: false,
        geometry: false,
        simd: false,
        requires: &[],
        // Fig 2's GEMV-end winner and the sparsest-class pick: nothing to
        // amortize, so the plain K/M-unrolled walk wins.
        batch_affinity: BatchAffinity::Gemv,
        constructor: build_unrolled_k4_m4,
    },
    KernelDescriptor {
        id: KernelId::UnrolledBlockedTcscK4M4,
        name: "unrolled_blocked_tcsc_k4_m4",
        family: KernelFamily::Blocked,
        supports_fused_prelu: false,
        uses_group: false,
        default_group: None,
        uses_block: true,
        uses_padded_scratch: false,
        uses_tile_scratch: false,
        geometry: false,
        simd: false,
        requires: &[],
        batch_affinity: BatchAffinity::Any,
        constructor: build_unrolled_blocked,
    },
    KernelDescriptor {
        id: KernelId::InterleavedTcsc,
        name: "interleaved_tcsc",
        family: KernelFamily::Interleaved,
        supports_fused_prelu: false,
        uses_group: true,
        default_group: Some(crate::PAPER_GROUP_SIZE),
        uses_block: false,
        uses_padded_scratch: false,
        uses_tile_scratch: false,
        geometry: false,
        simd: false,
        requires: &[],
        batch_affinity: BatchAffinity::Any,
        constructor: build_interleaved,
    },
    KernelDescriptor {
        id: KernelId::InterleavedBlockedTcsc,
        name: "interleaved_blocked_tcsc",
        family: KernelFamily::Interleaved,
        supports_fused_prelu: false,
        uses_group: true,
        default_group: Some(crate::PAPER_BLOCKED_GROUP),
        uses_block: true,
        uses_padded_scratch: false,
        uses_tile_scratch: false,
        geometry: false,
        simd: false,
        requires: &[],
        batch_affinity: BatchAffinity::Any,
        constructor: build_interleaved_blocked,
    },
    KernelDescriptor {
        id: KernelId::CompressedTernary,
        name: "compressed_ternary",
        family: KernelFamily::Compressed,
        supports_fused_prelu: false,
        uses_group: false,
        default_group: None,
        uses_block: false,
        uses_padded_scratch: false,
        uses_tile_scratch: false,
        geometry: false,
        simd: false,
        requires: &[],
        batch_affinity: BatchAffinity::Any,
        constructor: build_compressed,
    },
    KernelDescriptor {
        id: KernelId::CompressedTernaryBranch,
        name: "compressed_ternary_branch",
        family: KernelFamily::Compressed,
        supports_fused_prelu: false,
        uses_group: false,
        default_group: None,
        uses_block: false,
        uses_padded_scratch: false,
        uses_tile_scratch: false,
        geometry: false,
        simd: false,
        requires: &[],
        batch_affinity: BatchAffinity::Any,
        constructor: build_compressed_branch,
    },
    KernelDescriptor {
        id: KernelId::InvertedIndex,
        name: "inverted_index",
        family: KernelFamily::Inverted,
        supports_fused_prelu: false,
        uses_group: false,
        default_group: None,
        uses_block: false,
        uses_padded_scratch: false,
        uses_tile_scratch: false,
        geometry: false,
        simd: false,
        requires: &[],
        batch_affinity: BatchAffinity::Any,
        constructor: build_inverted,
    },
    KernelDescriptor {
        id: KernelId::SimdVertical,
        name: "simd_vertical",
        family: KernelFamily::Simd,
        supports_fused_prelu: true,
        uses_group: false,
        default_group: None,
        uses_block: false,
        uses_padded_scratch: true,
        uses_tile_scratch: false,
        geometry: false,
        simd: true,
        requires: &[],
        batch_affinity: BatchAffinity::Gemm,
        constructor: build_simd_vertical,
    },
    KernelDescriptor {
        id: KernelId::SimdHorizontal,
        name: "simd_horizontal",
        family: KernelFamily::Simd,
        supports_fused_prelu: true,
        uses_group: false,
        default_group: None,
        uses_block: false,
        uses_padded_scratch: true,
        uses_tile_scratch: false,
        geometry: false,
        simd: true,
        requires: &[],
        batch_affinity: BatchAffinity::Gemm,
        constructor: build_simd_horizontal,
    },
    KernelDescriptor {
        id: KernelId::SimdBlockedInterleaved,
        name: "simd_blocked_interleaved",
        family: KernelFamily::Simd,
        supports_fused_prelu: true,
        uses_group: true,
        default_group: Some(crate::PAPER_BLOCKED_GROUP),
        uses_block: true,
        uses_padded_scratch: false,
        uses_tile_scratch: false,
        geometry: false,
        simd: true,
        requires: &[],
        batch_affinity: BatchAffinity::Gemm,
        constructor: build_simd_blocked,
    },
    KernelDescriptor {
        id: KernelId::OuterProductTile,
        name: "outer_product_tile",
        family: KernelFamily::OuterProduct,
        supports_fused_prelu: false,
        uses_group: false,
        default_group: None,
        uses_block: false,
        uses_padded_scratch: false,
        uses_tile_scratch: false,
        geometry: true,
        simd: false,
        // Portable tile emulation: selectable anywhere, so the family's
        // bitwise-identity properties run on every CI host.
        requires: &[],
        batch_affinity: BatchAffinity::Gemm,
        constructor: build_outer_tile,
    },
    KernelDescriptor {
        id: KernelId::OuterProductTileSimd,
        name: "outer_product_tile_simd",
        family: KernelFamily::OuterProduct,
        supports_fused_prelu: false,
        uses_group: false,
        default_group: None,
        uses_block: false,
        uses_padded_scratch: false,
        uses_tile_scratch: true,
        geometry: true,
        simd: true,
        // The vector-register tile layout only wins with a real 128-bit
        // unit behind it; selection is gated, construction is not.
        requires: &[CpuFeature::Neon],
        batch_affinity: BatchAffinity::Gemm,
        constructor: build_outer_tile_simd,
    },
    KernelDescriptor {
        id: KernelId::DenseGemm,
        name: "dense_gemm",
        family: KernelFamily::Dense,
        supports_fused_prelu: false,
        uses_group: false,
        default_group: None,
        uses_block: false,
        uses_padded_scratch: false,
        uses_tile_scratch: false,
        geometry: false,
        simd: false,
        requires: &[],
        batch_affinity: BatchAffinity::Any,
        constructor: build_dense,
    },
];

/// Every descriptor, in canonical benchmark order.
pub fn descriptors() -> &'static [KernelDescriptor] {
    &DESCRIPTORS
}

/// All registry kernel names, in canonical benchmark order (derived from
/// the descriptor table).
pub fn kernel_names() -> &'static [&'static str] {
    static NAMES: OnceLock<Vec<&'static str>> = OnceLock::new();
    NAMES.get_or_init(|| DESCRIPTORS.iter().map(|d| d.name).collect())
}

/// All registry kernel ids, in canonical benchmark order.
pub fn kernel_ids() -> &'static [KernelId] {
    static IDS: OnceLock<Vec<KernelId>> = OnceLock::new();
    IDS.get_or_init(|| DESCRIPTORS.iter().map(|d| d.id).collect())
}

/// First kernel in canonical order whose descriptor satisfies `pred` —
/// the derived-query primitive behind the planner's candidate selection.
pub fn first_matching(pred: impl Fn(&KernelDescriptor) -> bool) -> Option<KernelId> {
    DESCRIPTORS.iter().find(|d| pred(d)).map(|d| d.id)
}

/// The scalar single-row specialist (Fig 2's GEMV end): the kernel for
/// the sparsest class and the M=1 rival in the planner's top-2 race.
pub fn gemv_specialist() -> KernelId {
    first_matching(|d| d.batch_affinity == BatchAffinity::Gemv && !d.simd)
        .expect("descriptor table declares a scalar GEMV specialist")
}

/// The paper's best scalar kernel (Figs 6–9): blocked + interleaved,
/// no SIMD.
pub fn best_scalar() -> KernelId {
    first_matching(|d| d.uses_block && d.uses_group && !d.simd)
        .expect("descriptor table declares a blocked interleaved scalar kernel")
}

/// The preferred fused-PReLU SIMD kernel (Fig 11): vector, fuses the
/// activation, no blocking machinery to amortize.
pub fn fused_simd() -> KernelId {
    first_matching(|d| d.simd && d.supports_fused_prelu && !d.uses_block)
        .expect("descriptor table declares a fusing SIMD kernel")
}

/// Kernels whose `requires` list `caps` satisfies, in canonical order —
/// the capability-filtered enumeration behind planner candidate sets,
/// sweep grids and the online top-2 race.
pub fn available_ids(caps: &CpuCaps) -> Vec<KernelId> {
    DESCRIPTORS
        .iter()
        .filter(|d| caps.satisfies(d.requires))
        .map(|d| d.id)
        .collect()
}

/// [`available_ids`] for the host CPU, computed once per process.
pub fn available_kernel_ids() -> &'static [KernelId] {
    static IDS: OnceLock<Vec<KernelId>> = OnceLock::new();
    IDS.get_or_init(|| available_ids(&CpuCaps::host()))
}

/// The outer-product (matrix-unit orientation) pick for `caps`: the SIMD
/// tile kernel where its capability is present, else the portable scalar
/// tile emulation. `None` only if the whole family were gated off.
pub fn matrix_tile(caps: &CpuCaps) -> Option<KernelId> {
    first_matching(|d| {
        d.family == KernelFamily::OuterProduct && d.simd && caps.satisfies(d.requires)
    })
    .or_else(|| {
        first_matching(|d| {
            d.family == KernelFamily::OuterProduct && !d.simd && caps.satisfies(d.requires)
        })
    })
}

/// Build a prepared kernel by registry **name** — the boundary for
/// name-keyed callers (benches, CLI flags). Typed callers use
/// [`KernelId::prepare`] directly.
///
/// # Errors
/// [`Error::UnknownKernel`] for unregistered names,
/// [`Error::BadKernelParams`] for invalid params.
pub fn prepare_kernel(
    name: &str,
    w: &TernaryMatrix,
    params: KernelParams,
) -> Result<Box<dyn PreparedGemm>> {
    name.parse::<KernelId>()?.prepare(w, params)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{dense_oracle, prelu_inplace};

    #[test]
    fn every_registry_kernel_matches_oracle() {
        let w = TernaryMatrix::random(96, 24, 0.25, 131);
        let x = Matrix::random(8, 96, 132);
        let bias: Vec<f32> = (0..24).map(|i| 0.1 * i as f32).collect();
        let oracle = dense_oracle(&x, &w, &bias);
        for &name in kernel_names() {
            let kern = prepare_kernel(name, &w, KernelParams::default()).unwrap();
            assert_eq!(kern.k(), 96);
            assert_eq!(kern.n(), 24);
            let mut y = Matrix::zeros(8, 24);
            kern.run(&x, &bias, &mut y);
            assert!(y.allclose(&oracle, 1e-3), "kernel {name}");
        }
    }

    #[test]
    fn descriptor_table_is_consistent() {
        // Names and ids are unique; the derived enumerations match the
        // table exactly; names round-trip through parse/Display.
        let ds = descriptors();
        for (i, d) in ds.iter().enumerate() {
            assert_eq!(d.id.descriptor().name, d.name);
            assert_eq!(KernelId::parse(d.name), Some(d.id), "{}", d.name);
            assert_eq!(d.name.parse::<KernelId>().unwrap(), d.id);
            assert_eq!(d.id.to_string(), d.name);
            for other in &ds[i + 1..] {
                assert_ne!(d.name, other.name, "duplicate kernel name");
                assert_ne!(d.id, other.id, "duplicate kernel id");
            }
        }
        let derived: Vec<&str> = ds.iter().map(|d| d.name).collect();
        assert_eq!(kernel_names(), derived.as_slice());
        let ids: Vec<KernelId> = ds.iter().map(|d| d.id).collect();
        assert_eq!(kernel_ids(), ids.as_slice());
        assert_eq!(
            KernelId::parse("nope"),
            None,
            "unknown names must not resolve"
        );
        assert_eq!(
            "nope".parse::<KernelId>(),
            Err(Error::UnknownKernel("nope".into()))
        );
    }

    #[test]
    fn capability_roles_resolve_to_paper_picks() {
        // The planner's derived candidate queries must land on the paper's
        // kernels; if a new descriptor accidentally matches a role filter
        // first, the heuristics silently change — this pins them.
        assert_eq!(gemv_specialist(), KernelId::UnrolledTcscK4M4);
        assert_eq!(best_scalar(), KernelId::InterleavedBlockedTcsc);
        assert_eq!(fused_simd(), KernelId::SimdVertical);
    }

    // Declared-capability vs runtime-behavior consistency is covered by
    // the random-shape property test in rust/tests/prop_kernels.rs
    // (prop_descriptor_capabilities_match_runtime_on_random_shapes).

    #[test]
    fn prelu_param_fuses() {
        let w = TernaryMatrix::random(64, 16, 0.5, 7);
        let x = Matrix::random(4, 64, 8);
        let bias = vec![0.0f32; 16];
        let mut oracle = dense_oracle(&x, &w, &bias);
        prelu_inplace(&mut oracle, 0.25);
        let params = KernelParams {
            prelu_alpha: Some(0.25),
            ..Default::default()
        };
        // Derived query: every kernel declaring fusion support fuses and
        // still matches the oracle.
        let fusing: Vec<KernelId> = descriptors()
            .iter()
            .filter(|d| d.supports_fused_prelu)
            .map(|d| d.id)
            .collect();
        assert_eq!(fusing.len(), 3, "the SIMD family fuses");
        for id in fusing {
            let kern = id.prepare(&w, params).unwrap();
            assert!(kern.fused_prelu());
            let mut y = Matrix::zeros(4, 16);
            kern.run(&x, &bias, &mut y);
            assert!(y.allclose(&oracle, 1e-4), "kernel {id}");
        }
    }

    #[test]
    fn unknown_kernel_and_bad_params_are_typed_errors() {
        let w = TernaryMatrix::random(8, 8, 0.5, 1);
        assert_eq!(
            prepare_kernel("nope", &w, KernelParams::default()).err(),
            Some(Error::UnknownKernel("nope".into()))
        );
        let bad = KernelParams {
            group: Some(0),
            ..Default::default()
        };
        assert!(matches!(
            KernelId::InterleavedTcsc.prepare(&w, bad),
            Err(Error::BadKernelParams(_))
        ));
    }

    #[test]
    fn group_param_is_threaded_through() {
        let w = TernaryMatrix::random(96, 24, 0.25, 17);
        let x = Matrix::random(5, 96, 18);
        let bias: Vec<f32> = (0..24).map(|i| 0.05 * i as f32).collect();
        let oracle = dense_oracle(&x, &w, &bias);
        // Paper defaults when no group is given.
        for (id, want) in [
            (KernelId::InterleavedTcsc, crate::PAPER_GROUP_SIZE),
            (KernelId::InterleavedBlockedTcsc, crate::PAPER_BLOCKED_GROUP),
            (KernelId::SimdBlockedInterleaved, crate::PAPER_BLOCKED_GROUP),
        ] {
            let kern = id.prepare(&w, KernelParams::default()).unwrap();
            assert_eq!(kern.interleave_group(), Some(want), "{id} default");
        }
        // Explicit groups are honored by every interleaving kernel
        // (derived from the descriptor table) and stay correct.
        for g in [1usize, 3, 4] {
            let params = KernelParams {
                group: Some(g),
                ..Default::default()
            };
            for d in descriptors().iter().filter(|d| d.uses_group) {
                let kern = d.id.prepare(&w, params).unwrap();
                assert_eq!(kern.interleave_group(), Some(g), "{} g={g}", d.name);
                let mut y = Matrix::zeros(5, 24);
                kern.run(&x, &bias, &mut y);
                assert!(y.allclose(&oracle, 1e-3), "{} g={g}", d.name);
            }
        }
    }

    #[test]
    fn scratch_path_matches_and_reuses_allocation() {
        let w = TernaryMatrix::random(64, 20, 0.25, 55);
        let x = Matrix::random(6, 64, 56);
        let bias = vec![0.1f32; 20];
        for d in descriptors() {
            let kern = d.id.prepare(&w, KernelParams::default()).unwrap();
            let mut y_plain = Matrix::zeros(6, 20);
            kern.run(&x, &bias, &mut y_plain);
            let mut scratch = GemmScratch::new();
            let mut y_scratch = Matrix::zeros(6, 20);
            kern.run_with_scratch(&x, &bias, &mut y_scratch, &mut scratch);
            assert_eq!(
                y_plain, y_scratch,
                "{} scratch path must be bitwise equal",
                d.name
            );
            // Repeated calls must not grow the scratch.
            let cap = scratch.padded_capacity();
            let tile_cap = scratch.tile_capacity();
            for _ in 0..3 {
                kern.run_with_scratch(&x, &bias, &mut y_scratch, &mut scratch);
            }
            assert_eq!(scratch.padded_capacity(), cap, "{}", d.name);
            assert_eq!(scratch.tile_capacity(), tile_cap, "{}", d.name);
            if d.uses_padded_scratch {
                assert_eq!(cap, 6 * 65, "{} pads X into scratch", d.name);
            } else {
                assert_eq!(cap, 0, "{} needs no padded scratch", d.name);
            }
            if d.uses_tile_scratch {
                assert!(
                    tile_cap >= 64 * crate::formats::OUTER_TILE,
                    "{} stages the transposed tile in scratch",
                    d.name
                );
            } else {
                assert_eq!(tile_cap, 0, "{} needs no tile scratch", d.name);
            }
        }
    }

    #[test]
    fn scratch_reserve_tile_presizes() {
        let mut scratch = GemmScratch::new();
        scratch.reserve_tile(100);
        let cap = scratch.tile_capacity();
        assert!(cap >= 100 * crate::formats::OUTER_TILE);
        scratch.reserve_tile(50); // smaller K must not shrink or realloc
        assert_eq!(scratch.tile_capacity(), cap);
    }

    #[test]
    fn capability_gated_kernels_follow_caps() {
        let scalar = CpuCaps::scalar_only();
        let avail = available_ids(&scalar);
        // Exactly the rows with an empty requires list survive the
        // weakest host.
        for d in descriptors() {
            assert_eq!(
                avail.contains(&d.id),
                d.requires.is_empty(),
                "{}",
                d.name
            );
        }
        assert!(avail.contains(&KernelId::OuterProductTile));
        assert!(!avail.contains(&KernelId::OuterProductTileSimd));
        // A NEON + matrix-unit host sees the full table.
        assert_eq!(available_ids(&CpuCaps::apple_like()), kernel_ids());
        // The cached host enumeration agrees with the host snapshot.
        let host = available_ids(&CpuCaps::host());
        assert_eq!(available_kernel_ids(), host.as_slice());
    }

    #[test]
    fn capability_gated_matrix_tile_pick() {
        assert_eq!(
            matrix_tile(&CpuCaps::apple_like()),
            Some(KernelId::OuterProductTileSimd)
        );
        assert_eq!(
            matrix_tile(&CpuCaps::scalar_only()),
            Some(KernelId::OuterProductTile)
        );
    }

    #[test]
    fn geometry_axis_is_declared_and_threaded() {
        // Exactly the outer-product family declares the geometry axis.
        for d in descriptors() {
            assert_eq!(
                d.geometry,
                d.family == KernelFamily::OuterProduct,
                "{}",
                d.name
            );
        }
        // Every declared geometry builds and is bitwise-identical to the
        // default-geometry build — geometry moves memory, never results.
        let w = TernaryMatrix::random(96, 24, 0.25, 211);
        let x = Matrix::random(6, 96, 212);
        let bias: Vec<f32> = (0..24).map(|i| 0.2 * i as f32).collect();
        for d in descriptors().iter().filter(|d| d.geometry) {
            let default = d.id.prepare(&w, KernelParams::default()).unwrap();
            let mut y_default = Matrix::zeros(6, 24);
            default.run(&x, &bias, &mut y_default);
            for g in [
                TileGeometry::new(8, 0),
                TileGeometry::new(4, 16),
                TileGeometry::new(8, 4096),
            ] {
                let params = KernelParams {
                    geometry: Some(g),
                    ..Default::default()
                };
                let kern = d.id.prepare(&w, params).unwrap();
                let mut y = Matrix::zeros(6, 24);
                kern.run(&x, &bias, &mut y);
                assert_eq!(y, y_default, "{} {g}", d.name);
            }
        }
        // Unsupported panel widths are typed errors at the validation
        // boundary, like every other bad parameter.
        let bad = KernelParams {
            geometry: Some(TileGeometry::new(5, 0)),
            ..Default::default()
        };
        assert!(matches!(
            KernelId::OuterProductTile.prepare(&w, bad),
            Err(Error::BadKernelParams(_))
        ));
    }

    #[test]
    fn effective_block_follows_paper_rule() {
        let p = KernelParams::default();
        assert_eq!(p.effective_block(1024), 1024);
        assert_eq!(p.effective_block(16384), 4096);
    }
}
