//! Kernel registry: build a *prepared* GEMM (format constructed, kernel
//! bound) from a kernel name + dense ternary weights. This is the dispatch
//! surface the serving engine, CLI and benches share.

use crate::formats::{
    BlockedTcsc, CompressedTernary, InterleavedBlockedTcsc, InterleavedTcsc, InvertedIndex,
    SparseFormat, SymmetricTcsc, Tcsc,
};
use crate::kernels::simd::{HorizontalSimdKernel, SimdBlockedMnKernel, VerticalSimdKernel};
use crate::kernels::{
    BaseTcscKernel, CompressedKernel, DenseGemm, InterleavedBlockedKernel, InterleavedKernel,
    InvertedKernel, Kernel, UnrolledBlockedKernel, UnrolledMKernel, UnrolledTcscKernel,
};
use crate::tensor::{Matrix, PaddedMatrix};
use crate::ternary::TernaryMatrix;

/// Parameters a kernel build may consume (paper defaults).
#[derive(Debug, Clone, Copy)]
pub struct KernelParams {
    /// Block size for blocked formats; the paper's rule is `min(K, 4096)`.
    pub block_size: usize,
    /// Interleave group size (indices per sign).
    pub group: usize,
    /// PReLU slope for kernels that fuse activation; `None` = no activation.
    pub prelu_alpha: Option<f32>,
}

impl Default for KernelParams {
    fn default() -> Self {
        KernelParams {
            block_size: crate::PAPER_BLOCK_SIZE,
            group: crate::PAPER_GROUP_SIZE,
            prelu_alpha: None,
        }
    }
}

impl KernelParams {
    /// Paper rule: block size `min(K, 4096)`.
    pub fn effective_block(&self, k: usize) -> usize {
        self.block_size.min(k.max(1))
    }
}

/// A kernel bound to its prepared format: the serving-time object.
pub trait PreparedGemm: Send + Sync {
    /// Registry name.
    fn name(&self) -> &str;

    /// Compute `Y = X·W + b` (+ fused activation where supported).
    fn run(&self, x: &Matrix, bias: &[f32], y: &mut Matrix);

    /// Logical K.
    fn k(&self) -> usize;

    /// Logical N.
    fn n(&self) -> usize;

    /// Stored nonzeros.
    fn nnz(&self) -> usize;

    /// Exact format byte size (operational-intensity accounting).
    fn format_bytes(&self) -> usize;

    /// Whether PReLU is fused into `run`.
    fn fused_prelu(&self) -> bool {
        false
    }
}

macro_rules! typed_prepared {
    ($struct_name:ident, $fmt:ty, $kernel:expr, $name:expr) => {
        struct $struct_name {
            fmt: $fmt,
        }
        impl PreparedGemm for $struct_name {
            fn name(&self) -> &str {
                $name
            }
            fn run(&self, x: &Matrix, bias: &[f32], y: &mut Matrix) {
                $kernel.run(x, &self.fmt, bias, y);
            }
            fn k(&self) -> usize {
                self.fmt.k()
            }
            fn n(&self) -> usize {
                self.fmt.n()
            }
            fn nnz(&self) -> usize {
                self.fmt.nnz()
            }
            fn format_bytes(&self) -> usize {
                self.fmt.bytes()
            }
        }
    };
}

typed_prepared!(PBase, Tcsc, BaseTcscKernel, "base_tcsc");
typed_prepared!(PUnrolled5, Tcsc, UnrolledTcscKernel::<5>, "unrolled_tcsc_5");
typed_prepared!(PUnrolled12, Tcsc, UnrolledTcscKernel::<12>, "unrolled_tcsc_12");
typed_prepared!(PUnrolledK4M4, Tcsc, UnrolledMKernel::<4, 4>, "unrolled_tcsc_k4_m4");
typed_prepared!(
    PBlocked,
    BlockedTcsc,
    UnrolledBlockedKernel::<4, 4>,
    "unrolled_blocked_tcsc_k4_m4"
);
typed_prepared!(PInterleaved, InterleavedTcsc, InterleavedKernel::<4>, "interleaved_tcsc");
typed_prepared!(
    PInterleavedBlocked,
    InterleavedBlockedTcsc,
    InterleavedBlockedKernel::<4>,
    "interleaved_blocked_tcsc"
);
typed_prepared!(PCompressed, CompressedTernary, CompressedKernel, "compressed_ternary");
typed_prepared!(
    PCompressedBranch,
    CompressedTernary,
    crate::kernels::compressed::CompressedKernelBranch,
    "compressed_ternary_branch"
);
typed_prepared!(PInverted, InvertedIndex, InvertedKernel, "inverted_index");

struct PDense {
    gemm: DenseGemm,
    k: usize,
    n: usize,
    nnz: usize,
}

impl PreparedGemm for PDense {
    fn name(&self) -> &str {
        "dense_gemm"
    }
    fn run(&self, x: &Matrix, bias: &[f32], y: &mut Matrix) {
        self.gemm.run(x, bias, y);
    }
    fn k(&self) -> usize {
        self.k
    }
    fn n(&self) -> usize {
        self.n
    }
    fn nnz(&self) -> usize {
        self.nnz
    }
    fn format_bytes(&self) -> usize {
        self.k * self.n * std::mem::size_of::<f32>()
    }
}

struct PSimd<K> {
    fmt: SymmetricTcsc,
    kernel: K,
    name: &'static str,
    prelu: bool,
}

impl PreparedGemm for PSimd<VerticalSimdKernel> {
    fn name(&self) -> &str {
        self.name
    }
    fn run(&self, x: &Matrix, bias: &[f32], y: &mut Matrix) {
        let padded = PaddedMatrix::from_matrix(x);
        self.kernel.run_padded(&padded, &self.fmt, bias, y);
    }
    fn k(&self) -> usize {
        self.fmt.k()
    }
    fn n(&self) -> usize {
        self.fmt.n()
    }
    fn nnz(&self) -> usize {
        self.fmt.nnz()
    }
    fn format_bytes(&self) -> usize {
        self.fmt.bytes()
    }
    fn fused_prelu(&self) -> bool {
        self.prelu
    }
}

impl PreparedGemm for PSimd<HorizontalSimdKernel> {
    fn name(&self) -> &str {
        self.name
    }
    fn run(&self, x: &Matrix, bias: &[f32], y: &mut Matrix) {
        let padded = PaddedMatrix::from_matrix(x);
        self.kernel.run_padded(&padded, &self.fmt, bias, y);
    }
    fn k(&self) -> usize {
        self.fmt.k()
    }
    fn n(&self) -> usize {
        self.fmt.n()
    }
    fn nnz(&self) -> usize {
        self.fmt.nnz()
    }
    fn format_bytes(&self) -> usize {
        self.fmt.bytes()
    }
    fn fused_prelu(&self) -> bool {
        self.prelu
    }
}

struct PSimdBlocked {
    fmt: InterleavedBlockedTcsc,
    kernel: SimdBlockedMnKernel,
    prelu: bool,
}

impl PreparedGemm for PSimdBlocked {
    fn name(&self) -> &str {
        "simd_blocked_interleaved"
    }
    fn run(&self, x: &Matrix, bias: &[f32], y: &mut Matrix) {
        self.kernel.run(x, &self.fmt, bias, y);
    }
    fn k(&self) -> usize {
        self.fmt.k()
    }
    fn n(&self) -> usize {
        self.fmt.n()
    }
    fn nnz(&self) -> usize {
        self.fmt.nnz()
    }
    fn format_bytes(&self) -> usize {
        self.fmt.bytes()
    }
    fn fused_prelu(&self) -> bool {
        self.prelu
    }
}

/// All registry kernel names, in canonical benchmark order.
pub fn kernel_names() -> &'static [&'static str] {
    &[
        "base_tcsc",
        "unrolled_tcsc_5",
        "unrolled_tcsc_12",
        "unrolled_tcsc_k4_m4",
        "unrolled_blocked_tcsc_k4_m4",
        "interleaved_tcsc",
        "interleaved_blocked_tcsc",
        "compressed_ternary",
        "compressed_ternary_branch",
        "inverted_index",
        "simd_vertical",
        "simd_horizontal",
        "simd_blocked_interleaved",
        "dense_gemm",
    ]
}

/// Build a prepared kernel by registry name.
///
/// # Errors
/// Returns `Err` for unknown names.
pub fn prepare_kernel(
    name: &str,
    w: &TernaryMatrix,
    params: KernelParams,
) -> Result<Box<dyn PreparedGemm>, String> {
    let bs = params.effective_block(w.k());
    Ok(match name {
        "base_tcsc" => Box::new(PBase {
            fmt: Tcsc::from_ternary(w),
        }),
        "unrolled_tcsc_5" => Box::new(PUnrolled5 {
            fmt: Tcsc::from_ternary(w),
        }),
        "unrolled_tcsc_12" => Box::new(PUnrolled12 {
            fmt: Tcsc::from_ternary(w),
        }),
        "unrolled_tcsc_k4_m4" => Box::new(PUnrolledK4M4 {
            fmt: Tcsc::from_ternary(w),
        }),
        "unrolled_blocked_tcsc_k4_m4" => Box::new(PBlocked {
            fmt: BlockedTcsc::from_ternary(w, bs),
        }),
        "interleaved_tcsc" => Box::new(PInterleaved {
            fmt: InterleavedTcsc::from_ternary(w, params.group),
        }),
        "interleaved_blocked_tcsc" => Box::new(PInterleavedBlocked {
            fmt: InterleavedBlockedTcsc::from_ternary(w, bs, 2),
        }),
        "compressed_ternary" => Box::new(PCompressed {
            fmt: CompressedTernary::from_ternary(w),
        }),
        "compressed_ternary_branch" => Box::new(PCompressedBranch {
            fmt: CompressedTernary::from_ternary(w),
        }),
        "inverted_index" => Box::new(PInverted {
            fmt: InvertedIndex::from_ternary(w),
        }),
        "simd_vertical" => Box::new(PSimd {
            fmt: SymmetricTcsc::from_ternary(w),
            kernel: VerticalSimdKernel::new(params.prelu_alpha),
            name: "simd_vertical",
            prelu: params.prelu_alpha.is_some(),
        }),
        "simd_horizontal" => Box::new(PSimd {
            fmt: SymmetricTcsc::from_ternary(w),
            kernel: HorizontalSimdKernel::new(params.prelu_alpha),
            name: "simd_horizontal",
            prelu: params.prelu_alpha.is_some(),
        }),
        "simd_blocked_interleaved" => Box::new(PSimdBlocked {
            fmt: InterleavedBlockedTcsc::from_ternary(w, bs, 2),
            kernel: SimdBlockedMnKernel::new(params.prelu_alpha),
            prelu: params.prelu_alpha.is_some(),
        }),
        "dense_gemm" => Box::new(PDense {
            gemm: DenseGemm::new(w),
            k: w.k(),
            n: w.n(),
            nnz: w.nnz(),
        }),
        other => return Err(format!("unknown kernel '{other}'")),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{dense_oracle, prelu_inplace};

    #[test]
    fn every_registry_kernel_matches_oracle() {
        let w = TernaryMatrix::random(96, 24, 0.25, 131);
        let x = Matrix::random(8, 96, 132);
        let bias: Vec<f32> = (0..24).map(|i| 0.1 * i as f32).collect();
        let oracle = dense_oracle(&x, &w, &bias);
        for &name in kernel_names() {
            let kern = prepare_kernel(name, &w, KernelParams::default()).unwrap();
            assert_eq!(kern.k(), 96);
            assert_eq!(kern.n(), 24);
            let mut y = Matrix::zeros(8, 24);
            kern.run(&x, &bias, &mut y);
            assert!(y.allclose(&oracle, 1e-3), "kernel {name}");
        }
    }

    #[test]
    fn prelu_param_fuses() {
        let w = TernaryMatrix::random(64, 16, 0.5, 7);
        let x = Matrix::random(4, 64, 8);
        let bias = vec![0.0f32; 16];
        let mut oracle = dense_oracle(&x, &w, &bias);
        prelu_inplace(&mut oracle, 0.25);
        let params = KernelParams {
            prelu_alpha: Some(0.25),
            ..Default::default()
        };
        for name in ["simd_vertical", "simd_horizontal", "simd_blocked_interleaved"] {
            let kern = prepare_kernel(name, &w, params).unwrap();
            assert!(kern.fused_prelu());
            let mut y = Matrix::zeros(4, 16);
            kern.run(&x, &bias, &mut y);
            assert!(y.allclose(&oracle, 1e-4), "kernel {name}");
        }
    }

    #[test]
    fn unknown_kernel_is_error() {
        let w = TernaryMatrix::random(8, 8, 0.5, 1);
        assert!(prepare_kernel("nope", &w, KernelParams::default()).is_err());
    }

    #[test]
    fn effective_block_follows_paper_rule() {
        let p = KernelParams::default();
        assert_eq!(p.effective_block(1024), 1024);
        assert_eq!(p.effective_block(16384), 4096);
    }
}
