//! Kernel registry: build a *prepared* GEMM (format constructed, kernel
//! bound) from a kernel name + dense ternary weights. This is the dispatch
//! surface the serving engine, CLI and benches share.

use crate::formats::{
    BlockedTcsc, CompressedTernary, InterleavedBlockedTcsc, InterleavedTcsc, InvertedIndex,
    SparseFormat, SymmetricTcsc, Tcsc,
};
use crate::kernels::simd::{HorizontalSimdKernel, SimdBlockedMnKernel, VerticalSimdKernel};
use crate::kernels::{
    BaseTcscKernel, CompressedKernel, DenseGemm, InterleavedBlockedKernel, InterleavedKernel,
    InvertedKernel, Kernel, UnrolledBlockedKernel, UnrolledMKernel, UnrolledTcscKernel,
};
use crate::tensor::{Matrix, PaddedMatrix};
use crate::ternary::TernaryMatrix;

/// Parameters a kernel build may consume (paper defaults).
#[derive(Debug, Clone, Copy)]
pub struct KernelParams {
    /// Block size for blocked formats; the paper's rule is `min(K, 4096)`.
    pub block_size: usize,
    /// Interleave group size (indices per sign). `None` picks the paper
    /// default per kernel family: 4 for `interleaved_tcsc`, 2 for the
    /// blocked interleaved kernels. `Some(g)` is honored by every
    /// interleaving kernel.
    pub group: Option<usize>,
    /// PReLU slope for kernels that fuse activation; `None` = no activation.
    pub prelu_alpha: Option<f32>,
}

impl Default for KernelParams {
    fn default() -> Self {
        KernelParams {
            block_size: crate::PAPER_BLOCK_SIZE,
            group: None,
            prelu_alpha: None,
        }
    }
}

impl KernelParams {
    /// Paper rule: block size `min(K, 4096)`.
    pub fn effective_block(&self, k: usize) -> usize {
        self.block_size.min(k.max(1))
    }

    /// Group for the plain interleaved format (paper default 4).
    pub fn interleave_group(&self) -> usize {
        self.group.unwrap_or(crate::PAPER_GROUP_SIZE)
    }

    /// Group for the blocked interleaved formats (paper default 2).
    pub fn blocked_group(&self) -> usize {
        self.group.unwrap_or(crate::PAPER_BLOCKED_GROUP)
    }
}

/// Reusable per-caller buffers a prepared kernel may keep across runs.
/// Today this is the SIMD family's padded X copy — previously rebuilt on
/// **every** call, now reused whenever the allocation is large enough
/// (steady-state serving performs no allocation).
#[derive(Debug, Default)]
pub struct GemmScratch {
    padded_x: Option<PaddedMatrix>,
}

impl GemmScratch {
    pub fn new() -> GemmScratch {
        GemmScratch::default()
    }

    /// Padded copy of `x`, reusing the buffer when capacity allows.
    pub fn padded_x(&mut self, x: &Matrix) -> &PaddedMatrix {
        if self.padded_x.is_none() {
            self.padded_x = Some(PaddedMatrix::from_matrix(x));
        } else {
            self.padded_x.as_mut().expect("checked above").copy_from(x);
        }
        self.padded_x.as_ref().expect("just filled")
    }

    /// Pre-size the padded buffer for a `rows`×`k` problem (avoids the
    /// first-call allocation on the serving path).
    pub fn reserve_padded(&mut self, rows: usize, k: usize) {
        let needed = rows * (k + 1);
        let have = self.padded_x.as_ref().map_or(0, |p| p.capacity());
        if needed > have {
            self.padded_x = Some(PaddedMatrix::with_capacity(rows, k));
        }
    }

    /// Current padded-buffer capacity in f32 elements (0 = not allocated).
    /// Allocation-stability tests snapshot this across runs.
    pub fn padded_capacity(&self) -> usize {
        self.padded_x.as_ref().map_or(0, |p| p.capacity())
    }
}

/// A kernel bound to its prepared format: the serving-time object.
pub trait PreparedGemm: Send + Sync {
    /// Registry name.
    fn name(&self) -> &str;

    /// Compute `Y = X·W + b` (+ fused activation where supported).
    fn run(&self, x: &Matrix, bias: &[f32], y: &mut Matrix);

    /// Like [`PreparedGemm::run`], but allowed to keep per-call buffers in
    /// `scratch` for reuse across calls. Kernels that need no scratch fall
    /// through to `run`. The planned execution path
    /// ([`crate::plan::GemmPlan`]) always calls this form.
    fn run_with_scratch(
        &self,
        x: &Matrix,
        bias: &[f32],
        y: &mut Matrix,
        _scratch: &mut GemmScratch,
    ) {
        self.run(x, bias, y);
    }

    /// Logical K.
    fn k(&self) -> usize;

    /// Logical N.
    fn n(&self) -> usize;

    /// Stored nonzeros.
    fn nnz(&self) -> usize;

    /// Exact format byte size (operational-intensity accounting).
    fn format_bytes(&self) -> usize;

    /// Whether PReLU is fused into `run`.
    fn fused_prelu(&self) -> bool {
        false
    }

    /// Whether `run_with_scratch` uses the padded-X scratch buffer (the
    /// planner pre-sizes scratch only for kernels that benefit).
    fn uses_padded_scratch(&self) -> bool {
        false
    }

    /// Interleave group of the prepared format, for kernels built from an
    /// interleaved layout (`None` otherwise). Lets callers verify that
    /// [`KernelParams::group`] was honored.
    fn interleave_group(&self) -> Option<usize> {
        None
    }
}

// Trailing `with_group` marker opts in an `interleave_group` accessor for
// formats with a public `group` field.
macro_rules! typed_prepared {
    ($struct_name:ident, $fmt:ty, $kernel:expr, $name:expr $(, $with_group:ident)?) => {
        struct $struct_name {
            fmt: $fmt,
        }
        impl PreparedGemm for $struct_name {
            fn name(&self) -> &str {
                $name
            }
            fn run(&self, x: &Matrix, bias: &[f32], y: &mut Matrix) {
                $kernel.run(x, &self.fmt, bias, y);
            }
            fn k(&self) -> usize {
                self.fmt.k()
            }
            fn n(&self) -> usize {
                self.fmt.n()
            }
            fn nnz(&self) -> usize {
                self.fmt.nnz()
            }
            fn format_bytes(&self) -> usize {
                self.fmt.bytes()
            }
            $(
                fn interleave_group(&self) -> Option<usize> {
                    let _ = stringify!($with_group);
                    Some(self.fmt.group)
                }
            )?
        }
    };
}

typed_prepared!(PBase, Tcsc, BaseTcscKernel, "base_tcsc");
typed_prepared!(PUnrolled5, Tcsc, UnrolledTcscKernel::<5>, "unrolled_tcsc_5");
typed_prepared!(PUnrolled12, Tcsc, UnrolledTcscKernel::<12>, "unrolled_tcsc_12");
typed_prepared!(PUnrolledK4M4, Tcsc, UnrolledMKernel::<4, 4>, "unrolled_tcsc_k4_m4");
typed_prepared!(
    PBlocked,
    BlockedTcsc,
    UnrolledBlockedKernel::<4, 4>,
    "unrolled_blocked_tcsc_k4_m4"
);
typed_prepared!(
    PInterleaved,
    InterleavedTcsc,
    InterleavedKernel::<4>,
    "interleaved_tcsc",
    with_group
);
typed_prepared!(
    PInterleavedBlocked,
    InterleavedBlockedTcsc,
    InterleavedBlockedKernel::<4>,
    "interleaved_blocked_tcsc",
    with_group
);
typed_prepared!(PCompressed, CompressedTernary, CompressedKernel, "compressed_ternary");
typed_prepared!(
    PCompressedBranch,
    CompressedTernary,
    crate::kernels::compressed::CompressedKernelBranch,
    "compressed_ternary_branch"
);
typed_prepared!(PInverted, InvertedIndex, InvertedKernel, "inverted_index");

struct PDense {
    gemm: DenseGemm,
    k: usize,
    n: usize,
    nnz: usize,
}

impl PreparedGemm for PDense {
    fn name(&self) -> &str {
        "dense_gemm"
    }
    fn run(&self, x: &Matrix, bias: &[f32], y: &mut Matrix) {
        self.gemm.run(x, bias, y);
    }
    fn k(&self) -> usize {
        self.k
    }
    fn n(&self) -> usize {
        self.n
    }
    fn nnz(&self) -> usize {
        self.nnz
    }
    fn format_bytes(&self) -> usize {
        self.k * self.n * std::mem::size_of::<f32>()
    }
}

struct PSimd<K> {
    fmt: SymmetricTcsc,
    kernel: K,
    name: &'static str,
    prelu: bool,
}

impl PreparedGemm for PSimd<VerticalSimdKernel> {
    fn name(&self) -> &str {
        self.name
    }
    fn run(&self, x: &Matrix, bias: &[f32], y: &mut Matrix) {
        // One-shot path: pads X fresh. The planned path below reuses the
        // caller's scratch instead.
        let padded = PaddedMatrix::from_matrix(x);
        self.kernel.run_padded(&padded, &self.fmt, bias, y);
    }
    fn run_with_scratch(
        &self,
        x: &Matrix,
        bias: &[f32],
        y: &mut Matrix,
        scratch: &mut GemmScratch,
    ) {
        self.kernel.run_padded(scratch.padded_x(x), &self.fmt, bias, y);
    }
    fn k(&self) -> usize {
        self.fmt.k()
    }
    fn n(&self) -> usize {
        self.fmt.n()
    }
    fn nnz(&self) -> usize {
        self.fmt.nnz()
    }
    fn format_bytes(&self) -> usize {
        self.fmt.bytes()
    }
    fn fused_prelu(&self) -> bool {
        self.prelu
    }
    fn uses_padded_scratch(&self) -> bool {
        true
    }
}

impl PreparedGemm for PSimd<HorizontalSimdKernel> {
    fn name(&self) -> &str {
        self.name
    }
    fn run(&self, x: &Matrix, bias: &[f32], y: &mut Matrix) {
        let padded = PaddedMatrix::from_matrix(x);
        self.kernel.run_padded(&padded, &self.fmt, bias, y);
    }
    fn run_with_scratch(
        &self,
        x: &Matrix,
        bias: &[f32],
        y: &mut Matrix,
        scratch: &mut GemmScratch,
    ) {
        self.kernel.run_padded(scratch.padded_x(x), &self.fmt, bias, y);
    }
    fn k(&self) -> usize {
        self.fmt.k()
    }
    fn n(&self) -> usize {
        self.fmt.n()
    }
    fn nnz(&self) -> usize {
        self.fmt.nnz()
    }
    fn format_bytes(&self) -> usize {
        self.fmt.bytes()
    }
    fn fused_prelu(&self) -> bool {
        self.prelu
    }
    fn uses_padded_scratch(&self) -> bool {
        true
    }
}

struct PSimdBlocked {
    fmt: InterleavedBlockedTcsc,
    kernel: SimdBlockedMnKernel,
    prelu: bool,
}

impl PreparedGemm for PSimdBlocked {
    fn name(&self) -> &str {
        "simd_blocked_interleaved"
    }
    fn run(&self, x: &Matrix, bias: &[f32], y: &mut Matrix) {
        self.kernel.run(x, &self.fmt, bias, y);
    }
    fn k(&self) -> usize {
        self.fmt.k()
    }
    fn n(&self) -> usize {
        self.fmt.n()
    }
    fn nnz(&self) -> usize {
        self.fmt.nnz()
    }
    fn format_bytes(&self) -> usize {
        self.fmt.bytes()
    }
    fn fused_prelu(&self) -> bool {
        self.prelu
    }
    fn interleave_group(&self) -> Option<usize> {
        Some(self.fmt.group)
    }
}

/// All registry kernel names, in canonical benchmark order.
pub fn kernel_names() -> &'static [&'static str] {
    &[
        "base_tcsc",
        "unrolled_tcsc_5",
        "unrolled_tcsc_12",
        "unrolled_tcsc_k4_m4",
        "unrolled_blocked_tcsc_k4_m4",
        "interleaved_tcsc",
        "interleaved_blocked_tcsc",
        "compressed_ternary",
        "compressed_ternary_branch",
        "inverted_index",
        "simd_vertical",
        "simd_horizontal",
        "simd_blocked_interleaved",
        "dense_gemm",
    ]
}

/// Build a prepared kernel by registry name.
///
/// # Errors
/// Returns `Err` for unknown names.
pub fn prepare_kernel(
    name: &str,
    w: &TernaryMatrix,
    params: KernelParams,
) -> Result<Box<dyn PreparedGemm>, String> {
    if params.group == Some(0) {
        return Err("interleave group must be >= 1".into());
    }
    let bs = params.effective_block(w.k());
    Ok(match name {
        "base_tcsc" => Box::new(PBase {
            fmt: Tcsc::from_ternary(w),
        }),
        "unrolled_tcsc_5" => Box::new(PUnrolled5 {
            fmt: Tcsc::from_ternary(w),
        }),
        "unrolled_tcsc_12" => Box::new(PUnrolled12 {
            fmt: Tcsc::from_ternary(w),
        }),
        "unrolled_tcsc_k4_m4" => Box::new(PUnrolledK4M4 {
            fmt: Tcsc::from_ternary(w),
        }),
        "unrolled_blocked_tcsc_k4_m4" => Box::new(PBlocked {
            fmt: BlockedTcsc::from_ternary(w, bs),
        }),
        "interleaved_tcsc" => Box::new(PInterleaved {
            fmt: InterleavedTcsc::from_ternary(w, params.interleave_group()),
        }),
        "interleaved_blocked_tcsc" => Box::new(PInterleavedBlocked {
            fmt: InterleavedBlockedTcsc::from_ternary(w, bs, params.blocked_group()),
        }),
        "compressed_ternary" => Box::new(PCompressed {
            fmt: CompressedTernary::from_ternary(w),
        }),
        "compressed_ternary_branch" => Box::new(PCompressedBranch {
            fmt: CompressedTernary::from_ternary(w),
        }),
        "inverted_index" => Box::new(PInverted {
            fmt: InvertedIndex::from_ternary(w),
        }),
        "simd_vertical" => Box::new(PSimd {
            fmt: SymmetricTcsc::from_ternary(w),
            kernel: VerticalSimdKernel::new(params.prelu_alpha),
            name: "simd_vertical",
            prelu: params.prelu_alpha.is_some(),
        }),
        "simd_horizontal" => Box::new(PSimd {
            fmt: SymmetricTcsc::from_ternary(w),
            kernel: HorizontalSimdKernel::new(params.prelu_alpha),
            name: "simd_horizontal",
            prelu: params.prelu_alpha.is_some(),
        }),
        "simd_blocked_interleaved" => Box::new(PSimdBlocked {
            fmt: InterleavedBlockedTcsc::from_ternary(w, bs, params.blocked_group()),
            kernel: SimdBlockedMnKernel::new(params.prelu_alpha),
            prelu: params.prelu_alpha.is_some(),
        }),
        "dense_gemm" => Box::new(PDense {
            gemm: DenseGemm::new(w),
            k: w.k(),
            n: w.n(),
            nnz: w.nnz(),
        }),
        other => return Err(format!("unknown kernel '{other}'")),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{dense_oracle, prelu_inplace};

    #[test]
    fn every_registry_kernel_matches_oracle() {
        let w = TernaryMatrix::random(96, 24, 0.25, 131);
        let x = Matrix::random(8, 96, 132);
        let bias: Vec<f32> = (0..24).map(|i| 0.1 * i as f32).collect();
        let oracle = dense_oracle(&x, &w, &bias);
        for &name in kernel_names() {
            let kern = prepare_kernel(name, &w, KernelParams::default()).unwrap();
            assert_eq!(kern.k(), 96);
            assert_eq!(kern.n(), 24);
            let mut y = Matrix::zeros(8, 24);
            kern.run(&x, &bias, &mut y);
            assert!(y.allclose(&oracle, 1e-3), "kernel {name}");
        }
    }

    #[test]
    fn prelu_param_fuses() {
        let w = TernaryMatrix::random(64, 16, 0.5, 7);
        let x = Matrix::random(4, 64, 8);
        let bias = vec![0.0f32; 16];
        let mut oracle = dense_oracle(&x, &w, &bias);
        prelu_inplace(&mut oracle, 0.25);
        let params = KernelParams {
            prelu_alpha: Some(0.25),
            ..Default::default()
        };
        for name in ["simd_vertical", "simd_horizontal", "simd_blocked_interleaved"] {
            let kern = prepare_kernel(name, &w, params).unwrap();
            assert!(kern.fused_prelu());
            let mut y = Matrix::zeros(4, 16);
            kern.run(&x, &bias, &mut y);
            assert!(y.allclose(&oracle, 1e-4), "kernel {name}");
        }
    }

    #[test]
    fn unknown_kernel_is_error() {
        let w = TernaryMatrix::random(8, 8, 0.5, 1);
        assert!(prepare_kernel("nope", &w, KernelParams::default()).is_err());
        assert!(prepare_kernel(
            "interleaved_tcsc",
            &w,
            KernelParams {
                group: Some(0),
                ..Default::default()
            }
        )
        .is_err());
    }

    #[test]
    fn group_param_is_threaded_through() {
        let w = TernaryMatrix::random(96, 24, 0.25, 17);
        let x = Matrix::random(5, 96, 18);
        let bias: Vec<f32> = (0..24).map(|i| 0.05 * i as f32).collect();
        let oracle = dense_oracle(&x, &w, &bias);
        // Paper defaults when no group is given.
        for (name, want) in [
            ("interleaved_tcsc", crate::PAPER_GROUP_SIZE),
            ("interleaved_blocked_tcsc", crate::PAPER_BLOCKED_GROUP),
            ("simd_blocked_interleaved", crate::PAPER_BLOCKED_GROUP),
        ] {
            let kern = prepare_kernel(name, &w, KernelParams::default()).unwrap();
            assert_eq!(kern.interleave_group(), Some(want), "{name} default");
        }
        // Explicit groups are honored by every interleaving kernel and
        // stay correct.
        for g in [1usize, 3, 4] {
            let params = KernelParams {
                group: Some(g),
                ..Default::default()
            };
            for name in [
                "interleaved_tcsc",
                "interleaved_blocked_tcsc",
                "simd_blocked_interleaved",
            ] {
                let kern = prepare_kernel(name, &w, params).unwrap();
                assert_eq!(kern.interleave_group(), Some(g), "{name} g={g}");
                let mut y = Matrix::zeros(5, 24);
                kern.run(&x, &bias, &mut y);
                assert!(y.allclose(&oracle, 1e-3), "{name} g={g}");
            }
        }
    }

    #[test]
    fn scratch_path_matches_and_reuses_allocation() {
        let w = TernaryMatrix::random(64, 20, 0.25, 55);
        let x = Matrix::random(6, 64, 56);
        let bias = vec![0.1f32; 20];
        for name in kernel_names() {
            let kern = prepare_kernel(name, &w, KernelParams::default()).unwrap();
            let mut y_plain = Matrix::zeros(6, 20);
            kern.run(&x, &bias, &mut y_plain);
            let mut scratch = GemmScratch::new();
            let mut y_scratch = Matrix::zeros(6, 20);
            kern.run_with_scratch(&x, &bias, &mut y_scratch, &mut scratch);
            assert_eq!(y_plain, y_scratch, "{name} scratch path must be bitwise equal");
            // Repeated calls must not grow the scratch.
            let cap = scratch.padded_capacity();
            for _ in 0..3 {
                kern.run_with_scratch(&x, &bias, &mut y_scratch, &mut scratch);
            }
            assert_eq!(scratch.padded_capacity(), cap, "{name}");
            if kern.uses_padded_scratch() {
                assert_eq!(cap, 6 * 65, "{name} pads X into scratch");
            } else {
                assert_eq!(cap, 0, "{name} needs no padded scratch");
            }
        }
    }

    #[test]
    fn effective_block_follows_paper_rule() {
        let p = KernelParams::default();
        assert_eq!(p.effective_block(1024), 1024);
        assert_eq!(p.effective_block(16384), 4096);
    }
}
