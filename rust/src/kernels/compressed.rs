//! Value-compression kernel (paper §3 "Value Compression") — walks the
//! base-3 packed byte codes, decoding each through the 243-entry LUT and
//! applying the five ternary digits to five *consecutive* X elements
//! (sequential access — the format's selling point), wasting work on packed
//! zeros (its downfall below 50% density; the ablation bench shows it).

use crate::formats::compressed::{decode_lut, CompressedTernary, DIGITS};
use crate::kernels::Kernel;
use crate::tensor::Matrix;

/// LUT-decoded packed-ternary kernel.
pub struct CompressedKernel;

impl Kernel for CompressedKernel {
    type Format = CompressedTernary;

    fn name(&self) -> &'static str {
        "compressed_ternary"
    }

    fn run(&self, x: &Matrix, w: &CompressedTernary, bias: &[f32], y: &mut Matrix) {
        use crate::formats::SparseFormat;
        crate::kernels::debug_check_shapes(x, w.k(), w.n(), bias, y);
        let lut = decode_lut();
        let m = x.rows();
        let n = w.n();
        let k = w.k();
        for r in 0..m {
            let xr = x.row(r);
            let yr = y.row_mut(r);
            for c in 0..n {
                let mut acc = 0.0f32;
                let codes = w.col_codes(c);
                // All full 5-tuples (no bounds checks needed inside).
                let full = k / DIGITS;
                for (t, &code) in codes[..full].iter().enumerate() {
                    let digits = &lut[code as usize];
                    let base = t * DIGITS;
                    // Branchless-ish: multiply by the ternary digit. The
                    // paper counts these as flops too (adds *and* muls).
                    acc += digits[0] as f32 * xr[base]
                        + digits[1] as f32 * xr[base + 1]
                        + digits[2] as f32 * xr[base + 2]
                        + digits[3] as f32 * xr[base + 3]
                        + digits[4] as f32 * xr[base + 4];
                }
                // Tail code (K not a multiple of 5).
                if full < codes.len() {
                    let digits = &lut[codes[full] as usize];
                    let base = full * DIGITS;
                    for (d, &v) in digits.iter().enumerate() {
                        if base + d < k && v != 0 {
                            acc += v as f32 * xr[base + d];
                        }
                    }
                }
                yr[c] = acc + bias[c];
            }
        }
    }
}

/// Branch-decoding variant: per digit, `match` on the sign and add/sub
/// (no multiplies — closer to the paper's "zero-flop decode" claim, but
/// with a data-dependent branch per digit). Benchmarked against the
/// multiply variant in the ablation; whichever wins becomes the registry
/// `compressed_ternary` entry for a host.
pub struct CompressedKernelBranch;

impl Kernel for CompressedKernelBranch {
    type Format = CompressedTernary;

    fn name(&self) -> &'static str {
        "compressed_ternary_branch"
    }

    fn run(&self, x: &Matrix, w: &CompressedTernary, bias: &[f32], y: &mut Matrix) {
        use crate::formats::SparseFormat;
        crate::kernels::debug_check_shapes(x, w.k(), w.n(), bias, y);
        let lut = decode_lut();
        let m = x.rows();
        let n = w.n();
        let k = w.k();
        for r in 0..m {
            let xr = x.row(r);
            let yr = y.row_mut(r);
            for c in 0..n {
                let mut acc = 0.0f32;
                let codes = w.col_codes(c);
                let full = k / DIGITS;
                for (t, &code) in codes[..full].iter().enumerate() {
                    let digits = &lut[code as usize];
                    let base = t * DIGITS;
                    for (d, &v) in digits.iter().enumerate() {
                        match v {
                            1 => acc += xr[base + d],
                            -1 => acc -= xr[base + d],
                            _ => {}
                        }
                    }
                }
                if full < codes.len() {
                    let digits = &lut[codes[full] as usize];
                    let base = full * DIGITS;
                    for (d, &v) in digits.iter().enumerate() {
                        if base + d < k {
                            match v {
                                1 => acc += xr[base + d],
                                -1 => acc -= xr[base + d],
                                _ => {}
                            }
                        }
                    }
                }
                yr[c] = acc + bias[c];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::dense_oracle;
    use crate::ternary::TernaryMatrix;

    fn check(k: usize, s: f32) {
        let w = TernaryMatrix::random(k, 16, s, 81);
        let f = CompressedTernary::from_ternary(&w);
        let x = Matrix::random(4, k, 82);
        let bias: Vec<f32> = (0..16).map(|i| i as f32 * 0.1).collect();
        let oracle = dense_oracle(&x, &w, &bias);
        let mut y = Matrix::zeros(4, 16);
        CompressedKernel.run(&x, &f, &bias, &mut y);
        assert!(y.allclose(&oracle, 1e-4), "k={k} s={s}");
    }

    #[test]
    fn matches_oracle() {
        for &s in &crate::PAPER_SPARSITIES {
            check(125, s); // divisible by 5
        }
    }

    #[test]
    fn tail_handling() {
        check(123, 0.5); // 123 = 24·5 + 3
        check(7, 0.5);
        check(4, 0.25); // smaller than one code
    }

    #[test]
    fn branch_variant_matches_oracle() {
        for &s in &crate::PAPER_SPARSITIES {
            let w = TernaryMatrix::random(123, 16, s, 85);
            let f = CompressedTernary::from_ternary(&w);
            let x = Matrix::random(4, 123, 86);
            let bias: Vec<f32> = (0..16).map(|i| i as f32 * 0.1).collect();
            let oracle = dense_oracle(&x, &w, &bias);
            let mut y = Matrix::zeros(4, 16);
            CompressedKernelBranch.run(&x, &f, &bias, &mut y);
            assert!(y.allclose(&oracle, 1e-4), "s={s}");
        }
    }

    #[test]
    fn variants_agree_bitwise_order() {
        // Both variants accumulate in the same order → identical floats.
        let w = TernaryMatrix::random(60, 8, 0.5, 5);
        let f = CompressedTernary::from_ternary(&w);
        let x = Matrix::random(2, 60, 6);
        let bias = vec![0.5f32; 8];
        let mut ya = Matrix::zeros(2, 8);
        let mut yb = Matrix::zeros(2, 8);
        CompressedKernel.run(&x, &f, &bias, &mut ya);
        CompressedKernelBranch.run(&x, &f, &bias, &mut yb);
        assert!(ya.allclose(&yb, 1e-5));
    }
}
