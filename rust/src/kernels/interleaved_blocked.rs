//! InterleavedBlockedTCSC kernel — the paper's **best scalar
//! implementation**: K-blocked (B = 4096) for X locality, interleaved in
//! groups of 2 per sign (4-wide inner step: 2 adds + 2 subtracts), unrolled
//! over `MU = 4` rows of X/Y. Processes each blocked column in three
//! phases: interleaved pairs, remaining positives, remaining negatives.

use crate::formats::InterleavedBlockedTcsc;
use crate::kernels::unrolled_m::gather_rows;
use crate::kernels::Kernel;
use crate::tensor::Matrix;

/// Best-scalar kernel. Paper configuration: `MU = 4`, group = 2, B = 4096.
pub struct InterleavedBlockedKernel<const MU: usize>;

/// One interleaved stream pass specialized for group = 2 (the paper's
/// choice: with unroll factor F=4, F/2 = 2 indices per sign): each step
/// does 2 adds and 2 subtracts per row.
#[inline(always)]
fn walk_interleaved_g2<const MU: usize>(
    xrows: &[&[f32]; MU],
    inter: &[u32],
    acc: &mut [f32; MU],
) {
    use crate::kernels::unrolled::gat;
    debug_assert_eq!(inter.len() % 4, 0);
    // §Perf notes (EXPERIMENTS.md §Perf, headline point K=16384/s=50%):
    //   iter 2: dual-accumulator 2-step unroll measured -3% (memory-bound,
    //           not add-latency-bound) — reverted.
    //   iter 3: software prefetch (_mm_prefetch, distance 2 steps) measured
    //           -9% (the B=4096 block already sits in cache; prefetches
    //           burned load slots) — reverted.
    let mut p = 0;
    while p < inter.len() {
        let (p0, p1) = (inter[p], inter[p + 1]);
        let (n0, n1) = (inter[p + 2], inter[p + 3]);
        for (m, row) in xrows.iter().enumerate() {
            // 4 independent gathered operands per row per step.
            acc[m] += gat(row, p0) + gat(row, p1) - gat(row, n0) - gat(row, n1);
        }
        p += 4;
    }
}

/// Generic-group interleaved walk (used when the format was built with a
/// group other than 2).
#[inline(always)]
fn walk_interleaved_gn<const MU: usize>(
    xrows: &[&[f32]; MU],
    inter: &[u32],
    g: usize,
    acc: &mut [f32; MU],
) {
    use crate::kernels::unrolled::gat;
    let step = 2 * g;
    let mut p = 0;
    while p < inter.len() {
        for &i in &inter[p..p + g] {
            for (m, row) in xrows.iter().enumerate() {
                acc[m] += gat(row, i);
            }
        }
        for &i in &inter[p + g..p + step] {
            for (m, row) in xrows.iter().enumerate() {
                acc[m] -= gat(row, i);
            }
        }
        p += step;
    }
}

impl<const MU: usize> InterleavedBlockedKernel<MU> {
    #[inline(always)]
    fn tile<const TM: usize>(
        x: &Matrix,
        w: &InterleavedBlockedTcsc,
        y: &mut Matrix,
        b: usize,
        r: usize,
        n: usize,
    ) {
        let xrows: [&[f32]; TM] = std::array::from_fn(|i| x.row(r + i));
        for c in 0..n {
            let mut acc = [0.0f32; TM];
            let inter = w.seg_interleaved(b, c);
            if w.group == 2 {
                walk_interleaved_g2::<TM>(&xrows, inter, &mut acc);
            } else {
                walk_interleaved_gn::<TM>(&xrows, inter, w.group, &mut acc);
            }
            gather_rows::<4, TM>(&xrows, w.seg_rest_pos(b, c), &mut acc, false);
            gather_rows::<4, TM>(&xrows, w.seg_rest_neg(b, c), &mut acc, true);
            for (i, a) in acc.iter().enumerate() {
                y[(r + i, c)] += a;
            }
        }
    }
}

impl<const MU: usize> Kernel for InterleavedBlockedKernel<MU> {
    type Format = InterleavedBlockedTcsc;

    fn name(&self) -> &'static str {
        "interleaved_blocked_tcsc"
    }

    fn run(&self, x: &Matrix, w: &InterleavedBlockedTcsc, bias: &[f32], y: &mut Matrix) {
        use crate::formats::SparseFormat;
        crate::kernels::debug_check_shapes(x, w.k(), w.n(), bias, y);
        let m = x.rows();
        let n = w.n();
        for r in 0..m {
            y.row_mut(r).copy_from_slice(bias);
        }
        for b in 0..w.nblocks() {
            let mut r = 0;
            while r + MU <= m {
                Self::tile::<MU>(x, w, y, b, r, n);
                r += MU;
            }
            while r < m {
                Self::tile::<1>(x, w, y, b, r, n);
                r += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::dense_oracle;
    use crate::ternary::TernaryMatrix;

    fn check<const MU: usize>(m: usize, k: usize, bs: usize, g: usize, s: f32) {
        let w = TernaryMatrix::random(k, 20, s, 71);
        let f = InterleavedBlockedTcsc::from_ternary(&w, bs, g);
        let x = Matrix::random(m, k, 72);
        let bias: Vec<f32> = (0..20).map(|i| 0.05 * i as f32).collect();
        let oracle = dense_oracle(&x, &w, &bias);
        let mut y = Matrix::zeros(m, 20);
        InterleavedBlockedKernel::<MU>.run(&x, &f, &bias, &mut y);
        assert!(
            y.allclose(&oracle, 1e-4),
            "MU={MU} m={m} k={k} bs={bs} g={g} s={s}"
        );
    }

    #[test]
    fn paper_best_scalar_config() {
        check::<4>(8, 256, 64, 2, 0.5);
    }

    #[test]
    fn across_sparsities() {
        for &s in &crate::PAPER_SPARSITIES {
            check::<4>(4, 128, 32, 2, s);
        }
    }

    #[test]
    fn odd_shapes_and_groups() {
        check::<4>(7, 100, 17, 2, 0.25);
        check::<2>(5, 90, 30, 4, 0.5);
        check::<1>(1, 50, 8, 1, 0.5);
    }

    #[test]
    fn single_block() {
        check::<4>(4, 64, 4096, 2, 0.5);
    }
}
