//! The sparse ternary GEMM kernel family.
//!
//! Every kernel computes `Y = X·W + b` (optionally followed by fused
//! PReLU) where `W` is ternary and stored in one of the [`crate::formats`]
//! layouts. Because `W`'s entries are ±1, the inner loops are pure
//! add/subtract streams over gathered `X` elements — the paper's entire
//! optimization space is *which order* those gathers happen in.
//!
//! Kernels come in two flavors:
//! - **typed**: `run(x, &format, bias, &mut y)` — used by benches and tests;
//! - **prepared** ([`PreparedGemm`]): format captured at build time,
//!   `run(x, bias, &mut y)` — used by the serving engine and the registry.

pub mod dense;
pub mod base;
pub mod unrolled;
pub mod unrolled_m;
pub mod blocked;
pub mod interleaved;
pub mod interleaved_blocked;
pub mod compressed;
pub mod inverted;
pub mod prelu;
pub mod simd;
pub mod registry;
pub mod parallel;
pub mod outer_product;

pub use base::BaseTcscKernel;
pub use blocked::UnrolledBlockedKernel;
pub use dense::{dense_oracle, DenseGemm};
pub use interleaved::InterleavedKernel;
pub use interleaved_blocked::InterleavedBlockedKernel;
pub use compressed::CompressedKernel;
pub use inverted::InvertedKernel;
pub use outer_product::{OuterTileKernel, OuterTileSimdKernel};
pub use parallel::ParallelGemm;
pub use prelu::{prelu_inplace, PRELU_DEFAULT_ALPHA};
pub use registry::{
    available_ids, available_kernel_ids, best_scalar, descriptors, first_matching, fused_simd,
    gemv_specialist, kernel_ids, kernel_names, matrix_tile, prepare_kernel, BatchAffinity,
    GemmScratch, KernelDescriptor, KernelFamily, KernelId, KernelParams, PreparedGemm,
};
pub use unrolled::UnrolledTcscKernel;
pub use unrolled_m::UnrolledMKernel;

use crate::tensor::Matrix;

/// Typed kernel interface over a specific sparse format.
pub trait Kernel {
    type Format;

    /// Kernel name as it appears in benchmark tables.
    fn name(&self) -> &'static str;

    /// Compute `Y = X·W + b`. `Y` must be M×N and is fully overwritten.
    fn run(&self, x: &Matrix, w: &Self::Format, bias: &[f32], y: &mut Matrix);
}

/// Validate shapes shared by all kernels. Always on (one check per GEMM
/// call): the inner gather loops use unchecked indexing whose safety
/// contract is "X rows are exactly K long and format indices are < K"
/// (the latter is enforced by format constructors/validate()).
#[inline]
pub(crate) fn debug_check_shapes(
    x: &Matrix,
    k: usize,
    n: usize,
    bias: &[f32],
    y: &Matrix,
) {
    assert_eq!(x.cols(), k, "X cols must equal K");
    assert_eq!(bias.len(), n, "bias length must equal N");
    assert_eq!(y.rows(), x.rows(), "Y rows must equal X rows");
    assert_eq!(y.cols(), n, "Y cols must equal N");
}
