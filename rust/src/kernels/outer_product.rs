//! Outer-product tile kernels over [`TilePanelTcsc`] — the matrix-unit
//! orientation of the ternary GEMM.
//!
//! Every other kernel in this crate is inner-product shaped: it finishes
//! one output value (or a short vector of them) before moving on, so each
//! nonzero is touched once per output *row*. The outer-product family
//! inverts that: it walks a panel's `(k, c)` entry stream **once** per
//! M-row tile and scatters each gathered X value into a register-resident
//! accumulator tile of [`OUTER_TILE`] rows × `W` panel columns, where `W`
//! is the format's [`crate::formats::TileGeometry::panel_width`] (4 or 8 — both kernels
//! are const-generic over it and dispatch on the format header). That is
//! the orchestration "Above the Inner Loop" asks for: accumulators never
//! leave registers inside a panel, and the index stream is amortized
//! across both the M and N tile dimensions — the operational-intensity
//! regime where AMX/SME-class matrix units pay off.
//!
//! When the geometry carries a nonzero `k_block`, each panel's streams
//! are walked K-block by K-block (all positive blocks in ascending k,
//! then all negative blocks) into the *same* register tile, so the X
//! values touched between accumulator spills stay within an L1d-resident
//! K-slice. Because the blocks partition each stream at ascending-k
//! boundaries, the blocked walk replays the unblocked entry order
//! exactly — blocking changes locality, never results.
//!
//! Bitwise contract: for each output cell the accumulation order is
//! positives in ascending k, then negatives in ascending k, then `+ bias`
//! — exactly [`crate::kernels::BaseTcscKernel`]'s order, which the
//! `(k, c)`-lexicographic stream order guarantees per in-panel column at
//! every geometry. The property suite asserts `assert_eq!` (not
//! `allclose`) against the baseline, on any host: [`OuterTileSimdKernel`]
//! uses the portable [`F32x4`] stand-in whose lane ops are IEEE-identical
//! to scalar code.

use crate::formats::{SparseFormat, TilePanelTcsc, MAX_PANEL_WIDTH, OUTER_TILE};
use crate::kernels::simd::f32x4::F32x4;
use crate::kernels::unrolled::gat;
use crate::kernels::Kernel;
use crate::tensor::Matrix;

/// Portable scalar outer-product kernel: one `OUTER_TILE`×`W` accumulator
/// tile per (row-tile, panel) pair, `W` taken from the format's geometry.
/// Runs anywhere; the registry's capability table leaves its `requires`
/// list empty.
pub struct OuterTileKernel;

/// SIMD outer-product kernel: the accumulator tile is `W` vector
/// registers (one [`F32x4`] per panel column, lanes = M rows), fed by
/// sequential loads from a transposed X tile staged per row-tile. Gated on
/// NEON for *selection* (the lane layout only wins with a real vector
/// unit) but portable by construction.
pub struct OuterTileSimdKernel;

/// Scalar tile walk, const-generic over the panel width `W`.
fn run_scalar_width<const W: usize>(
    x: &Matrix,
    w: &TilePanelTcsc,
    bias: &[f32],
    y: &mut Matrix,
) {
    debug_assert_eq!(w.tile(), W);
    let m = x.rows();
    let panels = w.panels();
    let kblocks = w.k_blocks();
    let mut r = 0;
    // Full OUTER_TILE-row tiles: OUTER_TILE×W register accumulator per
    // panel, fed one K-block at a time (positives first, then negatives —
    // block concatenation replays the unblocked stream).
    while r + OUTER_TILE <= m {
        let xrows: [&[f32]; OUTER_TILE] = std::array::from_fn(|i| x.row(r + i));
        for p in 0..panels {
            let col0 = p * W;
            let width = w.panel_width(p);
            let mut acc = [[0.0f32; W]; OUTER_TILE]; // [row][panel col]
            for b in 0..kblocks {
                let (ks, cs) = w.panel_pos_block(p, b);
                for (&kk, &c) in ks.iter().zip(cs) {
                    for (mrow, row) in xrows.iter().enumerate() {
                        acc[mrow][c as usize] += gat(row, kk);
                    }
                }
            }
            for b in 0..kblocks {
                let (ks, cs) = w.panel_neg_block(p, b);
                for (&kk, &c) in ks.iter().zip(cs) {
                    for (mrow, row) in xrows.iter().enumerate() {
                        acc[mrow][c as usize] -= gat(row, kk);
                    }
                }
            }
            for (mrow, acc_row) in acc.iter().enumerate() {
                let yr = &mut y.row_mut(r + mrow)[col0..col0 + width];
                for c in 0..width {
                    yr[c] = acc_row[c] + bias[col0 + c];
                }
            }
        }
        r += OUTER_TILE;
    }
    // Single-row remainder: a 1×W accumulator strip, same entry order.
    while r < m {
        let xr = x.row(r);
        let yr = y.row_mut(r);
        for p in 0..panels {
            let col0 = p * W;
            let width = w.panel_width(p);
            let mut acc = [0.0f32; W];
            for b in 0..kblocks {
                let (ks, cs) = w.panel_pos_block(p, b);
                for (&kk, &c) in ks.iter().zip(cs) {
                    acc[c as usize] += gat(xr, kk);
                }
            }
            for b in 0..kblocks {
                let (ks, cs) = w.panel_neg_block(p, b);
                for (&kk, &c) in ks.iter().zip(cs) {
                    acc[c as usize] -= gat(xr, kk);
                }
            }
            for c in 0..width {
                yr[col0 + c] = acc[c] + bias[col0 + c];
            }
        }
        r += 1;
    }
}

impl Kernel for OuterTileKernel {
    type Format = TilePanelTcsc;

    fn name(&self) -> &'static str {
        "outer_product_tile"
    }

    fn run(&self, x: &Matrix, w: &TilePanelTcsc, bias: &[f32], y: &mut Matrix) {
        crate::kernels::debug_check_shapes(x, w.k(), w.n(), bias, y);
        match w.tile() {
            MAX_PANEL_WIDTH => run_scalar_width::<MAX_PANEL_WIDTH>(x, w, bias, y),
            _ => run_scalar_width::<OUTER_TILE>(x, w, bias, y),
        }
    }
}

/// SIMD tile walk, const-generic over the panel width `W`. Lanes stay
/// [`OUTER_TILE`] M rows regardless of `W`; a wider panel means more
/// vector accumulators live per panel, not wider vectors.
fn run_simd_width<const W: usize>(
    x: &Matrix,
    w: &TilePanelTcsc,
    bias: &[f32],
    y: &mut Matrix,
    xt: &mut [f32],
) {
    debug_assert_eq!(w.tile(), W);
    let m = x.rows();
    let k = w.k();
    let panels = w.panels();
    let kblocks = w.k_blocks();
    let mut r = 0;
    while r < m {
        let rows = (m - r).min(OUTER_TILE);
        for lane in 0..OUTER_TILE {
            if lane < rows {
                for (kk, &v) in x.row(r + lane).iter().enumerate() {
                    xt[kk * OUTER_TILE + lane] = v;
                }
            } else {
                for kk in 0..k {
                    xt[kk * OUTER_TILE + lane] = 0.0;
                }
            }
        }
        for p in 0..panels {
            let col0 = p * W;
            let width = w.panel_width(p);
            // One vector register per panel column; lanes are M rows.
            let mut acc = [F32x4::ZERO; W];
            for b in 0..kblocks {
                let (ks, cs) = w.panel_pos_block(p, b);
                for (&kk, &c) in ks.iter().zip(cs) {
                    let v = F32x4::load(&xt[kk as usize * OUTER_TILE..]);
                    acc[c as usize] = acc[c as usize].add(v);
                }
            }
            for b in 0..kblocks {
                let (ks, cs) = w.panel_neg_block(p, b);
                for (&kk, &c) in ks.iter().zip(cs) {
                    let v = F32x4::load(&xt[kk as usize * OUTER_TILE..]);
                    acc[c as usize] = acc[c as usize].sub(v);
                }
            }
            for c in 0..width {
                let out = acc[c].add(F32x4::splat(bias[col0 + c]));
                for lane in 0..rows {
                    y[(r + lane, col0 + c)] = out.0[lane];
                }
            }
        }
        r += rows;
    }
}

impl OuterTileSimdKernel {
    /// Run reusing a caller-owned staging buffer for the transposed X tile
    /// (`K · OUTER_TILE` f32; resized as needed, steady-state
    /// allocation-free). Layout: `xt[kk·T + lane] = X[r0+lane][kk]`, unused
    /// lanes zero — so every entry becomes one sequential vector load
    /// instead of a gather. The staging layout depends only on K and the
    /// lane count, never on the panel width.
    pub fn run_with_buf(
        &self,
        x: &Matrix,
        w: &TilePanelTcsc,
        bias: &[f32],
        y: &mut Matrix,
        xt: &mut Vec<f32>,
    ) {
        crate::kernels::debug_check_shapes(x, w.k(), w.n(), bias, y);
        xt.clear();
        xt.resize(w.k() * OUTER_TILE, 0.0);
        match w.tile() {
            MAX_PANEL_WIDTH => run_simd_width::<MAX_PANEL_WIDTH>(x, w, bias, y, xt),
            _ => run_simd_width::<OUTER_TILE>(x, w, bias, y, xt),
        }
    }
}

impl Kernel for OuterTileSimdKernel {
    type Format = TilePanelTcsc;

    fn name(&self) -> &'static str {
        "outer_product_tile_simd"
    }

    fn run(&self, x: &Matrix, w: &TilePanelTcsc, bias: &[f32], y: &mut Matrix) {
        let mut xt = Vec::new();
        self.run_with_buf(x, w, bias, y, &mut xt);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::{Tcsc, TileGeometry};
    use crate::kernels::{dense_oracle, BaseTcscKernel};
    use crate::ternary::TernaryMatrix;

    /// Geometries every bitwise check sweeps: both panel widths,
    /// unblocked, a block that doesn't divide K, and a block ≥ K.
    fn check_geometries(k: usize) -> Vec<TileGeometry> {
        let mut gs = Vec::new();
        for width in [4usize, 8] {
            for kb in [0usize, 7, k.max(1) + 3] {
                gs.push(TileGeometry::new(width, kb));
            }
        }
        gs
    }

    fn bitwise_check(m: usize, k: usize, n: usize, s: f32, seed: u64) {
        let w = TernaryMatrix::random(k, n, s, seed);
        let tcsc = Tcsc::from_ternary(&w);
        let x = Matrix::random(m, k, seed + 1);
        let bias: Vec<f32> = (0..n).map(|i| (i as f32 * 0.7).cos()).collect();
        let mut base = Matrix::zeros(m, n);
        BaseTcscKernel.run(&x, &tcsc, &bias, &mut base);
        let oracle = dense_oracle(&x, &w, &bias);
        for g in check_geometries(k) {
            let panel = TilePanelTcsc::from_ternary_with(&w, g);
            for (name, y) in [
                ("scalar", run_scalar(&x, &panel, &bias)),
                ("simd", run_simd(&x, &panel, &bias)),
            ] {
                assert_eq!(
                    y, base,
                    "{name} m={m} k={k} n={n} s={s} geom={g}: not bitwise"
                );
                assert!(y.allclose(&oracle, 2e-3), "{name} geom={g} vs oracle");
            }
        }
    }

    fn run_scalar(x: &Matrix, w: &TilePanelTcsc, bias: &[f32]) -> Matrix {
        let mut y = Matrix::zeros(x.rows(), w.n());
        OuterTileKernel.run(x, w, bias, &mut y);
        y
    }

    fn run_simd(x: &Matrix, w: &TilePanelTcsc, bias: &[f32]) -> Matrix {
        let mut y = Matrix::zeros(x.rows(), w.n());
        OuterTileSimdKernel.run(x, w, bias, &mut y);
        y
    }

    #[test]
    fn bitwise_identical_to_baseline_across_sparsities() {
        for &s in &crate::PAPER_SPARSITIES {
            bitwise_check(8, 64, 16, s, 41);
        }
    }

    #[test]
    fn k_not_multiple_of_tile_and_odd_shapes() {
        bitwise_check(4, 97, 13, 0.5, 42); // K % 4 != 0, narrow last panel
        bitwise_check(3, 33, 7, 0.25, 43); // M below a full tile
        bitwise_check(7, 61, 5, 0.125, 44); // row remainder of 3
    }

    #[test]
    fn wide_panels_with_ragged_n() {
        bitwise_check(6, 48, 12, 0.5, 50); // N % 8 = 4: ragged last p8 panel
        bitwise_check(5, 40, 9, 0.25, 51); // N % 8 = 1 and N % 4 = 1
        bitwise_check(8, 32, 8, 0.5, 52); // exactly one full p8 panel
    }

    #[test]
    fn degenerate_m() {
        bitwise_check(0, 32, 8, 0.5, 45); // empty batch must not panic
        bitwise_check(1, 32, 8, 0.5, 46); // GEMV shape
    }

    #[test]
    fn k_block_boundary_shapes() {
        bitwise_check(4, 15, 8, 0.5, 53); // K < every nontrivial block
        bitwise_check(4, 14, 8, 0.5, 54); // K % 7 = 0: block divides K
        bitwise_check(4, 1, 8, 0.5, 55); // single K row
    }

    #[test]
    fn all_zero_matrix_yields_bias() {
        let w = TernaryMatrix::zeros(24, 6);
        let bias: Vec<f32> = (0..6).map(|i| i as f32).collect();
        let x = Matrix::random(5, 24, 47);
        for g in check_geometries(24) {
            let panel = TilePanelTcsc::from_ternary_with(&w, g);
            for y in [run_scalar(&x, &panel, &bias), run_simd(&x, &panel, &bias)] {
                for r in 0..5 {
                    for c in 0..6 {
                        assert_eq!(y[(r, c)], bias[c], "geom {g}");
                    }
                }
            }
        }
    }

    #[test]
    fn simd_buf_reuse_is_stable() {
        let w = TernaryMatrix::random(40, 12, 0.25, 48);
        let x = Matrix::random(6, 40, 49);
        let bias = vec![0.5f32; 12];
        for g in [TileGeometry::DEFAULT, TileGeometry::new(8, 16)] {
            let panel = TilePanelTcsc::from_ternary_with(&w, g);
            let mut xt = Vec::new();
            let mut y1 = Matrix::zeros(6, 12);
            OuterTileSimdKernel.run_with_buf(&x, &panel, &bias, &mut y1, &mut xt);
            let cap = xt.capacity();
            let mut y2 = Matrix::zeros(6, 12);
            OuterTileSimdKernel.run_with_buf(&x, &panel, &bias, &mut y2, &mut xt);
            assert_eq!(y1, y2);
            assert_eq!(xt.capacity(), cap, "steady-state reuse must not realloc");
        }
    }
}
