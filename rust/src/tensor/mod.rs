//! Dense tensors: cache-aligned row-major matrices of `f32`.

pub mod matrix;

pub use matrix::{Matrix, PaddedMatrix};
