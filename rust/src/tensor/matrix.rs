//! Row-major `f32` matrix with 64-byte-aligned storage.
//!
//! Alignment matters for the SIMD kernels (aligned 4-lane loads) and for
//! honest cache-line accounting in the locality experiments.

use crate::util::rng::Rng;

const ALIGN: usize = 64;

/// Row-major dense matrix of `f32`, 64-byte aligned.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: AlignedVec,
}

impl Matrix {
    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix {
            rows,
            cols,
            data: AlignedVec::zeroed(rows * cols),
        }
    }

    /// Matrix filled from a closure of (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Matrix {
        let mut m = Matrix::zeros(rows, cols);
        for r in 0..rows {
            for c in 0..cols {
                m[(r, c)] = f(r, c);
            }
        }
        m
    }

    /// Matrix from a row-major slice.
    pub fn from_slice(rows: usize, cols: usize, data: &[f32]) -> Matrix {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        let mut m = Matrix::zeros(rows, cols);
        m.as_mut_slice().copy_from_slice(data);
        m
    }

    /// Uniform random entries in [-1, 1), seeded.
    pub fn random(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::from_fn(rows, cols, |_, _| rng.f32_range(-1.0, 1.0))
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn as_slice(&self) -> &[f32] {
        self.data.as_slice()
    }

    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        self.data.as_mut_slice()
    }

    /// A single row as a slice.
    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        debug_assert!(r < self.rows);
        &self.as_slice()[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        debug_assert!(r < self.rows);
        let c = self.cols;
        &mut self.as_mut_slice()[r * c..(r + 1) * c]
    }

    pub fn fill(&mut self, v: f32) {
        self.as_mut_slice().fill(v);
    }

    /// Max absolute difference against another matrix of the same shape.
    pub fn max_abs_diff(&self, other: &Matrix) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.as_slice()
            .iter()
            .zip(other.as_slice())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Relative closeness check: |a-b| <= tol * max(1, |a|, |b|) everywhere.
    pub fn allclose(&self, other: &Matrix, tol: f32) -> bool {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.as_slice().iter().zip(other.as_slice()).all(|(a, b)| {
            let scale = 1.0_f32.max(a.abs()).max(b.abs());
            (a - b).abs() <= tol * scale
        })
    }

    /// Run `f` on a read-only `Matrix` aliasing `data` (rows × cols,
    /// contiguous row-major). Zero-copy: the plan partitioner uses this to
    /// hand a row chunk of X to kernels that take `&Matrix` without
    /// materializing the chunk. The temporary never owns the storage (its
    /// capacity is zero, so no deallocation can happen), and `f` receives a
    /// shared reference, so nothing can write through it.
    pub fn with_view<R>(
        data: &[f32],
        rows: usize,
        cols: usize,
        f: impl FnOnce(&Matrix) -> R,
    ) -> R {
        assert_eq!(data.len(), rows * cols, "view shape mismatch");
        let m = std::mem::ManuallyDrop::new(Matrix {
            rows,
            cols,
            data: AlignedVec {
                ptr: data.as_ptr() as *mut f32,
                len: data.len(),
                cap_bytes: 0,
            },
        });
        f(&m)
    }

    /// Mutable counterpart of [`Matrix::with_view`]: `f` gets a `Matrix`
    /// aliasing `data` and writes land directly in the caller's slice. Used
    /// to let a kernel write its output into a disjoint row block of a
    /// larger Y with no intermediate buffer or stitch copy.
    pub fn with_view_mut<R>(
        data: &mut [f32],
        rows: usize,
        cols: usize,
        f: impl FnOnce(&mut Matrix) -> R,
    ) -> R {
        assert_eq!(data.len(), rows * cols, "view shape mismatch");
        let mut m = std::mem::ManuallyDrop::new(Matrix {
            rows,
            cols,
            data: AlignedVec {
                ptr: data.as_mut_ptr(),
                len: data.len(),
                cap_bytes: 0,
            },
        });
        f(&mut m)
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f32;
    #[inline]
    fn index(&self, (r, c): (usize, usize)) -> &f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &self.as_slice()[r * self.cols + c]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        let cols = self.cols;
        &mut self.as_mut_slice()[r * cols + c]
    }
}

/// An activation matrix padded with one trailing zero element per row
/// region, for the symmetric SIMD format whose deficit lanes point at a
/// dummy index (reads must yield exactly 0.0). Layout: each row is
/// `k + 1` long; element `k` of every row is 0.0 and never written.
#[derive(Debug, Clone)]
pub struct PaddedMatrix {
    rows: usize,
    k: usize,
    data: AlignedVec,
}

impl PaddedMatrix {
    /// Copy `x` (M×K) into padded storage with stride K+1 and a zero pad slot.
    pub fn from_matrix(x: &Matrix) -> PaddedMatrix {
        let rows = x.rows();
        let k = x.cols();
        let mut data = AlignedVec::zeroed(rows * (k + 1));
        for r in 0..rows {
            data.as_mut_slice()[r * (k + 1)..r * (k + 1) + k].copy_from_slice(x.row(r));
        }
        PaddedMatrix { rows, k, data }
    }

    /// All-zero padded storage sized for `rows` × `k` (scratch pre-sizing).
    pub fn with_capacity(rows: usize, k: usize) -> PaddedMatrix {
        PaddedMatrix {
            rows,
            k,
            data: AlignedVec::zeroed(rows * (k + 1)),
        }
    }

    /// Backing capacity in f32 elements (allocation-stability accounting).
    pub fn capacity(&self) -> usize {
        self.data.capacity()
    }

    /// Re-fill from `x`, reusing the existing allocation whenever it is
    /// large enough (the serving hot path: repeated batches at a steady M
    /// perform no allocation). Falls back to a fresh allocation only when
    /// `x` needs more storage than the current capacity.
    pub fn copy_from(&mut self, x: &Matrix) {
        let rows = x.rows();
        let k = x.cols();
        let needed = rows * (k + 1);
        if needed > self.data.capacity() {
            *self = PaddedMatrix::from_matrix(x);
            return;
        }
        self.rows = rows;
        self.k = k;
        self.data.set_len(needed);
        let stride = k + 1;
        let dst = self.data.as_mut_slice();
        for r in 0..rows {
            dst[r * stride..r * stride + k].copy_from_slice(x.row(r));
            dst[r * stride + k] = 0.0;
        }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Logical K (row length without the pad slot).
    #[inline]
    pub fn k(&self) -> usize {
        self.k
    }

    /// The index that always reads 0.0 — used as the dummy target for
    /// deficit lanes in the symmetric format.
    #[inline]
    pub fn dummy_index(&self) -> u32 {
        self.k as u32
    }

    /// Row slice of length K+1 (including the zero pad slot at index K).
    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        debug_assert!(r < self.rows);
        &self.data.as_slice()[r * (self.k + 1)..(r + 1) * (self.k + 1)]
    }
}

/// 64-byte-aligned `Vec<f32>` replacement.
#[derive(Debug)]
struct AlignedVec {
    ptr: *mut f32,
    len: usize,
    cap_bytes: usize,
}

// SAFETY: AlignedVec owns its allocation exclusively; f32 is Send + Sync.
unsafe impl Send for AlignedVec {}
unsafe impl Sync for AlignedVec {}

impl AlignedVec {
    fn zeroed(len: usize) -> AlignedVec {
        if len == 0 {
            return AlignedVec {
                ptr: std::ptr::NonNull::<f32>::dangling().as_ptr(),
                len: 0,
                cap_bytes: 0,
            };
        }
        let bytes = len * std::mem::size_of::<f32>();
        let layout = std::alloc::Layout::from_size_align(bytes, ALIGN).expect("layout");
        // SAFETY: layout has non-zero size; alloc_zeroed returns valid or null.
        let ptr = unsafe { std::alloc::alloc_zeroed(layout) } as *mut f32;
        assert!(!ptr.is_null(), "allocation failed ({bytes} bytes)");
        AlignedVec {
            ptr,
            len,
            cap_bytes: bytes,
        }
    }

    /// Capacity in f32 elements. Borrowed views report 0 (they own nothing).
    #[inline]
    fn capacity(&self) -> usize {
        self.cap_bytes / std::mem::size_of::<f32>()
    }

    /// Shrink or re-grow the logical length within the existing capacity.
    #[inline]
    fn set_len(&mut self, len: usize) {
        assert!(len <= self.capacity(), "set_len beyond capacity");
        self.len = len;
    }

    #[inline]
    fn as_slice(&self) -> &[f32] {
        // SAFETY: ptr valid for len f32s (or dangling with len 0).
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }

    #[inline]
    fn as_mut_slice(&mut self) -> &mut [f32] {
        // SAFETY: exclusive access via &mut self.
        unsafe { std::slice::from_raw_parts_mut(self.ptr, self.len) }
    }
}

impl Clone for AlignedVec {
    fn clone(&self) -> Self {
        let mut v = AlignedVec::zeroed(self.len);
        v.as_mut_slice().copy_from_slice(self.as_slice());
        v
    }
}

impl PartialEq for AlignedVec {
    fn eq(&self, other: &Self) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl Drop for AlignedVec {
    fn drop(&mut self) {
        if self.cap_bytes > 0 {
            let layout =
                std::alloc::Layout::from_size_align(self.cap_bytes, ALIGN).expect("layout");
            // SAFETY: allocated with the same layout in zeroed().
            unsafe { std::alloc::dealloc(self.ptr as *mut u8, layout) };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_index() {
        let mut m = Matrix::zeros(3, 4);
        assert_eq!(m[(2, 3)], 0.0);
        m[(1, 2)] = 5.0;
        assert_eq!(m[(1, 2)], 5.0);
        assert_eq!(m.row(1), &[0.0, 0.0, 5.0, 0.0]);
    }

    #[test]
    fn alignment_is_64() {
        for n in [1usize, 7, 64, 1000] {
            let m = Matrix::zeros(n, n);
            assert_eq!(m.as_slice().as_ptr() as usize % 64, 0);
        }
    }

    #[test]
    fn from_fn_layout_row_major() {
        let m = Matrix::from_fn(2, 3, |r, c| (r * 10 + c) as f32);
        assert_eq!(m.as_slice(), &[0.0, 1.0, 2.0, 10.0, 11.0, 12.0]);
    }

    #[test]
    fn from_slice_roundtrip() {
        let data = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let m = Matrix::from_slice(2, 3, &data);
        assert_eq!(m.as_slice(), &data);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn from_slice_rejects_bad_shape() {
        Matrix::from_slice(2, 2, &[1.0, 2.0, 3.0]);
    }

    #[test]
    fn random_is_seeded() {
        let a = Matrix::random(4, 4, 99);
        let b = Matrix::random(4, 4, 99);
        let c = Matrix::random(4, 4, 100);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert!(a.as_slice().iter().all(|v| (-1.0..1.0).contains(v)));
    }

    #[test]
    fn allclose_and_diff() {
        let a = Matrix::from_slice(1, 3, &[1.0, 2.0, 3.0]);
        let mut b = a.clone();
        assert!(a.allclose(&b, 1e-6));
        b[(0, 2)] = 3.001;
        assert!(!a.allclose(&b, 1e-6));
        assert!(a.allclose(&b, 1e-2));
        assert!((a.max_abs_diff(&b) - 0.001).abs() < 1e-6);
    }

    #[test]
    fn zero_sized_matrix_ok() {
        let m = Matrix::zeros(0, 5);
        assert_eq!(m.as_slice().len(), 0);
        let m2 = m.clone();
        assert_eq!(m, m2);
    }

    #[test]
    fn views_alias_without_copy() {
        let x = Matrix::from_fn(4, 3, |r, c| (r * 3 + c) as f32);
        // Read-only view of rows 1..3.
        let chunk = &x.as_slice()[3..9];
        Matrix::with_view(chunk, 2, 3, |v| {
            assert_eq!(v.rows(), 2);
            assert_eq!(v.row(0), x.row(1));
            assert_eq!(v.row(1), x.row(2));
        });
        // Mutable view writes land in the original storage.
        let mut y = Matrix::zeros(4, 3);
        {
            let rows = y.as_mut_slice();
            let (_, tail) = rows.split_at_mut(6);
            Matrix::with_view_mut(tail, 2, 3, |v| {
                v.row_mut(0).copy_from_slice(&[1.0, 2.0, 3.0]);
                v[(1, 2)] = 9.0;
            });
        }
        assert_eq!(y.row(2), &[1.0, 2.0, 3.0]);
        assert_eq!(y[(3, 2)], 9.0);
        assert_eq!(y.row(0), &[0.0, 0.0, 0.0]);
    }

    #[test]
    fn padded_copy_from_reuses_allocation() {
        let x8 = Matrix::random(8, 16, 1);
        let mut p = PaddedMatrix::from_matrix(&x8);
        let cap = p.capacity();
        assert_eq!(cap, 8 * 17);
        // Same shape: no reallocation, contents replaced.
        let x8b = Matrix::random(8, 16, 2);
        p.copy_from(&x8b);
        assert_eq!(p.capacity(), cap);
        assert_eq!(&p.row(3)[..16], x8b.row(3));
        assert_eq!(p.row(3)[16], 0.0);
        // Smaller batch: still no reallocation.
        let x2 = Matrix::random(2, 16, 3);
        p.copy_from(&x2);
        assert_eq!(p.capacity(), cap);
        assert_eq!(p.rows(), 2);
        assert_eq!(&p.row(1)[..16], x2.row(1));
        // Larger batch: grows.
        let x16 = Matrix::random(16, 16, 4);
        p.copy_from(&x16);
        assert!(p.capacity() >= 16 * 17);
        assert_eq!(&p.row(15)[..16], x16.row(15));
        assert_eq!(p.row(15)[16], 0.0);
    }

    #[test]
    fn padded_matrix_dummy_reads_zero() {
        let x = Matrix::random(3, 8, 7);
        let p = PaddedMatrix::from_matrix(&x);
        assert_eq!(p.dummy_index(), 8);
        for r in 0..3 {
            let row = p.row(r);
            assert_eq!(row.len(), 9);
            assert_eq!(row[8], 0.0);
            assert_eq!(&row[..8], x.row(r));
        }
    }
}
