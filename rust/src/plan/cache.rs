//! [`PlanCache`]: M-bucketed plan reuse for the serving path.
//!
//! The serving workload is a stream of batches whose row count M varies
//! request-to-request (the dynamic batcher closes on whatever has queued).
//! A single [`GemmPlan`] per layer — PR 1's design — freezes the scratch
//! pre-sizing and thread fan-out at whatever the config guessed, and every
//! change of execution policy would mean re-planning on the hot path.
//!
//! The cache fixes both: plans are keyed by **(layer, M-bucket, threads)**
//! and built lazily on first traffic, then reused until a background
//! re-tune swaps them ([`PlanCache::rebuild`]). M is bucketed
//! to powers of two (capped at [`MAX_M_BUCKET`]) so a mixed-M stream
//! converges onto a handful of plans; the thread count is part of the key
//! so the load-aware coordinator can re-size fan-out without touching
//! existing plans.
//!
//! Kernel choice per layer: the explicit [`KernelId`] override if the
//! spec pins one, else the shared [`Planner`]'s tuning table (M-aware
//! entries first, then the M-agnostic fallback), else — uniquely to this
//! layer of the stack — an **online top-2 race**: the first real batch of
//! an untuned (K, sparsity, M-bucket) class runs both paper-candidate
//! kernels, times them, and records the winner in the shared table
//! **under the M-aware class**, so every other layer and engine skips the
//! race for that bucket while other buckets still get their own race — a
//! kernel that wins at M=1 is never silently locked in for M=64.
//!
//! Everything here dispatches on typed [`KernelId`]s: a tuning entry
//! naming a kernel the registry doesn't know is unrepresentable, so the
//! PR-2-era "poisoned table entry" failure mode (and its heuristic
//! fallback on the serving path) is gone by construction.
//!
//! Blocking geometry rides the same machinery: prepared formats are
//! keyed **(kernel, geometry)** so two plans at different tile geometries
//! never alias one format, the online race times geometry variants of
//! geometry-axis kernels alongside the rival kernel, and a winning
//! non-default geometry is recorded in the shared table next to the
//! winning kernel ([`TuneEntry::geometry`]).
//!
//! Multi-layer forwards: the cache also compiles and caches **wavefront
//! pipelines** ([`MlpPlan`], keyed (M-bucket, threads) like plans) over
//! the whole registered layer chain, with intermediates in a shared
//! [`ActivationArena`] — see [`PlanCache::run_pipelined`] /
//! [`PlanCache::run_layers`] and [`crate::plan::pipeline`].

use crate::autotune::{ShapeClass, TuneEntry};
use crate::formats::TileGeometry;
use crate::kernels::{GemmScratch, KernelId, KernelParams, PreparedGemm};
use crate::perf::timer::{CycleTimer, Measurement};
use crate::plan::gemm_plan::{Epilogue, GemmPlan};
use crate::plan::partition::RowPartition;
use crate::plan::pipeline::{ActivationArena, ArenaStats, MlpPlan, PipelineMode, PipelineStats};
use crate::plan::planner::{heuristic_top2_caps, Planner};
use crate::tensor::Matrix;
use crate::ternary::TernaryMatrix;
use crate::{Error, Result};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};

// The canonical M bucketing lives next to `ShapeClass` so plan keys and
// M-aware tuning classes can never disagree; re-exported here because the
// plan cache is where callers meet it.
pub use crate::autotune::table::{m_bucket, MAX_M_BUCKET};

/// Handle to a registered layer (index into the cache's layer list).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LayerId(usize);

/// Everything the cache needs to (re)build a layer's plans on demand.
pub struct LayerSpec {
    /// Dense ternary weights; kept so any bucket's plan (and the top-2
    /// race's rival format) can be prepared lazily.
    pub weights: TernaryMatrix,
    pub params: KernelParams,
    pub epilogue: Epilogue,
    /// Explicit registry kernel override; `None` = table/heuristic/race.
    pub kernel: Option<KernelId>,
    /// Minimum rows per parallel chunk (see [`crate::plan::RowPartition`]).
    pub min_rows_per_chunk: usize,
}

impl LayerSpec {
    /// Spec with default params, no override, paper chunking.
    pub fn new(weights: TernaryMatrix, epilogue: Epilogue) -> LayerSpec {
        LayerSpec {
            weights,
            params: KernelParams::default(),
            epilogue,
            kernel: None,
            min_rows_per_chunk: 2,
        }
    }
}

/// Cache construction knobs.
#[derive(Debug, Clone)]
pub struct PlanCacheConfig {
    /// Initial worker-thread ceiling (live-adjustable via
    /// [`PlanCache::set_threads`]; the load-aware router uses that).
    pub threads: usize,
    /// Race the top-2 candidate kernels on the first real batch of an
    /// untuned (K, sparsity) class and record the winner.
    pub online_top2: bool,
    /// Timing reps per candidate in the online race (plus one warmup).
    pub race_reps: usize,
}

impl Default for PlanCacheConfig {
    fn default() -> Self {
        PlanCacheConfig {
            threads: 1,
            online_top2: true,
            race_reps: 2,
        }
    }
}

/// Monotonic cache counters (relaxed; for tests and /metrics).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheSnapshot {
    /// Runs served by an already-built plan.
    pub hits: u64,
    /// Runs (or `plan_for` calls) that had to build a plan.
    pub misses: u64,
    /// Online top-2 races executed.
    pub races: u64,
    /// Plans currently cached across all layers.
    pub plans: usize,
    /// Pipelined forwards served by an already-compiled [`MlpPlan`].
    pub pipeline_hits: u64,
    /// Pipelined forwards that had to compile an [`MlpPlan`].
    pub pipeline_misses: u64,
    /// Pipelines currently cached across (bucket, threads) keys.
    pub pipeline_plans: usize,
}

/// (M-bucket, threads) → plan.
type PlanMap = BTreeMap<(usize, usize), Arc<GemmPlan>>;

/// (Kernel, tile geometry) → prepared format. The expensive part of a
/// plan is the sparse-format construction, which depends only on
/// (weights, params, kernel, geometry) — never on the M-bucket or thread
/// count — so every plan key of a layer shares one prepared GEMM per
/// (kernel, geometry) pair. Kernels without the geometry axis always key
/// under [`TileGeometry::DEFAULT`].
type GemmMap = BTreeMap<(KernelId, TileGeometry), Arc<dyn PreparedGemm>>;

struct CachedLayer {
    spec: LayerSpec,
    /// Built lazily, kept until invalidated.
    plans: Mutex<PlanMap>,
    /// Shared prepared formats (kept across [`PlanCache::invalidate`];
    /// bounded by the handful of kernels a class ever selects).
    gemms: Mutex<GemmMap>,
}

/// M-bucketed, thread-aware plan cache shared by a model's layers.
pub struct PlanCache {
    planner: Arc<Planner>,
    online_top2: bool,
    race_reps: usize,
    threads: AtomicUsize,
    layers: RwLock<Vec<Arc<CachedLayer>>>,
    hits: AtomicU64,
    misses: AtomicU64,
    races: AtomicU64,
    /// Compiled wavefront pipelines, keyed like plans by
    /// (M-bucket, threads); cleared alongside them on invalidate/register.
    pipelines: Mutex<BTreeMap<(usize, usize), Arc<MlpPlan>>>,
    /// Layer-set generation: bumped by [`PlanCache::register`] so a
    /// pipeline compiled concurrently over the *old* layer set is never
    /// inserted after the register-time clear (stale-plan race).
    generation: AtomicU64,
    /// Shared activation arena for pipelined and barrier multi-layer
    /// forwards; built lazily once the layer set is known.
    arena: Mutex<Option<Arc<ActivationArena>>>,
    /// Whether warm-up should pre-compile wavefront pipelines (`false`
    /// for `--no-pipeline` models: their forwards only ever take the
    /// barrier path, so warmed pipelines would be dead weight).
    pipelining: AtomicBool,
    pipeline_hits: AtomicU64,
    pipeline_misses: AtomicU64,
}

impl PlanCache {
    pub fn new(planner: Arc<Planner>, cfg: PlanCacheConfig) -> PlanCache {
        PlanCache {
            planner,
            online_top2: cfg.online_top2,
            race_reps: cfg.race_reps.max(1),
            threads: AtomicUsize::new(cfg.threads.max(1)),
            layers: RwLock::new(Vec::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            races: AtomicU64::new(0),
            pipelines: Mutex::new(BTreeMap::new()),
            generation: AtomicU64::new(0),
            arena: Mutex::new(None),
            pipelining: AtomicBool::new(true),
            pipeline_hits: AtomicU64::new(0),
            pipeline_misses: AtomicU64::new(0),
        }
    }

    /// The shared planner (tuning table owner).
    pub fn planner(&self) -> &Arc<Planner> {
        &self.planner
    }

    /// Register a layer; plans are built lazily per (M-bucket, threads).
    ///
    /// Everything a kernel build could reject is validated here, so a
    /// registered layer's lazy builds cannot fail mid-traffic (the batch
    /// loop has no caller left to surface an error to). Kernel identity is
    /// typed — an unknown kernel cannot reach this point — and an explicit
    /// override naming a capability-gated kernel the planner's CPU cannot
    /// run is rejected up front ([`Error::UnsupportedKernel`]), keeping
    /// plans for unavailable capabilities unrepresentable in the cache.
    pub fn register(&self, spec: LayerSpec) -> Result<LayerId> {
        if spec.epilogue.bias.len() != spec.weights.n() {
            return Err(Error::Shape(format!(
                "bias length {} != N {}",
                spec.epilogue.bias.len(),
                spec.weights.n()
            )));
        }
        spec.params.validate()?;
        if let Some(kernel) = spec.kernel {
            let d = kernel.descriptor();
            if !self.planner.caps().satisfies(d.requires) {
                return Err(Error::UnsupportedKernel(format!(
                    "kernel '{}' requires {:?}, which the planner's CPU \
                     capabilities do not provide",
                    d.name, d.requires
                )));
            }
        }
        let id = {
            let mut layers = self.layers.write().unwrap_or_else(|e| e.into_inner());
            layers.push(Arc::new(CachedLayer {
                spec,
                plans: Mutex::new(BTreeMap::new()),
                gemms: Mutex::new(BTreeMap::new()),
            }));
            LayerId(layers.len() - 1)
        };
        // The layer set changed: compiled pipelines and the arena sizing
        // are stale. The generation bump keeps an in-flight concurrent
        // compile over the old layer set from being inserted after this
        // clear (see `PlanCache::cache_pipeline`).
        self.generation.fetch_add(1, Ordering::SeqCst);
        self.pipelines
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .clear();
        *self.arena.lock().unwrap_or_else(|e| e.into_inner()) = None;
        Ok(id)
    }

    pub fn num_layers(&self) -> usize {
        self.layers.read().unwrap_or_else(|e| e.into_inner()).len()
    }

    /// Current worker-thread ceiling.
    pub fn threads(&self) -> usize {
        self.threads.load(Ordering::Relaxed)
    }

    /// Re-size the worker-thread ceiling (load-aware coordinator). Plans
    /// for the new count are built on first use; existing plans remain.
    pub fn set_threads(&self, threads: usize) {
        self.threads.store(threads.max(1), Ordering::Relaxed);
    }

    fn layer(&self, id: LayerId) -> Arc<CachedLayer> {
        self.layers.read().unwrap_or_else(|e| e.into_inner())[id.0].clone()
    }

    pub fn k(&self, id: LayerId) -> usize {
        self.layer(id).spec.weights.k()
    }

    pub fn n(&self, id: LayerId) -> usize {
        self.layer(id).spec.weights.n()
    }

    pub fn nnz(&self, id: LayerId) -> usize {
        self.layer(id).spec.weights.nnz()
    }

    pub fn scale(&self, id: LayerId) -> f32 {
        self.layer(id).spec.epilogue.scale
    }

    pub fn prelu_alpha(&self, id: LayerId) -> Option<f32> {
        self.layer(id).spec.epilogue.prelu_alpha
    }

    /// Paper cost-model flops for an M-row batch of this layer (same
    /// accounting as [`GemmPlan::flops`]).
    pub fn flops(&self, id: LayerId, m: usize) -> f64 {
        let layer = self.layer(id);
        let n = layer.spec.weights.n();
        let mut f = m as f64 * layer.spec.weights.nnz() as f64 + (m * n) as f64;
        if layer.spec.epilogue.prelu_alpha.is_some() {
            f += (m * n) as f64;
        }
        f
    }

    /// The kernel a plan for batch size `m` would use right now: explicit
    /// override, else the shared table (the M-aware entry for `m`'s
    /// bucket first, then the M-agnostic fallback), else the paper
    /// heuristic. (The online race may still overturn the heuristic on
    /// first traffic in that bucket.)
    pub fn kernel_for(&self, id: LayerId, m: usize) -> KernelId {
        let layer = self.layer(id);
        self.kernel_for_spec(&layer.spec, m_bucket(m)).0
    }

    /// The tile geometry a plan for batch size `m` would build its format
    /// at right now — `None` for kernels without the geometry axis and
    /// for axis kernels staying at [`TileGeometry::DEFAULT`]. Serve-time
    /// introspection (`/metrics`) and tests.
    pub fn geometry_for(&self, id: LayerId, m: usize) -> Option<TileGeometry> {
        let layer = self.layer(id);
        self.kernel_for_spec(&layer.spec, m_bucket(m)).1
    }

    /// Kernel **and** geometry for a spec at an M-bucket: an explicit
    /// spec kernel takes the policy geometry (when it carries the axis),
    /// auto specs resolve through the planner (tuned entry first). An
    /// explicit `spec.params.geometry` pin overrides either.
    fn kernel_for_spec(
        &self,
        spec: &LayerSpec,
        bucket: usize,
    ) -> (KernelId, Option<TileGeometry>) {
        let (kernel, selected) = match spec.kernel {
            Some(k) => (k, self.policy_geometry(k)),
            None => self.planner.select_kernel_geometry(
                spec.weights.k(),
                spec.weights.density() as f32,
                bucket,
                spec.epilogue.fusible_prelu().is_some(),
            ),
        };
        (kernel, spec.params.geometry.or(selected))
    }

    /// The planner's policy geometry for `kernel`, `None` when its
    /// descriptor lacks the geometry axis.
    fn policy_geometry(&self, kernel: KernelId) -> Option<TileGeometry> {
        if kernel.descriptor().geometry {
            Some(self.planner.blocking_policy().geometry)
        } else {
            None
        }
    }

    fn effective_threads(&self, bucket: usize) -> usize {
        // `bucket >= 1`, so this is a plain ceiling, not a clamp.
        self.threads().clamp(1, bucket)
    }

    /// The shared prepared format for `(kernel, geometry)` (built once per
    /// layer × kernel × geometry; every plan key reuses it).
    fn prepared_gemm(
        &self,
        layer: &CachedLayer,
        kernel: KernelId,
        geometry: Option<TileGeometry>,
    ) -> Result<Arc<dyn PreparedGemm>> {
        let key = (kernel, geometry.unwrap_or(TileGeometry::DEFAULT));
        let cached = {
            let gemms = layer.gemms.lock().unwrap_or_else(|e| e.into_inner());
            gemms.get(&key).cloned()
        };
        if let Some(gemm) = cached {
            return Ok(gemm);
        }
        // Same fusion and blocking rules as `Planner::plan`: the kernel
        // fuses PReLU only when the epilogue allows it bit-exactly, and
        // the paper block-size constant (the `Default`) is a sentinel the
        // cache-driven policy replaces — an explicit non-default block is
        // honored verbatim.
        let block_size = if layer.spec.params.block_size == crate::PAPER_BLOCK_SIZE {
            self.planner.blocking_policy().scalar_block
        } else {
            layer.spec.params.block_size
        };
        let kparams = KernelParams {
            prelu_alpha: layer.spec.epilogue.fusible_prelu(),
            block_size,
            geometry,
            ..layer.spec.params
        };
        let gemm: Arc<dyn PreparedGemm> =
            kernel.prepare(&layer.spec.weights, kparams)?.into();
        Ok(layer
            .gemms
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .entry(key)
            .or_insert(gemm)
            .clone())
    }

    /// Assemble a plan over the shared prepared format: partition, pool
    /// hookup and scratch pre-sized for `bucket` rows. Mirrors
    /// `Planner::plan` exactly, minus the per-plan format build.
    fn build_plan(
        &self,
        layer: &CachedLayer,
        bucket: usize,
        threads: usize,
        kernel: KernelId,
        geometry: Option<TileGeometry>,
    ) -> Result<Arc<GemmPlan>> {
        let gemm = self.prepared_gemm(layer, kernel, geometry)?;
        let threads = threads.max(1);
        let partition = RowPartition::new(threads, layer.spec.min_rows_per_chunk);
        let pool = if threads > 1 {
            Some(self.planner.shared_pool())
        } else {
            None
        };
        let mut scratches: Vec<GemmScratch> =
            (0..threads).map(|_| GemmScratch::new()).collect();
        if gemm.uses_padded_scratch() {
            for (i, &(lo, hi)) in partition.ranges(bucket).iter().enumerate() {
                scratches[i].reserve_padded(hi - lo, layer.spec.weights.k());
            }
        }
        if gemm.uses_tile_scratch() {
            for s in &mut scratches {
                s.reserve_tile(layer.spec.weights.k());
            }
        }
        Ok(Arc::new(GemmPlan {
            gemm,
            epilogue: layer.spec.epilogue.clone(),
            partition,
            pool,
            scratch: Mutex::new(scratches),
        }))
    }

    /// Build with the spec/table/heuristic kernel choice. With typed
    /// kernel ids a table entry can never name a missing kernel, and
    /// params were validated at registration — so unlike the PR-2 string
    /// era there is no "poisoned entry" fallback path here.
    fn build_auto(
        &self,
        layer: &CachedLayer,
        bucket: usize,
        threads: usize,
    ) -> Result<Arc<GemmPlan>> {
        let (kernel, geometry) = self.kernel_for_spec(&layer.spec, bucket);
        self.build_plan(layer, bucket, threads, kernel, geometry)
    }

    /// Time both top-2 candidates on the live batch — geometry-axis
    /// candidates at both the policy geometry and the default layout —
    /// record the winner in the shared table **under the M-aware class**
    /// (this bucket's race must not decide other buckets' kernels), and
    /// return the winning plan. A winning non-default geometry is recorded
    /// in the entry; an entry without one means the default layout won.
    fn race_top2(
        &self,
        layer: &CachedLayer,
        bucket: usize,
        threads: usize,
        x: &Matrix,
    ) -> Result<Arc<GemmPlan>> {
        self.races.fetch_add(1, Ordering::Relaxed);
        let spec = &layer.spec;
        let k = spec.weights.k();
        let sparsity = spec.weights.density() as f32;
        let wants_fused = spec.epilogue.fusible_prelu().is_some();
        let caps = self.planner.caps();
        let [a, b] = heuristic_top2_caps(&caps, k, sparsity, bucket, wants_fused);
        // Each candidate kernel enters at every geometry worth timing: an
        // explicit spec pin freezes the axis, geometry-axis kernels race
        // the policy pick against the default layout (when they differ),
        // everything else runs its single variant. Bounded: 2 kernels ×
        // ≤ 2 geometries = ≤ 4 timed plans per race.
        let mut variants: Vec<(KernelId, Option<TileGeometry>)> = Vec::with_capacity(4);
        for kernel in [a, b] {
            if spec.params.geometry.is_some() {
                variants.push((kernel, spec.params.geometry));
                continue;
            }
            match self.policy_geometry(kernel) {
                Some(g) => {
                    variants.push((kernel, Some(g)));
                    if g != TileGeometry::DEFAULT {
                        variants.push((kernel, Some(TileGeometry::DEFAULT)));
                    }
                }
                None => variants.push((kernel, None)),
            }
        }
        let timer = CycleTimer::new(1, self.race_reps);
        let mut y = Matrix::zeros(x.rows(), spec.weights.n());
        let mut best: Option<(Arc<GemmPlan>, Measurement, KernelId, Option<TileGeometry>)> =
            None;
        for (kernel, geometry) in variants {
            let plan = self.build_plan(layer, bucket, threads, kernel, geometry)?;
            // One checked run per candidate first: a worker panic must
            // surface as a typed error, not vanish inside the timing loop.
            plan.run(x, &mut y)?;
            let meas = timer.run(|| {
                let _ = plan.run(x, &mut y);
            });
            // Strict `<` keeps the earlier candidate on ties — the same
            // lead-candidate preference the two-plan race had.
            let better = match &best {
                Some((_, m, _, _)) => meas.cycles < m.cycles,
                None => true,
            };
            if better {
                best = Some((plan, meas, kernel, geometry));
            }
        }
        let (winner, meas, kernel, geometry) =
            best.expect("top-2 race always times at least two variants");
        let flops = winner.flops(x.rows());
        let mut entry = TuneEntry::new(kernel, meas.flops_per_cycle(flops));
        // Record geometry only when it diverges from the default layout —
        // absence means default, so old and new tables read the same way.
        entry.geometry = geometry.filter(|g| *g != TileGeometry::DEFAULT);
        self.planner.record(ShapeClass::of_m(k, sparsity, bucket), entry);
        Ok(winner)
    }

    /// The plan for batch size `m` at the current thread ceiling, building
    /// it (without racing — there is no live batch to time) on a miss.
    pub fn plan_for(&self, id: LayerId, m: usize) -> Result<Arc<GemmPlan>> {
        let layer = self.layer(id);
        let bucket = m_bucket(m);
        let threads = self.effective_threads(bucket);
        let key = (bucket, threads);
        // Bind outside the `if let` so the guard drops before any work.
        let cached = self.plans_lock(&layer).get(&key).cloned();
        if let Some(plan) = cached {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(plan);
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let built = self.build_auto(&layer, bucket, threads)?;
        Ok(self.plans_lock(&layer).entry(key).or_insert(built).clone())
    }

    fn plans_lock<'a>(&self, layer: &'a CachedLayer) -> std::sync::MutexGuard<'a, PlanMap> {
        layer.plans.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Run layer `id` on `x` into `y` through the cached plan for `x`'s
    /// M-bucket, building (and, for untuned auto classes, racing) on the
    /// first sighting of the bucket.
    pub fn run(&self, id: LayerId, x: &Matrix, y: &mut Matrix) -> Result<()> {
        let layer = self.layer(id);
        let bucket = m_bucket(x.rows());
        let threads = self.effective_threads(bucket);
        let key = (bucket, threads);
        // Bind outside the `if let` so the map guard drops before the GEMM
        // runs — concurrent batches on different buckets must not contend.
        let cached = self.plans_lock(&layer).get(&key).cloned();
        if let Some(plan) = cached {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return plan.run(x, y);
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let spec = &layer.spec;
        // Untuned for *this bucket*: neither an M-aware entry nor the
        // M-agnostic fallback covers it, so this bucket gets its own race.
        let untuned = self
            .planner
            .lookup_entry(spec.weights.k(), spec.weights.density() as f32, bucket)
            .is_none();
        let built = if spec.kernel.is_none() && self.online_top2 && untuned {
            self.race_top2(&layer, bucket, threads, x)?
        } else {
            self.build_auto(&layer, bucket, threads)?
        };
        // First insert wins so concurrent builders converge on one plan.
        let plan = self
            .plans_lock(&layer)
            .entry(key)
            .or_insert(built)
            .clone();
        plan.run(x, y)
    }

    /// Allocating convenience: run into a fresh M×N matrix.
    pub fn forward(&self, id: LayerId, x: &Matrix) -> Result<Matrix> {
        let mut y = Matrix::zeros(x.rows(), self.n(id));
        self.run(id, x, &mut y)?;
        Ok(y)
    }

    /// Whether the registered layers form a chain (`N_i == K_{i+1}`) the
    /// multi-layer paths can execute end to end. A model's cache always
    /// does; caches holding unrelated layers (tests, tools) don't.
    fn layers_chain(&self) -> bool {
        let layers = self.layers.read().unwrap_or_else(|e| e.into_inner());
        !layers.is_empty()
            && layers
                .windows(2)
                .all(|pair| pair[0].spec.weights.n() == pair[1].spec.weights.k())
    }

    /// The shared activation arena, sized to the widest intermediate
    /// activation of the registered layer chain (built lazily; reset when
    /// a layer is registered).
    fn arena(&self) -> Arc<ActivationArena> {
        let mut guard = self.arena.lock().unwrap_or_else(|e| e.into_inner());
        guard
            .get_or_insert_with(|| {
                let layers = self.layers.read().unwrap_or_else(|e| e.into_inner());
                let widest = layers
                    .iter()
                    .take(layers.len().saturating_sub(1))
                    .map(|l| l.spec.weights.n())
                    .max()
                    .unwrap_or(0);
                Arc::new(ActivationArena::new(widest))
            })
            .clone()
    }

    /// Activation-arena counters (zero-allocation steady-state assertion,
    /// /metrics).
    pub fn arena_stats(&self) -> ArenaStats {
        self.arena
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .as_ref()
            .map(|a| a.stats())
            .unwrap_or_default()
    }

    /// Whether the kernel choice for `layer` at batch size `m` is already
    /// settled: an explicit override, a tuning-table entry resolving for
    /// `m`'s bucket (M-aware or the M-agnostic fallback), or racing
    /// disabled. Unsettled choices belong to the online top-2 race.
    fn settled_for(&self, layer: &CachedLayer, m: usize) -> bool {
        layer.spec.kernel.is_some()
            || !self.online_top2
            || self
                .planner
                .lookup_entry(
                    layer.spec.weights.k(),
                    layer.spec.weights.density() as f32,
                    m,
                )
                .is_some()
    }

    /// Compile an [`MlpPlan`] over **all registered layers** (in
    /// registration order) for batch size `m` at the current thread
    /// ceiling — uncached, so benches can compile
    /// [`PipelineMode::Barrier`] twins for stall comparisons.
    ///
    /// # Errors
    /// [`Error::Shape`] when the layers do not chain, [`Error::Config`]
    /// when none are registered.
    pub fn compile_pipeline(&self, m: usize, mode: PipelineMode) -> Result<Arc<MlpPlan>> {
        let bucket = m_bucket(m);
        self.build_pipeline(bucket, self.effective_threads(bucket), mode)
    }

    fn build_pipeline(
        &self,
        bucket: usize,
        threads: usize,
        mode: PipelineMode,
    ) -> Result<Arc<MlpPlan>> {
        let layers: Vec<Arc<CachedLayer>> = self
            .layers
            .read()
            .unwrap_or_else(|e| e.into_inner())
            .clone();
        if layers.is_empty() {
            return Err(Error::Config("no layers registered".into()));
        }
        let mut specs = Vec::with_capacity(layers.len());
        for layer in &layers {
            let (kernel, geometry) = self.kernel_for_spec(&layer.spec, bucket);
            let gemm = self.prepared_gemm(layer, kernel, geometry)?;
            specs.push((
                gemm,
                layer.spec.epilogue.clone(),
                layer.spec.min_rows_per_chunk,
            ));
        }
        let pool = if threads > 1 {
            Some(self.planner.shared_pool())
        } else {
            None
        };
        Ok(Arc::new(MlpPlan::compile(
            specs,
            bucket,
            threads,
            mode,
            pool,
            self.arena(),
        )?))
    }

    /// Compile the **decode pipeline**: one uncached [`MlpPlan`] sized for
    /// up to `max_sessions` concurrent decode rows, with every layer
    /// pinned to its **M=1-bucket kernel choice** (explicit override ▸
    /// tuned entry resolving for bucket 1 ▸ paper heuristic — never the
    /// online race, so two independently built schedulers always resolve
    /// the same kernels).
    ///
    /// Why pin the M=1 selection at a larger bucket: a decode step batches
    /// `m` session rows where `m` drifts between 1 and `max_sessions` as
    /// sessions join and leave. If each `m` resolved its own bucket's
    /// winner, two different kernels — with two different per-cell
    /// summation orders — could serve adjacent steps of the *same*
    /// session, and a continuously-batched step would not be bitwise
    /// identical to the per-session forwards. One plan, one kernel per
    /// layer, for every step: per-row bitwise identity then follows from
    /// row-band partitioning (each output row depends only on its own
    /// input row, and the threaded path is already bitwise-identical to
    /// sequential). The M=1 choice is the right pin because decode is a
    /// GEMV stream — a single session runs exactly the tuned M=1 path.
    ///
    /// The decode bucket's arena pair is reserved here too, so the first
    /// step allocates nothing.
    ///
    /// # Errors
    /// [`Error::Shape`] when the layers do not chain, [`Error::Config`]
    /// when none are registered.
    pub fn decode_plan(&self, max_sessions: usize) -> Result<Arc<MlpPlan>> {
        let bucket = m_bucket(max_sessions);
        let threads = self.effective_threads(bucket);
        let layers: Vec<Arc<CachedLayer>> = self
            .layers
            .read()
            .unwrap_or_else(|e| e.into_inner())
            .clone();
        if layers.is_empty() {
            return Err(Error::Config("no layers registered".into()));
        }
        let mut specs = Vec::with_capacity(layers.len());
        for layer in &layers {
            // Bucket 1, not `bucket`: the decode pin described above.
            let (kernel, geometry) = self.kernel_for_spec(&layer.spec, 1);
            let gemm = self.prepared_gemm(layer, kernel, geometry)?;
            specs.push((
                gemm,
                layer.spec.epilogue.clone(),
                layer.spec.min_rows_per_chunk,
            ));
        }
        let pool = if threads > 1 {
            Some(self.planner.shared_pool())
        } else {
            None
        };
        let arena = self.arena();
        if layers.len() >= 2 {
            arena.reserve(bucket);
        }
        Ok(Arc::new(MlpPlan::compile(
            specs,
            bucket,
            threads,
            PipelineMode::Wavefront,
            pool,
            arena,
        )?))
    }

    /// Whether warm-up pre-compiles wavefront pipelines (default true;
    /// [`crate::model::TernaryMlp`] turns it off for `pipeline: false` /
    /// `--no-pipeline` models whose forwards only take the barrier path).
    pub fn pipelining(&self) -> bool {
        self.pipelining.load(Ordering::Relaxed)
    }

    /// Toggle warm-time pipeline compilation (see [`PlanCache::pipelining`]).
    pub fn set_pipelining(&self, on: bool) {
        self.pipelining.store(on, Ordering::Relaxed);
    }

    /// Compile and cache the wavefront pipeline for `key`, unless the
    /// layer set changed while we were building — then the freshly built
    /// plan is stale and a register-time clear must not be undone, so
    /// rebuild against the new layer set and return it uncached (the next
    /// call caches).
    fn cache_pipeline(
        &self,
        key: (usize, usize),
        mode: PipelineMode,
    ) -> Result<Arc<MlpPlan>> {
        let gen = self.generation.load(Ordering::SeqCst);
        let built = self.build_pipeline(key.0, key.1, mode)?;
        if self.generation.load(Ordering::SeqCst) != gen {
            return self.build_pipeline(key.0, key.1, mode);
        }
        let mut pipelines = self.pipelines.lock().unwrap_or_else(|e| e.into_inner());
        if self.generation.load(Ordering::SeqCst) != gen {
            drop(pipelines);
            return self.build_pipeline(key.0, key.1, mode);
        }
        Ok(pipelines.entry(key).or_insert(built).clone())
    }

    /// The cached wavefront pipeline for batch size `m` at the current
    /// thread ceiling, compiling it on a miss.
    pub fn pipeline_for(&self, m: usize) -> Result<Arc<MlpPlan>> {
        let bucket = m_bucket(m);
        let threads = self.effective_threads(bucket);
        let key = (bucket, threads);
        let cached = self
            .pipelines
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .get(&key)
            .cloned();
        if let Some(plan) = cached {
            self.pipeline_hits.fetch_add(1, Ordering::Relaxed);
            return Ok(plan);
        }
        self.pipeline_misses.fetch_add(1, Ordering::Relaxed);
        self.cache_pipeline(key, PipelineMode::Wavefront)
    }

    /// Full wavefront-pipelined forward pass through every registered
    /// layer: `y` must be `x.rows × d_out` and is fully overwritten.
    ///
    /// Returns `Some(stats)` when the pipeline ran. Returns `None` when
    /// the batch was served through the per-layer barrier path instead —
    /// that happens while any layer's kernel choice for this bucket is
    /// still unsettled, so the online top-2 race (which needs the
    /// per-layer path's live-batch timing) is never skipped; once every
    /// layer is settled the bucket's pipeline compiles and sticks.
    pub fn run_pipelined(
        &self,
        x: &Matrix,
        y: &mut Matrix,
    ) -> Result<Option<PipelineStats>> {
        // Past the bucket cap the bucketed pipelines (and their arena
        // sizing) stop covering `m`; the barrier path leases exact-size
        // buffers and handles any batch.
        if x.rows() > MAX_M_BUCKET {
            self.run_layers(x, y)?;
            return Ok(None);
        }
        let bucket = m_bucket(x.rows());
        let threads = self.effective_threads(bucket);
        let key = (bucket, threads);
        let cached = self
            .pipelines
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .get(&key)
            .cloned();
        if let Some(plan) = cached {
            self.pipeline_hits.fetch_add(1, Ordering::Relaxed);
            return plan.run(x, y).map(Some);
        }
        let unsettled = {
            let layers = self.layers.read().unwrap_or_else(|e| e.into_inner());
            layers.iter().any(|l| !self.settled_for(l, x.rows()))
        };
        if unsettled {
            self.run_layers(x, y)?;
            return Ok(None);
        }
        self.pipeline_misses.fetch_add(1, Ordering::Relaxed);
        let plan = self.cache_pipeline(key, PipelineMode::Wavefront)?;
        plan.run(x, y).map(Some)
    }

    /// Barrier forward pass through every registered layer, per-layer
    /// cached plans with a full join between layers — the `--no-pipeline`
    /// escape hatch and the online race's execution path. The first
    /// layer reads `x` borrowed (no input clone) and intermediates
    /// ping-pong through the arena, so steady state allocates nothing;
    /// batches beyond the bucket cap lease exact-size buffers.
    pub fn run_layers(&self, x: &Matrix, y: &mut Matrix) -> Result<()> {
        let n_layers = self.num_layers();
        if n_layers == 0 {
            return Err(Error::Config("no layers registered".into()));
        }
        // Same typed rejection the pipelined path gives — without this a
        // non-chaining cache would feed one layer's output into the next
        // layer's mismatched K and panic in a shape assert instead.
        if n_layers > 1 && !self.layers_chain() {
            return Err(Error::Shape(
                "registered layers do not chain (N_i != K_{i+1})".into(),
            ));
        }
        let widths: Vec<usize> = (0..n_layers).map(|i| self.n(LayerId(i))).collect();
        crate::plan::pipeline::pingpong_forward(
            &self.arena(),
            &widths,
            x,
            y,
            |i, xin, yout| self.run(LayerId(i), xin, yout),
        )
    }

    /// Pre-build plans for every layer at the given batch buckets and the
    /// current thread ceiling (serve startup with a measured table: first
    /// traffic then allocates nothing and races nothing). When the layers
    /// chain, the bucket's wavefront pipeline and arena buffers are warmed
    /// too.
    pub fn warm(&self, buckets: &[usize]) -> Result<()> {
        let n = self.num_layers();
        for i in 0..n {
            for &m in buckets {
                self.plan_for(LayerId(i), m)?;
            }
        }
        if self.layers_chain() {
            let arena = self.arena();
            for &m in buckets {
                if self.pipelining() {
                    self.pipeline_for(m)?;
                }
                // run_layers uses the arena too, so reserve regardless of
                // the pipelining flag.
                if n >= 2 {
                    arena.reserve(m_bucket(m));
                }
            }
        }
        Ok(())
    }

    /// The thread values the load-aware controller can advise up to
    /// `max_threads`: powers of two ≤ `max_threads`. The controller
    /// clamps its advice the same way
    /// ([`crate::coordinator::LoadController`]), so warming exactly these
    /// steps covers every (bucket, threads) key it can ever create — on
    /// non-pow2 core counts (Apple M-series) the ceiling itself is
    /// deliberately not a step.
    pub fn controller_thread_steps(max_threads: usize) -> Vec<usize> {
        let max_threads = max_threads.max(1);
        let mut steps = Vec::new();
        let mut t = 1usize;
        while t <= max_threads {
            steps.push(t);
            t *= 2;
        }
        steps
    }

    /// Warm `buckets` × `thread_steps`, but **only for (layer, bucket)
    /// pairs whose kernel choice is already settled** — an explicit
    /// override, a tuning-table entry resolving for that bucket (M-aware
    /// or the M-agnostic fallback), or racing disabled. Unsettled buckets
    /// are left cold on purpose: their first real traffic should run the
    /// online top-2 race, and a pre-built heuristic plan would silently
    /// skip it. Buckets whose **every** layer is settled also get their
    /// wavefront pipeline compiled and arena buffers reserved, so first
    /// traffic neither compiles nor allocates. Restores the thread ceiling
    /// it found; startup-time only (the temporary ceiling changes are
    /// visible to concurrent traffic).
    pub fn warm_settled(&self, buckets: &[usize], thread_steps: &[usize]) -> Result<()> {
        let saved = self.threads();
        let n = self.num_layers();
        let chain = self.layers_chain();
        let mut result = Ok(());
        'outer: for &step in thread_steps {
            self.set_threads(step);
            for &m in buckets {
                let mut all_settled = true;
                for i in 0..n {
                    let id = LayerId(i);
                    let layer = self.layer(id);
                    if !self.settled_for(&layer, m) {
                        all_settled = false;
                        continue;
                    }
                    if let Err(e) = self.plan_for(id, m) {
                        result = Err(e);
                        break 'outer;
                    }
                }
                if chain && all_settled {
                    if self.pipelining() {
                        if let Err(e) = self.pipeline_for(m) {
                            result = Err(e);
                            break 'outer;
                        }
                    }
                    // run_layers uses the arena too, so reserve regardless
                    // of the pipelining flag.
                    if n >= 2 {
                        self.arena().reserve(m_bucket(m));
                    }
                }
            }
        }
        self.set_threads(saved);
        result
    }

    /// Drop every cached plan and compiled pipeline (the next batches
    /// rebuild from the current tuning entries). Prefer
    /// [`PlanCache::rebuild`] on a serving path — it replaces plans
    /// without a window where none exist.
    pub fn invalidate(&self) {
        let layers = self.layers.read().unwrap_or_else(|e| e.into_inner());
        for layer in layers.iter() {
            self.plans_lock(layer).clear();
        }
        drop(layers);
        self.pipelines
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .clear();
    }

    /// Hand the cache's memory back: everything [`PlanCache::invalidate`]
    /// drops, plus the shared prepared formats (`gemms`) and the
    /// activation arena that invalidate deliberately keeps. This is the
    /// model-unload path — the registered layer specs stay (a clone of
    /// the cache Arc can rebuild lazily), but nothing sized to the model's
    /// weights or activations survives.
    pub fn release(&self) {
        self.invalidate();
        let layers = self.layers.read().unwrap_or_else(|e| e.into_inner());
        for layer in layers.iter() {
            layer
                .gemms
                .lock()
                .unwrap_or_else(|e| e.into_inner())
                .clear();
        }
        drop(layers);
        *self.arena.lock().unwrap_or_else(|e| e.into_inner()) = None;
    }

    /// Re-resolve every cached plan key against the current tuning table
    /// and swap the fresh plans in, one key at a time — serving traffic
    /// always finds a plan, and only genuinely changed winners pay a new
    /// format build (shared formats make unchanged keys shell-cheap).
    /// This is the background re-tune hook's path.
    pub fn rebuild(&self) -> Result<()> {
        let layers: Vec<Arc<CachedLayer>> = self
            .layers
            .read()
            .unwrap_or_else(|e| e.into_inner())
            .clone();
        for layer in &layers {
            let keys: Vec<(usize, usize)> =
                self.plans_lock(layer).keys().copied().collect();
            for (bucket, threads) in keys {
                let plan = self.build_auto(layer, bucket, threads)?;
                self.plans_lock(layer).insert((bucket, threads), plan);
            }
        }
        // Re-compile pipelines against the fresh winners, same keys.
        let keys: Vec<(usize, usize)> = self
            .pipelines
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .keys()
            .copied()
            .collect();
        for (bucket, threads) in keys {
            let plan = self.build_pipeline(bucket, threads, PipelineMode::Wavefront)?;
            self.pipelines
                .lock()
                .unwrap_or_else(|e| e.into_inner())
                .insert((bucket, threads), plan);
        }
        Ok(())
    }

    /// Plans currently cached across all layers.
    pub fn plans_built(&self) -> usize {
        let layers = self.layers.read().unwrap_or_else(|e| e.into_inner());
        layers.iter().map(|l| self.plans_lock(l).len()).sum()
    }

    pub fn snapshot(&self) -> CacheSnapshot {
        CacheSnapshot {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            races: self.races.load(Ordering::Relaxed),
            plans: self.plans_built(),
            pipeline_hits: self.pipeline_hits.load(Ordering::Relaxed),
            pipeline_misses: self.pipeline_misses.load(Ordering::Relaxed),
            pipeline_plans: self
                .pipelines
                .lock()
                .unwrap_or_else(|e| e.into_inner())
                .len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::dense_oracle;
    use crate::plan::planner::heuristic_top2;

    fn cache_with(threads: usize, online: bool) -> PlanCache {
        PlanCache::new(
            Arc::new(Planner::new()),
            PlanCacheConfig {
                threads,
                online_top2: online,
                race_reps: 1,
            },
        )
    }

    #[test]
    fn buckets_are_pow2_and_capped() {
        assert_eq!(m_bucket(0), 1);
        assert_eq!(m_bucket(1), 1);
        assert_eq!(m_bucket(2), 2);
        assert_eq!(m_bucket(3), 4);
        assert_eq!(m_bucket(8), 8);
        assert_eq!(m_bucket(9), 16);
        assert_eq!(m_bucket(100_000), MAX_M_BUCKET);
    }

    #[test]
    fn mixed_m_stream_reuses_bucket_plans() {
        let cache = cache_with(1, false);
        let w = TernaryMatrix::random(48, 12, 0.25, 3);
        let id = cache
            .register(LayerSpec::new(w, Epilogue::with_bias(vec![0.1; 12])))
            .unwrap();
        let ms = [1usize, 3, 8, 5, 2, 16, 7, 8, 1, 4];
        for &m in &ms {
            let x = Matrix::random(m, 48, 50 + m as u64);
            let y = cache.forward(id, &x).unwrap();
            assert_eq!((y.rows(), y.cols()), (m, 12));
        }
        let warm = cache.snapshot();
        // Buckets seen: 1, 2, 4, 8, 16 → five plans, five misses.
        assert_eq!(warm.plans, 5);
        assert_eq!(warm.misses, 5);
        for &m in &ms {
            let x = Matrix::random(m, 48, 90 + m as u64);
            cache.forward(id, &x).unwrap();
        }
        let hot = cache.snapshot();
        assert_eq!(hot.misses, warm.misses, "warm stream must not re-plan");
        assert_eq!(hot.plans, warm.plans);
        assert_eq!(hot.hits, warm.hits + ms.len() as u64);
    }

    #[test]
    fn cached_run_matches_oracle_and_explicit_override_sticks() {
        let cache = cache_with(2, false);
        let w = TernaryMatrix::random(64, 16, 0.5, 7);
        let bias: Vec<f32> = (0..16).map(|i| 0.05 * i as f32).collect();
        let id = cache
            .register(LayerSpec {
                weights: w.clone(),
                params: KernelParams::default(),
                epilogue: Epilogue::with_bias(bias.clone()),
                kernel: Some(KernelId::BaseTcsc),
                min_rows_per_chunk: 2,
            })
            .unwrap();
        assert_eq!(cache.kernel_for(id, 8), KernelId::BaseTcsc);
        let x = Matrix::random(8, 64, 8);
        let y = cache.forward(id, &x).unwrap();
        assert!(y.allclose(&dense_oracle(&x, &w, &bias), 1e-4));
    }

    #[test]
    fn online_race_locks_winner_into_shared_table() {
        let planner = Arc::new(Planner::new());
        let cache = PlanCache::new(
            Arc::clone(&planner),
            PlanCacheConfig {
                threads: 1,
                online_top2: true,
                race_reps: 1,
            },
        );
        let w = TernaryMatrix::random(64, 16, 0.25, 9);
        let bias = vec![0.0f32; 16];
        let id = cache
            .register(LayerSpec::new(w.clone(), Epilogue::with_bias(bias.clone())))
            .unwrap();
        assert!(planner.lookup_entry(64, 0.25, 8).is_none());
        let x = Matrix::random(8, 64, 10);
        let y = cache.forward(id, &x).unwrap();
        assert!(y.allclose(&dense_oracle(&x, &w, &bias), 1e-3));
        let entry = planner
            .lookup_entry(64, 0.25, 8)
            .expect("race records winner");
        let candidates = heuristic_top2(64, 0.25, 8, false);
        assert!(candidates.contains(&entry.kernel), "{}", entry.kernel);
        assert_eq!(cache.snapshot().races, 1);
        // A second layer in the same class (same bucket) reuses the entry
        // — no new race.
        let id2 = cache
            .register(LayerSpec::new(
                TernaryMatrix::random(64, 8, 0.25, 11),
                Epilogue::with_bias(vec![0.0; 8]),
            ))
            .unwrap();
        cache.forward(id2, &x).unwrap();
        assert_eq!(cache.snapshot().races, 1);
        assert_eq!(cache.kernel_for(id2, 8), entry.kernel);
    }

    #[test]
    fn set_threads_adds_keys_and_invalidate_clears() {
        let cache = cache_with(1, false);
        let id = cache
            .register(LayerSpec::new(
                TernaryMatrix::random(32, 8, 0.5, 2),
                Epilogue::with_bias(vec![0.0; 8]),
            ))
            .unwrap();
        let x = Matrix::random(8, 32, 3);
        cache.forward(id, &x).unwrap();
        assert_eq!(cache.plans_built(), 1);
        cache.set_threads(4);
        cache.forward(id, &x).unwrap();
        assert_eq!(cache.plans_built(), 2, "new thread count → new key");
        cache.forward(id, &x).unwrap();
        assert_eq!(cache.plans_built(), 2, "then cached");
        cache.invalidate();
        assert_eq!(cache.plans_built(), 0);
        cache.forward(id, &x).unwrap();
        assert_eq!(cache.plans_built(), 1);
    }

    #[test]
    fn warm_prebuilds_every_layer_bucket() {
        let cache = cache_with(1, true);
        for seed in 0..3u64 {
            cache
                .register(LayerSpec::new(
                    TernaryMatrix::random(32, 8, 0.5, seed),
                    Epilogue::with_bias(vec![0.0; 8]),
                ))
                .unwrap();
        }
        cache.warm(&[1, 8]).unwrap();
        assert_eq!(cache.plans_built(), 6);
        // Warmed buckets neither race nor re-plan on first traffic.
        let x = Matrix::random(8, 32, 40);
        cache.forward(LayerId(0), &x).unwrap();
        let snap = cache.snapshot();
        assert_eq!(snap.races, 0);
        assert_eq!(snap.plans, 6);
    }

    #[test]
    fn thread_steps_are_pow2_capped() {
        assert_eq!(PlanCache::controller_thread_steps(1), vec![1]);
        assert_eq!(PlanCache::controller_thread_steps(4), vec![1, 2, 4]);
        // Non-pow2 ceilings (Apple M-series core counts) stop at the
        // largest pow2 — the controller can never advise 6 threads.
        assert_eq!(PlanCache::controller_thread_steps(6), vec![1, 2, 4]);
        assert_eq!(PlanCache::controller_thread_steps(0), vec![1]);
    }

    #[test]
    fn warm_settled_skips_untuned_classes_so_they_still_race() {
        let planner = Arc::new(Planner::new());
        let cache = PlanCache::new(
            Arc::clone(&planner),
            PlanCacheConfig {
                threads: 1,
                online_top2: true,
                race_reps: 1,
            },
        );
        // Layer 0: pinned kernel (settled). Layer 1: untuned auto class.
        let mut pinned = LayerSpec::new(
            TernaryMatrix::random(32, 8, 0.5, 1),
            Epilogue::with_bias(vec![0.0; 8]),
        );
        pinned.kernel = Some(KernelId::BaseTcsc);
        cache.register(pinned).unwrap();
        let auto_id = cache
            .register(LayerSpec::new(
                TernaryMatrix::random(64, 8, 0.25, 2),
                Epilogue::with_bias(vec![0.0; 8]),
            ))
            .unwrap();
        cache
            .warm_settled(&[1, 8], &PlanCache::controller_thread_steps(4))
            .unwrap();
        // Pinned layer warmed: bucket 1 → (1,1); bucket 8 → (8,1..4).
        assert_eq!(cache.plans_built(), 4);
        assert_eq!(cache.threads(), 1, "ceiling restored after warming");
        assert_eq!(cache.snapshot().races, 0);
        // The untuned layer stayed cold, so first traffic still races.
        let x = Matrix::random(8, 64, 3);
        cache.forward(auto_id, &x).unwrap();
        assert_eq!(cache.snapshot().races, 1);
        assert!(planner.lookup_entry(64, 0.25, 8).is_some());
    }

    #[test]
    fn each_m_bucket_races_once_and_records_its_own_winner() {
        let planner = Arc::new(Planner::new());
        let cache = PlanCache::new(
            Arc::clone(&planner),
            PlanCacheConfig {
                threads: 1,
                online_top2: true,
                race_reps: 1,
            },
        );
        let w = TernaryMatrix::random(64, 16, 0.25, 17);
        let id = cache
            .register(LayerSpec::new(w, Epilogue::with_bias(vec![0.0; 16])))
            .unwrap();
        // First sighting of bucket 1 races and records under m=1 only.
        cache.forward(id, &Matrix::random(1, 64, 20)).unwrap();
        assert_eq!(cache.snapshot().races, 1);
        assert!(planner.lookup_entry(64, 0.25, 1).is_some());
        assert!(
            planner.lookup_entry(64, 0.25, 16).is_none(),
            "bucket 1's race must not settle bucket 16"
        );
        // Bucket 16 runs its own race on first sighting.
        cache.forward(id, &Matrix::random(16, 64, 21)).unwrap();
        assert_eq!(cache.snapshot().races, 2);
        assert!(planner.lookup_entry(64, 0.25, 16).is_some());
        // Both buckets are now settled: repeat traffic never races.
        cache.forward(id, &Matrix::random(1, 64, 22)).unwrap();
        cache.forward(id, &Matrix::random(16, 64, 23)).unwrap();
        assert_eq!(cache.snapshot().races, 2);
    }

    #[test]
    fn per_m_table_entries_pick_different_kernels_per_bucket() {
        use crate::autotune::TuningTable;
        let mut table = TuningTable::new();
        table.insert(
            ShapeClass::of(64, 0.25),
            TuneEntry::new(KernelId::InterleavedBlockedTcsc, 2.0),
        );
        table.insert(
            ShapeClass::of_m(64, 0.25, 1),
            TuneEntry::new(KernelId::UnrolledTcscK4M4, 3.0),
        );
        let cache = PlanCache::new(
            Arc::new(Planner::with_table(table)),
            PlanCacheConfig {
                threads: 1,
                online_top2: true,
                race_reps: 1,
            },
        );
        let id = cache
            .register(LayerSpec::new(
                TernaryMatrix::random(64, 8, 0.25, 19),
                Epilogue::with_bias(vec![0.0; 8]),
            ))
            .unwrap();
        assert_eq!(cache.kernel_for(id, 1), KernelId::UnrolledTcscK4M4);
        assert_eq!(cache.kernel_for(id, 8), KernelId::InterleavedBlockedTcsc);
        assert_eq!(
            cache.plan_for(id, 1).unwrap().kernel_name(),
            "unrolled_tcsc_k4_m4"
        );
        assert_eq!(
            cache.plan_for(id, 8).unwrap().kernel_name(),
            "interleaved_blocked_tcsc"
        );
        // Every bucket resolves through the table → no races anywhere.
        cache.forward(id, &Matrix::random(1, 64, 24)).unwrap();
        cache.forward(id, &Matrix::random(8, 64, 25)).unwrap();
        assert_eq!(cache.snapshot().races, 0);
    }

    #[test]
    fn rebuild_swaps_plans_to_fresh_table_winners() {
        let planner = Arc::new(Planner::new());
        let cache = PlanCache::new(
            Arc::clone(&planner),
            PlanCacheConfig {
                threads: 1,
                online_top2: false,
                race_reps: 1,
            },
        );
        let w = TernaryMatrix::random(64, 8, 0.25, 5);
        let bias = vec![0.0f32; 8];
        let id = cache
            .register(LayerSpec::new(w.clone(), Epilogue::with_bias(bias.clone())))
            .unwrap();
        let x = Matrix::random(8, 64, 6);
        cache.forward(id, &x).unwrap();
        assert_eq!(
            cache.plan_for(id, 8).unwrap().kernel_name(),
            "interleaved_blocked_tcsc"
        );
        // A re-tune records a new winner; rebuild swaps it in, same keys.
        planner.record(
            ShapeClass::of(64, 0.25),
            TuneEntry::new(KernelId::UnrolledTcsc12, 9.0),
        );
        let plans_before = cache.plans_built();
        cache.rebuild().unwrap();
        assert_eq!(cache.plans_built(), plans_before, "rebuild keeps the key set");
        assert_eq!(cache.plan_for(id, 8).unwrap().kernel_name(), "unrolled_tcsc_12");
        let y = cache.forward(id, &x).unwrap();
        assert!(y.allclose(&dense_oracle(&x, &w, &bias), 1e-3));
    }

    /// Two chained layers for the pipeline tests (K=48 → 32 → 12).
    fn chain_cache(threads: usize, online: bool, kernel: Option<KernelId>) -> PlanCache {
        let cache = cache_with(threads, online);
        for (k, n, seed) in [(48usize, 32usize, 70u64), (32, 12, 71)] {
            let mut spec = LayerSpec::new(
                TernaryMatrix::random(k, n, 0.25, seed),
                Epilogue::new(vec![0.05; n], 1.0, Some(0.25)),
            );
            spec.kernel = kernel;
            cache.register(spec).unwrap();
        }
        cache
    }

    #[test]
    fn pipelined_forward_matches_barrier_path_bitwise() {
        for &threads in &[1usize, 4] {
            let cache = chain_cache(threads, false, Some(KernelId::InterleavedBlockedTcsc));
            for &m in &[0usize, 1, 5, 8, 17] {
                let x = Matrix::random(m, 48, 300 + m as u64);
                let mut y_barrier = Matrix::zeros(m, 12);
                cache.run_layers(&x, &mut y_barrier).unwrap();
                let mut y_pipe = Matrix::zeros(m, 12);
                let stats = cache
                    .run_pipelined(&x, &mut y_pipe)
                    .unwrap()
                    .expect("settled chain must pipeline");
                assert_eq!(y_barrier, y_pipe, "threads={threads} m={m}");
                if m > 0 {
                    assert!(stats.tasks >= 2);
                }
            }
        }
    }

    #[test]
    fn unsettled_buckets_race_through_barrier_then_pipeline() {
        let cache = chain_cache(1, true, None);
        let x = Matrix::random(8, 48, 400);
        let mut y = Matrix::zeros(8, 12);
        // First sighting: layers untuned → barrier fallback + races.
        assert!(cache.run_pipelined(&x, &mut y).unwrap().is_none());
        assert_eq!(cache.snapshot().races, 2, "both layer classes race");
        assert_eq!(cache.snapshot().pipeline_plans, 0);
        // Second sighting: settled → pipeline compiles and runs.
        let stats = cache.run_pipelined(&x, &mut y).unwrap();
        assert!(stats.is_some());
        let snap = cache.snapshot();
        assert_eq!(snap.races, 2, "pipeline must not skip or repeat races");
        assert_eq!(snap.pipeline_plans, 1);
        assert_eq!(snap.pipeline_misses, 1);
        // Third: cached pipeline.
        cache.run_pipelined(&x, &mut y).unwrap().unwrap();
        assert_eq!(cache.snapshot().pipeline_hits, 1);
    }

    #[test]
    fn steady_state_pipelined_serving_allocates_no_activations() {
        let cache = chain_cache(2, false, None);
        let ms = [1usize, 8, 5, 16];
        for &m in &ms {
            let x = Matrix::random(m, 48, 500 + m as u64);
            let mut y = Matrix::zeros(m, 12);
            cache.run_pipelined(&x, &mut y).unwrap();
        }
        let warm = cache.arena_stats();
        assert!(warm.allocations > 0, "warmup allocated arena pairs");
        for round in 0..3u64 {
            for &m in &ms {
                let x = Matrix::random(m, 48, 600 + 10 * round + m as u64);
                let mut y = Matrix::zeros(m, 12);
                cache.run_pipelined(&x, &mut y).unwrap();
            }
        }
        let hot = cache.arena_stats();
        assert_eq!(
            hot.allocations, warm.allocations,
            "steady state must perform zero activation allocation"
        );
        assert_eq!(hot.reuses, warm.reuses + 3 * ms.len() as u64);
    }

    #[test]
    fn warm_compiles_pipelines_and_reserves_arena() {
        let cache = chain_cache(1, false, None);
        cache.warm(&[1, 8]).unwrap();
        let snap = cache.snapshot();
        assert_eq!(snap.pipeline_plans, 2);
        let warm_allocs = cache.arena_stats().allocations;
        assert!(warm_allocs >= 2, "arena reserved per bucket");
        // First traffic: no compile, no allocation — only reuse.
        let x = Matrix::random(8, 48, 700);
        let mut y = Matrix::zeros(8, 12);
        cache.run_pipelined(&x, &mut y).unwrap().unwrap();
        let snap = cache.snapshot();
        assert_eq!(snap.pipeline_misses, 2, "warm counted the compiles");
        assert_eq!(snap.pipeline_hits, 1);
        assert_eq!(cache.arena_stats().allocations, warm_allocs);
        assert_eq!(cache.arena_stats().reuses, 1);
    }

    #[test]
    fn rebuild_recompiles_pipelines_to_fresh_winners() {
        let planner = Arc::new(Planner::new());
        let cache = PlanCache::new(
            Arc::clone(&planner),
            PlanCacheConfig {
                threads: 1,
                online_top2: false,
                race_reps: 1,
            },
        );
        for (k, n, seed) in [(64usize, 32usize, 80u64), (32, 8, 81)] {
            cache
                .register(LayerSpec::new(
                    TernaryMatrix::random(k, n, 0.25, seed),
                    Epilogue::with_bias(vec![0.0; n]),
                ))
                .unwrap();
        }
        let x = Matrix::random(8, 64, 800);
        let mut y = Matrix::zeros(8, 8);
        cache.run_pipelined(&x, &mut y).unwrap().unwrap();
        assert_eq!(
            cache.pipeline_for(8).unwrap().kernel_names(),
            vec!["interleaved_blocked_tcsc"; 2]
        );
        planner.record(
            ShapeClass::of(64, 0.25),
            TuneEntry::new(KernelId::UnrolledTcsc12, 9.0),
        );
        cache.rebuild().unwrap();
        assert_eq!(
            cache.pipeline_for(8).unwrap().kernel_names(),
            vec!["unrolled_tcsc_12", "interleaved_blocked_tcsc"]
        );
        let mut y2 = Matrix::zeros(8, 8);
        cache.run_pipelined(&x, &mut y2).unwrap().unwrap();
        let mut y_barrier = Matrix::zeros(8, 8);
        cache.run_layers(&x, &mut y_barrier).unwrap();
        assert_eq!(y2, y_barrier);
    }

    #[test]
    fn non_chaining_layers_reject_pipelining() {
        // Settled path (no racing): typed rejection from pipeline compile.
        let cache = cache_with(1, false);
        for seed in 0..2u64 {
            cache
                .register(LayerSpec::new(
                    TernaryMatrix::random(32, 8, 0.5, seed),
                    Epilogue::with_bias(vec![0.0; 8]),
                ))
                .unwrap();
        }
        let x = Matrix::random(4, 32, 900);
        let mut y = Matrix::zeros(4, 8);
        assert!(matches!(
            cache.run_pipelined(&x, &mut y),
            Err(Error::Shape(_))
        ));
        // Racing config: the unsettled fallback goes through run_layers,
        // which must give the same typed error, not a shape-assert panic.
        let racing = cache_with(1, true);
        for seed in 0..2u64 {
            racing
                .register(LayerSpec::new(
                    TernaryMatrix::random(32, 8, 0.5, seed),
                    Epilogue::with_bias(vec![0.0; 8]),
                ))
                .unwrap();
        }
        assert!(matches!(
            racing.run_pipelined(&x, &mut y),
            Err(Error::Shape(_))
        ));
        assert!(matches!(racing.run_layers(&x, &mut y), Err(Error::Shape(_))));
        // warm() skips the pipeline for non-chains instead of failing.
        cache.warm(&[1, 4]).unwrap();
        assert_eq!(cache.snapshot().pipeline_plans, 0);
    }

    #[test]
    fn no_pipelining_flag_skips_warm_compiles_but_keeps_arena() {
        let cache = chain_cache(1, false, Some(KernelId::InterleavedBlockedTcsc));
        cache.set_pipelining(false);
        cache.warm(&[1, 8]).unwrap();
        let snap = cache.snapshot();
        assert_eq!(snap.pipeline_plans, 0, "--no-pipeline warms no pipelines");
        assert_eq!(snap.pipeline_misses, 0);
        assert!(
            cache.arena_stats().allocations >= 2,
            "barrier path still gets warmed arena pairs"
        );
        // The barrier forward reuses the reserved pair immediately.
        let x = Matrix::random(8, 48, 910);
        let mut y = Matrix::zeros(8, 12);
        cache.run_layers(&x, &mut y).unwrap();
        assert!(cache.arena_stats().reuses >= 1);
    }

    #[test]
    fn register_validates_bias_and_params() {
        let cache = cache_with(1, false);
        let w = TernaryMatrix::random(16, 8, 0.5, 1);
        assert!(matches!(
            cache.register(LayerSpec::new(w.clone(), Epilogue::with_bias(vec![0.0; 7]))),
            Err(Error::Shape(_))
        ));
        // Bad params are rejected up front too — lazy builds cannot fail.
        // (An unknown kernel is unrepresentable: the override is a typed
        // KernelId, so the PR-2 "bogus name" rejection test is gone with
        // the failure mode it covered.)
        let mut spec = LayerSpec::new(w, Epilogue::with_bias(vec![0.0; 8]));
        spec.params.group = Some(0);
        assert!(matches!(
            cache.register(spec),
            Err(Error::BadKernelParams(_))
        ));
    }

    #[test]
    fn capability_gated_register_rejects_unavailable_kernel() {
        use crate::perf::CpuCaps;
        let cache = PlanCache::new(
            Arc::new(Planner::new().with_caps(CpuCaps::scalar_only())),
            PlanCacheConfig {
                threads: 1,
                online_top2: false,
                race_reps: 1,
            },
        );
        let w = TernaryMatrix::random(64, 8, 0.25, 21);
        let bias = vec![0.0f32; 8];
        // The NEON tile kernel is gated; a scalar-only planner must reject
        // it at registration, before any lazy build could trip on it.
        let mut spec = LayerSpec::new(w.clone(), Epilogue::with_bias(bias.clone()));
        spec.kernel = Some(KernelId::OuterProductTileSimd);
        assert!(matches!(
            cache.register(spec),
            Err(Error::UnsupportedKernel(_))
        ));
        // The portable tile-emulation variant has no requirements and
        // registers (and runs) anywhere.
        let mut spec = LayerSpec::new(w.clone(), Epilogue::with_bias(bias.clone()));
        spec.kernel = Some(KernelId::OuterProductTile);
        let id = cache.register(spec).unwrap();
        let x = Matrix::random(4, 64, 22);
        let y = cache.forward(id, &x).unwrap();
        assert!(y.allclose(&dense_oracle(&x, &w, &bias), 1e-3));
    }

    #[test]
    fn capability_gated_race_discovers_tile_family() {
        use crate::perf::CpuCaps;
        // On a large-K wide-M class the capability-aware top-2 injects the
        // outer-product family as the rival even on a scalar host (the
        // portable variant), so the race can discover it with zero name
        // literals.
        let planner = Arc::new(Planner::new().with_caps(CpuCaps::scalar_only()));
        let cache = PlanCache::new(
            Arc::clone(&planner),
            PlanCacheConfig {
                threads: 1,
                online_top2: true,
                race_reps: 1,
            },
        );
        let w = TernaryMatrix::random(1024, 8, 0.25, 31);
        let bias = vec![0.0f32; 8];
        let id = cache
            .register(LayerSpec::new(w.clone(), Epilogue::with_bias(bias.clone())))
            .unwrap();
        let x = Matrix::random(16, 1024, 32);
        let y = cache.forward(id, &x).unwrap();
        assert!(y.allclose(&dense_oracle(&x, &w, &bias), 1e-3));
        let entry = planner
            .lookup_entry(1024, 0.25, 16)
            .expect("race records winner");
        let caps = planner.caps();
        let expected = heuristic_top2_caps(&caps, 1024, 0.25, 16, false);
        assert!(
            expected.contains(&KernelId::OuterProductTile),
            "scalar host races the portable tile rival"
        );
        assert!(expected.contains(&entry.kernel), "{}", entry.kernel);
        assert!(
            caps.satisfies(entry.kernel.descriptor().requires),
            "race winner must be runnable on the planner's CPU"
        );
    }

    #[test]
    fn race_times_geometry_variants_and_records_divergent_winner() {
        use crate::perf::CpuCaps;
        // An apple-like planner derives a non-default policy geometry
        // (wide panels, K-blocked streams), so the race on a tile-eligible
        // class times each tile candidate at both geometries.
        let planner = Arc::new(Planner::new().with_caps(CpuCaps::apple_like()));
        let policy_geom = planner.blocking_policy().geometry;
        assert_ne!(policy_geom, TileGeometry::DEFAULT);
        let cache = PlanCache::new(
            Arc::clone(&planner),
            PlanCacheConfig {
                threads: 1,
                online_top2: true,
                race_reps: 1,
            },
        );
        let w = TernaryMatrix::random(1024, 8, 0.25, 41);
        let bias = vec![0.0f32; 8];
        let id = cache
            .register(LayerSpec::new(w.clone(), Epilogue::with_bias(bias.clone())))
            .unwrap();
        let x = Matrix::random(16, 1024, 42);
        let y = cache.forward(id, &x).unwrap();
        assert!(y.allclose(&dense_oracle(&x, &w, &bias), 1e-3));
        assert_eq!(cache.snapshot().races, 1);
        let entry = planner
            .lookup_entry(1024, 0.25, 16)
            .expect("race records winner");
        if entry.kernel.descriptor().geometry {
            // A recorded geometry is one the race actually timed, never
            // the default layout (absence means default).
            assert!(
                entry.geometry.is_none() || entry.geometry == Some(policy_geom),
                "unexpected raced geometry {:?}",
                entry.geometry
            );
        } else {
            assert_eq!(entry.geometry, None);
        }
        // Settled: subsequent plans resolve to the recorded geometry and
        // repeat traffic never re-races.
        assert_eq!(cache.geometry_for(id, 16), entry.geometry);
        cache.forward(id, &Matrix::random(16, 1024, 43)).unwrap();
        assert_eq!(cache.snapshot().races, 1);
    }

    #[test]
    fn pinned_geometry_is_honored_and_bitwise_stable() {
        use crate::perf::CpuCaps;
        let planner = Arc::new(Planner::new().with_caps(CpuCaps::apple_like()));
        let cache = PlanCache::new(
            Arc::clone(&planner),
            PlanCacheConfig {
                threads: 1,
                online_top2: false,
                race_reps: 1,
            },
        );
        let w = TernaryMatrix::random(256, 20, 0.25, 43);
        let bias = vec![0.0f32; 20];
        let pin = TileGeometry::new(4, 64);
        let mut spec = LayerSpec::new(w.clone(), Epilogue::with_bias(bias.clone()));
        spec.kernel = Some(KernelId::OuterProductTile);
        spec.params.geometry = Some(pin);
        let id = cache.register(spec).unwrap();
        // The pin wins over the policy geometry in every bucket.
        assert_eq!(cache.geometry_for(id, 1), Some(pin));
        assert_eq!(cache.geometry_for(id, 64), Some(pin));
        // And the pinned-geometry output matches an unpinned cache of the
        // same kernel bit for bit — geometry is layout, never arithmetic.
        let x = Matrix::random(8, 256, 44);
        let y = cache.forward(id, &x).unwrap();
        let base_cache = cache_with(1, false);
        let mut base_spec = LayerSpec::new(w.clone(), Epilogue::with_bias(bias.clone()));
        base_spec.kernel = Some(KernelId::OuterProductTile);
        let base_id = base_cache.register(base_spec).unwrap();
        let y_base = base_cache.forward(base_id, &x).unwrap();
        assert_eq!(y.as_slice(), y_base.as_slice());
        assert!(y.allclose(&dense_oracle(&x, &w, &bias), 1e-4));
        // Non-geometry kernels never resolve a geometry.
        let mut plain = LayerSpec::new(w, Epilogue::with_bias(bias));
        plain.kernel = Some(KernelId::BaseTcsc);
        let plain_id = cache.register(plain).unwrap();
        assert_eq!(cache.geometry_for(plain_id, 8), None);
    }
}
