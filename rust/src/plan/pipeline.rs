//! Wavefront-pipelined multi-layer execution: cross-layer band scheduling
//! with a zero-allocation activation arena.
//!
//! The barrier forward pass ([`crate::model::TernaryMlp::forward`] before
//! PR 5) ran a full thread-pool join after every layer and allocated a
//! fresh activation matrix per layer per request. But row-major GEMM has a
//! stronger dependence structure: row band `[a, b)` of layer `i+1` depends
//! **only** on row band `[a, b)` of layer `i`'s output. Bands can therefore
//! flow through the whole MLP with no global barrier — layer `i+1`'s first
//! bands overlap layer `i`'s tail, exactly the cross-layer pipelining the
//! ROADMAP names.
//!
//! Three pieces implement it:
//!
//! - [`ActivationArena`] — pre-sized ping-pong activation buffers checked
//!   out per forward pass and returned on drop, keyed by M-bucket. After
//!   the first sighting of a bucket, steady-state serving performs **zero
//!   activation allocation** (asserted via [`ArenaStats`] reuse counters).
//!   Two buffers suffice for any depth: layer `i` writes buffer `i mod 2`,
//!   and the band dependency graph guarantees every reader of a buffer
//!   region has finished before the next same-parity layer overwrites it.
//! - [`MlpPlan`] — all layers of a model compiled into band tasks over
//!   [`RowPartition`] tile-aligned ranges. Because every band runs the
//!   same prepared kernel on the same tile-aligned row range as the
//!   barrier path, outputs are **bitwise identical** to the sequential
//!   forward pass (the property `tests/prop_cache.rs` locks in).
//! - a pull-model band scheduler — long-lived pool workers
//!   ([`ThreadPool::run_scoped_workers`]) pick `(layer, band)` tasks whose
//!   predecessors completed, deepest layer first so hot activations are
//!   consumed while they are still in cache. One forward pass costs
//!   `threads` pool jobs instead of layers × bands spawn-per-call jobs.
//!
//! [`PipelineMode::Barrier`] runs the *same* machinery with full
//! layer-to-layer dependency edges: it exists for honest accounting — the
//! e2e bench measures per-layer barrier stall time (worker idle time
//! inside each layer's execution window) through the identical scheduler,
//! so the wavefront's win is tracked across PRs, and [`PipelineStats`]
//! feeds the serving [`crate::coordinator::Metrics`] gauges the load
//! controller's queue model reads.

use crate::kernels::{GemmScratch, PreparedGemm};
use crate::plan::gemm_plan::Epilogue;
use crate::plan::partition::RowPartition;
use crate::tensor::Matrix;
use crate::util::threadpool::ThreadPool;
use crate::{Error, Result};
use std::collections::BTreeMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::Instant;

/// Monotonic arena counters (relaxed; tests assert the zero-allocation
/// steady state through them, /metrics reports them).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ArenaStats {
    /// Buffer pairs created (one per bucket sighting per concurrent user).
    pub allocations: u64,
    /// Checkouts served by an already-allocated pair.
    pub reuses: u64,
}

/// A ping-pong pair of activation buffers, each `bucket × max_width`.
struct BufferPair {
    ping: Matrix,
    pong: Matrix,
}

/// Pool of pre-sized ping-pong activation buffers, keyed by M-bucket.
///
/// A forward pass checks a pair out ([`ActivationArena::checkout`]) and
/// the lease returns it on drop, so concurrent forwards never share a
/// buffer while the steady state allocates nothing. Buffers are sized
/// `bucket × max_width` where `max_width` is the widest intermediate
/// activation of the model — every layer's `m × n` output fits in the
/// prefix of such a buffer.
pub struct ActivationArena {
    max_width: usize,
    free: Mutex<BTreeMap<usize, Vec<BufferPair>>>,
    allocations: AtomicU64,
    reuses: AtomicU64,
}

impl ActivationArena {
    /// Arena for intermediates up to `max_width` columns wide (0 is valid:
    /// a single-layer model has no intermediates).
    pub fn new(max_width: usize) -> ActivationArena {
        ActivationArena {
            max_width,
            free: Mutex::new(BTreeMap::new()),
            allocations: AtomicU64::new(0),
            reuses: AtomicU64::new(0),
        }
    }

    /// Widest intermediate activation the buffers are sized for.
    pub fn max_width(&self) -> usize {
        self.max_width
    }

    fn fresh_pair(&self, bucket: usize) -> BufferPair {
        self.allocations.fetch_add(1, Ordering::Relaxed);
        let mut ping = Matrix::zeros(bucket, self.max_width);
        let mut pong = Matrix::zeros(bucket, self.max_width);
        // Long-lived, large, streamed row-major — prime THP candidates.
        // Advisory only (see util::alloc): bits are never touched.
        let _ = crate::util::alloc::advise_hugepages_f32(ping.as_mut_slice());
        let _ = crate::util::alloc::advise_hugepages_f32(pong.as_mut_slice());
        BufferPair { ping, pong }
    }

    /// Check a buffer pair out for a forward pass of up to `bucket` rows;
    /// the lease returns it on drop. Allocates only when every pair for
    /// this bucket is currently leased.
    pub fn checkout(&self, bucket: usize) -> ArenaLease<'_> {
        let reused = {
            let mut free = self.free.lock().unwrap_or_else(|e| e.into_inner());
            free.get_mut(&bucket).and_then(|pairs| pairs.pop())
        };
        let pair = match reused {
            Some(pair) => {
                self.reuses.fetch_add(1, Ordering::Relaxed);
                pair
            }
            None => self.fresh_pair(bucket),
        };
        ArenaLease {
            arena: self,
            bucket,
            pair: Some(pair),
        }
    }

    /// Pre-allocate one pair for `bucket` (plan-cache warm-up: the first
    /// real request then reuses instead of allocating).
    pub fn reserve(&self, bucket: usize) {
        let empty = {
            let mut free = self.free.lock().unwrap_or_else(|e| e.into_inner());
            free.entry(bucket).or_default().is_empty()
        };
        if empty {
            let pair = self.fresh_pair(bucket);
            self.free
                .lock()
                .unwrap_or_else(|e| e.into_inner())
                .entry(bucket)
                .or_default()
                .push(pair);
        }
    }

    /// Like [`ActivationArena::reserve`], but the fresh pair's pages are
    /// **first-touched by the pool's own workers**, band by band: on
    /// parts where page placement follows the first writer, the rows a
    /// worker will stream every forward pass end up in that worker's
    /// locality domain. Job `i` routes to pool thread `i % size`
    /// (sticky), matching the band → worker preference the wavefront
    /// scheduler uses. No-op when a pair for `bucket` is already
    /// resident (its pages are already owned).
    pub fn reserve_first_touch(&self, bucket: usize, pool: &ThreadPool) {
        {
            let free = self.free.lock().unwrap_or_else(|e| e.into_inner());
            if free.get(&bucket).is_some_and(|pairs| !pairs.is_empty()) {
                return;
            }
        }
        let mut pair = self.fresh_pair(bucket);
        let cols = self.max_width;
        let workers = pool.size().max(1);
        if cols > 0 && bucket > 0 {
            let chunk = bucket.div_ceil(workers) * cols;
            let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
            // Order matters: ping band w at index w, pong band w at
            // index workers + w, so both land on thread w.
            for buf in [pair.ping.as_mut_slice(), pair.pong.as_mut_slice()] {
                for band in buf.chunks_mut(chunk.max(1)) {
                    let rows = band.len() / cols;
                    jobs.push(Box::new(move || {
                        crate::util::alloc::first_touch_band(band, cols, 0, rows);
                    }));
                }
            }
            let panicked = pool.run_scoped_assigned(jobs);
            debug_assert_eq!(panicked, 0, "first-touch jobs cannot panic");
        }
        self.free
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .entry(bucket)
            .or_default()
            .push(pair);
    }

    pub fn stats(&self) -> ArenaStats {
        ArenaStats {
            allocations: self.allocations.load(Ordering::Relaxed),
            reuses: self.reuses.load(Ordering::Relaxed),
        }
    }
}

/// A checked-out buffer pair; returns itself to the arena on drop.
pub struct ArenaLease<'a> {
    arena: &'a ActivationArena,
    bucket: usize,
    pair: Option<BufferPair>,
}

impl ArenaLease<'_> {
    /// The (ping, pong) buffers, mutably.
    pub(crate) fn bufs(&mut self) -> (&mut Matrix, &mut Matrix) {
        let pair = self.pair.as_mut().expect("lease holds buffers until drop");
        (&mut pair.ping, &mut pair.pong)
    }
}

impl Drop for ArenaLease<'_> {
    fn drop(&mut self) {
        if let Some(pair) = self.pair.take() {
            self.arena
                .free
                .lock()
                .unwrap_or_else(|e| e.into_inner())
                .entry(self.bucket)
                .or_default()
                .push(pair);
        }
    }
}

impl ActivationArena {
    /// Like [`ActivationArena::checkout`], but the lease owns an `Arc` to
    /// the arena instead of borrowing it — the decode path's sessions and
    /// scheduler hold leases across many steps (and across threads), which
    /// a borrow-scoped [`ArenaLease`] cannot express. Same pool, same
    /// stats, same return-on-drop semantics.
    pub fn checkout_owned(self: &Arc<Self>, bucket: usize) -> OwnedArenaLease {
        let reused = {
            let mut free = self.free.lock().unwrap_or_else(|e| e.into_inner());
            free.get_mut(&bucket).and_then(|pairs| pairs.pop())
        };
        let pair = match reused {
            Some(pair) => {
                self.reuses.fetch_add(1, Ordering::Relaxed);
                pair
            }
            None => self.fresh_pair(bucket),
        };
        OwnedArenaLease {
            arena: Arc::clone(self),
            bucket,
            pair: Some(pair),
        }
    }
}

/// A checked-out buffer pair that keeps its arena alive: the owning form
/// of [`ArenaLease`], held across decode steps by [`crate::model::DecodeSession`]
/// and the continuous-batching scheduler. Returns the pair to the arena on
/// drop, so session teardown recycles the buffers instead of leaking or
/// freeing them.
pub struct OwnedArenaLease {
    arena: Arc<ActivationArena>,
    bucket: usize,
    pair: Option<BufferPair>,
}

impl OwnedArenaLease {
    /// The (ping, pong) buffers, mutably.
    pub(crate) fn bufs(&mut self) -> (&mut Matrix, &mut Matrix) {
        let pair = self.pair.as_mut().expect("lease holds buffers until drop");
        (&mut pair.ping, &mut pair.pong)
    }

    /// Row capacity the pair was checked out for.
    pub fn bucket(&self) -> usize {
        self.bucket
    }
}

impl Drop for OwnedArenaLease {
    fn drop(&mut self) {
        if let Some(pair) = self.pair.take() {
            self.arena
                .free
                .lock()
                .unwrap_or_else(|e| e.into_inner())
                .entry(self.bucket)
                .or_default()
                .push(pair);
        }
    }
}

/// How the band tasks of consecutive layers are allowed to overlap.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PipelineMode {
    /// Full join between layers: band `(l, j)` depends on **every** band
    /// of layer `l-1`. Semantically the pre-PR-5 forward pass, run through
    /// the scheduler so its per-layer stall is measurable.
    Barrier,
    /// Band `(l, j)` depends only on the layer-`l-1` bands overlapping its
    /// row range — bands flow through the stack with no global barrier.
    Wavefront,
}

/// Per-run scheduler observability, fed into the serving metrics and the
/// e2e bench JSON.
#[derive(Debug, Clone, Default)]
pub struct PipelineStats {
    /// Band tasks executed.
    pub tasks: usize,
    /// Workers engaged (1 = inline sequential execution).
    pub workers: usize,
    /// Maximum number of layers simultaneously in flight (the pipeline
    /// depth actually achieved; 1 on a barrier or sequential run).
    pub max_depth: usize,
    /// Total worker time spent waiting for a runnable band (µs).
    pub stall_us: u64,
    /// Wall time of the whole forward pass (µs).
    pub wall_us: u64,
    /// Per-layer idle worker time inside the layer's execution window
    /// (µs): `workers × span − busy`. In barrier mode this is the join
    /// tail the wavefront eliminates; in wavefront mode other layers'
    /// bands fill it, so it over-approximates true idleness.
    pub per_layer_stall_us: Vec<u64>,
    /// Pool workers the OS actually pinned for this run (0 on unplaced
    /// pools and sequential runs) — the placement-effectiveness gauges
    /// in `/metrics` divide stall by wall per pinned-vs-not regime.
    pub pinned_workers: usize,
}

/// One compiled layer of the pipeline.
struct Stage {
    gemm: Arc<dyn PreparedGemm>,
    epilogue: Epilogue,
    partition: RowPartition,
    n: usize,
}

/// One `(layer, band)` unit of work plus its dependency bookkeeping.
struct Task {
    layer: usize,
    band: usize,
    lo: usize,
    hi: usize,
    scratch_slot: usize,
    /// Remaining unfinished predecessor bands.
    deps: AtomicUsize,
    /// Task indices unblocked (possibly) by this task's completion.
    succ: Vec<usize>,
    /// Start/end µs since the run epoch (+1 so 0 means "never ran").
    start_us: AtomicU64,
    end_us: AtomicU64,
}

/// Mutable scheduler state shared by the workers of one run.
struct Sched {
    /// Ready task indices (popped deepest-layer-first).
    ready: Vec<usize>,
    remaining: usize,
    failed: usize,
    aborted: bool,
    running_per_layer: Vec<u32>,
    max_depth: usize,
    stall_us: u64,
}

/// Raw-pointer view of one run's inputs/outputs, shared by the workers.
struct ExecCtx<'a> {
    stages: &'a [Stage],
    scratches: &'a [Mutex<GemmScratch>],
    tasks: &'a [Task],
    x_ptr: *const f32,
    x_cols: usize,
    y_ptr: *mut f32,
    ping: *mut f32,
    pong: *mut f32,
    epoch: Instant,
}

// SAFETY: the raw pointers alias the caller's `x`/`y` borrows and the
// arena lease held for the whole run. Workers only ever touch them through
// `run_task`, whose access pattern is made disjoint by the dependency
// graph: bands of one layer write disjoint flat regions (same stride,
// disjoint rows), and a band of layer `l+2` overwrites a flat buffer
// region only after (i) every layer-`l+1` band still reading any
// layer-`l` row stored in that region completed — its dataflow
// predecessors when the strides match, plus `MlpPlan::wavefront_dep`'s
// explicit anti-dependency edges when layer `l+2`'s stride differs from
// layer `l`'s — and (ii) every layer-`l` *writer* of those rows completed
// too: each such row's layer-`l+1` reader band is a predecessor by (i)
// and itself depends on the row's writer, chaining the writer in
// transitively (this holds for arbitrary, even mismatched, per-layer
// partitions). Shape bounds were validated at compile/run entry.
unsafe impl Sync for ExecCtx<'_> {}

/// All layers of a model compiled into a band-dependency pipeline for one
/// (M-bucket, threads) key: prepared kernels, per-layer epilogues and
/// tile-aligned partitions, plus pre-sized per-(layer, band) scratch.
///
/// Band boundaries come from the same [`RowPartition`] the barrier path
/// uses, so every band's kernel call — and therefore the output — is
/// bitwise identical to the sequential forward pass.
pub struct MlpPlan {
    stages: Vec<Stage>,
    mode: PipelineMode,
    threads: usize,
    bucket: usize,
    pool: Option<Arc<ThreadPool>>,
    arena: Arc<ActivationArena>,
    /// Slot `layer * threads + band`; a band locks only its own slot, so
    /// bands of one layer fill their padded-X scratch concurrently.
    scratches: Vec<Mutex<GemmScratch>>,
}

impl MlpPlan {
    /// Compile `stages` (prepared kernel, epilogue, min rows per chunk —
    /// in layer order) into a pipeline for batches of up to `bucket` rows
    /// at `threads` fan-out. Layer chaining (`N_i == K_{i+1}`) and arena
    /// sizing are validated here so `run` cannot fail structurally.
    pub(crate) fn compile(
        specs: Vec<(Arc<dyn PreparedGemm>, Epilogue, usize)>,
        bucket: usize,
        threads: usize,
        mode: PipelineMode,
        pool: Option<Arc<ThreadPool>>,
        arena: Arc<ActivationArena>,
    ) -> Result<MlpPlan> {
        if specs.is_empty() {
            return Err(Error::Config("pipeline needs at least one layer".into()));
        }
        let threads = threads.max(1);
        let bucket = bucket.max(1);
        for pair in specs.windows(2) {
            if pair[0].0.n() != pair[1].0.k() {
                return Err(Error::Shape(format!(
                    "pipeline layer dim mismatch: {} out vs {} in",
                    pair[0].0.n(),
                    pair[1].0.k()
                )));
            }
        }
        let widest = specs[..specs.len() - 1]
            .iter()
            .map(|(gemm, _, _)| gemm.n())
            .max()
            .unwrap_or(0);
        if widest > arena.max_width() {
            return Err(Error::Shape(format!(
                "arena width {} < widest intermediate {widest}",
                arena.max_width()
            )));
        }
        let mut stages = Vec::with_capacity(specs.len());
        let mut scratches = Vec::with_capacity(specs.len() * threads);
        for (gemm, epilogue, min_rows) in specs {
            let partition = RowPartition::new(threads, min_rows);
            let mut slots: Vec<GemmScratch> = (0..threads).map(|_| GemmScratch::new()).collect();
            if gemm.uses_padded_scratch() {
                for (i, &(lo, hi)) in partition.ranges(bucket).iter().enumerate() {
                    slots[i].reserve_padded(hi - lo, gemm.k());
                }
            }
            scratches.extend(slots.into_iter().map(Mutex::new));
            stages.push(Stage {
                n: gemm.n(),
                gemm,
                epilogue,
                partition,
            });
        }
        let plan = MlpPlan {
            stages,
            mode,
            threads,
            bucket,
            pool,
            arena,
            scratches,
        };
        // Multi-layer plans will stream arena buffers every pass: let the
        // pool's own workers fault the pages in, band by band, so page
        // ownership matches the sticky band → worker assignment.
        if plan.stages.len() > 1 {
            if let Some(pool) = &plan.pool {
                plan.arena.reserve_first_touch(plan.bucket, pool);
            }
        }
        Ok(plan)
    }

    pub fn num_layers(&self) -> usize {
        self.stages.len()
    }

    pub fn d_in(&self) -> usize {
        self.stages[0].gemm.k()
    }

    pub fn d_out(&self) -> usize {
        self.stages.last().expect("non-empty").n
    }

    pub fn mode(&self) -> PipelineMode {
        self.mode
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// M-bucket ceiling the plan (and its scratch) was compiled for.
    pub fn bucket(&self) -> usize {
        self.bucket
    }

    /// Registry names of the per-layer kernels, in layer order.
    pub fn kernel_names(&self) -> Vec<&str> {
        self.stages.iter().map(|s| s.gemm.name()).collect()
    }

    /// Whether wavefront band `(layer, [lo, hi))` must wait for the
    /// layer-`layer-1` band `[plo, phi)`.
    ///
    /// Edge kinds:
    /// - **dataflow** — the band reads exactly its own rows of layer
    ///   `layer-1`'s output.
    /// - **anti-dependency** — a band that writes a ping-pong buffer
    ///   overwrites the *flat* region `[lo·n, hi·n)` at its own stride
    ///   `n`, while the buffer holds layer `layer-2`'s output (and, where
    ///   that layer's narrower data didn't cover, even older same-parity
    ///   remnants) at *their* strides. Row overlap alone proves safety
    ///   only when the strides are equal, so per stride relation:
    ///   - `n == n_prev` — the stale rows under the write are exactly
    ///     `[lo, hi)` and every reader/writer of them chains in through
    ///     the row-overlap closure; no extra edges.
    ///   - `n < n_prev` — the write sits fully inside layer-`layer-2`'s
    ///     data but maps to rows outside `[lo, hi)`; add edges to every
    ///     layer-`layer-1` band still reading those rows.
    ///   - `n > n_prev` — the write can reach *past* layer-`layer-2`'s
    ///     data into older generations; take a local barrier on the whole
    ///     previous layer (once every layer-`layer-1` band finished, all
    ///     earlier tasks finished too — completion cascades through the
    ///     dataflow edges — so the entire buffer is dead).
    fn wavefront_dep(
        &self,
        m: usize,
        layer: usize,
        lo: usize,
        hi: usize,
        plo: usize,
        phi: usize,
    ) -> bool {
        if plo < hi && lo < phi {
            return true;
        }
        if layer >= 2 && layer < self.stages.len() - 1 {
            let n_new = self.stages[layer].n;
            let n_old = self.stages[layer - 2].n;
            if n_new > n_old {
                return true;
            }
            if n_new < n_old {
                let clobber_lo = (lo * n_new) / n_old;
                let clobber_hi = (hi * n_new).div_ceil(n_old).min(m);
                if plo < clobber_hi && clobber_lo < phi {
                    return true;
                }
            }
        }
        false
    }

    /// Band tasks in layer order with dependency edges per `self.mode`.
    fn build_tasks(&self, m: usize) -> Vec<Task> {
        let ranges: Vec<Vec<(usize, usize)>> = self
            .stages
            .iter()
            .map(|s| s.partition.ranges(m))
            .collect();
        let mut offsets = Vec::with_capacity(ranges.len());
        let mut total = 0usize;
        for r in &ranges {
            offsets.push(total);
            total += r.len();
        }
        let mut tasks = Vec::with_capacity(total);
        for (layer, bands) in ranges.iter().enumerate() {
            for (band, &(lo, hi)) in bands.iter().enumerate() {
                tasks.push(Task {
                    layer,
                    band,
                    lo,
                    hi,
                    scratch_slot: layer * self.threads + band,
                    deps: AtomicUsize::new(0),
                    succ: Vec::new(),
                    start_us: AtomicU64::new(0),
                    end_us: AtomicU64::new(0),
                });
            }
        }
        // Dependency + successor edges in one pass over adjacent layers.
        for (layer, bands) in ranges.iter().enumerate().skip(1) {
            for (band, &(lo, hi)) in bands.iter().enumerate() {
                let dst = offsets[layer] + band;
                for (pband, &(plo, phi)) in ranges[layer - 1].iter().enumerate() {
                    let linked = match self.mode {
                        PipelineMode::Barrier => true,
                        PipelineMode::Wavefront => {
                            self.wavefront_dep(m, layer, lo, hi, plo, phi)
                        }
                    };
                    if linked {
                        tasks[offsets[layer - 1] + pband].succ.push(dst);
                        tasks[dst].deps.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
        }
        tasks
    }

    /// Full forward pass for an M-row batch (`m ≤ bucket`): `y` must be
    /// `m × d_out` and is fully overwritten. Intermediate activations live
    /// in arena ping-pong buffers — steady state allocates nothing beyond
    /// the per-run task list.
    ///
    /// # Errors
    /// [`Error::Runtime`] when a band task panicked (`y` is then
    /// incomplete and must be discarded).
    pub fn run(&self, x: &Matrix, y: &mut Matrix) -> Result<PipelineStats> {
        let m = x.rows();
        assert_eq!(x.cols(), self.d_in(), "input width mismatch");
        assert_eq!(y.rows(), m, "output rows mismatch");
        assert_eq!(y.cols(), self.d_out(), "output width mismatch");
        assert!(m <= self.bucket, "batch {m} exceeds plan bucket {}", self.bucket);
        let epoch = Instant::now();
        let mut stats = PipelineStats {
            workers: 1,
            max_depth: 1,
            per_layer_stall_us: vec![0; self.stages.len()],
            ..Default::default()
        };
        if m == 0 {
            return Ok(stats);
        }
        let tasks = self.build_tasks(m);
        stats.tasks = tasks.len();
        // The lease must outlive every worker touching the raw pointers;
        // it drops (returning the buffers) only after the joins below.
        let mut lease = (self.stages.len() > 1).then(|| self.arena.checkout(self.bucket));
        let (ping, pong) = match lease.as_mut() {
            Some(lease) => {
                let (a, b) = lease.bufs();
                (a.as_mut_slice().as_mut_ptr(), b.as_mut_slice().as_mut_ptr())
            }
            None => (std::ptr::null_mut(), std::ptr::null_mut()),
        };
        let ctx = ExecCtx {
            stages: &self.stages,
            scratches: &self.scratches,
            tasks: &tasks,
            x_ptr: x.as_slice().as_ptr(),
            x_cols: x.cols(),
            y_ptr: y.as_mut_slice().as_mut_ptr(),
            ping,
            pong,
            epoch,
        };
        let state = Mutex::new(Sched {
            ready: tasks
                .iter()
                .enumerate()
                .filter(|(_, t)| t.deps.load(Ordering::Relaxed) == 0)
                .map(|(i, _)| i)
                .collect(),
            remaining: tasks.len(),
            failed: 0,
            aborted: false,
            running_per_layer: vec![0; self.stages.len()],
            max_depth: 0,
            stall_us: 0,
        });
        let cv = Condvar::new();
        let workers = match &self.pool {
            Some(pool) if self.threads > 1 && tasks.len() > 1 => {
                let engaged = self.threads.min(tasks.len());
                let panicked =
                    pool.run_scoped_workers(engaged, |worker| drain(&ctx, &state, &cv, worker, engaged));
                if panicked > 0 {
                    let mut s = state.lock().unwrap_or_else(|e| e.into_inner());
                    s.failed += panicked;
                }
                stats.pinned_workers = pool.pinned_workers().min(engaged);
                engaged
            }
            _ => {
                drain(&ctx, &state, &cv, 0, 1);
                1
            }
        };
        let sched = state.into_inner().unwrap_or_else(|e| e.into_inner());
        if sched.failed > 0 {
            return Err(Error::Runtime(format!(
                "{} pipelined band task(s) panicked",
                sched.failed
            )));
        }
        stats.workers = workers;
        stats.max_depth = sched.max_depth.max(1);
        stats.stall_us = sched.stall_us;
        stats.wall_us = epoch.elapsed().as_micros() as u64;
        // Per-layer stall: idle worker time inside the layer's execution
        // window, from the band timestamps.
        for (layer, stall) in stats.per_layer_stall_us.iter_mut().enumerate() {
            let (mut first, mut last, mut busy) = (u64::MAX, 0u64, 0u64);
            for t in tasks.iter().filter(|t| t.layer == layer) {
                let s = t.start_us.load(Ordering::Relaxed);
                let e = t.end_us.load(Ordering::Relaxed);
                if s == 0 || e == 0 {
                    continue;
                }
                first = first.min(s);
                last = last.max(e);
                busy += e.saturating_sub(s);
            }
            if first < last {
                *stall = (workers as u64 * (last - first)).saturating_sub(busy);
            }
        }
        drop(lease);
        Ok(stats)
    }
}

/// Barrier-style multi-layer forward over an arena ping-pong: layer 0
/// reads `x` borrowed, the last layer writes `y`, and intermediates
/// alternate between the lease's two buffers — the shared loop behind
/// [`crate::plan::PlanCache::run_layers`] and the explicit-layer
/// [`crate::model::TernaryMlp`] path. `widths[i]` is layer `i`'s output
/// width; `run_layer(i, input, output)` executes one layer.
///
/// Batches beyond the M-bucket cap lease an exact-size buffer pair (the
/// bucketed sizes stop covering `m` there), so arbitrarily large batches
/// keep working — rare giant sizes each allocate once and are reused when
/// the same size recurs.
pub(crate) fn pingpong_forward<F>(
    arena: &ActivationArena,
    widths: &[usize],
    x: &Matrix,
    y: &mut Matrix,
    mut run_layer: F,
) -> Result<()>
where
    F: FnMut(usize, &Matrix, &mut Matrix) -> Result<()>,
{
    let nl = widths.len();
    assert!(nl > 0, "pingpong_forward needs at least one layer");
    if nl == 1 {
        return run_layer(0, x, y);
    }
    let m = x.rows();
    let rows = crate::autotune::table::m_bucket(m).max(m);
    let mut lease = arena.checkout(rows);
    let (ping, pong) = lease.bufs();
    // `prev` holds layer i-1's output while layer i writes `next`.
    let mut prev: &mut [f32] = ping.as_mut_slice();
    let mut next: &mut [f32] = pong.as_mut_slice();
    let w0 = widths[0];
    Matrix::with_view_mut(&mut prev[..m * w0], m, w0, |y0| run_layer(0, x, y0))?;
    for i in 1..nl {
        let n_in = widths[i - 1];
        let n_out = widths[i];
        let result = Matrix::with_view(&prev[..m * n_in], m, n_in, |xin| {
            if i == nl - 1 {
                run_layer(i, xin, y)
            } else {
                Matrix::with_view_mut(&mut next[..m * n_out], m, n_out, |yout| {
                    run_layer(i, xin, yout)
                })
            }
        });
        result?;
        std::mem::swap(&mut prev, &mut next);
    }
    Ok(())
}

/// Worker loop: pull the deepest ready band preferring this worker's
/// own (sticky) bands, run it, release successors. Any single worker
/// can drain the whole graph alone (required by
/// [`ThreadPool::run_scoped_workers`]'s no-mutual-dependence contract).
fn drain(ctx: &ExecCtx<'_>, state: &Mutex<Sched>, cv: &Condvar, worker: usize, workers: usize) {
    let lock = || state.lock().unwrap_or_else(|e| e.into_inner());
    let mut guard: MutexGuard<'_, Sched> = lock();
    loop {
        while guard.ready.is_empty() && guard.remaining > 0 && !guard.aborted {
            let wait_start = Instant::now();
            guard = cv.wait(guard).unwrap_or_else(|e| e.into_inner());
            guard.stall_us += wait_start.elapsed().as_micros() as u64;
        }
        if guard.remaining == 0 || guard.aborted {
            cv.notify_all();
            return;
        }
        // Sticky bands first — band `j` of every layer prefers the same
        // worker (on a placed pool, the same pinned core, so a band
        // reuses the L2 that last streamed its rows); within that,
        // deepest layer first (finish rows; their activations are hot),
        // leftmost band as the tie-break. Foreign bands are still
        // stolen when nothing of our own is ready: placement moves
        // work, it never withholds it.
        let pos = guard
            .ready
            .iter()
            .enumerate()
            .max_by_key(|&(_, &t)| {
                let task = &ctx.tasks[t];
                let mine = ctx.stages[task.layer]
                    .partition
                    .preferred_worker(task.band, workers)
                    == worker;
                (mine, task.layer, std::cmp::Reverse(task.lo))
            })
            .map(|(pos, _)| pos)
            .expect("ready non-empty");
        let t_idx = guard.ready.swap_remove(pos);
        let layer = ctx.tasks[t_idx].layer;
        guard.running_per_layer[layer] += 1;
        let depth = guard.running_per_layer.iter().filter(|&&c| c > 0).count();
        guard.max_depth = guard.max_depth.max(depth);
        drop(guard);
        let panicked = catch_unwind(AssertUnwindSafe(|| run_task(ctx, t_idx))).is_err();
        guard = lock();
        guard.running_per_layer[layer] -= 1;
        guard.remaining -= 1;
        if panicked {
            guard.failed += 1;
            // Downstream bands would read garbage: stop the run. Workers
            // mid-band finish their current task and exit.
            guard.aborted = true;
            cv.notify_all();
            continue;
        }
        let mut released = false;
        for &succ in &ctx.tasks[t_idx].succ {
            if ctx.tasks[succ].deps.fetch_sub(1, Ordering::AcqRel) == 1 {
                guard.ready.push(succ);
                released = true;
            }
        }
        if released || guard.remaining == 0 {
            cv.notify_all();
        }
    }
}

/// Execute one band: gather the input/output row windows, run the layer's
/// prepared kernel with this band's scratch slot, apply the epilogue over
/// the band (elementwise, so per-band application is bitwise identical to
/// the barrier path's whole-matrix pass).
fn run_task(ctx: &ExecCtx<'_>, t_idx: usize) {
    let t = &ctx.tasks[t_idx];
    let stage = &ctx.stages[t.layer];
    let nl = ctx.stages.len();
    let rows = t.hi - t.lo;
    t.start_us
        .store(ctx.epoch.elapsed().as_micros() as u64 + 1, Ordering::Relaxed);
    let (in_ptr, in_cols) = if t.layer == 0 {
        (ctx.x_ptr, ctx.x_cols)
    } else {
        let buf = if (t.layer - 1) % 2 == 0 { ctx.ping } else { ctx.pong };
        (buf.cast_const(), ctx.stages[t.layer - 1].n)
    };
    let out_ptr = if t.layer == nl - 1 {
        ctx.y_ptr
    } else if t.layer % 2 == 0 {
        ctx.ping
    } else {
        ctx.pong
    };
    let out_cols = stage.n;
    // SAFETY: `in_ptr`/`out_ptr` point into buffers alive for the whole
    // run (caller's x/y borrows, or the arena lease). The row window
    // `[lo, hi)` is in bounds (ranges cover `0..m`, buffers hold `bucket ≥
    // m` rows at ≥ the layer's width, densely packed at this layer's
    // stride). Disjointness of concurrent accesses is the dependency
    // graph's invariant (see `ExecCtx`'s SAFETY note).
    let (x_chunk, y_chunk) = unsafe {
        (
            std::slice::from_raw_parts(in_ptr.add(t.lo * in_cols), rows * in_cols),
            std::slice::from_raw_parts_mut(out_ptr.add(t.lo * out_cols), rows * out_cols),
        )
    };
    let mut scratch = ctx.scratches[t.scratch_slot]
        .lock()
        .unwrap_or_else(|e| e.into_inner());
    Matrix::with_view(x_chunk, rows, in_cols, |xv| {
        Matrix::with_view_mut(y_chunk, rows, out_cols, |yv| {
            stage.gemm.run_with_scratch(xv, &stage.epilogue.bias, yv, &mut scratch);
            stage.epilogue.apply(yv, stage.gemm.fused_prelu());
        })
    });
    t.end_us
        .store(ctx.epoch.elapsed().as_micros() as u64 + 1, Ordering::Relaxed);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{dense_oracle, prelu_inplace, prepare_kernel, KernelParams};
    use crate::ternary::TernaryMatrix;

    fn stage(
        kernel: &str,
        w: &TernaryMatrix,
        bias: Vec<f32>,
        prelu: Option<f32>,
    ) -> (Arc<dyn PreparedGemm>, Epilogue, usize) {
        let gemm: Arc<dyn PreparedGemm> =
            prepare_kernel(kernel, w, KernelParams::default()).unwrap().into();
        (gemm, Epilogue::new(bias, 1.0, prelu), 2)
    }

    fn two_layer_plan(
        threads: usize,
        mode: PipelineMode,
        pool: Option<Arc<ThreadPool>>,
    ) -> (MlpPlan, TernaryMatrix, TernaryMatrix, Vec<f32>, Vec<f32>) {
        let w1 = TernaryMatrix::random(32, 48, 0.25, 1);
        let w2 = TernaryMatrix::random(48, 16, 0.25, 2);
        let b1: Vec<f32> = (0..48).map(|i| 0.01 * i as f32).collect();
        let b2: Vec<f32> = (0..16).map(|i| 0.02 * i as f32 - 0.1).collect();
        let arena = Arc::new(ActivationArena::new(48));
        let plan = MlpPlan::compile(
            vec![
                stage("interleaved_blocked_tcsc", &w1, b1.clone(), Some(0.25)),
                stage("simd_vertical", &w2, b2.clone(), None),
            ],
            64,
            threads,
            mode,
            pool,
            arena,
        )
        .unwrap();
        (plan, w1, w2, b1, b2)
    }

    fn oracle2(
        x: &Matrix,
        w1: &TernaryMatrix,
        w2: &TernaryMatrix,
        b1: &[f32],
        b2: &[f32],
    ) -> Matrix {
        let mut h = dense_oracle(x, w1, b1);
        prelu_inplace(&mut h, 0.25);
        dense_oracle(&h, w2, b2)
    }

    #[test]
    fn wavefront_matches_oracle_and_barrier_bitwise() {
        let pool = Arc::new(ThreadPool::new(4));
        for &m in &[0usize, 1, 3, 8, 13, 33, 64] {
            let x = Matrix::random(m, 32, 10 + m as u64);
            let (seq, w1, w2, b1, b2) = two_layer_plan(1, PipelineMode::Wavefront, None);
            let mut y_seq = Matrix::zeros(m, 16);
            let stats = seq.run(&x, &mut y_seq).unwrap();
            assert_eq!(stats.workers, 1);
            if m > 0 {
                assert!(y_seq.allclose(&oracle2(&x, &w1, &w2, &b1, &b2), 1e-3), "m={m}");
            }
            for &threads in &[2usize, 4] {
                for mode in [PipelineMode::Barrier, PipelineMode::Wavefront] {
                    let (par, ..) = two_layer_plan(threads, mode, Some(Arc::clone(&pool)));
                    let mut y_par = Matrix::zeros(m, 16);
                    let stats = par.run(&x, &mut y_par).unwrap();
                    assert_eq!(
                        y_seq, y_par,
                        "m={m} threads={threads} {mode:?}: must be bitwise sequential"
                    );
                    if m > 0 {
                        assert!(stats.tasks >= 2, "two layers → at least two bands");
                        assert_eq!(stats.per_layer_stall_us.len(), 2);
                    }
                }
            }
        }
    }

    /// Regression: same-parity layers with *different* widths share a
    /// ping-pong buffer at different strides, so a deep band's flat write
    /// region can cover stale rows outside its own row range — rows a
    /// not-yet-finished shallower band still reads. The anti-dependency
    /// edges (`wavefront_dep`) must serialize exactly those pairs; without
    /// them this test produces wrong bits or races. Covers both the
    /// width-growing (8 → 64) and width-shrinking (64 → 20) directions.
    #[test]
    fn mismatched_same_parity_widths_stay_bitwise_correct() {
        let pool = Arc::new(ThreadPool::new(4));
        // Layer widths 8, 16, 64, 4, 16: layer 2 (n=64) grows over layer 0
        // (n=8) on ping — the local-barrier direction — and layer 3 (n=4)
        // shrinks over layer 1 (n=16) on pong — the targeted-anti-edge
        // direction.
        let dims = [64usize, 8, 16, 64, 4, 16];
        let weights: Vec<TernaryMatrix> = dims
            .windows(2)
            .enumerate()
            .map(|(i, d)| TernaryMatrix::random(d[0], d[1], 0.25, 40 + i as u64))
            .collect();
        let build = |threads: usize, mode: PipelineMode, pool: Option<Arc<ThreadPool>>| {
            let arena = Arc::new(ActivationArena::new(64));
            MlpPlan::compile(
                weights
                    .iter()
                    .enumerate()
                    .map(|(i, w)| {
                        let prelu = (i + 1 < weights.len()).then_some(0.25);
                        stage("interleaved_blocked_tcsc", w, vec![0.01; w.n()], prelu)
                    })
                    .collect(),
                64,
                threads,
                mode,
                pool,
                arena,
            )
            .unwrap()
        };
        let seq = build(1, PipelineMode::Wavefront, None);
        for &m in &[1usize, 13, 33, 64] {
            let x = Matrix::random(m, 64, 50 + m as u64);
            let mut y_seq = Matrix::zeros(m, 16);
            seq.run(&x, &mut y_seq).unwrap();
            for &threads in &[2usize, 4] {
                let wave = build(threads, PipelineMode::Wavefront, Some(Arc::clone(&pool)));
                // Repeat: the hazard is an interleaving, not a one-shot.
                for rep in 0..5 {
                    let mut y_wave = Matrix::zeros(m, 16);
                    wave.run(&x, &mut y_wave).unwrap();
                    assert_eq!(
                        y_seq, y_wave,
                        "m={m} threads={threads} rep={rep}: stride-mismatched \
                         ping-pong reuse corrupted the wavefront output"
                    );
                }
            }
        }
    }

    #[test]
    fn single_layer_plan_skips_the_arena() {
        let w = TernaryMatrix::random(24, 8, 0.5, 3);
        let arena = Arc::new(ActivationArena::new(0));
        let plan = MlpPlan::compile(
            vec![stage("base_tcsc", &w, vec![0.1; 8], None)],
            16,
            1,
            PipelineMode::Wavefront,
            None,
            Arc::clone(&arena),
        )
        .unwrap();
        let x = Matrix::random(5, 24, 4);
        let bias = vec![0.1f32; 8];
        let mut y = Matrix::zeros(5, 8);
        plan.run(&x, &mut y).unwrap();
        assert!(y.allclose(&dense_oracle(&x, &w, &bias), 1e-4));
        assert_eq!(arena.stats(), ArenaStats::default(), "no intermediates");
    }

    #[test]
    fn compile_validates_chain_and_arena_width() {
        let w1 = TernaryMatrix::random(8, 16, 0.5, 1);
        let w2 = TernaryMatrix::random(4, 2, 0.5, 2); // mismatched
        let arena = Arc::new(ActivationArena::new(16));
        assert!(matches!(
            MlpPlan::compile(
                vec![
                    stage("base_tcsc", &w1, vec![0.0; 16], None),
                    stage("base_tcsc", &w2, vec![0.0; 2], None),
                ],
                8,
                1,
                PipelineMode::Wavefront,
                None,
                Arc::clone(&arena),
            ),
            Err(Error::Shape(_))
        ));
        // An arena narrower than the widest intermediate is rejected.
        let w3 = TernaryMatrix::random(16, 4, 0.5, 3);
        assert!(matches!(
            MlpPlan::compile(
                vec![
                    stage("base_tcsc", &w1, vec![0.0; 16], None),
                    stage("base_tcsc", &w3, vec![0.0; 4], None),
                ],
                8,
                1,
                PipelineMode::Wavefront,
                None,
                Arc::new(ActivationArena::new(8)),
            ),
            Err(Error::Shape(_))
        ));
        assert!(MlpPlan::compile(
            Vec::new(),
            8,
            1,
            PipelineMode::Wavefront,
            None,
            arena
        )
        .is_err());
    }

    #[test]
    fn arena_reuses_buffers_per_bucket() {
        let arena = ActivationArena::new(32);
        {
            let _a = arena.checkout(8);
            let _b = arena.checkout(8); // concurrent lease → second pair
        }
        assert_eq!(arena.stats(), ArenaStats { allocations: 2, reuses: 0 });
        {
            let _a = arena.checkout(8);
        }
        {
            let _a = arena.checkout(8);
            let _b = arena.checkout(16); // new bucket → new pair
        }
        let stats = arena.stats();
        assert_eq!(stats.allocations, 3, "bucket 8 pair is reused");
        assert_eq!(stats.reuses, 2);
        // reserve pre-allocates so the first checkout is a reuse.
        arena.reserve(4);
        arena.reserve(4); // idempotent while the pair sits free
        assert_eq!(arena.stats().allocations, 4);
        let _c = arena.checkout(4);
        assert_eq!(arena.stats().reuses, 3);
    }

    #[test]
    fn wavefront_overlaps_layers() {
        // With many bands and workers, the wavefront must actually reach
        // depth ≥ 2 (two layers in flight at once) on a healthy run.
        let pool = Arc::new(ThreadPool::new(4));
        let w1 = TernaryMatrix::random(64, 64, 0.25, 7);
        let w2 = TernaryMatrix::random(64, 64, 0.25, 8);
        let arena = Arc::new(ActivationArena::new(64));
        let plan = MlpPlan::compile(
            vec![
                stage("interleaved_blocked_tcsc", &w1, vec![0.0; 64], Some(0.25)),
                stage("interleaved_blocked_tcsc", &w2, vec![0.0; 64], None),
            ],
            256,
            4,
            PipelineMode::Wavefront,
            Some(pool),
            arena,
        )
        .unwrap();
        let x = Matrix::random(256, 64, 9);
        let mut y = Matrix::zeros(256, 64);
        // Depth is timing-dependent; assert it over a few attempts.
        let mut best_depth = 0;
        for _ in 0..5 {
            let stats = plan.run(&x, &mut y).unwrap();
            best_depth = best_depth.max(stats.max_depth);
        }
        assert!(best_depth >= 2, "wavefront never overlapped layers");
    }
}
