//! In-place parallel row partitioning.
//!
//! `Y = X·W + b` is embarrassingly parallel over rows of X, so a batch is
//! split into contiguous row chunks and the *same* prepared kernel runs on
//! each chunk concurrently. Unlike the old `ParallelGemm` wrapper (which
//! copied each X chunk into a fresh matrix, ran into a fresh per-chunk Y,
//! and stitched the results back), the partitioner here is zero-copy and
//! zero-allocation in steady state:
//!
//! - each worker reads its X rows through a borrowed [`Matrix::with_view`]
//!   over the contiguous row-major storage (no chunk materialization);
//! - each worker writes through [`Matrix::with_view_mut`] directly into its
//!   disjoint row block of the caller's Y (`split_at_mut`, no stitch copy);
//! - per-worker kernel scratch (the SIMD padded-X buffer) is owned by the
//!   caller and reused across runs;
//! - jobs execute on a shared [`ThreadPool`] via scoped fork-join
//!   ([`ThreadPool::run_scoped`]) instead of spawning OS threads per call.
//!
//! Chunk boundaries are aligned to [`ROW_TILE`] so that a row's membership
//! in a kernel's M-unroll tile is identical in sequential and chunked
//! runs — parallel results are **bitwise identical** to sequential ones.

use crate::kernels::{GemmScratch, PreparedGemm};
use crate::tensor::Matrix;
use crate::util::threadpool::ThreadPool;
use crate::{Error, Result};

/// The largest M-direction unroll used by any registry kernel (`MU = 4`).
/// Chunk boundaries are multiples of this so tile membership — and hence
/// floating-point accumulation order — matches the sequential run exactly.
pub const ROW_TILE: usize = 4;

/// Row-partitioning policy: how a batch of M rows splits across workers.
#[derive(Debug, Clone, Copy)]
pub struct RowPartition {
    /// Maximum parallel chunks (worker threads used per run).
    pub max_chunks: usize,
    /// Minimum rows per chunk; batches smaller than `2·min_rows` run
    /// sequentially (fan-out isn't worth it).
    pub min_rows_per_chunk: usize,
}

impl Default for RowPartition {
    fn default() -> Self {
        RowPartition {
            max_chunks: 1,
            min_rows_per_chunk: 2,
        }
    }
}

impl RowPartition {
    pub fn new(max_chunks: usize, min_rows_per_chunk: usize) -> RowPartition {
        RowPartition {
            max_chunks: max_chunks.max(1),
            min_rows_per_chunk: min_rows_per_chunk.max(1),
        }
    }

    /// Target number of chunks for an M-row batch (before tile alignment).
    pub fn chunks_for(&self, m: usize) -> usize {
        self.max_chunks.min(m / self.min_rows_per_chunk).max(1)
    }

    /// The worker a band *prefers* (cluster-sticky assignment): band `j`
    /// of every layer maps to worker `j mod workers`, so on a placed
    /// pool — where logical worker `i` is pinned to core `i`'s cluster —
    /// the same rows hit the same L2 pass after pass, and the arena's
    /// first-touch pass pages each band into its consumer's locality
    /// domain. A preference only: the wavefront scheduler still steals
    /// foreign bands rather than idle, which cannot change results
    /// (bands are bitwise-identical wherever they run).
    pub fn preferred_worker(&self, band: usize, workers: usize) -> usize {
        band % workers.max(1)
    }

    /// Contiguous row ranges `[lo, hi)` covering `0..m`. Every boundary is
    /// a multiple of [`ROW_TILE`] (except the final `m`), which may yield
    /// fewer chunks than [`RowPartition::chunks_for`] for small batches.
    pub fn ranges(&self, m: usize) -> Vec<(usize, usize)> {
        if m == 0 {
            return Vec::new();
        }
        let chunks = self.chunks_for(m);
        let rows_per = m.div_ceil(chunks).div_ceil(ROW_TILE).max(1) * ROW_TILE;
        let mut out = Vec::with_capacity(chunks);
        let mut lo = 0;
        while lo < m {
            let hi = (lo + rows_per).min(m);
            out.push((lo, hi));
            lo = hi;
        }
        out
    }
}

/// Execute `gemm` over `x` into `y`, row-partitioned per `part`.
///
/// Sequential when the batch is too small, `pool` is `None`, or only one
/// chunk results; otherwise fans out over `pool`, each worker writing its
/// disjoint `&mut Y` row block in place. `scratches` must hold at least
/// one slot, and at least as many as the partition can produce chunks when
/// a pool is supplied; slot `i` is reused by chunk `i` across calls.
///
/// # Errors
/// [`Error::Runtime`] when any worker job panicked — the panic is isolated
/// by the pool, but `y` is then incomplete and must not be served.
/// (Sequential execution propagates a kernel panic on the caller thread
/// unchanged.)
pub fn execute_partitioned(
    gemm: &dyn PreparedGemm,
    part: RowPartition,
    pool: Option<&ThreadPool>,
    x: &Matrix,
    bias: &[f32],
    y: &mut Matrix,
    scratches: &mut [GemmScratch],
) -> Result<()> {
    assert!(!scratches.is_empty(), "need at least one scratch slot");
    assert_eq!(x.rows(), y.rows(), "X/Y row mismatch");
    assert_eq!(x.cols(), gemm.k(), "X cols must equal K");
    assert_eq!(y.cols(), gemm.n(), "Y cols must equal N");
    let m = x.rows();
    let ranges = part.ranges(m);
    if ranges.len() <= 1 || pool.is_none() {
        gemm.run_with_scratch(x, bias, y, &mut scratches[0]);
        return Ok(());
    }
    let pool = pool.expect("checked above");
    assert!(
        scratches.len() >= ranges.len(),
        "need one scratch slot per chunk ({} < {})",
        scratches.len(),
        ranges.len()
    );
    let k = x.cols();
    let n = y.cols();
    let x_data = x.as_slice();
    let mut y_rest = y.as_mut_slice();
    let mut s_rest = scratches;
    let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(ranges.len());
    for &(lo, hi) in &ranges {
        let rows = hi - lo;
        let (y_chunk, y_next) = std::mem::take(&mut y_rest).split_at_mut(rows * n);
        y_rest = y_next;
        let (scratch, s_next) = std::mem::take(&mut s_rest)
            .split_first_mut()
            .expect("scratch slot per chunk");
        s_rest = s_next;
        let x_chunk = &x_data[lo * k..hi * k];
        jobs.push(Box::new(move || {
            Matrix::with_view(x_chunk, rows, k, |xv| {
                Matrix::with_view_mut(y_chunk, rows, n, |yv| {
                    gemm.run_with_scratch(xv, bias, yv, scratch);
                });
            });
        }));
    }
    // On strictly-placed pools, chunk `i` routes to pinned thread `i`
    // (see `RowPartition::preferred_worker`) so repeat batches stream
    // the same rows through the same L2.
    let panicked = if pool.sticky_routing() {
        pool.run_scoped_assigned(jobs)
    } else {
        pool.run_scoped(jobs)
    };
    if panicked > 0 {
        return Err(Error::Runtime(format!(
            "{panicked} partitioned GEMM worker(s) panicked"
        )));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{dense_oracle, prepare_kernel, KernelParams};
    use crate::ternary::TernaryMatrix;

    #[test]
    fn ranges_are_tile_aligned_and_cover() {
        let p = RowPartition::new(4, 2);
        for m in [0usize, 1, 2, 3, 4, 7, 8, 13, 64, 65] {
            let r = p.ranges(m);
            if m == 0 {
                assert!(r.is_empty());
                continue;
            }
            assert_eq!(r.first().unwrap().0, 0);
            assert_eq!(r.last().unwrap().1, m);
            for w in r.windows(2) {
                assert_eq!(w[0].1, w[1].0, "contiguous");
            }
            for &(lo, hi) in &r {
                assert!(lo % ROW_TILE == 0, "m={m} lo={lo}");
                assert!(hi == m || hi % ROW_TILE == 0, "m={m} hi={hi}");
            }
            assert!(r.len() <= p.chunks_for(m));
        }
    }

    #[test]
    fn tiny_batches_are_one_chunk() {
        let p = RowPartition::new(8, 2);
        assert_eq!(p.ranges(1).len(), 1);
        assert_eq!(p.ranges(3).len(), 1);
        assert_eq!(p.chunks_for(1), 1);
    }

    #[test]
    fn partitioned_execution_is_bitwise_sequential() {
        let w = TernaryMatrix::random(96, 32, 0.25, 3);
        let x = Matrix::random(13, 96, 4);
        let bias: Vec<f32> = (0..32).map(|i| 0.1 * i as f32).collect();
        let oracle = dense_oracle(&x, &w, &bias);
        let pool = ThreadPool::new(4);
        for name in [
            "interleaved_blocked_tcsc",
            "simd_vertical",
            "simd_blocked_interleaved",
            "unrolled_tcsc_k4_m4",
            "dense_gemm",
        ] {
            let gemm = prepare_kernel(name, &w, KernelParams::default()).unwrap();
            let mut y_seq = Matrix::zeros(13, 32);
            let mut seq_scratch = [GemmScratch::new()];
            execute_partitioned(
                gemm.as_ref(),
                RowPartition::new(1, 2),
                None,
                &x,
                &bias,
                &mut y_seq,
                &mut seq_scratch,
            )
            .unwrap();
            assert!(y_seq.allclose(&oracle, 1e-3), "{name} sequential");
            for threads in [2usize, 4, 8] {
                let mut scratches: Vec<GemmScratch> =
                    (0..threads).map(|_| GemmScratch::new()).collect();
                let mut y_par = Matrix::zeros(13, 32);
                execute_partitioned(
                    gemm.as_ref(),
                    RowPartition::new(threads, 2),
                    Some(&pool),
                    &x,
                    &bias,
                    &mut y_par,
                    &mut scratches,
                )
                .unwrap();
                assert_eq!(
                    y_seq, y_par,
                    "{name} threads={threads}: parallel must be bitwise sequential"
                );
            }
        }
    }

    /// A kernel that panics mid-batch: the GEMM family's own invariants
    /// (`debug_check_shapes`, unchecked-gather contracts) panic rather
    /// than return, so a worker panic is the failure mode the serving
    /// path must survive.
    struct PanickingGemm;

    impl PreparedGemm for PanickingGemm {
        fn name(&self) -> &str {
            "panicking_test_gemm"
        }
        fn run(&self, _x: &Matrix, _bias: &[f32], _y: &mut Matrix) {
            panic!("injected kernel panic");
        }
        fn k(&self) -> usize {
            8
        }
        fn n(&self) -> usize {
            4
        }
        fn nnz(&self) -> usize {
            0
        }
        fn format_bytes(&self) -> usize {
            0
        }
    }

    /// Regression (PR 5): worker panics used to be an ignorable return
    /// count — now they surface as a typed `Error::Runtime` through the
    /// plan/execute path, and the pool survives to serve the next batch.
    #[test]
    fn worker_panic_surfaces_as_runtime_error() {
        let pool = ThreadPool::new(2);
        let x = Matrix::random(16, 8, 1);
        let bias = vec![0.0f32; 4];
        let mut y = Matrix::zeros(16, 4);
        let mut scratches: Vec<GemmScratch> = (0..4).map(|_| GemmScratch::new()).collect();
        let err = execute_partitioned(
            &PanickingGemm,
            RowPartition::new(4, 2),
            Some(&pool),
            &x,
            &bias,
            &mut y,
            &mut scratches,
        )
        .unwrap_err();
        assert!(
            matches!(err, Error::Runtime(ref msg) if msg.contains("panicked")),
            "{err}"
        );
        // The pool is intact: a healthy kernel still runs through it.
        let w = TernaryMatrix::random(8, 4, 0.5, 2);
        let gemm = prepare_kernel("base_tcsc", &w, KernelParams::default()).unwrap();
        let mut ok = Matrix::zeros(16, 4);
        execute_partitioned(
            gemm.as_ref(),
            RowPartition::new(4, 2),
            Some(&pool),
            &x,
            &bias,
            &mut ok,
            &mut scratches,
        )
        .unwrap();
        assert!(ok.allclose(&dense_oracle(&x, &w, &bias), 1e-4));
    }
}
