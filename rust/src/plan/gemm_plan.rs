//! [`GemmPlan`]: the planned-execution object — a prepared kernel bound to
//! its epilogue, partitioning policy, thread pool and reusable scratch.

use crate::kernels::{prelu_inplace, GemmScratch, PreparedGemm};
use crate::plan::partition::{execute_partitioned, RowPartition};
use crate::tensor::Matrix;
use crate::util::threadpool::ThreadPool;
use crate::Result;
use std::sync::{Arc, Mutex};

/// Everything applied after the raw GEMM: `y = act(scale · (X·W + b))`.
///
/// The bias is always folded into the kernel call (every kernel adds it in
/// its inner loop). PReLU is fused into the kernel when the kernel family
/// supports it **and** no dequantization scale sits between the GEMM and
/// the activation (scale and PReLU don't commute bit-exactly); otherwise it
/// runs as a separate pass here.
#[derive(Debug, Clone)]
pub struct Epilogue {
    /// Per-output-column bias, length N.
    pub bias: Vec<f32>,
    /// Per-tensor dequantization scale (absmean quantizer's gamma);
    /// 1.0 = no scaling.
    pub scale: f32,
    /// PReLU slope; `None` = linear output.
    pub prelu_alpha: Option<f32>,
}

impl Epilogue {
    pub fn new(bias: Vec<f32>, scale: f32, prelu_alpha: Option<f32>) -> Epilogue {
        Epilogue {
            bias,
            scale,
            prelu_alpha,
        }
    }

    /// Bias-only epilogue (no scale, no activation).
    pub fn with_bias(bias: Vec<f32>) -> Epilogue {
        Epilogue::new(bias, 1.0, None)
    }

    /// The PReLU slope if it may be folded into a fusing kernel (exact only
    /// when no scale is applied between GEMM and activation).
    pub fn fusible_prelu(&self) -> Option<f32> {
        if self.scale == 1.0 {
            self.prelu_alpha
        } else {
            None
        }
    }

    /// Post-GEMM pass over `y`: scale, then PReLU unless the kernel
    /// already fused it.
    pub fn apply(&self, y: &mut Matrix, prelu_fused: bool) {
        if self.scale != 1.0 {
            for v in y.as_mut_slice() {
                *v *= self.scale;
            }
        }
        if let Some(alpha) = self.prelu_alpha {
            if !prelu_fused {
                prelu_inplace(y, alpha);
            }
        }
    }
}

/// A fully planned GEMM: run it, repeatedly, and nothing else needs
/// deciding — kernel, epilogue, threading and scratch were all fixed at
/// plan time by [`crate::plan::Planner::plan`].
///
/// `run` is `&self` (the serving engine shares plans across threads); the
/// scratch lives behind a mutex, so concurrent callers serialize on the
/// same plan while different plans (e.g. different layers) run freely.
pub struct GemmPlan {
    pub(crate) gemm: Arc<dyn PreparedGemm>,
    pub(crate) epilogue: Epilogue,
    pub(crate) partition: RowPartition,
    pub(crate) pool: Option<Arc<ThreadPool>>,
    pub(crate) scratch: Mutex<Vec<GemmScratch>>,
}

impl GemmPlan {
    /// Compute `y = act(scale · (x·W + b))` for an M-row batch. `y` must be
    /// M×N and is fully overwritten. Steady-state calls at a fixed M
    /// perform no allocation beyond the per-run job list.
    ///
    /// # Errors
    /// [`crate::Error::Runtime`] when a partitioned worker panicked (`y`
    /// is then incomplete and must be discarded).
    pub fn run(&self, x: &Matrix, y: &mut Matrix) -> Result<()> {
        {
            let mut scratches = self.scratch.lock().unwrap_or_else(|e| e.into_inner());
            execute_partitioned(
                self.gemm.as_ref(),
                self.partition,
                self.pool.as_deref(),
                x,
                &self.epilogue.bias,
                y,
                &mut scratches,
            )?;
        }
        self.epilogue.apply(y, self.gemm.fused_prelu());
        Ok(())
    }

    /// Allocating convenience: `run` into a fresh M×N matrix.
    pub fn forward(&self, x: &Matrix) -> Result<Matrix> {
        let mut y = Matrix::zeros(x.rows(), self.n());
        self.run(x, &mut y)?;
        Ok(y)
    }

    /// Registry name of the planned kernel.
    pub fn kernel_name(&self) -> &str {
        self.gemm.name()
    }

    pub fn k(&self) -> usize {
        self.gemm.k()
    }

    pub fn n(&self) -> usize {
        self.gemm.n()
    }

    pub fn nnz(&self) -> usize {
        self.gemm.nnz()
    }

    /// Exact format byte size (operational-intensity accounting).
    pub fn format_bytes(&self) -> usize {
        self.gemm.format_bytes()
    }

    /// Whether the kernel applies PReLU inside the GEMM.
    pub fn fused_prelu(&self) -> bool {
        self.gemm.fused_prelu()
    }

    pub fn epilogue(&self) -> &Epilogue {
        &self.epilogue
    }

    /// Maximum worker chunks this plan fans out to (1 = sequential).
    pub fn threads(&self) -> usize {
        self.partition.max_chunks
    }

    /// Capacity snapshot of every scratch slot, in f32 elements.
    /// Allocation-stability tests assert this is unchanged across runs.
    pub fn scratch_capacities(&self) -> Vec<usize> {
        self.scratch
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .iter()
            .map(|s| s.padded_capacity())
            .collect()
    }

    /// Paper cost-model flops for an M-row batch: `M·nnz` add/sub flops,
    /// `M·N` bias adds, plus an `M·N` activation pass when PReLU is on.
    pub fn flops(&self, m: usize) -> f64 {
        let mut f = m as f64 * self.nnz() as f64 + (m * self.n()) as f64;
        if self.epilogue.prelu_alpha.is_some() {
            f += (m * self.n()) as f64;
        }
        f
    }
}
