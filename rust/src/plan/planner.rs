//! [`Planner`]: turns dense ternary weights + execution hints into a
//! [`GemmPlan`], consulting the autotune [`TuningTable`] and falling back
//! to the paper's heuristics when a shape class was never tuned.
//!
//! Kernel choice is **typed end-to-end**: hints, tuning entries and the
//! heuristic candidates all carry a [`KernelId`], and the heuristic
//! candidate sets are *derived queries over the registry's descriptor
//! table* ([`crate::kernels::gemv_specialist`], [`crate::kernels::best_scalar`],
//! [`crate::kernels::fused_simd`], [`crate::kernels::matrix_tile`]) — no
//! kernel is named by string literal here, so a new registry row
//! automatically participates in selection.
//!
//! Selection is also **capability-filtered**: the planner carries a
//! [`CpuCaps`] snapshot (host by default, synthetic via
//! [`Planner::with_caps`]) and refuses to emit any kernel whose descriptor
//! `requires` a feature the caps lack — tuned entries are skipped, hinted
//! kernels error with [`Error::UnsupportedKernel`] — so a plan built for
//! an unavailable capability is unrepresentable.
//!
//! The tuning table lives behind a `RwLock` so one `Arc<Planner>` can be
//! shared by every layer, the [`crate::plan::PlanCache`]'s online top-2
//! races, and the serve-time background re-tune thread: a winner recorded
//! by any of them is immediately visible to every subsequent plan.
//!
//! Blocking geometry is cache-driven: unless the caller pinned
//! [`KernelParams::block_size`] or [`KernelParams::geometry`] explicitly,
//! the planner consults [`BlockingPolicy::for_caps`] — the scalar K-block
//! and the outer-tile panel/K-block geometry are derived from the caps'
//! probed L1d/L2 sizes, falling back to the paper's constants on hosts
//! whose caches cannot be probed. A tuned entry that recorded a winning
//! geometry ([`TuneEntry::geometry`]) overrides the policy for its class.

use crate::autotune::{ShapeClass, TuneEntry, TuningTable};
use crate::formats::TileGeometry;
use crate::kernels::{self, GemmScratch, KernelId, KernelParams, PreparedGemm};
use crate::perf::cpu::CpuCaps;
use crate::perf::topology::CpuTopology;
use crate::perf::BlockingPolicy;
use crate::plan::gemm_plan::{Epilogue, GemmPlan};
use crate::plan::partition::RowPartition;
use crate::ternary::TernaryMatrix;
use crate::util::affinity::PlacementPolicy;
use crate::util::threadpool::ThreadPool;
use crate::{Error, Result};
use std::sync::{Arc, Mutex, RwLock};

/// Execution hints for [`Planner::plan`] — everything that is about *how*
/// to run rather than *what* to compute.
#[derive(Debug, Clone)]
pub struct PlanHints {
    /// Explicit registry kernel override (benches and ablations keep full
    /// control); `None` = let the planner choose. Name-keyed callers
    /// resolve through [`KernelId::parse`] / `str::parse` first.
    pub kernel: Option<KernelId>,
    /// Worker threads for row-partitioned execution (1 = sequential).
    pub threads: usize,
    /// Minimum rows per parallel chunk.
    pub min_rows_per_chunk: usize,
    /// Expected steady-state batch size; when > 0 the plan pre-sizes the
    /// padded-X scratch so even the first serving call allocates nothing.
    pub expected_batch: usize,
}

impl Default for PlanHints {
    fn default() -> Self {
        PlanHints {
            kernel: None,
            threads: 1,
            min_rows_per_chunk: 2,
            expected_batch: 0,
        }
    }
}

impl PlanHints {
    /// Hints that pin a specific registry kernel (the bench-harness form).
    pub fn with_kernel(kernel: KernelId) -> PlanHints {
        PlanHints {
            kernel: Some(kernel),
            ..Default::default()
        }
    }
}

/// Minimum M-bucket for the outer-product family to enter the heuristics:
/// the T×T accumulator tile needs batch rows to amortize the per-row-tile
/// staging (and single-row batches never fill a tile).
pub const OUTER_MIN_M: usize = 16;

/// Minimum K for the outer-product family to enter the heuristics: short
/// panels leave the register-resident tile nothing to amortize.
pub const OUTER_MIN_K: usize = 1024;

/// Paper-derived kernel choice for an untuned (K, sparsity) class.
///
/// - At the sparsest paper level (≈6.25% nonzeros) the per-column index
///   streams are short and the interleave/blocking machinery has nothing
///   to amortize; the scalar GEMV specialist wins (Fig 9's low-s end).
/// - When a fused PReLU is wanted at high density, the fusing SIMD
///   kernel's fused epilogue pays for its padding overhead (Fig 11).
/// - Everywhere else the paper's best scalar kernel — blocked (`min(K,
///   4096)`) + interleaved — is the winner (Figs 6–9).
///
/// All three candidates are capability queries over the registry's
/// descriptor table, not name literals.
pub fn heuristic_kernel(_k: usize, sparsity: f32, wants_fused_prelu: bool) -> KernelId {
    if sparsity <= 0.07 {
        kernels::gemv_specialist()
    } else if wants_fused_prelu && sparsity >= 0.45 {
        kernels::fused_simd()
    } else {
        kernels::best_scalar()
    }
}

/// The two strongest candidates for an untuned (K, sparsity, M-bucket)
/// class, best first: the paper-heuristic pick plus its closest rival for
/// that batch regime. The [`crate::plan::PlanCache`] races exactly these
/// two on the first real batch of an untuned class and locks the measured
/// winner into the shared [`TuningTable`] under the M-aware class.
pub fn heuristic_top2(
    k: usize,
    sparsity: f32,
    m: usize,
    wants_fused_prelu: bool,
) -> [KernelId; 2] {
    let primary = heuristic_kernel(k, sparsity, wants_fused_prelu);
    let secondary = if primary == kernels::gemv_specialist() {
        // Fig 9: as density grows past the sparsest level, the blocked
        // interleaved kernel overtakes plain unrolling.
        kernels::best_scalar()
    } else if primary == kernels::fused_simd() {
        // Fig 11: the SIMD path and the best scalar path trade the lead
        // depending on padding overhead for the host's actual shapes.
        kernels::best_scalar()
    } else if m <= 1 {
        // Single-row batches leave the SIMD path's padded-X copy nothing
        // to amortize; the latency-shape rival is the scalar GEMV
        // specialist (Fig 2's GEMV end).
        kernels::gemv_specialist()
    } else {
        kernels::fused_simd()
    };
    [primary, secondary]
}

/// Capability-aware kernel choice for an untuned (K, sparsity, M) class.
///
/// On hosts whose [`CpuCaps`] carry the matrix-unit hint, large-batch
/// large-K classes above the sparsest level go to the outer-product tile
/// family ([`kernels::matrix_tile`]) — the regime where tile-resident
/// accumulation changes the operational-intensity picture. Everywhere else
/// this is exactly [`heuristic_kernel`]. The outer family never fuses
/// PReLU; the epilogue applies it as a separate pass.
pub fn heuristic_kernel_caps(
    caps: &CpuCaps,
    k: usize,
    sparsity: f32,
    m: usize,
    wants_fused_prelu: bool,
) -> KernelId {
    if caps.matrix_unit_hint && m >= OUTER_MIN_M && k >= OUTER_MIN_K && sparsity > 0.07 {
        if let Some(id) = kernels::matrix_tile(caps) {
            return id;
        }
    }
    heuristic_kernel(k, sparsity, wants_fused_prelu)
}

/// Capability-aware top-2: [`heuristic_kernel_caps`]'s pick plus its
/// closest rival under `caps`. When the outer-product family leads, the
/// paper's best scalar kernel is the rival; when a big-batch big-K class
/// leads with the paper pick, the best *selectable* tile kernel rides
/// along as rival — which is how hosts without the matrix-unit hint (and
/// CI's scalar emulation) still discover the family through the online
/// race. Otherwise this is exactly [`heuristic_top2`].
pub fn heuristic_top2_caps(
    caps: &CpuCaps,
    k: usize,
    sparsity: f32,
    m: usize,
    wants_fused_prelu: bool,
) -> [KernelId; 2] {
    let primary = heuristic_kernel_caps(caps, k, sparsity, m, wants_fused_prelu);
    if let Some(tile) = kernels::matrix_tile(caps) {
        if primary == tile {
            return [primary, kernels::best_scalar()];
        }
        if m >= OUTER_MIN_M && k >= OUTER_MIN_K && sparsity > 0.07 {
            return [primary, tile];
        }
    }
    heuristic_top2(k, sparsity, m, wants_fused_prelu)
}

/// Kernel selection + plan construction. Cheap to create; share one
/// `Arc<Planner>` per process so every layer's plan draws from the same
/// tuning table and thread pool, and online/background tuning results
/// propagate to all of them. The fleet registry
/// ([`crate::coordinator::ModelRegistry`]) makes this ownership explicit:
/// it holds the one planner, and every model it loads gets a per-model
/// plan cache layered on it — so tuning knowledge crosses model
/// boundaries while plan/arena memory stays per-model.
pub struct Planner {
    table: RwLock<TuningTable>,
    /// Capability set every emitted kernel must satisfy (host by default).
    caps: CpuCaps,
    /// Shared worker pool, created lazily on the first parallel plan and
    /// sized to the host's parallelism (or the placement's core budget).
    /// Plans cap their own fan-out via `PlanHints::threads`.
    pool: Mutex<Option<Arc<ThreadPool>>>,
    /// Worker placement the lazily-created pool spawns under, over
    /// `topology` (host by default). Set before the first parallel plan
    /// ([`Planner::set_placement`]); changing it later does not re-pin
    /// an already-created pool.
    placement: Mutex<PlacementPolicy>,
    topology: CpuTopology,
}

impl Default for Planner {
    fn default() -> Self {
        Planner::new()
    }
}

impl Planner {
    /// Planner with an empty tuning table (pure paper heuristics).
    pub fn new() -> Planner {
        Planner::with_table(TuningTable::new())
    }

    /// Planner backed by a measured tuning table.
    pub fn with_table(table: TuningTable) -> Planner {
        Planner {
            table: RwLock::new(table),
            caps: CpuCaps::host(),
            pool: Mutex::new(None),
            placement: Mutex::new(PlacementPolicy::None),
            topology: CpuTopology::host().clone(),
        }
    }

    /// Same planner, selecting against a synthetic capability set instead
    /// of the probed host (tests, cross-host what-if planning).
    pub fn with_caps(mut self, caps: CpuCaps) -> Planner {
        self.caps = caps;
        self
    }

    /// Same planner, placing its shared pool over a synthetic topology
    /// instead of the probed host (host-independent placement tests).
    pub fn with_topology(mut self, topology: CpuTopology) -> Planner {
        self.topology = topology;
        self
    }

    /// Set the placement policy the lazily-created shared pool will spawn
    /// its workers under. Must be called before the first parallel plan
    /// to take effect — an already-created pool keeps its placement (the
    /// coordinator sets this once at startup, from `--placement` /
    /// `--no-pin`). Returns whether the policy will apply to a future
    /// pool (`false` = the pool already exists).
    pub fn set_placement(&self, policy: PlacementPolicy) -> bool {
        *self.placement.lock().unwrap_or_else(|e| e.into_inner()) = policy;
        self.pool.lock().unwrap_or_else(|e| e.into_inner()).is_none()
    }

    /// The placement policy the shared pool spawns (or spawned) under.
    pub fn placement(&self) -> PlacementPolicy {
        *self.placement.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// The topology the shared pool is placed over.
    pub fn topology(&self) -> &CpuTopology {
        &self.topology
    }

    /// The capability set this planner selects against.
    pub fn caps(&self) -> CpuCaps {
        self.caps
    }

    /// Whether a tuned entry's kernel is selectable under this planner's
    /// capability set (a table recorded on a stronger host may carry
    /// winners this host cannot run).
    fn admissible(&self, entry: &TuneEntry) -> bool {
        self.caps.satisfies(entry.kernel.descriptor().requires)
    }

    /// Planner from a persisted tuning table (`stgemm autotune --save`).
    pub fn from_table_file(path: &str) -> Result<Planner> {
        Ok(Planner::with_table(TuningTable::load(path)?))
    }

    /// Clone of the current tuning table (persistence, background re-tune).
    pub fn table_snapshot(&self) -> TuningTable {
        self.table
            .read()
            .unwrap_or_else(|e| e.into_inner())
            .clone()
    }

    /// Number of tuned shape classes.
    pub fn tuned_classes(&self) -> usize {
        self.table.read().unwrap_or_else(|e| e.into_inner()).len()
    }

    /// The tuned entry for a (K, sparsity) class at batch size `m`: the
    /// M-aware entry for `m`'s bucket when one was recorded, else the
    /// M-agnostic fallback (PR-2-era tables resolve through this for
    /// every batch size). Entries naming a kernel this planner's caps
    /// cannot select are skipped — an inadmissible M-split falls through
    /// to an admissible M-agnostic entry.
    pub fn lookup_entry(&self, k: usize, sparsity: f32, m: usize) -> Option<TuneEntry> {
        let table = self.table.read().unwrap_or_else(|e| e.into_inner());
        table
            .lookup_m(k, sparsity, m)
            .filter(|e| self.admissible(e))
            .or_else(|| table.lookup(k, sparsity).filter(|e| self.admissible(e)))
            .cloned()
    }

    /// The tuned **M-agnostic** entry for a (K, sparsity) class, skipping
    /// any M-aware splits — for pinned plans whose batch size is unknown:
    /// a GEMV-specialized `_m1` entry must not decide a plan that may
    /// serve any batch size. Capability-inadmissible entries are skipped.
    pub fn lookup_entry_agnostic(&self, k: usize, sparsity: f32) -> Option<TuneEntry> {
        self.table
            .read()
            .unwrap_or_else(|e| e.into_inner())
            .lookup(k, sparsity)
            .filter(|e| self.admissible(e))
            .cloned()
    }

    /// Record a measured winner for a shape class (online top-2 fallback,
    /// `autotune sweep`). Last write wins. The entry's kernel is a typed
    /// [`KernelId`], so — unlike the PR-2 string era — a poisoned entry
    /// naming an unregistered kernel is unrepresentable.
    pub fn record(&self, class: ShapeClass, entry: TuneEntry) {
        self.table
            .write()
            .unwrap_or_else(|e| e.into_inner())
            .insert(class, entry);
    }

    /// Replace the tuning table wholesale (serve-time background re-tune).
    /// Existing plans keep running with their already-chosen kernels; new
    /// plans (and an invalidated [`crate::plan::PlanCache`]) pick up the
    /// fresh entries.
    pub fn install_table(&self, table: TuningTable) {
        *self.table.write().unwrap_or_else(|e| e.into_inner()) = table;
    }

    /// The kernel this planner would pick for a (K, sparsity) class at
    /// batch size `m`: tuned winner if the table has one (M-aware entry
    /// first, then the M-agnostic fallback), paper heuristic otherwise.
    pub fn select_kernel(
        &self,
        k: usize,
        sparsity: f32,
        m: usize,
        wants_fused_prelu: bool,
    ) -> KernelId {
        self.select_kernel_geometry(k, sparsity, m, wants_fused_prelu).0
    }

    /// The blocking policy this planner derives from its capability set:
    /// L1d-sized scalar K-block and outer-tile geometry, or the paper's
    /// fixed fallbacks when the caps carry no cache sizes.
    pub fn blocking_policy(&self) -> BlockingPolicy {
        BlockingPolicy::for_caps(&self.caps)
    }

    /// Kernel **and** tile geometry for a (K, sparsity) class at batch
    /// size `m`. A tuned entry decides both: its kernel plus its recorded
    /// geometry (`None` = the entry won at — or was recorded before — the
    /// default geometry, and stays there; the policy must not override a
    /// measured winner). An untuned class takes the heuristic kernel with
    /// the policy geometry when that kernel carries the geometry axis,
    /// `None` otherwise.
    pub fn select_kernel_geometry(
        &self,
        k: usize,
        sparsity: f32,
        m: usize,
        wants_fused_prelu: bool,
    ) -> (KernelId, Option<TileGeometry>) {
        match self.lookup_entry(k, sparsity, m) {
            Some(entry) => (entry.kernel, entry.geometry),
            None => {
                let kernel =
                    heuristic_kernel_caps(&self.caps, k, sparsity, m, wants_fused_prelu);
                (kernel, self.policy_geometry(kernel))
            }
        }
    }

    /// The policy geometry for `kernel`, or `None` when its descriptor
    /// does not carry the geometry axis (non-tile kernels ignore the
    /// field, so emitting one would only muddy plan introspection).
    fn policy_geometry(&self, kernel: KernelId) -> Option<TileGeometry> {
        if kernel.descriptor().geometry {
            Some(self.blocking_policy().geometry)
        } else {
            None
        }
    }

    pub(crate) fn shared_pool(&self) -> Arc<ThreadPool> {
        let policy = self.placement();
        let mut guard = self.pool.lock().unwrap_or_else(|e| e.into_inner());
        guard
            .get_or_insert_with(|| {
                // Under a real placement the worker budget is the *core*
                // budget the policy targets — the performance-core count
                // (every core on homogeneous parts) — so no worker needs
                // to share (or spill onto) an efficiency core. Unplaced
                // pools keep the host-parallelism sizing.
                let workers = match policy {
                    PlacementPolicy::None => std::thread::available_parallelism()
                        .map(|n| n.get())
                        .unwrap_or(4),
                    _ => self.topology.perf_cores().len(),
                };
                Arc::new(ThreadPool::with_placement(
                    workers.max(2),
                    policy,
                    &self.topology,
                ))
            })
            .clone()
    }

    /// Placement outcomes of the shared pool's workers (empty while the
    /// pool hasn't been lazily created; under [`PlacementPolicy::None`]
    /// every row reports `unrestricted`).
    pub fn pool_placements(&self) -> Vec<crate::util::threadpool::WorkerPlacement> {
        self.pool
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .as_ref()
            .map(|p| p.placements())
            .unwrap_or_default()
    }

    /// Size of the shared worker pool, or `None` while it hasn't been
    /// lazily created yet (fleet /status gauge: all models in a registry
    /// draw parallel execution from this one pool).
    pub fn shared_pool_threads(&self) -> Option<usize> {
        self.pool
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .as_ref()
            .map(|p| p.size())
    }

    /// Build a [`GemmPlan`] for weights `w`.
    ///
    /// Kernel choice: `hints.kernel` if given, else the tuning table, else
    /// the paper heuristics. PReLU fuses into the kernel when the epilogue
    /// allows it ([`Epilogue::fusible_prelu`]) and the chosen kernel
    /// supports fusion; the epilogue applies it otherwise.
    ///
    /// # Errors
    /// [`Error::Shape`] on a bias/N mismatch, [`Error::BadKernelParams`]
    /// on invalid params, [`Error::UnsupportedKernel`] when `hints.kernel`
    /// names a kernel whose capability requirements this planner's
    /// [`CpuCaps`] do not satisfy.
    pub fn plan(
        &self,
        w: &TernaryMatrix,
        params: KernelParams,
        epilogue: Epilogue,
        hints: &PlanHints,
    ) -> Result<GemmPlan> {
        if epilogue.bias.len() != w.n() {
            return Err(Error::Shape(format!(
                "bias length {} != N {}",
                epilogue.bias.len(),
                w.n()
            )));
        }
        let sparsity = w.density() as f32;
        let wants_fused = epilogue.fusible_prelu().is_some();
        // `selected_geometry` is the planner's pick for the geometry axis:
        // a tuned entry decides it outright (its recorded geometry, or
        // `None` = stay at the default — a measured winner is never
        // policy-overridden); hinted kernels and untuned classes take the
        // cache-driven policy geometry when the kernel carries the axis.
        let (kernel, selected_geometry) = match hints.kernel {
            Some(k) => {
                let d = k.descriptor();
                if !self.caps.satisfies(d.requires) {
                    return Err(Error::UnsupportedKernel(format!(
                        "kernel '{}' requires {:?}, which the planner's CPU \
                         capabilities do not provide",
                        d.name, d.requires
                    )));
                }
                (k, self.policy_geometry(k))
            }
            // A declared expected batch picks that regime's M-aware entry;
            // an unset one (0) resolves through the M-agnostic entry only —
            // the plan may serve any batch size, so a single-bucket split
            // (e.g. a GEMV-tuned `_m1` winner) must not decide it.
            None => {
                let entry = match hints.expected_batch {
                    0 => self.lookup_entry_agnostic(w.k(), sparsity),
                    m => self.lookup_entry(w.k(), sparsity, m),
                };
                match entry {
                    Some(e) => (e.kernel, e.geometry),
                    None => {
                        let k = heuristic_kernel_caps(
                            &self.caps,
                            w.k(),
                            sparsity,
                            hints.expected_batch,
                            wants_fused,
                        );
                        (k, self.policy_geometry(k))
                    }
                }
            }
        };
        // Block size is cache-driven unless pinned: the paper constant
        // doubles as the "caller didn't choose" sentinel (it is the
        // `Default`), so only a non-default value is honored verbatim.
        let policy = self.blocking_policy();
        let block_size = if params.block_size == crate::PAPER_BLOCK_SIZE {
            policy.scalar_block
        } else {
            params.block_size
        };
        let geometry = params.geometry.or(selected_geometry);
        let kparams = KernelParams {
            prelu_alpha: epilogue.fusible_prelu(),
            block_size,
            geometry,
            ..params
        };
        let gemm: Arc<dyn PreparedGemm> = kernel.prepare(w, kparams)?.into();
        let threads = hints.threads.max(1);
        let partition = RowPartition::new(threads, hints.min_rows_per_chunk);
        let pool = if threads > 1 {
            Some(self.shared_pool())
        } else {
            None
        };
        let mut scratches: Vec<GemmScratch> =
            (0..threads).map(|_| GemmScratch::new()).collect();
        if hints.expected_batch > 0 && gemm.uses_padded_scratch() {
            for (i, &(lo, hi)) in partition.ranges(hints.expected_batch).iter().enumerate() {
                scratches[i].reserve_padded(hi - lo, w.k());
            }
        }
        if gemm.uses_tile_scratch() {
            // Tile staging is K-sized regardless of batch, so pre-size it
            // unconditionally: the first call allocates nothing.
            for s in &mut scratches {
                s.reserve_tile(w.k());
            }
        }
        Ok(GemmPlan {
            gemm,
            epilogue,
            partition,
            pool,
            scratch: Mutex::new(scratches),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autotune::{ShapeClass, TuneEntry};
    use crate::kernels::dense_oracle;
    use crate::tensor::Matrix;

    #[test]
    fn heuristics_follow_the_paper() {
        assert_eq!(
            heuristic_kernel(4096, 0.0625, false),
            KernelId::UnrolledTcscK4M4
        );
        assert_eq!(
            heuristic_kernel(4096, 0.25, false),
            KernelId::InterleavedBlockedTcsc
        );
        assert_eq!(heuristic_kernel(4096, 0.5, true), KernelId::SimdVertical);
        assert_eq!(
            heuristic_kernel(4096, 0.5, false),
            KernelId::InterleavedBlockedTcsc
        );
    }

    #[test]
    fn top2_leads_with_heuristic_and_differs() {
        for &m in &[1usize, 8, 64] {
            for &(s, fused) in &[(0.0625f32, false), (0.25, false), (0.5, true), (0.5, false)] {
                let [a, b] = heuristic_top2(4096, s, m, fused);
                assert_eq!(a, heuristic_kernel(4096, s, fused));
                assert_ne!(a, b, "candidates must differ (s={s}, m={m}, fused={fused})");
                assert!(crate::kernels::kernel_ids().contains(&b), "unknown rival {b}");
            }
        }
        // The M=1 regime swaps the SIMD rival for the GEMV specialist.
        assert_eq!(
            heuristic_top2(4096, 0.25, 1, false)[1],
            KernelId::UnrolledTcscK4M4
        );
        assert_eq!(heuristic_top2(4096, 0.25, 8, false)[1], KernelId::SimdVertical);
    }

    #[test]
    fn capability_gated_heuristics_route_to_tile_family() {
        let apple = CpuCaps::apple_like();
        let scalar = CpuCaps::scalar_only();
        // Matrix-unit hint + big batch + big K above the sparsest level →
        // the outer-product pick leads, racing the paper's best scalar.
        assert_eq!(
            heuristic_kernel_caps(&apple, 4096, 0.25, 64, false),
            KernelId::OuterProductTileSimd
        );
        assert_eq!(
            heuristic_top2_caps(&apple, 4096, 0.25, 64, false),
            [KernelId::OuterProductTileSimd, KernelId::InterleavedBlockedTcsc]
        );
        // Below any threshold the paper heuristics stand unchanged.
        assert_eq!(
            heuristic_kernel_caps(&apple, 4096, 0.25, 1, false),
            heuristic_kernel(4096, 0.25, false)
        );
        assert_eq!(
            heuristic_kernel_caps(&apple, 256, 0.25, 64, false),
            heuristic_kernel(256, 0.25, false)
        );
        assert_eq!(
            heuristic_top2_caps(&apple, 4096, 0.0625, 64, false),
            heuristic_top2(4096, 0.0625, 64, false)
        );
        // Without the hint the paper pick leads, but the best *selectable*
        // tile kernel rides as rival — the race can still discover the
        // family, via the scalar emulation on the weakest host.
        assert_eq!(
            heuristic_kernel_caps(&scalar, 4096, 0.25, 64, false),
            KernelId::InterleavedBlockedTcsc
        );
        assert_eq!(
            heuristic_top2_caps(&scalar, 4096, 0.25, 64, false),
            [KernelId::InterleavedBlockedTcsc, KernelId::OuterProductTile]
        );
        // Small batches keep the paper's top-2 as-is.
        assert_eq!(
            heuristic_top2_caps(&scalar, 4096, 0.25, 8, false),
            heuristic_top2(4096, 0.25, 8, false)
        );
    }

    #[test]
    fn capability_gated_hint_is_rejected() {
        let planner = Planner::new().with_caps(CpuCaps::scalar_only());
        let w = TernaryMatrix::random(64, 8, 0.5, 9);
        let epi = || Epilogue::with_bias(vec![0.0; 8]);
        assert!(matches!(
            planner.plan(
                &w,
                KernelParams::default(),
                epi(),
                &PlanHints::with_kernel(KernelId::OuterProductTileSimd),
            ),
            Err(Error::UnsupportedKernel(_))
        ));
        // The portable tile emulation is selectable anywhere.
        let plan = planner
            .plan(
                &w,
                KernelParams::default(),
                epi(),
                &PlanHints::with_kernel(KernelId::OuterProductTile),
            )
            .unwrap();
        assert_eq!(plan.kernel_name(), "outer_product_tile");
    }

    #[test]
    fn capability_gated_tuned_entries_are_filtered() {
        // A table recorded on a stronger host may carry winners this host
        // cannot run; those entries must not decide a plan.
        let mut table = TuningTable::new();
        table.insert(
            ShapeClass::of(128, 0.25),
            TuneEntry::new(KernelId::OuterProductTileSimd, 9.0),
        );
        let planner = Planner::with_table(table).with_caps(CpuCaps::scalar_only());
        assert!(planner.lookup_entry(128, 0.25, 8).is_none());
        assert!(planner.lookup_entry_agnostic(128, 0.25).is_none());
        assert_eq!(
            planner.select_kernel(128, 0.25, 8, false),
            KernelId::InterleavedBlockedTcsc
        );
        // An inadmissible M-split falls through to an admissible
        // M-agnostic entry.
        let mut table = TuningTable::new();
        table.insert(
            ShapeClass::of(128, 0.25),
            TuneEntry::new(KernelId::BaseTcsc, 1.0),
        );
        table.insert(
            ShapeClass::of_m(128, 0.25, 8),
            TuneEntry::new(KernelId::OuterProductTileSimd, 9.0),
        );
        let planner = Planner::with_table(table).with_caps(CpuCaps::scalar_only());
        assert_eq!(
            planner.lookup_entry(128, 0.25, 8).unwrap().kernel,
            KernelId::BaseTcsc
        );
    }

    #[test]
    fn outer_tile_plan_runs_end_to_end() {
        let planner = Planner::new().with_caps(CpuCaps::apple_like());
        let w = TernaryMatrix::random(64, 12, 0.25, 11);
        let bias = vec![0.0f32; 12];
        let hints = PlanHints {
            kernel: Some(KernelId::OuterProductTileSimd),
            expected_batch: 8,
            ..Default::default()
        };
        let plan = planner
            .plan(
                &w,
                KernelParams::default(),
                Epilogue::with_bias(bias.clone()),
                &hints,
            )
            .unwrap();
        assert_eq!(plan.kernel_name(), "outer_product_tile_simd");
        let x = Matrix::random(8, 64, 12);
        let y = plan.forward(&x).unwrap();
        assert!(y.allclose(&dense_oracle(&x, &w, &bias), 1e-4));
    }

    #[test]
    fn tuning_table_wins_over_heuristics() {
        let mut table = TuningTable::new();
        table.insert(
            ShapeClass::of(128, 0.25),
            TuneEntry::new(KernelId::UnrolledTcsc12, 9.9),
        );
        let planner = Planner::with_table(table);
        let w = TernaryMatrix::random(128, 16, 0.25, 1);
        let plan = planner
            .plan(
                &w,
                KernelParams::default(),
                Epilogue::with_bias(vec![0.0; 16]),
                &PlanHints::default(),
            )
            .unwrap();
        assert_eq!(plan.kernel_name(), "unrolled_tcsc_12");
        // Untuned class falls back to the heuristic pick.
        let w2 = TernaryMatrix::random(4096, 16, 0.25, 2);
        let plan2 = planner
            .plan(
                &w2,
                KernelParams::default(),
                Epilogue::with_bias(vec![0.0; 16]),
                &PlanHints::default(),
            )
            .unwrap();
        assert_eq!(plan2.kernel_name(), "interleaved_blocked_tcsc");
    }

    #[test]
    fn recorded_entries_are_shared_and_replaceable() {
        let planner = Planner::new();
        assert_eq!(planner.tuned_classes(), 0);
        assert!(planner.lookup_entry(512, 0.25, 8).is_none());
        planner.record(
            ShapeClass::of(512, 0.25),
            TuneEntry::new(KernelId::BaseTcsc, 1.0),
        );
        assert_eq!(planner.tuned_classes(), 1);
        assert_eq!(planner.select_kernel(512, 0.25, 8, false), KernelId::BaseTcsc);
        // An M-aware entry overrides the fallback for its bucket only.
        planner.record(
            ShapeClass::of_m(512, 0.25, 1),
            TuneEntry::new(KernelId::UnrolledTcscK4M4, 2.0),
        );
        assert_eq!(
            planner.select_kernel(512, 0.25, 1, false),
            KernelId::UnrolledTcscK4M4
        );
        assert_eq!(planner.select_kernel(512, 0.25, 8, false), KernelId::BaseTcsc);
        // install_table replaces everything (the background re-tune path).
        planner.install_table(TuningTable::new());
        assert_eq!(planner.tuned_classes(), 0);
        assert_eq!(
            planner.select_kernel(512, 0.25, 8, false),
            KernelId::InterleavedBlockedTcsc
        );
        // Snapshot is a detached copy.
        let mut snap = planner.table_snapshot();
        snap.insert(
            ShapeClass::of(64, 0.5),
            TuneEntry::new(KernelId::BaseTcsc, 1.0),
        );
        assert_eq!(planner.tuned_classes(), 0);
    }

    #[test]
    fn pinned_plan_without_expected_batch_skips_m_aware_splits() {
        let mut table = TuningTable::new();
        table.insert(
            ShapeClass::of(128, 0.25),
            TuneEntry::new(KernelId::InterleavedBlockedTcsc, 2.0),
        );
        table.insert(
            ShapeClass::of_m(128, 0.25, 1),
            TuneEntry::new(KernelId::UnrolledTcscK4M4, 3.0),
        );
        let planner = Planner::with_table(table);
        let w = TernaryMatrix::random(128, 8, 0.25, 13);
        let epi = || Epilogue::with_bias(vec![0.0; 8]);
        // Batch size unknown → the M-agnostic mean winner, not the GEMV
        // split (the plan may serve any batch size).
        let plan = planner
            .plan(&w, KernelParams::default(), epi(), &PlanHints::default())
            .unwrap();
        assert_eq!(plan.kernel_name(), "interleaved_blocked_tcsc");
        // A declared single-row batch opts into the M=1 regime.
        let hints = PlanHints {
            expected_batch: 1,
            ..Default::default()
        };
        let plan = planner
            .plan(&w, KernelParams::default(), epi(), &hints)
            .unwrap();
        assert_eq!(plan.kernel_name(), "unrolled_tcsc_k4_m4");
        // A declared large batch resolves through the fallback.
        let hints = PlanHints {
            expected_batch: 64,
            ..Default::default()
        };
        let plan = planner
            .plan(&w, KernelParams::default(), epi(), &hints)
            .unwrap();
        assert_eq!(plan.kernel_name(), "interleaved_blocked_tcsc");
    }

    #[test]
    fn explicit_hint_overrides_everything() {
        let planner = Planner::new();
        let w = TernaryMatrix::random(64, 8, 0.5, 3);
        let plan = planner
            .plan(
                &w,
                KernelParams::default(),
                Epilogue::with_bias(vec![0.0; 8]),
                &PlanHints::with_kernel(KernelId::BaseTcsc),
            )
            .unwrap();
        assert_eq!(plan.kernel_name(), "base_tcsc");
        // Unknown kernel names now fail at the parse boundary — a bogus
        // name cannot even be expressed as a typed hint.
        assert_eq!(
            "bogus".parse::<KernelId>().err(),
            Some(Error::UnknownKernel("bogus".into()))
        );
    }

    #[test]
    fn bias_length_is_validated() {
        let planner = Planner::new();
        let w = TernaryMatrix::random(16, 8, 0.5, 4);
        assert!(matches!(
            planner.plan(
                &w,
                KernelParams::default(),
                Epilogue::with_bias(vec![0.0; 7]),
                &PlanHints::default(),
            ),
            Err(Error::Shape(_))
        ));
    }

    #[test]
    fn planned_run_matches_oracle_with_full_epilogue() {
        let planner = Planner::new();
        let w = TernaryMatrix::random(48, 12, 0.25, 5);
        let x = Matrix::random(5, 48, 6);
        let bias: Vec<f32> = (0..12).map(|i| 0.1 * i as f32 - 0.4).collect();
        let plan = planner
            .plan(
                &w,
                KernelParams::default(),
                Epilogue::new(bias.clone(), 0.5, Some(0.25)),
                &PlanHints::default(),
            )
            .unwrap();
        let mut want = dense_oracle(&x, &w, &bias);
        for v in want.as_mut_slice() {
            *v *= 0.5;
            if *v < 0.0 {
                *v *= 0.25;
            }
        }
        let y = plan.forward(&x).unwrap();
        assert!(y.allclose(&want, 1e-4));
    }

    #[test]
    fn expected_batch_presizes_simd_scratch() {
        let planner = Planner::new();
        let w = TernaryMatrix::random(32, 8, 0.5, 7);
        let hints = PlanHints {
            kernel: Some(KernelId::SimdVertical),
            expected_batch: 8,
            ..Default::default()
        };
        let plan = planner
            .plan(
                &w,
                KernelParams::default(),
                Epilogue::with_bias(vec![0.0; 8]),
                &hints,
            )
            .unwrap();
        let caps = plan.scratch_capacities();
        assert_eq!(caps, vec![8 * 33]);
        // First run at the expected batch must not grow the scratch.
        let x = Matrix::random(8, 32, 8);
        let mut y = Matrix::zeros(8, 8);
        plan.run(&x, &mut y).unwrap();
        assert_eq!(plan.scratch_capacities(), caps);
    }

    #[test]
    fn geometry_selection_is_cache_driven() {
        // An untuned class on a wide-L1d host picks the tile kernel with
        // the policy geometry; the same class on a cache-blind host keeps
        // the paper heuristics and no geometry.
        let apple = Planner::new().with_caps(CpuCaps::apple_like());
        let (k, g) = apple.select_kernel_geometry(4096, 0.25, 64, false);
        assert_eq!(k, KernelId::OuterProductTileSimd);
        assert_eq!(g, Some(crate::perf::tile_geometry(&CpuCaps::apple_like())));
        assert_eq!(g.unwrap(), apple.blocking_policy().geometry);
        let scalar = Planner::new().with_caps(CpuCaps::scalar_only());
        let (k, g) = scalar.select_kernel_geometry(4096, 0.25, 64, false);
        assert!(!k.descriptor().geometry);
        assert_eq!(g, None);
        // Non-geometry kernels never get a geometry, even on strong hosts.
        let (k, g) = apple.select_kernel_geometry(4096, 0.0625, 1, false);
        assert!(!k.descriptor().geometry);
        assert_eq!(g, None);
    }

    #[test]
    fn tuned_geometry_wins_and_absent_means_default() {
        let tuned = TileGeometry::new(4, 512);
        let mut table = TuningTable::new();
        let mut entry = TuneEntry::new(KernelId::OuterProductTileSimd, 9.0);
        entry.geometry = Some(tuned);
        table.insert(ShapeClass::of(4096, 0.25), entry);
        // A pre-geometry-era entry: kernel recorded, no geometry field.
        table.insert(
            ShapeClass::of(2048, 0.25),
            TuneEntry::new(KernelId::OuterProductTileSimd, 8.0),
        );
        let planner = Planner::with_table(table).with_caps(CpuCaps::apple_like());
        // The recorded geometry overrides the policy for its class…
        let (k, g) = planner.select_kernel_geometry(4096, 0.25, 64, false);
        assert_eq!((k, g), (KernelId::OuterProductTileSimd, Some(tuned)));
        assert_ne!(Some(planner.blocking_policy().geometry), g);
        // …while an entry without one stays at the default geometry: a
        // measured winner is never silently re-geometried by the policy.
        let (k, g) = planner.select_kernel_geometry(2048, 0.25, 64, false);
        assert_eq!((k, g), (KernelId::OuterProductTileSimd, None));
    }

    #[test]
    fn planned_geometry_produces_bitwise_identical_output() {
        // End-to-end: a plan whose geometry came from the policy (hinted
        // tile kernel on an apple-like host) matches the same plan at the
        // explicit default geometry bit for bit.
        let w = TernaryMatrix::random(2048, 20, 0.25, 21);
        let bias: Vec<f32> = (0..20).map(|i| 0.01 * i as f32).collect();
        let x = Matrix::random(8, 2048, 22);
        let hints = PlanHints {
            kernel: Some(KernelId::OuterProductTile),
            expected_batch: 8,
            ..Default::default()
        };
        let run = |planner: &Planner, params: KernelParams| {
            planner
                .plan(&w, params, Epilogue::with_bias(bias.clone()), &hints)
                .unwrap()
                .forward(&x)
                .unwrap()
        };
        let apple = Planner::new().with_caps(CpuCaps::apple_like());
        let y_policy = run(&apple, KernelParams::default());
        let y_default = run(
            &apple,
            KernelParams {
                geometry: Some(TileGeometry::DEFAULT),
                ..Default::default()
            },
        );
        assert_eq!(y_policy.as_slice(), y_default.as_slice());
        assert!(y_policy.allclose(&dense_oracle(&x, &w, &bias), 1e-4));
    }
}
