//! The GEMM planning layer: one place that owns kernel selection, format
//! preparation, the epilogue (scale + bias + PReLU), scratch reuse and
//! multi-core row partitioning.
//!
//! The paper's speedups come from picking the right format/kernel/unroll
//! for a given (K, sparsity) class. Before this module that choice was
//! scattered: string-keyed [`crate::kernels::prepare_kernel`] calls, an
//! autotune [`crate::autotune::TuningTable`] nothing consulted at
//! model-build time, and a bolt-on `ParallelGemm` wrapper the serving
//! engine never used. [`Planner::plan`] collapses all of it into a single
//! planned-execution object:
//!
//! ```text
//! Planner::plan(w, params, epilogue, hints)
//!     │  kernel choice: explicit hint ▸ TuningTable ▸ paper heuristics
//!     ▼
//! GemmPlan { prepared kernel + epilogue + partition + scratch }
//!     │  GemmPlan::run(x, &mut y)
//!     ▼
//! row-partitioned execution: workers write disjoint &mut Y row blocks
//! in place through the shared thread pool; the SIMD kernels' padded-X
//! copy lives in reused scratch (steady state allocates nothing)
//! ```
//!
//! Consumers: [`crate::model::TernaryLinear`] / [`crate::model::TernaryMlp`]
//! build layers through a `Planner` (kernel names are optional overrides),
//! [`crate::coordinator::engine::Engine`] serves batches through plans, and
//! the bench harness measures kernels through the same path it serves on.

pub mod gemm_plan;
pub mod partition;
pub mod planner;

pub use gemm_plan::{Epilogue, GemmPlan};
pub use partition::{execute_partitioned, RowPartition, ROW_TILE};
pub use planner::{heuristic_kernel, PlanHints, Planner};
