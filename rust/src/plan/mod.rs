//! The GEMM planning layer: one place that owns kernel selection, format
//! preparation, the epilogue (scale + bias + PReLU), scratch reuse and
//! multi-core row partitioning.
//!
//! The paper's speedups come from picking the right format/kernel/unroll
//! for a given (K, sparsity) class. Before this module that choice was
//! scattered: string-keyed [`crate::kernels::prepare_kernel`] calls, an
//! autotune [`crate::autotune::TuningTable`] nothing consulted at
//! model-build time, and a bolt-on `ParallelGemm` wrapper the serving
//! engine never used. [`Planner::plan`] collapses all of it into a single
//! planned-execution object:
//!
//! ```text
//! Planner::plan(w, params, epilogue, hints)
//!     │  kernel choice: explicit hint ▸ TuningTable ▸ paper heuristics
//!     ▼
//! GemmPlan { prepared kernel + epilogue + partition + scratch }
//!     │  GemmPlan::run(x, &mut y)
//!     ▼
//! row-partitioned execution: workers write disjoint &mut Y row blocks
//! in place through the shared thread pool; the SIMD kernels' padded-X
//! copy lives in reused scratch (steady state allocates nothing)
//! ```
//!
//! On the serving path, plans are not built per request: the
//! [`PlanCache`] keys them by (layer, M-bucket, threads) and builds each
//! combination once, on first traffic —
//!
//! ```text
//! PlanCache::run(layer, x, &mut y)
//!     │  bucket = next_pow2(x.rows()), threads = live ceiling
//!     ├─ hit  → cached GemmPlan::run (no planning, no allocation)
//!     └─ miss → build once; for an untuned (K, sparsity, M-bucket)
//!               class, race the top-2 candidate kernels on the live
//!               batch and lock the winner into the shared TuningTable
//!               under the M-aware `k{K}_s{S}_m{M}` class — lookups fall
//!               back to the M-agnostic `k{K}_s{S}` entry, so PR-2-era
//!               tables keep resolving for every batch size
//! ```
//!
//! Multi-layer models additionally flow through the **wavefront pipeline**
//! ([`pipeline`]): [`PlanCache::run_pipelined`] compiles every layer into
//! an [`MlpPlan`] per (M-bucket, threads) — a band-dependency graph whose
//! `(layer, band)` tasks are pulled by persistent pool workers, with
//! intermediate activations in [`ActivationArena`] ping-pong buffers — so
//! layer `i+1`'s first bands overlap layer `i`'s tail and steady-state
//! serving performs zero activation allocation, while outputs stay bitwise
//! identical to the barrier path.
//!
//! Consumers: [`crate::model::TernaryLinear`] / [`crate::model::TernaryMlp`]
//! build layers through a shared `Arc<Planner>` + `PlanCache` (kernel names
//! are optional overrides), [`crate::coordinator::engine::Engine`] serves
//! batches through cached plans (and the load-aware router re-sizes the
//! cache's thread ceiling), and the bench harness measures kernels through
//! the same path it serves on.

pub mod cache;
pub mod gemm_plan;
pub mod partition;
pub mod pipeline;
pub mod planner;

pub use cache::{
    m_bucket, CacheSnapshot, LayerId, LayerSpec, PlanCache, PlanCacheConfig, MAX_M_BUCKET,
};
pub use gemm_plan::{Epilogue, GemmPlan};
pub use partition::{execute_partitioned, RowPartition, ROW_TILE};
pub use pipeline::{
    ActivationArena, ArenaStats, MlpPlan, OwnedArenaLease, PipelineMode, PipelineStats,
};
pub use planner::{
    heuristic_kernel, heuristic_kernel_caps, heuristic_top2, heuristic_top2_caps, PlanHints,
    Planner, OUTER_MIN_K, OUTER_MIN_M,
};
