//! The ternary MLP / FFN stack: the model object the serving engine runs.
//!
//! Config-built models execute through a shared [`PlanCache`]: each layer
//! registers its weights once and plans are built lazily per (M-bucket,
//! threads), so a mixed-batch-size request stream converges onto a small
//! set of reused plans and the load-aware coordinator can re-size the
//! thread fan-out at runtime ([`TernaryMlp::set_threads`]).
//!
//! Multi-layer forwards are **wavefront-pipelined by default**
//! ([`crate::plan::pipeline`]): row bands of layer `i+1` start as soon as
//! the same bands of layer `i` finish — no global barrier between layers —
//! with intermediate activations in pre-sized arena ping-pong buffers, so
//! steady-state serving performs zero activation allocation while outputs
//! stay bitwise identical to the barrier path. The barrier path remains as
//! the `pipeline: false` / `serve --no-pipeline` escape hatch (and as the
//! execution path of the online kernel race), and it too reads the first
//! layer's input borrowed instead of cloning it.

use crate::model::config::ModelConfig;
use crate::model::layer::TernaryLinear;
use crate::plan::{ActivationArena, PipelineStats, PlanCache, PlanCacheConfig, Planner};
use crate::tensor::Matrix;
use crate::ternary::TernaryMatrix;
use crate::util::rng::Rng;
use crate::{Error, Result};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// A stack of ternary linear layers with PReLU between them.
pub struct TernaryMlp {
    pub name: String,
    layers: Vec<TernaryLinear>,
    /// Present for config-built models; `None` for explicit-layer stacks
    /// ([`TernaryMlp::from_layers`]).
    cache: Option<Arc<PlanCache>>,
    /// Activation ping-pong buffers for the explicit-layer path (cached
    /// models use the [`PlanCache`]'s shared arena instead).
    arena: ActivationArena,
    /// Wavefront pipelining for cached multi-layer forwards (config
    /// `pipeline`, default on; `serve --no-pipeline`).
    pipeline: AtomicBool,
}

impl TernaryMlp {
    /// Build from a config with a throwaway [`Planner`] (no tuning table).
    /// Serving code should prefer [`TernaryMlp::planned`] with a shared
    /// planner so layers benefit from measured tuning entries.
    pub fn from_config(cfg: &ModelConfig) -> Result<TernaryMlp> {
        Self::planned(cfg, &Arc::new(Planner::new()))
    }

    /// Build from a config through `planner`: weights generated
    /// deterministically from the seed (layer i uses `seed + i`), bias from
    /// `seed + i + 7777`. Layers execute through a shared [`PlanCache`]:
    /// each layer's kernel is the config's explicit override when set,
    /// otherwise the planner's pick for that layer's (K, sparsity) class —
    /// refined by the cache's online top-2 race on first traffic in an
    /// untuned class. The config's `threads` seeds the cache's (runtime
    /// adjustable) worker ceiling, and `pipeline` selects wavefront vs
    /// barrier execution for multi-layer forwards.
    pub fn planned(cfg: &ModelConfig, planner: &Arc<Planner>) -> Result<TernaryMlp> {
        let nlayers = cfg.dims.len() - 1;
        let cache = Arc::new(PlanCache::new(
            Arc::clone(planner),
            PlanCacheConfig {
                threads: cfg.threads,
                ..Default::default()
            },
        ));
        // Barrier-only models skip warm-time pipeline compilation.
        cache.set_pipelining(cfg.pipeline);
        let mut layers = Vec::with_capacity(nlayers);
        for i in 0..nlayers {
            let (k, n) = (cfg.dims[i], cfg.dims[i + 1]);
            let w = TernaryMatrix::random(k, n, cfg.sparsity, cfg.seed + i as u64);
            let mut rng = Rng::new(cfg.seed + i as u64 + 7777);
            let bias: Vec<f32> = (0..n).map(|_| rng.f32_range(-0.5, 0.5)).collect();
            let alpha = if i + 1 < nlayers {
                Some(cfg.prelu_alpha)
            } else {
                None
            };
            layers.push(TernaryLinear::cached(
                &cache,
                w,
                bias,
                1.0,
                alpha,
                cfg.kernel,
            )?);
        }
        Ok(TernaryMlp {
            name: cfg.name.clone(),
            arena: ActivationArena::new(0), // cached path uses the cache's
            layers,
            cache: Some(cache),
            pipeline: AtomicBool::new(cfg.pipeline),
        })
    }

    /// Build from explicit layers (the artifact loader uses this).
    pub fn from_layers(name: String, layers: Vec<TernaryLinear>) -> Result<TernaryMlp> {
        if layers.is_empty() {
            return Err(Error::Config("model needs at least one layer".into()));
        }
        for pair in layers.windows(2) {
            if pair[0].n() != pair[1].k() {
                return Err(Error::Shape(format!(
                    "layer dim mismatch: {} out vs {} in",
                    pair[0].n(),
                    pair[1].k()
                )));
            }
        }
        let widest = layers[..layers.len() - 1]
            .iter()
            .map(TernaryLinear::n)
            .max()
            .unwrap_or(0);
        Ok(TernaryMlp {
            name,
            layers,
            cache: None,
            arena: ActivationArena::new(widest),
            pipeline: AtomicBool::new(false),
        })
    }

    pub fn d_in(&self) -> usize {
        self.layers[0].k()
    }

    pub fn d_out(&self) -> usize {
        self.layers.last().unwrap().n()
    }

    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    pub fn layers(&self) -> &[TernaryLinear] {
        &self.layers
    }

    /// The shared plan cache, when this model was built from a config.
    pub fn plan_cache(&self) -> Option<&Arc<PlanCache>> {
        self.cache.as_ref()
    }

    /// Re-size the worker-thread ceiling for every layer (no-op for
    /// explicit-layer stacks). Plans for the new count build lazily.
    pub fn set_threads(&self, threads: usize) {
        if let Some(cache) = &self.cache {
            cache.set_threads(threads);
        }
    }

    /// Whether cached multi-layer forwards run through the wavefront
    /// pipeline (explicit-layer stacks always use the barrier path).
    pub fn pipelined(&self) -> bool {
        self.cache.is_some() && self.pipeline.load(Ordering::Relaxed)
    }

    /// Toggle wavefront pipelining at runtime (`serve --no-pipeline`
    /// passes `false` through the config instead; this is the live knob).
    pub fn set_pipeline(&self, on: bool) {
        self.pipeline.store(on, Ordering::Relaxed);
        if let Some(cache) = &self.cache {
            cache.set_pipelining(on);
        }
    }

    /// Full forward pass for a batch (rows of `x`) into a fresh matrix.
    pub fn forward(&self, x: &Matrix) -> Result<Matrix> {
        let mut y = Matrix::zeros(x.rows(), self.d_out());
        self.forward_into(x, &mut y)?;
        Ok(y)
    }

    /// Forward into caller-provided storage (`y` must be `x.rows × d_out`).
    pub fn forward_into(&self, x: &Matrix, y: &mut Matrix) -> Result<()> {
        self.forward_into_stats(x, y).map(|_| ())
    }

    /// Like [`TernaryMlp::forward_into`], returning the scheduler stats
    /// when the wavefront pipeline served the batch (`None` = barrier
    /// path; the engine feeds these into the serving metrics).
    pub fn forward_into_stats(
        &self,
        x: &Matrix,
        y: &mut Matrix,
    ) -> Result<Option<PipelineStats>> {
        assert_eq!(x.cols(), self.d_in(), "input width mismatch");
        assert_eq!(y.rows(), x.rows(), "output rows mismatch");
        assert_eq!(y.cols(), self.d_out(), "output width mismatch");
        if let Some(cache) = &self.cache {
            if self.pipeline.load(Ordering::Relaxed) {
                return cache.run_pipelined(x, y);
            }
            cache.run_layers(x, y)?;
            return Ok(None);
        }
        // Explicit-layer stacks: borrowed first-layer input, arena
        // ping-pong thereafter (no per-layer allocation, no x.clone()).
        let widths: Vec<usize> = self.layers.iter().map(TernaryLinear::n).collect();
        crate::plan::pipeline::pingpong_forward(&self.arena, &widths, x, y, |i, xin, yout| {
            self.layers[i].forward(xin, yout)
        })?;
        Ok(None)
    }

    /// Cost-model flops for a batch of `m` rows.
    pub fn flops(&self, m: usize) -> f64 {
        self.layers.iter().map(|l| l.flops(m)).sum()
    }

    /// Total format bytes across layers (memory accounting).
    pub fn format_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.format_bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{dense_oracle, prelu_inplace};

    fn cfg() -> ModelConfig {
        ModelConfig::from_json(
            r#"{"name":"t","dims":[32,64,16],"sparsity":0.25,"seed":11,
                "prelu_alpha":0.25,"kernel":"interleaved_blocked_tcsc"}"#,
        )
        .unwrap()
    }

    #[test]
    fn forward_matches_manual_composition() {
        let c = cfg();
        let mlp = TernaryMlp::from_config(&c).unwrap();
        assert!(mlp.pipelined(), "config default is wavefront");
        let x = Matrix::random(4, 32, 1);

        // Rebuild the same weights/biases manually and compose oracles.
        let w1 = TernaryMatrix::random(32, 64, 0.25, 11);
        let w2 = TernaryMatrix::random(64, 16, 0.25, 12);
        let mut rng1 = Rng::new(11 + 7777);
        let b1: Vec<f32> = (0..64).map(|_| rng1.f32_range(-0.5, 0.5)).collect();
        let mut rng2 = Rng::new(12 + 7777);
        let b2: Vec<f32> = (0..16).map(|_| rng2.f32_range(-0.5, 0.5)).collect();
        let mut h = dense_oracle(&x, &w1, &b1);
        prelu_inplace(&mut h, 0.25);
        let want = dense_oracle(&h, &w2, &b2);

        let got = mlp.forward(&x).unwrap();
        assert!(got.allclose(&want, 1e-3));
    }

    #[test]
    fn shapes_and_metadata() {
        let mlp = TernaryMlp::from_config(&cfg()).unwrap();
        assert_eq!(mlp.d_in(), 32);
        assert_eq!(mlp.d_out(), 16);
        assert_eq!(mlp.num_layers(), 2);
        assert!(mlp.flops(1) > 0.0);
        assert!(mlp.format_bytes() > 0);
        let y = mlp.forward(&Matrix::zeros(3, 32)).unwrap();
        assert_eq!((y.rows(), y.cols()), (3, 16));
        // Zero-row batches flow through every path.
        let y0 = mlp.forward(&Matrix::zeros(0, 32)).unwrap();
        assert_eq!((y0.rows(), y0.cols()), (0, 16));
    }

    #[test]
    fn kernel_choice_does_not_change_result() {
        let mut c = cfg();
        let x = Matrix::random(5, 32, 2);
        let reference = TernaryMlp::from_config(&c).unwrap().forward(&x).unwrap();
        for kernel in ["base_tcsc", "simd_vertical", "unrolled_tcsc_12", "dense_gemm"] {
            c.kernel = Some(kernel.parse().unwrap());
            let got = TernaryMlp::from_config(&c).unwrap().forward(&x).unwrap();
            assert!(got.allclose(&reference, 1e-3), "kernel {kernel}");
        }
        // Planner-selected (no explicit kernel) agrees too — even when the
        // cache's online top-2 race picks the winner.
        c.kernel = None;
        let got = TernaryMlp::from_config(&c).unwrap().forward(&x).unwrap();
        assert!(got.allclose(&reference, 1e-3), "auto kernel");
    }

    #[test]
    fn pipelined_and_barrier_paths_are_bitwise_identical() {
        let mut c = cfg();
        c.threads = 4;
        for &m in &[0usize, 1, 5, 13, 33] {
            let x = Matrix::random(m, 32, 40 + m as u64);
            let mlp = TernaryMlp::from_config(&c).unwrap();
            let wave = mlp.forward(&x).unwrap();
            mlp.set_pipeline(false);
            let barrier = mlp.forward(&x).unwrap();
            assert_eq!(wave, barrier, "m={m}");
            // A config with pipeline off builds the barrier model.
            c.pipeline = false;
            let off = TernaryMlp::from_config(&c).unwrap();
            assert!(!off.pipelined());
            assert_eq!(off.forward(&x).unwrap(), wave, "m={m} (config off)");
            c.pipeline = true;
        }
    }

    #[test]
    fn auto_config_uses_tuning_table() {
        use crate::autotune::{ShapeClass, TuneEntry};
        let mut c = cfg();
        c.kernel = None;
        // Tune both layer classes (K=32 and K=64 at 25%) to a fixed pick.
        let mut table = crate::autotune::TuningTable::new();
        for k in [32usize, 64] {
            table.insert(
                ShapeClass::of(k, 0.25),
                TuneEntry::new(crate::kernels::KernelId::UnrolledTcsc12, 1.0),
            );
        }
        let planner = Arc::new(Planner::with_table(table));
        let mlp = TernaryMlp::planned(&c, &planner).unwrap();
        for layer in mlp.layers() {
            assert_eq!(layer.kernel_name(), "unrolled_tcsc_12");
        }
        // And threading from the config still matches sequential output
        // (kernel pinned so the comparison is plan-for-plan bitwise).
        c.kernel = Some(crate::kernels::KernelId::InterleavedBlockedTcsc);
        c.threads = 4;
        let x = Matrix::random(9, 32, 5);
        let seq = TernaryMlp::from_config(&cfg()).unwrap().forward(&x).unwrap();
        let par = TernaryMlp::planned(&c, &Arc::new(Planner::new()))
            .unwrap()
            .forward(&x)
            .unwrap();
        assert_eq!(seq, par, "threaded forward must be bitwise sequential");
    }

    #[test]
    fn mixed_batch_sizes_reuse_cached_plans() {
        let mut c = cfg();
        c.kernel = None;
        let mlp = TernaryMlp::planned(&c, &Arc::new(Planner::new())).unwrap();
        let ms = [1usize, 7, 8, 3, 16, 8, 1];
        for &m in &ms {
            let y = mlp.forward(&Matrix::random(m, 32, 60 + m as u64)).unwrap();
            assert_eq!((y.rows(), y.cols()), (m, 16));
        }
        let cache = mlp.plan_cache().expect("config-built model has a cache");
        let warm = cache.snapshot();
        for &m in &ms {
            mlp.forward(&Matrix::random(m, 32, 80 + m as u64)).unwrap();
        }
        let hot = cache.snapshot();
        assert_eq!(hot.misses, warm.misses, "warm traffic must not re-plan");
        assert_eq!(hot.plans, warm.plans);
        // After two passes every bucket raced, settled and compiled its
        // pipeline; a third pass compiles nothing and allocates no
        // activation buffers — arena reuse only.
        let arena_warm = cache.arena_stats();
        for &m in &ms {
            mlp.forward(&Matrix::random(m, 32, 90 + m as u64)).unwrap();
        }
        let steady = cache.snapshot();
        assert_eq!(
            steady.pipeline_misses, hot.pipeline_misses,
            "steady traffic must not re-compile pipelines"
        );
        assert!(steady.pipeline_hits > hot.pipeline_hits);
        let arena_hot = cache.arena_stats();
        assert_eq!(arena_hot.allocations, arena_warm.allocations);
        assert!(arena_hot.reuses > arena_warm.reuses);
    }

    #[test]
    fn set_threads_keeps_results_bitwise_identical() {
        let mut c = cfg();
        c.kernel = None;
        let mlp = TernaryMlp::planned(&c, &Arc::new(Planner::new())).unwrap();
        let x = Matrix::random(13, 32, 5);
        let seq = mlp.forward(&x).unwrap();
        for t in [2usize, 4, 8] {
            mlp.set_threads(t);
            assert_eq!(mlp.forward(&x).unwrap(), seq, "threads={t}");
        }
    }

    #[test]
    fn from_layers_ping_pongs_without_cloning_input() {
        // Explicit-layer stacks run the barrier path over their own arena.
        let w1 = TernaryMatrix::random(24, 40, 0.25, 31);
        let w2 = TernaryMatrix::random(40, 8, 0.25, 32);
        let b1 = vec![0.1f32; 40];
        let b2 = vec![0.2f32; 8];
        let l1 =
            TernaryLinear::new("base_tcsc", &w1, b1.clone(), 1.0, Some(0.25)).unwrap();
        let l2 = TernaryLinear::new("base_tcsc", &w2, b2.clone(), 1.0, None).unwrap();
        let mlp = TernaryMlp::from_layers("explicit".into(), vec![l1, l2]).unwrap();
        assert!(!mlp.pipelined());
        let x = Matrix::random(6, 24, 33);
        let mut h = dense_oracle(&x, &w1, &b1);
        prelu_inplace(&mut h, 0.25);
        let want = dense_oracle(&h, &w2, &b2);
        let y1 = mlp.forward(&x).unwrap();
        assert!(y1.allclose(&want, 1e-3));
        // Steady state reuses the arena pair.
        mlp.forward(&x).unwrap();
        mlp.forward(&x).unwrap();
    }

    #[test]
    fn from_layers_validates_dims() {
        let w1 = TernaryMatrix::random(8, 16, 0.5, 1);
        let w2 = TernaryMatrix::random(4, 2, 0.5, 2); // mismatched
        let l1 = TernaryLinear::new("base_tcsc", &w1, vec![0.0; 16], 1.0, None).unwrap();
        let l2 = TernaryLinear::new("base_tcsc", &w2, vec![0.0; 2], 1.0, None).unwrap();
        assert!(TernaryMlp::from_layers("bad".into(), vec![l1, l2]).is_err());
        assert!(TernaryMlp::from_layers("empty".into(), vec![]).is_err());
    }
}
