//! Autoregressive decode sessions: the per-stream state of the decode
//! serving subsystem ([`crate::coordinator::DecodeScheduler`]).
//!
//! A [`DecodeSession`] is one autoregressive stream: a current state row
//! (the next step's model input) held in a **leased arena buffer pair**
//! that the session keeps across steps — after admission, a session's
//! steady state performs zero activation allocation (the lease returns
//! its pair to the arena on drop, so teardown recycles rather than
//! frees). Each decode step feeds the model's output row back as the next
//! input row and emits one synthetic token: the argmax index of the
//! output row (deterministic; first index wins ties). The feedback loop
//! is why decode requires `d_in == d_out` — the scheduler enforces that
//! at construction.
//!
//! Sessions never run the model themselves: the scheduler gathers every
//! active session's state row into one M-row batch, runs a single pinned
//! [`crate::plan::MlpPlan`], and scatters the output rows back through
//! [`DecodeSession::absorb_output`]. Because each output row of a
//! row-partitioned GEMM depends only on its own input row, a batched step
//! is bitwise-identical to stepping each session alone.

use crate::plan::pipeline::{ActivationArena, OwnedArenaLease};
use crate::{Error, Result};
use std::sync::Arc;

/// One autoregressive decode stream: identity, token budget, and the
/// state row leased from the decode arena across steps.
pub struct DecodeSession {
    id: u64,
    lease: OwnedArenaLease,
    width: usize,
    emitted: usize,
    max_tokens: usize,
}

impl DecodeSession {
    /// Open a session seeded with `prompt` (the d-dimensional embedding of
    /// the synthetic prompt), budgeted to emit at most `max_tokens`.
    /// Leases a bucket-1 buffer pair from `arena` and holds it until the
    /// session drops.
    ///
    /// # Errors
    /// [`Error::Shape`] when the prompt is empty or wider than the arena's
    /// buffers, [`Error::Config`] when `max_tokens` is zero.
    pub fn new(
        id: u64,
        arena: &Arc<ActivationArena>,
        prompt: &[f32],
        max_tokens: usize,
    ) -> Result<DecodeSession> {
        let width = prompt.len();
        if width == 0 || width > arena.max_width() {
            return Err(Error::Shape(format!(
                "decode prompt width {width} must be in [1, {}]",
                arena.max_width()
            )));
        }
        if max_tokens == 0 {
            return Err(Error::Config("max_tokens must be positive".into()));
        }
        let mut lease = arena.checkout_owned(1);
        let (ping, _) = lease.bufs();
        ping.row_mut(0)[..width].copy_from_slice(prompt);
        Ok(DecodeSession {
            id,
            lease,
            width,
            emitted: 0,
            max_tokens,
        })
    }

    pub fn id(&self) -> u64 {
        self.id
    }

    /// State-row width (= the model's `d_in` = `d_out`).
    pub fn width(&self) -> usize {
        self.width
    }

    /// Tokens emitted so far.
    pub fn emitted(&self) -> usize {
        self.emitted
    }

    /// Whether the token budget is exhausted (the session leaves the
    /// scheduler after the step that hits it).
    pub fn done(&self) -> bool {
        self.emitted >= self.max_tokens
    }

    /// The current state row — the session's next model input.
    pub fn state(&mut self) -> &[f32] {
        let width = self.width;
        let (ping, _) = self.lease.bufs();
        &ping.row(0)[..width]
    }

    /// Feed one decode step's output row back as the next state and emit
    /// its token: the argmax index (first index wins ties, so the token
    /// stream is a pure function of the row bits).
    pub fn absorb_output(&mut self, row: &[f32]) -> u32 {
        debug_assert_eq!(row.len(), self.width);
        let (ping, _) = self.lease.bufs();
        ping.row_mut(0)[..row.len()].copy_from_slice(row);
        self.emitted += 1;
        argmax_token(row)
    }
}

/// Deterministic synthetic token for an output row: the argmax index,
/// first index on ties (`>` comparison). NaNs lose every comparison, so a
/// row of NaNs yields token 0 rather than a panic.
pub fn argmax_token(row: &[f32]) -> u32 {
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &v) in row.iter().enumerate() {
        if v > best_v {
            best_v = v;
            best = i;
        }
    }
    best as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    fn arena(width: usize) -> Arc<ActivationArena> {
        Arc::new(ActivationArena::new(width))
    }

    #[test]
    fn session_feeds_output_back_as_state() {
        let arena = arena(4);
        let mut s = DecodeSession::new(7, &arena, &[1.0, 2.0, 3.0, 4.0], 2).unwrap();
        assert_eq!(s.id(), 7);
        assert_eq!(s.state(), &[1.0, 2.0, 3.0, 4.0]);
        assert!(!s.done());
        let tok = s.absorb_output(&[0.5, -1.0, 9.0, 0.0]);
        assert_eq!(tok, 2, "argmax index of the output row");
        assert_eq!(s.state(), &[0.5, -1.0, 9.0, 0.0], "output is the next input");
        assert_eq!(s.emitted(), 1);
        s.absorb_output(&[0.0; 4]);
        assert!(s.done(), "budget of 2 exhausted");
    }

    #[test]
    fn argmax_breaks_ties_on_first_index() {
        assert_eq!(argmax_token(&[1.0, 3.0, 3.0, 0.0]), 1);
        assert_eq!(argmax_token(&[f32::NAN, f32::NAN]), 0);
        assert_eq!(argmax_token(&[-2.0, -1.0]), 1);
    }

    #[test]
    fn session_rejects_bad_shapes() {
        let arena = arena(4);
        assert!(DecodeSession::new(0, &arena, &[], 4).is_err());
        assert!(DecodeSession::new(0, &arena, &[0.0; 5], 4).is_err());
        assert!(DecodeSession::new(0, &arena, &[0.0; 4], 0).is_err());
    }

    #[test]
    fn leases_return_to_the_arena_on_drop() {
        let arena = arena(8);
        {
            let _a = DecodeSession::new(0, &arena, &[0.0; 8], 1).unwrap();
            let _b = DecodeSession::new(1, &arena, &[0.0; 8], 1).unwrap();
        }
        assert_eq!(arena.stats().allocations, 2);
        // Dropped sessions returned their pairs: two fresh sessions reuse.
        let _c = DecodeSession::new(2, &arena, &[0.0; 8], 1).unwrap();
        let _d = DecodeSession::new(3, &arena, &[0.0; 8], 1).unwrap();
        let stats = arena.stats();
        assert_eq!(stats.allocations, 2, "steady state allocates nothing");
        assert_eq!(stats.reuses, 2);
    }
}
