//! Model configuration: a JSON document describing a ternary FFN and how
//! to serve it. Example (see `examples/` and `stgemm serve --model`):
//!
//! ```json
//! {
//!   "name": "ffn_demo",
//!   "dims": [256, 1024, 256],
//!   "sparsity": 0.25,
//!   "seed": 42,
//!   "prelu_alpha": 0.25,
//!   "batch_buckets": [1, 8],
//!   "threads": 1,
//!   "pipeline": true
//! }
//! ```
//!
//! `kernel` is **optional**: when absent, each layer's kernel is picked by
//! the [`crate::plan::Planner`] (autotune table + paper heuristics). Set it
//! only to pin an explicit registry kernel (benches, ablations).

use crate::kernels::KernelId;
use crate::util::json::Json;
use crate::{Error, Result};

/// Parsed model configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    /// Layer dimensions `d0 → d1 → … → dL`.
    pub dims: Vec<usize>,
    /// Nonzero fraction of every layer's ternary weights.
    pub sparsity: f32,
    /// Weight generation seed (layer i uses `seed + i`).
    pub seed: u64,
    /// PReLU slope between layers (never after the last layer).
    pub prelu_alpha: f32,
    /// Explicit registry kernel override, resolved to a typed id at parse
    /// time (the JSON stays name-keyed); `None` = planner-selected.
    pub kernel: Option<KernelId>,
    /// Batch sizes the server pads to (ascending).
    pub batch_buckets: Vec<usize>,
    /// Worker threads for row-partitioned layer execution (1 = sequential).
    pub threads: usize,
    /// Wavefront-pipeline multi-layer forwards (cross-layer band
    /// scheduling, zero-allocation activation arena). `false` restores
    /// the per-layer barrier path (`serve --no-pipeline` does the same).
    pub pipeline: bool,
    /// Admission queue budget when served by the fleet registry: submits
    /// that would grow the model's queue past this are rejected 429-style
    /// instead of queueing unboundedly. `0` (the default) = unlimited.
    pub queue_budget: usize,
}

impl Default for ModelConfig {
    fn default() -> Self {
        ModelConfig {
            name: "ffn_demo".to_string(),
            dims: vec![256, 1024, 256],
            sparsity: 0.25,
            seed: 42,
            prelu_alpha: 0.25,
            kernel: None,
            batch_buckets: vec![1, 8],
            threads: 1,
            pipeline: true,
            queue_budget: 0,
        }
    }
}

impl ModelConfig {
    /// Parse from a JSON string.
    pub fn from_json(text: &str) -> Result<ModelConfig> {
        let bad = |msg: &str| Error::Config(msg.to_string());
        let v = Json::parse(text).map_err(|e| Error::Config(e.to_string()))?;
        let d = ModelConfig::default();
        let dims = match v.get("dims") {
            Some(Json::Arr(items)) => items
                .iter()
                .map(|i| i.as_usize().ok_or_else(|| bad("dims must be integers")))
                .collect::<Result<Vec<_>>>()?,
            None => d.dims,
            _ => return Err(bad("dims must be an array")),
        };
        if dims.len() < 2 {
            return Err(bad("dims needs at least [d_in, d_out]"));
        }
        let batch_buckets = match v.get("batch_buckets") {
            Some(Json::Arr(items)) => {
                let mut b = items
                    .iter()
                    .map(|i| {
                        i.as_usize()
                            .filter(|&x| x > 0)
                            .ok_or_else(|| bad("batch_buckets must be positive integers"))
                    })
                    .collect::<Result<Vec<_>>>()?;
                b.sort_unstable();
                b.dedup();
                if b.is_empty() {
                    return Err(bad("batch_buckets must be non-empty"));
                }
                b
            }
            None => d.batch_buckets,
            _ => return Err(bad("batch_buckets must be an array")),
        };
        let sparsity = v
            .get("sparsity")
            .map(|s| s.as_f64().ok_or_else(|| bad("sparsity must be a number")))
            .transpose()?
            .map(|s| s as f32)
            .unwrap_or(d.sparsity);
        if !(0.0..=1.0).contains(&sparsity) {
            return Err(bad("sparsity must be in [0,1]"));
        }
        // The kernel key stays a registry *name* in JSON but resolves to a
        // typed id here — an unknown name fails the parse with
        // `Error::UnknownKernel`.
        let kernel = match v.get("kernel") {
            Some(k) => {
                let name = k.as_str().ok_or_else(|| bad("kernel must be a string"))?;
                Some(name.parse::<KernelId>()?)
            }
            None => None,
        };
        let threads = match v.get("threads") {
            Some(t) => t
                .as_usize()
                .filter(|&t| t > 0)
                .ok_or_else(|| bad("threads must be a positive integer"))?,
            None => d.threads,
        };
        let pipeline = match v.get("pipeline") {
            Some(Json::Bool(b)) => *b,
            None => d.pipeline,
            _ => return Err(bad("pipeline must be a boolean")),
        };
        let queue_budget = match v.get("queue_budget") {
            Some(q) => q
                .as_usize()
                .ok_or_else(|| bad("queue_budget must be a non-negative integer"))?,
            None => d.queue_budget,
        };
        Ok(ModelConfig {
            name: v
                .get("name")
                .and_then(|s| s.as_str())
                .unwrap_or(&d.name)
                .to_string(),
            dims,
            sparsity,
            seed: v
                .get("seed")
                .map(|s| s.as_f64().ok_or_else(|| bad("seed must be a number")))
                .transpose()?
                .map(|s| s as u64)
                .unwrap_or(d.seed),
            prelu_alpha: v
                .get("prelu_alpha")
                .map(|s| s.as_f64().ok_or_else(|| bad("prelu_alpha must be a number")))
                .transpose()?
                .map(|s| s as f32)
                .unwrap_or(d.prelu_alpha),
            kernel,
            batch_buckets,
            threads,
            pipeline,
            queue_budget,
        })
    }

    /// Load from a file path.
    pub fn from_file(path: &str) -> Result<ModelConfig> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::io(format!("read {path}"), e))?;
        Self::from_json(&text)
    }

    /// Serialize back to JSON (pretty). The kernel key is written only
    /// when an explicit override is set.
    pub fn to_json(&self) -> String {
        let mut fields = vec![
            ("name", Json::str(self.name.clone())),
            (
                "dims",
                Json::arr(self.dims.iter().map(|&d| Json::num(d as f64))),
            ),
            ("sparsity", Json::num(self.sparsity as f64)),
            ("seed", Json::num(self.seed as f64)),
            ("prelu_alpha", Json::num(self.prelu_alpha as f64)),
        ];
        if let Some(k) = &self.kernel {
            fields.push(("kernel", Json::str(k.name())));
        }
        fields.push((
            "batch_buckets",
            Json::arr(self.batch_buckets.iter().map(|&b| Json::num(b as f64))),
        ));
        fields.push(("threads", Json::num(self.threads as f64)));
        fields.push(("pipeline", Json::Bool(self.pipeline)));
        // Written only when set, so configs that never opted into
        // admission control roundtrip byte-identically.
        if self.queue_budget > 0 {
            fields.push(("queue_budget", Json::num(self.queue_budget as f64)));
        }
        Json::obj(fields).encode_pretty()
    }

    pub fn d_in(&self) -> usize {
        self.dims[0]
    }

    pub fn d_out(&self) -> usize {
        *self.dims.last().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let c = ModelConfig::default();
        let parsed = ModelConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(parsed, c);
    }

    #[test]
    fn partial_json_uses_defaults() {
        let c = ModelConfig::from_json(r#"{"dims": [8, 16, 4]}"#).unwrap();
        assert_eq!(c.dims, vec![8, 16, 4]);
        assert_eq!(c.kernel, None, "no kernel key = planner-selected");
        assert_eq!(c.threads, 1);
        assert!(c.pipeline, "pipelining defaults on");
        assert_eq!(c.d_in(), 8);
        assert_eq!(c.d_out(), 4);
    }

    #[test]
    fn explicit_kernel_and_threads_parse() {
        let c = ModelConfig::from_json(
            r#"{"dims": [8, 4], "kernel": "base_tcsc", "threads": 4}"#,
        )
        .unwrap();
        assert_eq!(c.kernel, Some(KernelId::BaseTcsc));
        assert_eq!(c.threads, 4);
        let back = ModelConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back, c);
    }

    #[test]
    fn rejects_bad_inputs() {
        assert!(ModelConfig::from_json("{").is_err());
        assert!(ModelConfig::from_json(r#"{"dims": [8]}"#).is_err());
        assert!(ModelConfig::from_json(r#"{"sparsity": 1.5}"#).is_err());
        assert!(matches!(
            ModelConfig::from_json(r#"{"kernel": "nope"}"#),
            Err(Error::UnknownKernel(_))
        ));
        assert!(ModelConfig::from_json(r#"{"batch_buckets": []}"#).is_err());
        assert!(ModelConfig::from_json(r#"{"batch_buckets": [0]}"#).is_err());
        assert!(ModelConfig::from_json(r#"{"threads": 0}"#).is_err());
        assert!(ModelConfig::from_json(r#"{"pipeline": 3}"#).is_err());
    }

    #[test]
    fn pipeline_key_parses_and_roundtrips() {
        let c = ModelConfig::from_json(r#"{"dims": [8, 4], "pipeline": false}"#).unwrap();
        assert!(!c.pipeline);
        let back = ModelConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back, c);
    }

    #[test]
    fn queue_budget_parses_and_roundtrips() {
        let c = ModelConfig::from_json(r#"{"dims": [8, 4]}"#).unwrap();
        assert_eq!(c.queue_budget, 0, "absent = unlimited");
        let c = ModelConfig::from_json(r#"{"dims": [8, 4], "queue_budget": 32}"#).unwrap();
        assert_eq!(c.queue_budget, 32);
        let back = ModelConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back, c);
        assert!(ModelConfig::from_json(r#"{"queue_budget": -1}"#).is_err());
        assert!(ModelConfig::from_json(r#"{"queue_budget": "a"}"#).is_err());
    }

    #[test]
    fn buckets_sorted_and_deduped() {
        let c = ModelConfig::from_json(r#"{"batch_buckets": [8, 1, 8, 4]}"#).unwrap();
        assert_eq!(c.batch_buckets, vec![1, 4, 8]);
    }
}
