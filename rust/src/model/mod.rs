//! Model layer: ternary linear layers, the FFN/MLP stack, the JSON config
//! system and binary weight serialization. This is what the serving engine
//! executes on its native (non-PJRT) path.

pub mod config;
pub mod layer;
pub mod mlp;
pub mod serialize;
pub mod session;

pub use config::ModelConfig;
pub use layer::TernaryLinear;
pub use mlp::TernaryMlp;
pub use session::DecodeSession;
