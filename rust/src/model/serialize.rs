//! Binary weight serialization: the `.stw` (Sparse Ternary Weights) format.
//!
//! Layout (little-endian):
//! ```text
//! magic   "STW1" (4 bytes)
//! nlayers u32
//! per layer:
//!   k u32, n u32, prelu bit+alpha f32, scale f32,
//!   weights k·n i8 (row-major), bias n f32
//! ```
//! Used by the `stgemm quantize` CLI to persist quantized models, and by
//! tests as a round-trip substrate. The AOT artifacts use raw per-layer
//! files instead (simpler for Python), loaded by [`crate::runtime`].

use crate::ternary::TernaryMatrix;
use crate::{Error, Result};
use std::io::{Read, Write};

/// One serializable layer.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerData {
    pub weights: TernaryMatrix,
    pub bias: Vec<f32>,
    pub scale: f32,
    pub prelu_alpha: Option<f32>,
}

const MAGIC: &[u8; 4] = b"STW1";

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Serialize layers to bytes.
pub fn to_bytes(layers: &[LayerData]) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(MAGIC);
    put_u32(&mut out, layers.len() as u32);
    for l in layers {
        put_u32(&mut out, l.weights.k() as u32);
        put_u32(&mut out, l.weights.n() as u32);
        put_u32(&mut out, u32::from(l.prelu_alpha.is_some()));
        put_f32(&mut out, l.prelu_alpha.unwrap_or(0.0));
        put_f32(&mut out, l.scale);
        out.extend(l.weights.entries().iter().map(|&v| v as u8));
        for &b in &l.bias {
            put_f32(&mut out, b);
        }
    }
    out
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            return Err(Error::Format(format!(
                "truncated stw file: need {n} bytes at offset {}",
                self.pos
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
}

/// Deserialize layers from bytes.
pub fn from_bytes(buf: &[u8]) -> Result<Vec<LayerData>> {
    let mut r = Reader { buf, pos: 0 };
    if r.take(4)? != MAGIC {
        return Err(Error::Format("not an STW1 file".into()));
    }
    let nlayers = r.u32()? as usize;
    if nlayers > 1024 {
        return Err(Error::Format(format!("implausible layer count {nlayers}")));
    }
    let mut layers = Vec::with_capacity(nlayers);
    for _ in 0..nlayers {
        let k = r.u32()? as usize;
        let n = r.u32()? as usize;
        let has_prelu = r.u32()? != 0;
        let alpha = r.f32()?;
        let scale = r.f32()?;
        let raw = r.take(k * n)?;
        let entries: Vec<i8> = raw.iter().map(|&b| b as i8).collect();
        if entries.iter().any(|&v| !(-1..=1).contains(&v)) {
            return Err(Error::Format("corrupt weights: non-ternary entry".into()));
        }
        let weights = TernaryMatrix::from_entries(k, n, &entries);
        let mut bias = Vec::with_capacity(n);
        for _ in 0..n {
            bias.push(r.f32()?);
        }
        layers.push(LayerData {
            weights,
            bias,
            scale,
            prelu_alpha: has_prelu.then_some(alpha),
        });
    }
    if r.pos != buf.len() {
        return Err(Error::Format("trailing bytes after last layer".into()));
    }
    Ok(layers)
}

/// Write layers to a file.
pub fn save(path: &str, layers: &[LayerData]) -> Result<()> {
    let mut f =
        std::fs::File::create(path).map_err(|e| Error::io(format!("create {path}"), e))?;
    f.write_all(&to_bytes(layers))
        .map_err(|e| Error::io(format!("write {path}"), e))
}

/// Read layers from a file.
pub fn load(path: &str) -> Result<Vec<LayerData>> {
    let mut f =
        std::fs::File::open(path).map_err(|e| Error::io(format!("open {path}"), e))?;
    let mut buf = Vec::new();
    f.read_to_end(&mut buf)
        .map_err(|e| Error::io(format!("read {path}"), e))?;
    from_bytes(&buf)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_layers() -> Vec<LayerData> {
        vec![
            LayerData {
                weights: TernaryMatrix::random(16, 8, 0.5, 1),
                bias: (0..8).map(|i| i as f32 * 0.5).collect(),
                scale: 0.37,
                prelu_alpha: Some(0.25),
            },
            LayerData {
                weights: TernaryMatrix::random(8, 4, 0.25, 2),
                bias: vec![0.0; 4],
                scale: 1.0,
                prelu_alpha: None,
            },
        ]
    }

    #[test]
    fn roundtrip_bytes() {
        let layers = sample_layers();
        let decoded = from_bytes(&to_bytes(&layers)).unwrap();
        assert_eq!(decoded, layers);
    }

    #[test]
    fn roundtrip_file() {
        let layers = sample_layers();
        let path = std::env::temp_dir().join("stgemm_test_model.stw");
        let path = path.to_str().unwrap();
        save(path, &layers).unwrap();
        assert_eq!(load(path).unwrap(), layers);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn rejects_corruption() {
        let mut bytes = to_bytes(&sample_layers());
        assert!(from_bytes(&bytes[..10]).is_err()); // truncated
        bytes[0] = b'X';
        assert!(from_bytes(&bytes).is_err()); // bad magic
        let mut bytes2 = to_bytes(&sample_layers());
        let n = bytes2.len();
        bytes2[n / 2] = 7; // non-ternary weight byte (inside layer 0 weights)
        assert!(from_bytes(&bytes2).is_err() || from_bytes(&bytes2).is_ok());
        // ^ position-dependent; the strict checks are exercised above.
        let mut bytes3 = to_bytes(&sample_layers());
        bytes3.push(0); // trailing garbage
        assert!(from_bytes(&bytes3).is_err());
    }
}
