//! A single ternary linear layer: prepared kernel + bias + optional
//! dequantization scale + optional PReLU.

use crate::kernels::{prelu_inplace, prepare_kernel, KernelParams, PreparedGemm};
use crate::tensor::Matrix;
use crate::ternary::TernaryMatrix;

/// One `Y = act(scale · (X·W + b))` layer with ternary W.
pub struct TernaryLinear {
    gemm: Box<dyn PreparedGemm>,
    bias: Vec<f32>,
    /// Per-tensor dequantization scale (absmean quantizer's gamma); folded
    /// in after the GEMM, before activation. 1.0 = no scaling.
    pub scale: f32,
    /// PReLU slope; `None` = linear output.
    pub prelu_alpha: Option<f32>,
}

impl TernaryLinear {
    /// Build from dense ternary weights with the named registry kernel.
    ///
    /// When `prelu_alpha` is set and the kernel supports fusion (the SIMD
    /// family), activation is fused into the GEMM; otherwise a separate
    /// PReLU pass runs after.
    pub fn new(
        kernel: &str,
        w: &TernaryMatrix,
        bias: Vec<f32>,
        scale: f32,
        prelu_alpha: Option<f32>,
    ) -> Result<TernaryLinear, String> {
        assert_eq!(bias.len(), w.n(), "bias length must equal N");
        // Fusion is only valid when no scale is applied after the GEMM
        // (PReLU and positive scaling commute, but keep it simple & exact).
        let fuse = scale == 1.0;
        let params = KernelParams {
            prelu_alpha: if fuse { prelu_alpha } else { None },
            ..Default::default()
        };
        let gemm = prepare_kernel(kernel, w, params)?;
        Ok(TernaryLinear {
            gemm,
            bias,
            scale,
            prelu_alpha,
        })
    }

    pub fn k(&self) -> usize {
        self.gemm.k()
    }

    pub fn n(&self) -> usize {
        self.gemm.n()
    }

    pub fn nnz(&self) -> usize {
        self.gemm.nnz()
    }

    pub fn kernel_name(&self) -> &str {
        self.gemm.name()
    }

    pub fn format_bytes(&self) -> usize {
        self.gemm.format_bytes()
    }

    /// Forward: `y` must be (x.rows × N).
    pub fn forward(&self, x: &Matrix, y: &mut Matrix) {
        self.gemm.run(x, &self.bias, y);
        if self.scale != 1.0 {
            for v in y.as_mut_slice() {
                *v *= self.scale;
            }
        }
        if let Some(alpha) = self.prelu_alpha {
            if !self.gemm.fused_prelu() {
                prelu_inplace(y, alpha);
            }
        }
    }

    /// Paper cost model flops for a batch of `m` rows.
    pub fn flops(&self, m: usize) -> f64 {
        let mut f = m as f64 * self.nnz() as f64 + (m * self.n()) as f64;
        if self.prelu_alpha.is_some() {
            f += (m * self.n()) as f64;
        }
        f
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::dense_oracle;

    #[test]
    fn forward_matches_oracle_with_scale_and_prelu() {
        let w = TernaryMatrix::random(64, 32, 0.25, 3);
        let bias: Vec<f32> = (0..32).map(|i| i as f32 * 0.1).collect();
        let x = Matrix::random(4, 64, 4);
        let layer =
            TernaryLinear::new("interleaved_blocked_tcsc", &w, bias.clone(), 0.5, Some(0.25))
                .unwrap();
        let mut y = Matrix::zeros(4, 32);
        layer.forward(&x, &mut y);

        let mut want = dense_oracle(&x, &w, &bias);
        for v in want.as_mut_slice() {
            *v *= 0.5;
            if *v < 0.0 {
                *v *= 0.25;
            }
        }
        assert!(y.allclose(&want, 1e-4));
    }

    #[test]
    fn fused_and_unfused_prelu_agree() {
        let w = TernaryMatrix::random(48, 16, 0.5, 9);
        let bias = vec![0.1f32; 16];
        let x = Matrix::random(4, 48, 10);
        let fused =
            TernaryLinear::new("simd_vertical", &w, bias.clone(), 1.0, Some(0.25)).unwrap();
        let unfused =
            TernaryLinear::new("base_tcsc", &w, bias.clone(), 1.0, Some(0.25)).unwrap();
        let mut yf = Matrix::zeros(4, 16);
        let mut yu = Matrix::zeros(4, 16);
        fused.forward(&x, &mut yf);
        unfused.forward(&x, &mut yu);
        assert!(yf.allclose(&yu, 1e-4));
    }

    #[test]
    fn flops_model() {
        let w = TernaryMatrix::random(32, 8, 0.5, 1);
        let layer = TernaryLinear::new("base_tcsc", &w, vec![0.0; 8], 1.0, None).unwrap();
        let nnz = layer.nnz() as f64;
        assert_eq!(layer.flops(2), 2.0 * nnz + 16.0);
    }

    #[test]
    fn unknown_kernel_errors() {
        let w = TernaryMatrix::random(8, 4, 0.5, 1);
        assert!(TernaryLinear::new("bogus", &w, vec![0.0; 4], 1.0, None).is_err());
    }
}
