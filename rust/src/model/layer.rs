//! A single ternary linear layer: bias, optional dequantization scale and
//! optional PReLU over a planned GEMM — either one pinned [`GemmPlan`]
//! (the explicit-override escape hatch benches use) or a handle into the
//! shared M-bucketed [`PlanCache`] (the serving path).

use crate::kernels::KernelId;
use crate::plan::{Epilogue, GemmPlan, LayerId, LayerSpec, PlanCache, PlanHints, Planner};
use crate::tensor::Matrix;
use crate::ternary::TernaryMatrix;
use crate::Result;
use std::sync::Arc;

enum Exec {
    /// One plan, fixed at construction (explicit kernel override or a
    /// single-shape tool like `selftest`).
    Pinned(GemmPlan),
    /// Plans come from the shared cache, keyed by the batch's M-bucket and
    /// the live thread ceiling.
    Cached { cache: Arc<PlanCache>, id: LayerId },
}

/// One `Y = act(scale · (X·W + b))` layer with ternary W, executed through
/// the planning layer.
pub struct TernaryLinear {
    exec: Exec,
}

impl TernaryLinear {
    /// Build with the kernel chosen by `planner` (tuning table + paper
    /// heuristics) and the execution policy in `hints`: a single pinned
    /// plan, for callers that serve one shape (e.g. `selftest`).
    pub fn planned(
        planner: &Planner,
        w: &TernaryMatrix,
        bias: Vec<f32>,
        scale: f32,
        prelu_alpha: Option<f32>,
        hints: &PlanHints,
    ) -> Result<TernaryLinear> {
        let plan = planner.plan(
            w,
            Default::default(),
            Epilogue::new(bias, scale, prelu_alpha),
            hints,
        )?;
        Ok(TernaryLinear { exec: Exec::Pinned(plan) })
    }

    /// Register the layer in a shared [`PlanCache`]: plans are built
    /// lazily per (M-bucket, threads), with the cache's online top-2 race
    /// covering untuned classes. This is the serving-path constructor.
    /// `kernel` stays the explicit override escape hatch.
    pub fn cached(
        cache: &Arc<PlanCache>,
        w: TernaryMatrix,
        bias: Vec<f32>,
        scale: f32,
        prelu_alpha: Option<f32>,
        kernel: Option<KernelId>,
    ) -> Result<TernaryLinear> {
        let mut spec = LayerSpec::new(w, Epilogue::new(bias, scale, prelu_alpha));
        spec.kernel = kernel;
        let id = cache.register(spec)?;
        Ok(TernaryLinear {
            exec: Exec::Cached {
                cache: Arc::clone(cache),
                id,
            },
        })
    }

    /// Build from dense ternary weights with an **explicit** registry
    /// kernel name — the override path benches and ablations use (the
    /// name resolves to a typed [`KernelId`] here; unknown names fail
    /// with [`crate::Error::UnknownKernel`]). When `prelu_alpha` is set,
    /// the kernel supports fusion (the SIMD family) and no scale
    /// intervenes, activation fuses into the GEMM; otherwise the plan's
    /// epilogue applies it after.
    pub fn new(
        kernel: &str,
        w: &TernaryMatrix,
        bias: Vec<f32>,
        scale: f32,
        prelu_alpha: Option<f32>,
    ) -> Result<TernaryLinear> {
        Self::planned(
            &Planner::new(),
            w,
            bias,
            scale,
            prelu_alpha,
            &PlanHints::with_kernel(kernel.parse::<KernelId>()?),
        )
    }

    /// Wrap an already-built plan as a layer.
    pub fn from_plan(plan: GemmPlan) -> TernaryLinear {
        TernaryLinear { exec: Exec::Pinned(plan) }
    }

    pub fn k(&self) -> usize {
        match &self.exec {
            Exec::Pinned(p) => p.k(),
            Exec::Cached { cache, id } => cache.k(*id),
        }
    }

    pub fn n(&self) -> usize {
        match &self.exec {
            Exec::Pinned(p) => p.n(),
            Exec::Cached { cache, id } => cache.n(*id),
        }
    }

    pub fn nnz(&self) -> usize {
        match &self.exec {
            Exec::Pinned(p) => p.nnz(),
            Exec::Cached { cache, id } => cache.nnz(*id),
        }
    }

    /// The kernel this layer executes with (for cached layers: the current
    /// selection for single-row batches — M-aware tuning entries may pick
    /// a different kernel per batch bucket, and the online race may refine
    /// any untuned bucket on its first traffic).
    pub fn kernel_name(&self) -> String {
        match &self.exec {
            Exec::Pinned(p) => p.kernel_name().to_string(),
            Exec::Cached { cache, id } => cache.kernel_for(*id, 1).name().to_string(),
        }
    }

    /// Exact format byte size (operational-intensity accounting). For
    /// cached layers this builds (once) the smallest-bucket plan.
    pub fn format_bytes(&self) -> usize {
        match &self.exec {
            Exec::Pinned(p) => p.format_bytes(),
            Exec::Cached { cache, id } => cache
                .plan_for(*id, 1)
                .map(|p| p.format_bytes())
                .unwrap_or(0),
        }
    }

    /// Per-tensor dequantization scale (1.0 = none).
    pub fn scale(&self) -> f32 {
        match &self.exec {
            Exec::Pinned(p) => p.epilogue().scale,
            Exec::Cached { cache, id } => cache.scale(*id),
        }
    }

    /// PReLU slope (`None` = linear output).
    pub fn prelu_alpha(&self) -> Option<f32> {
        match &self.exec {
            Exec::Pinned(p) => p.epilogue().prelu_alpha,
            Exec::Cached { cache, id } => cache.prelu_alpha(*id),
        }
    }

    /// The pinned plan, when this layer was built with one (introspection
    /// and direct use); `None` for cache-backed layers.
    pub fn pinned_plan(&self) -> Option<&GemmPlan> {
        match &self.exec {
            Exec::Pinned(p) => Some(p),
            Exec::Cached { .. } => None,
        }
    }

    /// Forward into caller-provided storage: `y` must be (x.rows × N).
    ///
    /// # Errors
    /// [`crate::Error::Runtime`] when a partitioned worker panicked (`y`
    /// is then incomplete and must be discarded).
    pub fn forward(&self, x: &Matrix, y: &mut Matrix) -> Result<()> {
        match &self.exec {
            Exec::Pinned(p) => p.run(x, y),
            Exec::Cached { cache, id } => cache.run(*id, x, y),
        }
    }

    /// Paper cost model flops for a batch of `m` rows.
    pub fn flops(&self, m: usize) -> f64 {
        match &self.exec {
            Exec::Pinned(p) => p.flops(m),
            Exec::Cached { cache, id } => cache.flops(*id, m),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::dense_oracle;
    use crate::plan::PlanCacheConfig;

    #[test]
    fn forward_matches_oracle_with_scale_and_prelu() {
        let w = TernaryMatrix::random(64, 32, 0.25, 3);
        let bias: Vec<f32> = (0..32).map(|i| i as f32 * 0.1).collect();
        let x = Matrix::random(4, 64, 4);
        let layer =
            TernaryLinear::new("interleaved_blocked_tcsc", &w, bias.clone(), 0.5, Some(0.25))
                .unwrap();
        let mut y = Matrix::zeros(4, 32);
        layer.forward(&x, &mut y).unwrap();

        let mut want = dense_oracle(&x, &w, &bias);
        for v in want.as_mut_slice() {
            *v *= 0.5;
            if *v < 0.0 {
                *v *= 0.25;
            }
        }
        assert!(y.allclose(&want, 1e-4));
    }

    #[test]
    fn fused_and_unfused_prelu_agree() {
        let w = TernaryMatrix::random(48, 16, 0.5, 9);
        let bias = vec![0.1f32; 16];
        let x = Matrix::random(4, 48, 10);
        let fused =
            TernaryLinear::new("simd_vertical", &w, bias.clone(), 1.0, Some(0.25)).unwrap();
        let unfused =
            TernaryLinear::new("base_tcsc", &w, bias.clone(), 1.0, Some(0.25)).unwrap();
        assert!(fused.pinned_plan().unwrap().fused_prelu());
        assert!(!unfused.pinned_plan().unwrap().fused_prelu());
        let mut yf = Matrix::zeros(4, 16);
        let mut yu = Matrix::zeros(4, 16);
        fused.forward(&x, &mut yf).unwrap();
        unfused.forward(&x, &mut yu).unwrap();
        assert!(yf.allclose(&yu, 1e-4));
    }

    #[test]
    fn planned_layer_picks_a_kernel_and_matches_explicit() {
        let planner = Planner::new();
        let w = TernaryMatrix::random(64, 16, 0.25, 11);
        let bias = vec![0.05f32; 16];
        let x = Matrix::random(3, 64, 12);
        let auto = TernaryLinear::planned(
            &planner,
            &w,
            bias.clone(),
            1.0,
            None,
            &PlanHints::default(),
        )
        .unwrap();
        // 25% nonzeros, no fused PReLU wanted → the paper's best scalar.
        assert_eq!(auto.kernel_name(), "interleaved_blocked_tcsc");
        let explicit =
            TernaryLinear::new("interleaved_blocked_tcsc", &w, bias, 1.0, None).unwrap();
        let mut ya = Matrix::zeros(3, 16);
        let mut ye = Matrix::zeros(3, 16);
        auto.forward(&x, &mut ya).unwrap();
        explicit.forward(&x, &mut ye).unwrap();
        assert_eq!(ya, ye);
    }

    #[test]
    fn cached_layer_runs_through_the_plan_cache() {
        let cache = Arc::new(PlanCache::new(
            Arc::new(Planner::new()),
            PlanCacheConfig {
                threads: 2,
                online_top2: false,
                race_reps: 1,
            },
        ));
        let w = TernaryMatrix::random(48, 12, 0.25, 21);
        let bias = vec![0.2f32; 12];
        let layer =
            TernaryLinear::cached(&cache, w.clone(), bias.clone(), 1.0, None, None).unwrap();
        assert_eq!((layer.k(), layer.n()), (48, 12));
        assert_eq!(layer.nnz(), w.nnz());
        assert!(layer.pinned_plan().is_none());
        for m in [1usize, 5, 8] {
            let x = Matrix::random(m, 48, 30 + m as u64);
            let mut y = Matrix::zeros(m, 12);
            layer.forward(&x, &mut y).unwrap();
            assert!(y.allclose(&dense_oracle(&x, &w, &bias), 1e-4), "m={m}");
        }
        assert!(cache.snapshot().plans > 0);
        assert!(layer.format_bytes() > 0);
    }

    #[test]
    fn flops_model() {
        let w = TernaryMatrix::random(32, 8, 0.5, 1);
        let layer = TernaryLinear::new("base_tcsc", &w, vec![0.0; 8], 1.0, None).unwrap();
        let nnz = layer.nnz() as f64;
        assert_eq!(layer.flops(2), 2.0 * nnz + 16.0);
    }

    #[test]
    fn unknown_kernel_errors() {
        let w = TernaryMatrix::random(8, 4, 0.5, 1);
        assert!(TernaryLinear::new("bogus", &w, vec![0.0; 4], 1.0, None).is_err());
    }

    #[test]
    fn bias_mismatch_errors() {
        let w = TernaryMatrix::random(8, 4, 0.5, 1);
        assert!(TernaryLinear::new("base_tcsc", &w, vec![0.0; 3], 1.0, None).is_err());
    }
}
