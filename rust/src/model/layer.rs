//! A single ternary linear layer: a [`GemmPlan`] owning the prepared
//! kernel, bias, optional dequantization scale and optional PReLU.

use crate::plan::{Epilogue, GemmPlan, PlanHints, Planner};
use crate::tensor::Matrix;
use crate::ternary::TernaryMatrix;

/// One `Y = act(scale · (X·W + b))` layer with ternary W, executed through
/// the planning layer.
pub struct TernaryLinear {
    plan: GemmPlan,
}

impl TernaryLinear {
    /// Build with the kernel chosen by `planner` (tuning table + paper
    /// heuristics) and the execution policy in `hints`. This is the
    /// serving-path constructor: no kernel name required.
    pub fn planned(
        planner: &Planner,
        w: &TernaryMatrix,
        bias: Vec<f32>,
        scale: f32,
        prelu_alpha: Option<f32>,
        hints: &PlanHints,
    ) -> Result<TernaryLinear, String> {
        let plan = planner.plan(
            w,
            Default::default(),
            Epilogue::new(bias, scale, prelu_alpha),
            hints,
        )?;
        Ok(TernaryLinear { plan })
    }

    /// Build from dense ternary weights with an **explicit** registry
    /// kernel — the override path benches and ablations use. When
    /// `prelu_alpha` is set, the kernel supports fusion (the SIMD family)
    /// and no scale intervenes, activation fuses into the GEMM; otherwise
    /// the plan's epilogue applies it after.
    pub fn new(
        kernel: &str,
        w: &TernaryMatrix,
        bias: Vec<f32>,
        scale: f32,
        prelu_alpha: Option<f32>,
    ) -> Result<TernaryLinear, String> {
        Self::planned(
            &Planner::new(),
            w,
            bias,
            scale,
            prelu_alpha,
            &PlanHints::with_kernel(kernel),
        )
    }

    /// Wrap an already-built plan as a layer.
    pub fn from_plan(plan: GemmPlan) -> TernaryLinear {
        TernaryLinear { plan }
    }

    pub fn k(&self) -> usize {
        self.plan.k()
    }

    pub fn n(&self) -> usize {
        self.plan.n()
    }

    pub fn nnz(&self) -> usize {
        self.plan.nnz()
    }

    pub fn kernel_name(&self) -> &str {
        self.plan.kernel_name()
    }

    pub fn format_bytes(&self) -> usize {
        self.plan.format_bytes()
    }

    /// Per-tensor dequantization scale (1.0 = none).
    pub fn scale(&self) -> f32 {
        self.plan.epilogue().scale
    }

    /// PReLU slope (`None` = linear output).
    pub fn prelu_alpha(&self) -> Option<f32> {
        self.plan.epilogue().prelu_alpha
    }

    /// The underlying plan (introspection and direct use).
    pub fn plan(&self) -> &GemmPlan {
        &self.plan
    }

    /// Forward: `y` must be (x.rows × N).
    pub fn forward(&self, x: &Matrix, y: &mut Matrix) {
        self.plan.run(x, y);
    }

    /// Paper cost model flops for a batch of `m` rows.
    pub fn flops(&self, m: usize) -> f64 {
        self.plan.flops(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::dense_oracle;

    #[test]
    fn forward_matches_oracle_with_scale_and_prelu() {
        let w = TernaryMatrix::random(64, 32, 0.25, 3);
        let bias: Vec<f32> = (0..32).map(|i| i as f32 * 0.1).collect();
        let x = Matrix::random(4, 64, 4);
        let layer =
            TernaryLinear::new("interleaved_blocked_tcsc", &w, bias.clone(), 0.5, Some(0.25))
                .unwrap();
        let mut y = Matrix::zeros(4, 32);
        layer.forward(&x, &mut y);

        let mut want = dense_oracle(&x, &w, &bias);
        for v in want.as_mut_slice() {
            *v *= 0.5;
            if *v < 0.0 {
                *v *= 0.25;
            }
        }
        assert!(y.allclose(&want, 1e-4));
    }

    #[test]
    fn fused_and_unfused_prelu_agree() {
        let w = TernaryMatrix::random(48, 16, 0.5, 9);
        let bias = vec![0.1f32; 16];
        let x = Matrix::random(4, 48, 10);
        let fused =
            TernaryLinear::new("simd_vertical", &w, bias.clone(), 1.0, Some(0.25)).unwrap();
        let unfused =
            TernaryLinear::new("base_tcsc", &w, bias.clone(), 1.0, Some(0.25)).unwrap();
        assert!(fused.plan().fused_prelu());
        assert!(!unfused.plan().fused_prelu());
        let mut yf = Matrix::zeros(4, 16);
        let mut yu = Matrix::zeros(4, 16);
        fused.forward(&x, &mut yf);
        unfused.forward(&x, &mut yu);
        assert!(yf.allclose(&yu, 1e-4));
    }

    #[test]
    fn planned_layer_picks_a_kernel_and_matches_explicit() {
        let planner = Planner::new();
        let w = TernaryMatrix::random(64, 16, 0.25, 11);
        let bias = vec![0.05f32; 16];
        let x = Matrix::random(3, 64, 12);
        let auto = TernaryLinear::planned(
            &planner,
            &w,
            bias.clone(),
            1.0,
            None,
            &PlanHints::default(),
        )
        .unwrap();
        // 25% nonzeros, no fused PReLU wanted → the paper's best scalar.
        assert_eq!(auto.kernel_name(), "interleaved_blocked_tcsc");
        let explicit =
            TernaryLinear::new("interleaved_blocked_tcsc", &w, bias, 1.0, None).unwrap();
        let mut ya = Matrix::zeros(3, 16);
        let mut ye = Matrix::zeros(3, 16);
        auto.forward(&x, &mut ya);
        explicit.forward(&x, &mut ye);
        assert_eq!(ya, ye);
    }

    #[test]
    fn flops_model() {
        let w = TernaryMatrix::random(32, 8, 0.5, 1);
        let layer = TernaryLinear::new("base_tcsc", &w, vec![0.0; 8], 1.0, None).unwrap();
        let nnz = layer.nnz() as f64;
        assert_eq!(layer.flops(2), 2.0 * nnz + 16.0);
    }

    #[test]
    fn unknown_kernel_errors() {
        let w = TernaryMatrix::random(8, 4, 0.5, 1);
        assert!(TernaryLinear::new("bogus", &w, vec![0.0; 4], 1.0, None).is_err());
    }

    #[test]
    fn bias_mismatch_errors() {
        let w = TernaryMatrix::random(8, 4, 0.5, 1);
        assert!(TernaryLinear::new("base_tcsc", &w, vec![0.0; 3], 1.0, None).is_err());
    }
}
