//! Memory-bandwidth probe: a STREAM-style triad over a buffer much larger
//! than the LLC, yielding the bytes/cycle ceiling for the roofline model.
//! The paper infers memory-boundedness from the operational-intensity ↔
//! performance correspondence (Fig 10); with a measured bandwidth we can
//! draw the actual roofline and place each kernel on it.

use crate::perf::timer::CycleTimer;
use std::sync::OnceLock;

/// Measured sustained bandwidth, bytes/cycle (triad: a[i] = b[i] + s·c[i],
/// counting 3 × 4 bytes moved per element — write-allocate ignored, the
/// same accounting the paper's byte model uses).
pub fn host_bytes_per_cycle() -> f64 {
    static BW: OnceLock<f64> = OnceLock::new();
    *BW.get_or_init(|| {
        // 64 MiB working set — far beyond any L2/L3 slice we care about.
        const ELEMS: usize = 16 << 20;
        let mut a = vec![0.0f32; ELEMS];
        let b = vec![1.0f32; ELEMS];
        let c = vec![2.0f32; ELEMS];
        let timer = CycleTimer::new(1, 3);
        let s = std::hint::black_box(0.5f32);
        let m = timer.run(|| {
            for i in 0..ELEMS {
                a[i] = b[i] + s * c[i];
            }
            std::hint::black_box(&a);
        });
        let bytes = (ELEMS * 3 * std::mem::size_of::<f32>()) as f64;
        bytes / m.cycles
    })
}

/// Roofline for this host: measured scalar compute peak + measured
/// bandwidth.
pub fn host_roofline() -> crate::perf::roofline::Roofline {
    crate::perf::roofline::Roofline {
        peak_flops_per_cycle: crate::perf::roofline::host_peak_scalar_flops_per_cycle(),
        bytes_per_cycle: host_bytes_per_cycle(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bandwidth_plausible() {
        let bw = host_bytes_per_cycle();
        // Debug builds land well below release, but any machine moves
        // between 0.05 and 128 bytes/cycle on a 64 MiB triad.
        assert!(bw > 0.05 && bw < 128.0, "implausible bandwidth {bw}");
    }

    #[test]
    fn roofline_has_positive_ridge() {
        let r = host_roofline();
        assert!(r.ridge() > 0.0);
        assert!(r.attainable(0.01) <= r.attainable(100.0));
    }
}
