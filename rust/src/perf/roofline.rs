//! Roofline model: peak flops/cycle (paper: M1 scalar 4, vector 16) and a
//! *measured* host peak so percent-of-peak numbers are honest on this
//! machine rather than borrowed from Apple's.

use crate::perf::timer::CycleTimer;
use std::sync::OnceLock;

/// The paper's Apple M1 peak model.
pub const M1_SCALAR_PEAK: f64 = 4.0; // flops/cycle, scalar fadd
pub const M1_VECTOR_PEAK: f64 = 16.0; // flops/cycle, 4-lane NEON × 4 ports

/// Measure the host's scalar f32-add peak (flops/cycle) with a fully
/// unrolled independent-accumulator loop — the same instruction mix the
/// paper's cost model counts. Cached per process.
pub fn host_peak_scalar_flops_per_cycle() -> f64 {
    static PEAK: OnceLock<f64> = OnceLock::new();
    *PEAK.get_or_init(|| {
        const ITERS: usize = 2_000_000;
        const LANES: usize = 16; // enough independent chains to fill add ports
        let timer = CycleTimer::new(3, 7);
        let mut sink = 0.0f32;
        let m = timer.run(|| {
            let mut acc = [1.0f32; LANES];
            let x = std::hint::black_box(1.000_000_1f32);
            for _ in 0..ITERS {
                for a in &mut acc {
                    *a += x;
                }
            }
            sink = acc.iter().sum();
        });
        std::hint::black_box(sink);
        let flops = (ITERS * LANES) as f64;
        flops / m.cycles
    })
}

/// A simple two-ceiling roofline.
#[derive(Debug, Clone, Copy)]
pub struct Roofline {
    /// Compute ceiling, flops/cycle.
    pub peak_flops_per_cycle: f64,
    /// Memory ceiling, bytes/cycle.
    pub bytes_per_cycle: f64,
}

impl Roofline {
    /// Attainable performance at a given operational intensity (flops/byte).
    pub fn attainable(&self, op_intensity: f64) -> f64 {
        (self.bytes_per_cycle * op_intensity).min(self.peak_flops_per_cycle)
    }

    /// The ridge point: intensity above which the kernel is compute-bound.
    pub fn ridge(&self) -> f64 {
        self.peak_flops_per_cycle / self.bytes_per_cycle
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_peak_plausible() {
        let p = host_peak_scalar_flops_per_cycle();
        // Release builds land at 1–8 flops/cycle (superscalar + possible
        // autovectorization of the probe loop); debug builds are ~0.1.
        // Either way the probe must return something positive and finite.
        assert!(p > 0.01 && p < 64.0, "implausible peak {p}");
    }

    #[test]
    fn roofline_shape() {
        let r = Roofline {
            peak_flops_per_cycle: 4.0,
            bytes_per_cycle: 8.0,
        };
        assert_eq!(r.attainable(10.0), 4.0); // compute-bound
        assert_eq!(r.attainable(0.25), 2.0); // memory-bound
        assert!((r.ridge() - 0.5).abs() < 1e-12);
    }
}
