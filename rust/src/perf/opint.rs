//! Operational-intensity model (paper Fig 10).
//!
//! The paper estimates input data volume from the *exact size of the sparse
//! format* plus X, Y and the bias vector b, and divides flops by those
//! bytes. We reproduce that estimate analytically so Fig 10's heatmap can be
//! regenerated for any format.

use crate::perf::flops::CostModel;

/// Byte-volume inputs for the operational-intensity estimate.
#[derive(Debug, Clone, Copy)]
pub struct OpIntInputs {
    pub m: usize,
    pub k: usize,
    pub n: usize,
    pub sparsity: f32,
    /// Exact byte size of the sparse format (use `SparseFormat::bytes()`).
    pub format_bytes: usize,
}

/// Bytes touched once per GEMM under the paper's compulsory-traffic model:
/// the whole sparse format + X + Y + b, each counted once.
pub fn total_bytes(inp: &OpIntInputs) -> f64 {
    let f32s = std::mem::size_of::<f32>();
    let x = inp.m * inp.k * f32s;
    let y = inp.m * inp.n * f32s;
    let b = inp.n * f32s;
    (inp.format_bytes + x + y + b) as f64
}

/// Analytic TCSC format size: 2·(N+1) column pointers + nnz row indices,
/// all u32 (what the paper's Fig 10 uses).
pub fn format_bytes_model(k: usize, n: usize, sparsity: f32) -> usize {
    let u32s = std::mem::size_of::<u32>();
    let nnz = (sparsity as f64 * (k * n) as f64).round() as usize;
    2 * (n + 1) * u32s + nnz * u32s
}

/// Operational intensity (flops/byte) for the paper's cost + traffic models.
pub fn operational_intensity(inp: &OpIntInputs) -> f64 {
    let flops = CostModel::new(inp.m, inp.k, inp.n, inp.sparsity).flops();
    flops / total_bytes(inp)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn intensity_increases_with_m() {
        // More rows of X amortize the format traffic.
        let mk = |m| OpIntInputs {
            m,
            k: 4096,
            n: 1024,
            sparsity: 0.25,
            format_bytes: format_bytes_model(4096, 1024, 0.25),
        };
        assert!(operational_intensity(&mk(64)) > operational_intensity(&mk(1)));
    }

    #[test]
    fn intensity_increases_with_density() {
        // Paper Fig 10: denser (higher s) → higher op intensity → faster.
        let mk = |s| OpIntInputs {
            m: 64,
            k: 8192,
            n: 4096,
            sparsity: s,
            format_bytes: format_bytes_model(8192, 4096, s),
        };
        assert!(operational_intensity(&mk(0.5)) > operational_intensity(&mk(0.0625)));
    }

    #[test]
    fn format_bytes_counts_pointers_and_indices() {
        // K=4, N=4, s=0.5 → nnz=8: 2·5 ptrs ·4B + 8 idx ·4B = 72
        assert_eq!(format_bytes_model(4, 4, 0.5), 72);
    }
}
