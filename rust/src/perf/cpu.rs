//! Runtime CPU-capability detection: what the *host* can run, as opposed
//! to what the shape *wants* — the second dispatch dimension the planner
//! gained alongside the paper's (K, sparsity, M) heuristics.
//!
//! A [`CpuCaps`] snapshot carries the architecture, vector/matrix-unit
//! hints and (where probeable) cache sizes. Kernel registry rows declare
//! their requirements as a [`CpuFeature`] list
//! ([`crate::kernels::KernelDescriptor::requires`]); the planner, the
//! autotune sweep and the online top-2 race all filter candidates through
//! [`CpuCaps::satisfies`], so a NEON-gated kernel is *selectable* only
//! where the capability exists. Preparation stays host-agnostic — every
//! kernel in this crate has a portable implementation (the SIMD family's
//! [`crate::kernels::simd::F32x4`] is a NEON stand-in that LLVM lowers to
//! vector ops on any target), so tests and cross-compiled tools can always
//! *construct* a gated kernel; only *selection* is gated.
//!
//! Detection is compile-time `cfg!` for the architecture facts (NEON is
//! baseline AdvSIMD on aarch64; the AMX/SME-class matrix coprocessor is an
//! Apple Silicon macOS hint) plus a best-effort cache-size probe: Linux
//! sysfs, or `sysctlbyname` on macOS (`hw.l1dcachesize`, and
//! `hw.perflevel0.l2cachesize` — the P-core cluster's L2 on Apple
//! Silicon — falling back to the legacy `hw.l2cachesize`). Everything
//! degrades to `None`/`false` — a failed probe can only make fewer
//! kernels selectable (and blocking policy fall back to the paper's
//! fixed geometry), never pick a wrong one.

use std::sync::OnceLock;

/// A CPU capability a kernel row may require. Selection metadata: the
/// registry's capability filters compare a descriptor's `requires` list
/// against the host's [`CpuCaps`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CpuFeature {
    /// 128-bit NEON/AdvSIMD vector unit (baseline on aarch64).
    Neon,
    /// AMX/SME-class matrix-coprocessor hint (Apple Silicon under macOS):
    /// the regime where outer-product tile kernels change the
    /// operational-intensity picture. A *hint* because the unit is not
    /// directly user-visible; the heuristics treat it as "this host
    /// rewards tile-resident accumulation".
    MatrixUnitHint,
}

/// Snapshot of the host CPU's capabilities (or a synthetic one for tests
/// and what-if planning). `Copy` so planners can embed it by value.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CpuCaps {
    /// Target architecture (`"aarch64"`, `"x86_64"`, …).
    pub arch: &'static str,
    /// NEON/AdvSIMD available.
    pub neon: bool,
    /// AMX/SME-class matrix coprocessor likely present (Apple Silicon).
    pub matrix_unit_hint: bool,
    /// L1 data cache size in bytes, where probeable.
    pub l1d_bytes: Option<usize>,
    /// L2 cache size in bytes, where probeable.
    pub l2_bytes: Option<usize>,
}

impl CpuCaps {
    /// Probe the current host. Architecture facts are compile-time
    /// (`cfg!`); cache sizes come from sysfs on Linux and `sysctlbyname`
    /// on macOS, and are `None` elsewhere or on probe failure.
    pub fn detect() -> CpuCaps {
        let (l1d_bytes, l2_bytes) = probe_cache_sizes();
        CpuCaps {
            arch: std::env::consts::ARCH,
            neon: cfg!(target_arch = "aarch64"),
            matrix_unit_hint: cfg!(all(target_arch = "aarch64", target_os = "macos")),
            l1d_bytes,
            l2_bytes,
        }
    }

    /// The cached host snapshot (detection runs once per process).
    pub fn host() -> CpuCaps {
        static HOST: OnceLock<CpuCaps> = OnceLock::new();
        *HOST.get_or_init(CpuCaps::detect)
    }

    /// A synthetic capability set with no vector or matrix features — the
    /// "weakest host" tests use to assert capability-gated kernels drop
    /// out of candidate sets.
    pub fn scalar_only() -> CpuCaps {
        CpuCaps {
            arch: "test-scalar",
            neon: false,
            matrix_unit_hint: false,
            l1d_bytes: None,
            l2_bytes: None,
        }
    }

    /// A synthetic Apple-Silicon-like capability set (NEON + matrix-unit
    /// hint) for host-independent planner tests.
    pub fn apple_like() -> CpuCaps {
        CpuCaps {
            arch: "test-aarch64",
            neon: true,
            matrix_unit_hint: true,
            l1d_bytes: Some(128 * 1024),
            l2_bytes: Some(12 * 1024 * 1024),
        }
    }

    /// Whether this capability set provides `feature`.
    pub fn supports(&self, feature: CpuFeature) -> bool {
        match feature {
            CpuFeature::Neon => self.neon,
            CpuFeature::MatrixUnitHint => self.matrix_unit_hint,
        }
    }

    /// Whether every feature in `requires` is available — the predicate
    /// behind all capability-filtered candidate sets. An empty list is
    /// satisfied everywhere.
    pub fn satisfies(&self, requires: &[CpuFeature]) -> bool {
        requires.iter().all(|&f| self.supports(f))
    }
}

/// Parse a sysfs cache-size string (`"32K"`, `"8M"`, `"131072"`) into
/// bytes. Returns `None` for anything unrecognized.
pub(crate) fn parse_cache_size(s: &str) -> Option<usize> {
    let s = s.trim();
    if s.is_empty() {
        return None;
    }
    let (digits, mult) = match s.as_bytes()[s.len() - 1] {
        b'K' | b'k' => (&s[..s.len() - 1], 1024usize),
        b'M' | b'm' => (&s[..s.len() - 1], 1024 * 1024),
        b'G' | b'g' => (&s[..s.len() - 1], 1024 * 1024 * 1024),
        _ => (s, 1),
    };
    digits.parse::<usize>().ok().map(|v| v * mult)
}

/// Best-effort (L1d, L2) cache sizes for the current host: Linux sysfs or
/// macOS sysctl; `(None, None)` elsewhere or when the probe fails.
fn probe_cache_sizes() -> (Option<usize>, Option<usize>) {
    if cfg!(target_os = "macos") {
        // Block is cfg'd so non-macOS builds never reference the FFI
        // probe; the `cfg!` guard keeps it conditionally *reached* too,
        // so no unreachable-code fallthrough on macOS.
        #[cfg(target_os = "macos")]
        {
            return sysctl_cache_sizes();
        }
    }
    if cfg!(target_os = "linux") {
        sysfs_cache_sizes()
    } else {
        (None, None)
    }
}

/// (L1d, L2) from Linux sysfs; `(None, None)` when the hierarchy is
/// unreadable (also the non-Linux result — the paths only exist there).
fn sysfs_cache_sizes() -> (Option<usize>, Option<usize>) {
    let base = "/sys/devices/system/cpu/cpu0/cache";
    let read = |idx: usize, file: &str| -> Option<String> {
        std::fs::read_to_string(format!("{base}/index{idx}/{file}")).ok()
    };
    let mut l1d = None;
    let mut l2 = None;
    for idx in 0..8 {
        let (level, kind) = match (read(idx, "level"), read(idx, "type")) {
            (Some(level), Some(kind)) => (level, kind),
            _ => break,
        };
        let level = level.trim();
        let kind = kind.trim();
        let size = read(idx, "size").as_deref().and_then(parse_cache_size);
        if level == "1" && (kind == "Data" || kind == "Unified") && l1d.is_none() {
            l1d = size;
        }
        if level == "2" && (kind == "Data" || kind == "Unified") && l2.is_none() {
            l2 = size;
        }
    }
    (l1d, l2)
}

/// L1d key preference on macOS: one global key.
#[cfg_attr(not(target_os = "macos"), allow(dead_code))]
pub(crate) const SYSCTL_L1D_KEYS: [&str; 1] = ["hw.l1dcachesize"];

/// L2 key preference on macOS: the per-cluster `hw.perflevel0.l2cachesize`
/// (the performance cores' shared L2 on Apple Silicon — the cluster the
/// serving threads run on) first, then the legacy global `hw.l2cachesize`
/// reported by Intel Macs and older kernels.
#[cfg_attr(not(target_os = "macos"), allow(dead_code))]
pub(crate) const SYSCTL_L2_KEYS: [&str; 2] = ["hw.perflevel0.l2cachesize", "hw.l2cachesize"];

/// First `Some` result over an ordered key-preference list. Pure so the
/// fallback ordering is unit-testable on any host; the macOS probe passes
/// a real `sysctlbyname` lookup.
#[cfg_attr(not(target_os = "macos"), allow(dead_code))]
pub(crate) fn first_probed(
    keys: &[&str],
    lookup: impl Fn(&str) -> Option<usize>,
) -> Option<usize> {
    keys.iter().find_map(|&key| lookup(key))
}

/// (L1d, L2) from macOS `sysctlbyname`; each side independently degrades
/// to `None` when no key answers.
#[cfg(target_os = "macos")]
fn sysctl_cache_sizes() -> (Option<usize>, Option<usize>) {
    (
        first_probed(&SYSCTL_L1D_KEYS, sysctl_usize),
        first_probed(&SYSCTL_L2_KEYS, sysctl_usize),
    )
}

/// Read one integer sysctl by name. Declared directly (no libc
/// dependency): `sysctlbyname` is part of macOS's always-linked libSystem.
/// Integer sysctls are 4 or 8 bytes; reading into a zero-initialized u64
/// on a little-endian target (all macOS targets) handles both widths.
#[cfg(target_os = "macos")]
pub(crate) fn sysctl_usize(name: &str) -> Option<usize> {
    use std::ffi::{c_char, c_int, c_void};
    extern "C" {
        fn sysctlbyname(
            name: *const c_char,
            oldp: *mut c_void,
            oldlenp: *mut usize,
            newp: *mut c_void,
            newlen: usize,
        ) -> c_int;
    }
    let mut cname = Vec::with_capacity(name.len() + 1);
    cname.extend_from_slice(name.as_bytes());
    cname.push(0);
    let mut val: u64 = 0;
    let mut len = std::mem::size_of::<u64>();
    let rc = unsafe {
        sysctlbyname(
            cname.as_ptr() as *const c_char,
            &mut val as *mut u64 as *mut c_void,
            &mut len,
            std::ptr::null_mut(),
            0,
        )
    };
    if rc == 0 && len <= std::mem::size_of::<u64>() && val > 0 {
        Some(val as usize)
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_cache_size_units() {
        assert_eq!(parse_cache_size("32K"), Some(32 * 1024));
        assert_eq!(parse_cache_size("32K\n"), Some(32 * 1024));
        assert_eq!(parse_cache_size("8M"), Some(8 * 1024 * 1024));
        assert_eq!(parse_cache_size("1G"), Some(1024 * 1024 * 1024));
        assert_eq!(parse_cache_size("131072"), Some(131072));
        assert_eq!(parse_cache_size(""), None);
        assert_eq!(parse_cache_size("abc"), None);
        assert_eq!(parse_cache_size("K"), None);
    }

    #[test]
    fn satisfies_is_subset_check() {
        let scalar = CpuCaps::scalar_only();
        assert!(scalar.satisfies(&[]));
        assert!(!scalar.satisfies(&[CpuFeature::Neon]));
        assert!(!scalar.satisfies(&[CpuFeature::MatrixUnitHint]));
        let apple = CpuCaps::apple_like();
        assert!(apple.satisfies(&[]));
        assert!(apple.satisfies(&[CpuFeature::Neon]));
        assert!(apple.satisfies(&[CpuFeature::Neon, CpuFeature::MatrixUnitHint]));
        assert!(apple.supports(CpuFeature::Neon));
        assert!(!scalar.supports(CpuFeature::Neon));
    }

    #[test]
    fn sysctl_key_preference_order() {
        // Pure fallback logic, exercised on every host: perflevel0 L2 wins
        // when present, the legacy key answers when it is not, and a host
        // answering neither degrades to None.
        let apple = |key: &str| match key {
            "hw.l1dcachesize" => Some(128 * 1024),
            "hw.perflevel0.l2cachesize" => Some(12 * 1024 * 1024),
            "hw.l2cachesize" => Some(4 * 1024 * 1024), // E-cluster-ish value
            _ => None,
        };
        assert_eq!(first_probed(&SYSCTL_L1D_KEYS, apple), Some(128 * 1024));
        assert_eq!(
            first_probed(&SYSCTL_L2_KEYS, apple),
            Some(12 * 1024 * 1024),
            "perflevel0 key must shadow the legacy key"
        );
        let intel_mac = |key: &str| match key {
            "hw.l1dcachesize" => Some(32 * 1024),
            "hw.l2cachesize" => Some(256 * 1024),
            _ => None, // no perflevel keys pre-Apple-Silicon
        };
        assert_eq!(first_probed(&SYSCTL_L2_KEYS, intel_mac), Some(256 * 1024));
        let mute = |_: &str| None;
        assert_eq!(first_probed(&SYSCTL_L1D_KEYS, mute), None);
        assert_eq!(first_probed(&SYSCTL_L2_KEYS, mute), None);
    }

    #[test]
    fn host_detection_is_consistent_and_cached() {
        let a = CpuCaps::host();
        let b = CpuCaps::host();
        assert_eq!(a, b, "host snapshot is cached");
        assert_eq!(a, CpuCaps::detect().with_same_cache_probe(a));
        // Architecture facts agree with the compile target.
        assert_eq!(a.neon, cfg!(target_arch = "aarch64"));
        assert_eq!(
            a.matrix_unit_hint,
            cfg!(all(target_arch = "aarch64", target_os = "macos"))
        );
        assert_eq!(a.arch, std::env::consts::ARCH);
    }
}

#[cfg(test)]
impl CpuCaps {
    /// Test helper: `detect()` re-probes sysfs, which can legitimately
    /// race CPU hotplug; compare everything but the probed sizes.
    fn with_same_cache_probe(mut self, other: CpuCaps) -> CpuCaps {
        self.l1d_bytes = other.l1d_bytes;
        self.l2_bytes = other.l2_bytes;
        self
    }
}
