//! Runtime CPU-capability detection: what the *host* can run, as opposed
//! to what the shape *wants* — the second dispatch dimension the planner
//! gained alongside the paper's (K, sparsity, M) heuristics.
//!
//! A [`CpuCaps`] snapshot carries the architecture, vector/matrix-unit
//! hints and (where probeable) cache sizes. Kernel registry rows declare
//! their requirements as a [`CpuFeature`] list
//! ([`crate::kernels::KernelDescriptor::requires`]); the planner, the
//! autotune sweep and the online top-2 race all filter candidates through
//! [`CpuCaps::satisfies`], so a NEON-gated kernel is *selectable* only
//! where the capability exists. Preparation stays host-agnostic — every
//! kernel in this crate has a portable implementation (the SIMD family's
//! [`crate::kernels::simd::F32x4`] is a NEON stand-in that LLVM lowers to
//! vector ops on any target), so tests and cross-compiled tools can always
//! *construct* a gated kernel; only *selection* is gated.
//!
//! Detection is compile-time `cfg!` for the architecture facts (NEON is
//! baseline AdvSIMD on aarch64; the AMX/SME-class matrix coprocessor is an
//! Apple Silicon macOS hint) plus a best-effort Linux sysfs probe for
//! cache sizes. Everything degrades to `None`/`false` — a failed probe
//! can only make fewer kernels selectable, never a wrong one.

use std::sync::OnceLock;

/// A CPU capability a kernel row may require. Selection metadata: the
/// registry's capability filters compare a descriptor's `requires` list
/// against the host's [`CpuCaps`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CpuFeature {
    /// 128-bit NEON/AdvSIMD vector unit (baseline on aarch64).
    Neon,
    /// AMX/SME-class matrix-coprocessor hint (Apple Silicon under macOS):
    /// the regime where outer-product tile kernels change the
    /// operational-intensity picture. A *hint* because the unit is not
    /// directly user-visible; the heuristics treat it as "this host
    /// rewards tile-resident accumulation".
    MatrixUnitHint,
}

/// Snapshot of the host CPU's capabilities (or a synthetic one for tests
/// and what-if planning). `Copy` so planners can embed it by value.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CpuCaps {
    /// Target architecture (`"aarch64"`, `"x86_64"`, …).
    pub arch: &'static str,
    /// NEON/AdvSIMD available.
    pub neon: bool,
    /// AMX/SME-class matrix coprocessor likely present (Apple Silicon).
    pub matrix_unit_hint: bool,
    /// L1 data cache size in bytes, where probeable.
    pub l1d_bytes: Option<usize>,
    /// L2 cache size in bytes, where probeable.
    pub l2_bytes: Option<usize>,
}

impl CpuCaps {
    /// Probe the current host. Architecture facts are compile-time
    /// (`cfg!`); cache sizes come from sysfs on Linux and are `None`
    /// elsewhere or on probe failure.
    pub fn detect() -> CpuCaps {
        let (l1d_bytes, l2_bytes) = sysfs_cache_sizes();
        CpuCaps {
            arch: std::env::consts::ARCH,
            neon: cfg!(target_arch = "aarch64"),
            matrix_unit_hint: cfg!(all(target_arch = "aarch64", target_os = "macos")),
            l1d_bytes,
            l2_bytes,
        }
    }

    /// The cached host snapshot (detection runs once per process).
    pub fn host() -> CpuCaps {
        static HOST: OnceLock<CpuCaps> = OnceLock::new();
        *HOST.get_or_init(CpuCaps::detect)
    }

    /// A synthetic capability set with no vector or matrix features — the
    /// "weakest host" tests use to assert capability-gated kernels drop
    /// out of candidate sets.
    pub fn scalar_only() -> CpuCaps {
        CpuCaps {
            arch: "test-scalar",
            neon: false,
            matrix_unit_hint: false,
            l1d_bytes: None,
            l2_bytes: None,
        }
    }

    /// A synthetic Apple-Silicon-like capability set (NEON + matrix-unit
    /// hint) for host-independent planner tests.
    pub fn apple_like() -> CpuCaps {
        CpuCaps {
            arch: "test-aarch64",
            neon: true,
            matrix_unit_hint: true,
            l1d_bytes: Some(128 * 1024),
            l2_bytes: Some(12 * 1024 * 1024),
        }
    }

    /// Whether this capability set provides `feature`.
    pub fn supports(&self, feature: CpuFeature) -> bool {
        match feature {
            CpuFeature::Neon => self.neon,
            CpuFeature::MatrixUnitHint => self.matrix_unit_hint,
        }
    }

    /// Whether every feature in `requires` is available — the predicate
    /// behind all capability-filtered candidate sets. An empty list is
    /// satisfied everywhere.
    pub fn satisfies(&self, requires: &[CpuFeature]) -> bool {
        requires.iter().all(|&f| self.supports(f))
    }
}

/// Parse a sysfs cache-size string (`"32K"`, `"8M"`, `"131072"`) into
/// bytes. Returns `None` for anything unrecognized.
pub(crate) fn parse_cache_size(s: &str) -> Option<usize> {
    let s = s.trim();
    if s.is_empty() {
        return None;
    }
    let (digits, mult) = match s.as_bytes()[s.len() - 1] {
        b'K' | b'k' => (&s[..s.len() - 1], 1024usize),
        b'M' | b'm' => (&s[..s.len() - 1], 1024 * 1024),
        b'G' | b'g' => (&s[..s.len() - 1], 1024 * 1024 * 1024),
        _ => (s, 1),
    };
    digits.parse::<usize>().ok().map(|v| v * mult)
}

/// Best-effort (L1d, L2) cache sizes from Linux sysfs; `(None, None)`
/// elsewhere or when the hierarchy is unreadable.
fn sysfs_cache_sizes() -> (Option<usize>, Option<usize>) {
    if !cfg!(target_os = "linux") {
        return (None, None);
    }
    let base = "/sys/devices/system/cpu/cpu0/cache";
    let read = |idx: usize, file: &str| -> Option<String> {
        std::fs::read_to_string(format!("{base}/index{idx}/{file}")).ok()
    };
    let mut l1d = None;
    let mut l2 = None;
    for idx in 0..8 {
        let (level, kind) = match (read(idx, "level"), read(idx, "type")) {
            (Some(level), Some(kind)) => (level, kind),
            _ => break,
        };
        let level = level.trim();
        let kind = kind.trim();
        let size = read(idx, "size").as_deref().and_then(parse_cache_size);
        if level == "1" && (kind == "Data" || kind == "Unified") && l1d.is_none() {
            l1d = size;
        }
        if level == "2" && (kind == "Data" || kind == "Unified") && l2.is_none() {
            l2 = size;
        }
    }
    (l1d, l2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_cache_size_units() {
        assert_eq!(parse_cache_size("32K"), Some(32 * 1024));
        assert_eq!(parse_cache_size("32K\n"), Some(32 * 1024));
        assert_eq!(parse_cache_size("8M"), Some(8 * 1024 * 1024));
        assert_eq!(parse_cache_size("1G"), Some(1024 * 1024 * 1024));
        assert_eq!(parse_cache_size("131072"), Some(131072));
        assert_eq!(parse_cache_size(""), None);
        assert_eq!(parse_cache_size("abc"), None);
        assert_eq!(parse_cache_size("K"), None);
    }

    #[test]
    fn satisfies_is_subset_check() {
        let scalar = CpuCaps::scalar_only();
        assert!(scalar.satisfies(&[]));
        assert!(!scalar.satisfies(&[CpuFeature::Neon]));
        assert!(!scalar.satisfies(&[CpuFeature::MatrixUnitHint]));
        let apple = CpuCaps::apple_like();
        assert!(apple.satisfies(&[]));
        assert!(apple.satisfies(&[CpuFeature::Neon]));
        assert!(apple.satisfies(&[CpuFeature::Neon, CpuFeature::MatrixUnitHint]));
        assert!(apple.supports(CpuFeature::Neon));
        assert!(!scalar.supports(CpuFeature::Neon));
    }

    #[test]
    fn host_detection_is_consistent_and_cached() {
        let a = CpuCaps::host();
        let b = CpuCaps::host();
        assert_eq!(a, b, "host snapshot is cached");
        assert_eq!(a, CpuCaps::detect().with_same_cache_probe(a));
        // Architecture facts agree with the compile target.
        assert_eq!(a.neon, cfg!(target_arch = "aarch64"));
        assert_eq!(
            a.matrix_unit_hint,
            cfg!(all(target_arch = "aarch64", target_os = "macos"))
        );
        assert_eq!(a.arch, std::env::consts::ARCH);
    }
}

#[cfg(test)]
impl CpuCaps {
    /// Test helper: `detect()` re-probes sysfs, which can legitimately
    /// race CPU hotplug; compare everything but the probed sizes.
    fn with_same_cache_probe(mut self, other: CpuCaps) -> CpuCaps {
        self.l1d_bytes = other.l1d_bytes;
        self.l2_bytes = other.l2_bytes;
        self
    }
}
