//! CPU **topology** discovery: which logical cores exist, which of them
//! are performance vs efficiency cores, and which share an L2 — the
//! placement facts [`crate::util::affinity`] turns into core sets.
//!
//! Where [`crate::perf::cpu::CpuCaps`] answers *"what can this host
//! run?"* (instruction sets, cache sizes), [`CpuTopology`] answers
//! *"where should long-lived workers sit?"*. On Apple-Silicon-class
//! parts the scheduler will happily park a wavefront worker on an
//! efficiency core, and per-cluster L2 residency — not just kernel
//! quality — decides how close a GEMM gets to peak ("Above the Inner
//! Loop", PAPERS.md). The probe classifies cores into clusters so the
//! placement layer can pin pipeline workers to performance cores and
//! keep a band's repeat traffic inside the L2 that last touched its
//! prepared format.
//!
//! Probes, in the same spirit as the caps module:
//! - **Linux sysfs**: per-cpu `cpu_capacity` (heterogeneous parts expose
//!   relative DMIPS capacity; the max-capacity class is the performance
//!   class) and `cache/index*/shared_cpu_list` for L2 sharing.
//! - **macOS sysctl**: `hw.perflevel0.logicalcpu` /
//!   `hw.perflevel1.logicalcpu` (perflevel0 is the performance cluster).
//!   Core *ids* on macOS are nominal — placement there goes through QoS
//!   classes and affinity tags, never explicit cpu numbers.
//! - Everything else (and every probe failure) degrades to a **flat**
//!   topology: one performance cluster holding every core. A degraded
//!   probe can only make placement less specific, never wrong.
//!
//! All classification is pure over [`CoreProbe`] records, so checked-in
//! sysfs/sysctl fixture snapshots exercise the exact production path on
//! any host, and [`CpuTopology::apple_like`] / [`CpuTopology::flat`]
//! give tests host-independent synthetic topologies. The host probe is
//! cached process-wide like [`CpuCaps::host`].
//!
//! [`CpuCaps::host`]: crate::perf::cpu::CpuCaps::host

use std::sync::OnceLock;

/// Cluster classification: does this group of cores trade throughput
/// for efficiency?
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClusterKind {
    /// Max-capacity cores (P-cores on Apple Silicon; every core of a
    /// homogeneous part).
    Performance,
    /// Lower-capacity cores (E-cores). Placement policies spill here
    /// only after the performance clusters are full.
    Efficiency,
}

impl ClusterKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            ClusterKind::Performance => "performance",
            ClusterKind::Efficiency => "efficiency",
        }
    }
}

/// One classified group of cores (same capacity class; on parts that
/// expose L2 sharing, also one shared L2).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CoreCluster {
    pub kind: ClusterKind,
    /// Logical cpu ids, ascending.
    pub cores: Vec<usize>,
}

/// One probed logical core — the pure input to classification. `None`
/// fields mean the host did not expose that fact (typical x86 servers
/// have no `cpu_capacity`; many report only private per-core L2s).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CoreProbe {
    /// Logical cpu id.
    pub id: usize,
    /// Relative capacity (`cpu_capacity` sysfs scale, max 1024).
    pub capacity: Option<usize>,
    /// Cores sharing this core's L2 (parsed `shared_cpu_list`),
    /// including the core itself.
    pub l2_shared: Option<Vec<usize>>,
}

/// The host's core layout: clusters (performance first) plus the raw
/// shared-L2 groups. Built once via [`CpuTopology::host`], or
/// synthetically for host-independent tests.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CpuTopology {
    /// Classified clusters, performance clusters first, each sorted by
    /// first core id. Never empty; every core appears in exactly one.
    pub clusters: Vec<CoreCluster>,
    /// Probed shared-L2 core groups (singletons on private-L2 parts;
    /// one all-core group when the hierarchy is unreadable).
    pub l2_groups: Vec<Vec<usize>>,
}

impl CpuTopology {
    /// Probe the current host: sysfs on Linux, sysctl perflevels on
    /// macOS, flat `available_parallelism` everywhere else.
    pub fn detect() -> CpuTopology {
        let fallback = || {
            CpuTopology::flat(
                std::thread::available_parallelism()
                    .map(|n| n.get())
                    .unwrap_or(1),
            )
        };
        if cfg!(target_os = "macos") {
            #[cfg(target_os = "macos")]
            {
                if let Some(t) = sysctl_topology() {
                    return t;
                }
            }
            return fallback();
        }
        if cfg!(target_os = "linux") {
            if let Some(t) = sysfs_topology() {
                return t;
            }
        }
        fallback()
    }

    /// The cached host snapshot (detection runs once per process).
    pub fn host() -> &'static CpuTopology {
        static HOST: OnceLock<CpuTopology> = OnceLock::new();
        HOST.get_or_init(CpuTopology::detect)
    }

    /// Synthetic M1-like topology: 4 performance cores (ids 0–3, one
    /// shared L2) + 4 efficiency cores (ids 4–7, one shared L2).
    pub fn apple_like() -> CpuTopology {
        let probes: Vec<CoreProbe> = (0..8)
            .map(|id| CoreProbe {
                id,
                capacity: Some(if id < 4 { 1024 } else { 384 }),
                l2_shared: Some(if id < 4 {
                    vec![0, 1, 2, 3]
                } else {
                    vec![4, 5, 6, 7]
                }),
            })
            .collect();
        CpuTopology::from_probes(probes)
    }

    /// Synthetic homogeneous topology: `n` performance cores, one L2
    /// group (`n >= 1` enforced). What every unprobeable host becomes.
    pub fn flat(n: usize) -> CpuTopology {
        let n = n.max(1);
        let cores: Vec<usize> = (0..n).collect();
        CpuTopology {
            clusters: vec![CoreCluster {
                kind: ClusterKind::Performance,
                cores: cores.clone(),
            }],
            l2_groups: vec![cores],
        }
    }

    /// Classify probed cores into clusters. Pure — fixtures and the live
    /// sysfs probe share this path.
    ///
    /// Rules:
    /// - Cores with the maximum observed capacity (or no capacity at all
    ///   on homogeneous parts) are [`ClusterKind::Performance`]; every
    ///   lower capacity class is [`ClusterKind::Efficiency`].
    /// - Within a capacity class, multi-core shared-L2 groups split the
    ///   class into one cluster per group (the M-series shape). Private
    ///   per-core L2s (all-singleton groups, the x86 server shape) do
    ///   *not* shatter the class into per-core clusters.
    pub fn from_probes(mut probes: Vec<CoreProbe>) -> CpuTopology {
        if probes.is_empty() {
            return CpuTopology::flat(1);
        }
        probes.sort_by_key(|p| p.id);
        probes.dedup_by_key(|p| p.id);

        // Raw L2 groups: dedup the probed share lists; cores with no L2
        // info each form a singleton so the field stays total.
        let mut l2_groups: Vec<Vec<usize>> = Vec::new();
        for p in &probes {
            let mut group = p.l2_shared.clone().unwrap_or_else(|| vec![p.id]);
            group.sort_unstable();
            group.dedup();
            if !l2_groups.contains(&group) {
                l2_groups.push(group);
            }
        }
        l2_groups.sort_by_key(|g| g.first().copied().unwrap_or(0));

        // Capacity classes: unknown capacity counts as the maximum, so a
        // homogeneous part with no capacity files stays one class.
        let max_cap = probes.iter().filter_map(|p| p.capacity).max();
        let is_perf = |p: &CoreProbe| match (p.capacity, max_cap) {
            (Some(c), Some(m)) => c == m,
            _ => true,
        };
        let mut classes: Vec<(ClusterKind, Vec<usize>)> = Vec::new();
        let perf: Vec<usize> = probes.iter().filter(|p| is_perf(p)).map(|p| p.id).collect();
        if !perf.is_empty() {
            classes.push((ClusterKind::Performance, perf));
        }
        // Efficiency classes, one per distinct sub-max capacity value
        // (descending capacity so "closer to performance" sorts first).
        let mut eff_caps: Vec<usize> = probes
            .iter()
            .filter(|p| !is_perf(p))
            .filter_map(|p| p.capacity)
            .collect();
        eff_caps.sort_unstable_by(|a, b| b.cmp(a));
        eff_caps.dedup();
        for cap in eff_caps {
            let cores: Vec<usize> = probes
                .iter()
                .filter(|p| !is_perf(p) && p.capacity == Some(cap))
                .map(|p| p.id)
                .collect();
            classes.push((ClusterKind::Efficiency, cores));
        }

        // Split each class by multi-core L2 groups (when any exist).
        let mut clusters: Vec<CoreCluster> = Vec::new();
        for (kind, class_cores) in classes {
            let mut parts: Vec<Vec<usize>> = Vec::new();
            for group in &l2_groups {
                let members: Vec<usize> = group
                    .iter()
                    .copied()
                    .filter(|c| class_cores.contains(c))
                    .collect();
                if !members.is_empty() {
                    parts.push(members);
                }
            }
            let split = parts.len() > 1 && parts.iter().any(|p| p.len() > 1);
            if split {
                for cores in parts {
                    clusters.push(CoreCluster { kind, cores });
                }
            } else {
                clusters.push(CoreCluster {
                    kind,
                    cores: class_cores,
                });
            }
        }
        clusters.sort_by_key(|c| {
            (
                matches!(c.kind, ClusterKind::Efficiency),
                c.cores.first().copied().unwrap_or(0),
            )
        });
        CpuTopology { clusters, l2_groups }
    }

    /// Topology from macOS perflevel counts: `perf` performance cores
    /// then `eff` efficiency cores, each cluster one L2 group. Ids are
    /// nominal (macOS placement goes through QoS, not cpu numbers).
    pub fn from_perflevels(perf: usize, eff: usize) -> CpuTopology {
        let perf = if perf == 0 && eff == 0 { 1 } else { perf };
        let p_cores: Vec<usize> = (0..perf).collect();
        let e_cores: Vec<usize> = (perf..perf + eff).collect();
        let mut clusters = Vec::new();
        let mut l2_groups = Vec::new();
        if !p_cores.is_empty() {
            clusters.push(CoreCluster {
                kind: ClusterKind::Performance,
                cores: p_cores.clone(),
            });
            l2_groups.push(p_cores);
        }
        if !e_cores.is_empty() {
            clusters.push(CoreCluster {
                kind: ClusterKind::Efficiency,
                cores: e_cores.clone(),
            });
            l2_groups.push(e_cores);
        }
        CpuTopology { clusters, l2_groups }
    }

    /// Parse a checked-in sysfs snapshot: one line per core,
    /// `cpu<N> capacity=<v|-> l2=<list|->` (`-` = not exposed; `#`
    /// comments and blank lines skipped). Returns `None` when no line
    /// parses — fixtures and tests feed the result to
    /// [`CpuTopology::from_probes`].
    pub fn parse_sysfs_snapshot(text: &str) -> Option<Vec<CoreProbe>> {
        let mut probes = Vec::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut fields = line.split_whitespace();
            let id: usize = fields.next()?.strip_prefix("cpu")?.parse().ok()?;
            let mut capacity = None;
            let mut l2_shared = None;
            for field in fields {
                if let Some(v) = field.strip_prefix("capacity=") {
                    if v != "-" {
                        capacity = v.parse().ok();
                    }
                } else if let Some(v) = field.strip_prefix("l2=") {
                    if v != "-" {
                        l2_shared = parse_cpu_list(v);
                    }
                }
            }
            probes.push(CoreProbe {
                id,
                capacity,
                l2_shared,
            });
        }
        if probes.is_empty() {
            None
        } else {
            Some(probes)
        }
    }

    /// Parse a checked-in macOS sysctl snapshot (`sysctl hw.perflevel*`
    /// output: `hw.perflevel0.logicalcpu: 4` lines) into (perf, eff)
    /// counts. `perflevel0` is the performance level on Apple Silicon.
    pub fn parse_sysctl_snapshot(text: &str) -> Option<(usize, usize)> {
        let mut perf = None;
        let mut eff = None;
        for line in text.lines() {
            let line = line.trim();
            let (key, value) = match line.split_once(':') {
                Some((k, v)) => (k.trim(), v.trim()),
                None => continue,
            };
            let parsed = value.parse::<usize>().ok();
            match key {
                "hw.perflevel0.logicalcpu" => perf = parsed,
                "hw.perflevel1.logicalcpu" => eff = parsed,
                _ => {}
            }
        }
        perf.map(|p| (p, eff.unwrap_or(0)))
    }

    /// Total logical cores.
    pub fn num_cores(&self) -> usize {
        self.clusters.iter().map(|c| c.cores.len()).sum()
    }

    /// Performance-cluster cores, cluster order then id order.
    pub fn perf_cores(&self) -> Vec<usize> {
        self.cores_of(ClusterKind::Performance)
    }

    /// Efficiency-cluster cores, cluster order then id order.
    pub fn efficiency_cores(&self) -> Vec<usize> {
        self.cores_of(ClusterKind::Efficiency)
    }

    fn cores_of(&self, kind: ClusterKind) -> Vec<usize> {
        self.clusters
            .iter()
            .filter(|c| c.kind == kind)
            .flat_map(|c| c.cores.iter().copied())
            .collect()
    }

    /// Index of the cluster holding `core`, if any.
    pub fn cluster_of(&self, core: usize) -> Option<usize> {
        self.clusters.iter().position(|c| c.cores.contains(&core))
    }

    /// Compact one-line description for logs and `/status`.
    pub fn describe(&self) -> String {
        let parts: Vec<String> = self
            .clusters
            .iter()
            .map(|c| format!("{}x{}", c.cores.len(), &c.kind.as_str()[..4]))
            .collect();
        format!("{} cores ({})", self.num_cores(), parts.join("+"))
    }
}

/// Parse a sysfs cpu-list string (`"0-3,5,8-9"`) into ascending ids.
/// Returns `None` for anything unrecognized or empty.
pub(crate) fn parse_cpu_list(s: &str) -> Option<Vec<usize>> {
    let mut out = Vec::new();
    for part in s.trim().split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        match part.split_once('-') {
            Some((lo, hi)) => {
                let lo: usize = lo.trim().parse().ok()?;
                let hi: usize = hi.trim().parse().ok()?;
                if hi < lo || hi - lo > 4096 {
                    return None;
                }
                out.extend(lo..=hi);
            }
            None => out.push(part.parse().ok()?),
        }
    }
    out.sort_unstable();
    out.dedup();
    if out.is_empty() {
        None
    } else {
        Some(out)
    }
}

/// Probe Linux sysfs for per-cpu capacity and L2 sharing. `None` when
/// the cpu directory itself is unreadable (then the flat fallback
/// applies); individual missing files degrade per-core.
#[cfg_attr(not(target_os = "linux"), allow(dead_code))]
fn sysfs_topology() -> Option<CpuTopology> {
    let base = "/sys/devices/system/cpu";
    let mut probes = Vec::new();
    // `possible` is "0-N" on every modern kernel; fall back to probing
    // cpu0.. until a directory is missing.
    let ids: Vec<usize> = std::fs::read_to_string(format!("{base}/possible"))
        .ok()
        .as_deref()
        .and_then(parse_cpu_list)
        .unwrap_or_else(|| (0..1024).collect());
    for id in ids {
        let cpu_dir = format!("{base}/cpu{id}");
        if !std::path::Path::new(&cpu_dir).exists() {
            break;
        }
        let capacity = std::fs::read_to_string(format!("{cpu_dir}/cpu_capacity"))
            .ok()
            .and_then(|s| s.trim().parse().ok());
        let mut l2_shared = None;
        for idx in 0..8 {
            let level = std::fs::read_to_string(format!("{cpu_dir}/cache/index{idx}/level"));
            let level = match level {
                Ok(l) => l,
                Err(_) => break,
            };
            if level.trim() == "2" {
                l2_shared = std::fs::read_to_string(format!(
                    "{cpu_dir}/cache/index{idx}/shared_cpu_list"
                ))
                .ok()
                .as_deref()
                .and_then(parse_cpu_list);
                break;
            }
        }
        probes.push(CoreProbe {
            id,
            capacity,
            l2_shared,
        });
    }
    if probes.is_empty() {
        None
    } else {
        Some(CpuTopology::from_probes(probes))
    }
}

/// macOS perflevel probe (`hw.perflevel0/1.logicalcpu`). `None` when the
/// keys do not answer (Intel Macs answer only the total).
#[cfg(target_os = "macos")]
fn sysctl_topology() -> Option<CpuTopology> {
    let perf = crate::perf::cpu::sysctl_usize("hw.perflevel0.logicalcpu")?;
    let eff = crate::perf::cpu::sysctl_usize("hw.perflevel1.logicalcpu").unwrap_or(0);
    Some(CpuTopology::from_perflevels(perf, eff))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_cpu_list_forms() {
        assert_eq!(parse_cpu_list("0-3"), Some(vec![0, 1, 2, 3]));
        assert_eq!(parse_cpu_list("0-1,4,6-7\n"), Some(vec![0, 1, 4, 6, 7]));
        assert_eq!(parse_cpu_list("5"), Some(vec![5]));
        assert_eq!(parse_cpu_list(""), None);
        assert_eq!(parse_cpu_list("3-1"), None);
        assert_eq!(parse_cpu_list("x"), None);
    }

    #[test]
    fn apple_like_classifies_two_clusters() {
        let t = CpuTopology::apple_like();
        assert_eq!(t.num_cores(), 8);
        assert_eq!(t.clusters.len(), 2);
        assert_eq!(t.clusters[0].kind, ClusterKind::Performance);
        assert_eq!(t.clusters[0].cores, vec![0, 1, 2, 3]);
        assert_eq!(t.clusters[1].kind, ClusterKind::Efficiency);
        assert_eq!(t.clusters[1].cores, vec![4, 5, 6, 7]);
        assert_eq!(t.perf_cores(), vec![0, 1, 2, 3]);
        assert_eq!(t.efficiency_cores(), vec![4, 5, 6, 7]);
        assert_eq!(t.cluster_of(2), Some(0));
        assert_eq!(t.cluster_of(6), Some(1));
        assert_eq!(t.cluster_of(99), None);
        assert_eq!(t.l2_groups.len(), 2);
    }

    #[test]
    fn flat_is_one_performance_cluster() {
        let t = CpuTopology::flat(6);
        assert_eq!(t.clusters.len(), 1);
        assert_eq!(t.clusters[0].kind, ClusterKind::Performance);
        assert_eq!(t.perf_cores(), vec![0, 1, 2, 3, 4, 5]);
        assert!(t.efficiency_cores().is_empty());
        // Degenerate input stays usable.
        assert_eq!(CpuTopology::flat(0).num_cores(), 1);
    }

    #[test]
    fn probes_without_capacity_are_one_performance_class() {
        // x86-server shape: no cpu_capacity, private per-core L2s. Must
        // NOT shatter into per-core clusters.
        let probes: Vec<CoreProbe> = (0..4)
            .map(|id| CoreProbe {
                id,
                capacity: None,
                l2_shared: Some(vec![id]),
            })
            .collect();
        let t = CpuTopology::from_probes(probes);
        assert_eq!(t.clusters.len(), 1);
        assert_eq!(t.clusters[0].kind, ClusterKind::Performance);
        assert_eq!(t.clusters[0].cores, vec![0, 1, 2, 3]);
        assert_eq!(t.l2_groups.len(), 4, "private L2s stay visible");
    }

    #[test]
    fn multi_core_l2_groups_split_a_class() {
        // One capacity class spanning two shared-L2 complexes (the
        // AMD-CCX-like shape) → two performance clusters.
        let probes: Vec<CoreProbe> = (0..8)
            .map(|id| CoreProbe {
                id,
                capacity: Some(1024),
                l2_shared: Some(if id < 4 {
                    vec![0, 1, 2, 3]
                } else {
                    vec![4, 5, 6, 7]
                }),
            })
            .collect();
        let t = CpuTopology::from_probes(probes);
        assert_eq!(t.clusters.len(), 2);
        assert!(t.clusters.iter().all(|c| c.kind == ClusterKind::Performance));
        assert_eq!(t.clusters[0].cores, vec![0, 1, 2, 3]);
        assert_eq!(t.clusters[1].cores, vec![4, 5, 6, 7]);
    }

    #[test]
    fn sysfs_snapshot_roundtrip() {
        let text = "# comment\ncpu0 capacity=1024 l2=0-1\ncpu1 capacity=1024 l2=0-1\n\
                    cpu2 capacity=384 l2=2-3\ncpu3 capacity=384 l2=2-3\n";
        let probes = CpuTopology::parse_sysfs_snapshot(text).unwrap();
        assert_eq!(probes.len(), 4);
        assert_eq!(probes[0].capacity, Some(1024));
        assert_eq!(probes[3].l2_shared, Some(vec![2, 3]));
        let t = CpuTopology::from_probes(probes);
        assert_eq!(t.perf_cores(), vec![0, 1]);
        assert_eq!(t.efficiency_cores(), vec![2, 3]);
        // Dashes mean "not exposed".
        let bare = CpuTopology::parse_sysfs_snapshot("cpu0 capacity=- l2=-").unwrap();
        assert_eq!(bare[0].capacity, None);
        assert_eq!(bare[0].l2_shared, None);
        assert_eq!(CpuTopology::parse_sysfs_snapshot("junk"), None);
    }

    #[test]
    fn sysctl_snapshot_parses_perflevels() {
        let text = "hw.perflevel0.logicalcpu: 4\nhw.perflevel1.logicalcpu: 4\n";
        assert_eq!(CpuTopology::parse_sysctl_snapshot(text), Some((4, 4)));
        let t = {
            let (p, e) = CpuTopology::parse_sysctl_snapshot(text).unwrap();
            CpuTopology::from_perflevels(p, e)
        };
        assert_eq!(t.perf_cores(), vec![0, 1, 2, 3]);
        assert_eq!(t.efficiency_cores(), vec![4, 5, 6, 7]);
        // Intel Macs: no perflevel keys at all.
        assert_eq!(
            CpuTopology::parse_sysctl_snapshot("hw.logicalcpu: 8"),
            None
        );
        // P-only parts still classify.
        let only_p = CpuTopology::from_perflevels(6, 0);
        assert_eq!(only_p.clusters.len(), 1);
        assert_eq!(only_p.num_cores(), 6);
    }

    #[test]
    fn host_detection_is_cached_and_total() {
        let a = CpuTopology::host();
        let b = CpuTopology::host();
        assert!(std::ptr::eq(a, b), "host snapshot is cached");
        assert!(a.num_cores() >= 1);
        assert!(!a.clusters.is_empty());
        // Every core belongs to exactly one cluster.
        let mut all: Vec<usize> = a
            .clusters
            .iter()
            .flat_map(|c| c.cores.iter().copied())
            .collect();
        let n = all.len();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), n, "no core in two clusters");
        assert!(!a.describe().is_empty());
    }
}
