//! The paper's floating-point cost model.
//!
//! Multiplications by ±1 are executed as additions/subtractions, so the cost
//! metric is the count of f32 adds:
//!
//! ```text
//! C(M, K, N, s) = M · N · (1 + s·K)
//! ```
//!
//! — `s·K` adds per output element for the nonzeros plus one add for the
//! bias. PReLU-fused kernels add `M·N` extra flops (one multiply per
//! element on the negative branch; the paper counts adds and muls equally).

/// Paper cost model inputs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostModel {
    pub m: usize,
    pub k: usize,
    pub n: usize,
    /// Fraction of nonzero entries in W (the paper calls this "sparsity").
    pub sparsity: f32,
    /// Whether PReLU is fused (adds one flop per output element).
    pub prelu: bool,
}

impl CostModel {
    pub fn new(m: usize, k: usize, n: usize, sparsity: f32) -> Self {
        CostModel {
            m,
            k,
            n,
            sparsity,
            prelu: false,
        }
    }

    pub fn with_prelu(mut self) -> Self {
        self.prelu = true;
        self
    }

    /// Total flops by the paper's model.
    pub fn flops(&self) -> f64 {
        let base = self.m as f64 * self.n as f64 * (1.0 + self.sparsity as f64 * self.k as f64);
        if self.prelu {
            base + (self.m * self.n) as f64
        } else {
            base
        }
    }

    /// Flops computed from an *actual* nonzero count rather than the nominal
    /// sparsity (exact generators make these equal; quantized real weights
    /// may not be).
    pub fn flops_exact(&self, nnz: usize) -> f64 {
        // Each nonzero contributes M adds; bias contributes M·N adds.
        let base = self.m as f64 * nnz as f64 + (self.m * self.n) as f64;
        if self.prelu {
            base + (self.m * self.n) as f64
        } else {
            base
        }
    }
}

/// Convenience: `C(M,K,N,s)` directly.
pub fn cost_flops(m: usize, k: usize, n: usize, sparsity: f32) -> f64 {
    CostModel::new(m, k, n, sparsity).flops()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_paper_formula() {
        // M=64, K=8192, N=4096, s=0.5 → 64·4096·(1+4096)
        let c = cost_flops(64, 8192, 4096, 0.5);
        assert_eq!(c, 64.0 * 4096.0 * (1.0 + 0.5 * 8192.0));
    }

    #[test]
    fn exact_equals_model_for_exact_nnz() {
        let (m, k, n, s) = (8, 1024, 256, 0.25);
        let model = CostModel::new(m, k, n, s);
        let nnz = (s as f64 * (k * n) as f64).round() as usize;
        assert_eq!(model.flops(), model.flops_exact(nnz));
    }

    #[test]
    fn prelu_adds_mn() {
        let a = CostModel::new(4, 128, 32, 0.5);
        let b = a.with_prelu();
        assert_eq!(b.flops() - a.flops(), (4 * 32) as f64);
    }

    #[test]
    fn zero_sparsity_is_bias_only() {
        assert_eq!(cost_flops(3, 999, 5, 0.0), 15.0);
    }
}
