//! Performance measurement substrate: cycle counters, the paper's flop cost
//! model, operational-intensity estimates and a roofline model.
//!
//! The paper reports *flops/cycle* against Apple M1's scalar peak of 4
//! flops/cycle (16 vectorized). We reproduce the metric on x86-64 via a
//! calibrated `rdtsc` (see [`timer`]) and report both the paper's M1 peak
//! model and a measured host peak (see [`roofline`]).

pub mod timer;
pub mod flops;
pub mod opint;
pub mod roofline;
pub mod membw;
pub mod cpu;
pub mod blocking;
pub mod topology;

pub use blocking::{geometry_candidates, scalar_block, tile_geometry, BlockingPolicy};
pub use cpu::{CpuCaps, CpuFeature};
pub use topology::{ClusterKind, CoreCluster, CoreProbe, CpuTopology};
pub use timer::{cycles_per_second, read_cycles, CycleTimer, Measurement};
pub use flops::{cost_flops, CostModel};
pub use opint::{format_bytes_model, operational_intensity, OpIntInputs};
pub use roofline::{host_peak_scalar_flops_per_cycle, Roofline};
