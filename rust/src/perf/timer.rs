//! Cycle-accurate timing.
//!
//! The paper's metric is flops/cycle. On x86-64 we read the TSC (constant-
//! rate on every CPU from the last decade) and calibrate it against
//! `std::time::Instant` once per process to obtain cycles/second. On other
//! architectures we fall back to nanosecond timing scaled by the calibrated
//! frequency (identity fallback of 1 GHz if no TSC).

use std::sync::OnceLock;
use std::time::Instant;

/// Read the cycle counter (TSC on x86-64; nanoseconds elsewhere).
#[inline]
pub fn read_cycles() -> u64 {
    #[cfg(target_arch = "x86_64")]
    unsafe {
        core::arch::x86_64::_rdtsc()
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        // Monotonic ns as a stand-in "cycle"; cycles_per_second() returns
        // 1e9 for consistency.
        static START: OnceLock<Instant> = OnceLock::new();
        let start = START.get_or_init(Instant::now);
        start.elapsed().as_nanos() as u64
    }
}

/// Calibrated TSC frequency (cycles per second), measured once per process.
pub fn cycles_per_second() -> f64 {
    static HZ: OnceLock<f64> = OnceLock::new();
    *HZ.get_or_init(|| {
        #[cfg(not(target_arch = "x86_64"))]
        {
            return 1e9;
        }
        #[cfg(target_arch = "x86_64")]
        {
            // Median of several short calibration windows to reject noise.
            let mut rates = Vec::with_capacity(5);
            for _ in 0..5 {
                let t0 = Instant::now();
                let c0 = read_cycles();
                while t0.elapsed().as_micros() < 20_000 {
                    std::hint::spin_loop();
                }
                let c1 = read_cycles();
                let dt = t0.elapsed().as_secs_f64();
                rates.push((c1 - c0) as f64 / dt);
            }
            rates.sort_by(|a, b| a.partial_cmp(b).unwrap());
            rates[rates.len() / 2]
        }
    })
}

/// One timed run: cycles and wall seconds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Measurement {
    pub cycles: f64,
    pub seconds: f64,
}

impl Measurement {
    pub fn flops_per_cycle(&self, flops: f64) -> f64 {
        flops / self.cycles
    }

    pub fn gflops_per_second(&self, flops: f64) -> f64 {
        flops / self.seconds / 1e9
    }
}

/// Warmup + repetition measurement loop (median-of-reps, the protocol the
/// paper's course infrastructure uses and what criterion would do for us).
pub struct CycleTimer {
    pub warmup: usize,
    pub reps: usize,
}

impl Default for CycleTimer {
    fn default() -> Self {
        CycleTimer { warmup: 2, reps: 7 }
    }
}

impl CycleTimer {
    pub fn new(warmup: usize, reps: usize) -> Self {
        CycleTimer {
            warmup,
            reps: reps.max(1),
        }
    }

    /// Time `f`, returning the median measurement across reps.
    pub fn run<F: FnMut()>(&self, f: F) -> Measurement {
        self.run_stats(f).0
    }

    /// Time `f`, returning the median measurement across reps **and** the
    /// coefficient of variation (sample σ/μ) of the per-rep cycle counts
    /// — the run-to-run noise signal `autotune sweep` calibrates its
    /// per-M divergence threshold against. The CV is 0 for a single rep
    /// (no spread to measure).
    pub fn run_stats<F: FnMut()>(&self, mut f: F) -> (Measurement, f64) {
        for _ in 0..self.warmup {
            f();
        }
        let mut cycles: Vec<f64> = Vec::with_capacity(self.reps);
        let mut secs: Vec<f64> = Vec::with_capacity(self.reps);
        for _ in 0..self.reps {
            let t0 = Instant::now();
            let c0 = read_cycles();
            f();
            let c1 = read_cycles();
            cycles.push((c1.wrapping_sub(c0)) as f64);
            secs.push(t0.elapsed().as_secs_f64());
        }
        let mean = cycles.iter().sum::<f64>() / cycles.len() as f64;
        let cv = if cycles.len() > 1 && mean > 0.0 {
            let var = cycles.iter().map(|c| (c - mean).powi(2)).sum::<f64>()
                / (cycles.len() - 1) as f64;
            var.sqrt() / mean
        } else {
            0.0
        };
        cycles.sort_by(|a, b| a.partial_cmp(b).unwrap());
        secs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        (
            Measurement {
                cycles: cycles[cycles.len() / 2],
                seconds: secs[secs.len() / 2],
            },
            cv,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cycles_monotone() {
        let a = read_cycles();
        let b = read_cycles();
        assert!(b >= a);
    }

    #[test]
    fn calibration_plausible() {
        let hz = cycles_per_second();
        // Any machine this runs on clocks between 0.5 and 8 GHz.
        assert!(hz > 5e8 && hz < 8e9, "implausible TSC rate {hz}");
    }

    #[test]
    fn timer_measures_work() {
        let timer = CycleTimer::new(1, 3);
        let mut acc = 0.0f64;
        let m = timer.run(|| {
            for i in 0..100_000 {
                acc += (i as f64).sqrt();
            }
        });
        std::hint::black_box(acc);
        assert!(m.cycles > 1000.0, "100k sqrts must cost >1k cycles");
        assert!(m.seconds > 0.0);
    }

    #[test]
    fn run_stats_reports_spread() {
        // Multi-rep runs report a finite, non-negative CV; a single rep
        // has no spread to measure.
        let timer = CycleTimer::new(0, 5);
        let mut acc = 0.0f64;
        let (m, cv) = timer.run_stats(|| {
            for i in 0..10_000 {
                acc += (i as f64).sqrt();
            }
        });
        std::hint::black_box(acc);
        assert!(m.cycles > 0.0);
        assert!(cv.is_finite() && cv >= 0.0, "cv={cv}");
        let single = CycleTimer::new(0, 1);
        let (_, cv1) = single.run_stats(|| {
            acc += 1.0;
        });
        assert_eq!(cv1, 0.0);
    }

    #[test]
    fn flops_per_cycle_math() {
        let m = Measurement {
            cycles: 1000.0,
            seconds: 1e-6,
        };
        assert!((m.flops_per_cycle(4000.0) - 4.0).abs() < 1e-12);
        assert!((m.gflops_per_second(4000.0) - 4.0).abs() < 1e-9);
    }
}
