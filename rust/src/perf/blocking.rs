//! Cache-hierarchy-driven blocking policy: the bridge between the
//! [`CpuCaps`] L1d/L2 probes and the geometry knobs the kernels expose.
//!
//! Two families consume blocking decisions:
//!
//! * the **blocked/interleaved scalar formats** take a K-block length
//!   (`KernelParams::block_size`) that bounds how much of X a block walk
//!   re-touches;
//! * the **outer-product tile family** takes a [`TileGeometry`] — panel
//!   width and K-slice length — carried in the `TilePanelTcsc` header.
//!
//! The sizing rule is the same for both: a K-block of `B` rows keeps
//! `B` staged X values per M-row lane hot, i.e. `B · OUTER_TILE · 4`
//! bytes for the tile kernels' transposed X tile. Targeting **half of
//! L1d** for that working set (the other half absorbs the entry streams
//! and the output tile) gives `B = l1d_bytes / 32`, floored to a power
//! of two so block boundaries stay aligned, and clamped to sane bounds.
//! On the paper's M1 (128 KiB L1d per P-core) this lands exactly on the
//! paper's hand-picked block of 4096.
//!
//! Every probe degrades to a **documented fixed fallback** when the cache
//! size is `None` (no sysfs/sysctl on this host): the scalar block falls
//! back to [`crate::PAPER_BLOCK_SIZE`], the tile geometry to
//! [`TileGeometry::DEFAULT`] (4-wide panels, unblocked K) — i.e. exactly
//! the pre-policy behaviour, so an unprobeable host never regresses.
//!
//! Selection-time only: the policy feeds the planner's parameter
//! defaults, the plan-cache race and the `--geometry` sweep grid. Kernel
//! *preparation* stays host-agnostic — any geometry can be built
//! anywhere; this module only decides which ones are worth building.

use crate::formats::{TileGeometry, MAX_PANEL_WIDTH, OUTER_TILE};
use crate::perf::CpuCaps;

/// Lower clamp for cache-derived scalar K-blocks: below this the
/// per-block bookkeeping dominates the walk.
pub const MIN_SCALAR_BLOCK: usize = 512;
/// Upper clamp for cache-derived scalar K-blocks: beyond this the block
/// no longer fits any plausible L1d and the policy is extrapolating.
pub const MAX_SCALAR_BLOCK: usize = 16384;
/// Clamp bounds for the tile family's K-slices (tighter than the scalar
/// family's: the tile walk also keeps an accumulator tile live).
pub const MIN_TILE_K_BLOCK: usize = 256;
/// See [`MIN_TILE_K_BLOCK`].
pub const MAX_TILE_K_BLOCK: usize = 8192;
/// L1d threshold above which 8-wide panels are the default: doubling the
/// live accumulators only pays when the wider streamed working set still
/// fits comfortably.
pub const WIDE_PANEL_L1D_BYTES: usize = 96 * 1024;
/// Fallback K-slice used for the *candidate grid* (not the default pick)
/// when L1d is unprobeable — keeps `--geometry` sweeps meaningful on
/// hosts with no cache probe.
pub const FALLBACK_TILE_K_BLOCK: usize = 1024;

/// A host's derived blocking decisions. Built once per selection site
/// from a [`CpuCaps`] snapshot (synthetic ones in tests).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BlockingPolicy {
    /// K-block length for the blocked/interleaved scalar formats.
    pub scalar_block: usize,
    /// Preferred geometry for the outer-product tile family.
    pub geometry: TileGeometry,
}

impl BlockingPolicy {
    /// Derive the policy from a capability snapshot. Pure: same caps in,
    /// same policy out — property tests sweep synthetic extremes.
    pub fn for_caps(caps: &CpuCaps) -> BlockingPolicy {
        BlockingPolicy {
            scalar_block: scalar_block(caps),
            geometry: tile_geometry(caps),
        }
    }
}

/// Largest power of two ≤ `v` (v ≥ 1).
fn prev_power_of_two(v: usize) -> usize {
    debug_assert!(v >= 1);
    let mut p = 1usize;
    while p * 2 <= v {
        p *= 2;
    }
    p
}

/// Half-of-L1d sizing rule shared by both families; see module docs.
fn l1d_block(l1d_bytes: usize, min: usize, max: usize) -> usize {
    let floats_per_row = OUTER_TILE * std::mem::size_of::<f32>() * 2; // = 32
    let raw = (l1d_bytes / floats_per_row).max(1);
    prev_power_of_two(raw).clamp(min, max)
}

/// K-block length for the blocked/interleaved scalar families:
/// `l1d / 32` pow2-floored into `[MIN_SCALAR_BLOCK, MAX_SCALAR_BLOCK]`,
/// or [`crate::PAPER_BLOCK_SIZE`] when L1d is unprobeable. 128 KiB L1d
/// (Apple P-core) ⇒ 4096 — the paper's pick.
pub fn scalar_block(caps: &CpuCaps) -> usize {
    match caps.l1d_bytes {
        Some(l1d) => l1d_block(l1d, MIN_SCALAR_BLOCK, MAX_SCALAR_BLOCK),
        None => crate::PAPER_BLOCK_SIZE,
    }
}

/// Preferred tile geometry: 8-wide panels on large-L1d hosts, K-slices
/// sized by the same half-of-L1d rule; [`TileGeometry::DEFAULT`] when
/// L1d is unprobeable.
pub fn tile_geometry(caps: &CpuCaps) -> TileGeometry {
    match caps.l1d_bytes {
        Some(l1d) => TileGeometry {
            panel_width: if l1d >= WIDE_PANEL_L1D_BYTES {
                MAX_PANEL_WIDTH
            } else {
                OUTER_TILE
            },
            k_block: l1d_block(l1d, MIN_TILE_K_BLOCK, MAX_TILE_K_BLOCK),
        },
        None => TileGeometry::DEFAULT,
    }
}

/// The candidate grid a geometry sweep or race measures: both panel
/// widths × {unblocked, cache-derived K-slice}. Deterministic order,
/// default geometry first, no duplicates. Small by construction (≤ 4) —
/// the grid multiplies per-kernel measurement cost.
pub fn geometry_candidates(caps: &CpuCaps) -> Vec<TileGeometry> {
    let derived = match caps.l1d_bytes {
        Some(l1d) => l1d_block(l1d, MIN_TILE_K_BLOCK, MAX_TILE_K_BLOCK),
        None => FALLBACK_TILE_K_BLOCK,
    };
    let mut out = Vec::with_capacity(4);
    for width in [OUTER_TILE, MAX_PANEL_WIDTH] {
        for kb in [0, derived] {
            let g = TileGeometry::new(width, kb);
            if !out.contains(&g) {
                out.push(g);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn caps_with_l1d(l1d: Option<usize>) -> CpuCaps {
        let mut caps = CpuCaps::scalar_only();
        caps.l1d_bytes = l1d;
        caps
    }

    #[test]
    fn unprobeable_hosts_get_paper_fallbacks() {
        let caps = CpuCaps::scalar_only();
        assert_eq!(scalar_block(&caps), crate::PAPER_BLOCK_SIZE);
        assert_eq!(tile_geometry(&caps), TileGeometry::DEFAULT);
        let policy = BlockingPolicy::for_caps(&caps);
        assert_eq!(policy.scalar_block, crate::PAPER_BLOCK_SIZE);
        assert_eq!(policy.geometry, TileGeometry::DEFAULT);
    }

    #[test]
    fn apple_like_l1d_reproduces_the_paper_block() {
        // 128 KiB L1d / 32 = 4096 — the half-of-L1d rule lands exactly on
        // the paper's hand-picked block, by design.
        let caps = CpuCaps::apple_like();
        assert_eq!(scalar_block(&caps), crate::PAPER_BLOCK_SIZE);
        let g = tile_geometry(&caps);
        assert_eq!(g.panel_width, MAX_PANEL_WIDTH);
        assert_eq!(g.k_block, 4096);
        g.validate().unwrap();
    }

    #[test]
    fn tiny_l1d_clamps_low_and_stays_narrow() {
        let caps = caps_with_l1d(Some(4 * 1024)); // 4 KiB: embedded-class
        assert_eq!(scalar_block(&caps), MIN_SCALAR_BLOCK);
        let g = tile_geometry(&caps);
        assert_eq!(g.panel_width, OUTER_TILE, "small L1d keeps narrow panels");
        assert_eq!(g.k_block, MIN_TILE_K_BLOCK);
    }

    #[test]
    fn huge_l1d_clamps_high() {
        let caps = caps_with_l1d(Some(64 * 1024 * 1024));
        assert_eq!(scalar_block(&caps), MAX_SCALAR_BLOCK);
        assert_eq!(tile_geometry(&caps).k_block, MAX_TILE_K_BLOCK);
    }

    #[test]
    fn non_pow2_l1d_floors_to_aligned_block() {
        // 96 KiB / 32 = 3072 → pow2 floor 2048.
        let caps = caps_with_l1d(Some(96 * 1024));
        assert_eq!(scalar_block(&caps), 2048);
        let g = tile_geometry(&caps);
        assert_eq!(g.k_block, 2048);
        assert_eq!(g.panel_width, MAX_PANEL_WIDTH, "96 KiB is the wide threshold");
    }

    #[test]
    fn candidate_grid_is_small_deduped_and_default_first() {
        for caps in [
            CpuCaps::scalar_only(),
            CpuCaps::apple_like(),
            caps_with_l1d(Some(4 * 1024)),
        ] {
            let grid = geometry_candidates(&caps);
            assert_eq!(grid[0], TileGeometry::DEFAULT, "default geometry leads");
            assert!(grid.len() <= 4);
            for g in &grid {
                g.validate().unwrap();
                assert_eq!(grid.iter().filter(|h| *h == g).count(), 1, "dup {g}");
            }
            // Both widths are always represented.
            assert!(grid.iter().any(|g| g.panel_width == OUTER_TILE));
            assert!(grid.iter().any(|g| g.panel_width == MAX_PANEL_WIDTH));
        }
        // Unprobeable hosts still get a nontrivial K-blocked candidate.
        let grid = geometry_candidates(&CpuCaps::scalar_only());
        assert!(grid.iter().any(|g| g.k_block == FALLBACK_TILE_K_BLOCK));
    }

    #[test]
    fn prev_power_of_two_floors() {
        assert_eq!(prev_power_of_two(1), 1);
        assert_eq!(prev_power_of_two(2), 2);
        assert_eq!(prev_power_of_two(3), 2);
        assert_eq!(prev_power_of_two(4096), 4096);
        assert_eq!(prev_power_of_two(6000), 4096);
    }
}
