//! Serving metrics: counters plus a lock-free log-bucketed latency
//! histogram with percentile estimation.

use crate::util::json::Json;
use std::sync::atomic::{AtomicU64, Ordering};

/// Number of log2 latency buckets (1 µs .. ~17 min).
const BUCKETS: usize = 30;

/// Lock-free log2 histogram of microsecond values.
#[derive(Debug, Default)]
pub struct LatencyHistogram {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

impl LatencyHistogram {
    pub fn record(&self, us: u64) {
        let b = (64 - us.max(1).leading_zeros() as usize - 1).min(BUCKETS - 1);
        self.buckets[b].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_us(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum_us.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    pub fn max_us(&self) -> u64 {
        self.max_us.load(Ordering::Relaxed)
    }

    /// Percentile estimate (upper bound of the bucket containing rank q).
    pub fn percentile_us(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let rank = ((q / 100.0) * total as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= rank {
                return 1u64 << (i + 1); // bucket upper bound
            }
        }
        self.max_us()
    }
}

/// Coordinator-wide metrics.
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub responses: AtomicU64,
    pub errors: AtomicU64,
    pub batches: AtomicU64,
    pub batched_rows: AtomicU64,
    pub e2e_latency: LatencyHistogram,
    pub queue_latency: LatencyHistogram,
    pub compute_latency: LatencyHistogram,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn record_batch(&self, size: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_rows.fetch_add(size as u64, Ordering::Relaxed);
    }

    /// Mean rows per executed batch.
    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.batched_rows.load(Ordering::Relaxed) as f64 / b as f64
        }
    }

    /// JSON snapshot for the /metrics endpoint.
    pub fn snapshot(&self) -> Json {
        Json::obj(vec![
            (
                "requests",
                Json::num(self.requests.load(Ordering::Relaxed) as f64),
            ),
            (
                "responses",
                Json::num(self.responses.load(Ordering::Relaxed) as f64),
            ),
            ("errors", Json::num(self.errors.load(Ordering::Relaxed) as f64)),
            (
                "batches",
                Json::num(self.batches.load(Ordering::Relaxed) as f64),
            ),
            ("mean_batch_size", Json::num(self.mean_batch_size())),
            (
                "latency_us",
                Json::obj(vec![
                    ("mean", Json::num(self.e2e_latency.mean_us())),
                    ("p50", Json::num(self.e2e_latency.percentile_us(50.0) as f64)),
                    ("p95", Json::num(self.e2e_latency.percentile_us(95.0) as f64)),
                    ("p99", Json::num(self.e2e_latency.percentile_us(99.0) as f64)),
                    ("max", Json::num(self.e2e_latency.max_us() as f64)),
                ]),
            ),
            (
                "queue_us_mean",
                Json::num(self.queue_latency.mean_us()),
            ),
            (
                "compute_us_mean",
                Json::num(self.compute_latency.mean_us()),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_percentiles_ordered() {
        let h = LatencyHistogram::default();
        for us in [1u64, 10, 100, 1000, 10_000, 100_000] {
            for _ in 0..10 {
                h.record(us);
            }
        }
        assert_eq!(h.count(), 60);
        let p50 = h.percentile_us(50.0);
        let p95 = h.percentile_us(95.0);
        let p99 = h.percentile_us(99.0);
        assert!(p50 <= p95 && p95 <= p99);
        assert!(h.max_us() == 100_000);
        assert!(h.mean_us() > 0.0);
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = LatencyHistogram::default();
        assert_eq!(h.percentile_us(99.0), 0);
        assert_eq!(h.mean_us(), 0.0);
    }

    #[test]
    fn percentile_bounds_contain_value() {
        let h = LatencyHistogram::default();
        for _ in 0..100 {
            h.record(500); // bucket [256, 512)
        }
        let p = h.percentile_us(50.0);
        assert!(p >= 500 && p <= 1024, "p50 {p}");
    }

    #[test]
    fn metrics_snapshot_is_valid_json() {
        let m = Metrics::new();
        m.requests.fetch_add(3, Ordering::Relaxed);
        m.record_batch(8);
        m.record_batch(4);
        m.e2e_latency.record(1234);
        let snap = m.snapshot().encode();
        let parsed = Json::parse(&snap).unwrap();
        assert_eq!(parsed.get("requests").unwrap().as_f64(), Some(3.0));
        assert_eq!(parsed.get("mean_batch_size").unwrap().as_f64(), Some(6.0));
    }
}
