//! Serving metrics: counters, a lock-free log-bucketed latency histogram
//! with percentile estimation, and the load signals the adaptive
//! coordinator steers by (queue-depth gauge + arrival-rate EWMA).

use crate::util::json::Json;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Microseconds since the first metrics observation in this process
/// (monotonic; only differences are ever used). Offset by +1 so 0 stays
/// available as the "never observed" sentinel even for the very first
/// call, which initializes the epoch and would otherwise read 0.
fn now_us() -> u64 {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    EPOCH.get_or_init(Instant::now).elapsed().as_micros() as u64 + 1
}

/// Number of log2 latency buckets (1 µs .. ~17 min).
const BUCKETS: usize = 30;

/// Lock-free log2 histogram of microsecond values.
#[derive(Debug, Default)]
pub struct LatencyHistogram {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

impl LatencyHistogram {
    pub fn record(&self, us: u64) {
        let b = (64 - us.max(1).leading_zeros() as usize - 1).min(BUCKETS - 1);
        self.buckets[b].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_us(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum_us.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    pub fn max_us(&self) -> u64 {
        self.max_us.load(Ordering::Relaxed)
    }

    /// Percentile estimate (upper bound of the bucket containing rank q).
    pub fn percentile_us(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let rank = ((q / 100.0) * total as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= rank {
                return 1u64 << (i + 1); // bucket upper bound
            }
        }
        self.max_us()
    }
}

/// Coordinator-wide metrics.
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub responses: AtomicU64,
    pub errors: AtomicU64,
    pub batches: AtomicU64,
    pub batched_rows: AtomicU64,
    pub e2e_latency: LatencyHistogram,
    pub queue_latency: LatencyHistogram,
    pub compute_latency: LatencyHistogram,
    /// Last observed batcher queue depth (gauge, set by the batcher).
    pub queue_depth: AtomicU64,
    /// High-water mark of the queue depth.
    pub peak_queue_depth: AtomicU64,
    /// Worker threads the autoscaler currently targets (gauge).
    pub threads_in_use: AtomicU64,
    /// `max_batch` the autoscaler currently targets (gauge).
    pub max_batch_in_use: AtomicU64,
    /// Times the load controller re-advised this model (counter).
    pub autoscale_adjustments: AtomicU64,
    /// Submits refused because the model's admission queue budget was
    /// exhausted (429-style rejections; counter).
    pub admission_rejections: AtomicU64,
    /// Wavefront forwards executed (counter; barrier/race batches don't
    /// count).
    pub pipeline_runs: AtomicU64,
    /// Layers simultaneously in flight during the last pipelined batch
    /// (gauge). 1 means the wavefront degenerated to sequential.
    pub pipeline_depth: AtomicU64,
    /// Cumulative scheduler stall — worker time spent waiting for a
    /// runnable band — across pipelined batches, in µs (counter). Stall is
    /// part of the compute wall time the batcher's queue model sees, so
    /// surfacing it keeps the load controller's latency budget honest.
    pub pipeline_stall_us: AtomicU64,
    /// Cumulative wall time of pipelined batches in µs (counter). Divides
    /// `pipeline_stall_us` into the placement-effectiveness gauge: the
    /// stall fraction under a pinned pool vs an unpinned one is the
    /// observable difference worker placement makes.
    pub pipeline_wall_us: AtomicU64,
    /// Workers of the shared pool that reported a successful pin during
    /// the last pipelined batch (gauge; 0 under `--no-pin` or on
    /// platforms where placement is a no-op).
    pub pinned_workers: AtomicU64,
    /// Decode: tokens emitted across all sessions (counter).
    pub decode_tokens: AtomicU64,
    /// Decode: continuous-batching steps executed (counter).
    pub decode_steps: AtomicU64,
    /// Decode: total session rows across steps (counter; together with
    /// `decode_steps` this gives mean batch occupancy).
    pub decode_step_rows: AtomicU64,
    /// Decode: currently active sessions (gauge, set by the scheduler).
    pub decode_active_sessions: AtomicU64,
    /// Decode: sessions admitted over the model's lifetime (counter).
    pub decode_sessions_started: AtomicU64,
    /// Decode: `begin`s refused at the session capacity (429-style;
    /// counter).
    pub decode_rejections: AtomicU64,
    /// Inter-token latency (per session: the gap between its consecutive
    /// tokens), across all sessions.
    pub intertoken_latency: LatencyHistogram,
    /// EWMA of the inter-arrival gap in µs (0 = fewer than two arrivals).
    ewma_interarrival_us: AtomicU64,
    /// Timestamp of the last arrival in µs since the metrics epoch.
    last_arrival_us: AtomicU64,
    /// EWMA of batch compute latency in µs (0 = no batches yet). Unlike
    /// `compute_latency`'s lifetime mean, this tracks load *shifts* — the
    /// signal the autoscaler steers threads by.
    ewma_compute_us: AtomicU64,
    /// EWMA of the gap between decode steps in µs (0 = fewer than two
    /// steps).
    ewma_interstep_us: AtomicU64,
    /// Timestamp of the last decode step in µs since the metrics epoch.
    last_decode_step_us: AtomicU64,
    /// EWMA of rows per decode step, in milli-rows (fixed-point so small
    /// integer row counts keep fractional smoothing).
    ewma_step_mrows: AtomicU64,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn record_batch(&self, size: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_rows.fetch_add(size as u64, Ordering::Relaxed);
    }

    /// Note one request arrival: maintains the inter-arrival EWMA the
    /// load controller derives the arrival rate from. Called by the
    /// batcher on every accepted submit.
    pub fn note_arrival(&self) {
        let now = now_us();
        let prev = self.last_arrival_us.swap(now, Ordering::Relaxed);
        if prev == 0 || now <= prev {
            return; // first arrival, or same-µs burst: no usable gap
        }
        let gap = now - prev;
        let old = self.ewma_interarrival_us.load(Ordering::Relaxed);
        // α = 1/8: smooth enough to ride out bursts, fast enough to track
        // load shifts within a few dozen requests. Benign data race: a
        // lost update just weights a neighbouring sample instead.
        let new = if old == 0 { gap } else { (old * 7 + gap) / 8 };
        self.ewma_interarrival_us.store(new.max(1), Ordering::Relaxed);
    }

    /// Update the queue-depth gauge (and its high-water mark).
    pub fn set_queue_depth(&self, depth: usize) {
        self.queue_depth.store(depth as u64, Ordering::Relaxed);
        self.peak_queue_depth
            .fetch_max(depth as u64, Ordering::Relaxed);
    }

    /// Note one wavefront-pipelined batch: bumps the run counter, sets the
    /// depth gauge and accumulates scheduler stall.
    pub fn note_pipeline(&self, stats: &crate::plan::PipelineStats) {
        self.pipeline_runs.fetch_add(1, Ordering::Relaxed);
        self.pipeline_depth
            .store(stats.max_depth as u64, Ordering::Relaxed);
        self.pipeline_stall_us
            .fetch_add(stats.stall_us, Ordering::Relaxed);
        self.pipeline_wall_us
            .fetch_add(stats.wall_us, Ordering::Relaxed);
        self.pinned_workers
            .store(stats.pinned_workers as u64, Ordering::Relaxed);
    }

    /// Placement-effectiveness gauge: the fraction of pipelined wall time
    /// the workers spent stalled (0.0 until a pipelined batch ran).
    /// Compared across pinned and unpinned runs of the same workload,
    /// this is the per-layer stall delta the placement work targets.
    pub fn pipeline_stall_frac(&self) -> f64 {
        let wall = self.pipeline_wall_us.load(Ordering::Relaxed);
        if wall == 0 {
            0.0
        } else {
            self.pipeline_stall_us.load(Ordering::Relaxed) as f64 / wall as f64
        }
    }

    /// Note one batch's compute latency (EWMA companion to the
    /// `compute_latency` histogram; same α as the arrival EWMA).
    pub fn note_compute(&self, us: u64) {
        let old = self.ewma_compute_us.load(Ordering::Relaxed);
        let new = if old == 0 { us.max(1) } else { (old * 7 + us) / 8 };
        self.ewma_compute_us.store(new.max(1), Ordering::Relaxed);
    }

    /// Smoothed batch compute latency in µs (0.0 until a batch ran).
    pub fn compute_ewma_us(&self) -> f64 {
        self.ewma_compute_us.load(Ordering::Relaxed) as f64
    }

    /// Smoothed request arrival rate in requests/second (0.0 until two
    /// arrivals have been observed). The EWMA only updates on arrivals, so
    /// the current silence is folded in: once the gap since the last
    /// arrival exceeds the EWMA, the reported rate decays as 1/elapsed —
    /// a burst that ended does not pin the rate high forever.
    pub fn arrival_rate_rps(&self) -> f64 {
        let ewma = self.ewma_interarrival_us.load(Ordering::Relaxed);
        if ewma == 0 {
            return 0.0;
        }
        let last = self.last_arrival_us.load(Ordering::Relaxed);
        let silence = now_us().saturating_sub(last);
        1e6 / ewma.max(silence) as f64
    }

    /// Note one continuous-batching decode step of `rows` session rows
    /// (one token per row): bumps the token/step counters and maintains
    /// the inter-step + occupancy EWMAs [`Metrics::decode_tokens_per_sec`]
    /// reads. Same α and benign-race trade-offs as [`Metrics::note_arrival`].
    pub fn note_decode_step(&self, rows: usize) {
        self.decode_steps.fetch_add(1, Ordering::Relaxed);
        self.decode_step_rows
            .fetch_add(rows as u64, Ordering::Relaxed);
        self.decode_tokens.fetch_add(rows as u64, Ordering::Relaxed);
        let mrows = (rows as u64) * 1000;
        let old = self.ewma_step_mrows.load(Ordering::Relaxed);
        let new = if old == 0 { mrows } else { (old * 7 + mrows) / 8 };
        self.ewma_step_mrows.store(new.max(1), Ordering::Relaxed);
        let now = now_us();
        let prev = self.last_decode_step_us.swap(now, Ordering::Relaxed);
        if prev == 0 || now <= prev {
            return; // first step, or same-µs burst: no usable gap
        }
        let gap = now - prev;
        let old = self.ewma_interstep_us.load(Ordering::Relaxed);
        let new = if old == 0 { gap } else { (old * 7 + gap) / 8 };
        self.ewma_interstep_us.store(new.max(1), Ordering::Relaxed);
    }

    /// Smoothed decode throughput in tokens/second (0.0 until two steps
    /// have run). Rows-per-step EWMA over the inter-step gap EWMA, with
    /// the same silence decay as [`Metrics::arrival_rate_rps`] — an idle
    /// scheduler's rate falls off instead of pinning at the last burst.
    pub fn decode_tokens_per_sec(&self) -> f64 {
        let ewma = self.ewma_interstep_us.load(Ordering::Relaxed);
        if ewma == 0 {
            return 0.0;
        }
        let last = self.last_decode_step_us.load(Ordering::Relaxed);
        let silence = now_us().saturating_sub(last);
        let rows = self.ewma_step_mrows.load(Ordering::Relaxed) as f64 / 1000.0;
        rows * 1e6 / ewma.max(silence) as f64
    }

    /// Mean session rows per decode step over the model's lifetime.
    pub fn decode_mean_occupancy(&self) -> f64 {
        let steps = self.decode_steps.load(Ordering::Relaxed);
        if steps == 0 {
            0.0
        } else {
            self.decode_step_rows.load(Ordering::Relaxed) as f64 / steps as f64
        }
    }

    /// Mean rows per executed batch.
    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.batched_rows.load(Ordering::Relaxed) as f64 / b as f64
        }
    }

    /// JSON snapshot for the /metrics endpoint.
    pub fn snapshot(&self) -> Json {
        Json::obj(vec![
            (
                "requests",
                Json::num(self.requests.load(Ordering::Relaxed) as f64),
            ),
            (
                "responses",
                Json::num(self.responses.load(Ordering::Relaxed) as f64),
            ),
            ("errors", Json::num(self.errors.load(Ordering::Relaxed) as f64)),
            (
                "batches",
                Json::num(self.batches.load(Ordering::Relaxed) as f64),
            ),
            ("mean_batch_size", Json::num(self.mean_batch_size())),
            (
                "latency_us",
                Json::obj(vec![
                    ("mean", Json::num(self.e2e_latency.mean_us())),
                    ("p50", Json::num(self.e2e_latency.percentile_us(50.0) as f64)),
                    ("p95", Json::num(self.e2e_latency.percentile_us(95.0) as f64)),
                    ("p99", Json::num(self.e2e_latency.percentile_us(99.0) as f64)),
                    ("max", Json::num(self.e2e_latency.max_us() as f64)),
                ]),
            ),
            (
                "queue_us_mean",
                Json::num(self.queue_latency.mean_us()),
            ),
            (
                "compute_us_mean",
                Json::num(self.compute_latency.mean_us()),
            ),
            (
                "queue_depth",
                Json::num(self.queue_depth.load(Ordering::Relaxed) as f64),
            ),
            (
                "peak_queue_depth",
                Json::num(self.peak_queue_depth.load(Ordering::Relaxed) as f64),
            ),
            ("arrival_rps", Json::num(self.arrival_rate_rps())),
            (
                "threads",
                Json::num(self.threads_in_use.load(Ordering::Relaxed) as f64),
            ),
            (
                "max_batch",
                Json::num(self.max_batch_in_use.load(Ordering::Relaxed) as f64),
            ),
            (
                "autoscale_adjustments",
                Json::num(self.autoscale_adjustments.load(Ordering::Relaxed) as f64),
            ),
            (
                "admission_rejections",
                Json::num(self.admission_rejections.load(Ordering::Relaxed) as f64),
            ),
            (
                "pipeline",
                Json::obj(vec![
                    (
                        "runs",
                        Json::num(self.pipeline_runs.load(Ordering::Relaxed) as f64),
                    ),
                    (
                        "depth",
                        Json::num(self.pipeline_depth.load(Ordering::Relaxed) as f64),
                    ),
                    (
                        "stall_us_total",
                        Json::num(self.pipeline_stall_us.load(Ordering::Relaxed) as f64),
                    ),
                    (
                        "wall_us_total",
                        Json::num(self.pipeline_wall_us.load(Ordering::Relaxed) as f64),
                    ),
                    ("stall_frac", Json::num(self.pipeline_stall_frac())),
                    (
                        "pinned_workers",
                        Json::num(self.pinned_workers.load(Ordering::Relaxed) as f64),
                    ),
                ]),
            ),
            (
                "decode",
                Json::obj(vec![
                    (
                        "active_sessions",
                        Json::num(
                            self.decode_active_sessions.load(Ordering::Relaxed) as f64,
                        ),
                    ),
                    (
                        "sessions_started",
                        Json::num(
                            self.decode_sessions_started.load(Ordering::Relaxed) as f64,
                        ),
                    ),
                    (
                        "rejections",
                        Json::num(self.decode_rejections.load(Ordering::Relaxed) as f64),
                    ),
                    (
                        "tokens",
                        Json::num(self.decode_tokens.load(Ordering::Relaxed) as f64),
                    ),
                    (
                        "steps",
                        Json::num(self.decode_steps.load(Ordering::Relaxed) as f64),
                    ),
                    ("tokens_per_sec", Json::num(self.decode_tokens_per_sec())),
                    ("mean_occupancy", Json::num(self.decode_mean_occupancy())),
                    (
                        "intertoken_us",
                        Json::obj(vec![
                            ("mean", Json::num(self.intertoken_latency.mean_us())),
                            (
                                "p50",
                                Json::num(
                                    self.intertoken_latency.percentile_us(50.0) as f64
                                ),
                            ),
                            (
                                "p99",
                                Json::num(
                                    self.intertoken_latency.percentile_us(99.0) as f64
                                ),
                            ),
                        ]),
                    ),
                ]),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_percentiles_ordered() {
        let h = LatencyHistogram::default();
        for us in [1u64, 10, 100, 1000, 10_000, 100_000] {
            for _ in 0..10 {
                h.record(us);
            }
        }
        assert_eq!(h.count(), 60);
        let p50 = h.percentile_us(50.0);
        let p95 = h.percentile_us(95.0);
        let p99 = h.percentile_us(99.0);
        assert!(p50 <= p95 && p95 <= p99);
        assert!(h.max_us() == 100_000);
        assert!(h.mean_us() > 0.0);
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = LatencyHistogram::default();
        assert_eq!(h.percentile_us(99.0), 0);
        assert_eq!(h.mean_us(), 0.0);
    }

    #[test]
    fn percentile_bounds_contain_value() {
        let h = LatencyHistogram::default();
        for _ in 0..100 {
            h.record(500); // bucket [256, 512)
        }
        let p = h.percentile_us(50.0);
        assert!(p >= 500 && p <= 1024, "p50 {p}");
    }

    #[test]
    fn arrival_ewma_tracks_rate() {
        let m = Metrics::new();
        assert_eq!(m.arrival_rate_rps(), 0.0, "no arrivals yet");
        m.note_arrival();
        assert_eq!(m.arrival_rate_rps(), 0.0, "one arrival has no gap");
        for _ in 0..5 {
            std::thread::sleep(std::time::Duration::from_millis(2));
            m.note_arrival();
        }
        let rps = m.arrival_rate_rps();
        // ~2 ms gaps → on the order of 500 req/s; allow wide slack for
        // scheduler jitter, but it must be a plausible finite rate.
        assert!(rps > 1.0 && rps < 100_000.0, "rps {rps}");
        // After traffic stops the reported rate decays with the silence:
        // ≥30 ms without arrivals bounds the rate at 1e6/30000 ≈ 33 rps
        // no matter what the EWMA held.
        std::thread::sleep(std::time::Duration::from_millis(30));
        let decayed = m.arrival_rate_rps();
        assert!(decayed <= 35.0, "rate must decay in silence: {decayed}");
    }

    #[test]
    fn compute_ewma_tracks_shifts() {
        let m = Metrics::new();
        assert_eq!(m.compute_ewma_us(), 0.0);
        for _ in 0..64 {
            m.note_compute(100);
        }
        let slow_start = m.compute_ewma_us();
        assert!((90.0..=110.0).contains(&slow_start), "{slow_start}");
        for _ in 0..64 {
            m.note_compute(10_000);
        }
        assert!(
            m.compute_ewma_us() > 5_000.0,
            "EWMA must follow a load shift, got {}",
            m.compute_ewma_us()
        );
    }

    #[test]
    fn queue_depth_gauge_tracks_peak() {
        let m = Metrics::new();
        m.set_queue_depth(3);
        m.set_queue_depth(9);
        m.set_queue_depth(1);
        assert_eq!(m.queue_depth.load(Ordering::Relaxed), 1);
        assert_eq!(m.peak_queue_depth.load(Ordering::Relaxed), 9);
    }

    #[test]
    fn metrics_snapshot_is_valid_json() {
        let m = Metrics::new();
        m.requests.fetch_add(3, Ordering::Relaxed);
        m.record_batch(8);
        m.record_batch(4);
        m.e2e_latency.record(1234);
        m.note_pipeline(&crate::plan::PipelineStats {
            tasks: 6,
            workers: 2,
            max_depth: 2,
            stall_us: 40,
            wall_us: 100,
            per_layer_stall_us: vec![10, 30],
            pinned_workers: 2,
        });
        m.note_pipeline(&crate::plan::PipelineStats {
            max_depth: 3,
            stall_us: 10,
            ..Default::default()
        });
        let snap = m.snapshot().encode();
        let parsed = Json::parse(&snap).unwrap();
        assert_eq!(parsed.get("requests").unwrap().as_f64(), Some(3.0));
        assert_eq!(parsed.get("mean_batch_size").unwrap().as_f64(), Some(6.0));
        let pipeline = parsed.get("pipeline").unwrap();
        assert_eq!(pipeline.get("runs").unwrap().as_f64(), Some(2.0));
        assert_eq!(pipeline.get("depth").unwrap().as_f64(), Some(3.0));
        assert_eq!(pipeline.get("stall_us_total").unwrap().as_f64(), Some(50.0));
        assert_eq!(pipeline.get("wall_us_total").unwrap().as_f64(), Some(100.0));
        assert_eq!(pipeline.get("stall_frac").unwrap().as_f64(), Some(0.5));
        assert_eq!(pipeline.get("pinned_workers").unwrap().as_f64(), Some(0.0));
    }
}
