//! Open-loop (trace-driven) load generation.
//!
//! Closed-loop clients (in [`crate::coordinator::loadgen`]) understate tail
//! latency under overload; serving evaluations therefore also drive
//! systems open-loop from an arrival trace. This module generates Poisson
//! traces, records/replays them, and reports tail latency at a fixed
//! offered rate.

use crate::coordinator::router::Router;
use crate::util::json::Json;
use crate::util::rng::Rng;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// An arrival trace: request send offsets from t0, in microseconds.
#[derive(Debug, Clone, PartialEq)]
pub struct RequestTrace {
    pub offsets_us: Vec<u64>,
}

impl RequestTrace {
    /// Poisson arrivals at `rate_rps` for `duration`; exponential
    /// inter-arrival times from the seeded generator.
    pub fn poisson(rate_rps: f64, duration: Duration, seed: u64) -> RequestTrace {
        assert!(rate_rps > 0.0);
        let mut rng = Rng::new(seed);
        let mut offsets = Vec::new();
        let mut t = 0.0f64;
        let horizon = duration.as_secs_f64();
        loop {
            // Exponential(-ln U / λ); clamp U away from 0.
            let u = f64::from(rng.f32()).max(1e-9);
            t += -u.ln() / rate_rps;
            if t >= horizon {
                break;
            }
            offsets.push((t * 1e6) as u64);
        }
        RequestTrace {
            offsets_us: offsets,
        }
    }

    /// Constant-rate arrivals (deterministic spacing).
    pub fn uniform(rate_rps: f64, duration: Duration) -> RequestTrace {
        let period_us = 1e6 / rate_rps;
        let count = (duration.as_secs_f64() * rate_rps) as usize;
        RequestTrace {
            offsets_us: (0..count).map(|i| (i as f64 * period_us) as u64).collect(),
        }
    }

    pub fn len(&self) -> usize {
        self.offsets_us.len()
    }

    pub fn is_empty(&self) -> bool {
        self.offsets_us.is_empty()
    }

    /// Achieved offered rate of the trace.
    pub fn offered_rps(&self) -> f64 {
        match (self.offsets_us.first(), self.offsets_us.last()) {
            (Some(_), Some(&last)) if last > 0 => {
                self.offsets_us.len() as f64 / (last as f64 / 1e6)
            }
            _ => 0.0,
        }
    }

    // ---- persistence (JSON) ------------------------------------------------

    pub fn to_json(&self) -> Json {
        Json::arr(self.offsets_us.iter().map(|&o| Json::num(o as f64)))
    }

    pub fn from_json(v: &Json) -> crate::Result<RequestTrace> {
        let arr = v
            .as_arr()
            .ok_or_else(|| crate::Error::Config("trace must be an array".into()))?;
        let mut offsets = Vec::with_capacity(arr.len());
        let mut prev = 0u64;
        for item in arr {
            let o = item
                .as_f64()
                .filter(|&f| f >= 0.0)
                .ok_or_else(|| {
                    crate::Error::Config("trace offsets must be non-negative numbers".into())
                })? as u64;
            if o < prev {
                return Err(crate::Error::Config(
                    "trace offsets must be non-decreasing".into(),
                ));
            }
            prev = o;
            offsets.push(o);
        }
        Ok(RequestTrace {
            offsets_us: offsets,
        })
    }
}

/// Result of an open-loop replay.
#[derive(Debug, Clone)]
pub struct OpenLoopReport {
    pub offered: usize,
    pub completed: usize,
    pub errors: usize,
    pub offered_rps: f64,
    pub latency_us_p50: u64,
    pub latency_us_p99: u64,
    pub latency_us_max: u64,
}

impl OpenLoopReport {
    pub fn summary(&self) -> String {
        format!(
            "open-loop: offered {} ({:.0} req/s) completed {} errors {} | latency µs p50={} p99={} max={}",
            self.offered,
            self.offered_rps,
            self.completed,
            self.errors,
            self.latency_us_p50,
            self.latency_us_p99,
            self.latency_us_max
        )
    }
}

/// Replay a trace against the router: submit each request at its offset
/// (non-blocking), then collect all responses.
pub fn replay(
    router: &Arc<Router>,
    trace: &RequestTrace,
    model: &str,
    d_in: usize,
    seed: u64,
) -> OpenLoopReport {
    let t0 = Instant::now();
    let mut rng = Rng::new(seed);
    let mut pending = Vec::with_capacity(trace.len());
    for &off_us in &trace.offsets_us {
        let target = Duration::from_micros(off_us);
        let now = t0.elapsed();
        if target > now {
            std::thread::sleep(target - now);
        }
        let input: Vec<f32> = (0..d_in).map(|_| rng.f32_range(-1.0, 1.0)).collect();
        let sent = Instant::now();
        match router.submit(model, input) {
            Ok(rx) => pending.push((sent, rx)),
            Err(_) => pending.push((sent, {
                // Synthesize a closed channel to count as error below.
                let (_tx, rx) = std::sync::mpsc::channel();
                rx
            })),
        }
    }
    let mut lats = Vec::with_capacity(pending.len());
    let mut errors = 0usize;
    for (sent, rx) in pending {
        match rx.recv_timeout(Duration::from_secs(30)) {
            Ok(resp) if resp.output.is_ok() => {
                lats.push(sent.elapsed().as_micros() as u64)
            }
            _ => errors += 1,
        }
    }
    lats.sort_unstable();
    let pct = |q: f64| {
        if lats.is_empty() {
            0
        } else {
            lats[((q / 100.0 * lats.len() as f64).ceil() as usize).clamp(1, lats.len()) - 1]
        }
    };
    OpenLoopReport {
        offered: trace.len(),
        completed: lats.len(),
        errors,
        offered_rps: trace.offered_rps(),
        latency_us_p50: pct(50.0),
        latency_us_p99: pct(99.0),
        latency_us_max: lats.last().copied().unwrap_or(0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{BatchPolicy, Engine};
    use crate::model::{ModelConfig, TernaryMlp};

    #[test]
    fn poisson_trace_statistics() {
        let trace = RequestTrace::poisson(1000.0, Duration::from_secs(2), 7);
        // ~2000 expected; allow generous slack.
        assert!(trace.len() > 1200 && trace.len() < 2800, "len {}", trace.len());
        assert!(trace.offsets_us.windows(2).all(|w| w[0] <= w[1]));
        let rate = trace.offered_rps();
        assert!((rate - 1000.0).abs() < 250.0, "rate {rate}");
    }

    #[test]
    fn uniform_trace_spacing() {
        let trace = RequestTrace::uniform(100.0, Duration::from_secs(1));
        assert_eq!(trace.len(), 100);
        assert_eq!(trace.offsets_us[1] - trace.offsets_us[0], 10_000);
    }

    #[test]
    fn trace_json_roundtrip() {
        let trace = RequestTrace::poisson(500.0, Duration::from_millis(200), 3);
        let decoded = RequestTrace::from_json(&trace.to_json()).unwrap();
        assert_eq!(decoded, trace);
        assert!(RequestTrace::from_json(&Json::parse("[5, 1]").unwrap()).is_err());
    }

    #[test]
    fn replay_completes_all() {
        let cfg = ModelConfig::from_json(
            r#"{"name":"ol","dims":[8,16,4],"sparsity":0.5,"seed":2}"#,
        )
        .unwrap();
        let mut router = Router::new();
        router.register(
            Engine::new("ol", TernaryMlp::from_config(&cfg).unwrap()),
            BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_micros(300),
            },
        );
        let router = Arc::new(router);
        let trace = RequestTrace::uniform(2000.0, Duration::from_millis(50)); // 100 reqs
        let report = replay(&router, &trace, "ol", 8, 5);
        assert_eq!(report.offered, 100);
        assert_eq!(report.completed, 100);
        assert_eq!(report.errors, 0);
        assert!(report.latency_us_p50 <= report.latency_us_p99);
        assert!(!report.summary().is_empty());
    }
}
