//! Model fleet registry: the mutable, runtime model set behind the
//! routing front door.
//!
//! PR 1–7 built a coordinator whose model set was frozen at startup —
//! `serve` registered one model and only whole-process shutdown could
//! retire it. The registry makes the fleet a first-class runtime concept:
//!
//! - **Lifecycle**: every model is a [`ModelHandle`] with an explicit
//!   [`ModelState`] — `Cold` (loaded, no plans built) → `Warming` (plan
//!   compile in progress: first traffic or an explicit
//!   [`ModelRegistry::warm`]) → `Hot` (serving with compiled plans) →
//!   `Draining` (unload/shutdown in progress: new submits are rejected,
//!   queued requests still complete).
//! - **Shared substrate**: the registry owns **one** [`Planner`] (and
//!   through it one `TuningTable` and one lazily-created shared
//!   [`crate::util::threadpool::ThreadPool`]); every loaded model gets its
//!   own `PlanCache` layered on that planner, so tuning knowledge learned
//!   by one model's online races is immediately visible to every other
//!   model with the same (K, sparsity, M) classes.
//! - **Admission control**: each model carries an [`AdmissionController`]
//!   enforcing a queue budget at submit time. A hot model that outruns its
//!   budget gets 429-style
//!   [`crate::coordinator::SubmitError::Overloaded`] rejections instead of
//!   unbounded queueing — it cannot starve its neighbours' worker threads
//!   by stacking work the fleet can never drain.
//! - **Thread-budget split**: [`ModelRegistry::start_balancer`] runs a
//!   fleet tick that splits one process-wide worker-thread budget across
//!   models by observed demand (arrival rate × compute EWMA, via
//!   [`crate::coordinator::load::split_thread_budget`]); each model's
//!   autoscale advice is clamped to its share.
//!
//! Unload and shutdown share one drain path, with the ordering fix the
//! single-model router needed: the autoscale tick thread stops **before**
//! the batch loop is joined, so a late tick can never re-advise (and touch
//! the plan cache of) a model whose loop is already gone.

use crate::coordinator::batcher::{BatchPolicy, DynamicBatcher, SubmitError};
use crate::coordinator::decode::{DecodeConfig, DecodeScheduler};
use crate::coordinator::engine::Engine;
use crate::coordinator::load::{
    pow2_floor, split_thread_budget, Advice, AdviceHysteresis, LoadControlConfig,
    LoadController,
};
use crate::coordinator::request::{InferenceRequest, InferenceResponse};
use crate::model::ModelConfig;
use crate::plan::{PlanCache, Planner};
use crate::util::affinity::PlacementPolicy;
use crate::util::threadpool::WorkerPlacement;
use crate::{Error, Result};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::Duration;

/// Lifecycle state of a loaded model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelState {
    /// Loaded and registered; no plans built yet. First traffic (or an
    /// explicit warm) moves it to `Warming`.
    Cold = 0,
    /// Plan compile in progress (lazy, on first traffic, or eager via
    /// [`ModelRegistry::warm`]).
    Warming = 1,
    /// Serving with compiled plans.
    Hot = 2,
    /// Unload/shutdown in progress: new submits are rejected, in-flight
    /// and queued requests still complete.
    Draining = 3,
}

impl ModelState {
    fn from_u8(v: u8) -> ModelState {
        match v {
            0 => ModelState::Cold,
            1 => ModelState::Warming,
            2 => ModelState::Hot,
            _ => ModelState::Draining,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            ModelState::Cold => "cold",
            ModelState::Warming => "warming",
            ModelState::Hot => "hot",
            ModelState::Draining => "draining",
        }
    }
}

impl std::fmt::Display for ModelState {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Per-model queue budget, checked inside the batcher's submit lock.
///
/// A budget of 0 means unlimited (the single-model default). With a
/// budget set, a submit that would push the queue past it is rejected
/// with [`SubmitError::Overloaded`] — the 429-style backpressure that
/// keeps one hot model from stacking unbounded work.
#[derive(Debug, Default)]
pub struct AdmissionController {
    queue_budget: AtomicUsize,
}

impl AdmissionController {
    pub fn new(queue_budget: usize) -> AdmissionController {
        AdmissionController {
            queue_budget: AtomicUsize::new(queue_budget),
        }
    }

    /// Current budget (0 = unlimited).
    pub fn budget(&self) -> usize {
        self.queue_budget.load(Ordering::Relaxed)
    }

    /// Re-size the budget at runtime (0 = unlimited).
    pub fn set_budget(&self, budget: usize) {
        self.queue_budget.store(budget, Ordering::Relaxed);
    }

    /// Whether a request may join a queue currently `depth` deep.
    pub fn admits(&self, depth: usize) -> bool {
        let budget = self.budget();
        budget == 0 || depth < budget
    }
}

/// How to load a model into the registry.
#[derive(Debug, Clone)]
pub struct LoadOptions {
    /// Batch assembly policy for the model's dynamic batcher.
    pub policy: BatchPolicy,
    /// Autoscale configuration; `None` pins the static policy.
    pub control: Option<LoadControlConfig>,
    /// Admission queue budget (0 = unlimited).
    pub queue_budget: usize,
    /// Eagerly compile plans at load time (`Cold → Warming → Hot` before
    /// the first request). `false` defers the compile to first traffic.
    pub warm: bool,
    /// Batch buckets a warm-up compiles plans for. Empty defers entirely
    /// to first traffic ([`ModelRegistry::load`] fills this from the
    /// config's `batch_buckets`).
    pub buckets: Vec<usize>,
    /// Decode serving knobs for this model's lazily-created
    /// [`DecodeScheduler`] (session capacity + default token budget).
    pub decode: DecodeConfig,
}

impl Default for LoadOptions {
    fn default() -> Self {
        LoadOptions {
            policy: BatchPolicy::default(),
            control: None,
            queue_budget: 0,
            warm: false,
            buckets: Vec::new(),
            decode: DecodeConfig::default(),
        }
    }
}

/// One loaded model: engine + batcher + lifecycle + admission + the
/// threads that serve it.
pub struct ModelHandle {
    engine: Arc<Engine>,
    batcher: Arc<DynamicBatcher>,
    admission: Arc<AdmissionController>,
    state: AtomicU8,
    /// This model's share of the fleet thread budget (upper bound for
    /// autoscale advice; re-split by the balancer tick).
    thread_cap: AtomicUsize,
    /// Buckets an explicit warm compiles plans for.
    buckets: Vec<usize>,
    controller: Option<Arc<LoadController>>,
    /// Both advise triggers (batch-count and timer tick) and the fleet
    /// balancer serialize on this lock; each computes its advice from the
    /// metrics *inside* the critical section — so a tick that read
    /// pre-burst signals can never stomp the batch loop's fresh scale-up,
    /// and the gauge pair is never observed torn between two advices.
    advise_lock: Arc<Mutex<()>>,
    loop_handle: Mutex<Option<JoinHandle<()>>>,
    /// Dropping this stops the autoscale tick thread (its `recv_timeout`
    /// sees the disconnect).
    tick_stop: Mutex<Option<mpsc::Sender<()>>>,
    tick_handle: Mutex<Option<JoinHandle<()>>>,
    /// Decode scheduler, created (and its step loop started) on the first
    /// `/generate`; a model that never decodes pays nothing. Taken and
    /// shut down by the drain path.
    decode: Mutex<Option<Arc<DecodeScheduler>>>,
    decode_cfg: DecodeConfig,
}

impl ModelHandle {
    pub fn state(&self) -> ModelState {
        ModelState::from_u8(self.state.load(Ordering::Acquire))
    }

    pub fn engine(&self) -> &Arc<Engine> {
        &self.engine
    }

    pub fn admission(&self) -> &Arc<AdmissionController> {
        &self.admission
    }

    /// Current batcher queue depth.
    pub fn queue_depth(&self) -> usize {
        self.batcher.depth()
    }

    /// This model's current share of the fleet thread budget.
    pub fn thread_cap(&self) -> usize {
        self.thread_cap.load(Ordering::Relaxed)
    }

    /// The model's decode scheduler, creating it — and starting its step
    /// loop — on first use. Decode needs the native plan-cache path (an
    /// explicit-layer or XLA-only engine has no cache to pin a decode
    /// plan in) and a square model (`d_in == d_out`, checked by
    /// [`DecodeScheduler::new`]); both surface as typed errors here
    /// rather than panics deep in a step.
    pub fn decode_scheduler(&self) -> Result<Arc<DecodeScheduler>> {
        let mut slot = self.decode.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(s) = slot.as_ref() {
            return Ok(Arc::clone(s));
        }
        // Check state under the slot lock: drain takes the slot first,
        // then this check refuses a re-create behind its back.
        if self.state() == ModelState::Draining {
            return Err(Error::Serve(format!(
                "model '{}' is draining",
                self.engine.name
            )));
        }
        let cache = self.engine.plan_cache().ok_or_else(|| {
            Error::Serve(format!(
                "model '{}' has no plan cache (explicit-layer/XLA engines \
                 do not serve decode)",
                self.engine.name
            ))
        })?;
        let sched = Arc::new(DecodeScheduler::new(
            self.engine.name.clone(),
            cache,
            Arc::clone(&self.engine.metrics),
            self.decode_cfg.clone(),
        )?);
        sched.spawn_loop();
        *slot = Some(Arc::clone(&sched));
        Ok(sched)
    }

    /// The decode scheduler if one has already been started (status and
    /// metrics rendering must not force-create one).
    pub fn decode_scheduler_if_started(&self) -> Option<Arc<DecodeScheduler>> {
        self.decode
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .as_ref()
            .map(Arc::clone)
    }

    /// Move to `to` unless the model is already `Draining` — drain is
    /// terminal and must never be overwritten by a racing warm-up or
    /// batch-loop Hot transition.
    fn advance_state(&self, to: ModelState) {
        let mut cur = self.state.load(Ordering::Acquire);
        loop {
            if ModelState::from_u8(cur) == ModelState::Draining {
                return;
            }
            match self.state.compare_exchange(
                cur,
                to as u8,
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => return,
                Err(actual) => cur = actual,
            }
        }
    }

    /// First traffic on a cold model starts the (lazy) warm-up: the plan
    /// cache compiles on the batch loop's first miss.
    fn mark_traffic(&self) {
        let _ = self.state.compare_exchange(
            ModelState::Cold as u8,
            ModelState::Warming as u8,
            Ordering::AcqRel,
            Ordering::Relaxed,
        );
    }

    /// Eagerly compile plans for the configured buckets at every thread
    /// step the coordinator can use (settled kernel choices only — untuned
    /// buckets stay cold so their first real traffic races the top-2
    /// candidates).
    fn warm_plans(&self) -> Result<()> {
        if let Some(cache) = self.engine.plan_cache() {
            // Hold the advise lock: warm_settled temporarily walks the
            // cache's thread ceiling through each step, which a concurrent
            // advice application must not observe.
            let _guard = self.advise_lock.lock().unwrap_or_else(|e| e.into_inner());
            self.advance_state(ModelState::Warming);
            let steps = match &self.controller {
                // Fixed ceiling: only one step is reachable.
                None => vec![cache.threads()],
                Some(c) => PlanCache::controller_thread_steps(c.cfg().max_threads),
            };
            cache.warm_settled(&self.buckets, &steps)?;
        }
        self.advance_state(ModelState::Hot);
        Ok(())
    }
}

/// Apply one piece of controller advice to a model's live knobs and
/// gauges (shared by the batch-loop and timer-tick triggers). The thread
/// target is additionally clamped to the model's fleet budget share.
fn apply_advice(handle: &ModelHandle, mut advice: Advice) {
    let cap = pow2_floor(handle.thread_cap.load(Ordering::Relaxed).max(1));
    advice.threads = advice.threads.min(cap);
    handle.batcher.set_max_batch(advice.max_batch);
    handle.engine.set_threads(advice.threads);
    handle
        .engine
        .metrics
        .max_batch_in_use
        .store(advice.max_batch as u64, Ordering::Relaxed);
    handle
        .engine
        .metrics
        .threads_in_use
        .store(advice.threads as u64, Ordering::Relaxed);
    handle
        .engine
        .metrics
        .autoscale_adjustments
        .fetch_add(1, Ordering::Relaxed);
}

/// The dynamic multi-model fleet registry.
///
/// Owns the shared planning substrate (one [`Planner`] → one tuning
/// table + one shared thread pool) and the name → [`ModelHandle`] map.
/// Models load, warm, serve, and unload at runtime; the thin
/// [`crate::coordinator::Router`] front door delegates here.
pub struct ModelRegistry {
    planner: Arc<Planner>,
    /// Shared with the balancer tick thread (it needs the live model set
    /// without holding the registry itself).
    models: Arc<RwLock<BTreeMap<String, Arc<ModelHandle>>>>,
    next_id: AtomicU64,
    /// Name lookups that found / missed a model (fleet gauges).
    hits: AtomicU64,
    misses: AtomicU64,
    /// Process-wide worker-thread budget the balancer splits by demand.
    thread_budget: usize,
    balancer_stop: Mutex<Option<mpsc::Sender<()>>>,
    balancer_handle: Mutex<Option<JoinHandle<()>>>,
}

impl ModelRegistry {
    /// Registry over a shared planner, with the host's parallelism as the
    /// fleet thread budget.
    pub fn new(planner: Arc<Planner>) -> ModelRegistry {
        let budget = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4);
        ModelRegistry::with_thread_budget(planner, budget)
    }

    /// Registry with a worker-placement policy: the planner's shared pool
    /// pins its workers per `policy` when it is (lazily) created, and the
    /// fleet thread budget becomes a **core budget** — the topology's
    /// performance-core count under any placing policy, host parallelism
    /// under [`PlacementPolicy::None`] (`--no-pin`). Placement never
    /// changes results, only where the work runs.
    pub fn with_placement(planner: Arc<Planner>, policy: PlacementPolicy) -> ModelRegistry {
        planner.set_placement(policy);
        let budget = match policy {
            PlacementPolicy::None => std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4),
            _ => planner.topology().perf_cores().len().max(1),
        };
        ModelRegistry::with_thread_budget(planner, budget)
    }

    /// The placement policy the shared pool pins (or will pin) with.
    pub fn placement(&self) -> PlacementPolicy {
        self.planner.placement()
    }

    /// Per-worker placement rows of the shared pool (empty until the pool
    /// exists — it is created lazily by the first multi-threaded plan).
    pub fn pool_placements(&self) -> Vec<WorkerPlacement> {
        self.planner.pool_placements()
    }

    /// Registry with an explicit fleet-wide worker-thread budget.
    pub fn with_thread_budget(planner: Arc<Planner>, thread_budget: usize) -> ModelRegistry {
        ModelRegistry {
            planner,
            models: Arc::new(RwLock::new(BTreeMap::new())),
            next_id: AtomicU64::new(1),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            thread_budget: thread_budget.max(1),
            balancer_stop: Mutex::new(None),
            balancer_handle: Mutex::new(None),
        }
    }

    /// The shared planning substrate (tuning table + thread pool owner).
    pub fn planner(&self) -> &Arc<Planner> {
        &self.planner
    }

    /// The fleet-wide worker-thread budget.
    pub fn thread_budget(&self) -> usize {
        self.thread_budget
    }

    /// Registry lookups that resolved to a loaded model.
    pub fn hit_count(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Registry lookups that named no loaded model.
    pub fn miss_count(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Build a model from its config through the shared planner and load
    /// it. Empty `opts.buckets` are filled from the config's
    /// `batch_buckets`; a zero `opts.queue_budget` takes the config's
    /// `queue_budget` key.
    pub fn load(&self, cfg: &ModelConfig, mut opts: LoadOptions) -> Result<Arc<ModelHandle>> {
        if opts.buckets.is_empty() {
            opts.buckets = cfg.batch_buckets.clone();
        }
        if opts.queue_budget == 0 {
            opts.queue_budget = cfg.queue_budget;
        }
        let engine = Engine::from_config(cfg, &self.planner)?;
        self.load_engine(engine, opts)
    }

    /// Load a pre-built engine (the path for engines carrying an XLA
    /// executor or explicit layers). Fails when the name is taken — unload
    /// first to replace a model.
    pub fn load_engine(&self, engine: Engine, opts: LoadOptions) -> Result<Arc<ModelHandle>> {
        let name = engine.name.clone();
        if self.models.read().unwrap_or_else(|e| e.into_inner()).contains_key(&name) {
            return Err(Error::Serve(format!("model '{name}' is already loaded")));
        }
        let controller = opts
            .control
            .clone()
            .map(|c| Arc::new(LoadController::new(c)));
        let engine = Arc::new(engine);
        let admission = Arc::new(AdmissionController::new(opts.queue_budget));
        let batcher = Arc::new(
            DynamicBatcher::new(opts.policy)
                .with_metrics(Arc::clone(&engine.metrics))
                .with_admission(Arc::clone(&admission)),
        );
        engine
            .metrics
            .max_batch_in_use
            .store(opts.policy.max_batch as u64, Ordering::Relaxed);
        let mut initial_threads = engine.plan_cache().map(|c| c.threads()).unwrap_or(1);
        // Controller advice only ever lands on powers of two ≤ its
        // `max_threads`, and the warm steps cover exactly those — an
        // autoscaled model whose config seeded a ceiling outside that set
        // (e.g. "threads": 6, or 8 with --max-threads 4) would otherwise
        // build unwarmed plans that become dead weight on the first
        // advice. Fixed-policy models keep the config value untouched
        // (the documented escape hatch).
        if let Some(ctl) = &controller {
            let clamped = pow2_floor(initial_threads.min(ctl.cfg().max_threads));
            if clamped != initial_threads {
                engine.set_threads(clamped);
                initial_threads = clamped;
            }
        }
        engine
            .metrics
            .threads_in_use
            .store(initial_threads as u64, Ordering::Relaxed);
        let handle = Arc::new(ModelHandle {
            engine,
            batcher,
            admission,
            state: AtomicU8::new(ModelState::Cold as u8),
            thread_cap: AtomicUsize::new(self.thread_budget),
            buckets: opts.buckets,
            controller,
            advise_lock: Arc::new(Mutex::new(())),
            loop_handle: Mutex::new(None),
            tick_stop: Mutex::new(None),
            tick_handle: Mutex::new(None),
            decode: Mutex::new(None),
            decode_cfg: opts.decode,
        });
        // Eager warm happens before the serving threads exist: an
        // autoscaled model's advise tick would otherwise race
        // warm_settled's temporary thread-ceiling changes.
        if opts.warm {
            handle.warm_plans()?;
        }
        self.spawn_batch_loop(&name, &handle);
        self.spawn_autoscale_tick(&name, &handle);
        let mut models = self.models.write().unwrap_or_else(|e| e.into_inner());
        if models.contains_key(&name) {
            // Lost a load race for the same name: tear our threads down
            // and report the conflict.
            drop(models);
            Self::drain(&handle);
            return Err(Error::Serve(format!("model '{name}' is already loaded")));
        }
        models.insert(name, Arc::clone(&handle));
        Ok(handle)
    }

    fn spawn_batch_loop(&self, name: &str, handle: &Arc<ModelHandle>) {
        let h = Arc::clone(handle);
        let loop_handle = std::thread::Builder::new()
            .name(format!("stgemm-batch-{name}"))
            .spawn(move || {
                let mut executed: u64 = 0;
                while let Some(batch) = h.batcher.next_batch() {
                    h.engine.run_batch(batch);
                    executed += 1;
                    // First executed batch: the lazy warm-up (plan-cache
                    // compile on miss) has happened — the model is hot.
                    h.advance_state(ModelState::Hot);
                    if let Some(ctl) = &h.controller {
                        if executed % ctl.cfg().adjust_every_batches == 0 {
                            let _guard =
                                h.advise_lock.lock().unwrap_or_else(|e| e.into_inner());
                            let advice = ctl.advise_from(&h.engine.metrics);
                            apply_advice(&h, advice);
                        }
                    }
                }
            })
            .expect("spawn batch loop");
        *handle.loop_handle.lock().unwrap_or_else(|e| e.into_inner()) = Some(loop_handle);
    }

    /// Timer-driven advise tick: without it an idle model never
    /// re-advises (advice otherwise fires per executed batch), so
    /// threads/batch targets could never decay back after a burst.
    fn spawn_autoscale_tick(&self, name: &str, handle: &Arc<ModelHandle>) {
        let Some(ctl) = handle.controller.clone() else {
            return;
        };
        let (stop_tx, stop_rx) = mpsc::channel::<()>();
        let h = Arc::clone(handle);
        let tick_handle = std::thread::Builder::new()
            .name(format!("stgemm-tick-{name}"))
            .spawn(move || {
                let mut hysteresis = AdviceHysteresis::default();
                loop {
                    match stop_rx.recv_timeout(ctl.cfg().tick) {
                        Err(mpsc::RecvTimeoutError::Timeout) => {
                            let _guard =
                                h.advise_lock.lock().unwrap_or_else(|e| e.into_inner());
                            let advice = ctl.advise_from(&h.engine.metrics);
                            let current = Advice {
                                max_batch: h
                                    .engine
                                    .metrics
                                    .max_batch_in_use
                                    .load(Ordering::Relaxed)
                                    as usize,
                                threads: h
                                    .engine
                                    .metrics
                                    .threads_in_use
                                    .load(Ordering::Relaxed)
                                    as usize,
                            };
                            if let Some(a) = hysteresis.observe(advice, current) {
                                apply_advice(&h, a);
                            }
                        }
                        // Sender dropped (drain) or explicit stop.
                        _ => break,
                    }
                }
            })
            .expect("spawn autoscale tick");
        *handle.tick_stop.lock().unwrap_or_else(|e| e.into_inner()) = Some(stop_tx);
        *handle.tick_handle.lock().unwrap_or_else(|e| e.into_inner()) = Some(tick_handle);
    }

    /// Start the fleet balancer: every `tick`, split the thread budget
    /// across loaded models by observed demand (arrival rate × compute
    /// EWMA) and clamp each model's autoscale ceiling to its share. An
    /// over-share model is pulled down immediately; growth waits for the
    /// model's own controller to advise it (so an idle model's share is a
    /// cap, not a reservation).
    pub fn start_balancer(&self, tick: Duration) {
        let mut stop_guard = self.balancer_stop.lock().unwrap_or_else(|e| e.into_inner());
        if stop_guard.is_some() {
            return; // already running
        }
        let (stop_tx, stop_rx) = mpsc::channel::<()>();
        let models = Arc::clone(&self.models);
        let total = self.thread_budget;
        let handle = std::thread::Builder::new()
            .name("stgemm-fleet-balance".into())
            .spawn(move || loop {
                match stop_rx.recv_timeout(tick) {
                    Err(mpsc::RecvTimeoutError::Timeout) => {
                        let handles: Vec<Arc<ModelHandle>> = models
                            .read()
                            .unwrap_or_else(|e| e.into_inner())
                            .values()
                            .cloned()
                            .collect();
                        if handles.is_empty() {
                            continue;
                        }
                        let demands: Vec<f64> = handles
                            .iter()
                            .map(|h| {
                                let m = &h.engine.metrics;
                                // µs of compute arriving per second: the
                                // load each model actually puts on the
                                // shared pool.
                                m.arrival_rate_rps() * m.compute_ewma_us().max(1.0)
                            })
                            .collect();
                        let shares = split_thread_budget(total, &demands);
                        for (h, share) in handles.iter().zip(shares) {
                            h.thread_cap.store(share, Ordering::Relaxed);
                            let current =
                                h.engine.metrics.threads_in_use.load(Ordering::Relaxed)
                                    as usize;
                            if current > share {
                                let _guard = h
                                    .advise_lock
                                    .lock()
                                    .unwrap_or_else(|e| e.into_inner());
                                h.engine.set_threads(share);
                                h.engine
                                    .metrics
                                    .threads_in_use
                                    .store(share as u64, Ordering::Relaxed);
                            }
                        }
                    }
                    _ => break,
                }
            })
            .expect("spawn fleet balancer");
        *stop_guard = Some(stop_tx);
        *self
            .balancer_handle
            .lock()
            .unwrap_or_else(|e| e.into_inner()) = Some(handle);
    }

    /// Loaded model names (sorted).
    pub fn names(&self) -> Vec<String> {
        self.models
            .read()
            .unwrap_or_else(|e| e.into_inner())
            .keys()
            .cloned()
            .collect()
    }

    /// Look a model up by name (counts fleet hit/miss gauges).
    pub fn get(&self, name: &str) -> Option<Arc<ModelHandle>> {
        let found = self
            .models
            .read()
            .unwrap_or_else(|e| e.into_inner())
            .get(name)
            .cloned();
        match &found {
            Some(_) => self.hits.fetch_add(1, Ordering::Relaxed),
            None => self.misses.fetch_add(1, Ordering::Relaxed),
        };
        found
    }

    /// Snapshot of (name, handle) pairs for status/metrics rendering.
    pub fn handles(&self) -> Vec<(String, Arc<ModelHandle>)> {
        self.models
            .read()
            .unwrap_or_else(|e| e.into_inner())
            .iter()
            .map(|(k, v)| (k.clone(), Arc::clone(v)))
            .collect()
    }

    /// Eagerly compile a loaded model's plans (`Cold → Warming → Hot`).
    pub fn warm(&self, name: &str) -> Result<()> {
        let handle = self
            .get(name)
            .ok_or_else(|| Error::Serve(format!("unknown model '{name}'")))?;
        handle.warm_plans()
    }

    /// Submit an input row; returns the response receiver.
    pub fn submit(
        &self,
        model: &str,
        input: Vec<f32>,
    ) -> Result<mpsc::Receiver<InferenceResponse>> {
        let handle = self
            .get(model)
            .ok_or_else(|| Error::Serve(format!("unknown model '{model}'")))?;
        if handle.state() == ModelState::Draining {
            handle
                .engine
                .metrics
                .errors
                .fetch_add(1, Ordering::Relaxed);
            return Err(Error::Serve(format!("model '{model}' is draining")));
        }
        handle.mark_traffic();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        handle
            .engine
            .metrics
            .requests
            .fetch_add(1, Ordering::Relaxed);
        let (req, rx) = InferenceRequest::new(id, model, input);
        handle.batcher.submit(req).map_err(|e| {
            handle
                .engine
                .metrics
                .errors
                .fetch_add(1, Ordering::Relaxed);
            Error::Serve(match e {
                SubmitError::Closed(_) => "model is shutting down".to_string(),
                SubmitError::EmptyInput(_) => "empty input".to_string(),
                SubmitError::Overloaded(_) => {
                    handle
                        .engine
                        .metrics
                        .admission_rejections
                        .fetch_add(1, Ordering::Relaxed);
                    format!("overloaded: model '{model}' queue is at its admission budget")
                }
            })
        })?;
        Ok(rx)
    }

    /// Submit and block for the response (with timeout).
    pub fn infer_blocking(
        &self,
        model: &str,
        input: Vec<f32>,
        timeout: Duration,
    ) -> Result<InferenceResponse> {
        let rx = self.submit(model, input)?;
        rx.recv_timeout(timeout)
            .map_err(|e| Error::Serve(format!("inference timed out/disconnected: {e}")))
    }

    /// The one drain path, shared by [`ModelRegistry::unload`] and
    /// [`ModelRegistry::shutdown`]:
    ///
    /// 1. mark `Draining` — new submits are rejected from here on;
    /// 2. stop and join the autoscale tick thread **before** touching the
    ///    batch loop (a tick joined after the loop could re-advise a
    ///    model with no consumer left and mutate its plan cache mid-free);
    /// 3. shut the decode scheduler down (if one was started): its step
    ///    loop joins and every open `/generate` stream ends — decode
    ///    sessions hold arena leases, so they must retire before the
    ///    plan cache is released;
    /// 4. close the batcher — queued requests are still handed to the
    ///    batch loop, so nothing accepted is ever dropped;
    /// 5. join the batch loop: when it exits, every in-flight response
    ///    has been delivered.
    fn drain(handle: &ModelHandle) {
        handle.state.store(ModelState::Draining as u8, Ordering::Release);
        handle
            .tick_stop
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .take();
        if let Some(h) = handle
            .tick_handle
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .take()
        {
            let _ = h.join();
        }
        if let Some(d) = handle
            .decode
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .take()
        {
            d.shutdown();
        }
        handle.batcher.close();
        if let Some(h) = handle
            .loop_handle
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .take()
        {
            let _ = h.join();
        }
    }

    /// Unload a model: drain it (no accepted request is dropped), remove
    /// it from the registry, and release its plan/pipeline/arena memory.
    /// The name becomes immediately re-loadable.
    pub fn unload(&self, name: &str) -> Result<()> {
        // Resolve without removing: the model stays visible (as Draining)
        // to /status while its queue flushes.
        let handle = self
            .get(name)
            .ok_or_else(|| Error::Serve(format!("unknown model '{name}'")))?;
        Self::drain(&handle);
        self.models
            .write()
            .unwrap_or_else(|e| e.into_inner())
            .remove(name);
        if let Some(cache) = handle.engine.plan_cache() {
            cache.release();
        }
        Ok(())
    }

    /// Stop everything: balancer first (so no re-split lands mid-drain),
    /// then all models through the shared drain ordering — ticks stopped
    /// and joined before any batch loop is joined. Idempotent; queued
    /// requests still complete.
    pub fn shutdown(&self) {
        self.balancer_stop
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .take();
        if let Some(h) = self
            .balancer_handle
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .take()
        {
            let _ = h.join();
        }
        let handles: Vec<Arc<ModelHandle>> = self
            .models
            .read()
            .unwrap_or_else(|e| e.into_inner())
            .values()
            .cloned()
            .collect();
        // Phase 1: stop accepting + stop ticks everywhere, so all models
        // drain concurrently instead of serially. Decode schedulers shut
        // down here too — each join is cheap (the step loop exits at its
        // next condvar wake) and open token streams end immediately.
        for h in &handles {
            h.state.store(ModelState::Draining as u8, Ordering::Release);
            h.tick_stop.lock().unwrap_or_else(|e| e.into_inner()).take();
            if let Some(d) = h.decode.lock().unwrap_or_else(|e| e.into_inner()).take() {
                d.shutdown();
            }
            h.batcher.close();
        }
        // Phase 2: join ticks before any batch loop.
        for h in &handles {
            if let Some(t) = h
                .tick_handle
                .lock()
                .unwrap_or_else(|e| e.into_inner())
                .take()
            {
                let _ = t.join();
            }
        }
        // Phase 3: join loops (each finishes flushing its queue).
        for h in &handles {
            if let Some(l) = h
                .loop_handle
                .lock()
                .unwrap_or_else(|e| e.into_inner())
                .take()
            {
                let _ = l.join();
            }
        }
    }
}

impl Drop for ModelRegistry {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::Ordering;

    fn cfg(name: &str, seed: u64) -> ModelConfig {
        ModelConfig::from_json(&format!(
            r#"{{"name":"{name}","dims":[8,16,4],"sparsity":0.5,"seed":{seed}}}"#
        ))
        .unwrap()
    }

    fn registry() -> ModelRegistry {
        ModelRegistry::with_thread_budget(Arc::new(Planner::new()), 8)
    }

    #[test]
    fn placement_turns_the_thread_budget_into_a_core_budget() {
        let topo = crate::perf::topology::CpuTopology::apple_like();
        let planner = Arc::new(Planner::new().with_topology(topo.clone()));
        let reg = ModelRegistry::with_placement(planner, PlacementPolicy::PerfCoresFirst);
        assert_eq!(reg.placement(), PlacementPolicy::PerfCoresFirst);
        assert_eq!(
            reg.thread_budget(),
            topo.perf_cores().len(),
            "placed fleets budget performance cores, not host threads"
        );
        assert!(
            reg.pool_placements().is_empty(),
            "shared pool is lazy: no placement rows before the first plan"
        );
        let unpinned =
            ModelRegistry::with_placement(Arc::new(Planner::new()), PlacementPolicy::None);
        assert_eq!(unpinned.placement(), PlacementPolicy::None);
        assert!(unpinned.thread_budget() >= 1);
    }

    #[test]
    fn lifecycle_cold_until_traffic_then_hot() {
        let reg = registry();
        let handle = reg.load(&cfg("m1", 1), LoadOptions::default()).unwrap();
        assert_eq!(handle.state(), ModelState::Cold);
        let resp = reg
            .infer_blocking("m1", vec![0.5; 8], Duration::from_secs(5))
            .unwrap();
        assert_eq!(resp.output.unwrap().len(), 4);
        // The batch loop marks Hot right after the first executed batch —
        // but after delivering its responses, so poll briefly.
        let deadline = std::time::Instant::now() + Duration::from_secs(2);
        while handle.state() != ModelState::Hot {
            assert!(
                std::time::Instant::now() < deadline,
                "first executed batch never marked the model Hot (state: {})",
                handle.state()
            );
            std::thread::yield_now();
        }
    }

    #[test]
    fn lifecycle_explicit_warm_compiles_plans_before_traffic() {
        let reg = registry();
        let handle = reg
            .load(
                &cfg("m1", 2),
                LoadOptions {
                    warm: true,
                    control: Some(LoadControlConfig {
                        max_threads: 2,
                        tick: Duration::from_secs(3600),
                        ..LoadControlConfig::default()
                    }),
                    ..LoadOptions::default()
                },
            )
            .unwrap();
        assert_eq!(handle.state(), ModelState::Hot);
        let cache = handle.engine().plan_cache().expect("config-built");
        assert!(
            cache.plans_built() > 0,
            "eager warm must compile plans before any traffic"
        );
    }

    #[test]
    fn lifecycle_unload_frees_name_and_releases_plans() {
        let reg = registry();
        let handle = reg.load(&cfg("m1", 3), LoadOptions::default()).unwrap();
        reg.infer_blocking("m1", vec![0.1; 8], Duration::from_secs(5))
            .unwrap();
        let cache = handle.engine().plan_cache().cloned().expect("config-built");
        assert!(cache.plans_built() > 0);
        reg.unload("m1").unwrap();
        assert!(reg.get("m1").is_none(), "unloaded model is gone");
        assert_eq!(cache.plans_built(), 0, "unload releases plan memory");
        assert_eq!(cache.arena_stats().reuses + cache.arena_stats().allocations, 0);
        // The name is immediately re-loadable.
        reg.load(&cfg("m1", 3), LoadOptions::default()).unwrap();
        let resp = reg
            .infer_blocking("m1", vec![0.1; 8], Duration::from_secs(5))
            .unwrap();
        assert!(resp.output.is_ok());
    }

    #[test]
    fn lifecycle_duplicate_load_conflicts() {
        let reg = registry();
        reg.load(&cfg("m1", 4), LoadOptions::default()).unwrap();
        let err = reg.load(&cfg("m1", 4), LoadOptions::default()).unwrap_err();
        assert!(err.to_string().contains("already loaded"), "{err}");
    }

    #[test]
    fn lifecycle_admission_budget_rejects_overload() {
        let reg = registry();
        // max_batch 8 with a 10 s max_wait: the consumer won't take a
        // batch until 8 rows queue, so submits pile up deterministically.
        reg.load(
            &cfg("m1", 5),
            LoadOptions {
                policy: BatchPolicy {
                    max_batch: 8,
                    max_wait: Duration::from_secs(10),
                },
                queue_budget: 2,
                ..LoadOptions::default()
            },
        )
        .unwrap();
        let _rx1 = reg.submit("m1", vec![0.1; 8]).unwrap();
        let _rx2 = reg.submit("m1", vec![0.1; 8]).unwrap();
        let err = reg.submit("m1", vec![0.1; 8]).unwrap_err();
        assert!(err.to_string().contains("overloaded"), "{err}");
        let handle = reg.get("m1").unwrap();
        assert_eq!(
            handle
                .engine()
                .metrics
                .admission_rejections
                .load(Ordering::Relaxed),
            1
        );
        // Queued requests still drain on shutdown (no response lost).
        reg.shutdown();
        assert!(_rx1.recv().unwrap().output.is_ok());
        assert!(_rx2.recv().unwrap().output.is_ok());
    }

    #[test]
    fn lifecycle_draining_model_rejects_new_submits() {
        let reg = registry();
        reg.load(&cfg("m1", 6), LoadOptions::default()).unwrap();
        let handle = reg.get("m1").unwrap();
        handle
            .state
            .store(ModelState::Draining as u8, Ordering::Release);
        let err = reg.submit("m1", vec![0.1; 8]).unwrap_err();
        assert!(err.to_string().contains("draining"), "{err}");
    }

    #[test]
    fn lifecycle_registry_counts_hits_and_misses() {
        let reg = registry();
        reg.load(&cfg("m1", 7), LoadOptions::default()).unwrap();
        assert!(reg.get("m1").is_some());
        assert!(reg.get("nope").is_none());
        assert!(reg.get("m1").is_some());
        assert_eq!(reg.hit_count(), 2);
        assert_eq!(reg.miss_count(), 1);
    }

    #[test]
    fn lifecycle_models_share_one_planner_substrate() {
        let reg = registry();
        let h1 = reg.load(&cfg("m1", 8), LoadOptions::default()).unwrap();
        let h2 = reg.load(&cfg("m2", 9), LoadOptions::default()).unwrap();
        let p1 = h1.engine().plan_cache().unwrap().planner();
        let p2 = h2.engine().plan_cache().unwrap().planner();
        assert!(
            Arc::ptr_eq(p1, p2) && Arc::ptr_eq(p1, reg.planner()),
            "every model's plan cache must sit on the registry's planner"
        );
        assert!(
            !Arc::ptr_eq(
                h1.engine().plan_cache().unwrap(),
                h2.engine().plan_cache().unwrap()
            ),
            "plan caches stay per-model"
        );
    }

    #[test]
    fn lifecycle_balancer_splits_budget_and_caps_idle_models() {
        let reg = registry();
        reg.load(&cfg("hot", 10), LoadOptions::default()).unwrap();
        reg.load(&cfg("cold", 11), LoadOptions::default()).unwrap();
        reg.start_balancer(Duration::from_millis(5));
        // Drive traffic at the hot model only; the cold model's demand
        // signal stays zero.
        for _ in 0..30 {
            reg.infer_blocking("hot", vec![0.2; 8], Duration::from_secs(5))
                .unwrap()
                .output
                .unwrap();
        }
        let hot = reg.get("hot").unwrap();
        let cold = reg.get("cold").unwrap();
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        loop {
            // With all demand on one model, the split hands the hot model
            // the larger share and the idle model the floor.
            if hot.thread_cap() > cold.thread_cap() && cold.thread_cap() == 1 {
                break;
            }
            assert!(
                std::time::Instant::now() < deadline,
                "balancer never skewed the split: hot={} cold={}",
                hot.thread_cap(),
                cold.thread_cap()
            );
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(hot.thread_cap().is_power_of_two());
    }

    #[test]
    fn lifecycle_shutdown_is_idempotent_and_final() {
        let reg = registry();
        reg.load(&cfg("m1", 12), LoadOptions::default()).unwrap();
        reg.start_balancer(Duration::from_millis(10));
        reg.shutdown();
        reg.shutdown(); // second call must be a no-op, not a deadlock
        assert!(reg.submit("m1", vec![0.1; 8]).is_err());
    }

    /// Square dims, as the decode feedback loop requires.
    fn square_cfg(name: &str, seed: u64) -> ModelConfig {
        ModelConfig::from_json(&format!(
            r#"{{"name":"{name}","dims":[8,16,8],"sparsity":0.5,"seed":{seed}}}"#
        ))
        .unwrap()
    }

    #[test]
    fn decode_scheduler_is_lazy_and_drains_with_the_model() {
        let reg = registry();
        let handle = reg
            .load(&square_cfg("m1", 13), LoadOptions::default())
            .unwrap();
        assert!(
            handle.decode_scheduler_if_started().is_none(),
            "no /generate traffic yet — no scheduler"
        );
        let sched = handle.decode_scheduler().unwrap();
        let again = handle.decode_scheduler().unwrap();
        assert!(Arc::ptr_eq(&sched, &again), "one scheduler per model");
        let stream = sched.begin(&[0.25; 8], Some(3)).unwrap();
        let first = stream.next().expect("step loop delivers tokens");
        assert_eq!(first.index, 0);
        reg.unload("m1").unwrap();
        // Drain shut the scheduler down: the stream ends rather than
        // hanging...
        while stream.next().is_some() {}
        // ...and the drained handle refuses to build a replacement.
        let err = handle.decode_scheduler().unwrap_err();
        assert!(err.to_string().contains("draining"), "{err}");
    }

    #[test]
    fn decode_scheduler_requires_square_dims() {
        let reg = registry();
        let handle = reg.load(&cfg("m1", 14), LoadOptions::default()).unwrap();
        let err = handle.decode_scheduler().unwrap_err();
        assert!(err.to_string().contains("d_in == d_out"), "{err}");
    }
}
