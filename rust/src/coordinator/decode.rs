//! Continuous batching for autoregressive decode: the
//! [`DecodeScheduler`] turns concurrent [`DecodeSession`]s into one
//! M-row GEMV-stream batch per step.
//!
//! The decode workload is the paper's motivating traffic shape: a stream
//! of M=1..N GEMVs where per-token overhead decides tokens/sec. The
//! scheduler serves it with three standing guarantees:
//!
//! - **One pinned plan.** At construction the scheduler compiles a single
//!   [`MlpPlan`] via [`crate::plan::PlanCache::decode_plan`]: every layer
//!   pinned to its **M=1-bucket kernel choice**, sized for the session
//!   capacity. Every step — whatever its occupancy `m` — runs through
//!   this one plan, so there is **no per-token plan lookup** and no
//!   kernel change across a session's lifetime. A single active session
//!   therefore runs exactly the tuned M=1 GEMV path.
//! - **Bitwise identity.** Each output row of a row-partitioned GEMM
//!   depends only on its own input row, and per-cell accumulation order
//!   is a property of the prepared format, not of M — so a continuously
//!   batched step is bitwise-identical to stepping each session as an
//!   independent forward (`tests/decode_serving.rs` property-tests this
//!   across session counts × join/leave churn × thread counts).
//! - **Zero steady-state allocation.** The scheduler owns a private
//!   decode [`ActivationArena`] (width `d`): it holds one leased
//!   gather/scatter pair across steps, and every session holds its own
//!   bucket-1 state pair. Leaving sessions return pairs that joining
//!   sessions reuse, so churn past the first sighting allocates nothing
//!   (asserted via [`crate::plan::ArenaStats`]).
//!
//! Sessions join and leave **between** steps: [`DecodeScheduler::begin`]
//! admits a stream (refused 429-style past the capacity, counted in
//! [`Metrics::decode_rejections`]), and a session leaves when its token
//! budget is exhausted, its [`DecodeStream`] is canceled or dropped
//! (client disconnect), or the scheduler shuts down (model drain). Tokens
//! flow sender-per-session: each step sends one [`TokenEvent`] per active
//! session down its channel; dropping the sender is how a stream learns
//! it ended.

use crate::coordinator::metrics::Metrics;
use crate::coordinator::registry::AdmissionController;
use crate::model::session::DecodeSession;
use crate::perf::topology::CpuTopology;
use crate::plan::pipeline::OwnedArenaLease;
use crate::plan::{ActivationArena, ArenaStats, MlpPlan, PlanCache, MAX_M_BUCKET};
use crate::tensor::Matrix;
use crate::util::affinity::{core_set, pin_current_thread, PinOutcome, PlacementPolicy};
use crate::{Error, Result};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::ThreadId;
use std::time::{Duration, Instant};

/// Decode-serving knobs (per model).
#[derive(Debug, Clone)]
pub struct DecodeConfig {
    /// Concurrent-session capacity: `begin` past it is refused
    /// 429-style. Clamped to `[1, MAX_M_BUCKET]` at construction.
    pub max_sessions: usize,
    /// Token budget for streams that don't ask for one.
    pub default_max_tokens: usize,
    /// Placement of the scheduler's tick thread — the thread that runs
    /// every M=1 step inline, so for a lone latency-critical session
    /// *this* is the placement that matters. `Compact` (the default)
    /// pins it to the first performance core; `None` leaves it to the
    /// OS (`--no-pin`). Best-effort like all placement.
    pub placement: PlacementPolicy,
}

impl Default for DecodeConfig {
    fn default() -> Self {
        DecodeConfig {
            max_sessions: 4,
            default_max_tokens: 32,
            placement: PlacementPolicy::Compact,
        }
    }
}

/// One decoded token, as delivered down a session's channel.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TokenEvent {
    /// Position in the stream (0-based).
    pub index: usize,
    /// The synthetic token: argmax index of the output row.
    pub token: u32,
}

/// What [`DecodeStream::next_timeout`] observed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StreamEvent {
    /// A token arrived.
    Token(TokenEvent),
    /// Nothing arrived within the timeout; the stream is still live.
    Idle,
    /// The stream ended: budget exhausted, canceled, or model drained.
    Ended,
}

/// The consumer half of a decode session: a receiver of [`TokenEvent`]s
/// plus a cancel flag the scheduler checks between steps. Dropping the
/// stream (client disconnect) cancels the session — the scheduler notices
/// the hung-up channel on its next send and retires the session cleanly.
pub struct DecodeStream {
    id: u64,
    rx: Receiver<TokenEvent>,
    cancel: Arc<AtomicBool>,
}

impl DecodeStream {
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Ask the scheduler to retire this session before its next step.
    pub fn cancel(&self) {
        self.cancel.store(true, Ordering::Relaxed);
    }

    /// Block until the next token, or `None` when the stream ended.
    pub fn next(&self) -> Option<TokenEvent> {
        self.rx.recv().ok()
    }

    /// Wait up to `timeout` for the next token, distinguishing a quiet
    /// stream from a finished one.
    pub fn next_timeout(&self, timeout: Duration) -> StreamEvent {
        match self.rx.recv_timeout(timeout) {
            Ok(ev) => StreamEvent::Token(ev),
            Err(RecvTimeoutError::Timeout) => StreamEvent::Idle,
            Err(RecvTimeoutError::Disconnected) => StreamEvent::Ended,
        }
    }
}

impl Drop for DecodeStream {
    fn drop(&mut self) {
        self.cancel.store(true, Ordering::Relaxed);
    }
}

/// One admitted session, scheduler-side.
struct ActiveSession {
    session: DecodeSession,
    tx: Sender<TokenEvent>,
    cancel: Arc<AtomicBool>,
    /// Last token delivery, for the inter-token latency histogram.
    last_token: Option<Instant>,
}

/// Lock-protected scheduler state: the session list and the shared
/// gather/scatter buffer pair. One mutex: joins, leaves and steps all
/// serialize on it, which is the "sessions join and leave between steps"
/// semantic by construction.
struct Inner {
    sessions: Vec<ActiveSession>,
    lease: OwnedArenaLease,
}

/// Continuous-batching decode scheduler for one model. See the module
/// docs for the standing guarantees.
pub struct DecodeScheduler {
    model: String,
    plan: Arc<MlpPlan>,
    arena: Arc<ActivationArena>,
    width: usize,
    admission: AdmissionController,
    default_max_tokens: usize,
    metrics: Arc<Metrics>,
    inner: Mutex<Inner>,
    work: Condvar,
    stop: AtomicBool,
    next_id: AtomicU64,
    loop_handle: Mutex<Option<std::thread::JoinHandle<()>>>,
    /// Tick-thread placement (see [`DecodeConfig::placement`]).
    placement: PlacementPolicy,
    /// `(core set, outcome)` the tick thread reported at spawn.
    tick_placement: Mutex<Option<(Vec<usize>, PinOutcome)>>,
    /// The serving-loop thread, once spawned.
    tick_thread: Mutex<Option<ThreadId>>,
    /// The thread that executed the most recent step.
    last_step_thread: Mutex<Option<ThreadId>>,
}

impl DecodeScheduler {
    /// Build the scheduler for a model: compiles the pinned decode plan
    /// (see [`PlanCache::decode_plan`]), sizes a private decode arena and
    /// checks the shared gather/scatter pair out of it.
    ///
    /// # Errors
    /// [`Error::Config`] when the model's `d_in != d_out` (the decode
    /// feedback loop feeds each output row back as the next input) or
    /// when no layers are registered.
    pub fn new(
        model: impl Into<String>,
        cache: &Arc<PlanCache>,
        metrics: Arc<Metrics>,
        cfg: DecodeConfig,
    ) -> Result<DecodeScheduler> {
        let model = model.into();
        let capacity = cfg.max_sessions.clamp(1, MAX_M_BUCKET);
        let plan = cache.decode_plan(capacity)?;
        let (d_in, d_out) = (plan.d_in(), plan.d_out());
        if d_in != d_out {
            return Err(Error::Config(format!(
                "decode requires d_in == d_out (got {d_in} → {d_out}): \
                 each output row is the next step's input row"
            )));
        }
        // Private arena, width d: the gather/scatter pair and every
        // session's state pair lease from here, so decode's
        // zero-allocation steady state is observable on its own counters
        // (the model's forward arena is sized to intermediates, which may
        // be narrower than d).
        let arena = Arc::new(ActivationArena::new(d_in));
        let lease = arena.checkout_owned(plan.bucket());
        Ok(DecodeScheduler {
            model,
            plan,
            arena,
            width: d_in,
            admission: AdmissionController::new(capacity),
            default_max_tokens: cfg.default_max_tokens.max(1),
            metrics,
            inner: Mutex::new(Inner {
                sessions: Vec::with_capacity(capacity),
                lease,
            }),
            work: Condvar::new(),
            stop: AtomicBool::new(false),
            next_id: AtomicU64::new(0),
            loop_handle: Mutex::new(None),
            placement: cfg.placement,
            tick_placement: Mutex::new(None),
            tick_thread: Mutex::new(None),
            last_step_thread: Mutex::new(None),
        })
    }

    /// The model this scheduler serves.
    pub fn model(&self) -> &str {
        &self.model
    }

    /// State-row width (= the model's `d_in` = `d_out`).
    pub fn width(&self) -> usize {
        self.width
    }

    /// Concurrent-session capacity.
    pub fn capacity(&self) -> usize {
        self.admission.budget()
    }

    /// Currently active sessions.
    pub fn active_sessions(&self) -> usize {
        self.inner
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .sessions
            .len()
    }

    /// Decode-arena counters (zero-allocation steady-state assertion).
    pub fn arena_stats(&self) -> ArenaStats {
        self.arena.stats()
    }

    /// The tick-thread placement policy this scheduler was built with.
    pub fn placement(&self) -> PlacementPolicy {
        self.placement
    }

    /// `(core set, outcome)` the tick thread reported when the serving
    /// loop pinned itself (`None` until [`DecodeScheduler::spawn_loop`]).
    pub fn tick_placement(&self) -> Option<(Vec<usize>, PinOutcome)> {
        self.tick_placement
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .clone()
    }

    /// The serving-loop thread id (`None` until the loop was spawned).
    pub fn tick_thread(&self) -> Option<ThreadId> {
        *self.tick_thread.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// The thread that executed the most recent [`DecodeScheduler::step`]
    /// — with the loop running, the pinned tick thread (M=1 steps run
    /// inline on it, which is the satellite guarantee the decode
    /// placement test asserts).
    pub fn last_step_thread(&self) -> Option<ThreadId> {
        *self
            .last_step_thread
            .lock()
            .unwrap_or_else(|e| e.into_inner())
    }

    /// Admit a new session seeded with `prompt`, joining the batch before
    /// the next step. Returns the stream handle tokens arrive on.
    ///
    /// # Errors
    /// [`Error::Serve`] (`"overloaded: …"`, mapped to HTTP 429) at the
    /// session capacity or when the scheduler is draining;
    /// [`Error::Shape`] when the prompt width is not the model's `d`.
    pub fn begin(&self, prompt: &[f32], max_tokens: Option<usize>) -> Result<DecodeStream> {
        if self.stop.load(Ordering::SeqCst) {
            return Err(Error::Serve(format!(
                "model '{}' is draining; no new decode sessions",
                self.model
            )));
        }
        if prompt.len() != self.width {
            return Err(Error::Shape(format!(
                "decode prompt has {} values, model '{}' wants {}",
                prompt.len(),
                self.model,
                self.width
            )));
        }
        let budget = max_tokens.unwrap_or(self.default_max_tokens);
        let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        if !self.admission.admits(inner.sessions.len()) {
            self.metrics
                .decode_rejections
                .fetch_add(1, Ordering::Relaxed);
            return Err(Error::Serve(format!(
                "overloaded: model '{}' is at its decode session capacity ({})",
                self.model,
                self.admission.budget()
            )));
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let session = DecodeSession::new(id, &self.arena, prompt, budget)?;
        let (tx, rx) = channel();
        let cancel = Arc::new(AtomicBool::new(false));
        inner.sessions.push(ActiveSession {
            session,
            tx,
            cancel: Arc::clone(&cancel),
            last_token: None,
        });
        self.metrics
            .decode_sessions_started
            .fetch_add(1, Ordering::Relaxed);
        self.metrics
            .decode_active_sessions
            .store(inner.sessions.len() as u64, Ordering::Relaxed);
        drop(inner);
        self.work.notify_all();
        Ok(DecodeStream { id, rx, cancel })
    }

    /// Run **one** continuous-batching step: retire canceled sessions,
    /// gather every remaining session's state row into the shared M-row
    /// batch, run the pinned plan once, scatter the output rows back,
    /// deliver one token per session, retire exhausted/hung-up sessions.
    /// Returns the number of sessions still active afterwards.
    ///
    /// Public and deterministic on purpose: the bitwise-identity property
    /// tests drive the scheduler step by step, interleaving joins and
    /// leaves exactly where serving would allow them.
    pub fn step(&self) -> Result<usize> {
        *self
            .last_step_thread
            .lock()
            .unwrap_or_else(|e| e.into_inner()) = Some(std::thread::current().id());
        let mut guard = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        let inner = &mut *guard;
        inner
            .sessions
            .retain(|s| !s.cancel.load(Ordering::Relaxed));
        let m = inner.sessions.len();
        if m == 0 {
            self.metrics.decode_active_sessions.store(0, Ordering::Relaxed);
            return Ok(0);
        }
        let width = self.width;
        let Inner { sessions, lease } = inner;
        let (xb, yb) = lease.bufs();
        for (i, s) in sessions.iter_mut().enumerate() {
            xb.row_mut(i)[..width].copy_from_slice(s.session.state());
        }
        let stats = Matrix::with_view(&xb.as_slice()[..m * width], m, width, |x| {
            Matrix::with_view_mut(&mut yb.as_mut_slice()[..m * width], m, width, |y| {
                self.plan.run(x, y)
            })
        })?;
        self.metrics.note_pipeline(&stats);
        let now = Instant::now();
        for (i, s) in sessions.iter_mut().enumerate() {
            let row = &yb.row(i)[..width];
            let token = s.session.absorb_output(row);
            if let Some(prev) = s.last_token.replace(now) {
                self.metrics
                    .intertoken_latency
                    .record(now.duration_since(prev).as_micros() as u64);
            }
            let event = TokenEvent {
                index: s.session.emitted() - 1,
                token,
            };
            // A failed send means the stream was dropped (client
            // disconnect): flag the session so the retain below retires
            // it — its lease returns to the arena for the next join.
            if s.tx.send(event).is_err() {
                s.cancel.store(true, Ordering::Relaxed);
            }
        }
        sessions.retain(|s| !s.cancel.load(Ordering::Relaxed) && !s.session.done());
        let remaining = sessions.len();
        self.metrics
            .decode_active_sessions
            .store(remaining as u64, Ordering::Relaxed);
        self.metrics.note_decode_step(m);
        Ok(remaining)
    }

    /// Start the background serving loop: parked while no sessions are
    /// active (a `begin` wakes it), stepping continuously otherwise. Used
    /// by the serving path; tests drive [`DecodeScheduler::step`]
    /// directly instead.
    pub fn spawn_loop(self: &Arc<Self>) {
        let mut slot = self.loop_handle.lock().unwrap_or_else(|e| e.into_inner());
        if slot.is_some() {
            return;
        }
        let me = Arc::clone(self);
        *slot = Some(std::thread::spawn(move || {
            me.pin_tick_thread();
            loop {
                {
                    let mut inner = me.inner.lock().unwrap_or_else(|e| e.into_inner());
                    while inner.sessions.is_empty() && !me.stop.load(Ordering::SeqCst) {
                        inner = me.work.wait(inner).unwrap_or_else(|e| e.into_inner());
                    }
                }
                if me.stop.load(Ordering::SeqCst) {
                    break;
                }
                if me.step().is_err() {
                    // A typed step failure (worker panic surfacing as
                    // Error::Runtime) retires every session — their streams
                    // end — instead of spinning on a broken plan.
                    me.retire_all();
                }
                // The step loop and `begin` contend on one mutex; yielding
                // between steps keeps joins from starving under a hot loop.
                std::thread::yield_now();
            }
        }));
    }

    /// Pin the serving-loop (tick) thread per the configured placement.
    /// M=1 steps execute inline on this thread, so a `Compact` placement
    /// parks the lone-session decode path on the first performance core;
    /// `None` skips the syscall entirely and records `Unrestricted`.
    fn pin_tick_thread(&self) {
        let topo = CpuTopology::host();
        let cores = core_set(self.placement, topo, 0, 1);
        let outcome = if self.placement == PlacementPolicy::None {
            PinOutcome::Unrestricted
        } else {
            pin_current_thread(topo, &cores)
        };
        *self
            .tick_placement
            .lock()
            .unwrap_or_else(|e| e.into_inner()) = Some((cores, outcome));
        *self.tick_thread.lock().unwrap_or_else(|e| e.into_inner()) =
            Some(std::thread::current().id());
    }

    /// Retire every active session: their senders drop, so every stream
    /// observes `Ended`.
    fn retire_all(&self) {
        let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        inner.sessions.clear();
        self.metrics.decode_active_sessions.store(0, Ordering::Relaxed);
    }

    /// Drain the scheduler: refuse new sessions, stop and join the
    /// serving loop, retire every active session (streams observe
    /// `Ended`). Idempotent; the registry calls this on model drain.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
        self.work.notify_all();
        let handle = self
            .loop_handle
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .take();
        if let Some(h) = handle {
            let _ = h.join();
        }
        self.retire_all();
    }
}

impl Drop for DecodeScheduler {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ModelConfig, TernaryMlp};
    use crate::plan::Planner;

    fn scheduler(max_sessions: usize) -> (Arc<DecodeScheduler>, Arc<PlanCache>) {
        let cfg = ModelConfig::from_json(
            r#"{"name":"dec","dims":[32,64,32],"sparsity":0.25,"seed":11,
                "kernel":"base_tcsc"}"#,
        )
        .unwrap();
        let mlp = TernaryMlp::planned(&cfg, &Arc::new(Planner::new())).unwrap();
        let cache = Arc::clone(mlp.plan_cache().expect("config-built"));
        let sched = DecodeScheduler::new(
            "dec",
            &cache,
            Arc::new(Metrics::new()),
            DecodeConfig {
                max_sessions,
                default_max_tokens: 4,
                ..DecodeConfig::default()
            },
        )
        .unwrap();
        (Arc::new(sched), cache)
    }

    fn prompt(width: usize, seed: u64) -> Vec<f32> {
        let m = Matrix::random(1, width, seed);
        m.row(0).to_vec()
    }

    #[test]
    fn single_session_streams_its_budget_then_ends() {
        let (sched, _) = scheduler(2);
        let stream = sched.begin(&prompt(32, 3), Some(3)).unwrap();
        while sched.step().unwrap() > 0 {}
        let mut tokens = Vec::new();
        while let Some(ev) = stream.next() {
            tokens.push(ev);
        }
        assert_eq!(tokens.len(), 3);
        assert_eq!(
            tokens.iter().map(|e| e.index).collect::<Vec<_>>(),
            vec![0, 1, 2]
        );
        assert_eq!(sched.active_sessions(), 0);
    }

    #[test]
    fn admission_refuses_past_capacity_and_recovers() {
        let (sched, _) = scheduler(2);
        let a = sched.begin(&prompt(32, 1), Some(1)).unwrap();
        let _b = sched.begin(&prompt(32, 2), Some(8)).unwrap();
        let err = sched.begin(&prompt(32, 3), Some(1)).unwrap_err();
        assert!(
            err.to_string().contains("overloaded"),
            "429-style rejection: {err}"
        );
        sched.step().unwrap(); // session a exhausts its budget of 1
        assert_eq!(a.next().unwrap().index, 0);
        assert!(a.next().is_none(), "ended after budget");
        sched
            .begin(&prompt(32, 4), Some(1))
            .expect("capacity freed by the finished session");
    }

    #[test]
    fn dropped_stream_retires_its_session() {
        let (sched, _) = scheduler(4);
        let keep = sched.begin(&prompt(32, 5), Some(16)).unwrap();
        let dropped = sched.begin(&prompt(32, 6), Some(16)).unwrap();
        drop(dropped); // client disconnect
        sched.step().unwrap();
        assert_eq!(
            sched.active_sessions(),
            1,
            "canceled session retired before the step"
        );
        assert!(matches!(
            keep.next_timeout(Duration::from_secs(5)),
            StreamEvent::Token(_)
        ));
    }

    #[test]
    fn shutdown_ends_streams_and_refuses_new_sessions() {
        let (sched, _) = scheduler(4);
        sched.spawn_loop();
        let stream = sched.begin(&prompt(32, 7), Some(1_000_000)).unwrap();
        assert!(matches!(
            stream.next_timeout(Duration::from_secs(10)),
            StreamEvent::Token(_)
        ));
        sched.shutdown();
        // Drain the channel: it must END (disconnect), not idle forever.
        loop {
            match stream.next_timeout(Duration::from_secs(10)) {
                StreamEvent::Token(_) => continue,
                StreamEvent::Ended => break,
                StreamEvent::Idle => panic!("drained stream must disconnect"),
            }
        }
        assert!(sched.begin(&prompt(32, 8), Some(1)).is_err());
    }

    #[test]
    fn lone_session_steps_on_the_pinned_tick_thread() {
        let (sched, _) = scheduler(2);
        assert_eq!(sched.placement(), PlacementPolicy::Compact);
        assert!(sched.tick_placement().is_none(), "loop not spawned yet");
        sched.spawn_loop();
        let stream = sched.begin(&prompt(32, 9), Some(2)).unwrap();
        loop {
            match stream.next_timeout(Duration::from_secs(10)) {
                StreamEvent::Token(_) => continue,
                StreamEvent::Ended => break,
                StreamEvent::Idle => panic!("lone session must make progress"),
            }
        }
        let (cores, outcome) = sched.tick_placement().expect("loop pinned at spawn");
        assert!(!cores.is_empty(), "compact placement names a core");
        // The pin may legitimately fail in restricted sandboxes; what is
        // asserted is that the attempt happened and was recorded.
        let _ = outcome.as_str();
        // The whole point of satellite 2: a lone M=1 session's steps run
        // inline on the scheduler's own (pinned) tick thread.
        assert_eq!(
            sched.last_step_thread().expect("a step ran"),
            sched.tick_thread().expect("loop spawned"),
        );
    }

    #[test]
    fn mismatched_dims_are_a_config_error() {
        let cfg = ModelConfig::from_json(
            r#"{"name":"bad","dims":[32,64,16],"sparsity":0.25,"seed":1}"#,
        )
        .unwrap();
        let mlp = TernaryMlp::planned(&cfg, &Arc::new(Planner::new())).unwrap();
        let cache = Arc::clone(mlp.plan_cache().unwrap());
        let err = DecodeScheduler::new(
            "bad",
            &cache,
            Arc::new(Metrics::new()),
            DecodeConfig::default(),
        )
        .unwrap_err();
        assert!(matches!(err, Error::Config(_)), "{err}");
    }
}
