//! Load-aware control: derives per-model `max_batch` and worker-thread
//! targets from observed traffic instead of static config.
//!
//! Signals (all maintained by the batcher/engine in [`Metrics`]):
//! - arrival rate (EWMA of inter-arrival gaps),
//! - current queue depth,
//! - mean batch compute latency.
//!
//! Policy, kept deliberately simple and fully unit-testable:
//! - **Batch size** follows Little's law: the number of arrivals expected
//!   within the queueing-latency budget (`target_queue_us`) is the largest
//!   batch the batcher can close without blowing that budget. Growing M is
//!   free for these kernels (paper Fig 8), so we take every row the budget
//!   allows.
//! - **Threads** follow compute pressure: if one batch takes longer to
//!   compute than the gap between batches, the loop falls behind — fan
//!   out until a batch drains before the next one fills. Thread targets
//!   snap to powers of two so the plan cache only ever materializes a
//!   handful of (bucket, threads) keys.
//! - A queue deeper than twice the batch ceiling means we are already
//!   behind regardless of what the averages claim — go maximally wide.

use crate::coordinator::metrics::Metrics;
use std::sync::atomic::Ordering;

/// Controller limits and targets.
#[derive(Debug, Clone)]
pub struct LoadControlConfig {
    /// Queueing-latency budget the batcher may spend coalescing rows (µs).
    pub target_queue_us: u64,
    /// Lower bound for the advised batch ceiling.
    pub min_batch: usize,
    /// Upper bound for the advised batch ceiling (e.g. the largest
    /// compiled bucket, or a memory bound).
    pub max_batch: usize,
    /// Upper bound for the advised worker-thread count (advice snaps to
    /// powers of two ≤ this).
    pub max_threads: usize,
    /// Re-advise cadence, in executed batches.
    pub adjust_every_batches: u64,
    /// Timer-driven re-advise cadence. The batch-count cadence alone
    /// never fires on an idle model (no batches execute), so a burst's
    /// elevated batch/thread targets would stick forever; the timer tick
    /// decays them, gated by [`AdviceHysteresis`].
    pub tick: std::time::Duration,
}

impl Default for LoadControlConfig {
    fn default() -> Self {
        LoadControlConfig {
            target_queue_us: 2000,
            min_batch: 1,
            max_batch: 64,
            max_threads: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4),
            adjust_every_batches: 16,
            tick: std::time::Duration::from_millis(250),
        }
    }
}

/// One piece of controller output.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Advice {
    pub max_batch: usize,
    pub threads: usize,
}

/// Largest power of two ≤ `n` (1 for `n == 0`). Thread advice snaps down
/// to this so the plan cache only ever materializes pow2 thread keys —
/// `min(t, max_threads)` alone would leak the raw ceiling through on
/// non-pow2 core counts (e.g. the 6 P-cores of an Apple M-series part).
pub(crate) fn pow2_floor(n: usize) -> usize {
    match n {
        0 => 1,
        n => 1usize << (usize::BITS - 1 - n.leading_zeros()),
    }
}

/// Split a fleet-wide worker-thread budget across models by observed
/// demand (the registry's balancer feeds arrival-rate × compute-EWMA per
/// model). Each model's share is its demand-proportional slice of the
/// budget, snapped down to a power of two (the plan-cache key invariant)
/// with a floor of one thread. The shares are **caps, not reservations**:
/// a model with zero demand keeps the full pow2 budget as its cap — an
/// idle fleet shouldn't throttle the first model to wake up — while any
/// nonzero skew immediately squeezes the idle models to the floor.
pub fn split_thread_budget(total: usize, demands: &[f64]) -> Vec<usize> {
    let total = total.max(1);
    if demands.is_empty() {
        return Vec::new();
    }
    let sum: f64 = demands.iter().map(|d| d.max(0.0)).sum();
    if sum <= 0.0 {
        return vec![pow2_floor(total); demands.len()];
    }
    demands
        .iter()
        .map(|&d| {
            let share = (d.max(0.0) / sum * total as f64).floor() as usize;
            pow2_floor(share.max(1)).min(pow2_floor(total))
        })
        .collect()
}

/// Two-consecutive-tick hysteresis for timer-driven advice: a target
/// change is applied only after the controller has advised the *same*
/// differing target on two ticks in a row, so a single noisy sample
/// (e.g. one straggler batch inflating the compute EWMA) cannot make
/// the batch/thread targets oscillate.
#[derive(Debug, Default)]
pub struct AdviceHysteresis {
    pending: Option<Advice>,
}

impl AdviceHysteresis {
    /// Feed one tick's advice; returns the advice to apply, if any.
    pub fn observe(&mut self, advice: Advice, current: Advice) -> Option<Advice> {
        if advice == current {
            self.pending = None;
            return None;
        }
        if self.pending == Some(advice) {
            self.pending = None;
            return Some(advice);
        }
        self.pending = Some(advice);
        None
    }
}

/// Pure-function load controller (state lives in [`Metrics`]).
pub struct LoadController {
    cfg: LoadControlConfig,
}

impl LoadController {
    pub fn new(cfg: LoadControlConfig) -> LoadController {
        LoadController {
            cfg: LoadControlConfig {
                min_batch: cfg.min_batch.max(1),
                max_batch: cfg.max_batch.max(cfg.min_batch.max(1)),
                max_threads: cfg.max_threads.max(1),
                adjust_every_batches: cfg.adjust_every_batches.max(1),
                tick: cfg.tick.max(std::time::Duration::from_millis(1)),
                ..cfg
            },
        }
    }

    pub fn cfg(&self) -> &LoadControlConfig {
        &self.cfg
    }

    /// Advise batch/thread targets from raw signals.
    pub fn advise(
        &self,
        queue_depth: usize,
        arrival_rps: f64,
        mean_compute_us: f64,
    ) -> Advice {
        // Little's law: arrivals expected inside the queueing budget.
        let expected =
            (arrival_rps * self.cfg.target_queue_us as f64 / 1e6).ceil() as usize;
        // Whatever is already queued should also ride the next batch (it
        // has waited its share of the budget), up to the ceiling.
        let max_batch = expected
            .max(queue_depth)
            .clamp(self.cfg.min_batch, self.cfg.max_batch);

        // Compute pressure: batch compute time vs the time one batch takes
        // to fill. Pressure > 1 means the consumer loop cannot keep up
        // single-threaded; each doubling of workers roughly halves the
        // batch compute time (row partitioning is embarrassingly parallel).
        // Advice always lands on a power of two ≤ `max_threads`: the plan
        // cache keys plans by thread count, and pow2 steps keep that key
        // set to a handful even on non-pow2 core counts.
        let t_cap = pow2_floor(self.cfg.max_threads);
        let threads = if queue_depth > 2 * max_batch {
            t_cap
        } else if arrival_rps > 0.0 && mean_compute_us > 0.0 {
            let batch_fill_us = max_batch as f64 * 1e6 / arrival_rps;
            let pressure = mean_compute_us / batch_fill_us.max(1.0);
            let mut t = 1usize;
            while (t as f64) < pressure && t < t_cap {
                t *= 2;
            }
            t.min(t_cap)
        } else {
            1
        };
        Advice { max_batch, threads }
    }

    /// Advise from a model's live metrics. Uses the compute-latency EWMA
    /// (not the lifetime mean) so thread advice tracks load *shifts*: an
    /// hour of tiny batches must not mask a sudden move to heavy ones.
    pub fn advise_from(&self, metrics: &Metrics) -> Advice {
        self.advise(
            metrics.queue_depth.load(Ordering::Relaxed) as usize,
            metrics.arrival_rate_rps(),
            metrics.compute_ewma_us(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn controller() -> LoadController {
        LoadController::new(LoadControlConfig {
            target_queue_us: 2000,
            min_batch: 1,
            max_batch: 64,
            max_threads: 8,
            adjust_every_batches: 16,
            ..LoadControlConfig::default()
        })
    }

    #[test]
    fn idle_traffic_gets_minimum_batch_and_one_thread() {
        let c = controller();
        let a = c.advise(0, 0.0, 0.0);
        assert_eq!(a, Advice { max_batch: 1, threads: 1 });
        // A trickle (10 req/s, fast compute) stays small and sequential.
        let a = c.advise(0, 10.0, 50.0);
        assert_eq!(a.max_batch, 1);
        assert_eq!(a.threads, 1);
    }

    #[test]
    fn heavy_arrivals_grow_the_batch_to_the_cap() {
        let c = controller();
        // 100k req/s × 2 ms budget = 200 expected rows → clamped to 64.
        let a = c.advise(0, 100_000.0, 100.0);
        assert_eq!(a.max_batch, 64);
        // Moderate load lands between the bounds.
        let a = c.advise(0, 4_000.0, 10.0);
        assert_eq!(a.max_batch, 8, "4k rps × 2ms = 8 rows");
    }

    #[test]
    fn queued_rows_ride_the_next_batch() {
        let c = controller();
        let a = c.advise(24, 100.0, 10.0);
        assert_eq!(a.max_batch, 24, "existing queue sets the floor");
    }

    #[test]
    fn compute_pressure_fans_threads_out_in_pow2_steps() {
        let c = controller();
        // Batch of 8 fills in 2 ms; compute takes 7 ms → pressure 3.5 →
        // 4 threads.
        let a = c.advise(0, 4_000.0, 7_000.0);
        assert_eq!(a.max_batch, 8);
        assert_eq!(a.threads, 4);
        // Light compute stays sequential.
        let a = c.advise(0, 4_000.0, 100.0);
        assert_eq!(a.threads, 1);
        // Absurd pressure clamps at the ceiling.
        let a = c.advise(0, 4_000.0, 10_000_000.0);
        assert_eq!(a.threads, 8);
    }

    #[test]
    fn deep_queue_forces_max_width() {
        let c = controller();
        // Depth 40 > 2 × advised batch? advised batch = max(1, 40) = 40,
        // 40 is not > 80 → normal path. Use a tiny cap to trigger.
        let tight = LoadController::new(LoadControlConfig {
            max_batch: 8,
            max_threads: 8,
            ..LoadControlConfig::default()
        });
        let a = tight.advise(40, 10.0, 10.0);
        assert_eq!(a.max_batch, 8);
        assert_eq!(a.threads, 8, "deep backlog → all workers");
    }

    #[test]
    fn thread_advice_is_pow2_on_non_pow2_ceilings() {
        // Regression: `max_threads: 6` with pressure ~5 used to advise
        // t=8 → min(8, 6) = 6 — a non-pow2 thread count that violates the
        // pow2-steps invariant the plan cache relies on. Real on Apple
        // M-series parts, whose P-core counts are not powers of two.
        let c = LoadController::new(LoadControlConfig {
            max_batch: 8,
            max_threads: 6,
            ..LoadControlConfig::default()
        });
        // Batch of 8 fills in 2 ms; compute takes 10 ms → pressure 5.
        let a = c.advise(0, 4_000.0, 10_000.0);
        assert_eq!(a.threads, 4, "largest pow2 ≤ 6");
        // Deep backlog goes maximally wide — still pow2.
        let a = c.advise(100, 10.0, 10.0);
        assert_eq!(a.threads, 4);
        // Every advised value across a sweep of signals is pow2 ≤ cap.
        for &(q, rps, us) in &[
            (0usize, 0.0f64, 0.0f64),
            (3, 100.0, 5_000.0),
            (50, 50_000.0, 50_000.0),
            (7, 1e9, 1e9),
        ] {
            let a = c.advise(q, rps, us);
            assert!(a.threads.is_power_of_two() && a.threads <= 6, "{a:?}");
        }
    }

    #[test]
    fn hysteresis_applies_only_after_two_consecutive_ticks() {
        let cur = Advice { max_batch: 8, threads: 4 };
        let decay = Advice { max_batch: 1, threads: 1 };
        let other = Advice { max_batch: 2, threads: 2 };
        let mut h = AdviceHysteresis::default();
        // Advice equal to the current targets never applies (and clears
        // any pending change).
        assert_eq!(h.observe(cur, cur), None);
        // A change needs two consecutive identical ticks.
        assert_eq!(h.observe(decay, cur), None);
        assert_eq!(h.observe(decay, cur), Some(decay));
        // A flapping signal never applies...
        assert_eq!(h.observe(decay, cur), None);
        assert_eq!(h.observe(other, cur), None);
        assert_eq!(h.observe(decay, cur), None);
        // ...and settling back to current resets the pending change.
        assert_eq!(h.observe(cur, cur), None);
        assert_eq!(h.observe(decay, cur), None);
        assert_eq!(h.observe(decay, cur), Some(decay));
    }

    #[test]
    fn thread_budget_splits_by_demand_in_pow2_shares() {
        // Heavy skew: the hot model takes (nearly) everything, the cold
        // one keeps the one-thread floor.
        assert_eq!(split_thread_budget(8, &[3000.0, 100.0]), vec![4, 1]);
        // Even demand splits evenly.
        assert_eq!(split_thread_budget(8, &[1.0, 1.0]), vec![4, 4]);
        // All demand on one model hands it the whole budget.
        assert_eq!(split_thread_budget(8, &[10.0, 0.0]), vec![8, 1]);
        // Idle fleet: shares are caps, not reservations — nobody is
        // throttled below the full pow2 budget.
        assert_eq!(split_thread_budget(8, &[0.0, 0.0]), vec![8, 8]);
        // Degenerate shapes stay sane.
        assert_eq!(split_thread_budget(0, &[1.0]), vec![1]);
        assert!(split_thread_budget(8, &[]).is_empty());
        // Non-pow2 budget snaps each share down to pow2.
        for share in split_thread_budget(6, &[5.0, 3.0, 1.0]) {
            assert!(share.is_power_of_two() && share <= 4);
        }
    }

    #[test]
    fn config_bounds_are_sanitized() {
        let c = LoadController::new(LoadControlConfig {
            min_batch: 0,
            max_batch: 0,
            max_threads: 0,
            adjust_every_batches: 0,
            ..LoadControlConfig::default()
        });
        assert_eq!(c.cfg().min_batch, 1);
        assert_eq!(c.cfg().max_batch, 1);
        assert_eq!(c.cfg().max_threads, 1);
        assert_eq!(c.cfg().adjust_every_batches, 1);
        let a = c.advise(100, 1e9, 1e9);
        assert_eq!(a, Advice { max_batch: 1, threads: 1 });
    }
}
