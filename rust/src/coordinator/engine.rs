//! Inference engine: executes batches on the native ternary kernels (via
//! the planning layer's [`crate::plan::GemmPlan`]s inside the model) or the
//! PJRT-compiled JAX/Pallas artifact, and can cross-check the two.

use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::{InferenceRequest, InferenceResponse};
use crate::model::TernaryMlp;
use crate::runtime::XlaExecutor;
use crate::tensor::Matrix;
use crate::{Error, Result};
use std::sync::Arc;
use std::time::Instant;

/// Which execution path serves requests.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// Rust sparse ternary kernels (the paper's system).
    Native,
    /// PJRT executable compiled from the JAX/Pallas AOT artifact.
    Xla,
}

impl std::str::FromStr for Backend {
    type Err = Error;

    fn from_str(s: &str) -> Result<Backend> {
        match s {
            "native" => Ok(Backend::Native),
            "xla" => Ok(Backend::Xla),
            other => Err(Error::Config(format!("unknown backend '{other}' (native|xla)"))),
        }
    }
}

/// One served model: native MLP (always present) + optional XLA executor.
pub struct Engine {
    pub name: String,
    mlp: TernaryMlp,
    xla: Option<XlaExecutor>,
    pub backend: Backend,
    pub metrics: Arc<Metrics>,
}

impl Engine {
    pub fn new(name: impl Into<String>, mlp: TernaryMlp) -> Engine {
        Engine {
            name: name.into(),
            mlp,
            xla: None,
            backend: Backend::Native,
            metrics: Arc::new(Metrics::new()),
        }
    }

    /// Build the native model through the planning layer: every layer's
    /// kernel comes from the shared `planner` (tuning table + paper
    /// heuristics, refined by the plan cache's online top-2 race) unless
    /// the config pins an explicit override. Batches served by
    /// [`Engine::run_batch`] execute through M-bucketed cached
    /// [`crate::plan::GemmPlan`]s (allocation-stable scratch, row-parallel
    /// fan-out seeded by the config's `threads` and re-sizable at runtime
    /// via [`Engine::set_threads`]).
    pub fn from_config(
        cfg: &crate::model::ModelConfig,
        planner: &Arc<crate::plan::Planner>,
    ) -> Result<Engine> {
        Ok(Engine::new(
            cfg.name.clone(),
            TernaryMlp::planned(cfg, planner)?,
        ))
    }

    /// The model's shared plan cache (config-built models only).
    pub fn plan_cache(&self) -> Option<&Arc<crate::plan::PlanCache>> {
        self.mlp.plan_cache()
    }

    /// Re-size the worker-thread ceiling for the model's cached plans
    /// (no-op for explicit-layer models). Called by the load-aware router.
    pub fn set_threads(&self, threads: usize) {
        self.mlp.set_threads(threads);
    }

    /// Attach an XLA executor (enables `Backend::Xla` and cross-checks).
    pub fn with_xla(mut self, xla: XlaExecutor) -> Engine {
        assert_eq!(xla.d_in, self.mlp.d_in(), "XLA d_in mismatch");
        assert_eq!(xla.d_out, self.mlp.d_out(), "XLA d_out mismatch");
        self.xla = Some(xla);
        self
    }

    pub fn with_backend(mut self, backend: Backend) -> Engine {
        if backend == Backend::Xla {
            assert!(self.xla.is_some(), "XLA backend requires an executor");
        }
        self.backend = backend;
        self
    }

    pub fn d_in(&self) -> usize {
        self.mlp.d_in()
    }

    pub fn d_out(&self) -> usize {
        self.mlp.d_out()
    }

    pub fn has_xla(&self) -> bool {
        self.xla.is_some()
    }

    /// Run a raw batch matrix on the configured backend.
    pub fn infer_matrix(&self, x: &Matrix) -> Result<Matrix> {
        match self.backend {
            Backend::Native => self.mlp.forward(x),
            Backend::Xla => self
                .xla
                .as_ref()
                .expect("backend checked at construction")
                .run(x)
                .map_err(|e| Error::Runtime(format!("{e:#}"))),
        }
    }

    /// Run a batch on *both* backends and return (native, xla, max |Δ|).
    pub fn cross_check(&self, x: &Matrix) -> Result<(Matrix, Matrix, f32)> {
        let xla = self
            .xla
            .as_ref()
            .ok_or_else(|| Error::Runtime("cross-check requires an XLA executor".into()))?;
        let native = self.mlp.forward(x)?;
        let xla_out = xla.run(x).map_err(|e| Error::Runtime(format!("{e:#}")))?;
        let diff = native.max_abs_diff(&xla_out);
        Ok((native, xla_out, diff))
    }

    /// Execute one assembled batch of requests: validates inputs, packs the
    /// batch matrix, runs the backend, and delivers per-request responses.
    pub fn run_batch(&self, batch: Vec<InferenceRequest>) {
        if batch.is_empty() {
            return;
        }
        let d_in = self.d_in();
        // Partition valid/invalid without losing anybody.
        let mut valid = Vec::with_capacity(batch.len());
        for req in batch {
            if req.input.len() == d_in {
                valid.push(req);
            } else {
                self.metrics
                    .errors
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                let len = req.input.len();
                req.reject(Error::Shape(format!("input length {len} != d_in {d_in}")));
            }
        }
        if valid.is_empty() {
            return;
        }
        let m = valid.len();
        self.metrics.record_batch(m);
        let mut x = Matrix::zeros(m, d_in);
        for (r, req) in valid.iter().enumerate() {
            x.row_mut(r).copy_from_slice(&req.input);
        }
        let t0 = Instant::now();
        // The native path runs through `forward_into_stats` so wavefront
        // scheduler observability (depth/stall) lands in the metrics the
        // load controller's queue model reads.
        let result = match self.backend {
            Backend::Native => {
                let mut y = Matrix::zeros(m, self.d_out());
                self.mlp.forward_into_stats(&x, &mut y).map(|stats| {
                    if let Some(stats) = stats {
                        self.metrics.note_pipeline(&stats);
                    }
                    y
                })
            }
            Backend::Xla => self.infer_matrix(&x),
        };
        let compute_us = t0.elapsed().as_micros() as u64;
        self.metrics.compute_latency.record(compute_us);
        self.metrics.note_compute(compute_us);
        match result {
            Ok(y) => {
                for (r, req) in valid.into_iter().enumerate() {
                    let queue_us = req.enqueued.elapsed().as_micros() as u64;
                    self.metrics.queue_latency.record(queue_us);
                    self.metrics.e2e_latency.record(queue_us); // queue incl. compute
                    self.metrics
                        .responses
                        .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    let _ = req.resp_tx.send(InferenceResponse {
                        id: req.id,
                        output: Ok(y.row(r).to_vec()),
                        queue_us,
                        compute_us,
                        batch_size: m,
                    });
                }
            }
            Err(e) => {
                for req in valid {
                    self.metrics
                        .errors
                        .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    let _ = req.resp_tx.send(InferenceResponse {
                        id: req.id,
                        output: Err(e.clone()),
                        queue_us: req.enqueued.elapsed().as_micros() as u64,
                        compute_us,
                        batch_size: m,
                    });
                }
            }
        }
    }

    /// Cost-model flops for a batch of `m` rows (reporting).
    pub fn flops(&self, m: usize) -> f64 {
        self.mlp.flops(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;

    fn engine() -> Engine {
        let cfg = ModelConfig::from_json(
            r#"{"name":"t","dims":[16,32,8],"sparsity":0.25,"seed":3}"#,
        )
        .unwrap();
        Engine::from_config(&cfg, &Arc::new(crate::plan::Planner::new())).unwrap()
    }

    #[test]
    fn run_batch_delivers_all_responses() {
        let e = engine();
        let mut rxs = Vec::new();
        let mut batch = Vec::new();
        for i in 0..5 {
            let (req, rx) = InferenceRequest::new(i, "t", vec![0.1; 16]);
            batch.push(req);
            rxs.push(rx);
        }
        e.run_batch(batch);
        for (i, rx) in rxs.into_iter().enumerate() {
            let resp = rx.recv().unwrap();
            assert_eq!(resp.id, i as u64);
            let out = resp.output.unwrap();
            assert_eq!(out.len(), 8);
            assert_eq!(resp.batch_size, 5);
        }
        assert_eq!(
            e.metrics
                .responses
                .load(std::sync::atomic::Ordering::Relaxed),
            5
        );
    }

    #[test]
    fn batch_output_matches_single_row_runs() {
        let e = engine();
        let x1 = vec![0.5f32; 16];
        let x2: Vec<f32> = (0..16).map(|i| i as f32 * 0.1).collect();
        let (ra, rxa) = InferenceRequest::new(1, "t", x1.clone());
        let (rb, rxb) = InferenceRequest::new(2, "t", x2.clone());
        e.run_batch(vec![ra, rb]);
        let ya = rxa.recv().unwrap().output.unwrap();
        let yb = rxb.recv().unwrap().output.unwrap();

        // Single-row ground truth.
        let m1 = Matrix::from_slice(1, 16, &x1);
        let m2 = Matrix::from_slice(1, 16, &x2);
        let s1 = e.infer_matrix(&m1).unwrap();
        let s2 = e.infer_matrix(&m2).unwrap();
        for (a, b) in ya.iter().zip(s1.as_slice()) {
            assert!((a - b).abs() < 1e-5);
        }
        for (a, b) in yb.iter().zip(s2.as_slice()) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn pipelined_serving_records_metrics() {
        let e = engine();
        // Batch 1 races the untuned classes (barrier fallback); batch 2+
        // runs the wavefront pipeline and records its stats.
        for round in 0..3u64 {
            let mut batch = Vec::new();
            let mut rxs = Vec::new();
            for i in 0..4u64 {
                let (req, rx) = InferenceRequest::new(round * 10 + i, "t", vec![0.1; 16]);
                batch.push(req);
                rxs.push(rx);
            }
            e.run_batch(batch);
            for rx in rxs {
                rx.recv().unwrap().output.unwrap();
            }
        }
        use std::sync::atomic::Ordering;
        assert!(e.metrics.pipeline_runs.load(Ordering::Relaxed) >= 1);
        assert!(e.metrics.pipeline_depth.load(Ordering::Relaxed) >= 1);
        let cache = e.plan_cache().expect("config-built engine");
        assert!(cache.snapshot().pipeline_plans >= 1);
    }

    #[test]
    fn invalid_input_gets_error_response() {
        let e = engine();
        let (good, rx_good) = InferenceRequest::new(1, "t", vec![0.0; 16]);
        let (bad, rx_bad) = InferenceRequest::new(2, "t", vec![0.0; 3]);
        e.run_batch(vec![good, bad]);
        assert!(rx_good.recv().unwrap().output.is_ok());
        assert!(rx_bad.recv().unwrap().output.is_err());
        assert_eq!(
            e.metrics.errors.load(std::sync::atomic::Ordering::Relaxed),
            1
        );
    }

    #[test]
    fn backend_parse() {
        assert_eq!("native".parse::<Backend>().unwrap(), Backend::Native);
        assert_eq!("xla".parse::<Backend>().unwrap(), Backend::Xla);
        assert!("gpu".parse::<Backend>().is_err());
    }
}
