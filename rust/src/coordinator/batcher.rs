//! Dynamic batcher: accumulates single-row requests into GEMM batches.
//!
//! Policy: a batch closes when it reaches `max_batch` rows OR the oldest
//! queued request has waited `max_wait`. Growing M is performance-neutral
//! for the paper's kernels (Fig 8: performance is constant across M/N), so
//! batching converts latency headroom directly into throughput.
//!
//! `max_batch` is a *live* knob: the load-aware router re-sizes it from
//! observed arrival rate and queue depth ([`DynamicBatcher::set_max_batch`]),
//! and the batcher reports queue depth and arrivals into the engine's
//! [`Metrics`] so the controller has signals to steer by.

use crate::coordinator::metrics::Metrics;
use crate::coordinator::registry::AdmissionController;
use crate::coordinator::request::InferenceRequest;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Batch assembly policy.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Maximum rows per batch (should match the largest compiled bucket).
    pub max_batch: usize,
    /// Maximum time the oldest request may wait before the batch closes.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
        }
    }
}

/// Why [`DynamicBatcher::submit`] refused a request (the request rides
/// along so the caller can deliver an error response or retry elsewhere).
#[derive(Debug)]
pub enum SubmitError {
    /// The batcher was shut down.
    Closed(InferenceRequest),
    /// The request carried a zero-length input row: it would contribute
    /// nothing to a GEMM batch and can never produce output.
    EmptyInput(InferenceRequest),
    /// The model's admission queue budget is exhausted: accepting the
    /// request would grow the queue past what the fleet is willing to
    /// hold for this model (429-style backpressure, not shutdown).
    Overloaded(InferenceRequest),
}

struct QueueState {
    queue: VecDeque<InferenceRequest>,
    closed: bool,
}

/// Thread-safe dynamic batching queue (Mutex + Condvar; producers are
/// server connections, the consumer is the model's batch loop).
pub struct DynamicBatcher {
    max_wait: Duration,
    max_batch: AtomicUsize,
    state: Mutex<QueueState>,
    cv: Condvar,
    metrics: Option<Arc<Metrics>>,
    admission: Option<Arc<AdmissionController>>,
}

impl DynamicBatcher {
    pub fn new(policy: BatchPolicy) -> DynamicBatcher {
        assert!(policy.max_batch >= 1);
        DynamicBatcher {
            max_wait: policy.max_wait,
            max_batch: AtomicUsize::new(policy.max_batch),
            state: Mutex::new(QueueState {
                queue: VecDeque::new(),
                closed: false,
            }),
            cv: Condvar::new(),
            metrics: None,
            admission: None,
        }
    }

    /// Report queue depth and arrivals into `metrics` (the load-aware
    /// coordinator's signal source).
    pub fn with_metrics(mut self, metrics: Arc<Metrics>) -> DynamicBatcher {
        self.metrics = Some(metrics);
        self
    }

    /// Enforce `admission`'s queue budget at submit time: a request that
    /// would push the queue past the budget is refused with
    /// [`SubmitError::Overloaded`] instead of queueing unboundedly.
    pub fn with_admission(mut self, admission: Arc<AdmissionController>) -> DynamicBatcher {
        self.admission = Some(admission);
        self
    }

    /// The current policy (with the live `max_batch` value).
    pub fn policy(&self) -> BatchPolicy {
        BatchPolicy {
            max_batch: self.max_batch(),
            max_wait: self.max_wait,
        }
    }

    /// Current batch-size ceiling.
    pub fn max_batch(&self) -> usize {
        self.max_batch.load(Ordering::Relaxed)
    }

    /// Re-size the batch ceiling (load-aware router). Takes effect for the
    /// next batch decision; a waiting consumer is woken so a now-full
    /// queue closes immediately.
    pub fn set_max_batch(&self, max_batch: usize) {
        self.max_batch.store(max_batch.max(1), Ordering::Relaxed);
        // Serialize with the consumer's check-then-park: without taking
        // the mutex, the notify could land between its ceiling check and
        // its condvar wait and be lost until max_wait expires.
        drop(self.state.lock().expect("batcher mutex"));
        self.cv.notify_all();
    }

    /// Enqueue a request. Fails when the batcher is shut down or the input
    /// row is empty (zero-row requests never reach the engine).
    pub fn submit(&self, req: InferenceRequest) -> Result<(), SubmitError> {
        if req.input.is_empty() {
            return Err(SubmitError::EmptyInput(req));
        }
        let mut st = self.state.lock().expect("batcher mutex");
        if st.closed {
            return Err(SubmitError::Closed(req));
        }
        // Checked under the queue lock so the depth the budget sees is
        // exact — concurrent producers can't both slip past the last slot.
        if let Some(adm) = &self.admission {
            if !adm.admits(st.queue.len()) {
                return Err(SubmitError::Overloaded(req));
            }
        }
        st.queue.push_back(req);
        let depth = st.queue.len();
        drop(st);
        if let Some(m) = &self.metrics {
            m.note_arrival();
            m.set_queue_depth(depth);
        }
        self.cv.notify_all();
        Ok(())
    }

    /// Current queue depth.
    pub fn depth(&self) -> usize {
        self.state.lock().expect("batcher mutex").queue.len()
    }

    /// Block until a batch is ready (full, or the oldest request timed
    /// out, or shutdown). Returns `None` only after `close()` with an
    /// empty queue — the consumer's exit signal.
    pub fn next_batch(&self) -> Option<Vec<InferenceRequest>> {
        let mut st = self.state.lock().expect("batcher mutex");
        loop {
            if !st.queue.is_empty() {
                let max_batch = self.max_batch();
                let oldest = st.queue.front().unwrap().enqueued;
                let deadline = oldest + self.max_wait;
                let now = Instant::now();
                if st.queue.len() >= max_batch || now >= deadline || st.closed {
                    let take = st.queue.len().min(max_batch);
                    let batch: Vec<InferenceRequest> = st.queue.drain(..take).collect();
                    let depth = st.queue.len();
                    drop(st);
                    if let Some(m) = &self.metrics {
                        m.set_queue_depth(depth);
                    }
                    return Some(batch);
                }
                // Wait until the deadline or a new arrival.
                let (guard, _timeout) = self
                    .cv
                    .wait_timeout(st, deadline - now)
                    .expect("batcher condvar");
                st = guard;
            } else {
                if st.closed {
                    return None;
                }
                st = self.cv.wait(st).expect("batcher condvar");
            }
        }
    }

    /// Shut the batcher down. Queued requests are still drained by
    /// subsequent `next_batch` calls; new submissions are rejected.
    pub fn close(&self) {
        self.state.lock().expect("batcher mutex").closed = true;
        self.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::InferenceRequest;
    use std::sync::Arc;
    use std::time::Duration;

    fn req(id: u64) -> InferenceRequest {
        InferenceRequest::new(id, "m", vec![0.0]).0
    }

    #[test]
    fn full_batch_closes_immediately() {
        let b = DynamicBatcher::new(BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_secs(10),
        });
        for i in 0..4 {
            b.submit(req(i)).unwrap();
        }
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 4);
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
    }

    #[test]
    fn timeout_closes_partial_batch() {
        let b = DynamicBatcher::new(BatchPolicy {
            max_batch: 100,
            max_wait: Duration::from_millis(5),
        });
        b.submit(req(1)).unwrap();
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(t0.elapsed() >= Duration::from_millis(4));
    }

    #[test]
    fn max_wait_expiry_flushes_non_full_batch() {
        // Three of a possible hundred rows queued: the deadline of the
        // *oldest* request closes the batch with exactly those three.
        let b = DynamicBatcher::new(BatchPolicy {
            max_batch: 100,
            max_wait: Duration::from_millis(10),
        });
        for i in 0..3 {
            b.submit(req(i)).unwrap();
        }
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 3, "all queued rows ride the expiring batch");
        assert!(t0.elapsed() >= Duration::from_millis(8));
        assert_eq!(b.depth(), 0);
    }

    #[test]
    fn fifo_order_preserved_across_batches() {
        let b = DynamicBatcher::new(BatchPolicy {
            max_batch: 2,
            max_wait: Duration::from_secs(1),
        });
        for i in 0..5 {
            b.submit(req(i)).unwrap();
        }
        b.close();
        let mut ids = Vec::new();
        while let Some(batch) = b.next_batch() {
            ids.extend(batch.iter().map(|r| r.id));
        }
        assert_eq!(ids, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn close_rejects_new_and_unblocks_consumer() {
        let b = Arc::new(DynamicBatcher::new(BatchPolicy::default()));
        let b2 = Arc::clone(&b);
        let h = std::thread::spawn(move || b2.next_batch());
        std::thread::sleep(Duration::from_millis(10));
        b.close();
        assert!(h.join().unwrap().is_none());
        assert!(matches!(b.submit(req(1)), Err(SubmitError::Closed(_))));
    }

    #[test]
    fn close_while_waiting_flushes_partial_batch() {
        // The consumer is parked on a partial batch with a long max_wait;
        // close() must hand it the partial batch immediately (not None,
        // not after the deadline).
        let b = Arc::new(DynamicBatcher::new(BatchPolicy {
            max_batch: 100,
            max_wait: Duration::from_secs(30),
        }));
        b.submit(req(7)).unwrap();
        let b2 = Arc::clone(&b);
        let h = std::thread::spawn(move || b2.next_batch());
        std::thread::sleep(Duration::from_millis(10));
        let t0 = Instant::now();
        b.close();
        let batch = h.join().unwrap().expect("partial batch, not shutdown None");
        assert!(t0.elapsed() < Duration::from_secs(5), "close must not wait out max_wait");
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![7]);
        // Queue drained → now the exit signal.
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn zero_row_request_is_rejected() {
        let b = DynamicBatcher::new(BatchPolicy::default());
        let (empty, rx) = InferenceRequest::new(9, "m", vec![]);
        match b.submit(empty) {
            Err(SubmitError::EmptyInput(r)) => assert_eq!(r.id, 9),
            other => panic!("expected EmptyInput, got {other:?}"),
        }
        drop(rx);
        assert_eq!(b.depth(), 0, "rejected request never queues");
        // Non-empty input still flows.
        b.submit(req(1)).unwrap();
        assert_eq!(b.depth(), 1);
    }

    #[test]
    fn admission_budget_rejects_at_capacity_and_recovers() {
        let adm = Arc::new(AdmissionController::new(2));
        let b = DynamicBatcher::new(BatchPolicy {
            max_batch: 100,
            max_wait: Duration::from_secs(10),
        })
        .with_admission(Arc::clone(&adm));
        b.submit(req(1)).unwrap();
        b.submit(req(2)).unwrap();
        match b.submit(req(3)) {
            Err(SubmitError::Overloaded(r)) => assert_eq!(r.id, 3),
            other => panic!("expected Overloaded, got {other:?}"),
        }
        assert_eq!(b.depth(), 2, "rejected request never queues");
        // Raising the budget readmits immediately; 0 means unlimited.
        adm.set_budget(0);
        b.submit(req(3)).unwrap();
        assert_eq!(b.depth(), 3);
    }

    #[test]
    fn set_max_batch_applies_to_next_decision() {
        let b = DynamicBatcher::new(BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_secs(10),
        });
        for i in 0..4 {
            b.submit(req(i)).unwrap();
        }
        assert_eq!(b.max_batch(), 8);
        b.set_max_batch(2);
        assert_eq!(b.policy().max_batch, 2);
        // 4 queued ≥ new ceiling 2 → closes immediately at 2 rows.
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 2);
        assert_eq!(b.depth(), 2);
    }

    #[test]
    fn metrics_see_arrivals_and_depth() {
        let m = Arc::new(Metrics::new());
        let b = DynamicBatcher::new(BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_secs(1),
        })
        .with_metrics(Arc::clone(&m));
        for i in 0..4 {
            b.submit(req(i)).unwrap();
        }
        assert_eq!(
            m.peak_queue_depth.load(std::sync::atomic::Ordering::Relaxed),
            4
        );
        let _ = b.next_batch().unwrap();
        assert_eq!(m.queue_depth.load(std::sync::atomic::Ordering::Relaxed), 0);
    }

    #[test]
    fn concurrent_producers_lose_nothing() {
        let b = Arc::new(DynamicBatcher::new(BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(1),
        }));
        let producers: Vec<_> = (0..4)
            .map(|t| {
                let b = Arc::clone(&b);
                std::thread::spawn(move || {
                    for i in 0..50 {
                        b.submit(req(t * 1000 + i)).unwrap();
                    }
                })
            })
            .collect();
        let consumer = {
            let b = Arc::clone(&b);
            std::thread::spawn(move || {
                let mut seen = Vec::new();
                while let Some(batch) = b.next_batch() {
                    assert!(batch.len() <= 8);
                    seen.extend(batch.iter().map(|r| r.id));
                }
                seen
            })
        };
        for p in producers {
            p.join().unwrap();
        }
        // Drain then close.
        while b.depth() > 0 {
            std::thread::sleep(Duration::from_millis(1));
        }
        b.close();
        let mut seen = consumer.join().unwrap();
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen.len(), 200, "no request lost or duplicated");
    }
}
