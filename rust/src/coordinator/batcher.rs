//! Dynamic batcher: accumulates single-row requests into GEMM batches.
//!
//! Policy: a batch closes when it reaches `max_batch` rows OR the oldest
//! queued request has waited `max_wait`. Growing M is performance-neutral
//! for the paper's kernels (Fig 8: performance is constant across M/N), so
//! batching converts latency headroom directly into throughput.

use crate::coordinator::request::InferenceRequest;
use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Batch assembly policy.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Maximum rows per batch (should match the largest compiled bucket).
    pub max_batch: usize,
    /// Maximum time the oldest request may wait before the batch closes.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
        }
    }
}

struct QueueState {
    queue: VecDeque<InferenceRequest>,
    closed: bool,
}

/// Thread-safe dynamic batching queue (Mutex + Condvar; producers are
/// server connections, the consumer is the model's batch loop).
pub struct DynamicBatcher {
    policy: BatchPolicy,
    state: Mutex<QueueState>,
    cv: Condvar,
}

impl DynamicBatcher {
    pub fn new(policy: BatchPolicy) -> DynamicBatcher {
        assert!(policy.max_batch >= 1);
        DynamicBatcher {
            policy,
            state: Mutex::new(QueueState {
                queue: VecDeque::new(),
                closed: false,
            }),
            cv: Condvar::new(),
        }
    }

    pub fn policy(&self) -> BatchPolicy {
        self.policy
    }

    /// Enqueue a request. Returns `Err(req)` if the batcher is shut down.
    pub fn submit(&self, req: InferenceRequest) -> Result<(), InferenceRequest> {
        let mut st = self.state.lock().expect("batcher mutex");
        if st.closed {
            return Err(req);
        }
        st.queue.push_back(req);
        drop(st);
        self.cv.notify_all();
        Ok(())
    }

    /// Current queue depth.
    pub fn depth(&self) -> usize {
        self.state.lock().expect("batcher mutex").queue.len()
    }

    /// Block until a batch is ready (full, or the oldest request timed
    /// out, or shutdown). Returns `None` only after `close()` with an
    /// empty queue — the consumer's exit signal.
    pub fn next_batch(&self) -> Option<Vec<InferenceRequest>> {
        let mut st = self.state.lock().expect("batcher mutex");
        loop {
            if !st.queue.is_empty() {
                let oldest = st.queue.front().unwrap().enqueued;
                let deadline = oldest + self.policy.max_wait;
                let now = Instant::now();
                if st.queue.len() >= self.policy.max_batch || now >= deadline || st.closed {
                    let take = st.queue.len().min(self.policy.max_batch);
                    return Some(st.queue.drain(..take).collect());
                }
                // Wait until the deadline or a new arrival.
                let (guard, _timeout) = self
                    .cv
                    .wait_timeout(st, deadline - now)
                    .expect("batcher condvar");
                st = guard;
            } else {
                if st.closed {
                    return None;
                }
                st = self.cv.wait(st).expect("batcher condvar");
            }
        }
    }

    /// Shut the batcher down. Queued requests are still drained by
    /// subsequent `next_batch` calls; new submissions are rejected.
    pub fn close(&self) {
        self.state.lock().expect("batcher mutex").closed = true;
        self.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::InferenceRequest;
    use std::sync::Arc;
    use std::time::Duration;

    fn req(id: u64) -> InferenceRequest {
        InferenceRequest::new(id, "m", vec![0.0]).0
    }

    #[test]
    fn full_batch_closes_immediately() {
        let b = DynamicBatcher::new(BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_secs(10),
        });
        for i in 0..4 {
            b.submit(req(i)).unwrap();
        }
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 4);
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
    }

    #[test]
    fn timeout_closes_partial_batch() {
        let b = DynamicBatcher::new(BatchPolicy {
            max_batch: 100,
            max_wait: Duration::from_millis(5),
        });
        b.submit(req(1)).unwrap();
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(t0.elapsed() >= Duration::from_millis(4));
    }

    #[test]
    fn fifo_order_preserved_across_batches() {
        let b = DynamicBatcher::new(BatchPolicy {
            max_batch: 2,
            max_wait: Duration::from_secs(1),
        });
        for i in 0..5 {
            b.submit(req(i)).unwrap();
        }
        b.close();
        let mut ids = Vec::new();
        while let Some(batch) = b.next_batch() {
            ids.extend(batch.iter().map(|r| r.id));
        }
        assert_eq!(ids, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn close_rejects_new_and_unblocks_consumer() {
        let b = Arc::new(DynamicBatcher::new(BatchPolicy::default()));
        let b2 = Arc::clone(&b);
        let h = std::thread::spawn(move || b2.next_batch());
        std::thread::sleep(Duration::from_millis(10));
        b.close();
        assert!(h.join().unwrap().is_none());
        assert!(b.submit(req(1)).is_err());
    }

    #[test]
    fn concurrent_producers_lose_nothing() {
        let b = Arc::new(DynamicBatcher::new(BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(1),
        }));
        let producers: Vec<_> = (0..4)
            .map(|t| {
                let b = Arc::clone(&b);
                std::thread::spawn(move || {
                    for i in 0..50 {
                        b.submit(req(t * 1000 + i)).unwrap();
                    }
                })
            })
            .collect();
        let consumer = {
            let b = Arc::clone(&b);
            std::thread::spawn(move || {
                let mut seen = Vec::new();
                while let Some(batch) = b.next_batch() {
                    assert!(batch.len() <= 8);
                    seen.extend(batch.iter().map(|r| r.id));
                }
                seen
            })
        };
        for p in producers {
            p.join().unwrap();
        }
        // Drain then close.
        while b.depth() > 0 {
            std::thread::sleep(Duration::from_millis(1));
        }
        b.close();
        let mut seen = consumer.join().unwrap();
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen.len(), 200, "no request lost or duplicated");
    }
}
