//! HTTP/1.1 inference server (hand-rolled on std::net — no tokio offline).
//!
//! Endpoints:
//! - `POST /infer`      body `{"model": "...", "input": [f32...]}` →
//!   `{"id": n, "output": [...], "queue_us": n, "compute_us": n,
//!     "batch_size": n}`; 429 when the model's admission budget is
//!   exhausted, 503 for unknown/draining models.
//! - `POST /load_model` body `{"config": {...}}` (inline model config) or
//!   `{"path": "model.json"}`, optional `max_batch`, `max_wait_us`,
//!   `queue_budget`, `autoscale` (default true), `warm` (default false) →
//!   `{"model": "...", "state": "..."}`; 409 when the name is taken.
//! - `POST /unload`     body `{"model": "..."}` — drains in-flight
//!   batches (none dropped), joins the batch loop, releases plan/arena
//!   memory → `{"model": "...", "unloaded": true}`; 404 for unknown names.
//! - `POST /generate`   body `{"model": "...", "prompt": [f32...],
//!   "max_tokens": n}` — opens a decode session on the model's
//!   continuous-batching [`crate::coordinator::DecodeScheduler`] and
//!   streams one NDJSON line `{"index": n, "token": n}` per decoded token
//!   as a `Transfer-Encoding: chunked` response chunk. 429 when the
//!   model's decode session capacity is full, 503 for unknown/draining
//!   models, 400 for non-square models (decode needs `d_in == d_out`).
//!   A client hang-up mid-stream cancels the session before its next
//!   step.
//! - `GET  /status`     per-model lifecycle state + queue/latency gauges
//!   (including a `decode` row once a model has served `/generate`),
//!   plus fleet-level rows (thread budget, shared-pool size, tuned
//!   classes, registry hit/miss).
//! - `GET  /metrics`    `{"models": [{model, state, metrics}...],
//!   "fleet": {...}}` — full per-model metrics snapshots.
//! - `GET  /healthz`    liveness
//!
//! Connections are handled by a worker pool; each request blocks its
//! worker while the dynamic batcher assembles and the engine executes —
//! the thread-per-request model every pre-async HTTP stack used, sized by
//! the pool. Lifecycle endpoints go straight to the router's
//! [`ModelRegistry`]; `/infer` uses the same submit path the in-process
//! callers do.

use crate::coordinator::decode::{DecodeConfig, StreamEvent};
use crate::coordinator::registry::{LoadOptions, ModelRegistry};
use crate::coordinator::router::Router;
use crate::coordinator::BatchPolicy;
use crate::coordinator::LoadControlConfig;
use crate::model::ModelConfig;
use crate::util::json::Json;
use crate::util::threadpool::ThreadPool;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub addr: String,
    pub workers: usize,
    pub request_timeout: Duration,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:0".to_string(),
            workers: 8,
            request_timeout: Duration::from_secs(30),
        }
    }
}

/// The running server handle.
pub struct Server {
    pub local_addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_handle: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind and start serving on background threads. The router must
    /// outlive the server (Arc).
    pub fn start(router: Arc<Router>, cfg: ServerConfig) -> std::io::Result<Server> {
        let listener = TcpListener::bind(&cfg.addr)?;
        let local_addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let accept_handle = std::thread::Builder::new()
            .name("stgemm-accept".into())
            .spawn(move || {
                let pool = ThreadPool::new(cfg.workers);
                loop {
                    if stop2.load(Ordering::SeqCst) {
                        break;
                    }
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let router = Arc::clone(&router);
                            let timeout = cfg.request_timeout;
                            pool.execute(move || {
                                let _ = handle_connection(stream, &router, timeout);
                            });
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(1));
                        }
                        Err(_) => break,
                    }
                }
            })?;
        Ok(Server {
            local_addr,
            stop,
            accept_handle: Some(accept_handle),
        })
    }

    /// Stop accepting connections and join the accept loop.
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Parse one HTTP request and dispatch it.
fn handle_connection(
    stream: TcpStream,
    router: &Router,
    timeout: Duration,
) -> std::io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_secs(10)))?;
    stream.set_nodelay(true)?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut request_line = String::new();
    reader.read_line(&mut request_line)?;
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();

    // Headers → content length.
    let mut content_length = 0usize;
    loop {
        let mut line = String::new();
        reader.read_line(&mut line)?;
        let line = line.trim_end();
        if line.is_empty() {
            break;
        }
        if let Some(v) = line
            .to_ascii_lowercase()
            .strip_prefix("content-length:")
            .map(|v| v.trim().to_string())
        {
            content_length = v.parse().unwrap_or(0);
        }
    }
    const MAX_BODY: usize = 16 << 20;
    let mut stream = stream;
    if content_length > MAX_BODY {
        return respond(&mut stream, 413, &err_json("body too large"));
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body)?;
    let body = String::from_utf8_lossy(&body).into_owned();

    match (method.as_str(), path.as_str()) {
        ("POST", "/infer") => handle_infer(&mut stream, router, &body, timeout),
        ("POST", "/generate") => {
            handle_generate(&mut stream, router.registry(), &body, timeout)
        }
        ("POST", "/load_model") => handle_load_model(&mut stream, router.registry(), &body),
        ("POST", "/unload") => handle_unload(&mut stream, router.registry(), &body),
        ("GET", "/status") => {
            respond(&mut stream, 200, &status_json(router.registry()).encode())
        }
        ("GET", "/metrics") => {
            let registry = router.registry();
            let models = registry
                .handles()
                .into_iter()
                .map(|(name, h)| {
                    Json::obj(vec![
                        ("model", Json::str(name)),
                        ("state", Json::str(h.state().as_str())),
                        ("metrics", h.engine().metrics.snapshot()),
                    ])
                })
                .collect::<Vec<_>>();
            let body = Json::obj(vec![
                ("models", Json::arr(models)),
                ("fleet", fleet_json(registry)),
            ]);
            respond(&mut stream, 200, &body.encode())
        }
        ("GET", "/healthz") => respond(&mut stream, 200, r#"{"status":"ok"}"#),
        _ => respond(&mut stream, 404, &err_json("not found")),
    }
}

/// Fleet-level gauges: the shared-substrate view (`/metrics` and
/// `/status` both carry it).
fn fleet_json(registry: &ModelRegistry) -> Json {
    let planner = registry.planner();
    Json::obj(vec![
        ("models_loaded", Json::num(registry.names().len() as f64)),
        ("thread_budget", Json::num(registry.thread_budget() as f64)),
        (
            // Null until the first parallel plan lazily creates the pool.
            "shared_pool_threads",
            planner
                .shared_pool_threads()
                .map(|n| Json::num(n as f64))
                .unwrap_or(Json::Null),
        ),
        (
            "tuned_classes",
            Json::num(planner.tuned_classes() as f64),
        ),
        ("registry_hits", Json::num(registry.hit_count() as f64)),
        ("registry_misses", Json::num(registry.miss_count() as f64)),
        ("placement", Json::str(registry.placement().as_str())),
        ("topology", Json::str(planner.topology().describe())),
        (
            // Per-worker pin rows; empty until the first parallel plan
            // lazily creates the shared pool.
            "worker_placement",
            Json::arr(
                registry
                    .pool_placements()
                    .into_iter()
                    .map(|p| {
                        Json::obj(vec![
                            ("worker", Json::num(p.worker as f64)),
                            (
                                "cores",
                                Json::arr(
                                    p.cores.iter().map(|&c| Json::num(c as f64)),
                                ),
                            ),
                            ("outcome", Json::str(p.outcome.as_str())),
                        ])
                    })
                    .collect::<Vec<_>>(),
            ),
        ),
    ])
}

/// The `/status` body: one compact row per model + the fleet gauges.
fn status_json(registry: &ModelRegistry) -> Json {
    let models = registry
        .handles()
        .into_iter()
        .map(|(name, h)| {
            let m = &h.engine().metrics;
            Json::obj(vec![
                ("model", Json::str(name)),
                ("state", Json::str(h.state().as_str())),
                ("queue_depth", Json::num(h.queue_depth() as f64)),
                (
                    "queue_budget",
                    Json::num(h.admission().budget() as f64),
                ),
                ("thread_cap", Json::num(h.thread_cap() as f64)),
                (
                    "threads",
                    Json::num(m.threads_in_use.load(Ordering::Relaxed) as f64),
                ),
                (
                    "max_batch",
                    Json::num(m.max_batch_in_use.load(Ordering::Relaxed) as f64),
                ),
                (
                    "requests",
                    Json::num(m.requests.load(Ordering::Relaxed) as f64),
                ),
                (
                    "responses",
                    Json::num(m.responses.load(Ordering::Relaxed) as f64),
                ),
                (
                    "admission_rejections",
                    Json::num(m.admission_rejections.load(Ordering::Relaxed) as f64),
                ),
                (
                    "latency_us",
                    Json::obj(vec![
                        ("p50", Json::num(m.e2e_latency.percentile_us(50.0) as f64)),
                        ("p99", Json::num(m.e2e_latency.percentile_us(99.0) as f64)),
                    ]),
                ),
                (
                    "plans_built",
                    Json::num(
                        h.engine()
                            .plan_cache()
                            .map(|c| c.plans_built() as f64)
                            .unwrap_or(0.0),
                    ),
                ),
                (
                    // Placement effectiveness: the stall fraction of
                    // pipelined wall time, read against how many pool
                    // workers actually pinned. Compare pinned vs
                    // `--no-pin` runs of the same workload.
                    "placement",
                    Json::obj(vec![
                        (
                            "pinned_workers",
                            Json::num(m.pinned_workers.load(Ordering::Relaxed) as f64),
                        ),
                        ("stall_frac", Json::num(m.pipeline_stall_frac())),
                    ]),
                ),
                (
                    // Null until the model's first /generate starts its
                    // decode scheduler.
                    "decode",
                    h.decode_scheduler_if_started()
                        .map(|d| {
                            Json::obj(vec![
                                (
                                    "active_sessions",
                                    Json::num(d.active_sessions() as f64),
                                ),
                                ("capacity", Json::num(d.capacity() as f64)),
                                (
                                    "tokens_per_sec",
                                    Json::num(m.decode_tokens_per_sec()),
                                ),
                                (
                                    "mean_occupancy",
                                    Json::num(m.decode_mean_occupancy()),
                                ),
                                (
                                    // Null until spawn_loop pinned the
                                    // tick thread.
                                    "tick_pin",
                                    d.tick_placement()
                                        .map(|(cores, outcome)| {
                                            Json::obj(vec![
                                                (
                                                    "cores",
                                                    Json::arr(cores.iter().map(|&c| {
                                                        Json::num(c as f64)
                                                    })),
                                                ),
                                                (
                                                    "outcome",
                                                    Json::str(outcome.as_str()),
                                                ),
                                            ])
                                        })
                                        .unwrap_or(Json::Null),
                                ),
                            ])
                        })
                        .unwrap_or(Json::Null),
                ),
            ])
        })
        .collect::<Vec<_>>();
    Json::obj(vec![
        ("models", Json::arr(models)),
        ("fleet", fleet_json(registry)),
    ])
}

/// `POST /load_model`: build a model from an inline `"config"` object or
/// a `"path"` to a config file, then load it into the registry.
fn handle_load_model(
    stream: &mut TcpStream,
    registry: &ModelRegistry,
    body: &str,
) -> std::io::Result<()> {
    let parsed = match Json::parse(body) {
        Ok(v) => v,
        Err(e) => return respond(stream, 400, &err_json(&format!("bad json: {e}"))),
    };
    let cfg_text = if let Some(inline) = parsed.get("config") {
        inline.encode()
    } else if let Some(path) = parsed.get("path").and_then(|p| p.as_str()) {
        match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) => {
                return respond(
                    stream,
                    400,
                    &err_json(&format!("cannot read config '{path}': {e}")),
                )
            }
        }
    } else {
        return respond(stream, 400, &err_json("need 'config' object or 'path'"));
    };
    let cfg = match ModelConfig::from_json(&cfg_text) {
        Ok(c) => c,
        Err(e) => return respond(stream, 400, &err_json(&e.to_string())),
    };
    let mut policy = BatchPolicy::default();
    if let Some(mb) = parsed.get("max_batch").and_then(|v| v.as_usize()) {
        policy.max_batch = mb.max(1);
    }
    if let Some(us) = parsed.get("max_wait_us").and_then(|v| v.as_usize()) {
        policy.max_wait = Duration::from_micros(us as u64);
    }
    let autoscale = parsed
        .get("autoscale")
        .and_then(|v| v.as_bool())
        .unwrap_or(true);
    let opts = LoadOptions {
        policy,
        control: autoscale.then(LoadControlConfig::default).map(|mut c| {
            c.max_batch = c.max_batch.max(policy.max_batch);
            c
        }),
        queue_budget: parsed
            .get("queue_budget")
            .and_then(|v| v.as_usize())
            .unwrap_or(0),
        warm: parsed
            .get("warm")
            .and_then(|v| v.as_bool())
            .unwrap_or(false),
        decode: {
            let d = DecodeConfig::default();
            DecodeConfig {
                max_sessions: parsed
                    .get("decode_sessions")
                    .and_then(|v| v.as_usize())
                    .unwrap_or(d.max_sessions),
                default_max_tokens: parsed
                    .get("decode_max_tokens")
                    .and_then(|v| v.as_usize())
                    .unwrap_or(d.default_max_tokens),
                ..d
            }
        },
        ..LoadOptions::default()
    };
    match registry.load(&cfg, opts) {
        Ok(handle) => {
            let body = Json::obj(vec![
                ("model", Json::str(&cfg.name)),
                ("state", Json::str(handle.state().as_str())),
            ]);
            respond(stream, 200, &body.encode())
        }
        Err(e) => {
            let msg = e.to_string();
            let status = if msg.contains("already loaded") { 409 } else { 400 };
            respond(stream, status, &err_json(&msg))
        }
    }
}

/// `POST /unload`: drain + remove + release a model.
fn handle_unload(
    stream: &mut TcpStream,
    registry: &ModelRegistry,
    body: &str,
) -> std::io::Result<()> {
    let parsed = match Json::parse(body) {
        Ok(v) => v,
        Err(e) => return respond(stream, 400, &err_json(&format!("bad json: {e}"))),
    };
    let model = match parsed.get("model").and_then(|m| m.as_str()) {
        Some(m) => m.to_string(),
        None => return respond(stream, 400, &err_json("missing 'model'")),
    };
    match registry.unload(&model) {
        Ok(()) => {
            let body = Json::obj(vec![
                ("model", Json::str(&model)),
                ("unloaded", Json::Bool(true)),
            ]);
            respond(stream, 200, &body.encode())
        }
        Err(e) => respond(stream, 404, &err_json(&e.to_string())),
    }
}

fn handle_infer(
    stream: &mut TcpStream,
    router: &Router,
    body: &str,
    timeout: Duration,
) -> std::io::Result<()> {
    let parsed = match Json::parse(body) {
        Ok(v) => v,
        Err(e) => return respond(stream, 400, &err_json(&format!("bad json: {e}"))),
    };
    let model = match parsed.get("model").and_then(|m| m.as_str()) {
        Some(m) => m.to_string(),
        None => return respond(stream, 400, &err_json("missing 'model'")),
    };
    let input: Vec<f32> = match parsed.get("input").and_then(|i| i.as_arr()) {
        Some(arr) => {
            let mut v = Vec::with_capacity(arr.len());
            for item in arr {
                match item.as_f64() {
                    Some(f) => v.push(f as f32),
                    None => {
                        return respond(stream, 400, &err_json("input must be numbers"))
                    }
                }
            }
            v
        }
        None => return respond(stream, 400, &err_json("missing 'input' array")),
    };
    if input.is_empty() {
        // The batcher would reject it anyway (zero-row requests never
        // reach the engine); answer with a client error, not a 503.
        return respond(stream, 400, &err_json("empty input"));
    }
    match router.infer_blocking(&model, input, timeout) {
        Ok(resp) => match resp.output {
            Ok(out) => {
                let json = Json::obj(vec![
                    ("id", Json::num(resp.id as f64)),
                    (
                        "output",
                        Json::arr(out.iter().map(|&v| Json::num(v as f64))),
                    ),
                    ("queue_us", Json::num(resp.queue_us as f64)),
                    ("compute_us", Json::num(resp.compute_us as f64)),
                    ("batch_size", Json::num(resp.batch_size as f64)),
                ]);
                respond(stream, 200, &json.encode())
            }
            Err(e) => respond(stream, 422, &err_json(&e.to_string())),
        },
        Err(e) => {
            let msg = e.to_string();
            // Admission-budget rejection is backpressure, not outage:
            // tell the client to retry later, not that we're down.
            let status = if msg.contains("overloaded") { 429 } else { 503 };
            respond(stream, status, &err_json(&msg))
        }
    }
}

/// `POST /generate`: open a decode session and stream its tokens as
/// chunked NDJSON. The worker thread stays on this connection for the
/// life of the stream — the same thread-per-request model `/infer` uses,
/// except the response body grows one chunk per decode step.
fn handle_generate(
    stream: &mut TcpStream,
    registry: &ModelRegistry,
    body: &str,
    timeout: Duration,
) -> std::io::Result<()> {
    let parsed = match Json::parse(body) {
        Ok(v) => v,
        Err(e) => return respond(stream, 400, &err_json(&format!("bad json: {e}"))),
    };
    let model = match parsed.get("model").and_then(|m| m.as_str()) {
        Some(m) => m.to_string(),
        None => return respond(stream, 400, &err_json("missing 'model'")),
    };
    let prompt: Vec<f32> = match parsed.get("prompt").and_then(|p| p.as_arr()) {
        Some(arr) => {
            let mut v = Vec::with_capacity(arr.len());
            for item in arr {
                match item.as_f64() {
                    Some(f) => v.push(f as f32),
                    None => {
                        return respond(stream, 400, &err_json("prompt must be numbers"))
                    }
                }
            }
            v
        }
        None => return respond(stream, 400, &err_json("missing 'prompt' array")),
    };
    if prompt.is_empty() {
        return respond(stream, 400, &err_json("empty prompt"));
    }
    let max_tokens = parsed.get("max_tokens").and_then(|v| v.as_usize());
    let handle = match registry.get(&model) {
        Some(h) => h,
        None => return respond(stream, 503, &err_json(&format!("unknown model '{model}'"))),
    };
    let sched = match handle.decode_scheduler() {
        Ok(s) => s,
        Err(e) => {
            let msg = e.to_string();
            // Draining is an availability condition; everything else
            // (no plan cache, non-square dims) is a client asking a
            // model that cannot decode.
            let status = if msg.contains("draining") { 503 } else { 400 };
            return respond(stream, status, &err_json(&msg));
        }
    };
    let tokens = match sched.begin(&prompt, max_tokens) {
        Ok(t) => t,
        Err(e) => {
            let msg = e.to_string();
            let status = if msg.contains("overloaded") {
                429
            } else if msg.contains("draining") {
                503
            } else {
                400
            };
            return respond(stream, status, &err_json(&msg));
        }
    };
    // Session admitted: commit to a chunked 200 and stream.
    stream.write_all(
        b"HTTP/1.1 200 OK\r\nContent-Type: application/x-ndjson\r\n\
          Transfer-Encoding: chunked\r\nConnection: close\r\n\r\n",
    )?;
    loop {
        match tokens.next_timeout(timeout) {
            StreamEvent::Token(ev) => {
                let line =
                    format!("{{\"index\":{},\"token\":{}}}\n", ev.index, ev.token);
                if write_chunk(stream, &line).is_err() {
                    // Client hung up: dropping `tokens` flags the cancel;
                    // the scheduler retires the session before its next
                    // step.
                    return Ok(());
                }
            }
            // A stream idle past the request timeout is abandoned rather
            // than allowed to pin its worker forever (drop cancels).
            StreamEvent::Idle => break,
            StreamEvent::Ended => break,
        }
    }
    stream.write_all(b"0\r\n\r\n")
}

/// One HTTP/1.1 chunk: hex size line, payload, CRLF.
fn write_chunk(stream: &mut TcpStream, data: &str) -> std::io::Result<()> {
    write!(stream, "{:x}\r\n{data}\r\n", data.len())
}

fn err_json(msg: &str) -> String {
    Json::obj(vec![("error", Json::str(msg))]).encode()
}

fn respond(stream: &mut TcpStream, status: u16, body: &str) -> std::io::Result<()> {
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        409 => "Conflict",
        413 => "Payload Too Large",
        422 => "Unprocessable Entity",
        429 => "Too Many Requests",
        503 => "Service Unavailable",
        _ => "Error",
    };
    let resp = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(resp.as_bytes())
}

/// Minimal blocking HTTP client for tests/examples/loadgen (no reqwest
/// offline). Returns (status, body). Bounded by a 30 s default timeout —
/// use [`http_request_timeout`] for an explicit bound.
pub fn http_request(
    addr: &std::net::SocketAddr,
    method: &str,
    path: &str,
    body: &str,
) -> std::io::Result<(u16, String)> {
    http_request_timeout(addr, method, path, body, Duration::from_secs(30))
}

/// [`http_request`] with an explicit per-request bound: `timeout` caps
/// the connect and every read, so a stalled server surfaces as a
/// `WouldBlock`/`TimedOut` error instead of a caller blocked forever
/// (the load generator's per-request timeout rides on this).
pub fn http_request_timeout(
    addr: &std::net::SocketAddr,
    method: &str,
    path: &str,
    body: &str,
    timeout: Duration,
) -> std::io::Result<(u16, String)> {
    http_request_stream(addr, method, path, body, timeout, |_| true)
}

/// Streaming variant for chunked responses (`POST /generate`):
/// `on_chunk` sees each chunk payload as it arrives; returning `false`
/// hangs the connection up early — the server observes the disconnect
/// and cancels the decode session. Non-chunked responses invoke
/// `on_chunk` once with the whole body. Returns (status, full body).
pub fn http_request_stream(
    addr: &std::net::SocketAddr,
    method: &str,
    path: &str,
    body: &str,
    timeout: Duration,
    mut on_chunk: impl FnMut(&str) -> bool,
) -> std::io::Result<(u16, String)> {
    let mut stream = TcpStream::connect_timeout(addr, timeout)?;
    stream.set_nodelay(true)?;
    stream.set_read_timeout(Some(timeout))?;
    stream.set_write_timeout(Some(timeout))?;
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: stgemm\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(req.as_bytes())?;
    let mut reader = BufReader::new(stream);
    let mut status_line = String::new();
    reader.read_line(&mut status_line)?;
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(0);
    let mut content_length = 0usize;
    let mut chunked = false;
    loop {
        let mut line = String::new();
        reader.read_line(&mut line)?;
        if line.trim_end().is_empty() {
            break;
        }
        let lower = line.to_ascii_lowercase();
        if let Some(v) = lower.strip_prefix("content-length:") {
            content_length = v.trim().parse().unwrap_or(0);
        }
        if let Some(v) = lower.strip_prefix("transfer-encoding:") {
            chunked = v.trim() == "chunked";
        }
    }
    let mut full = String::new();
    if chunked {
        loop {
            let mut size_line = String::new();
            reader.read_line(&mut size_line)?;
            let size = usize::from_str_radix(size_line.trim(), 16).unwrap_or(0);
            if size == 0 {
                break;
            }
            // Payload + trailing CRLF.
            let mut chunk = vec![0u8; size + 2];
            reader.read_exact(&mut chunk)?;
            let payload = String::from_utf8_lossy(&chunk[..size]).into_owned();
            full.push_str(&payload);
            if !on_chunk(&payload) {
                // Early hang-up: the stream drops here and the server's
                // next chunk write fails.
                return Ok((status, full));
            }
        }
    } else {
        let mut body = vec![0u8; content_length];
        reader.read_exact(&mut body)?;
        full = String::from_utf8_lossy(&body).into_owned();
        on_chunk(&full);
    }
    Ok((status, full))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::BatchPolicy;
    use crate::coordinator::engine::Engine;
    use crate::model::{ModelConfig, TernaryMlp};

    fn start_server() -> (Server, Arc<Router>) {
        let cfg = ModelConfig::from_json(
            r#"{"name":"m1","dims":[8,16,4],"sparsity":0.5,"seed":1}"#,
        )
        .unwrap();
        let engine = Engine::new("m1", TernaryMlp::from_config(&cfg).unwrap());
        let mut router = Router::new();
        router.register(
            engine,
            BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_millis(1),
            },
        );
        let router = Arc::new(router);
        let server = Server::start(Arc::clone(&router), ServerConfig::default()).unwrap();
        (server, router)
    }

    #[test]
    fn infer_roundtrip_over_http() {
        let (server, _router) = start_server();
        let body = format!(
            r#"{{"model":"m1","input":[{}]}}"#,
            vec!["0.5"; 8].join(",")
        );
        let (status, resp) = http_request(&server.local_addr, "POST", "/infer", &body).unwrap();
        assert_eq!(status, 200, "{resp}");
        let v = Json::parse(&resp).unwrap();
        assert_eq!(v.get("output").unwrap().as_arr().unwrap().len(), 4);
    }

    #[test]
    fn health_and_metrics() {
        let (server, _router) = start_server();
        let (status, _) = http_request(&server.local_addr, "GET", "/healthz", "").unwrap();
        assert_eq!(status, 200);
        let (status, body) = http_request(&server.local_addr, "GET", "/metrics", "").unwrap();
        assert_eq!(status, 200);
        assert!(Json::parse(&body).is_ok());
    }

    #[test]
    fn error_paths() {
        let (server, _router) = start_server();
        let a = server.local_addr;
        assert_eq!(http_request(&a, "POST", "/infer", "not json").unwrap().0, 400);
        assert_eq!(
            http_request(&a, "POST", "/infer", r#"{"input":[1]}"#).unwrap().0,
            400
        );
        assert_eq!(
            http_request(&a, "POST", "/infer", r#"{"model":"zzz","input":[1]}"#)
                .unwrap()
                .0,
            503
        );
        // wrong input width → engine-level 422
        assert_eq!(
            http_request(&a, "POST", "/infer", r#"{"model":"m1","input":[1,2]}"#)
                .unwrap()
                .0,
            422
        );
        // zero-row request → client error before batching
        assert_eq!(
            http_request(&a, "POST", "/infer", r#"{"model":"m1","input":[]}"#)
                .unwrap()
                .0,
            400
        );
        assert_eq!(http_request(&a, "GET", "/nope", "").unwrap().0, 404);
    }

    #[test]
    fn lifecycle_roundtrip_over_http() {
        let (server, _router) = start_server();
        let a = server.local_addr;

        // Load a second model with an inline config.
        let load_body = r#"{"config":{"name":"m2","dims":[8,16,4],"sparsity":0.5,"seed":9},"autoscale":false}"#;
        let (status, resp) = http_request(&a, "POST", "/load_model", load_body).unwrap();
        assert_eq!(status, 200, "{resp}");
        let v = Json::parse(&resp).unwrap();
        assert_eq!(v.get("model").unwrap().as_str(), Some("m2"));
        assert_eq!(v.get("state").unwrap().as_str(), Some("cold"));

        // Loading the same name again conflicts.
        let (status, _) = http_request(&a, "POST", "/load_model", load_body).unwrap();
        assert_eq!(status, 409);

        // /status sees both models with lifecycle state.
        let (status, resp) = http_request(&a, "GET", "/status", "").unwrap();
        assert_eq!(status, 200);
        let v = Json::parse(&resp).unwrap();
        let models = v.get("models").unwrap().as_arr().unwrap();
        assert_eq!(models.len(), 2);
        assert!(v.get("fleet").unwrap().get("thread_budget").is_some());

        // The freshly loaded model serves.
        let infer = format!(r#"{{"model":"m2","input":[{}]}}"#, vec!["0.5"; 8].join(","));
        let (status, _) = http_request(&a, "POST", "/infer", &infer).unwrap();
        assert_eq!(status, 200);

        // Unload it; further traffic to it fails, m1 is untouched.
        let (status, resp) =
            http_request(&a, "POST", "/unload", r#"{"model":"m2"}"#).unwrap();
        assert_eq!(status, 200, "{resp}");
        let (status, _) = http_request(&a, "POST", "/infer", &infer).unwrap();
        assert_eq!(status, 503);
        let m1 = format!(r#"{{"model":"m1","input":[{}]}}"#, vec!["0.5"; 8].join(","));
        assert_eq!(http_request(&a, "POST", "/infer", &m1).unwrap().0, 200);

        // Unknown unload → 404; the name is re-loadable after unload.
        let (status, _) =
            http_request(&a, "POST", "/unload", r#"{"model":"m2"}"#).unwrap();
        assert_eq!(status, 404);
        let (status, _) = http_request(&a, "POST", "/load_model", load_body).unwrap();
        assert_eq!(status, 200);
    }

    #[test]
    fn admission_budget_returns_429_over_http() {
        let (server, _router) = start_server();
        let a = server.local_addr;
        // max_batch 8 with a 10 s wait parks the batch loop until the
        // queue fills; budget 1 admits exactly one request.
        let load_body = r#"{"config":{"name":"tight","dims":[8,16,4],"sparsity":0.5,"seed":11},"autoscale":false,"max_batch":8,"max_wait_us":10000000,"queue_budget":1}"#;
        let (status, resp) = http_request(&a, "POST", "/load_model", load_body).unwrap();
        assert_eq!(status, 200, "{resp}");
        let infer =
            format!(r#"{{"model":"tight","input":[{}]}}"#, vec!["0.5"; 8].join(","));
        // First request occupies the only queue slot (blocks on its
        // worker until the unload below flushes the partial batch).
        let first = {
            let infer = infer.clone();
            std::thread::spawn(move || http_request(&a, "POST", "/infer", &infer).unwrap())
        };
        // Wait until it is actually queued before probing the budget.
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        loop {
            let (_, resp) = http_request(&a, "GET", "/status", "").unwrap();
            let v = Json::parse(&resp).unwrap();
            let queued = v
                .get("models")
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .any(|m| {
                    m.get("model").unwrap().as_str() == Some("tight")
                        && m.get("queue_depth").unwrap().as_f64() == Some(1.0)
                });
            if queued {
                break;
            }
            assert!(std::time::Instant::now() < deadline, "request never queued");
            std::thread::sleep(Duration::from_millis(5));
        }
        let (status, resp) = http_request(&a, "POST", "/infer", &infer).unwrap();
        assert_eq!(status, 429, "{resp}");
        // Unloading drains the queued request — it gets a real response,
        // not an error, and the rejection is counted.
        let (status, _) =
            http_request(&a, "POST", "/unload", r#"{"model":"tight"}"#).unwrap();
        assert_eq!(status, 200);
        let (status, resp) = first.join().unwrap();
        assert_eq!(status, 200, "queued request must drain on unload: {resp}");
    }

    #[test]
    fn metrics_carries_fleet_rows() {
        let (server, _router) = start_server();
        let (status, body) = http_request(&server.local_addr, "GET", "/metrics", "").unwrap();
        assert_eq!(status, 200);
        let v = Json::parse(&body).unwrap();
        let models = v.get("models").unwrap().as_arr().unwrap();
        assert_eq!(models.len(), 1);
        assert!(models[0].get("state").is_some());
        assert!(models[0]
            .get("metrics")
            .unwrap()
            .get("admission_rejections")
            .is_some());
        let fleet = v.get("fleet").unwrap();
        for key in [
            "models_loaded",
            "thread_budget",
            "shared_pool_threads",
            "tuned_classes",
            "registry_hits",
            "registry_misses",
            "placement",
            "topology",
            "worker_placement",
        ] {
            assert!(fleet.get(key).is_some(), "missing fleet row {key}");
        }
    }

    #[test]
    fn generate_streams_tokens_over_http() {
        let (server, _router) = start_server();
        let a = server.local_addr;
        // Decode needs square dims; the default m1 (8→4) can't serve it.
        let load_body = r#"{"config":{"name":"sq","dims":[8,16,8],"sparsity":0.5,"seed":21},"autoscale":false}"#;
        let (status, resp) = http_request(&a, "POST", "/load_model", load_body).unwrap();
        assert_eq!(status, 200, "{resp}");
        let gen = format!(
            r#"{{"model":"sq","prompt":[{}],"max_tokens":4}}"#,
            vec!["0.5"; 8].join(",")
        );
        let mut chunks: Vec<String> = Vec::new();
        let (status, body) = http_request_stream(
            &a,
            "POST",
            "/generate",
            &gen,
            Duration::from_secs(10),
            |c| {
                chunks.push(c.to_string());
                true
            },
        )
        .unwrap();
        assert_eq!(status, 200, "{body}");
        assert_eq!(chunks.len(), 4, "one chunk per token: {chunks:?}");
        for (i, c) in chunks.iter().enumerate() {
            let v = Json::parse(c.trim()).unwrap();
            assert_eq!(v.get("index").unwrap().as_f64(), Some(i as f64));
            assert!(v.get("token").unwrap().as_f64().is_some());
        }
        // /status now carries the model's decode row.
        let (_, resp) = http_request(&a, "GET", "/status", "").unwrap();
        let v = Json::parse(&resp).unwrap();
        let models = v.get("models").unwrap().as_arr().unwrap();
        let row = models
            .iter()
            .find(|m| m.get("model").unwrap().as_str() == Some("sq"))
            .expect("sq row");
        let decode = row.get("decode").unwrap();
        assert_eq!(decode.get("active_sessions").unwrap().as_f64(), Some(0.0));
        assert!(decode.get("tokens_per_sec").is_some());
        assert!(decode.get("mean_occupancy").is_some());
        // /metrics snapshot carries the decode section with the totals.
        let (_, resp) = http_request(&a, "GET", "/metrics", "").unwrap();
        let v = Json::parse(&resp).unwrap();
        let row = v
            .get("models")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .find(|m| m.get("model").unwrap().as_str() == Some("sq"))
            .expect("sq metrics row")
            .get("metrics")
            .unwrap()
            .get("decode")
            .expect("decode metrics section")
            .clone();
        assert_eq!(row.get("tokens").unwrap().as_f64(), Some(4.0));
    }

    #[test]
    fn generate_error_paths() {
        let (server, _router) = start_server();
        let a = server.local_addr;
        let prompt = vec!["0.5"; 8].join(",");
        // Non-square model: decode is a client error, not an outage.
        let bad = format!(r#"{{"model":"m1","prompt":[{prompt}]}}"#);
        let (status, resp) = http_request(&a, "POST", "/generate", &bad).unwrap();
        assert_eq!(status, 400, "{resp}");
        assert!(resp.contains("d_in == d_out"), "{resp}");
        // Unknown model → 503; empty/missing prompt → 400.
        assert_eq!(
            http_request(&a, "POST", "/generate", r#"{"model":"zzz","prompt":[1]}"#)
                .unwrap()
                .0,
            503
        );
        assert_eq!(
            http_request(&a, "POST", "/generate", r#"{"model":"m1","prompt":[]}"#)
                .unwrap()
                .0,
            400
        );
        assert_eq!(
            http_request(&a, "POST", "/generate", r#"{"model":"m1"}"#).unwrap().0,
            400
        );
    }

    #[test]
    fn concurrent_http_clients() {
        let (server, _router) = start_server();
        let addr = server.local_addr;
        let handles: Vec<_> = (0..12)
            .map(|_| {
                std::thread::spawn(move || {
                    let body = format!(
                        r#"{{"model":"m1","input":[{}]}}"#,
                        vec!["0.1"; 8].join(",")
                    );
                    http_request(&addr, "POST", "/infer", &body).unwrap().0
                })
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), 200);
        }
    }
}
