//! HTTP/1.1 inference server (hand-rolled on std::net — no tokio offline).
//!
//! Endpoints:
//! - `POST /infer`   body `{"model": "...", "input": [f32...]}` →
//!   `{"id": n, "output": [...], "queue_us": n, "compute_us": n,
//!     "batch_size": n}`
//! - `GET  /metrics` per-model metrics snapshot
//! - `GET  /healthz` liveness
//!
//! Connections are handled by a worker pool; each request blocks its
//! worker while the dynamic batcher assembles and the engine executes —
//! the thread-per-request model every pre-async HTTP stack used, sized by
//! the pool.

use crate::coordinator::router::Router;
use crate::util::json::Json;
use crate::util::threadpool::ThreadPool;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub addr: String,
    pub workers: usize,
    pub request_timeout: Duration,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:0".to_string(),
            workers: 8,
            request_timeout: Duration::from_secs(30),
        }
    }
}

/// The running server handle.
pub struct Server {
    pub local_addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_handle: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind and start serving on background threads. The router must
    /// outlive the server (Arc).
    pub fn start(router: Arc<Router>, cfg: ServerConfig) -> std::io::Result<Server> {
        let listener = TcpListener::bind(&cfg.addr)?;
        let local_addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let accept_handle = std::thread::Builder::new()
            .name("stgemm-accept".into())
            .spawn(move || {
                let pool = ThreadPool::new(cfg.workers);
                loop {
                    if stop2.load(Ordering::SeqCst) {
                        break;
                    }
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let router = Arc::clone(&router);
                            let timeout = cfg.request_timeout;
                            pool.execute(move || {
                                let _ = handle_connection(stream, &router, timeout);
                            });
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(1));
                        }
                        Err(_) => break,
                    }
                }
            })?;
        Ok(Server {
            local_addr,
            stop,
            accept_handle: Some(accept_handle),
        })
    }

    /// Stop accepting connections and join the accept loop.
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Parse one HTTP request and dispatch it.
fn handle_connection(
    stream: TcpStream,
    router: &Router,
    timeout: Duration,
) -> std::io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_secs(10)))?;
    stream.set_nodelay(true)?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut request_line = String::new();
    reader.read_line(&mut request_line)?;
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();

    // Headers → content length.
    let mut content_length = 0usize;
    loop {
        let mut line = String::new();
        reader.read_line(&mut line)?;
        let line = line.trim_end();
        if line.is_empty() {
            break;
        }
        if let Some(v) = line
            .to_ascii_lowercase()
            .strip_prefix("content-length:")
            .map(|v| v.trim().to_string())
        {
            content_length = v.parse().unwrap_or(0);
        }
    }
    const MAX_BODY: usize = 16 << 20;
    let mut stream = stream;
    if content_length > MAX_BODY {
        return respond(&mut stream, 413, &err_json("body too large"));
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body)?;
    let body = String::from_utf8_lossy(&body).into_owned();

    match (method.as_str(), path.as_str()) {
        ("POST", "/infer") => handle_infer(&mut stream, router, &body, timeout),
        ("GET", "/metrics") => {
            let mut metrics = Vec::new();
            for name in router.model_names() {
                let engine = router.engine(name).unwrap();
                metrics.push(Json::obj(vec![
                    ("model", Json::str(name)),
                    ("metrics", engine.metrics.snapshot()),
                ]));
            }
            respond(&mut stream, 200, &Json::arr(metrics).encode())
        }
        ("GET", "/healthz") => respond(&mut stream, 200, r#"{"status":"ok"}"#),
        _ => respond(&mut stream, 404, &err_json("not found")),
    }
}

fn handle_infer(
    stream: &mut TcpStream,
    router: &Router,
    body: &str,
    timeout: Duration,
) -> std::io::Result<()> {
    let parsed = match Json::parse(body) {
        Ok(v) => v,
        Err(e) => return respond(stream, 400, &err_json(&format!("bad json: {e}"))),
    };
    let model = match parsed.get("model").and_then(|m| m.as_str()) {
        Some(m) => m.to_string(),
        None => return respond(stream, 400, &err_json("missing 'model'")),
    };
    let input: Vec<f32> = match parsed.get("input").and_then(|i| i.as_arr()) {
        Some(arr) => {
            let mut v = Vec::with_capacity(arr.len());
            for item in arr {
                match item.as_f64() {
                    Some(f) => v.push(f as f32),
                    None => {
                        return respond(stream, 400, &err_json("input must be numbers"))
                    }
                }
            }
            v
        }
        None => return respond(stream, 400, &err_json("missing 'input' array")),
    };
    if input.is_empty() {
        // The batcher would reject it anyway (zero-row requests never
        // reach the engine); answer with a client error, not a 503.
        return respond(stream, 400, &err_json("empty input"));
    }
    match router.infer_blocking(&model, input, timeout) {
        Ok(resp) => match resp.output {
            Ok(out) => {
                let json = Json::obj(vec![
                    ("id", Json::num(resp.id as f64)),
                    (
                        "output",
                        Json::arr(out.iter().map(|&v| Json::num(v as f64))),
                    ),
                    ("queue_us", Json::num(resp.queue_us as f64)),
                    ("compute_us", Json::num(resp.compute_us as f64)),
                    ("batch_size", Json::num(resp.batch_size as f64)),
                ]);
                respond(stream, 200, &json.encode())
            }
            Err(e) => respond(stream, 422, &err_json(&e.to_string())),
        },
        Err(e) => respond(stream, 503, &err_json(&e.to_string())),
    }
}

fn err_json(msg: &str) -> String {
    Json::obj(vec![("error", Json::str(msg))]).encode()
}

fn respond(stream: &mut TcpStream, status: u16, body: &str) -> std::io::Result<()> {
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        413 => "Payload Too Large",
        422 => "Unprocessable Entity",
        503 => "Service Unavailable",
        _ => "Error",
    };
    let resp = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(resp.as_bytes())
}

/// Minimal blocking HTTP client for tests/examples/loadgen (no reqwest
/// offline). Returns (status, body).
pub fn http_request(
    addr: &std::net::SocketAddr,
    method: &str,
    path: &str,
    body: &str,
) -> std::io::Result<(u16, String)> {
    let mut stream = TcpStream::connect(addr)?;
    stream.set_nodelay(true)?;
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: stgemm\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(req.as_bytes())?;
    let mut reader = BufReader::new(stream);
    let mut status_line = String::new();
    reader.read_line(&mut status_line)?;
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(0);
    let mut content_length = 0usize;
    loop {
        let mut line = String::new();
        reader.read_line(&mut line)?;
        if line.trim_end().is_empty() {
            break;
        }
        if let Some(v) = line
            .to_ascii_lowercase()
            .strip_prefix("content-length:")
            .map(|v| v.trim().to_string())
        {
            content_length = v.parse().unwrap_or(0);
        }
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body)?;
    Ok((status, String::from_utf8_lossy(&body).into_owned()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::BatchPolicy;
    use crate::coordinator::engine::Engine;
    use crate::model::{ModelConfig, TernaryMlp};

    fn start_server() -> (Server, Arc<Router>) {
        let cfg = ModelConfig::from_json(
            r#"{"name":"m1","dims":[8,16,4],"sparsity":0.5,"seed":1}"#,
        )
        .unwrap();
        let engine = Engine::new("m1", TernaryMlp::from_config(&cfg).unwrap());
        let mut router = Router::new();
        router.register(
            engine,
            BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_millis(1),
            },
        );
        let router = Arc::new(router);
        let server = Server::start(Arc::clone(&router), ServerConfig::default()).unwrap();
        (server, router)
    }

    #[test]
    fn infer_roundtrip_over_http() {
        let (server, _router) = start_server();
        let body = format!(
            r#"{{"model":"m1","input":[{}]}}"#,
            vec!["0.5"; 8].join(",")
        );
        let (status, resp) = http_request(&server.local_addr, "POST", "/infer", &body).unwrap();
        assert_eq!(status, 200, "{resp}");
        let v = Json::parse(&resp).unwrap();
        assert_eq!(v.get("output").unwrap().as_arr().unwrap().len(), 4);
    }

    #[test]
    fn health_and_metrics() {
        let (server, _router) = start_server();
        let (status, _) = http_request(&server.local_addr, "GET", "/healthz", "").unwrap();
        assert_eq!(status, 200);
        let (status, body) = http_request(&server.local_addr, "GET", "/metrics", "").unwrap();
        assert_eq!(status, 200);
        assert!(Json::parse(&body).is_ok());
    }

    #[test]
    fn error_paths() {
        let (server, _router) = start_server();
        let a = server.local_addr;
        assert_eq!(http_request(&a, "POST", "/infer", "not json").unwrap().0, 400);
        assert_eq!(
            http_request(&a, "POST", "/infer", r#"{"input":[1]}"#).unwrap().0,
            400
        );
        assert_eq!(
            http_request(&a, "POST", "/infer", r#"{"model":"zzz","input":[1]}"#)
                .unwrap()
                .0,
            503
        );
        // wrong input width → engine-level 422
        assert_eq!(
            http_request(&a, "POST", "/infer", r#"{"model":"m1","input":[1,2]}"#)
                .unwrap()
                .0,
            422
        );
        // zero-row request → client error before batching
        assert_eq!(
            http_request(&a, "POST", "/infer", r#"{"model":"m1","input":[]}"#)
                .unwrap()
                .0,
            400
        );
        assert_eq!(http_request(&a, "GET", "/nope", "").unwrap().0, 404);
    }

    #[test]
    fn concurrent_http_clients() {
        let (server, _router) = start_server();
        let addr = server.local_addr;
        let handles: Vec<_> = (0..12)
            .map(|_| {
                std::thread::spawn(move || {
                    let body = format!(
                        r#"{{"model":"m1","input":[{}]}}"#,
                        vec!["0.1"; 8].join(",")
                    );
                    http_request(&addr, "POST", "/infer", &body).unwrap().0
                })
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), 200);
        }
    }
}
