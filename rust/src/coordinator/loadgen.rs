//! Load generator: closed-loop concurrent clients driving the router
//! (in-process) or the HTTP server, reporting throughput and latency
//! percentiles. Powers the e2e serving benchmark (EXPERIMENTS.md E11).
//!
//! Two workload shapes:
//! - [`LoadGenerator`] — one-shot `/infer` requests (closed loop, N
//!   clients × M requests), reporting request throughput and e2e latency.
//! - [`DecodeLoadGen`] — autoregressive decode sessions against a
//!   [`DecodeScheduler`] (in-process) or the chunked `POST /generate`
//!   endpoint: sessions arrive in bursts, decode lengths are
//!   geometrically distributed, and the report carries tokens/sec plus
//!   inter-token latency percentiles.

use crate::coordinator::decode::DecodeScheduler;
use crate::coordinator::router::Router;
use crate::coordinator::server::{http_request_stream, http_request_timeout};
use crate::util::rng::Rng;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Load generation settings.
#[derive(Debug, Clone)]
pub struct LoadGenerator {
    /// Concurrent closed-loop clients.
    pub clients: usize,
    /// Requests per client.
    pub requests_per_client: usize,
    /// Input width (must match the model's d_in).
    pub d_in: usize,
    /// Model name to target.
    pub model: String,
    /// RNG seed for inputs.
    pub seed: u64,
    /// Per-request bound, applied to both drivers: an in-process request
    /// waits at most this long for its response, and an HTTP request
    /// caps its connect and every read by it — a stalled server counts
    /// as an error instead of hanging the client thread forever.
    pub request_timeout: Duration,
}

/// Aggregated load test results.
#[derive(Debug, Clone)]
pub struct LoadGenReport {
    pub total_requests: usize,
    pub errors: usize,
    pub wall_seconds: f64,
    pub throughput_rps: f64,
    pub latency_us_p50: u64,
    pub latency_us_p95: u64,
    pub latency_us_p99: u64,
    pub latency_us_mean: f64,
    pub mean_batch_size: f64,
}

impl LoadGenReport {
    fn from_latencies(
        mut lat_us: Vec<u64>,
        errors: usize,
        wall: Duration,
        mean_batch: f64,
    ) -> LoadGenReport {
        lat_us.sort_unstable();
        let n = lat_us.len().max(1);
        let pct = |q: f64| lat_us[((q / 100.0 * n as f64).ceil() as usize).clamp(1, n) - 1];
        LoadGenReport {
            total_requests: lat_us.len(),
            errors,
            wall_seconds: wall.as_secs_f64(),
            throughput_rps: lat_us.len() as f64 / wall.as_secs_f64().max(1e-9),
            latency_us_p50: if lat_us.is_empty() { 0 } else { pct(50.0) },
            latency_us_p95: if lat_us.is_empty() { 0 } else { pct(95.0) },
            latency_us_p99: if lat_us.is_empty() { 0 } else { pct(99.0) },
            latency_us_mean: lat_us.iter().sum::<u64>() as f64 / n as f64,
            mean_batch_size: mean_batch,
        }
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "{} reqs in {:.2}s → {:.0} req/s | latency µs p50={} p95={} p99={} mean={:.0} | mean batch {:.2} | errors {}",
            self.total_requests,
            self.wall_seconds,
            self.throughput_rps,
            self.latency_us_p50,
            self.latency_us_p95,
            self.latency_us_p99,
            self.latency_us_mean,
            self.mean_batch_size,
            self.errors
        )
    }
}

impl LoadGenerator {
    /// Drive the router directly (in-process, no HTTP overhead).
    pub fn run_inprocess(&self, router: &Arc<Router>) -> LoadGenReport {
        let errors = Arc::new(AtomicU64::new(0));
        let t0 = Instant::now();
        let handles: Vec<_> = (0..self.clients)
            .map(|c| {
                let router = Arc::clone(router);
                let errors = Arc::clone(&errors);
                let model = self.model.clone();
                let (d_in, n_req, seed) = (self.d_in, self.requests_per_client, self.seed);
                let timeout = self.request_timeout;
                std::thread::spawn(move || {
                    let mut rng = Rng::new(seed + c as u64);
                    let mut lats = Vec::with_capacity(n_req);
                    for _ in 0..n_req {
                        let input: Vec<f32> =
                            (0..d_in).map(|_| rng.f32_range(-1.0, 1.0)).collect();
                        let t = Instant::now();
                        match router.infer_blocking(&model, input, timeout) {
                            Ok(resp) if resp.output.is_ok() => {
                                lats.push(t.elapsed().as_micros() as u64);
                            }
                            _ => {
                                errors.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                    }
                    lats
                })
            })
            .collect();
        let mut all = Vec::new();
        for h in handles {
            all.extend(h.join().expect("client thread"));
        }
        let wall = t0.elapsed();
        let mean_batch = router
            .engine(&self.model)
            .map(|e| e.metrics.mean_batch_size())
            .unwrap_or(0.0);
        LoadGenReport::from_latencies(
            all,
            errors.load(Ordering::Relaxed) as usize,
            wall,
            mean_batch,
        )
    }

    /// Drive the HTTP server (full network path).
    pub fn run_http(&self, addr: std::net::SocketAddr) -> LoadGenReport {
        let errors = Arc::new(AtomicU64::new(0));
        let t0 = Instant::now();
        let handles: Vec<_> = (0..self.clients)
            .map(|c| {
                let errors = Arc::clone(&errors);
                let model = self.model.clone();
                let (d_in, n_req, seed) = (self.d_in, self.requests_per_client, self.seed);
                let timeout = self.request_timeout;
                std::thread::spawn(move || {
                    let mut rng = Rng::new(seed + 31 * c as u64);
                    let mut lats = Vec::with_capacity(n_req);
                    for _ in 0..n_req {
                        let input: Vec<String> = (0..d_in)
                            .map(|_| format!("{:.6}", rng.f32_range(-1.0, 1.0)))
                            .collect();
                        let body = format!(
                            r#"{{"model":"{model}","input":[{}]}}"#,
                            input.join(",")
                        );
                        let t = Instant::now();
                        // Bounded request: a stalled server is an error,
                        // not a forever-blocked client thread.
                        match http_request_timeout(&addr, "POST", "/infer", &body, timeout) {
                            Ok((200, _)) => lats.push(t.elapsed().as_micros() as u64),
                            _ => {
                                errors.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                    }
                    lats
                })
            })
            .collect();
        let mut all = Vec::new();
        for h in handles {
            all.extend(h.join().expect("client thread"));
        }
        let wall = t0.elapsed();
        LoadGenReport::from_latencies(all, errors.load(Ordering::Relaxed) as usize, wall, 0.0)
    }
}

/// Decode-workload settings: concurrent autoregressive sessions with
/// bursty arrivals and geometrically-distributed decode lengths.
#[derive(Debug, Clone)]
pub struct DecodeLoadGen {
    /// Total sessions to run (one client thread each).
    pub sessions: usize,
    /// Sessions launched per arrival burst.
    pub burst: usize,
    /// Pause between bursts.
    pub burst_gap: Duration,
    /// Prompt width (must match the model's d = d_in = d_out).
    pub d: usize,
    /// Model name (`run_generate_http` only).
    pub model: String,
    /// RNG seed for prompts and decode lengths.
    pub seed: u64,
    /// Mean of the geometric decode-length distribution.
    pub mean_tokens: usize,
    /// Per-session bound: admission retries stop at it, and every HTTP
    /// read is capped by it.
    pub request_timeout: Duration,
}

/// Aggregated decode-workload results.
#[derive(Debug, Clone)]
pub struct DecodeLoadReport {
    pub sessions: usize,
    pub errors: usize,
    pub tokens: usize,
    pub wall_seconds: f64,
    pub tokens_per_sec: f64,
    pub intertoken_us_p50: u64,
    pub intertoken_us_p99: u64,
    pub intertoken_us_mean: f64,
}

impl DecodeLoadReport {
    fn from_gaps(
        sessions: usize,
        errors: usize,
        tokens: usize,
        mut gaps_us: Vec<u64>,
        wall: Duration,
    ) -> DecodeLoadReport {
        gaps_us.sort_unstable();
        let n = gaps_us.len().max(1);
        let pct =
            |q: f64| gaps_us[((q / 100.0 * n as f64).ceil() as usize).clamp(1, n) - 1];
        DecodeLoadReport {
            sessions,
            errors,
            tokens,
            wall_seconds: wall.as_secs_f64(),
            tokens_per_sec: tokens as f64 / wall.as_secs_f64().max(1e-9),
            intertoken_us_p50: if gaps_us.is_empty() { 0 } else { pct(50.0) },
            intertoken_us_p99: if gaps_us.is_empty() { 0 } else { pct(99.0) },
            intertoken_us_mean: gaps_us.iter().sum::<u64>() as f64 / n as f64,
        }
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "{} sessions, {} tokens in {:.2}s → {:.0} tok/s | inter-token µs p50={} p99={} mean={:.0} | errors {}",
            self.sessions,
            self.tokens,
            self.wall_seconds,
            self.tokens_per_sec,
            self.intertoken_us_p50,
            self.intertoken_us_p99,
            self.intertoken_us_mean,
            self.errors
        )
    }
}

/// Geometric decode length with the given mean (≥ 1): trials to the
/// first success of a Bernoulli(1/mean), capped at 8× the mean so one
/// unlucky session cannot dominate a run's wall clock.
fn geometric_len(rng: &mut Rng, mean: usize) -> usize {
    let mean = mean.max(1);
    let p = 1.0 / mean as f32;
    let cap = 8 * mean;
    let mut n = 1;
    while rng.f32_range(0.0, 1.0) > p && n < cap {
        n += 1;
    }
    n
}

/// Per-session outcome: (tokens received, inter-token gaps µs, errors).
type SessionOutcome = (usize, Vec<u64>, usize);

impl DecodeLoadGen {
    /// Drive a scheduler directly (in-process). The scheduler's step
    /// loop must be running ([`DecodeScheduler::spawn_loop`]).
    ///
    /// Sessions past the scheduler's capacity retry with a short backoff
    /// until admitted or timed out — bursty arrivals are *supposed* to
    /// overrun capacity; only a session that never gets in is an error.
    pub fn run_scheduler(&self, sched: &Arc<DecodeScheduler>) -> DecodeLoadReport {
        self.run_with(|prompt, len, timeout| {
            let sched = Arc::clone(sched);
            move || {
                let deadline = Instant::now() + timeout;
                let stream = loop {
                    match sched.begin(&prompt, Some(len)) {
                        Ok(s) => break s,
                        Err(e) if e.to_string().contains("overloaded") => {
                            if Instant::now() > deadline {
                                return (0, Vec::new(), 1);
                            }
                            std::thread::sleep(Duration::from_micros(200));
                        }
                        Err(_) => return (0, Vec::new(), 1),
                    }
                };
                let mut gaps = Vec::with_capacity(len);
                let mut tokens = 0usize;
                let mut last = Instant::now();
                let mut first = true;
                while stream.next().is_some() {
                    // The first gap is time-to-first-token, not an
                    // inter-token gap; skip it.
                    if !first {
                        gaps.push(last.elapsed().as_micros() as u64);
                    }
                    first = false;
                    last = Instant::now();
                    tokens += 1;
                }
                (tokens, gaps, 0)
            }
        })
    }

    /// Drive the chunked `POST /generate` endpoint (full network path).
    pub fn run_generate_http(&self, addr: std::net::SocketAddr) -> DecodeLoadReport {
        let model = self.model.clone();
        self.run_with(|prompt, len, timeout| {
            let model = model.clone();
            move || {
                let nums: Vec<String> =
                    prompt.iter().map(|v| format!("{v:.6}")).collect();
                let body = format!(
                    r#"{{"model":"{model}","prompt":[{}],"max_tokens":{len}}}"#,
                    nums.join(",")
                );
                let deadline = Instant::now() + timeout;
                loop {
                    let mut gaps = Vec::with_capacity(len);
                    let mut tokens = 0usize;
                    let mut last = Instant::now();
                    let mut first = true;
                    let result =
                        http_request_stream(&addr, "POST", "/generate", &body, timeout, |_| {
                            if !first {
                                gaps.push(last.elapsed().as_micros() as u64);
                            }
                            first = false;
                            last = Instant::now();
                            tokens += 1;
                            true
                        });
                    match result {
                        Ok((200, _)) => return (tokens, gaps, 0),
                        // 429 = decode capacity full; bursty arrivals are
                        // expected to hit it, so retry to the deadline.
                        Ok((429, _)) if Instant::now() < deadline => {
                            std::thread::sleep(Duration::from_millis(1));
                        }
                        _ => return (0, Vec::new(), 1),
                    }
                }
            }
        })
    }

    /// Shared driver: launch sessions in bursts, each as one client
    /// thread built by `mk_client(prompt, decode_len, timeout)`.
    fn run_with<C, F>(&self, mut mk_client: C) -> DecodeLoadReport
    where
        C: FnMut(Vec<f32>, usize, Duration) -> F,
        F: FnOnce() -> SessionOutcome + Send + 'static,
    {
        let mut rng = Rng::new(self.seed);
        let t0 = Instant::now();
        let mut handles = Vec::with_capacity(self.sessions);
        let mut launched = 0usize;
        while launched < self.sessions {
            let burst = self.burst.max(1).min(self.sessions - launched);
            for _ in 0..burst {
                let prompt: Vec<f32> =
                    (0..self.d).map(|_| rng.f32_range(-1.0, 1.0)).collect();
                let len = geometric_len(&mut rng, self.mean_tokens);
                let client = mk_client(prompt, len, self.request_timeout);
                handles.push(std::thread::spawn(client));
                launched += 1;
            }
            if launched < self.sessions && !self.burst_gap.is_zero() {
                std::thread::sleep(self.burst_gap);
            }
        }
        let mut tokens = 0usize;
        let mut errors = 0usize;
        let mut gaps = Vec::new();
        for h in handles {
            let (t, g, e) = h.join().expect("decode client thread");
            tokens += t;
            gaps.extend(g);
            errors += e;
        }
        DecodeLoadReport::from_gaps(self.sessions, errors, tokens, gaps, t0.elapsed())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::BatchPolicy;
    use crate::coordinator::engine::Engine;
    use crate::model::{ModelConfig, TernaryMlp};

    fn router() -> Arc<Router> {
        let cfg = ModelConfig::from_json(
            r#"{"name":"m1","dims":[16,32,8],"sparsity":0.25,"seed":5}"#,
        )
        .unwrap();
        let mut r = Router::new();
        r.register(
            Engine::new("m1", TernaryMlp::from_config(&cfg).unwrap()),
            BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_micros(500),
            },
        );
        Arc::new(r)
    }

    #[test]
    fn inprocess_load_completes_all_requests() {
        let r = router();
        let gen = LoadGenerator {
            clients: 4,
            requests_per_client: 25,
            d_in: 16,
            model: "m1".into(),
            seed: 1,
            request_timeout: Duration::from_secs(30),
        };
        let report = gen.run_inprocess(&r);
        assert_eq!(report.total_requests, 100);
        assert_eq!(report.errors, 0);
        assert!(report.throughput_rps > 0.0);
        assert!(report.latency_us_p50 <= report.latency_us_p99);
        assert!(!report.summary().is_empty());
    }

    #[test]
    fn decode_load_runs_bursty_sessions_against_a_scheduler() {
        use crate::coordinator::decode::{DecodeConfig, DecodeScheduler};
        use crate::coordinator::metrics::Metrics;
        use crate::plan::Planner;
        let cfg = ModelConfig::from_json(
            r#"{"name":"dec","dims":[16,32,16],"sparsity":0.25,"seed":7}"#,
        )
        .unwrap();
        let mlp = TernaryMlp::planned(&cfg, &Arc::new(Planner::new())).unwrap();
        let cache = Arc::clone(mlp.plan_cache().unwrap());
        let sched = Arc::new(
            DecodeScheduler::new(
                "dec",
                &cache,
                Arc::new(Metrics::new()),
                DecodeConfig {
                    max_sessions: 3,
                    default_max_tokens: 8,
                    ..DecodeConfig::default()
                },
            )
            .unwrap(),
        );
        sched.spawn_loop();
        let gen = DecodeLoadGen {
            sessions: 6, // 2× capacity: the backoff path must absorb it
            burst: 3,
            burst_gap: Duration::from_millis(1),
            d: 16,
            model: "dec".into(),
            seed: 3,
            mean_tokens: 4,
            request_timeout: Duration::from_secs(30),
        };
        let report = gen.run_scheduler(&sched);
        assert_eq!(report.sessions, 6);
        assert_eq!(report.errors, 0, "{}", report.summary());
        assert!(report.tokens >= 6, "every session decodes ≥ 1 token");
        assert!(report.tokens_per_sec > 0.0);
        assert!(report.intertoken_us_p50 <= report.intertoken_us_p99);
        sched.shutdown();
    }

    #[test]
    fn geometric_lengths_hover_around_the_mean() {
        let mut rng = Rng::new(42);
        let n = 2000;
        let total: usize = (0..n).map(|_| geometric_len(&mut rng, 8)).sum();
        let mean = total as f64 / n as f64;
        assert!(
            (4.0..16.0).contains(&mean),
            "geometric mean wildly off: {mean}"
        );
        assert!((0..50).all(|_| geometric_len(&mut rng, 1) == 1));
    }

    #[test]
    fn report_percentiles_from_known_data() {
        let lats: Vec<u64> = (1..=100).collect();
        let rep = LoadGenReport::from_latencies(lats, 0, Duration::from_secs(1), 2.0);
        assert_eq!(rep.latency_us_p50, 50);
        assert_eq!(rep.latency_us_p95, 95);
        assert_eq!(rep.latency_us_p99, 99);
        assert!((rep.throughput_rps - 100.0).abs() < 1e-6);
    }
}
