//! Load generator: closed-loop concurrent clients driving the router
//! (in-process) or the HTTP server, reporting throughput and latency
//! percentiles. Powers the e2e serving benchmark (EXPERIMENTS.md E11).

use crate::coordinator::router::Router;
use crate::coordinator::server::http_request;
use crate::util::rng::Rng;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Load generation settings.
#[derive(Debug, Clone)]
pub struct LoadGenerator {
    /// Concurrent closed-loop clients.
    pub clients: usize,
    /// Requests per client.
    pub requests_per_client: usize,
    /// Input width (must match the model's d_in).
    pub d_in: usize,
    /// Model name to target.
    pub model: String,
    /// RNG seed for inputs.
    pub seed: u64,
}

/// Aggregated load test results.
#[derive(Debug, Clone)]
pub struct LoadGenReport {
    pub total_requests: usize,
    pub errors: usize,
    pub wall_seconds: f64,
    pub throughput_rps: f64,
    pub latency_us_p50: u64,
    pub latency_us_p95: u64,
    pub latency_us_p99: u64,
    pub latency_us_mean: f64,
    pub mean_batch_size: f64,
}

impl LoadGenReport {
    fn from_latencies(
        mut lat_us: Vec<u64>,
        errors: usize,
        wall: Duration,
        mean_batch: f64,
    ) -> LoadGenReport {
        lat_us.sort_unstable();
        let n = lat_us.len().max(1);
        let pct = |q: f64| lat_us[((q / 100.0 * n as f64).ceil() as usize).clamp(1, n) - 1];
        LoadGenReport {
            total_requests: lat_us.len(),
            errors,
            wall_seconds: wall.as_secs_f64(),
            throughput_rps: lat_us.len() as f64 / wall.as_secs_f64().max(1e-9),
            latency_us_p50: if lat_us.is_empty() { 0 } else { pct(50.0) },
            latency_us_p95: if lat_us.is_empty() { 0 } else { pct(95.0) },
            latency_us_p99: if lat_us.is_empty() { 0 } else { pct(99.0) },
            latency_us_mean: lat_us.iter().sum::<u64>() as f64 / n as f64,
            mean_batch_size: mean_batch,
        }
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "{} reqs in {:.2}s → {:.0} req/s | latency µs p50={} p95={} p99={} mean={:.0} | mean batch {:.2} | errors {}",
            self.total_requests,
            self.wall_seconds,
            self.throughput_rps,
            self.latency_us_p50,
            self.latency_us_p95,
            self.latency_us_p99,
            self.latency_us_mean,
            self.mean_batch_size,
            self.errors
        )
    }
}

impl LoadGenerator {
    /// Drive the router directly (in-process, no HTTP overhead).
    pub fn run_inprocess(&self, router: &Arc<Router>) -> LoadGenReport {
        let errors = Arc::new(AtomicU64::new(0));
        let t0 = Instant::now();
        let handles: Vec<_> = (0..self.clients)
            .map(|c| {
                let router = Arc::clone(router);
                let errors = Arc::clone(&errors);
                let model = self.model.clone();
                let (d_in, n_req, seed) = (self.d_in, self.requests_per_client, self.seed);
                std::thread::spawn(move || {
                    let mut rng = Rng::new(seed + c as u64);
                    let mut lats = Vec::with_capacity(n_req);
                    for _ in 0..n_req {
                        let input: Vec<f32> =
                            (0..d_in).map(|_| rng.f32_range(-1.0, 1.0)).collect();
                        let t = Instant::now();
                        match router.infer_blocking(&model, input, Duration::from_secs(30)) {
                            Ok(resp) if resp.output.is_ok() => {
                                lats.push(t.elapsed().as_micros() as u64);
                            }
                            _ => {
                                errors.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                    }
                    lats
                })
            })
            .collect();
        let mut all = Vec::new();
        for h in handles {
            all.extend(h.join().expect("client thread"));
        }
        let wall = t0.elapsed();
        let mean_batch = router
            .engine(&self.model)
            .map(|e| e.metrics.mean_batch_size())
            .unwrap_or(0.0);
        LoadGenReport::from_latencies(
            all,
            errors.load(Ordering::Relaxed) as usize,
            wall,
            mean_batch,
        )
    }

    /// Drive the HTTP server (full network path).
    pub fn run_http(&self, addr: std::net::SocketAddr) -> LoadGenReport {
        let errors = Arc::new(AtomicU64::new(0));
        let t0 = Instant::now();
        let handles: Vec<_> = (0..self.clients)
            .map(|c| {
                let errors = Arc::clone(&errors);
                let model = self.model.clone();
                let (d_in, n_req, seed) = (self.d_in, self.requests_per_client, self.seed);
                std::thread::spawn(move || {
                    let mut rng = Rng::new(seed + 31 * c as u64);
                    let mut lats = Vec::with_capacity(n_req);
                    for _ in 0..n_req {
                        let input: Vec<String> = (0..d_in)
                            .map(|_| format!("{:.6}", rng.f32_range(-1.0, 1.0)))
                            .collect();
                        let body = format!(
                            r#"{{"model":"{model}","input":[{}]}}"#,
                            input.join(",")
                        );
                        let t = Instant::now();
                        match http_request(&addr, "POST", "/infer", &body) {
                            Ok((200, _)) => lats.push(t.elapsed().as_micros() as u64),
                            _ => {
                                errors.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                    }
                    lats
                })
            })
            .collect();
        let mut all = Vec::new();
        for h in handles {
            all.extend(h.join().expect("client thread"));
        }
        let wall = t0.elapsed();
        LoadGenReport::from_latencies(all, errors.load(Ordering::Relaxed) as usize, wall, 0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::BatchPolicy;
    use crate::coordinator::engine::Engine;
    use crate::model::{ModelConfig, TernaryMlp};

    fn router() -> Arc<Router> {
        let cfg = ModelConfig::from_json(
            r#"{"name":"m1","dims":[16,32,8],"sparsity":0.25,"seed":5}"#,
        )
        .unwrap();
        let mut r = Router::new();
        r.register(
            Engine::new("m1", TernaryMlp::from_config(&cfg).unwrap()),
            BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_micros(500),
            },
        );
        Arc::new(r)
    }

    #[test]
    fn inprocess_load_completes_all_requests() {
        let r = router();
        let gen = LoadGenerator {
            clients: 4,
            requests_per_client: 25,
            d_in: 16,
            model: "m1".into(),
            seed: 1,
        };
        let report = gen.run_inprocess(&r);
        assert_eq!(report.total_requests, 100);
        assert_eq!(report.errors, 0);
        assert!(report.throughput_rps > 0.0);
        assert!(report.latency_us_p50 <= report.latency_us_p99);
        assert!(!report.summary().is_empty());
    }

    #[test]
    fn report_percentiles_from_known_data() {
        let lats: Vec<u64> = (1..=100).collect();
        let rep = LoadGenReport::from_latencies(lats, 0, Duration::from_secs(1), 2.0);
        assert_eq!(rep.latency_us_p50, 50);
        assert_eq!(rep.latency_us_p95, 95);
        assert_eq!(rep.latency_us_p99, 99);
        assert!((rep.throughput_rps - 100.0).abs() < 1e-6);
    }
}
