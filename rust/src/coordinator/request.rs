//! Request/response types flowing through the coordinator.

use crate::Error;
use std::sync::mpsc;
use std::time::Instant;

/// An inference request: one input row for a named model.
#[derive(Debug)]
pub struct InferenceRequest {
    pub id: u64,
    pub model: String,
    pub input: Vec<f32>,
    pub enqueued: Instant,
    /// Channel the response is delivered on.
    pub resp_tx: mpsc::Sender<InferenceResponse>,
}

/// The outcome of a request.
#[derive(Debug, Clone)]
pub struct InferenceResponse {
    pub id: u64,
    pub output: Result<Vec<f32>, Error>,
    /// Time spent queued before batch assembly.
    pub queue_us: u64,
    /// Batch compute time (shared by all requests in the batch).
    pub compute_us: u64,
    /// Size of the batch this request rode in.
    pub batch_size: usize,
}

impl InferenceRequest {
    /// Create a request plus the receiver for its response.
    pub fn new(
        id: u64,
        model: impl Into<String>,
        input: Vec<f32>,
    ) -> (InferenceRequest, mpsc::Receiver<InferenceResponse>) {
        let (tx, rx) = mpsc::channel();
        (
            InferenceRequest {
                id,
                model: model.into(),
                input,
                enqueued: Instant::now(),
                resp_tx: tx,
            },
            rx,
        )
    }

    /// Consume the request, delivering `err` as its response (queue time
    /// recorded, no compute). The rejection paths — invalid input shape,
    /// draining model, admission overflow — all answer through here so a
    /// refused request is never silently dropped.
    pub fn reject(self, err: Error) {
        let queue_us = self.enqueued.elapsed().as_micros() as u64;
        let _ = self.resp_tx.send(InferenceResponse {
            id: self.id,
            output: Err(err),
            queue_us,
            compute_us: 0,
            batch_size: 0,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_response_channel() {
        let (req, rx) = InferenceRequest::new(7, "m", vec![1.0, 2.0]);
        assert_eq!(req.id, 7);
        req.resp_tx
            .send(InferenceResponse {
                id: 7,
                output: Ok(vec![3.0]),
                queue_us: 10,
                compute_us: 20,
                batch_size: 4,
            })
            .unwrap();
        let resp = rx.recv().unwrap();
        assert_eq!(resp.id, 7);
        assert_eq!(resp.output.unwrap(), vec![3.0]);
        assert_eq!(resp.batch_size, 4);
    }

    #[test]
    fn reject_delivers_error_response() {
        let (req, rx) = InferenceRequest::new(8, "m", vec![1.0]);
        req.reject(Error::Serve("nope".into()));
        let resp = rx.recv().unwrap();
        assert_eq!(resp.id, 8);
        assert!(resp.output.unwrap_err().to_string().contains("nope"));
        assert_eq!(resp.batch_size, 0);
    }
}
