//! Router: maps model names to engines and owns each model's batcher +
//! batch-loop thread. This is the coordinator's composition root.
//!
//! Registration comes in two flavours: [`Router::register`] with a fixed
//! [`BatchPolicy`], and [`Router::register_autoscaled`], where the batch
//! loop periodically consults a [`LoadController`] and re-sizes the live
//! `max_batch` and the model's plan-cache thread ceiling from observed
//! queue depth, arrival rate and compute latency.

use crate::coordinator::batcher::{BatchPolicy, DynamicBatcher, SubmitError};
use crate::coordinator::engine::Engine;
use crate::coordinator::load::{LoadControlConfig, LoadController};
use crate::coordinator::request::{InferenceRequest, InferenceResponse};
use std::collections::BTreeMap;
use std::sync::atomic::Ordering;
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

struct ModelEntry {
    engine: Arc<Engine>,
    batcher: Arc<DynamicBatcher>,
    loop_handle: Option<JoinHandle<()>>,
}

/// Multi-model router with per-model dynamic batching loops.
pub struct Router {
    models: BTreeMap<String, ModelEntry>,
    next_id: std::sync::atomic::AtomicU64,
}

impl Default for Router {
    fn default() -> Self {
        Self::new()
    }
}

impl Router {
    pub fn new() -> Router {
        Router {
            models: BTreeMap::new(),
            next_id: std::sync::atomic::AtomicU64::new(1),
        }
    }

    /// Register an engine and start its batch loop with a fixed policy.
    pub fn register(&mut self, engine: Engine, policy: BatchPolicy) {
        self.register_inner(engine, policy, None);
    }

    /// Register an engine whose batch ceiling and thread fan-out track
    /// observed load: every `control.adjust_every_batches` executed
    /// batches, the loop re-advises from the model's metrics and applies
    /// the result to the live batcher and plan cache.
    pub fn register_autoscaled(
        &mut self,
        engine: Engine,
        policy: BatchPolicy,
        control: LoadControlConfig,
    ) {
        self.register_inner(engine, policy, Some(LoadController::new(control)));
    }

    fn register_inner(
        &mut self,
        engine: Engine,
        policy: BatchPolicy,
        controller: Option<LoadController>,
    ) {
        let name = engine.name.clone();
        let engine = Arc::new(engine);
        let batcher = Arc::new(
            DynamicBatcher::new(policy).with_metrics(Arc::clone(&engine.metrics)),
        );
        engine
            .metrics
            .max_batch_in_use
            .store(policy.max_batch as u64, Ordering::Relaxed);
        let initial_threads = engine.plan_cache().map(|c| c.threads()).unwrap_or(1);
        engine
            .metrics
            .threads_in_use
            .store(initial_threads as u64, Ordering::Relaxed);
        let loop_engine = Arc::clone(&engine);
        let loop_batcher = Arc::clone(&batcher);
        let handle = std::thread::Builder::new()
            .name(format!("stgemm-batch-{name}"))
            .spawn(move || {
                let mut executed: u64 = 0;
                while let Some(batch) = loop_batcher.next_batch() {
                    loop_engine.run_batch(batch);
                    executed += 1;
                    if let Some(ctl) = &controller {
                        if executed % ctl.cfg().adjust_every_batches == 0 {
                            let advice = ctl.advise_from(&loop_engine.metrics);
                            loop_batcher.set_max_batch(advice.max_batch);
                            loop_engine.set_threads(advice.threads);
                            loop_engine
                                .metrics
                                .max_batch_in_use
                                .store(advice.max_batch as u64, Ordering::Relaxed);
                            loop_engine
                                .metrics
                                .threads_in_use
                                .store(advice.threads as u64, Ordering::Relaxed);
                            loop_engine
                                .metrics
                                .autoscale_adjustments
                                .fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
            })
            .expect("spawn batch loop");
        self.models.insert(
            name,
            ModelEntry {
                engine,
                batcher,
                loop_handle: Some(handle),
            },
        );
    }

    pub fn model_names(&self) -> Vec<&str> {
        self.models.keys().map(|s| s.as_str()).collect()
    }

    pub fn engine(&self, model: &str) -> Option<&Arc<Engine>> {
        self.models.get(model).map(|e| &e.engine)
    }

    /// Submit an input row; returns the response receiver.
    pub fn submit(
        &self,
        model: &str,
        input: Vec<f32>,
    ) -> Result<mpsc::Receiver<InferenceResponse>, String> {
        let entry = self
            .models
            .get(model)
            .ok_or_else(|| format!("unknown model '{model}'"))?;
        let id = self
            .next_id
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        entry
            .engine
            .metrics
            .requests
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let (req, rx) = InferenceRequest::new(id, model, input);
        entry.batcher.submit(req).map_err(|e| {
            entry
                .engine
                .metrics
                .errors
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            match e {
                SubmitError::Closed(_) => "model is shutting down".to_string(),
                SubmitError::EmptyInput(_) => "empty input".to_string(),
            }
        })?;
        Ok(rx)
    }

    /// Submit and block for the response (with timeout).
    pub fn infer_blocking(
        &self,
        model: &str,
        input: Vec<f32>,
        timeout: Duration,
    ) -> Result<InferenceResponse, String> {
        let rx = self.submit(model, input)?;
        rx.recv_timeout(timeout)
            .map_err(|e| format!("inference timed out/disconnected: {e}"))
    }

    /// Stop all batch loops, draining queues first.
    pub fn shutdown(&mut self) {
        for entry in self.models.values() {
            entry.batcher.close();
        }
        for entry in self.models.values_mut() {
            if let Some(h) = entry.loop_handle.take() {
                let _ = h.join();
            }
        }
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ModelConfig, TernaryMlp};
    use crate::plan::Planner;

    fn router() -> Router {
        let cfg = ModelConfig::from_json(
            r#"{"name":"m1","dims":[8,16,4],"sparsity":0.5,"seed":1}"#,
        )
        .unwrap();
        let engine = Engine::new("m1", TernaryMlp::from_config(&cfg).unwrap());
        let mut r = Router::new();
        r.register(
            engine,
            BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
            },
        );
        r
    }

    #[test]
    fn end_to_end_single_request() {
        let r = router();
        let resp = r
            .infer_blocking("m1", vec![0.5; 8], Duration::from_secs(5))
            .unwrap();
        assert_eq!(resp.output.unwrap().len(), 4);
    }

    #[test]
    fn unknown_model_rejected() {
        let r = router();
        assert!(r.submit("nope", vec![0.0; 8]).is_err());
    }

    #[test]
    fn empty_input_rejected_before_batching() {
        let r = router();
        let err = r.submit("m1", vec![]).unwrap_err();
        assert!(err.contains("empty input"), "{err}");
        let e = r.engine("m1").unwrap();
        assert_eq!(
            e.metrics.errors.load(std::sync::atomic::Ordering::Relaxed),
            1
        );
    }

    #[test]
    fn many_concurrent_requests_all_answered() {
        let r = Arc::new(router());
        let handles: Vec<_> = (0..16)
            .map(|_| {
                let r = Arc::clone(&r);
                std::thread::spawn(move || {
                    r.infer_blocking("m1", vec![0.25; 8], Duration::from_secs(5))
                        .unwrap()
                })
            })
            .collect();
        let mut batched = 0usize;
        for h in handles {
            let resp = h.join().unwrap();
            assert!(resp.output.is_ok());
            if resp.batch_size > 1 {
                batched += 1;
            }
        }
        // With 16 parallel requests and max_batch 4, at least some batches
        // should have formed (not a hard guarantee, but overwhelmingly
        // likely; tolerate zero to avoid flakes on slow machines).
        let _ = batched;
    }

    #[test]
    fn autoscaled_model_serves_and_adjusts() {
        let cfg = ModelConfig::from_json(
            r#"{"name":"a1","dims":[8,16,4],"sparsity":0.5,"seed":2}"#,
        )
        .unwrap();
        let engine =
            Engine::from_config(&cfg, &Arc::new(Planner::new())).unwrap();
        let mut r = Router::new();
        r.register_autoscaled(
            engine,
            BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_micros(200),
            },
            LoadControlConfig {
                max_batch: 16,
                max_threads: 4,
                adjust_every_batches: 1, // advise after every batch
                ..LoadControlConfig::default()
            },
        );
        let r = Arc::new(r);
        let handles: Vec<_> = (0..24)
            .map(|_| {
                let r = Arc::clone(&r);
                std::thread::spawn(move || {
                    r.infer_blocking("a1", vec![0.1; 8], Duration::from_secs(10))
                        .unwrap()
                })
            })
            .collect();
        for h in handles {
            assert!(h.join().unwrap().output.is_ok());
        }
        // 24 requests with a batch cap of 16 forces ≥ 2 batches, and the
        // controller advises after every one — so by the time the last
        // response (of a later batch) arrived, at least one adjustment
        // must have been recorded. Gauges are seeded at registration, so
        // only this counter proves the advise loop actually ran.
        let m = &r.engine("a1").unwrap().metrics;
        assert!(
            m.autoscale_adjustments.load(Ordering::Relaxed) >= 1,
            "load controller never re-advised"
        );
        assert!(m.max_batch_in_use.load(Ordering::Relaxed) >= 1);
        assert!(m.threads_in_use.load(Ordering::Relaxed) >= 1);
    }

    #[test]
    fn shutdown_rejects_new_requests() {
        let mut r = router();
        r.shutdown();
        assert!(r.submit("m1", vec![0.0; 8]).is_err());
    }
}
