//! Router: maps model names to engines and owns each model's batcher +
//! batch-loop thread. This is the coordinator's composition root.
//!
//! Registration comes in two flavours: [`Router::register`] with a fixed
//! [`BatchPolicy`], and [`Router::register_autoscaled`], where a
//! [`LoadController`] re-sizes the live `max_batch` and the model's
//! plan-cache thread ceiling from observed queue depth, arrival rate and
//! compute latency — on two triggers:
//!
//! - every `adjust_every_batches` **executed batches** (the batch loop,
//!   applied immediately: real traffic is already steering), and
//! - every [`LoadControlConfig::tick`] on a **timer** with
//!   two-consecutive-tick hysteresis ([`crate::coordinator::load::AdviceHysteresis`]).
//!   The batch-count trigger alone never fires on an idle model (no
//!   batches execute), so a burst's elevated targets would stick forever;
//!   the timer decays them once the arrival-rate EWMA's silence folding
//!   drags the advice back down.

use crate::coordinator::batcher::{BatchPolicy, DynamicBatcher, SubmitError};
use crate::coordinator::engine::Engine;
use crate::coordinator::load::{
    pow2_floor, Advice, AdviceHysteresis, LoadControlConfig, LoadController,
};
use crate::coordinator::request::{InferenceRequest, InferenceResponse};
use std::collections::BTreeMap;
use std::sync::atomic::Ordering;
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

struct ModelEntry {
    engine: Arc<Engine>,
    batcher: Arc<DynamicBatcher>,
    loop_handle: Option<JoinHandle<()>>,
    /// Dropping this stops the autoscale tick thread (its `recv_timeout`
    /// sees the disconnect).
    tick_stop: Option<mpsc::Sender<()>>,
    tick_handle: Option<JoinHandle<()>>,
}

/// Apply one piece of controller advice to a model's live knobs and
/// gauges (shared by the batch-loop and timer-tick triggers).
fn apply_advice(batcher: &DynamicBatcher, engine: &Engine, advice: Advice) {
    batcher.set_max_batch(advice.max_batch);
    engine.set_threads(advice.threads);
    engine
        .metrics
        .max_batch_in_use
        .store(advice.max_batch as u64, Ordering::Relaxed);
    engine
        .metrics
        .threads_in_use
        .store(advice.threads as u64, Ordering::Relaxed);
    engine
        .metrics
        .autoscale_adjustments
        .fetch_add(1, Ordering::Relaxed);
}

/// Multi-model router with per-model dynamic batching loops.
pub struct Router {
    models: BTreeMap<String, ModelEntry>,
    next_id: std::sync::atomic::AtomicU64,
}

impl Default for Router {
    fn default() -> Self {
        Self::new()
    }
}

impl Router {
    pub fn new() -> Router {
        Router {
            models: BTreeMap::new(),
            next_id: std::sync::atomic::AtomicU64::new(1),
        }
    }

    /// Register an engine and start its batch loop with a fixed policy.
    pub fn register(&mut self, engine: Engine, policy: BatchPolicy) {
        self.register_inner(engine, policy, None);
    }

    /// Register an engine whose batch ceiling and thread fan-out track
    /// observed load: every `control.adjust_every_batches` executed
    /// batches — and every `control.tick` of wall clock, so an idle
    /// model's targets decay too — the controller re-advises from the
    /// model's metrics and applies the result to the live batcher and
    /// plan cache.
    pub fn register_autoscaled(
        &mut self,
        engine: Engine,
        policy: BatchPolicy,
        control: LoadControlConfig,
    ) {
        self.register_inner(engine, policy, Some(Arc::new(LoadController::new(control))));
    }

    fn register_inner(
        &mut self,
        engine: Engine,
        policy: BatchPolicy,
        controller: Option<Arc<LoadController>>,
    ) {
        let name = engine.name.clone();
        let engine = Arc::new(engine);
        let batcher = Arc::new(
            DynamicBatcher::new(policy).with_metrics(Arc::clone(&engine.metrics)),
        );
        engine
            .metrics
            .max_batch_in_use
            .store(policy.max_batch as u64, Ordering::Relaxed);
        let mut initial_threads = engine.plan_cache().map(|c| c.threads()).unwrap_or(1);
        // Controller advice only ever lands on powers of two ≤ its
        // `max_threads`, and the warm steps cover exactly those — an
        // autoscaled model whose config seeded a ceiling outside that set
        // (e.g. "threads": 6, or 8 with --max-threads 4) would otherwise
        // build unwarmed plans that become dead weight on the first
        // advice. Fixed-policy models keep the config value untouched
        // (the documented escape hatch).
        if let Some(ctl) = &controller {
            let clamped = pow2_floor(initial_threads.min(ctl.cfg().max_threads));
            if clamped != initial_threads {
                engine.set_threads(clamped);
                initial_threads = clamped;
            }
        }
        engine
            .metrics
            .threads_in_use
            .store(initial_threads as u64, Ordering::Relaxed);
        // Both advise triggers (batch-count and timer tick) serialize on
        // this lock, and each computes its advice from the metrics
        // *inside* the critical section — so a tick that read pre-burst
        // signals can never stomp the batch loop's fresh scale-up, and
        // the gauge pair is never observed torn between two advices.
        let advise_lock = Arc::new(std::sync::Mutex::new(()));
        let loop_engine = Arc::clone(&engine);
        let loop_batcher = Arc::clone(&batcher);
        let loop_controller = controller.clone();
        let loop_advise_lock = Arc::clone(&advise_lock);
        let handle = std::thread::Builder::new()
            .name(format!("stgemm-batch-{name}"))
            .spawn(move || {
                let mut executed: u64 = 0;
                while let Some(batch) = loop_batcher.next_batch() {
                    loop_engine.run_batch(batch);
                    executed += 1;
                    if let Some(ctl) = &loop_controller {
                        if executed % ctl.cfg().adjust_every_batches == 0 {
                            let _guard = loop_advise_lock
                                .lock()
                                .unwrap_or_else(|e| e.into_inner());
                            let advice = ctl.advise_from(&loop_engine.metrics);
                            apply_advice(&loop_batcher, &loop_engine, advice);
                        }
                    }
                }
            })
            .expect("spawn batch loop");
        // Timer-driven advise tick: without it an idle model never
        // re-advises (advice otherwise fires per executed batch), so
        // threads/batch targets could never decay back after a burst.
        let (tick_stop, tick_handle) = match &controller {
            Some(ctl) => {
                let (stop_tx, stop_rx) = mpsc::channel::<()>();
                let ctl = Arc::clone(ctl);
                let tick_engine = Arc::clone(&engine);
                let tick_batcher = Arc::clone(&batcher);
                let tick_advise_lock = Arc::clone(&advise_lock);
                let handle = std::thread::Builder::new()
                    .name(format!("stgemm-tick-{name}"))
                    .spawn(move || {
                        let mut hysteresis = AdviceHysteresis::default();
                        loop {
                            match stop_rx.recv_timeout(ctl.cfg().tick) {
                                Err(mpsc::RecvTimeoutError::Timeout) => {
                                    let _guard = tick_advise_lock
                                        .lock()
                                        .unwrap_or_else(|e| e.into_inner());
                                    let advice = ctl.advise_from(&tick_engine.metrics);
                                    let current = Advice {
                                        max_batch: tick_engine
                                            .metrics
                                            .max_batch_in_use
                                            .load(Ordering::Relaxed)
                                            as usize,
                                        threads: tick_engine
                                            .metrics
                                            .threads_in_use
                                            .load(Ordering::Relaxed)
                                            as usize,
                                    };
                                    if let Some(a) = hysteresis.observe(advice, current) {
                                        apply_advice(&tick_batcher, &tick_engine, a);
                                    }
                                }
                                // Sender dropped (shutdown) or explicit stop.
                                _ => break,
                            }
                        }
                    })
                    .expect("spawn autoscale tick");
                (Some(stop_tx), Some(handle))
            }
            None => (None, None),
        };
        self.models.insert(
            name,
            ModelEntry {
                engine,
                batcher,
                loop_handle: Some(handle),
                tick_stop,
                tick_handle,
            },
        );
    }

    pub fn model_names(&self) -> Vec<&str> {
        self.models.keys().map(|s| s.as_str()).collect()
    }

    pub fn engine(&self, model: &str) -> Option<&Arc<Engine>> {
        self.models.get(model).map(|e| &e.engine)
    }

    /// Submit an input row; returns the response receiver.
    pub fn submit(
        &self,
        model: &str,
        input: Vec<f32>,
    ) -> crate::Result<mpsc::Receiver<InferenceResponse>> {
        let entry = self
            .models
            .get(model)
            .ok_or_else(|| crate::Error::Serve(format!("unknown model '{model}'")))?;
        let id = self
            .next_id
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        entry
            .engine
            .metrics
            .requests
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let (req, rx) = InferenceRequest::new(id, model, input);
        entry.batcher.submit(req).map_err(|e| {
            entry
                .engine
                .metrics
                .errors
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            crate::Error::Serve(match e {
                SubmitError::Closed(_) => "model is shutting down".to_string(),
                SubmitError::EmptyInput(_) => "empty input".to_string(),
            })
        })?;
        Ok(rx)
    }

    /// Submit and block for the response (with timeout).
    pub fn infer_blocking(
        &self,
        model: &str,
        input: Vec<f32>,
        timeout: Duration,
    ) -> crate::Result<InferenceResponse> {
        let rx = self.submit(model, input)?;
        rx.recv_timeout(timeout)
            .map_err(|e| crate::Error::Serve(format!("inference timed out/disconnected: {e}")))
    }

    /// Stop all batch loops (draining queues first) and autoscale ticks.
    pub fn shutdown(&mut self) {
        for entry in self.models.values_mut() {
            entry.batcher.close();
            // Dropping the sender disconnects the tick thread's
            // `recv_timeout` so it exits without waiting out a tick.
            entry.tick_stop.take();
        }
        for entry in self.models.values_mut() {
            if let Some(h) = entry.loop_handle.take() {
                let _ = h.join();
            }
            if let Some(h) = entry.tick_handle.take() {
                let _ = h.join();
            }
        }
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ModelConfig, TernaryMlp};
    use crate::plan::Planner;

    fn router() -> Router {
        let cfg = ModelConfig::from_json(
            r#"{"name":"m1","dims":[8,16,4],"sparsity":0.5,"seed":1}"#,
        )
        .unwrap();
        let engine = Engine::new("m1", TernaryMlp::from_config(&cfg).unwrap());
        let mut r = Router::new();
        r.register(
            engine,
            BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
            },
        );
        r
    }

    #[test]
    fn end_to_end_single_request() {
        let r = router();
        let resp = r
            .infer_blocking("m1", vec![0.5; 8], Duration::from_secs(5))
            .unwrap();
        assert_eq!(resp.output.unwrap().len(), 4);
    }

    #[test]
    fn unknown_model_rejected() {
        let r = router();
        assert!(r.submit("nope", vec![0.0; 8]).is_err());
    }

    #[test]
    fn empty_input_rejected_before_batching() {
        let r = router();
        let err = r.submit("m1", vec![]).unwrap_err();
        assert!(err.contains("empty input"), "{err}");
        let e = r.engine("m1").unwrap();
        assert_eq!(
            e.metrics.errors.load(std::sync::atomic::Ordering::Relaxed),
            1
        );
    }

    #[test]
    fn many_concurrent_requests_all_answered() {
        let r = Arc::new(router());
        let handles: Vec<_> = (0..16)
            .map(|_| {
                let r = Arc::clone(&r);
                std::thread::spawn(move || {
                    r.infer_blocking("m1", vec![0.25; 8], Duration::from_secs(5))
                        .unwrap()
                })
            })
            .collect();
        let mut batched = 0usize;
        for h in handles {
            let resp = h.join().unwrap();
            assert!(resp.output.is_ok());
            if resp.batch_size > 1 {
                batched += 1;
            }
        }
        // With 16 parallel requests and max_batch 4, at least some batches
        // should have formed (not a hard guarantee, but overwhelmingly
        // likely; tolerate zero to avoid flakes on slow machines).
        let _ = batched;
    }

    #[test]
    fn autoscaled_model_serves_and_adjusts() {
        let cfg = ModelConfig::from_json(
            r#"{"name":"a1","dims":[8,16,4],"sparsity":0.5,"seed":2}"#,
        )
        .unwrap();
        let engine =
            Engine::from_config(&cfg, &Arc::new(Planner::new())).unwrap();
        let mut r = Router::new();
        r.register_autoscaled(
            engine,
            BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_micros(200),
            },
            LoadControlConfig {
                max_batch: 16,
                max_threads: 4,
                adjust_every_batches: 1, // advise after every batch
                ..LoadControlConfig::default()
            },
        );
        let r = Arc::new(r);
        let handles: Vec<_> = (0..24)
            .map(|_| {
                let r = Arc::clone(&r);
                std::thread::spawn(move || {
                    r.infer_blocking("a1", vec![0.1; 8], Duration::from_secs(10))
                        .unwrap()
                })
            })
            .collect();
        for h in handles {
            assert!(h.join().unwrap().output.is_ok());
        }
        // 24 requests with a batch cap of 16 forces ≥ 2 batches, and the
        // controller advises after every one — so by the time the last
        // response (of a later batch) arrived, at least one adjustment
        // must have been recorded. Gauges are seeded at registration, so
        // only this counter proves the advise loop actually ran.
        let m = &r.engine("a1").unwrap().metrics;
        assert!(
            m.autoscale_adjustments.load(Ordering::Relaxed) >= 1,
            "load controller never re-advised"
        );
        assert!(m.max_batch_in_use.load(Ordering::Relaxed) >= 1);
        assert!(m.threads_in_use.load(Ordering::Relaxed) >= 1);
    }

    #[test]
    fn autoscaled_registration_clamps_non_pow2_config_threads() {
        let cfg = ModelConfig::from_json(
            r#"{"name":"a3","dims":[8,16,4],"sparsity":0.5,"seed":5,"threads":6}"#,
        )
        .unwrap();
        let engine =
            Engine::from_config(&cfg, &Arc::new(Planner::new())).unwrap();
        let mut r = Router::new();
        r.register_autoscaled(
            engine,
            BatchPolicy::default(),
            LoadControlConfig {
                max_threads: 6,
                // Keep the advise tick out of this test's window so the
                // assertions observe the registration-time seed only.
                tick: Duration::from_secs(3600),
                ..LoadControlConfig::default()
            },
        );
        let e = r.engine("a3").unwrap();
        assert_eq!(
            e.plan_cache().unwrap().threads(),
            4,
            "autoscaled ceiling snaps to pow2 so warmed keys cover it"
        );
        assert_eq!(e.metrics.threads_in_use.load(Ordering::Relaxed), 4);
        // Fixed-policy registration keeps the configured value verbatim.
        let cfg2 = ModelConfig::from_json(
            r#"{"name":"a4","dims":[8,16,4],"sparsity":0.5,"seed":6,"threads":6}"#,
        )
        .unwrap();
        let engine2 =
            Engine::from_config(&cfg2, &Arc::new(Planner::new())).unwrap();
        r.register(engine2, BatchPolicy::default());
        assert_eq!(r.engine("a4").unwrap().plan_cache().unwrap().threads(), 6);
        // A pow2 config seed above the controller's ceiling is clamped to
        // it too: advice can never reach 8, so (bucket, 8) plans would be
        // unwarmed dead weight.
        let cfg3 = ModelConfig::from_json(
            r#"{"name":"a5","dims":[8,16,4],"sparsity":0.5,"seed":7,"threads":8}"#,
        )
        .unwrap();
        let engine3 =
            Engine::from_config(&cfg3, &Arc::new(Planner::new())).unwrap();
        r.register_autoscaled(
            engine3,
            BatchPolicy::default(),
            LoadControlConfig {
                max_threads: 4,
                tick: Duration::from_secs(3600),
                ..LoadControlConfig::default()
            },
        );
        assert_eq!(r.engine("a5").unwrap().plan_cache().unwrap().threads(), 4);
    }

    #[test]
    fn idle_autoscaled_model_decays_targets_via_timer_ticks() {
        let cfg = ModelConfig::from_json(
            r#"{"name":"a2","dims":[8,16,4],"sparsity":0.5,"seed":3}"#,
        )
        .unwrap();
        let engine =
            Engine::from_config(&cfg, &Arc::new(Planner::new())).unwrap();
        let mut r = Router::new();
        r.register_autoscaled(
            engine,
            BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_micros(200),
            },
            LoadControlConfig {
                max_batch: 16,
                max_threads: 4,
                // The batch-count trigger can never fire (no batches
                // execute); only the timer tick can re-advise.
                adjust_every_batches: 1_000_000,
                tick: Duration::from_millis(10),
                ..LoadControlConfig::default()
            },
        );
        // Gauges are seeded from the static policy (max_batch 8). Idle
        // advice is (min_batch = 1, threads = 1); the hysteresis applies
        // it on the second consecutive tick, so the decay must land well
        // within the (generous, anti-flake) deadline.
        let m = Arc::clone(&r.engine("a2").unwrap().metrics);
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        loop {
            let mb = m.max_batch_in_use.load(Ordering::Relaxed);
            let th = m.threads_in_use.load(Ordering::Relaxed);
            if mb == 1 && th == 1 {
                break;
            }
            assert!(
                std::time::Instant::now() < deadline,
                "idle targets never decayed: max_batch={mb} threads={th}"
            );
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(
            m.autoscale_adjustments.load(Ordering::Relaxed) >= 1,
            "timer tick must count as an adjustment"
        );
        r.shutdown();
        // Shutdown joined the tick thread; counters stop moving.
        let after = m.autoscale_adjustments.load(Ordering::Relaxed);
        std::thread::sleep(Duration::from_millis(40));
        assert_eq!(m.autoscale_adjustments.load(Ordering::Relaxed), after);
    }

    #[test]
    fn shutdown_rejects_new_requests() {
        let mut r = router();
        r.shutdown();
        assert!(r.submit("m1", vec![0.0; 8]).is_err());
    }
}
